//! 1-D entropy-grouping substrate for CGC (paper Eq. 4).
//!
//! (Renamed from `cluster` — "cluster" now means the multi-server
//! topology tier, [`crate::shard`].)
//!
//! CGC groups per-channel entropies — scalars — into `g` clusters via
//! 1-D k-means. Two implementations:
//!
//! * [`kmeans_1d`]: Lloyd's algorithm with k-means++ seeding, what the paper
//!   names. Deterministic given the RNG seed.
//! * [`kmeans_1d_exact`]: optimal 1-D k-means via dynamic programming over
//!   the sorted values (O(k·n²) — trivial at n = #channels). Used by the
//!   ablation bench to quantify how far Lloyd lands from the optimum, and
//!   by tests as the ground truth.
//!
//! Empty clusters are repaired by stealing the point farthest from its
//! centroid, so the output always has exactly `min(g, #distinct)` non-empty
//! groups.

use crate::util::rng::Pcg32;

/// Result of a 1-D clustering: per-point group assignment + group centroids.
#[derive(Debug, Clone, PartialEq)]
pub struct Clustering {
    /// assignment[i] = group index of point i (0..groups)
    pub assignment: Vec<usize>,
    /// centroid (mean) of each group
    pub centroids: Vec<f32>,
}

impl Clustering {
    pub fn groups(&self) -> usize {
        self.centroids.len()
    }

    /// Member indices per group.
    pub fn members(&self) -> Vec<Vec<usize>> {
        let mut m = vec![Vec::new(); self.centroids.len()];
        for (i, &g) in self.assignment.iter().enumerate() {
            m[g].push(i);
        }
        m
    }

    /// Within-cluster sum of squares (the Eq. 4 objective).
    pub fn wcss(&self, xs: &[f32]) -> f64 {
        self.assignment
            .iter()
            .zip(xs)
            .map(|(&g, &x)| {
                let d = (x - self.centroids[g]) as f64;
                d * d
            })
            .sum()
    }
}

/// Lloyd's k-means on scalars with k-means++ seeding, best of
/// `RESTARTS` runs by WCSS (cheap at n = #channels, and removes most of
/// Lloyd's seeding variance).
pub fn kmeans_1d(xs: &[f32], g: usize, rng: &mut Pcg32) -> Clustering {
    const RESTARTS: usize = 4;
    let mut best: Option<(f64, Clustering)> = None;
    for _ in 0..RESTARTS {
        let c = kmeans_1d_once(xs, g, rng);
        let w = c.wcss(xs);
        if best.as_ref().is_none_or(|(bw, _)| w < *bw) {
            best = Some((w, c));
        }
    }
    best.unwrap().1
}

/// One Lloyd run with k-means++ seeding.
fn kmeans_1d_once(xs: &[f32], g: usize, rng: &mut Pcg32) -> Clustering {
    assert!(!xs.is_empty());
    let g = effective_k(xs, g);
    let mut centroids = kpp_seed(xs, g, rng);
    let mut assignment = vec![0usize; xs.len()];
    for _iter in 0..100 {
        // assign
        let mut changed = false;
        for (i, &x) in xs.iter().enumerate() {
            let best = nearest(&centroids, x);
            if assignment[i] != best {
                assignment[i] = best;
                changed = true;
            }
        }
        // update
        let mut sums = vec![0.0f64; g];
        let mut counts = vec![0usize; g];
        for (i, &x) in xs.iter().enumerate() {
            sums[assignment[i]] += x as f64;
            counts[assignment[i]] += 1;
        }
        for j in 0..g {
            if counts[j] > 0 {
                centroids[j] = (sums[j] / counts[j] as f64) as f32;
            }
        }
        repair_empty(xs, &mut assignment, &mut centroids, &counts);
        if !changed {
            break;
        }
    }
    normalize_order(xs, assignment, centroids)
}

/// Optimal 1-D k-means via DP on sorted order (Wang & Song 2011 style,
/// quadratic variant). Ground truth for tests/ablation.
pub fn kmeans_1d_exact(xs: &[f32], g: usize) -> Clustering {
    assert!(!xs.is_empty());
    let g = effective_k(xs, g);
    let n = xs.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let sorted: Vec<f64> = order.iter().map(|&i| xs[i] as f64).collect();

    // prefix sums for O(1) segment cost
    let mut ps = vec![0.0f64; n + 1];
    let mut ps2 = vec![0.0f64; n + 1];
    for i in 0..n {
        ps[i + 1] = ps[i] + sorted[i];
        ps2[i + 1] = ps2[i] + sorted[i] * sorted[i];
    }
    let seg_cost = |a: usize, b: usize| -> f64 {
        // cost of sorted[a..=b] as one cluster
        let m = (b - a + 1) as f64;
        let s = ps[b + 1] - ps[a];
        let s2 = ps2[b + 1] - ps2[a];
        (s2 - s * s / m).max(0.0)
    };

    // dp[k][i]: min cost of first i+1 points in k+1 clusters
    let mut dp = vec![vec![f64::INFINITY; n]; g];
    let mut cut = vec![vec![0usize; n]; g];
    for i in 0..n {
        dp[0][i] = seg_cost(0, i);
    }
    for k in 1..g {
        for i in k..n {
            for j in k..=i {
                let cost = dp[k - 1][j - 1] + seg_cost(j, i);
                if cost < dp[k][i] {
                    dp[k][i] = cost;
                    cut[k][i] = j;
                }
            }
        }
    }

    // backtrack segment boundaries
    let mut bounds = Vec::with_capacity(g + 1);
    bounds.push(n);
    let mut i = n - 1;
    for k in (1..g).rev() {
        let j = cut[k][i];
        bounds.push(j);
        i = j - 1;
    }
    bounds.push(0);
    bounds.reverse(); // [0, b1, ..., n]

    let mut assignment = vec![0usize; n];
    let mut centroids = vec![0.0f32; g];
    for k in 0..g {
        let (a, b) = (bounds[k], bounds[k + 1]);
        let mean = (ps[b] - ps[a]) / (b - a) as f64;
        centroids[k] = mean as f32;
        for &orig in &order[a..b] {
            assignment[orig] = k;
        }
    }
    Clustering { assignment, centroids }
}

fn effective_k(xs: &[f32], g: usize) -> usize {
    let mut distinct: Vec<f32> = xs.to_vec();
    distinct.sort_by(|a, b| a.partial_cmp(b).unwrap());
    distinct.dedup();
    g.max(1).min(distinct.len())
}

fn nearest(centroids: &[f32], x: f32) -> usize {
    let mut best = 0;
    let mut bd = f32::INFINITY;
    for (j, &c) in centroids.iter().enumerate() {
        let d = (x - c).abs();
        if d < bd {
            bd = d;
            best = j;
        }
    }
    best
}

fn kpp_seed(xs: &[f32], g: usize, rng: &mut Pcg32) -> Vec<f32> {
    let mut centroids = Vec::with_capacity(g);
    centroids.push(xs[rng.below(xs.len() as u32) as usize]);
    while centroids.len() < g {
        let d2: Vec<f64> = xs
            .iter()
            .map(|&x| {
                let d = (x - centroids[nearest(&centroids, x)]) as f64;
                d * d
            })
            .collect();
        let total: f64 = d2.iter().sum();
        if total <= 0.0 {
            // all points coincide with centroids; fill with copies
            centroids.push(xs[rng.below(xs.len() as u32) as usize]);
            continue;
        }
        let mut r = rng.next_f64() * total;
        let mut pick = xs.len() - 1;
        for (i, &d) in d2.iter().enumerate() {
            r -= d;
            if r <= 0.0 {
                pick = i;
                break;
            }
        }
        centroids.push(xs[pick]);
    }
    centroids
}

fn repair_empty(xs: &[f32], assignment: &mut [usize], centroids: &mut [f32],
                counts: &[usize]) {
    for j in 0..centroids.len() {
        if counts[j] == 0 {
            // steal the point farthest from its centroid
            let (mut far_i, mut far_d) = (0usize, -1.0f32);
            for (i, &x) in xs.iter().enumerate() {
                let d = (x - centroids[assignment[i]]).abs();
                if d > far_d {
                    far_d = d;
                    far_i = i;
                }
            }
            assignment[far_i] = j;
            centroids[j] = xs[far_i];
        }
    }
}

/// Relabel groups so centroids ascend (deterministic output order: group 0
/// is the lowest-entropy group). Drops empty groups.
fn normalize_order(xs: &[f32], assignment: Vec<usize>, centroids: Vec<f32>)
                   -> Clustering {
    let g = centroids.len();
    let mut counts = vec![0usize; g];
    for &a in &assignment {
        counts[a] += 1;
    }
    let mut order: Vec<usize> = (0..g).filter(|&j| counts[j] > 0).collect();
    order.sort_by(|&a, &b| centroids[a].partial_cmp(&centroids[b]).unwrap());
    let mut relabel = vec![usize::MAX; g];
    for (new, &old) in order.iter().enumerate() {
        relabel[old] = new;
    }
    let new_assignment: Vec<usize> = assignment.iter().map(|&a| relabel[a]).collect();
    // recompute centroids exactly
    let ng = order.len();
    let mut sums = vec![0.0f64; ng];
    let mut cnt = vec![0usize; ng];
    for (i, &a) in new_assignment.iter().enumerate() {
        sums[a] += xs[i] as f64;
        cnt[a] += 1;
    }
    let new_centroids: Vec<f32> =
        (0..ng).map(|j| (sums[j] / cnt[j] as f64) as f32).collect();
    Clustering { assignment: new_assignment, centroids: new_centroids }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;

    #[test]
    fn two_obvious_clusters() {
        let xs = [1.0, 1.1, 0.9, 10.0, 10.2, 9.8];
        let mut rng = Pcg32::seeded(1);
        let c = kmeans_1d(&xs, 2, &mut rng);
        assert_eq!(c.groups(), 2);
        assert_eq!(c.assignment[..3], [0, 0, 0]);
        assert_eq!(c.assignment[3..], [1, 1, 1]);
        assert!((c.centroids[0] - 1.0).abs() < 0.2);
        assert!((c.centroids[1] - 10.0).abs() < 0.2);
    }

    #[test]
    fn exact_matches_known_optimum() {
        let xs = [0.0, 0.1, 0.2, 5.0, 5.1, 9.9, 10.0];
        let c = kmeans_1d_exact(&xs, 3);
        assert_eq!(c.assignment, vec![0, 0, 0, 1, 1, 2, 2]);
    }

    #[test]
    fn k_larger_than_distinct_values() {
        let xs = [2.0, 2.0, 2.0];
        let mut rng = Pcg32::seeded(2);
        let c = kmeans_1d(&xs, 5, &mut rng);
        assert_eq!(c.groups(), 1);
        assert_eq!(c.assignment, vec![0, 0, 0]);
    }

    #[test]
    fn single_point() {
        let c = kmeans_1d_exact(&[3.5], 4);
        assert_eq!(c.groups(), 1);
        assert_eq!(c.centroids, vec![3.5]);
    }

    #[test]
    fn centroids_ascend() {
        let mut rng = Pcg32::seeded(3);
        let xs: Vec<f32> = (0..64).map(|_| rng.next_f32() * 8.0).collect();
        let c = kmeans_1d(&xs, 4, &mut rng);
        for w in c.centroids.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn lloyd_near_exact_property() {
        // Lloyd with k-means++ should land within 2x of the DP optimum WCSS
        // on scalar data (usually equal; bound is generous for adversarial
        // random draws).
        Prop::new("lloyd within 2x of optimal wcss").cases(60).max_size(48)
            .run(|rng, size| {
                let n = (size + 2).min(48);
                let xs: Vec<f32> = (0..n).map(|_| rng.next_f32() * 10.0).collect();
                let g = 1 + rng.below(6) as usize;
                let lloyd = kmeans_1d(&xs, g, rng);
                let exact = kmeans_1d_exact(&xs, g);
                let (lw, ew) = (lloyd.wcss(&xs), exact.wcss(&xs));
                if lw + 1e-9 < ew {
                    return Err(format!("lloyd beat exact?! {lw} < {ew}"));
                }
                if lw > 2.0 * ew + 1e-6 {
                    return Err(format!("lloyd {lw} much worse than optimal {ew}"));
                }
                Ok(())
            });
    }

    #[test]
    fn assignment_is_voronoi_property() {
        // every point must be assigned to its nearest centroid
        Prop::new("kmeans voronoi consistency").cases(50).max_size(64)
            .run(|rng, size| {
                let n = (size + 2).min(64);
                let xs: Vec<f32> = (0..n).map(|_| rng.next_gaussian()).collect();
                let g = 1 + rng.below(5) as usize;
                let c = kmeans_1d(&xs, g, rng);
                for (i, &x) in xs.iter().enumerate() {
                    let d_mine = (x - c.centroids[c.assignment[i]]).abs();
                    for &cc in &c.centroids {
                        if (x - cc).abs() + 1e-6 < d_mine {
                            return Err(format!("point {i} not at nearest centroid"));
                        }
                    }
                }
                Ok(())
            });
    }

    #[test]
    fn members_partition_everything() {
        let mut rng = Pcg32::seeded(5);
        let xs: Vec<f32> = (0..32).map(|_| rng.next_f32()).collect();
        let c = kmeans_1d(&xs, 4, &mut rng);
        let members = c.members();
        let total: usize = members.iter().map(|m| m.len()).sum();
        assert_eq!(total, xs.len());
        for m in &members {
            assert!(!m.is_empty());
        }
    }
}
