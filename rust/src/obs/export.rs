//! Live metrics export: the `--metrics-bind` scrape listener and the
//! `--metrics-every` JSONL snapshot writer.
//!
//! [`MetricsExporter`] is a fully non-blocking HTTP/1.1 responder designed
//! to be *serviced* from the single-threaded `PollFleet` event loop (see
//! [`crate::sched::event_loop::PollFleet::attach_exporter`]): every call to
//! [`MetricsExporter::service`] accepts any waiting scrapers, advances each
//! pending connection as far as its socket allows, and returns immediately.
//! No thread is spawned and the training path never blocks on a scraper —
//! a stalled client just holds its connection until the idle timeout.

use std::collections::BTreeMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::Instant;

use crate::obs::metrics;
use crate::util::json::Json;

/// A scraper connection can sit half-open this long before being dropped.
const SCRAPE_IDLE_S: f64 = 5.0;
/// Request-header cap; anything longer is answered anyway (we never parse
/// the request beyond "headers are complete").
const MAX_REQUEST_BYTES: usize = 8192;

struct ScrapeConn {
    stream: TcpStream,
    req: Vec<u8>,
    /// response bytes once the request headers completed; empty = still reading
    resp: Vec<u8>,
    written: usize,
    opened: Instant,
}

/// Non-blocking Prometheus-style text-exposition endpoint.
pub struct MetricsExporter {
    listener: TcpListener,
    conns: Vec<ScrapeConn>,
    addr: SocketAddr,
}

impl MetricsExporter {
    /// Bind `addr` (e.g. `127.0.0.1:9100`) in non-blocking mode.
    pub fn bind(addr: &str) -> Result<MetricsExporter, String> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| format!("--metrics-bind {addr}: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("--metrics-bind {addr}: {e}"))?;
        let addr = listener
            .local_addr()
            .map_err(|e| format!("--metrics-bind: {e}"))?;
        Ok(MetricsExporter { listener, conns: Vec::new(), addr })
    }

    /// The bound address (resolves `:0` ports for tests).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// One non-blocking service pass: accept, progress, reap. Call this
    /// from every event-loop wakeup; it never blocks.
    pub fn service(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(true).is_ok() {
                        self.conns.push(ScrapeConn {
                            stream,
                            req: Vec::new(),
                            resp: Vec::new(),
                            written: 0,
                            opened: Instant::now(),
                        });
                    }
                }
                Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
        self.conns.retain_mut(|conn| {
            if conn.opened.elapsed().as_secs_f64() > SCRAPE_IDLE_S {
                return false;
            }
            !progress(conn)
        });
    }
}

/// Advance one scraper as far as its socket allows; true = finished (drop).
fn progress(conn: &mut ScrapeConn) -> bool {
    if conn.resp.is_empty() {
        let mut buf = [0u8; 1024];
        loop {
            match conn.stream.read(&mut buf) {
                Ok(0) => return true, // peer gave up
                Ok(n) => {
                    conn.req.extend_from_slice(&buf[..n]);
                    if request_complete(&conn.req) || conn.req.len() >= MAX_REQUEST_BYTES {
                        conn.resp = build_response();
                        metrics::SCRAPES.inc();
                        break;
                    }
                }
                Err(ref e) if e.kind() == ErrorKind::WouldBlock => return false,
                Err(ref e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return true,
            }
        }
    }
    while conn.written < conn.resp.len() {
        match conn.stream.write(&conn.resp[conn.written..]) {
            Ok(0) => return true,
            Ok(n) => conn.written += n,
            Err(ref e) if e.kind() == ErrorKind::WouldBlock => return false,
            Err(ref e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return true,
        }
    }
    let _ = conn.stream.flush();
    let _ = conn.stream.shutdown(std::net::Shutdown::Both);
    true
}

/// We answer any request once its headers are in — the endpoint serves one
/// document, so there is nothing to route on.
fn request_complete(req: &[u8]) -> bool {
    req.windows(4).any(|w| w == b"\r\n\r\n") || req.windows(2).any(|w| w == b"\n\n")
}

fn build_response() -> Vec<u8> {
    let body = metrics::render_prometheus();
    let mut out = format!(
        "HTTP/1.1 200 OK\r\n\
         Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\n\
         Connection: close\r\n\r\n",
        body.len()
    )
    .into_bytes();
    out.extend_from_slice(body.as_bytes());
    out
}

/// Appends one whole-registry JSON snapshot per `every` closed rounds
/// (`--metrics-every N --metrics-out FILE`).
pub struct SnapshotWriter {
    file: std::fs::File,
    every: usize,
    pub written: usize,
}

impl SnapshotWriter {
    pub fn create(path: &str, every: usize) -> Result<SnapshotWriter, String> {
        if every == 0 {
            return Err("--metrics-every must be >= 1".to_string());
        }
        let file = std::fs::File::create(path)
            .map_err(|e| format!("--metrics-out {path}: {e}"))?;
        Ok(SnapshotWriter { file, every, written: 0 })
    }

    /// Called at every round close; writes on the cadence boundary.
    pub fn maybe_snapshot(&mut self, round: usize) {
        if (round + 1) % self.every != 0 {
            return;
        }
        let mut row = BTreeMap::new();
        row.insert("round".to_string(), Json::Num(round as f64));
        row.insert(
            "elapsed_ns".to_string(),
            Json::Num(crate::util::logging::elapsed_ns() as f64),
        );
        row.insert("metrics".to_string(), metrics::snapshot_json());
        let mut line = Json::Obj(row).dump();
        line.push('\n');
        if self.file.write_all(line.as_bytes()).is_ok() {
            self.written += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive `service()` like an event loop would until the scrape completes.
    fn scrape_once(ex: &mut MetricsExporter, request: &[u8]) -> String {
        let addr = ex.local_addr();
        let mut client = TcpStream::connect(addr).unwrap();
        client.write_all(request).unwrap();
        client
            .set_read_timeout(Some(std::time::Duration::from_millis(50)))
            .unwrap();
        let mut out = Vec::new();
        let deadline = Instant::now() + std::time::Duration::from_secs(5);
        loop {
            ex.service();
            let mut buf = [0u8; 4096];
            match client.read(&mut buf) {
                Ok(0) => break,
                Ok(n) => out.extend_from_slice(&buf[..n]),
                Err(ref e)
                    if e.kind() == ErrorKind::WouldBlock
                        || e.kind() == ErrorKind::TimedOut => {}
                Err(e) => panic!("scrape read: {e}"),
            }
            assert!(Instant::now() < deadline, "scrape did not finish");
        }
        String::from_utf8(out).unwrap()
    }

    #[test]
    fn serves_exposition_over_http() {
        metrics::POLL_WAKEUPS.inc();
        let mut ex = MetricsExporter::bind("127.0.0.1:0").unwrap();
        let text =
            scrape_once(&mut ex, b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(text.starts_with("HTTP/1.1 200 OK"), "{text}");
        assert!(text.contains("Content-Type: text/plain"));
        assert!(text.contains("slacc_poll_wakeups_total"));
        // Content-Length matches the body exactly
        let (head, body) = text.split_once("\r\n\r\n").unwrap();
        let len: usize = head
            .lines()
            .find_map(|l| l.strip_prefix("Content-Length: "))
            .unwrap()
            .parse()
            .unwrap();
        assert_eq!(len, body.len());
        assert!(ex.conns.is_empty(), "finished scraper must be reaped");
    }

    #[test]
    fn service_never_blocks_with_idle_scraper() {
        let mut ex = MetricsExporter::bind("127.0.0.1:0").unwrap();
        // connect but send nothing — service passes must return instantly
        let _idle = TcpStream::connect(ex.local_addr()).unwrap();
        for _ in 0..3 {
            let t = Instant::now();
            ex.service();
            assert!(t.elapsed().as_millis() < 100, "service must not block");
        }
        assert_eq!(ex.conns.len(), 1, "idle scraper stays pending");
        let n = metrics::SCRAPES.get();
        // a second, real scraper is served while the idle one hangs
        let text =
            scrape_once(&mut ex, b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(text.contains("slacc_metrics_scrapes_total"));
        assert!(metrics::SCRAPES.get() > n);
    }

    #[test]
    fn snapshot_writer_honors_cadence() {
        let path = std::env::temp_dir().join("slacc_snapshot_test.jsonl");
        let path = path.to_str().unwrap().to_string();
        let mut w = SnapshotWriter::create(&path, 2).unwrap();
        for round in 0..5 {
            w.maybe_snapshot(round);
        }
        assert_eq!(w.written, 2); // rounds 1 and 3
        let text = std::fs::read_to_string(&path).unwrap();
        let rows: Vec<Json> =
            text.lines().map(|l| Json::parse(l).unwrap()).collect();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].at(&["round"]), &Json::Num(1.0));
        assert_eq!(rows[1].at(&["round"]), &Json::Num(3.0));
        match rows[0].at(&["metrics", "counters"]) {
            Json::Obj(m) => assert!(m.contains_key("slacc_rounds_closed_total")),
            other => panic!("counters must be an object, got {other:?}"),
        }
        let _ = std::fs::remove_file(&path);
        assert!(SnapshotWriter::create(&path, 0).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
