//! Process-global lock-free metrics registry.
//!
//! Instruments are `static` items with `const` constructors, so handles are
//! resolved at compile time and the hot path is exactly one relaxed atomic
//! RMW — no locks, no map lookups, no steady-state allocation (asserted by
//! the counting-allocator audit in `benches/obs.rs`). The registry is the
//! fixed set of instruments enumerated by [`counters`]/[`gauges`]/
//! [`histograms`]; exporters ([`render_prometheus`], [`snapshot_json`], the
//! [`rollup_blob`] piggybacked on `ShardSync`) iterate that set.
//!
//! Counters are cumulative for the process lifetime (Prometheus counter
//! semantics): sessions sharing a process accumulate, and readers that want
//! per-session figures take before/after deltas.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};

use crate::quant::payload::{ByteReader, ByteWriter};
use crate::util::json::Json;

/// Monotonically increasing event/byte count.
pub struct Counter {
    base: &'static str,
    /// Prometheus label pairs without braces (e.g. `stream="uplink"`),
    /// empty for unlabelled instruments.
    label: &'static str,
    help: &'static str,
    v: AtomicU64,
}

impl Counter {
    pub const fn new(base: &'static str, label: &'static str, help: &'static str) -> Counter {
        Counter { base, label, help, v: AtomicU64::new(0) }
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }

    /// `base{label}` — the exposition identity (also the roll-up key).
    pub fn full_name(&self) -> String {
        full_name(self.base, self.label)
    }
}

/// Point-in-time signed level (queue depth, open connections).
pub struct Gauge {
    base: &'static str,
    label: &'static str,
    help: &'static str,
    v: AtomicI64,
}

impl Gauge {
    pub const fn new(base: &'static str, label: &'static str, help: &'static str) -> Gauge {
        Gauge { base, label, help, v: AtomicI64::new(0) }
    }

    #[inline]
    pub fn set(&self, v: i64) {
        self.v.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: i64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }

    pub fn full_name(&self) -> String {
        full_name(self.base, self.label)
    }
}

/// Fixed power-of-two buckets: bucket `i` holds observations `v` with
/// `floor(log2(v)) == i` (`v == 0` lands in bucket 0), clamped to the last
/// bucket. 36 buckets cover 1ns .. ~34s for nanosecond timings.
pub const HIST_BUCKETS: usize = 36;

pub struct Histogram {
    base: &'static str,
    label: &'static str,
    help: &'static str,
    buckets: [AtomicU64; HIST_BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

#[inline]
fn bucket_of(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        ((63 - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }
}

impl Histogram {
    pub const fn new(base: &'static str, label: &'static str, help: &'static str) -> Histogram {
        // array-repeat of a const item is the const-constructible form of
        // [AtomicU64::new(0); N]; the interior mutability is the point here
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Histogram {
            base,
            label,
            help,
            buckets: [ZERO; HIST_BUCKETS],
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn observe(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn full_name(&self) -> String {
        full_name(self.base, self.label)
    }
}

fn full_name(base: &str, label: &str) -> String {
    if label.is_empty() {
        base.to_string()
    } else {
        format!("{base}{{{label}}}")
    }
}

// ---------------------------------------------------------------- event loop

pub static POLL_WAKEUPS: Counter = Counter::new(
    "slacc_poll_wakeups_total",
    "",
    "event-loop poll(2) wakeups",
);
pub static FRAMES_RECV: Counter = Counter::new(
    "slacc_frames_recv_total",
    "",
    "protocol frames decoded off sockets by the event loop",
);
pub static FRAMES_SENT: Counter = Counter::new(
    "slacc_frames_sent_total",
    "",
    "protocol frames written to sockets by the event loop",
);
pub static NET_RX_BYTES: Counter = Counter::new(
    "slacc_net_rx_bytes_total",
    "",
    "framed bytes read off sockets (header + body)",
);
pub static NET_TX_BYTES: Counter = Counter::new(
    "slacc_net_tx_bytes_total",
    "",
    "framed bytes written to sockets (header + body)",
);
pub static QUEUE_DEPTH: Gauge = Gauge::new(
    "slacc_queue_depth",
    "",
    "frames parked in the event loop's arrival queue",
);
pub static OPEN_CONNS: Gauge = Gauge::new(
    "slacc_open_conns",
    "",
    "device sockets the event loop is driving",
);
pub static READY_EVENTS: Counter = Counter::new(
    "slacc_ready_events_total",
    "",
    "per-socket readiness events dispatched by the event loop (O(ready) work)",
);
pub static WRITE_STALLS: Counter = Counter::new(
    "slacc_write_stall_total",
    "",
    "writes aborted after stalling past --write-stall-secs (peer not reading)",
);
pub static CONN_BUF_BYTES: Gauge = Gauge::new(
    "slacc_conn_buf_bytes",
    "",
    "bytes of per-connection decode-ring capacity currently retained",
);

// -------------------------------------------------------- elastic membership

pub static FLEET_SIZE: Gauge = Gauge::new(
    "slacc_fleet_size",
    "",
    "devices currently admitted to the session (Active or Readmitted)",
);
pub static JOINS_TOTAL: Counter = Counter::new(
    "slacc_joins_total",
    "",
    "mid-session Join admissions completed",
);
pub static DEPARTURES_TOTAL: Counter = Counter::new(
    "slacc_departures_total",
    "",
    "mid-session departures (peer hang-ups, write stalls, Leave frames)",
);
pub static READMITS_TOTAL: Counter = Counter::new(
    "slacc_readmits_total",
    "",
    "Join admissions that returned a previously departed device",
);
pub static WRITE_BATCHES_TOTAL: Counter = Counter::new(
    "slacc_write_batches_total",
    "",
    "syscalls saved by coalescing adjacent control frames into one writev",
);
pub static CHECKPOINT_WRITE_NS: Histogram = Histogram::new(
    "slacc_checkpoint_write_ns",
    "",
    "nanoseconds per coordinator checkpoint write (serialize + fsync-free rename)",
);

// ------------------------------------------------------------ server compute

pub static SERVER_STEPS: Counter = Counter::new(
    "slacc_server_steps_total",
    "",
    "server_step items executed (one per device Activations)",
);
pub static SERVER_DISPATCHES: Counter = Counter::new(
    "slacc_server_dispatches_total",
    "",
    "compute dispatches those steps crossed the backend boundary in",
);
pub static DISPATCH_WIDTH: Histogram = Histogram::new(
    "slacc_dispatch_width",
    "",
    "devices coalesced per server_step_batch dispatch",
);
pub static SERVER_STEP_BATCH_NS: Histogram = Histogram::new(
    "slacc_server_step_batch_ns",
    "",
    "wall-clock nanoseconds per server_step_batch dispatch",
);

// ------------------------------------------- rounds / accounted wire traffic

pub static ROUNDS_CLOSED: Counter = Counter::new(
    "slacc_rounds_closed_total",
    "",
    "training rounds closed by the scheduler",
);
/// Accounted wire bytes per stream kind — incremented at round close with
/// exactly the [`crate::net::RoundCost`] figures that feed the end-of-run
/// report, so scraped totals and `TrainReport` totals agree to the byte.
pub static WIRE_UP_BYTES: Counter = Counter::new(
    "slacc_wire_bytes_total",
    "stream=\"uplink\"",
    "accounted payload bytes per stream (matches RoundCost totals)",
);
pub static WIRE_DOWN_BYTES: Counter = Counter::new(
    "slacc_wire_bytes_total",
    "stream=\"downlink\"",
    "accounted payload bytes per stream (matches RoundCost totals)",
);
pub static WIRE_SYNC_BYTES: Counter = Counter::new(
    "slacc_wire_bytes_total",
    "stream=\"sync\"",
    "accounted payload bytes per stream (matches RoundCost totals)",
);

// -------------------------------------------------------------- codec sites
// Measured where a codec runs (device worker or server), so in-process
// loopback sessions see both ends of each stream; the accounted per-round
// totals above are the wire-truth axis.

pub static CODEC_ENC_NS_UP: Histogram = Histogram::new(
    "slacc_codec_encode_ns",
    "stream=\"uplink\"",
    "nanoseconds per codec encode",
);
pub static CODEC_ENC_NS_DOWN: Histogram = Histogram::new(
    "slacc_codec_encode_ns",
    "stream=\"downlink\"",
    "nanoseconds per codec encode",
);
pub static CODEC_ENC_NS_SYNC: Histogram = Histogram::new(
    "slacc_codec_encode_ns",
    "stream=\"sync\"",
    "nanoseconds per codec encode",
);
pub static CODEC_DEC_NS_UP: Histogram = Histogram::new(
    "slacc_codec_decode_ns",
    "stream=\"uplink\"",
    "nanoseconds per codec decode",
);
pub static CODEC_DEC_NS_DOWN: Histogram = Histogram::new(
    "slacc_codec_decode_ns",
    "stream=\"downlink\"",
    "nanoseconds per codec decode",
);
pub static CODEC_DEC_NS_SYNC: Histogram = Histogram::new(
    "slacc_codec_decode_ns",
    "stream=\"sync\"",
    "nanoseconds per codec decode",
);
pub static CODEC_ENC_BYTES_UP: Counter = Counter::new(
    "slacc_codec_encode_bytes_total",
    "stream=\"uplink\"",
    "envelope bytes produced by codec encodes",
);
pub static CODEC_ENC_BYTES_DOWN: Counter = Counter::new(
    "slacc_codec_encode_bytes_total",
    "stream=\"downlink\"",
    "envelope bytes produced by codec encodes",
);
pub static CODEC_ENC_BYTES_SYNC: Counter = Counter::new(
    "slacc_codec_encode_bytes_total",
    "stream=\"sync\"",
    "envelope bytes produced by codec encodes",
);
pub static CODEC_DEC_BYTES_UP: Counter = Counter::new(
    "slacc_codec_decode_bytes_total",
    "stream=\"uplink\"",
    "envelope bytes consumed by codec decodes",
);
pub static CODEC_DEC_BYTES_DOWN: Counter = Counter::new(
    "slacc_codec_decode_bytes_total",
    "stream=\"downlink\"",
    "envelope bytes consumed by codec decodes",
);
pub static CODEC_DEC_BYTES_SYNC: Counter = Counter::new(
    "slacc_codec_decode_bytes_total",
    "stream=\"sync\"",
    "envelope bytes consumed by codec decodes",
);

// --------------------------------------------------------------- shard tier

pub static SHARD_SYNCS: Counter = Counter::new(
    "slacc_shard_syncs_total",
    "",
    "cross-shard sync exchanges completed",
);
pub static SHARD_SYNC_WAIT_NS: Histogram = Histogram::new(
    "slacc_shard_sync_wait_ns",
    "",
    "nanoseconds blocked at the shard-sync barrier (push sent to merge received)",
);
pub static FEDAVG_NS: Histogram = Histogram::new(
    "slacc_fedavg_ns",
    "",
    "nanoseconds per cross-shard FedAvg merge",
);

// ------------------------------------------------------------------ tracing

/// Span events overwritten in a full ring before a drain could save them —
/// nonzero means `--trace-out` files have holes.
pub static TRACE_DROPPED: Counter = Counter::new(
    "slacc_trace_dropped_total",
    "",
    "trace span events overwritten before drain (ring overflow)",
);

// -------------------------------------------------- channel-entropy drift
// Windowed mean/variance of the per-encode ACII channel-entropy means,
// recorded from the SL-ACC entropy paths (`codecs/slacc.rs`,
// `codecs/selection.rs`) via `codecs::stream::record_entropy`. Milli-bit
// units keep the integer gauge precise enough for the renegotiation loop
// (ROADMAP item 4) to see drift.

pub static ENTROPY_MEAN_UP: Gauge = Gauge::new(
    "slacc_entropy_mean_milli",
    "stream=\"uplink\"",
    "windowed mean of per-encode channel-entropy means (milli-bits)",
);
pub static ENTROPY_MEAN_DOWN: Gauge = Gauge::new(
    "slacc_entropy_mean_milli",
    "stream=\"downlink\"",
    "windowed mean of per-encode channel-entropy means (milli-bits)",
);
pub static ENTROPY_MEAN_SYNC: Gauge = Gauge::new(
    "slacc_entropy_mean_milli",
    "stream=\"sync\"",
    "windowed mean of per-encode channel-entropy means (milli-bits)",
);
pub static ENTROPY_VAR_UP: Gauge = Gauge::new(
    "slacc_entropy_var_milli",
    "stream=\"uplink\"",
    "windowed variance of per-encode channel-entropy means (milli-bits^2)",
);
pub static ENTROPY_VAR_DOWN: Gauge = Gauge::new(
    "slacc_entropy_var_milli",
    "stream=\"downlink\"",
    "windowed variance of per-encode channel-entropy means (milli-bits^2)",
);
pub static ENTROPY_VAR_SYNC: Gauge = Gauge::new(
    "slacc_entropy_var_milli",
    "stream=\"sync\"",
    "windowed variance of per-encode channel-entropy means (milli-bits^2)",
);

// ----------------------------------------------------------------- exporter

pub static SCRAPES: Counter = Counter::new(
    "slacc_metrics_scrapes_total",
    "",
    "metrics-endpoint scrapes served",
);

/// Every counter, same-base instruments adjacent (exposition groups TYPE
/// lines by base name). This order is also the roll-up wire order.
pub fn counters() -> &'static [&'static Counter] {
    &[
        &POLL_WAKEUPS,
        &FRAMES_RECV,
        &FRAMES_SENT,
        &NET_RX_BYTES,
        &NET_TX_BYTES,
        &SERVER_STEPS,
        &SERVER_DISPATCHES,
        &ROUNDS_CLOSED,
        &WIRE_UP_BYTES,
        &WIRE_DOWN_BYTES,
        &WIRE_SYNC_BYTES,
        &CODEC_ENC_BYTES_UP,
        &CODEC_ENC_BYTES_DOWN,
        &CODEC_ENC_BYTES_SYNC,
        &CODEC_DEC_BYTES_UP,
        &CODEC_DEC_BYTES_DOWN,
        &CODEC_DEC_BYTES_SYNC,
        &SHARD_SYNCS,
        &TRACE_DROPPED,
        &SCRAPES,
        &READY_EVENTS,
        &WRITE_STALLS,
        &JOINS_TOTAL,
        &DEPARTURES_TOTAL,
        &READMITS_TOTAL,
        &WRITE_BATCHES_TOTAL,
    ]
}

pub fn gauges() -> &'static [&'static Gauge] {
    &[
        &QUEUE_DEPTH,
        &OPEN_CONNS,
        &ENTROPY_MEAN_UP,
        &ENTROPY_MEAN_DOWN,
        &ENTROPY_MEAN_SYNC,
        &ENTROPY_VAR_UP,
        &ENTROPY_VAR_DOWN,
        &ENTROPY_VAR_SYNC,
        &CONN_BUF_BYTES,
        &FLEET_SIZE,
    ]
}

pub fn histograms() -> &'static [&'static Histogram] {
    &[
        &DISPATCH_WIDTH,
        &SERVER_STEP_BATCH_NS,
        &CODEC_ENC_NS_UP,
        &CODEC_ENC_NS_DOWN,
        &CODEC_ENC_NS_SYNC,
        &CODEC_DEC_NS_UP,
        &CODEC_DEC_NS_DOWN,
        &CODEC_DEC_NS_SYNC,
        &SHARD_SYNC_WAIT_NS,
        &FEDAVG_NS,
        &CHECKPOINT_WRITE_NS,
    ]
}

/// Prometheus text exposition (format 0.0.4) of the whole registry.
pub fn render_prometheus() -> String {
    let mut out = String::with_capacity(8192);
    let mut last = "";
    for c in counters() {
        if c.base != last {
            out.push_str(&format!("# HELP {} {}\n# TYPE {} counter\n", c.base, c.help, c.base));
            last = c.base;
        }
        out.push_str(&format!("{} {}\n", c.full_name(), c.get()));
    }
    for g in gauges() {
        out.push_str(&format!("# HELP {} {}\n# TYPE {} gauge\n", g.base, g.help, g.base));
        out.push_str(&format!("{} {}\n", g.full_name(), g.get()));
    }
    last = "";
    for h in histograms() {
        if h.base != last {
            out.push_str(&format!(
                "# HELP {} {}\n# TYPE {} histogram\n",
                h.base, h.help, h.base
            ));
            last = h.base;
        }
        let sep = if h.label.is_empty() { "" } else { "," };
        let mut cum = 0u64;
        for (i, b) in h.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            // bucket i holds v < 2^(i+1); with integer observations that is
            // exactly the `le = 2^(i+1)-1` cumulative bound
            let le = (1u128 << (i + 1)) - 1;
            out.push_str(&format!(
                "{}_bucket{{{}{}le=\"{}\"}} {}\n",
                h.base, h.label, sep, le, cum
            ));
        }
        out.push_str(&format!(
            "{}_bucket{{{}{}le=\"+Inf\"}} {}\n",
            h.base, h.label, sep, cum
        ));
        out.push_str(&format!("{}_sum{{{}}} {}\n", h.base, h.label, h.sum()));
        out.push_str(&format!("{}_count{{{}}} {}\n", h.base, h.label, h.count()));
    }
    out
}

/// Whole-registry snapshot as one JSON object (the `--metrics-every` JSONL
/// row body): counters/gauges by full name, histograms as `{count, sum}`.
pub fn snapshot_json() -> Json {
    let mut counters_o = BTreeMap::new();
    for c in counters() {
        counters_o.insert(c.full_name(), Json::Num(c.get() as f64));
    }
    let mut gauges_o = BTreeMap::new();
    for g in gauges() {
        gauges_o.insert(g.full_name(), Json::Num(g.get() as f64));
    }
    let mut hists_o = BTreeMap::new();
    for h in histograms() {
        hists_o.insert(
            h.full_name(),
            Json::obj(vec![
                ("count", Json::Num(h.count() as f64)),
                ("sum", Json::Num(h.sum() as f64)),
            ]),
        );
    }
    let mut root = BTreeMap::new();
    root.insert("counters".to_string(), Json::Obj(counters_o));
    root.insert("gauges".to_string(), Json::Obj(gauges_o));
    root.insert("histograms".to_string(), Json::Obj(hists_o));
    Json::Obj(root)
}

// ------------------------------------------------- shard→coordinator roll-up

/// Roll-up blob version (inside the `ShardSync` metrics field).
const ROLLUP_VERSION: u8 = 1;

/// Compact cumulative counter snapshot piggybacked on the `ShardSync`
/// exchange: `(fnv1a(full_name), value)` pairs in [`counters`] order. The
/// coordinator resolves hashes against its own registry (same binary, same
/// instrument set), so names never travel on the wire.
pub fn rollup_blob() -> Vec<u8> {
    let cs = counters();
    let mut w = ByteWriter::with_capacity(1 + 4 + cs.len() * 16);
    w.u8(ROLLUP_VERSION);
    w.u32(cs.len() as u32);
    for c in cs {
        w.u64(crate::codecs::stream::fnv1a(&c.full_name()));
        w.u64(c.get());
    }
    w.finish()
}

/// Parse a roll-up blob into `(name_hash, value)` pairs. An empty blob is a
/// valid "nothing to report" (pre-telemetry peers, coordinator→shard legs).
pub fn parse_rollup(blob: &[u8]) -> Result<Vec<(u64, u64)>, String> {
    if blob.is_empty() {
        return Ok(Vec::new());
    }
    let mut r = ByteReader::new(blob);
    let ver = r.u8()?;
    if ver != ROLLUP_VERSION {
        return Err(format!("unknown metrics roll-up version {ver}"));
    }
    let n = r.u32()? as usize;
    if n > 4096 {
        return Err(format!("roll-up claims {n} counters (cap 4096)"));
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push((r.u64()?, r.u64()?));
    }
    Ok(out)
}

/// Resolve a roll-up name hash against the local registry.
pub fn counter_name(hash: u64) -> Option<String> {
    counters().iter().find_map(|c| {
        let name = c.full_name();
        (crate::codecs::stream::fnv1a(&name) == hash).then_some(name)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(4), 2);
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn histogram_accumulates() {
        static H: Histogram = Histogram::new("test_hist_ns", "", "test");
        H.observe(0);
        H.observe(3);
        H.observe(1 << 20);
        assert_eq!(H.count(), 3);
        assert_eq!(H.sum(), 3 + (1 << 20));
    }

    #[test]
    fn exposition_contains_every_instrument() {
        POLL_WAKEUPS.inc();
        WIRE_UP_BYTES.add(10);
        QUEUE_DEPTH.set(3);
        DISPATCH_WIDTH.observe(4);
        let text = render_prometheus();
        assert!(text.contains("# TYPE slacc_poll_wakeups_total counter"));
        assert!(text.contains("slacc_wire_bytes_total{stream=\"uplink\"}"));
        assert!(text.contains("# TYPE slacc_queue_depth gauge"));
        assert!(text.contains("slacc_dispatch_width_bucket{le=\"+Inf\"}"));
        assert!(text.contains("slacc_dispatch_width_count{}"));
        // every registered base appears with a TYPE line exactly once
        for c in counters() {
            assert!(text.contains(&format!("# TYPE {} counter", c.base)), "{}", c.base);
        }
        for h in histograms() {
            assert!(text.contains(&format!("# TYPE {} histogram", h.base)), "{}", h.base);
        }
    }

    #[test]
    fn snapshot_json_parses_back() {
        let j = snapshot_json();
        let parsed = Json::parse(&j.dump()).unwrap();
        match parsed {
            Json::Obj(m) => {
                assert!(m.contains_key("counters"));
                assert!(m.contains_key("gauges"));
                assert!(m.contains_key("histograms"));
            }
            other => panic!("snapshot must be an object, got {other:?}"),
        }
    }

    #[test]
    fn rollup_round_trips_and_resolves() {
        FRAMES_RECV.add(7);
        let blob = rollup_blob();
        let pairs = parse_rollup(&blob).unwrap();
        assert_eq!(pairs.len(), counters().len());
        for (hash, _) in &pairs {
            assert!(counter_name(*hash).is_some(), "hash {hash:#x} must resolve");
        }
        // values snapshot real counter state (FRAMES_RECV >= 7)
        let frames = pairs
            .iter()
            .find(|(h, _)| counter_name(*h).as_deref() == Some("slacc_frames_recv_total"))
            .unwrap();
        assert!(frames.1 >= 7);
        // empty blob is the valid "nothing to report"
        assert!(parse_rollup(&[]).unwrap().is_empty());
        // truncated blob is rejected, not mis-read
        assert!(parse_rollup(&blob[..blob.len() - 3]).is_err());
    }
}
