//! `slacc trace`: offline cross-node trace analysis.
//!
//! Each node of a distributed session records spans into its own
//! `--trace-out FILE` with its own monotonic clock. This module merges
//! those files into one causally-ordered per-round timeline:
//!
//! 1. **Clock alignment** — every file opens with a header row carrying
//!    the per-device anchors stamped during the Hello exchange
//!    ([`crate::obs::span::record_anchor`]): the server stamps its clock at
//!    HelloAck send, the device stamps its own at HelloAck receipt. The two
//!    stamps for one gid differ by the clocks' offset (± one-way latency),
//!    so shifting a device file by `server_anchor - device_anchor` puts it
//!    on its server's clock — good to well under a round's duration, which
//!    is all stage attribution needs.
//! 2. **Round joining** — the server's `round` spans define per-round
//!    windows. Spans carrying a `round` attribute join directly; gid-only
//!    spans (`queue_wait`, `write_park` — recorded where the round is not
//!    in scope) join by time containment, falling back to the nearest
//!    window inside the session's round phase. Handshake/shutdown spans
//!    outside the phase are ignored.
//! 3. **Critical path** — per round, the device whose stage chain ends
//!    last is the critical (straggling) device; its per-stage durations,
//!    plus derived wire gaps (`uplink_wire`, `downlink_wire`) and an
//!    explicit `other` remainder, decompose the round wall clock. The
//!    stage with the largest share bounded the round.
//!
//! The analyzer is pure (parse → [`analyze`] → [`render_table`] /
//! [`summary`] / [`chrome_json`]); `slacc trace` in `main.rs` is a thin
//! I/O wrapper around it.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// One parsed `--trace-out` JSONL file.
#[derive(Debug, Clone)]
pub struct NodeTrace {
    pub path: String,
    /// node role from the header: "server", "device", "coordinator", ...
    pub role: String,
    pub shard: u64,
    /// session fingerprint (hex string; empty if the node never validated
    /// a Hello exchange)
    pub session_fp: String,
    /// (gid, this node's `elapsed_ns` at the Hello exchange for that gid)
    pub anchors: Vec<(u32, u64)>,
    pub events: Vec<RawEvent>,
    /// span events this node's rings overwrote before the drain
    pub dropped: u64,
}

/// One span row, clock-local to its node.
#[derive(Debug, Clone)]
pub struct RawEvent {
    pub name: String,
    pub start_ns: u64,
    pub dur_ns: u64,
    pub round: Option<u32>,
    pub gid: Option<u32>,
}

/// One span event shifted onto its reference server's clock.
#[derive(Debug, Clone)]
pub struct Event {
    /// index into the analyzed node list (the Chrome-export pid)
    pub node: usize,
    pub name: String,
    pub start_ns: i64,
    pub dur_ns: i64,
    pub round: Option<u32>,
    pub gid: Option<u32>,
}

/// The critical-path decomposition of one round.
#[derive(Debug, Clone)]
pub struct RoundBreakdown {
    pub shard: u64,
    pub round: u32,
    pub wall_ns: i64,
    /// gids whose uplinks joined this round
    pub participants: usize,
    /// the device whose stage chain ended last (None if no device-scoped
    /// span joined the round)
    pub critical_gid: Option<u32>,
    /// the largest stage on the critical chain
    pub bounding_stage: &'static str,
    pub bounding_ns: i64,
    /// the critical device's full stage chain, `other` last — sums to
    /// `wall_ns` up to clamping of overlapping stages
    pub stages: Vec<(&'static str, i64)>,
}

#[derive(Debug, Clone)]
pub struct StageStat {
    pub name: &'static str,
    pub count: usize,
    pub p50_ns: i64,
    pub p95_ns: i64,
    pub max_ns: i64,
}

/// The merged, aligned, per-round view over every input trace.
#[derive(Debug, Clone)]
pub struct Analysis {
    pub session_fp: String,
    /// one human label per input node, index-aligned with [`Event::node`]
    pub nodes: Vec<String>,
    pub rounds: Vec<RoundBreakdown>,
    pub stage_stats: Vec<StageStat>,
    /// per-gid rounds-on-the-critical-path counts, most-blamed first
    pub straggler_counts: Vec<(u32, usize)>,
    /// total ring-overwritten spans across all nodes (trace holes)
    pub dropped: u64,
    /// round-lifecycle spans that could not be attached to any round
    pub unjoined: usize,
    /// every aligned span, for the Chrome export
    pub events: Vec<Event>,
}

/// The per-device lifecycle stages in causal order. `uplink_wire` and
/// `downlink_wire` are derived gaps (no process observes the network
/// itself); `batch_seal_wait` / `server_step_batch` are round-scoped and
/// shared by the batch the device rode in.
const DEVICE_STAGES: &[&str] = &[
    "client_fwd",
    "uplink_encode",
    "uplink_wire",
    "queue_wait",
    "uplink_decode",
    "batch_seal_wait",
    "server_step_batch",
    "downlink_encode",
    "write_park",
    "downlink_wire",
    "downlink_decode",
    "client_bwd",
];

/// Round-scoped stages that follow the per-device chain. The membership
/// spans (`join`/`catchup`/`leave`) and the coordinator `checkpoint` span
/// land here too: they happen at round boundaries, not inside any single
/// device's activation chain.
const ROUND_STAGES: &[&str] = &[
    "fedavg",
    "eval",
    "shard_barrier",
    "spec_update",
    "join",
    "catchup",
    "leave",
    "checkpoint",
];

/// Parse one trace file's text (header row, span rows, dropped rows).
pub fn parse_trace(path: &str, text: &str) -> Result<NodeTrace, String> {
    let mut lines = text.lines().enumerate();
    let (_, first) = lines
        .next()
        .ok_or_else(|| format!("{path}: empty trace file"))?;
    let head = Json::parse(first).map_err(|e| format!("{path}:1: {e}"))?;
    if head.get("header").is_none() {
        return Err(format!(
            "{path}: first row is not a trace header — re-record with this \
             version's --trace-out"
        ));
    }
    let role = head
        .get("role")
        .and_then(|j| j.as_str())
        .unwrap_or("")
        .to_string();
    let shard = head.get("shard").and_then(|j| j.as_f64()).unwrap_or(0.0) as u64;
    let session_fp = head
        .get("session_fp")
        .and_then(|j| j.as_str())
        .unwrap_or("")
        .to_string();
    let mut anchors = Vec::new();
    if let Some(arr) = head.get("anchors").and_then(|j| j.as_arr()) {
        for pair in arr {
            let p = pair
                .as_arr()
                .ok_or_else(|| format!("{path}: malformed anchor entry"))?;
            if p.len() != 2 {
                return Err(format!("{path}: anchor entry is not a [gid, ns] pair"));
            }
            anchors.push((
                p[0].as_f64().unwrap_or(0.0) as u32,
                p[1].as_f64().unwrap_or(0.0) as u64,
            ));
        }
    }
    let mut events = Vec::new();
    let mut dropped = 0u64;
    for (i, line) in lines {
        if line.trim().is_empty() {
            continue;
        }
        let row = Json::parse(line).map_err(|e| format!("{path}:{}: {e}", i + 1))?;
        if let Some(d) = row.get("dropped").and_then(|j| j.as_f64()) {
            dropped += d as u64;
            continue;
        }
        let Some(name) = row.get("name").and_then(|j| j.as_str()) else {
            return Err(format!(
                "{path}:{}: row has neither a span name nor a dropped count",
                i + 1
            ));
        };
        events.push(RawEvent {
            name: name.to_string(),
            start_ns: row.get("start_ns").and_then(|j| j.as_f64()).unwrap_or(0.0)
                as u64,
            dur_ns: row.get("dur_ns").and_then(|j| j.as_f64()).unwrap_or(0.0) as u64,
            round: row.get("round").and_then(|j| j.as_f64()).map(|x| x as u32),
            gid: row.get("gid").and_then(|j| j.as_f64()).map(|x| x as u32),
        });
    }
    Ok(NodeTrace { path: path.to_string(), role, shard, session_fp, anchors, events, dropped })
}

/// [`parse_trace`] over a file on disk.
pub fn parse_file(path: &str) -> Result<NodeTrace, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    parse_trace(path, &text)
}

/// `sorted` percentile by nearest-rank (deterministic, no interpolation).
fn pct(sorted: &[i64], q: f64) -> i64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Merge, align, and decompose the given node traces. Errors on traces
/// from different sessions or a device file no server file anchors.
pub fn analyze(nodes: Vec<NodeTrace>) -> Result<Analysis, String> {
    if nodes.is_empty() {
        return Err("no trace files given".into());
    }
    // all non-empty session fingerprints must agree
    let mut session_fp = String::new();
    for n in &nodes {
        if n.session_fp.is_empty() {
            continue;
        }
        if session_fp.is_empty() {
            session_fp = n.session_fp.clone();
        } else if session_fp != n.session_fp {
            return Err(format!(
                "{}: session fingerprint {} does not match {} — these traces \
                 come from different sessions",
                n.path, n.session_fp, session_fp
            ));
        }
    }

    // per-node reference (the node whose clock its events are shifted
    // onto) and offset. Non-device nodes are their own reference; a device
    // joins the server whose anchors cover one of its gids.
    let mut refs = vec![0usize; nodes.len()];
    let mut offsets = vec![0i64; nodes.len()];
    for (i, n) in nodes.iter().enumerate() {
        if n.role != "device" {
            refs[i] = i;
            continue;
        }
        let mut found = None;
        'anchors: for &(gid, dev_ns) in &n.anchors {
            for (j, m) in nodes.iter().enumerate() {
                if m.role == "device" {
                    continue;
                }
                if let Some(&(_, srv_ns)) = m.anchors.iter().find(|(g, _)| *g == gid)
                {
                    found = Some((j, srv_ns as i64 - dev_ns as i64));
                    break 'anchors;
                }
            }
        }
        let Some((j, off)) = found else {
            let gids: Vec<u32> = n.anchors.iter().map(|a| a.0).collect();
            return Err(format!(
                "{}: no server trace anchors this device's gid(s) {gids:?} — \
                 pass the serving node's --trace-out file too",
                n.path
            ));
        };
        refs[i] = j;
        offsets[i] = off;
    }

    // align every event onto its reference clock
    let mut events: Vec<Event> = Vec::new();
    for (i, n) in nodes.iter().enumerate() {
        for e in &n.events {
            events.push(Event {
                node: i,
                name: e.name.clone(),
                start_ns: e.start_ns as i64 + offsets[i],
                dur_ns: e.dur_ns as i64,
                round: e.round,
                gid: e.gid,
            });
        }
    }
    events.sort_by_key(|e| e.start_ns);

    // round windows per reference node; duplicate `round` spans (an
    // in-process multi-shard sim records one per shard thread) merge by
    // min-start / max-end
    let mut windows: BTreeMap<(usize, u32), (i64, i64)> = BTreeMap::new();
    for e in &events {
        if e.name != "round" {
            continue;
        }
        let Some(r) = e.round else { continue };
        let end = e.start_ns + e.dur_ns;
        let w = windows.entry((refs[e.node], r)).or_insert((e.start_ns, end));
        w.0 = w.0.min(e.start_ns);
        w.1 = w.1.max(end);
    }
    if windows.is_empty() {
        return Err(
            "no `round` spans in any trace — was the serving node run with \
             --trace-out?"
                .into(),
        );
    }

    // join every non-round span to a (reference, round) bucket
    let mut buckets: BTreeMap<(usize, u32), Vec<usize>> = BTreeMap::new();
    let mut unjoined = 0usize;
    for (idx, e) in events.iter().enumerate() {
        if e.name == "round" {
            continue;
        }
        let rf = refs[e.node];
        if let Some(r) = e.round {
            buckets.entry((rf, r)).or_default().push(idx);
            continue;
        }
        if e.gid.is_none() {
            continue; // free-form span (warmup, shard_sync, ...): not lifecycle
        }
        // gid-only span: time containment, else nearest window within the
        // session's round phase (gaps between consecutive rounds are thin)
        let mid = e.start_ns + e.dur_ns / 2;
        let mut best: Option<(i64, u32)> = None;
        let mut phase: Option<(i64, i64)> = None;
        for (&(wr, r), &(s, t)) in &windows {
            if wr != rf {
                continue;
            }
            let p = phase.get_or_insert((s, t));
            p.0 = p.0.min(s);
            p.1 = p.1.max(t);
            let dist = if mid < s {
                s - mid
            } else if mid > t {
                mid - t
            } else {
                0
            };
            let better = match best {
                None => true,
                Some((bd, _)) => dist < bd,
            };
            if better {
                best = Some((dist, r));
            }
        }
        match (best, phase) {
            (Some((0, r)), _) => buckets.entry((rf, r)).or_default().push(idx),
            (Some((_, r)), Some((ps, pt))) if mid >= ps && mid <= pt => {
                buckets.entry((rf, r)).or_default().push(idx)
            }
            (Some(_), _) => {} // handshake/shutdown span outside the rounds
            (None, _) => unjoined += 1, // this reference recorded no rounds
        }
    }

    // per-round critical-path decomposition
    let mut rounds = Vec::with_capacity(windows.len());
    let mut stage_samples: BTreeMap<&'static str, Vec<i64>> = BTreeMap::new();
    let mut critical_counts: BTreeMap<u32, usize> = BTreeMap::new();
    let empty: Vec<usize> = Vec::new();
    for (&(rf, r), &(wstart, wend)) in &windows {
        let idxs = buckets.get(&(rf, r)).unwrap_or(&empty);
        let wall = wend - wstart;

        let dur_of = |gid: u32, name: &str| -> i64 {
            idxs.iter()
                .map(|&i| &events[i])
                .filter(|e| e.gid == Some(gid) && e.name == name)
                .map(|e| e.dur_ns)
                .sum()
        };
        let first_start = |gid: u32, name: &str| -> Option<i64> {
            idxs.iter()
                .map(|&i| &events[i])
                .filter(|e| e.gid == Some(gid) && e.name == name)
                .map(|e| e.start_ns)
                .min()
        };
        let last_end = |gid: u32, name: &str| -> Option<i64> {
            idxs.iter()
                .map(|&i| &events[i])
                .filter(|e| e.gid == Some(gid) && e.name == name)
                .map(|e| e.start_ns + e.dur_ns)
                .max()
        };
        let round_dur = |name: &str| -> i64 {
            idxs.iter()
                .map(|&i| &events[i])
                .filter(|e| e.gid.is_none() && e.name == name)
                .map(|e| e.dur_ns)
                .sum()
        };

        let mut gids: Vec<u32> = idxs.iter().filter_map(|&i| events[i].gid).collect();
        gids.sort_unstable();
        gids.dedup();
        let chain_end = |gid: u32| -> i64 {
            idxs.iter()
                .map(|&i| &events[i])
                .filter(|e| e.gid == Some(gid))
                .map(|e| e.start_ns + e.dur_ns)
                .max()
                .unwrap_or(wstart)
        };
        let critical_gid = gids.iter().copied().max_by_key(|&g| chain_end(g));

        let mut stages: Vec<(&'static str, i64)> = Vec::new();
        if let Some(g) = critical_gid {
            let uplink_sent = last_end(g, "uplink_encode");
            let uplink_arrived =
                first_start(g, "queue_wait").or_else(|| first_start(g, "uplink_decode"));
            let uplink_wire = match (uplink_sent, uplink_arrived) {
                (Some(a), Some(b)) => (b - a).max(0),
                _ => 0,
            };
            let downlink_sent =
                last_end(g, "write_park").max(last_end(g, "downlink_encode"));
            let downlink_wire =
                match (downlink_sent, first_start(g, "downlink_decode")) {
                    (Some(a), Some(b)) => (b - a).max(0),
                    _ => 0,
                };
            for &name in DEVICE_STAGES {
                let ns = match name {
                    "uplink_wire" => uplink_wire,
                    "downlink_wire" => downlink_wire,
                    "batch_seal_wait" | "server_step_batch" => round_dur(name),
                    _ => dur_of(g, name),
                };
                stages.push((name, ns));
            }
            for &name in ROUND_STAGES {
                stages.push((name, round_dur(name)));
            }
            let spent: i64 = stages.iter().map(|s| s.1).sum();
            stages.push(("other", (wall - spent).max(0)));
            *critical_counts.entry(g).or_insert(0) += 1;
        }
        let (bounding_stage, bounding_ns) = stages
            .iter()
            .copied()
            .max_by_key(|&(_, ns)| ns)
            .unwrap_or(("other", 0));

        for &(name, ns) in &stages {
            if ns > 0 {
                stage_samples.entry(name).or_default().push(ns);
            }
        }
        stage_samples.entry("round").or_default().push(wall);

        rounds.push(RoundBreakdown {
            shard: nodes[rf].shard,
            round: r,
            wall_ns: wall,
            participants: gids.len(),
            critical_gid,
            bounding_stage,
            bounding_ns,
            stages,
        });
    }

    let mut stage_stats: Vec<StageStat> = stage_samples
        .into_iter()
        .map(|(name, mut xs)| {
            xs.sort_unstable();
            StageStat {
                name,
                count: xs.len(),
                p50_ns: pct(&xs, 0.5),
                p95_ns: pct(&xs, 0.95),
                max_ns: *xs.last().unwrap_or(&0),
            }
        })
        .collect();
    // present stages in chain order, then the extras
    let order = |n: &str| -> usize {
        DEVICE_STAGES
            .iter()
            .chain(ROUND_STAGES.iter())
            .position(|&s| s == n)
            .unwrap_or(usize::MAX)
    };
    stage_stats.sort_by_key(|s| order(s.name));

    let mut straggler_counts: Vec<(u32, usize)> = critical_counts.into_iter().collect();
    straggler_counts.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));

    let labels = nodes
        .iter()
        .map(|n| {
            let role = if n.role.is_empty() { "node" } else { &n.role };
            format!("{role} shard {} ({})", n.shard, n.path)
        })
        .collect();
    Ok(Analysis {
        session_fp,
        nodes: labels,
        rounds,
        stage_stats,
        straggler_counts,
        dropped: nodes.iter().map(|n| n.dropped).sum(),
        unjoined,
        events,
    })
}

/// The human-readable critical-path report.
pub fn render_table(a: &Analysis) -> String {
    let ms = |ns: i64| ns as f64 / 1e6;
    let mut out = String::new();
    out.push_str("per-round critical path\n");
    out.push_str(&format!(
        "{:>5} {:>5} {:>10} {:>7}  {:<17} {:>10}\n",
        "shard", "round", "wall_ms", "device", "bounded by", "stage_ms"
    ));
    for r in &a.rounds {
        let dev = match r.critical_gid {
            Some(g) => g.to_string(),
            None => "-".to_string(),
        };
        out.push_str(&format!(
            "{:>5} {:>5} {:>10.3} {:>7}  {:<17} {:>10.3}\n",
            r.shard,
            r.round,
            ms(r.wall_ns),
            dev,
            r.bounding_stage,
            ms(r.bounding_ns)
        ));
        let chain: Vec<String> = r
            .stages
            .iter()
            .filter(|s| s.1 > 0)
            .map(|&(n, ns)| format!("{n} {:.3}", ms(ns)))
            .collect();
        if !chain.is_empty() {
            out.push_str(&format!("        {}\n", chain.join(" | ")));
        }
    }
    out.push_str("\nper-stage latency (ms)\n");
    out.push_str(&format!(
        "{:<18} {:>6} {:>10} {:>10} {:>10}\n",
        "stage", "count", "p50", "p95", "max"
    ));
    for s in &a.stage_stats {
        out.push_str(&format!(
            "{:<18} {:>6} {:>10.3} {:>10.3} {:>10.3}\n",
            s.name,
            s.count,
            ms(s.p50_ns),
            ms(s.p95_ns),
            ms(s.max_ns)
        ));
    }
    if !a.straggler_counts.is_empty() {
        out.push_str("\nstraggler attribution (rounds bounded by each device)\n");
        for &(g, c) in &a.straggler_counts {
            out.push_str(&format!("  device {g}: {c}/{} rounds\n", a.rounds.len()));
        }
    }
    out
}

/// The one-screen summary (`dropped spans: N` is the CI health line).
pub fn summary(a: &Analysis) -> String {
    let mut out = String::new();
    if !a.session_fp.is_empty() {
        out.push_str(&format!("session: {}\n", a.session_fp));
    }
    for label in &a.nodes {
        out.push_str(&format!("node: {label}\n"));
    }
    out.push_str(&format!("rounds reconstructed: {}\n", a.rounds.len()));
    out.push_str(&format!("unjoined spans: {}\n", a.unjoined));
    out.push_str(&format!("dropped spans: {}\n", a.dropped));
    out
}

/// The merged timeline as Chrome trace-event JSON (load in
/// `chrome://tracing` or Perfetto): one complete ("X") event per span,
/// microsecond timestamps on the aligned clock, pid = node, tid = gid.
pub fn chrome_json(a: &Analysis) -> Json {
    Json::Arr(
        a.events
            .iter()
            .map(|e| {
                let mut args = Vec::new();
                if let Some(r) = e.round {
                    args.push(("round", Json::Num(r as f64)));
                }
                if let Some(g) = e.gid {
                    args.push(("gid", Json::Num(g as f64)));
                }
                Json::obj(vec![
                    ("name", Json::str(&e.name)),
                    ("ph", Json::str("X")),
                    ("ts", Json::Num(e.start_ns as f64 / 1e3)),
                    ("dur", Json::Num(e.dur_ns as f64 / 1e3)),
                    ("pid", Json::Num(e.node as f64)),
                    ("tid", Json::Num(e.gid.unwrap_or(0) as f64)),
                    ("args", Json::obj(args)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn server_text() -> String {
        [
            r#"{"header": 1, "role": "server", "shard": 0, "session_fp": "00000000000000ab", "anchors": [[1, 1000]]}"#,
            // a handshake-time queue_wait, before any round: must be
            // ignored, not counted unjoined
            r#"{"thread": "main", "name": "queue_wait", "key": "", "val": 0, "start_ns": 100, "dur_ns": 50, "depth": 1, "gid": 1}"#,
            r#"{"thread": "main", "name": "round", "key": "", "val": 0, "start_ns": 2000, "dur_ns": 1000, "depth": 1, "round": 0}"#,
            r#"{"thread": "main", "name": "queue_wait", "key": "", "val": 0, "start_ns": 2300, "dur_ns": 100, "depth": 1, "gid": 1}"#,
            r#"{"thread": "main", "name": "uplink_decode", "key": "", "val": 0, "start_ns": 2400, "dur_ns": 50, "depth": 1, "round": 0, "gid": 1, "kind": 0}"#,
            r#"{"thread": "main", "name": "server_step_batch", "key": "width", "val": 1, "start_ns": 2500, "dur_ns": 250, "depth": 1, "round": 0}"#,
            r#"{"thread": "main", "name": "downlink_encode", "key": "", "val": 0, "start_ns": 2750, "dur_ns": 50, "depth": 1, "round": 0, "gid": 1, "kind": 1}"#,
        ]
        .join("\n")
    }

    fn device_text() -> String {
        // device clock runs 500ns behind the server's anchor: the
        // server stamped 1000, this node stamped 500 -> offset +500
        [
            r#"{"header": 1, "role": "device", "shard": 0, "session_fp": "00000000000000ab", "anchors": [[1, 500]]}"#,
            r#"{"thread": "main", "name": "client_fwd", "key": "", "val": 0, "start_ns": 1600, "dur_ns": 100, "depth": 1, "round": 0, "gid": 1}"#,
            r#"{"thread": "main", "name": "uplink_encode", "key": "", "val": 0, "start_ns": 1700, "dur_ns": 100, "depth": 1, "round": 0, "gid": 1, "kind": 0}"#,
            r#"{"thread": "main", "name": "downlink_decode", "key": "", "val": 0, "start_ns": 2300, "dur_ns": 50, "depth": 1, "round": 0, "gid": 1, "kind": 1}"#,
            r#"{"thread": "main", "name": "client_bwd", "key": "", "val": 0, "start_ns": 2350, "dur_ns": 100, "depth": 1, "round": 0, "gid": 1}"#,
            r#"{"thread": "main", "dropped": 3}"#,
        ]
        .join("\n")
    }

    fn two_node() -> Analysis {
        analyze(vec![
            parse_trace("server.jsonl", &server_text()).unwrap(),
            parse_trace("device.jsonl", &device_text()).unwrap(),
        ])
        .unwrap()
    }

    #[test]
    fn parses_header_events_and_dropped_rows() {
        let n = parse_trace("device.jsonl", &device_text()).unwrap();
        assert_eq!(n.role, "device");
        assert_eq!(n.session_fp, "00000000000000ab");
        assert_eq!(n.anchors, vec![(1, 500)]);
        assert_eq!(n.events.len(), 4);
        assert_eq!(n.dropped, 3);
        assert_eq!(n.events[0].name, "client_fwd");
        assert_eq!(n.events[0].round, Some(0));
        assert_eq!(n.events[0].gid, Some(1));
    }

    #[test]
    fn device_clock_is_shifted_onto_the_servers() {
        let a = two_node();
        let fwd = a.events.iter().find(|e| e.name == "client_fwd").unwrap();
        // device-local 1600 + (1000 - 500) anchor offset
        assert_eq!(fwd.start_ns, 2100);
        assert_eq!(fwd.node, 1);
    }

    #[test]
    fn critical_path_decomposes_the_round() {
        let a = two_node();
        assert_eq!(a.rounds.len(), 1);
        let r = &a.rounds[0];
        assert_eq!(r.round, 0);
        assert_eq!(r.wall_ns, 1000);
        assert_eq!(r.participants, 1);
        assert_eq!(r.critical_gid, Some(1));
        assert_eq!(r.bounding_stage, "server_step_batch");
        assert_eq!(r.bounding_ns, 250);
        // the chain sums exactly to the round wall clock
        let total: i64 = r.stages.iter().map(|s| s.1).sum();
        assert_eq!(total, r.wall_ns);
        let get = |name: &str| r.stages.iter().find(|s| s.0 == name).unwrap().1;
        assert_eq!(get("client_fwd"), 100);
        assert_eq!(get("uplink_encode"), 100);
        // encode ends (aligned) at 2300, queue_wait starts at 2300
        assert_eq!(get("uplink_wire"), 0);
        assert_eq!(get("queue_wait"), 100);
        assert_eq!(get("uplink_decode"), 50);
        assert_eq!(get("server_step_batch"), 250);
        assert_eq!(get("downlink_encode"), 50);
        // downlink_encode ends 2800; decode starts (aligned) at 2800
        assert_eq!(get("downlink_wire"), 0);
        assert_eq!(get("downlink_decode"), 50);
        assert_eq!(get("client_bwd"), 100);
        assert_eq!(get("other"), 200);
        // the handshake queue_wait was outside the round phase: not joined,
        // not unjoined
        assert_eq!(a.unjoined, 0);
        assert_eq!(a.dropped, 3);
        assert_eq!(a.straggler_counts, vec![(1, 1)]);
    }

    #[test]
    fn summary_reports_the_drop_count() {
        let a = two_node();
        let s = summary(&a);
        assert!(s.contains("rounds reconstructed: 1"), "{s}");
        assert!(s.contains("unjoined spans: 0"), "{s}");
        assert!(s.contains("dropped spans: 3"), "{s}");
    }

    #[test]
    fn table_renders_every_section() {
        let a = two_node();
        let t = render_table(&a);
        assert!(t.contains("per-round critical path"), "{t}");
        assert!(t.contains("server_step_batch"), "{t}");
        assert!(t.contains("per-stage latency"), "{t}");
        assert!(t.contains("straggler attribution"), "{t}");
        assert!(t.contains("device 1: 1/1 rounds"), "{t}");
    }

    #[test]
    fn chrome_export_is_an_event_array() {
        let a = two_node();
        let j = chrome_json(&a);
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), a.events.len());
        let fwd = arr
            .iter()
            .find(|e| e.at(&["name"]) == &Json::Str("client_fwd".into()))
            .unwrap();
        assert_eq!(fwd.at(&["ph"]), &Json::Str("X".into()));
        assert_eq!(fwd.at(&["ts"]), &Json::Num(2.1)); // 2100ns in us
        assert_eq!(fwd.at(&["pid"]), &Json::Num(1.0));
        assert_eq!(fwd.at(&["tid"]), &Json::Num(1.0));
    }

    #[test]
    fn mismatched_sessions_are_rejected() {
        let other = server_text().replace("00000000000000ab", "00000000000000cd");
        let err = analyze(vec![
            parse_trace("a.jsonl", &server_text()).unwrap(),
            parse_trace("b.jsonl", &other).unwrap(),
        ])
        .unwrap_err();
        assert!(err.contains("different sessions"), "{err}");
    }

    #[test]
    fn unanchored_device_is_rejected() {
        let lone = parse_trace("device.jsonl", &device_text()).unwrap();
        let err = analyze(vec![lone]).unwrap_err();
        assert!(err.contains("no server trace anchors"), "{err}");
    }
}
