//! Hierarchical wall-clock tracing spans.
//!
//! A span is an RAII guard ([`SpanGuard`]) that records `(name, key=val,
//! start_ns, dur_ns, depth)` into its thread's ring buffer when dropped.
//! Recording is gated on one process-global relaxed atomic (the same
//! pattern as [`crate::util::logging`]'s level gate), so a disabled span
//! costs ~1ns — one load, no clock read, no ring touch. Enabled spans take
//! their own thread's uncontended mutex, so there is no cross-thread
//! contention on the hot path either.
//!
//! Rings are fixed-capacity ([`RING_CAP`] events, oldest overwritten) and
//! registered globally on first use, so any thread — in practice the server
//! main thread at session end — can [`drain`] every thread's events and
//! write them as JSONL (`--trace-out FILE`) for flame/straggler analysis.
//!
//! Timestamps are nanoseconds since the shared process epoch
//! ([`crate::util::logging::elapsed_ns`]), so span times line up with log
//! line stamps.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::util::json::Json;
use crate::util::logging::elapsed_ns;

/// Events kept per thread before the oldest are overwritten.
pub const RING_CAP: usize = 4096;

static ENABLED: AtomicBool = AtomicBool::new(false);

#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span recording on/off process-wide (`--trace-out` sets it).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// One recorded span.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanEvent {
    pub name: &'static str,
    /// optional attribute key (`""` when the span carries none)
    pub key: &'static str,
    pub val: u64,
    /// nanoseconds since the process epoch
    pub start_ns: u64,
    pub dur_ns: u64,
    /// nesting depth at record time (1 = top-level span on its thread)
    pub depth: u32,
}

struct Ring {
    thread: String,
    events: Vec<SpanEvent>,
    /// next overwrite slot once `events` is full
    head: usize,
    /// lifetime events recorded (so drains can report drops)
    total: u64,
}

impl Ring {
    fn push(&mut self, ev: SpanEvent) {
        if self.events.len() < RING_CAP {
            self.events.push(ev);
        } else {
            self.events[self.head] = ev;
            self.head = (self.head + 1) % RING_CAP;
        }
        self.total += 1;
    }

    /// Events in chronological order, clearing the ring.
    fn take(&mut self) -> Vec<SpanEvent> {
        let mut out = Vec::with_capacity(self.events.len());
        out.extend_from_slice(&self.events[self.head..]);
        out.extend_from_slice(&self.events[..self.head]);
        self.events.clear();
        self.head = 0;
        out
    }
}

fn rings() -> &'static Mutex<Vec<&'static Mutex<Ring>>> {
    static RINGS: OnceLock<Mutex<Vec<&'static Mutex<Ring>>>> = OnceLock::new();
    RINGS.get_or_init(|| Mutex::new(Vec::new()))
}

fn lock_clean<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    // a panicking span elsewhere must not wedge tracing for the process
    m.lock().unwrap_or_else(|e| e.into_inner())
}

thread_local! {
    static DEPTH: Cell<u32> = const { Cell::new(0) };
    static MY_RING: Cell<Option<&'static Mutex<Ring>>> = const { Cell::new(None) };
}

/// This thread's ring, registering (one bounded leak per thread) on first use.
fn my_ring() -> &'static Mutex<Ring> {
    MY_RING.with(|cell| match cell.get() {
        Some(r) => r,
        None => {
            let cur = std::thread::current();
            let ring: &'static Mutex<Ring> = Box::leak(Box::new(Mutex::new(Ring {
                thread: cur.name().unwrap_or("unnamed").to_string(),
                events: Vec::with_capacity(RING_CAP),
                head: 0,
                total: 0,
            })));
            lock_clean(rings()).push(ring);
            cell.set(Some(ring));
            ring
        }
    })
}

/// RAII span — see the [`crate::span!`] macro for the ergonomic form.
pub struct SpanGuard {
    name: &'static str,
    key: &'static str,
    val: u64,
    start_ns: u64,
    active: bool,
}

impl SpanGuard {
    #[inline]
    pub fn begin(name: &'static str, key: &'static str, val: u64) -> SpanGuard {
        if !enabled() {
            return SpanGuard { name, key, val, start_ns: 0, active: false };
        }
        DEPTH.with(|d| d.set(d.get() + 1));
        SpanGuard { name, key, val, start_ns: elapsed_ns(), active: true }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        let end = elapsed_ns();
        let depth = DEPTH.with(|d| {
            let v = d.get();
            d.set(v.saturating_sub(1));
            v
        });
        let ev = SpanEvent {
            name: self.name,
            key: self.key,
            val: self.val,
            start_ns: self.start_ns,
            dur_ns: end.saturating_sub(self.start_ns),
            depth,
        };
        lock_clean(my_ring()).push(ev);
    }
}

/// Open a span: `let _sp = span!("server_step_batch", width = n);` — the
/// guard must be bound to a name so it lives to the end of the scope.
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        $crate::obs::span::SpanGuard::begin($name, "", 0)
    };
    ($name:expr, $key:ident = $val:expr) => {
        $crate::obs::span::SpanGuard::begin($name, stringify!($key), ($val) as u64)
    };
}

/// Drain every thread's ring: `(thread_name, recorded_since_last_drain,
/// events)` per thread with anything new, events in chronological order,
/// rings cleared.
pub fn drain() -> Vec<(String, u64, Vec<SpanEvent>)> {
    let regs = lock_clean(rings());
    let mut out = Vec::with_capacity(regs.len());
    for ring in regs.iter() {
        let mut g = lock_clean(ring);
        let total = g.total;
        g.total = 0;
        let events = g.take();
        if total > 0 {
            out.push((g.thread.clone(), total, events));
        }
    }
    out
}

/// Drain all rings to `path` as JSONL (one span per line). Returns the
/// number of events written.
pub fn write_jsonl(path: &str) -> Result<usize, String> {
    use std::io::Write;
    let mut file = std::fs::File::create(path)
        .map_err(|e| format!("--trace-out {path}: {e}"))?;
    let mut written = 0usize;
    let mut lines = String::new();
    for (thread, total, events) in drain() {
        let dropped = total.saturating_sub(events.len() as u64);
        for ev in &events {
            let row = Json::obj(vec![
                ("thread", Json::Str(thread.clone())),
                ("name", Json::Str(ev.name.to_string())),
                ("key", Json::Str(ev.key.to_string())),
                ("val", Json::Num(ev.val as f64)),
                ("start_ns", Json::Num(ev.start_ns as f64)),
                ("dur_ns", Json::Num(ev.dur_ns as f64)),
                ("depth", Json::Num(ev.depth as f64)),
            ]);
            lines.push_str(&row.dump());
            lines.push('\n');
            written += 1;
        }
        if dropped > 0 {
            let row = Json::obj(vec![
                ("thread", Json::Str(thread.clone())),
                ("dropped", Json::Num(dropped as f64)),
            ]);
            lines.push_str(&row.dump());
            lines.push('\n');
        }
    }
    file.write_all(lines.as_bytes())
        .map_err(|e| format!("--trace-out {path}: {e}"))?;
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;

    // span tests share the process-global enable gate, so they must not
    // run concurrently with each other
    static GATE: Mutex<()> = Mutex::new(());

    // run each test's spans on a dedicated named thread so drains are clean
    fn on_thread<F: FnOnce() + Send + 'static>(name: &str, f: F) {
        std::thread::Builder::new()
            .name(name.to_string())
            .spawn(f)
            .unwrap()
            .join()
            .unwrap();
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = lock_clean(&GATE);
        set_enabled(false);
        on_thread("span-off", || {
            let _a = crate::span!("quiet");
            let _b = crate::span!("quiet", device = 3);
        });
        let got: Vec<_> = drain()
            .into_iter()
            .filter(|(t, _, _)| t == "span-off")
            .collect();
        assert!(got.is_empty(), "disabled spans must not touch any ring");
    }

    #[test]
    fn nested_spans_carry_depth_and_attributes() {
        let _g = lock_clean(&GATE);
        set_enabled(true);
        on_thread("span-nest", || {
            let _outer = crate::span!("outer");
            {
                let _inner = crate::span!("inner", device = 7);
            }
        });
        set_enabled(false);
        let mut threads = drain();
        threads.retain(|(t, _, _)| t == "span-nest");
        assert_eq!(threads.len(), 1);
        let (_, total, events) = &threads[0];
        assert_eq!(*total, 2);
        assert_eq!(events.len(), 2);
        // inner drops first
        assert_eq!(events[0].name, "inner");
        assert_eq!(events[0].key, "device");
        assert_eq!(events[0].val, 7);
        assert_eq!(events[0].depth, 2);
        assert_eq!(events[1].name, "outer");
        assert_eq!(events[1].depth, 1);
        assert!(events[1].start_ns <= events[0].start_ns);
        assert!(events[1].dur_ns >= events[0].dur_ns);
    }

    #[test]
    fn ring_overwrites_oldest_but_counts_all() {
        let _g = lock_clean(&GATE);
        set_enabled(true);
        on_thread("span-ring", || {
            for i in 0..(RING_CAP + 10) {
                let _s = crate::span!("tick", i = i);
            }
        });
        set_enabled(false);
        let mut threads = drain();
        threads.retain(|(t, _, _)| t == "span-ring");
        let (_, total, events) = &threads[0];
        assert_eq!(*total, (RING_CAP + 10) as u64);
        assert_eq!(events.len(), RING_CAP);
        // oldest 10 were overwritten: first surviving event is i == 10
        assert_eq!(events[0].val, 10);
        assert_eq!(events[RING_CAP - 1].val, (RING_CAP + 9) as u64);
    }

    #[test]
    fn jsonl_lines_parse() {
        let _g = lock_clean(&GATE);
        set_enabled(true);
        on_thread("span-jsonl", || {
            let _s = crate::span!("write_me", round = 4);
        });
        set_enabled(false);
        let path = std::env::temp_dir().join("slacc_span_test.jsonl");
        let path = path.to_str().unwrap().to_string();
        let n = write_jsonl(&path).unwrap();
        assert!(n >= 1);
        let text = std::fs::read_to_string(&path).unwrap();
        let mine: Vec<&str> =
            text.lines().filter(|l| l.contains("span-jsonl")).collect();
        assert_eq!(mine.len(), 1);
        let row = Json::parse(mine[0]).unwrap();
        assert_eq!(row.at(&["name"]), &Json::Str("write_me".to_string()));
        assert_eq!(row.at(&["key"]), &Json::Str("round".to_string()));
        assert_eq!(row.at(&["val"]), &Json::Num(4.0));
        let _ = std::fs::remove_file(&path);
    }
}
