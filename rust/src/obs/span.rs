//! Hierarchical wall-clock tracing spans.
//!
//! A span is an RAII guard ([`SpanGuard`]) that records `(name, key=val,
//! start_ns, dur_ns, depth)` plus a small fixed attribute set — round id,
//! global device id, stream kind — into its thread's ring buffer when
//! dropped. Recording is gated on one process-global relaxed atomic (the
//! same pattern as [`crate::util::logging`]'s level gate), so a disabled
//! span costs ~1ns — one load, no clock read, no ring touch. Enabled spans
//! take their own thread's uncontended mutex, so there is no cross-thread
//! contention on the hot path either.
//!
//! Rings are fixed-capacity ([`RING_CAP`] events, oldest overwritten) and
//! registered globally on first use, so any thread — in practice the server
//! main thread at session end — can [`drain`] every thread's events and
//! write them as JSONL (`--trace-out FILE`) for flame/straggler analysis.
//! Overwrites are surfaced on the metrics registry
//! ([`crate::obs::metrics::TRACE_DROPPED`]) and warned about at drain time.
//!
//! Timestamps are nanoseconds since the shared process epoch
//! ([`crate::util::logging::elapsed_ns`]), so span times line up with log
//! line stamps — but only *within* one process. To make traces from
//! different nodes joinable offline, each JSONL file opens with a header
//! row carrying the node role, shard id, session fingerprint, and the
//! per-device clock anchors stamped during the Hello exchange
//! ([`record_anchor`]); `slacc trace` ([`crate::obs::trace`]) uses the
//! anchor pairs to shift every device file onto its server's clock.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::util::json::Json;
use crate::util::logging::elapsed_ns;

/// Events kept per thread before the oldest are overwritten.
pub const RING_CAP: usize = 4096;

/// Sentinel for an unset round / global-device-id attribute.
pub const NO_ID: u32 = u32::MAX;
/// Sentinel for an unset stream-kind attribute (set values are
/// `StreamKind as u8`: 0 uplink, 1 downlink, 2 sync).
pub const NO_KIND: u8 = u8::MAX;

static ENABLED: AtomicBool = AtomicBool::new(false);

#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span recording on/off process-wide (`--trace-out` sets it).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// One recorded span.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanEvent {
    pub name: &'static str,
    /// optional attribute key (`""` when the span carries none)
    pub key: &'static str,
    pub val: u64,
    /// nanoseconds since the process epoch
    pub start_ns: u64,
    pub dur_ns: u64,
    /// nesting depth at record time (1 = top-level span on its thread)
    pub depth: u32,
    /// round id, [`NO_ID`] when the span is not tied to a round
    pub round: u32,
    /// global device id, [`NO_ID`] when not device-scoped
    pub gid: u32,
    /// stream kind (`StreamKind as u8`), [`NO_KIND`] when not stream-scoped
    pub kind: u8,
}

impl SpanEvent {
    /// A manually timed span (for waits computed from timestamps rather
    /// than RAII scopes — queue wait, batch-seal wait, the round itself).
    /// Chain `.round(..)/.gid(..)/.kind(..)` then hand to [`record`].
    pub fn manual(name: &'static str, start_ns: u64, dur_ns: u64) -> SpanEvent {
        SpanEvent {
            name,
            key: "",
            val: 0,
            start_ns,
            dur_ns,
            depth: 1,
            round: NO_ID,
            gid: NO_ID,
            kind: NO_KIND,
        }
    }

    pub fn round(mut self, r: u32) -> SpanEvent {
        self.round = r;
        self
    }

    pub fn gid(mut self, g: u32) -> SpanEvent {
        self.gid = g;
        self
    }

    pub fn kind(mut self, k: u8) -> SpanEvent {
        self.kind = k;
        self
    }

    pub fn attr(mut self, key: &'static str, val: u64) -> SpanEvent {
        self.key = key;
        self.val = val;
        self
    }
}

/// Record a manually built event into this thread's ring (no-op while the
/// gate is off). Zero allocation: the event is `Copy` and the ring is
/// preallocated.
#[inline]
pub fn record(ev: SpanEvent) {
    if !enabled() {
        return;
    }
    lock_clean(my_ring()).push(ev);
}

struct Ring {
    thread: String,
    events: Vec<SpanEvent>,
    /// next overwrite slot once `events` is full
    head: usize,
    /// lifetime events recorded (so drains can report drops)
    total: u64,
}

impl Ring {
    fn push(&mut self, ev: SpanEvent) {
        if self.events.len() < RING_CAP {
            self.events.push(ev);
        } else {
            self.events[self.head] = ev;
            self.head = (self.head + 1) % RING_CAP;
            crate::obs::metrics::TRACE_DROPPED.inc();
        }
        self.total += 1;
    }

    /// Events in chronological order, clearing the ring.
    fn take(&mut self) -> Vec<SpanEvent> {
        let mut out = Vec::with_capacity(self.events.len());
        out.extend_from_slice(&self.events[self.head..]);
        out.extend_from_slice(&self.events[..self.head]);
        self.events.clear();
        self.head = 0;
        out
    }
}

fn rings() -> &'static Mutex<Vec<&'static Mutex<Ring>>> {
    static RINGS: OnceLock<Mutex<Vec<&'static Mutex<Ring>>>> = OnceLock::new();
    RINGS.get_or_init(|| Mutex::new(Vec::new()))
}

fn lock_clean<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    // a panicking span elsewhere must not wedge tracing for the process
    m.lock().unwrap_or_else(|e| e.into_inner())
}

thread_local! {
    static DEPTH: Cell<u32> = const { Cell::new(0) };
    static MY_RING: Cell<Option<&'static Mutex<Ring>>> = const { Cell::new(None) };
}

/// This thread's ring, registering (one bounded leak per thread) on first use.
fn my_ring() -> &'static Mutex<Ring> {
    MY_RING.with(|cell| match cell.get() {
        Some(r) => r,
        None => {
            let cur = std::thread::current();
            let ring: &'static Mutex<Ring> = Box::leak(Box::new(Mutex::new(Ring {
                thread: cur.name().unwrap_or("unnamed").to_string(),
                events: Vec::with_capacity(RING_CAP),
                head: 0,
                total: 0,
            })));
            lock_clean(rings()).push(ring);
            cell.set(Some(ring));
            ring
        }
    })
}

/// RAII span — see the [`crate::span!`] macro for the ergonomic form.
pub struct SpanGuard {
    name: &'static str,
    key: &'static str,
    val: u64,
    start_ns: u64,
    round: u32,
    gid: u32,
    kind: u8,
    active: bool,
}

impl SpanGuard {
    #[inline]
    pub fn begin(
        name: &'static str,
        key: &'static str,
        val: u64,
        round: u32,
        gid: u32,
        kind: u8,
    ) -> SpanGuard {
        if !enabled() {
            return SpanGuard {
                name,
                key,
                val,
                start_ns: 0,
                round,
                gid,
                kind,
                active: false,
            };
        }
        DEPTH.with(|d| d.set(d.get() + 1));
        SpanGuard {
            name,
            key,
            val,
            start_ns: elapsed_ns(),
            round,
            gid,
            kind,
            active: true,
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        let end = elapsed_ns();
        let depth = DEPTH.with(|d| {
            let v = d.get();
            d.set(v.saturating_sub(1));
            v
        });
        let ev = SpanEvent {
            name: self.name,
            key: self.key,
            val: self.val,
            start_ns: self.start_ns,
            dur_ns: end.saturating_sub(self.start_ns),
            depth,
            round: self.round,
            gid: self.gid,
            kind: self.kind,
        };
        lock_clean(my_ring()).push(ev);
    }
}

/// Open a span: `let _sp = span!("server_step_batch", width = n);` — the
/// guard must be bound to a name so it lives to the end of the scope.
///
/// `round = ..`, `gid = ..`, and `kind = ..` are the *fixed* attributes
/// (they fill [`SpanEvent::round`]/[`SpanEvent::gid`]/[`SpanEvent::kind`],
/// in that literal spelling and order); one extra free-form `key = val`
/// pair may follow.
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        $crate::obs::span::SpanGuard::begin(
            $name,
            "",
            0,
            $crate::obs::span::NO_ID,
            $crate::obs::span::NO_ID,
            $crate::obs::span::NO_KIND,
        )
    };
    ($name:expr, round = $r:expr, gid = $g:expr, kind = $k:expr, $key:ident = $val:expr) => {
        $crate::obs::span::SpanGuard::begin(
            $name,
            stringify!($key),
            ($val) as u64,
            ($r) as u32,
            ($g) as u32,
            ($k) as u8,
        )
    };
    ($name:expr, round = $r:expr, gid = $g:expr, kind = $k:expr) => {
        $crate::obs::span::SpanGuard::begin(
            $name,
            "",
            0,
            ($r) as u32,
            ($g) as u32,
            ($k) as u8,
        )
    };
    ($name:expr, round = $r:expr, gid = $g:expr, $key:ident = $val:expr) => {
        $crate::obs::span::SpanGuard::begin(
            $name,
            stringify!($key),
            ($val) as u64,
            ($r) as u32,
            ($g) as u32,
            $crate::obs::span::NO_KIND,
        )
    };
    ($name:expr, round = $r:expr, gid = $g:expr) => {
        $crate::obs::span::SpanGuard::begin(
            $name,
            "",
            0,
            ($r) as u32,
            ($g) as u32,
            $crate::obs::span::NO_KIND,
        )
    };
    ($name:expr, round = $r:expr, $key:ident = $val:expr) => {
        $crate::obs::span::SpanGuard::begin(
            $name,
            stringify!($key),
            ($val) as u64,
            ($r) as u32,
            $crate::obs::span::NO_ID,
            $crate::obs::span::NO_KIND,
        )
    };
    ($name:expr, round = $r:expr) => {
        $crate::obs::span::SpanGuard::begin(
            $name,
            "",
            0,
            ($r) as u32,
            $crate::obs::span::NO_ID,
            $crate::obs::span::NO_KIND,
        )
    };
    ($name:expr, gid = $g:expr, $key:ident = $val:expr) => {
        $crate::obs::span::SpanGuard::begin(
            $name,
            stringify!($key),
            ($val) as u64,
            $crate::obs::span::NO_ID,
            ($g) as u32,
            $crate::obs::span::NO_KIND,
        )
    };
    ($name:expr, gid = $g:expr) => {
        $crate::obs::span::SpanGuard::begin(
            $name,
            "",
            0,
            $crate::obs::span::NO_ID,
            ($g) as u32,
            $crate::obs::span::NO_KIND,
        )
    };
    ($name:expr, $key:ident = $val:expr) => {
        $crate::obs::span::SpanGuard::begin(
            $name,
            stringify!($key),
            ($val) as u64,
            $crate::obs::span::NO_ID,
            $crate::obs::span::NO_ID,
            $crate::obs::span::NO_KIND,
        )
    };
}

// ---- cross-node trace metadata (the JSONL header row) ---------------------

struct TraceMeta {
    /// node role: "server", "device", "coordinator", "" until declared
    role: &'static str,
    shard: u64,
    session_fp: Option<u64>,
    /// (gid, this process's `elapsed_ns` at the Hello exchange) — the
    /// server stamps one per device at HelloAck send; a device stamps its
    /// own gid at HelloAck receipt. The pair of stamps for one gid differs
    /// by the two clocks' offset (± one-way latency), which is exactly the
    /// shift `slacc trace` applies to join the files.
    anchors: Vec<(u32, u64)>,
}

static META: Mutex<TraceMeta> = Mutex::new(TraceMeta {
    role: "",
    shard: 0,
    session_fp: None,
    anchors: Vec::new(),
});

/// Declare this process's role/shard for the trace header (binaries and
/// examples call this once at launch; latest call wins).
pub fn set_trace_role(role: &'static str, shard: u64) {
    let mut m = lock_clean(&META);
    m.role = role;
    m.shard = shard;
}

/// Declare the negotiated session fingerprint for the trace header
/// (stamped by the runtimes once the Hello exchange has validated it).
pub fn set_trace_session(fp: u64) {
    lock_clean(&META).session_fp = Some(fp);
}

/// Stamp a clock anchor for `gid`: this process's [`elapsed_ns`] at the
/// moment the Hello exchange for that device completed on this side.
/// Re-anchoring a gid replaces the old stamp (latest session wins).
pub fn record_anchor(gid: u32, anchor_ns: u64) {
    let mut m = lock_clean(&META);
    if let Some(slot) = m.anchors.iter_mut().find(|(g, _)| *g == gid) {
        slot.1 = anchor_ns;
    } else {
        m.anchors.push((gid, anchor_ns));
    }
}

/// The header row `write_jsonl` opens each trace file with.
fn header_row() -> Json {
    let m = lock_clean(&META);
    Json::obj(vec![
        ("header", Json::Num(1.0)),
        ("role", Json::Str(m.role.to_string())),
        ("shard", Json::Num(m.shard as f64)),
        (
            "session_fp",
            Json::Str(m.session_fp.map_or(String::new(), |fp| format!("{fp:016x}"))),
        ),
        (
            "anchors",
            Json::Arr(
                m.anchors
                    .iter()
                    .map(|&(g, ns)| {
                        Json::Arr(vec![Json::Num(g as f64), Json::Num(ns as f64)])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Drain every thread's ring: `(thread_name, recorded_since_last_drain,
/// events)` per thread with anything new, events in chronological order,
/// rings cleared. Warns once when any ring overwrote events since the last
/// drain — the trace has holes and `TRACE_DROPPED` says how many.
pub fn drain() -> Vec<(String, u64, Vec<SpanEvent>)> {
    let regs = lock_clean(rings());
    let mut out = Vec::with_capacity(regs.len());
    let mut dropped = 0u64;
    for ring in regs.iter() {
        let mut g = lock_clean(ring);
        let total = g.total;
        g.total = 0;
        let events = g.take();
        dropped += total.saturating_sub(events.len() as u64);
        if total > 0 {
            out.push((g.thread.clone(), total, events));
        }
    }
    if dropped > 0 {
        crate::log_warn!(
            "trace rings overwrote {dropped} span(s) before this drain — the \
             trace has holes (see slacc_trace_dropped_total)"
        );
    }
    out
}

/// Drain all rings to `path` as JSONL: one header row (node role, shard,
/// session fingerprint, Hello clock anchors), then one span per line.
/// Returns the number of span events written.
pub fn write_jsonl(path: &str) -> Result<usize, String> {
    use std::io::Write;
    let mut file = std::fs::File::create(path)
        .map_err(|e| format!("--trace-out {path}: {e}"))?;
    let mut written = 0usize;
    let mut lines = header_row().dump();
    lines.push('\n');
    for (thread, total, events) in drain() {
        let dropped = total.saturating_sub(events.len() as u64);
        for ev in &events {
            let mut fields = vec![
                ("thread", Json::Str(thread.clone())),
                ("name", Json::Str(ev.name.to_string())),
                ("key", Json::Str(ev.key.to_string())),
                ("val", Json::Num(ev.val as f64)),
                ("start_ns", Json::Num(ev.start_ns as f64)),
                ("dur_ns", Json::Num(ev.dur_ns as f64)),
                ("depth", Json::Num(ev.depth as f64)),
            ];
            if ev.round != NO_ID {
                fields.push(("round", Json::Num(ev.round as f64)));
            }
            if ev.gid != NO_ID {
                fields.push(("gid", Json::Num(ev.gid as f64)));
            }
            if ev.kind != NO_KIND {
                fields.push(("kind", Json::Num(ev.kind as f64)));
            }
            lines.push_str(&Json::obj(fields).dump());
            lines.push('\n');
            written += 1;
        }
        if dropped > 0 {
            let row = Json::obj(vec![
                ("thread", Json::Str(thread.clone())),
                ("dropped", Json::Num(dropped as f64)),
            ]);
            lines.push_str(&row.dump());
            lines.push('\n');
        }
    }
    file.write_all(lines.as_bytes())
        .map_err(|e| format!("--trace-out {path}: {e}"))?;
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;

    // span tests share the process-global enable gate, so they must not
    // run concurrently with each other
    static GATE: Mutex<()> = Mutex::new(());

    // run each test's spans on a dedicated named thread so drains are clean
    fn on_thread<F: FnOnce() + Send + 'static>(name: &str, f: F) {
        std::thread::Builder::new()
            .name(name.to_string())
            .spawn(f)
            .unwrap()
            .join()
            .unwrap();
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = lock_clean(&GATE);
        set_enabled(false);
        on_thread("span-off", || {
            let _a = crate::span!("quiet");
            let _b = crate::span!("quiet", device = 3);
            record(SpanEvent::manual("quiet", 1, 2));
        });
        let got: Vec<_> = drain()
            .into_iter()
            .filter(|(t, _, _)| t == "span-off")
            .collect();
        assert!(got.is_empty(), "disabled spans must not touch any ring");
    }

    #[test]
    fn nested_spans_carry_depth_and_attributes() {
        let _g = lock_clean(&GATE);
        set_enabled(true);
        on_thread("span-nest", || {
            let _outer = crate::span!("outer");
            {
                let _inner = crate::span!("inner", device = 7);
            }
        });
        set_enabled(false);
        let mut threads = drain();
        threads.retain(|(t, _, _)| t == "span-nest");
        assert_eq!(threads.len(), 1);
        let (_, total, events) = &threads[0];
        assert_eq!(*total, 2);
        assert_eq!(events.len(), 2);
        // inner drops first
        assert_eq!(events[0].name, "inner");
        assert_eq!(events[0].key, "device");
        assert_eq!(events[0].val, 7);
        assert_eq!(events[0].depth, 2);
        assert_eq!(events[0].round, NO_ID);
        assert_eq!(events[0].gid, NO_ID);
        assert_eq!(events[0].kind, NO_KIND);
        assert_eq!(events[1].name, "outer");
        assert_eq!(events[1].depth, 1);
        assert!(events[1].start_ns <= events[0].start_ns);
        assert!(events[1].dur_ns >= events[0].dur_ns);
    }

    #[test]
    fn fixed_attributes_ride_every_macro_arm() {
        let _g = lock_clean(&GATE);
        set_enabled(true);
        on_thread("span-attrs", || {
            let _a = crate::span!("a", round = 3, gid = 7);
            let _b = crate::span!("b", round = 4, gid = 8, kind = 1u8);
            let _c = crate::span!("c", round = 5, gid = 9, bytes = 100);
            let _d = crate::span!("d", round = 6);
            let _e = crate::span!("e", gid = 10);
            record(
                SpanEvent::manual("m", 50, 25)
                    .round(11)
                    .gid(12)
                    .kind(0)
                    .attr("n", 2),
            );
        });
        set_enabled(false);
        let mut threads = drain();
        threads.retain(|(t, _, _)| t == "span-attrs");
        let (_, _, events) = &threads[0];
        let by_name = |n: &str| *events.iter().find(|e| e.name == n).unwrap();
        let a = by_name("a");
        assert_eq!((a.round, a.gid, a.kind), (3, 7, NO_KIND));
        let b = by_name("b");
        assert_eq!((b.round, b.gid, b.kind), (4, 8, 1));
        let c = by_name("c");
        assert_eq!((c.round, c.gid, c.key, c.val), (5, 9, "bytes", 100));
        let d = by_name("d");
        assert_eq!((d.round, d.gid), (6, NO_ID));
        let e = by_name("e");
        assert_eq!((e.round, e.gid), (NO_ID, 10));
        let m = by_name("m");
        assert_eq!(
            (m.round, m.gid, m.kind, m.start_ns, m.dur_ns, m.key, m.val),
            (11, 12, 0, 50, 25, "n", 2)
        );
    }

    #[test]
    fn ring_overwrites_oldest_but_counts_all() {
        let _g = lock_clean(&GATE);
        set_enabled(true);
        let dropped0 = crate::obs::metrics::TRACE_DROPPED.get();
        on_thread("span-ring", || {
            for i in 0..(RING_CAP + 10) {
                let _s = crate::span!("tick", i = i);
            }
        });
        set_enabled(false);
        let mut threads = drain();
        threads.retain(|(t, _, _)| t == "span-ring");
        let (_, total, events) = &threads[0];
        assert_eq!(*total, (RING_CAP + 10) as u64);
        assert_eq!(events.len(), RING_CAP);
        // oldest 10 were overwritten: first surviving event is i == 10
        assert_eq!(events[0].val, 10);
        assert_eq!(events[RING_CAP - 1].val, (RING_CAP + 9) as u64);
        // ...and the loss is visible on the metrics registry
        assert!(crate::obs::metrics::TRACE_DROPPED.get() - dropped0 >= 10);
    }

    #[test]
    fn jsonl_has_header_and_attribute_fields() {
        let _g = lock_clean(&GATE);
        set_enabled(true);
        set_trace_role("server", 2);
        set_trace_session(0xabcd_1234_5678_9abc);
        record_anchor(5, 1_000);
        record_anchor(5, 2_000); // re-anchor replaces
        record_anchor(6, 3_000);
        on_thread("span-jsonl", || {
            let _s = crate::span!("write_me", round = 4, gid = 9, bytes = 17);
        });
        set_enabled(false);
        let path = std::env::temp_dir().join("slacc_span_test.jsonl");
        let path = path.to_str().unwrap().to_string();
        let n = write_jsonl(&path).unwrap();
        assert!(n >= 1);
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);

        // line 0 is the header row
        let head = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(head.at(&["header"]), &Json::Num(1.0));
        assert_eq!(head.at(&["role"]), &Json::Str("server".to_string()));
        assert_eq!(head.at(&["shard"]), &Json::Num(2.0));
        assert_eq!(
            head.at(&["session_fp"]),
            &Json::Str("abcd123456789abc".to_string())
        );
        let anchors = head.at(&["anchors"]).as_arr().unwrap();
        assert_eq!(anchors.len(), 2);
        assert_eq!(anchors[0].as_arr().unwrap()[1], Json::Num(2000.0));

        let mine: Vec<&str> =
            text.lines().filter(|l| l.contains("span-jsonl")).collect();
        assert_eq!(mine.len(), 1);
        let row = Json::parse(mine[0]).unwrap();
        assert_eq!(row.at(&["name"]), &Json::Str("write_me".to_string()));
        assert_eq!(row.at(&["key"]), &Json::Str("bytes".to_string()));
        assert_eq!(row.at(&["val"]), &Json::Num(17.0));
        assert_eq!(row.at(&["round"]), &Json::Num(4.0));
        assert_eq!(row.at(&["gid"]), &Json::Num(9.0));
        // kind was unset, so the field is omitted
        assert!(row.get("kind").is_none());
    }
}
