//! Fleet telemetry: lock-free metrics registry, tracing spans, and live
//! export.
//!
//! Three pieces, same no-async-runtime discipline as [`crate::sched`]:
//!
//! * [`metrics`] — process-global atomic counters/gauges/histograms with
//!   statically registered handles. The hot path is one relaxed atomic RMW
//!   and zero steady-state allocation; instruments are wired through the
//!   codec layer, the event loop, server compute, round accounting, and the
//!   shard tier.
//! * [`span`] — RAII wall-clock spans (`span!("server_step_batch", width =
//!   n)`) recorded into per-thread ring buffers, ~1ns when disabled via a
//!   relaxed atomic gate, drained to JSONL by `--trace-out FILE`.
//! * [`trace`] — the offline half of `--trace-out`: `slacc trace` merges
//!   multi-node span JSONL onto one clock (via the Hello-exchange anchors
//!   in each file's header row) and decomposes every round into a
//!   critical-path stage breakdown, with an optional Chrome trace-event
//!   export.
//! * [`export`] — a non-blocking Prometheus-style scrape endpoint
//!   (`--metrics-bind ADDR`) serviced from the `PollFleet` event loop, and
//!   a per-round JSONL snapshot writer (`--metrics-every N`). Shard
//!   processes additionally piggyback a counter roll-up on every
//!   `ShardSync` exchange so the coordinator can report cluster-wide
//!   totals.
//!
//! This layer is the measurement substrate ROADMAP's adaptive directions
//! (runtime codec renegotiation, straggler-aware device selection) read
//! from; it observes the session but never alters numerics — telemetry
//! flags are deliberately *not* part of the config fingerprint.

pub mod export;
pub mod metrics;
pub mod span;
pub mod trace;
