//! Lightweight per-channel statistics views over channel-major data.
//!
//! These are the scalar reductions the codecs need per channel (min/max for
//! quantizer boundaries, mean/std for the SplitFC and STD-selection
//! baselines) computed in one pass each.

/// Min and max of a slice in a single pass. Returns (0, 0) for empty input.
pub fn min_max(xs: &[f32]) -> (f32, f32) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mut mn = xs[0];
    let mut mx = xs[0];
    for &x in &xs[1..] {
        if x < mn {
            mn = x;
        }
        if x > mx {
            mx = x;
        }
    }
    (mn, mx)
}

/// Mean and population standard deviation in one pass.
pub fn mean_std(xs: &[f32]) -> (f32, f32) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mut sum = 0.0f64;
    let mut sumsq = 0.0f64;
    for &x in xs {
        sum += x as f64;
        sumsq += (x as f64) * (x as f64);
    }
    let mean = sum / n;
    let var = (sumsq / n - mean * mean).max(0.0);
    (mean as f32, var.sqrt() as f32)
}

/// Squared L2 norm.
pub fn sq_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn min_max_basic() {
        assert_eq!(min_max(&[3.0, -1.0, 2.0]), (-1.0, 3.0));
        assert_eq!(min_max(&[]), (0.0, 0.0));
        assert_eq!(min_max(&[5.0]), (5.0, 5.0));
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0, 4.0]);
        assert!((m - 2.5).abs() < 1e-6);
        assert!((s - (1.25f32).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn mean_std_constant() {
        let (m, s) = mean_std(&[7.0; 100]);
        assert!((m - 7.0).abs() < 1e-6);
        assert!(s.abs() < 1e-6);
    }

    #[test]
    fn sq_norm_basic() {
        assert!((sq_norm(&[3.0, 4.0]) - 25.0).abs() < 1e-9);
    }
}
