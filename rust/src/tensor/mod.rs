//! Dense f32 tensor substrate for the coordinator.
//!
//! The coordinator moves smashed data (NCHW activations / gradients) between
//! the PJRT runtime and the compression codecs. Codecs are channel-wise, so
//! the central utility here is the NCHW ⇄ channel-major (C, N) relayout:
//! channel c owns the N = B·H·W elements `x[b, c, h, w]` for all b/h/w —
//! exactly the grouping ACII's entropy and CGC's quantizer operate over
//! (mirrors `channel_entropy_nchw` on the Python side).

pub mod view;

/// A dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    dims: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            dims.iter().product::<usize>(),
            data.len(),
            "dims {:?} don't match data length {}",
            dims,
            data.len()
        );
        Tensor { dims, data }
    }

    pub fn zeros(dims: Vec<usize>) -> Tensor {
        let len = dims.iter().product();
        Tensor { dims, data: vec![0.0; len] }
    }

    pub fn scalar(x: f32) -> Tensor {
        Tensor { dims: vec![], data: vec![x] }
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with new dims (same element count).
    pub fn reshape(mut self, dims: Vec<usize>) -> Tensor {
        assert_eq!(dims.iter().product::<usize>(), self.data.len());
        self.dims = dims;
        self
    }

    /// NCHW accessor helpers. Panics if not 4-D.
    pub fn nchw(&self) -> (usize, usize, usize, usize) {
        assert_eq!(self.dims.len(), 4, "expected NCHW tensor, got {:?}", self.dims);
        (self.dims[0], self.dims[1], self.dims[2], self.dims[3])
    }

    /// Relayout NCHW -> channel-major (C, N), N = B·H·W.
    pub fn to_channel_major(&self) -> ChannelMajor {
        let (b, c, h, w) = self.nchw();
        let hw = h * w;
        let n = b * hw;
        let mut out = vec![0.0f32; c * n];
        for bi in 0..b {
            for ci in 0..c {
                let src = (bi * c + ci) * hw;
                let dst = ci * n + bi * hw;
                out[dst..dst + hw].copy_from_slice(&self.data[src..src + hw]);
            }
        }
        ChannelMajor { channels: c, n_per_channel: n, batch: b, height: h, width: w, data: out }
    }

    /// Mean absolute difference against another tensor of identical shape.
    pub fn mean_abs_diff(&self, other: &Tensor) -> f64 {
        assert_eq!(self.dims, other.dims);
        let s: f64 = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs() as f64)
            .sum();
        s / self.data.len().max(1) as f64
    }
}

/// Channel-major view of smashed data: row c = channel c's N elements.
#[derive(Debug, Clone)]
pub struct ChannelMajor {
    pub channels: usize,
    pub n_per_channel: usize,
    batch: usize,
    height: usize,
    width: usize,
    data: Vec<f32>,
}

impl ChannelMajor {
    /// Build directly from (C, N) data with explicit original geometry.
    pub fn from_rows(
        channels: usize,
        n_per_channel: usize,
        batch: usize,
        height: usize,
        width: usize,
        data: Vec<f32>,
    ) -> ChannelMajor {
        assert_eq!(channels * n_per_channel, data.len());
        assert_eq!(batch * height * width, n_per_channel);
        ChannelMajor { channels, n_per_channel, batch, height, width, data }
    }

    pub fn channel(&self, c: usize) -> &[f32] {
        let n = self.n_per_channel;
        &self.data[c * n..(c + 1) * n]
    }

    pub fn channel_mut(&mut self, c: usize) -> &mut [f32] {
        let n = self.n_per_channel;
        &mut self.data[c * n..(c + 1) * n]
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Consume the view, returning the raw (C, N) buffer. Lets callers
    /// that built the view from a reusable scratch buffer (via
    /// [`ChannelMajor::from_rows`]) take the allocation back afterwards.
    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Relayout back to NCHW.
    pub fn to_nchw(&self) -> Tensor {
        let (b, c, hw) = (self.batch, self.channels, self.height * self.width);
        let n = self.n_per_channel;
        let mut out = vec![0.0f32; c * n];
        for bi in 0..b {
            for ci in 0..c {
                let src = ci * n + bi * hw;
                let dst = (bi * c + ci) * hw;
                out[dst..dst + hw].copy_from_slice(&self.data[src..src + hw]);
            }
        }
        Tensor::new(vec![b, c, self.height, self.width], out)
    }

    pub fn geometry(&self) -> (usize, usize, usize, usize) {
        (self.batch, self.channels, self.height, self.width)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn random_nchw(dims: (usize, usize, usize, usize), seed: u64) -> Tensor {
        let (b, c, h, w) = dims;
        let mut rng = Pcg32::seeded(seed);
        let data: Vec<f32> = (0..b * c * h * w).map(|_| rng.next_gaussian()).collect();
        Tensor::new(vec![b, c, h, w], data)
    }

    #[test]
    fn channel_major_roundtrip() {
        let t = random_nchw((3, 5, 4, 2), 1);
        let cm = t.to_channel_major();
        assert_eq!(cm.channels, 5);
        assert_eq!(cm.n_per_channel, 3 * 4 * 2);
        assert_eq!(cm.to_nchw(), t);
    }

    #[test]
    fn channel_contents_match_strided_access() {
        let (b, c, h, w) = (2, 3, 2, 2);
        let t = random_nchw((b, c, h, w), 2);
        let cm = t.to_channel_major();
        for ci in 0..c {
            let row = cm.channel(ci);
            let mut k = 0;
            for bi in 0..b {
                for hi in 0..h {
                    for wi in 0..w {
                        let idx = ((bi * c + ci) * h + hi) * w + wi;
                        assert_eq!(row[k], t.data()[idx]);
                        k += 1;
                    }
                }
            }
        }
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let r = t.clone().reshape(vec![3, 2]);
        assert_eq!(r.data(), t.data());
        assert_eq!(r.dims(), &[3, 2]);
    }

    #[test]
    #[should_panic]
    fn bad_dims_panic() {
        let _ = Tensor::new(vec![2, 2], vec![1.0; 5]);
    }

    #[test]
    fn mean_abs_diff_zero_for_identical() {
        let t = random_nchw((1, 2, 3, 3), 3);
        assert_eq!(t.mean_abs_diff(&t), 0.0);
    }

    #[test]
    fn channel_mut_writes_back() {
        let t = random_nchw((2, 2, 2, 2), 4);
        let mut cm = t.to_channel_major();
        for v in cm.channel_mut(1) {
            *v = 7.0;
        }
        let back = cm.to_nchw();
        let (b, c, h, w) = back.nchw();
        for bi in 0..b {
            for hi in 0..h {
                for wi in 0..w {
                    let idx = ((bi * c + 1) * h + hi) * w + wi;
                    assert_eq!(back.data()[idx], 7.0);
                }
            }
        }
    }
}
