//! Elastic fleet membership (proto v6): the per-device state machine that
//! lets a split-learning session survive devices leaving and returning
//! mid-run instead of treating every hang-up as fatal.
//!
//! The server owns one [`MembershipTable`] per session. Every device slot
//! walks the state machine
//!
//! ```text
//!            Hello handshake            PeerClosed / Leave / stall
//!   (start) ----------------> Active ------------------------------+
//!                               ^                                  v
//!                               |        Join (epoch ok)        Departed
//!                          Readmitted <----------- Joining <-------+
//!                               |   JoinAck + Catchup at the
//!                               +-- next round boundary
//! ```
//!
//! Each admission stamps the slot with a fresh **member epoch**: the
//! server returns it in `JoinAck`, the device echoes it in any future
//! `Join`, and [`MembershipTable::begin_join`] rejects a claimed epoch
//! that matches neither "fresh process" (0) nor the slot's current
//! epoch — so a delayed `Join` replayed from a previous incarnation can
//! never re-enter the session and replay an old round.
//!
//! The scheduler consumes two event types produced by an elastic
//! [`crate::sched::fleet::Fleet`]: typed [`Departure`]s (a closed or
//! stalled connection shrinking the participant set, absorbed by the
//! existing quorum semantics) and [`JoinRequest`]s (a parked `Join`
//! handshake awaiting admission at the next round boundary).

use crate::obs::metrics::{DEPARTURES_TOTAL, FLEET_SIZE, JOINS_TOTAL, READMITS_TOTAL};
use crate::transport::proto::Message;
use crate::transport::TransportError;

/// Where one device slot stands in the elastic-membership lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemberState {
    /// A `Join` handshake is parked, awaiting admission at the next round
    /// boundary.
    Joining,
    /// In the session since the initial `Hello` handshake.
    Active,
    /// Connection closed (peer hang-up, write stall, or graceful `Leave`);
    /// the slot is vacant and open to a re-join.
    Departed,
    /// Back in the session after at least one departure (scheduling-wise
    /// identical to `Active`).
    Readmitted,
}

impl MemberState {
    pub fn label(&self) -> &'static str {
        match self {
            MemberState::Joining => "joining",
            MemberState::Active => "active",
            MemberState::Departed => "departed",
            MemberState::Readmitted => "readmitted",
        }
    }
}

#[derive(Debug, Clone)]
struct Entry {
    state: MemberState,
    /// admission epoch: 0 for the initial handshake, bumped by every
    /// re-admission; stamped into `JoinAck` and validated on `Join`
    epoch: u32,
    departures: u32,
}

/// A device connection ended mid-session. In an elastic session these are
/// drained by the scheduler ([`crate::sched::fleet::Fleet::take_departures`])
/// and shrink the participant set; in a fixed-fleet session the same
/// condition stays a fatal [`TransportError`].
#[derive(Debug, Clone)]
pub struct Departure {
    /// connection slot (== global device id on a flat fleet; the fleet
    /// maps slot → gid on sharded shapes)
    pub slot: usize,
    /// what ended the connection; [`TransportError::PeerClosed`] for a
    /// hang-up, `Protocol`/`Io` for stalls and framing violations
    pub error: TransportError,
    /// true when the device announced the departure with a `Leave` frame
    /// before hanging up
    pub graceful: bool,
}

/// A parked `Join` handshake: a late or returning device whose first
/// frame arrived on a fresh connection, held by the fleet until the
/// scheduler admits (or rejects) it at a round boundary.
#[derive(Debug, Clone)]
pub struct JoinRequest {
    /// fleet-internal handle; pass back to `admit_join` / `reject_join`
    pub key: u64,
    /// global device id the connection claims to serve
    pub gid: usize,
    /// admission epoch the device last held (0 for a fresh process)
    pub member_epoch: u32,
    /// the full `Join` frame, so the server can run the same spec-table /
    /// fingerprint validation as the initial `Hello`
    pub msg: Message,
    /// wire size of the `Join` frame, credited to the slot's `WireStats`
    /// on admission so per-device accounting stays exact across
    /// incarnations
    pub join_bytes: u64,
}

/// Per-gid membership state machine for one session, owned by the server
/// (and mirrored per-shard at the coordinator tier). All transitions keep
/// the `slacc_fleet_size` gauge and the join/departure/readmit counters
/// current.
#[derive(Debug)]
pub struct MembershipTable {
    entries: Vec<Entry>,
}

impl MembershipTable {
    /// A table for `n` devices that all completed the initial `Hello`
    /// handshake: everyone starts `Active` at epoch 0.
    pub fn new(n: usize) -> MembershipTable {
        FLEET_SIZE.set(n as i64);
        MembershipTable {
            entries: vec![Entry { state: MemberState::Active, epoch: 0, departures: 0 }; n],
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn state(&self, gid: usize) -> MemberState {
        self.entries[gid].state
    }

    /// Current admission epoch of `gid` (what the next `Join` must claim,
    /// unless it is a fresh process claiming 0).
    pub fn epoch(&self, gid: usize) -> u32 {
        self.entries[gid].epoch
    }

    /// Devices currently in the session (`Active` or `Readmitted`).
    pub fn active_count(&self) -> usize {
        self.entries.iter().filter(|e| is_in_session(e.state)).count()
    }

    /// Is `gid` currently in the session?
    pub fn is_active(&self, gid: usize) -> bool {
        is_in_session(self.entries[gid].state)
    }

    /// Record a departure. Returns false (and changes nothing) if the slot
    /// was already out of the session — close paths may fire twice.
    pub fn depart(&mut self, gid: usize) -> bool {
        let e = &mut self.entries[gid];
        if !is_in_session(e.state) && e.state != MemberState::Joining {
            return false;
        }
        e.state = MemberState::Departed;
        e.departures += 1;
        DEPARTURES_TOTAL.inc();
        FLEET_SIZE.set(self.active_count() as i64);
        true
    }

    /// Validate a `Join` for `gid` and park it as `Joining`. The claimed
    /// epoch must be 0 (a fresh process) or the slot's current epoch (the
    /// same incarnation the server last admitted); anything else is a
    /// stale incarnation replaying an admission it no longer owns.
    pub fn begin_join(&mut self, gid: usize, claimed_epoch: u32) -> Result<(), String> {
        if gid >= self.entries.len() {
            return Err(format!("join for device {gid} of a {}-device fleet", self.entries.len()));
        }
        let e = &mut self.entries[gid];
        if e.state != MemberState::Departed {
            return Err(format!(
                "join for device {gid} in state {} (slot is not vacant)",
                e.state.label()
            ));
        }
        if claimed_epoch != 0 && claimed_epoch != e.epoch {
            return Err(format!(
                "stale member epoch for device {gid}: join claims epoch {claimed_epoch}, \
                 current is {}",
                e.epoch
            ));
        }
        e.state = MemberState::Joining;
        Ok(())
    }

    /// Admit a parked join: `Joining → Readmitted`, stamping and returning
    /// the fresh admission epoch for the `JoinAck`.
    pub fn admit(&mut self, gid: usize) -> Result<u32, String> {
        let e = &mut self.entries[gid];
        if e.state != MemberState::Joining {
            return Err(format!(
                "admit for device {gid} in state {} (no parked join)",
                e.state.label()
            ));
        }
        e.state = MemberState::Readmitted;
        e.epoch += 1;
        JOINS_TOTAL.inc();
        if e.departures > 0 {
            READMITS_TOTAL.inc();
        }
        FLEET_SIZE.set(self.active_count() as i64);
        Ok(self.entries[gid].epoch)
    }

    /// Roll a parked join back to `Departed` (validation failed after
    /// `begin_join`, or the fleet dropped the pending connection).
    pub fn reject(&mut self, gid: usize) {
        let e = &mut self.entries[gid];
        if e.state == MemberState::Joining {
            e.state = MemberState::Departed;
        }
    }
}

fn is_in_session(s: MemberState) -> bool {
    matches!(s, MemberState::Active | MemberState::Readmitted)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_fleet_is_fully_active() {
        let t = MembershipTable::new(4);
        assert_eq!(t.len(), 4);
        assert_eq!(t.active_count(), 4);
        for gid in 0..4 {
            assert_eq!(t.state(gid), MemberState::Active);
            assert_eq!(t.epoch(gid), 0);
            assert!(t.is_active(gid));
        }
    }

    #[test]
    fn depart_join_admit_walks_the_state_machine() {
        let mut t = MembershipTable::new(3);
        assert!(t.depart(1));
        assert_eq!(t.state(1), MemberState::Departed);
        assert_eq!(t.active_count(), 2);
        assert!(!t.is_active(1));

        t.begin_join(1, 0).unwrap();
        assert_eq!(t.state(1), MemberState::Joining);
        assert!(!t.is_active(1), "a parked join is not yet in the session");

        let epoch = t.admit(1).unwrap();
        assert_eq!(epoch, 1);
        assert_eq!(t.state(1), MemberState::Readmitted);
        assert_eq!(t.epoch(1), 1);
        assert_eq!(t.active_count(), 3);
    }

    #[test]
    fn double_depart_is_idempotent() {
        let mut t = MembershipTable::new(2);
        assert!(t.depart(0));
        assert!(!t.depart(0), "second depart of the same slot must be a no-op");
        assert_eq!(t.active_count(), 1);
    }

    #[test]
    fn stale_epoch_join_is_rejected() {
        let mut t = MembershipTable::new(2);
        // first churn cycle: depart, rejoin holding epoch 0 → admitted as 1
        t.depart(0);
        t.begin_join(0, 0).unwrap();
        assert_eq!(t.admit(0).unwrap(), 1);
        // second cycle: the *current* incarnation (epoch 1) may rejoin...
        t.depart(0);
        t.begin_join(0, 1).unwrap();
        assert_eq!(t.admit(0).unwrap(), 2);
        // ...but a replayed Join from the epoch-1 incarnation must bounce
        t.depart(0);
        let err = t.begin_join(0, 1).unwrap_err();
        assert!(err.contains("stale member epoch"), "{err}");
        // a fresh process (epoch 0) is always allowed to claim the slot
        t.begin_join(0, 0).unwrap();
        assert_eq!(t.admit(0).unwrap(), 3);
    }

    #[test]
    fn join_requires_a_vacant_slot() {
        let mut t = MembershipTable::new(2);
        let err = t.begin_join(0, 0).unwrap_err();
        assert!(err.contains("not vacant"), "{err}");
        let err = t.begin_join(5, 0).unwrap_err();
        assert!(err.contains("2-device fleet"), "{err}");
    }

    #[test]
    fn admit_without_parked_join_errors_and_reject_rolls_back() {
        let mut t = MembershipTable::new(2);
        assert!(t.admit(0).is_err());
        t.depart(0);
        assert!(t.admit(0).is_err(), "Departed slot has no parked join");
        t.begin_join(0, 0).unwrap();
        t.reject(0);
        assert_eq!(t.state(0), MemberState::Departed);
        assert!(t.admit(0).is_err(), "rejected join must not be admittable");
    }
}
