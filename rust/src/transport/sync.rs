//! ModelSync payload packing: FedAvg traffic through the codec stack.
//!
//! Client sub-model pushes used to travel as raw f32 tensor lists baked
//! into the frame. They now ride the same payload-envelope machinery as
//! smashed data: the parameter tensors are flattened into one 1×1×1×N
//! channel-major tensor, compressed through the session's *ModelSync codec
//! stream* (`--sync-codec`, identity by default so the default path stays
//! lossless), and prefixed with a shape table so the receiver can rebuild
//! the original tensor list.
//!
//! ```text
//! n_tensors  u32 (<= MAX_TENSORS)
//! per tensor: rank u8 (<= MAX_RANK), dims u32 x rank
//! blob_len   u32
//! blob       codec envelope of the flattened parameters
//! ```
//!
//! Like the frame protocol, every length is capped before allocation. The
//! byte count of the full pack is what `RoundCost::bytes_sync` accounts —
//! separately from the paper's smashed-data axis.

use crate::codecs::{Codec, CodecError, RoundCtx};
use crate::quant::payload::{ByteReader, ByteWriter, MAX_ELEMENTS};
use crate::tensor::{ChannelMajor, Tensor};

/// Cap on tensors per pack (a sub-model has a handful of params).
pub const MAX_TENSORS: usize = 1 << 12;
/// Cap on tensor rank.
pub const MAX_RANK: usize = 8;

/// Reusable scratch for the pack paths: the parameter flatten buffer and
/// the codec-envelope writer. A session endpoint owns one and reuses it
/// across rounds and devices, so the steady-state encode side of a sync
/// push/broadcast performs exactly one allocation — the returned payload
/// the frame takes ownership of (the same contract the PR 3 redesign
/// established for the uplink codecs).
#[derive(Default)]
pub struct SyncScratch {
    flat: Vec<f32>,
    blob: ByteWriter,
}

/// Pack a parameter list through `codec`. An empty list encodes to a
/// shape-table-only pack (the "keep what you have" reply). Convenience
/// wrapper over [`pack_params_with`] with throwaway scratch; per-round
/// callers (the server broadcast loop, the device push) hold a
/// [`SyncScratch`] and call [`pack_params_with`] directly.
pub fn pack_params(params: &[Tensor], codec: &mut dyn Codec) -> Vec<u8> {
    pack_params_with(params, codec, &mut SyncScratch::default())
}

/// [`pack_params`] with caller-owned scratch buffers. Byte-identical
/// output; the warmed steady state performs exactly ONE allocation — the
/// returned payload, sized up front from the already-encoded blob
/// (`benches/codecs.rs` audits this with its counting allocator).
pub fn pack_params_with(
    params: &[Tensor],
    codec: &mut dyn Codec,
    scratch: &mut SyncScratch,
) -> Vec<u8> {
    assert!(params.len() <= MAX_TENSORS, "{} params exceed pack cap", params.len());
    let total: usize = params.iter().map(|t| t.len()).sum();
    scratch.blob.clear();
    if !params.is_empty() {
        scratch.flat.clear();
        scratch.flat.reserve(total);
        for t in params {
            scratch.flat.extend_from_slice(t.data());
        }
        // a flat 1x1x1xN NCHW tensor and its channel-major view share one
        // layout, so the view is built straight over the scratch buffer
        // (no relayout copy) and the buffer is taken back after the encode
        let cm =
            ChannelMajor::from_rows(1, total, 1, 1, total, std::mem::take(&mut scratch.flat));
        codec.encode(&cm, RoundCtx::default(), &mut scratch.blob);
        scratch.flat = cm.into_data();
    }
    let table: usize = params.iter().map(|t| 1 + 4 * t.dims().len()).sum();
    let mut w = ByteWriter::with_capacity(4 + table + 4 + scratch.blob.len());
    w.u32(params.len() as u32);
    for t in params {
        assert!(t.dims().len() <= MAX_RANK, "rank {} exceeds pack cap", t.dims().len());
        w.u8(t.dims().len() as u8);
        for &d in t.dims() {
            w.u32(d as u32);
        }
    }
    if params.is_empty() {
        return w.finish();
    }
    w.u32(scratch.blob.len() as u32);
    w.bytes(scratch.blob.as_slice());
    w.finish()
}

/// Rebuild the parameter list from a pack. `codec` must be a stream twin
/// of the packer's (the envelopes are self-describing, so any instance of
/// the same codec family decodes them).
pub fn unpack_params(bytes: &[u8], codec: &mut dyn Codec) -> Result<Vec<Tensor>, CodecError> {
    let mut r = ByteReader::new(bytes);
    let n = r.u32()? as usize;
    if n > MAX_TENSORS {
        return Err(CodecError::LimitExceeded {
            what: "sync pack tensors",
            claimed: n,
            cap: MAX_TENSORS,
        });
    }
    let mut shapes = Vec::with_capacity(n);
    let mut total = 0usize;
    for _ in 0..n {
        let rank = r.u8()? as usize;
        if rank > MAX_RANK {
            return Err(CodecError::LimitExceeded {
                what: "sync tensor rank",
                claimed: rank,
                cap: MAX_RANK,
            });
        }
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(r.u32()? as usize);
        }
        let elems = dims
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .ok_or(CodecError::LimitExceeded {
                what: "sync tensor elements",
                claimed: usize::MAX,
                cap: MAX_ELEMENTS,
            })?;
        if elems > MAX_ELEMENTS {
            return Err(CodecError::LimitExceeded {
                what: "sync tensor elements",
                claimed: elems,
                cap: MAX_ELEMENTS,
            });
        }
        total = total.checked_add(elems).ok_or(CodecError::LimitExceeded {
            what: "sync pack elements",
            claimed: usize::MAX,
            cap: MAX_ELEMENTS,
        })?;
        shapes.push((dims, elems));
    }
    if total > MAX_ELEMENTS {
        return Err(CodecError::LimitExceeded {
            what: "sync pack elements",
            claimed: total,
            cap: MAX_ELEMENTS,
        });
    }
    if n == 0 {
        r.expect_end()?;
        return Ok(Vec::new());
    }
    let blob_len = r.u32()? as usize;
    if blob_len != r.remaining() {
        return Err(CodecError::Malformed(format!(
            "sync pack blob length {blob_len} disagrees with {} remaining bytes",
            r.remaining()
        )));
    }
    let blob = r.bytes(blob_len)?;
    let flat = codec.decode(blob)?;
    if flat.len() != total {
        return Err(CodecError::Malformed(format!(
            "sync pack decoded to {} elements, shape table wants {total}",
            flat.len()
        )));
    }
    let data = flat.data();
    let mut out = Vec::with_capacity(n);
    let mut off = 0usize;
    for (dims, elems) in shapes {
        out.push(Tensor::new(dims, data[off..off + elems].to_vec()));
        off += elems;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::by_name;

    fn params() -> Vec<Tensor> {
        vec![
            Tensor::new(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 0.25, -7.0]),
            Tensor::scalar(4.0),
            Tensor::new(vec![4], vec![0.1, 0.2, 0.3, 0.4]),
        ]
    }

    #[test]
    fn identity_pack_is_lossless() {
        let mut up = by_name("identity", 1, 10, 0).unwrap();
        let mut twin = by_name("identity", 1, 10, 0).unwrap();
        let pack = pack_params(&params(), up.as_mut());
        let back = unpack_params(&pack, twin.as_mut()).unwrap();
        assert_eq!(back, params());
    }

    #[test]
    fn scratch_pack_is_byte_identical_and_reusable() {
        // one scratch across rounds AND across payload shapes must keep
        // producing exactly the bytes of the allocating path
        let mut scratch = SyncScratch::default();
        let mut a = by_name("uniform8", 1, 10, 0).unwrap();
        let mut b = by_name("uniform8", 1, 10, 0).unwrap();
        let small = params();
        let big = vec![Tensor::new(
            vec![16, 8],
            (0..128).map(|i| (i % 11) as f32 * 0.4 - 2.0).collect(),
        )];
        for round in 0..3 {
            for p in [&small, &big] {
                let fresh = pack_params(p, a.as_mut());
                let reused = pack_params_with(p, b.as_mut(), &mut scratch);
                assert_eq!(fresh, reused, "round {round}");
            }
        }
        // empty packs skip the codec entirely but still work with scratch
        let mut c = by_name("identity", 1, 10, 0).unwrap();
        assert_eq!(
            pack_params(&[], c.as_mut()),
            pack_params_with(&[], c.as_mut(), &mut scratch)
        );
    }

    #[test]
    fn empty_pack_roundtrips() {
        let mut up = by_name("identity", 1, 10, 0).unwrap();
        let pack = pack_params(&[], up.as_mut());
        let back = unpack_params(&pack, up.as_mut()).unwrap();
        assert!(back.is_empty());
    }

    #[test]
    fn lossy_pack_preserves_shapes_and_compresses() {
        let big: Vec<Tensor> = vec![Tensor::new(
            vec![32, 16],
            (0..512).map(|i| (i % 17) as f32 * 0.3 - 1.0).collect(),
        )];
        let mut up = by_name("uniform4", 1, 10, 0).unwrap();
        let mut twin = by_name("uniform4", 1, 10, 0).unwrap();
        let pack = pack_params(&big, up.as_mut());
        let back = unpack_params(&pack, twin.as_mut()).unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].dims(), &[32, 16]);
        // 4-bit quantization: the pack must be well under raw f32
        assert!(pack.len() < 512 * 4, "pack {} >= raw {}", pack.len(), 512 * 4);
    }

    #[test]
    fn hostile_shape_tables_rejected() {
        let mut codec = by_name("identity", 1, 10, 0).unwrap();
        // claims 2^20 tensors
        let mut w = ByteWriter::new();
        w.u32(1 << 20);
        assert!(unpack_params(&w.finish(), codec.as_mut()).is_err());
        // one tensor claiming terabytes of elements
        let mut w = ByteWriter::new();
        w.u32(1);
        w.u8(4);
        for _ in 0..4 {
            w.u32(60000);
        }
        assert!(unpack_params(&w.finish(), codec.as_mut()).is_err());
        // truncated shape table
        let mut w = ByteWriter::new();
        w.u32(2);
        w.u8(1);
        assert!(unpack_params(&w.finish(), codec.as_mut()).is_err());
        // blob length lies about the remaining bytes
        let mut w = ByteWriter::new();
        w.u32(1);
        w.u8(1);
        w.u32(2);
        w.u32(9999);
        w.f32(1.0);
        assert!(unpack_params(&w.finish(), codec.as_mut()).is_err());
    }

    #[test]
    fn shape_mismatch_against_blob_rejected() {
        // pack two floats but advertise three in the shape table
        let mut up = by_name("identity", 1, 10, 0).unwrap();
        let good = pack_params(&[Tensor::new(vec![2], vec![1.0, 2.0])], up.as_mut());
        // rebuild with a lying shape table: rank-1 dim 3
        let mut w = ByteWriter::new();
        w.u32(1);
        w.u8(1);
        w.u32(3);
        // splice the original blob (skip n=4, rank=1, dim=4 ... recompute)
        // simplest: take everything after the original 10-byte shape table
        let blob_and_len = &good[4 + 1 + 4..];
        w.bytes(blob_and_len);
        assert!(unpack_params(&w.finish(), up.as_mut()).is_err());
    }
}
