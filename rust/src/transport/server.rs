//! Server-side session runtime: accepts N device connections and drives
//! stages ii–iii of the round loop per device — decompress the uplink
//! envelope, `server_step` through [`Compute`], compress the downlink
//! gradients — plus FedAvg aggregation, evaluation, metrics, and the
//! simulated-time accounting.
//!
//! The runtime is transport-agnostic: the in-process trainer hands it
//! loopback connections plus a `pump` callback that runs each device
//! worker's turn, while `slacc serve` hands it TCP connections and a
//! no-op pump (remote devices run themselves). Either way the round loop
//! is this one code path, and `NetworkSim::round_cost` is fed the same
//! codec-envelope byte counts the simulator always measured.
//!
//! Devices are *processed* in device-id order every round (the shared
//! server sub-model makes stage iii inherently sequential, as in SFL), so
//! a session's numerics and wire bytes are identical across transports
//! and timings.

use std::sync::Arc;
use std::time::Instant;

use crate::codecs::{Codec, RoundCtx};
use crate::config::ExperimentConfig;
use crate::coordinator::device::fedavg_params;
use crate::coordinator::metrics::{MetricsLog, RoundRecord, TrainReport};
use crate::coordinator::server::ServerState;
use crate::data::Dataset;
use crate::net::timeline::Timeline;
use crate::net::NetworkSim;
use crate::tensor::Tensor;

use super::compute::{self, Compute, MockCompute, StepOut};
use super::proto::Message;
use super::Transport;

/// The run shape a server session enforces (a projection of
/// [`ExperimentConfig`] plus the model's batch geometry).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub devices: usize,
    pub rounds: usize,
    pub lr: f32,
    pub eval_every: usize,
    pub client_agg_every: usize,
    pub target_accuracy: Option<f64>,
    pub compress_gradients: bool,
    /// codec label for logs and the report
    pub label: String,
    /// evaluation batch size (the artifacts are shape-specialized)
    pub eval_batch: usize,
    /// [`ExperimentConfig::fingerprint`] of the launching config; devices
    /// must present the same digest in their Hello
    pub config_fp: u64,
}

/// What a device declared in its Hello frame.
#[derive(Debug, Clone)]
pub struct DeviceHello {
    pub device_id: usize,
    pub shard_len: usize,
    pub codec: String,
    pub config_fp: u64,
}

/// Receive one Hello per connection and order connections by device id.
/// Connections may arrive in any order (TCP accept order is racy); the
/// Hello tells the server which slot each one serves.
pub fn handshake(
    conns: Vec<Box<dyn Transport>>,
    devices: usize,
) -> Result<(Vec<Box<dyn Transport>>, Vec<DeviceHello>), String> {
    if conns.len() != devices {
        return Err(format!("handshake: {} connections for {devices} devices", conns.len()));
    }
    let mut slots: Vec<Option<(Box<dyn Transport>, DeviceHello)>> =
        (0..devices).map(|_| None).collect();
    for mut conn in conns {
        let msg = conn.recv()?;
        let (device_id, fleet, shard_len, codec, config_fp) = match msg {
            Message::Hello { device_id, devices, shard_len, codec, config_fp } => {
                (device_id as usize, devices as usize, shard_len as usize, codec, config_fp)
            }
            other => {
                return Err(format!(
                    "handshake: expected Hello from {}, got {}",
                    conn.peer(),
                    other.type_name()
                ))
            }
        };
        if fleet != devices {
            return Err(format!(
                "device {device_id} was configured for {fleet} devices, server for {devices}"
            ));
        }
        if device_id >= devices {
            return Err(format!("device id {device_id} out of range (devices={devices})"));
        }
        if shard_len == 0 {
            return Err(format!("device {device_id} declares an empty data shard"));
        }
        if slots[device_id].is_some() {
            return Err(format!("two connections claim device id {device_id}"));
        }
        crate::log_info!(
            "transport: device {device_id} connected from {} (shard={shard_len}, codec={codec})",
            conn.peer()
        );
        slots[device_id] =
            Some((conn, DeviceHello { device_id, shard_len, codec, config_fp }));
    }
    let mut out_conns = Vec::with_capacity(devices);
    let mut hellos = Vec::with_capacity(devices);
    for (d, slot) in slots.into_iter().enumerate() {
        let (conn, hello) = slot.ok_or_else(|| format!("no connection for device {d}"))?;
        out_conns.push(conn);
        hellos.push(hello);
    }
    Ok((out_conns, hellos))
}

/// The server half of an SL training session.
pub struct ServerRuntime<C: Compute> {
    cfg: ServeConfig,
    compute: C,
    server: ServerState,
    /// per-device uplink codec twins (decompression is wire-driven, so a
    /// fresh instance mirrors the device's compressor exactly)
    up_codecs: Vec<Box<dyn Codec>>,
    /// per-device downlink compressors (the compress-side state lives here)
    down_codecs: Vec<Box<dyn Codec>>,
    /// last client sub-model each device pushed via ModelSync
    client_params: Vec<Option<Vec<Tensor>>>,
    test: Arc<Dataset>,
    net: NetworkSim,
    timeline: Timeline,
    metrics: MetricsLog,
}

impl<C: Compute> ServerRuntime<C> {
    pub fn new(
        cfg: ServeConfig,
        compute: C,
        server_init: Vec<Tensor>,
        up_codecs: Vec<Box<dyn Codec>>,
        down_codecs: Vec<Box<dyn Codec>>,
        test: Arc<Dataset>,
        net: NetworkSim,
    ) -> Result<ServerRuntime<C>, String> {
        if up_codecs.len() != cfg.devices || down_codecs.len() != cfg.devices {
            return Err(format!(
                "runtime wants {} up / {} down codecs for {} devices",
                up_codecs.len(),
                down_codecs.len(),
                cfg.devices
            ));
        }
        let client_params = (0..cfg.devices).map(|_| None).collect();
        Ok(ServerRuntime {
            cfg,
            compute,
            server: ServerState::new(server_init),
            up_codecs,
            down_codecs,
            client_params,
            test,
            net,
            timeline: Timeline::new(),
            metrics: MetricsLog::new(),
        })
    }

    pub fn devices(&self) -> usize {
        self.cfg.devices
    }

    pub fn metrics(&self) -> &MetricsLog {
        &self.metrics
    }

    /// Test accuracy of (client, server) params over the held-out set.
    pub fn evaluate_with(&mut self, client: &[Tensor]) -> Result<f64, String> {
        let batch = self.cfg.eval_batch;
        let n_batches = self.test.len() / batch;
        if n_batches == 0 {
            return Err("test set smaller than one batch".into());
        }
        let mut correct = 0usize;
        let mut total = 0usize;
        for bi in 0..n_batches {
            let idx: Vec<usize> = (bi * batch..(bi + 1) * batch).collect();
            let (x, y) = self.test.batch(&idx);
            let x_dims = [batch, self.test.channels, self.test.height, self.test.width];
            let logits = self.compute.eval_logits(
                client,
                &self.server.server_params,
                &x,
                &x_dims,
            )?;
            let classes = self.test.classes;
            for (i, &label) in y.iter().enumerate() {
                let row = &logits.data()[i * classes..(i + 1) * classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred == label as usize {
                    correct += 1;
                }
                total += 1;
            }
        }
        Ok(correct as f64 / total as f64)
    }

    fn evaluate(&mut self) -> Result<f64, String> {
        let client = self.client_params[0]
            .take()
            .ok_or("evaluate: device 0 has not synced its client sub-model")?;
        let acc = self.evaluate_with(&client);
        self.client_params[0] = Some(client);
        acc
    }

    /// Drive a full training session over the given (handshaken, device-id
    /// ordered) connections. `pump(d)` gives in-process device workers
    /// their turn; pass a no-op for remote transports.
    pub fn serve(
        &mut self,
        conns: &mut [Box<dyn Transport>],
        hellos: &[DeviceHello],
        mut pump: impl FnMut(usize) -> Result<(), String>,
    ) -> Result<TrainReport, String> {
        let n = self.cfg.devices;
        if conns.len() != n || hellos.len() != n {
            return Err(format!(
                "serve: {} connections / {} hellos for {n} devices",
                conns.len(),
                hellos.len()
            ));
        }
        let want_fp = super::session_fingerprint(self.cfg.config_fp, self.compute.kind());
        for (d, hello) in hellos.iter().enumerate() {
            let want = self.up_codecs[d].name();
            if hello.codec != want {
                return Err(format!(
                    "device {d} runs codec '{}', server expects '{want}' — \
                     launch both sides with the same --codec flags",
                    hello.codec
                ));
            }
            if hello.config_fp != want_fp {
                return Err(format!(
                    "device {d} presents session fingerprint {:#018x}, server expects \
                     {want_fp:#018x} — launch both sides with identical flags \
                     (lr/seed/dataset/partition/...) and the same engine-vs-mock mode",
                    hello.config_fp
                ));
            }
        }
        let weights: Vec<f64> = hellos.iter().map(|h| h.shard_len as f64).collect();
        for (d, conn) in conns.iter_mut().enumerate() {
            conn.send(&Message::HelloAck {
                device_id: d as u32,
                rounds: self.cfg.rounds as u32,
                agg_every: self.cfg.client_agg_every as u32,
            })?;
        }
        for d in 0..n {
            pump(d)?;
        }

        let label = self.cfg.label.clone();
        let mut time_to_target = None;
        let mut rounds_run = 0;
        'rounds: for round in 0..self.cfg.rounds {
            let wall = Instant::now();
            let agg_due = (round + 1) % self.cfg.client_agg_every == 0;
            let eval_due =
                (round + 1) % self.cfg.eval_every == 0 || round + 1 == self.cfg.rounds;
            // aggregation needs every device's sub-model; evaluation only
            // device 0's — don't ship N-1 unused full models on eval-only
            // rounds (ModelSync is outside the smashed-data byte axis, but
            // it is real wall-clock on a wide fleet)
            let wants_sync = |d: usize| agg_due || (eval_due && d == 0);

            // stage i fans out to every device in parallel
            for (d, conn) in conns.iter_mut().enumerate() {
                conn.send(&Message::RoundOpen { round: round as u32, sync: wants_sync(d) })?;
            }
            for d in 0..n {
                pump(d)?;
            }

            // stages ii-iii, sequential in device order (shared server model)
            let mut up_bytes = vec![0usize; n];
            let mut down_bytes = vec![0usize; n];
            let mut loss_sum = 0.0f64;
            for d in 0..n {
                let msg = conns[d].recv()?;
                let (r2, dev, labels, payload) = match msg {
                    Message::Activations { round, device_id, labels, payload } => {
                        (round as usize, device_id as usize, labels, payload)
                    }
                    other => {
                        return Err(format!(
                            "round {round}: expected Activations from device {d}, got {}",
                            other.type_name()
                        ))
                    }
                };
                if r2 != round || dev != d {
                    return Err(format!(
                        "round {round}: device {d} sent activations for round {r2} as device {dev}"
                    ));
                }
                up_bytes[d] = payload.len();
                let acts_hat = self.up_codecs[d].decompress(&payload)?;

                let StepOut { loss, g_acts, new_params } = self.compute.server_step(
                    &self.server.server_params,
                    &acts_hat,
                    &labels,
                    self.cfg.lr,
                )?;
                if !loss.is_finite() {
                    return Err(format!("round {round} device {d}: loss diverged ({loss})"));
                }
                loss_sum += loss;
                self.server.update(new_params);

                // downlink: every path goes through a codec envelope (the
                // uncompressed config uses IdentityCodec), so byte
                // accounting is comparable across configs
                let g_ent = if self.cfg.compress_gradients {
                    Some(self.compute.entropy(&g_acts)?)
                } else {
                    None
                };
                let g_cm = g_acts.to_channel_major();
                let payload_down = self.down_codecs[d]
                    .compress(&g_cm, RoundCtx { entropy: g_ent.as_deref() });
                down_bytes[d] = payload_down.len();
                conns[d].send(&Message::Gradients {
                    round: round as u32,
                    device_id: d as u32,
                    loss: loss as f32,
                    payload: payload_down,
                })?;
            }
            for d in 0..n {
                pump(d)?;
            }

            // SFL aggregation / model sync
            if agg_due || eval_due {
                for d in 0..n {
                    if !wants_sync(d) {
                        continue;
                    }
                    let msg = conns[d].recv()?;
                    match msg {
                        Message::ModelSync { device_id, tensors, .. }
                            if device_id as usize == d && !tensors.is_empty() =>
                        {
                            self.client_params[d] = Some(tensors);
                        }
                        other => {
                            return Err(format!(
                                "round {round}: expected non-empty ModelSync from device {d}, got {}",
                                other.type_name()
                            ))
                        }
                    }
                }
                if agg_due {
                    let sets: Vec<&[Tensor]> = self
                        .client_params
                        .iter()
                        .map(|p| p.as_deref().expect("all devices just synced"))
                        .collect();
                    // peers are remote: reject mismatched sub-models here
                    // rather than panicking (or silently truncating) inside
                    // the weighted average
                    for (d, set) in sets.iter().enumerate().skip(1) {
                        let shapes_match = set.len() == sets[0].len()
                            && set
                                .iter()
                                .zip(sets[0].iter())
                                .all(|(a, b)| a.dims() == b.dims());
                        if !shapes_match {
                            return Err(format!(
                                "round {round}: device {d} synced a client sub-model \
                                 whose shape differs from device 0's"
                            ));
                        }
                    }
                    let reply = fedavg_params(&sets, &weights);
                    for (d, conn) in conns.iter_mut().enumerate() {
                        conn.send(&Message::ModelSync {
                            round: round as u32,
                            device_id: d as u32,
                            tensors: reply.clone(),
                        })?;
                    }
                    for p in self.client_params.iter_mut() {
                        *p = Some(reply.clone());
                    }
                }
                for d in 0..n {
                    pump(d)?;
                }
            }

            // accounting + evaluation, identical to the simulator semantics
            let cost = self.net.round_cost(&up_bytes, &down_bytes);
            self.timeline.push(cost);
            rounds_run = round + 1;
            let loss = loss_sum / n as f64;
            let accuracy = if eval_due { Some(self.evaluate()?) } else { None };
            let rec = RoundRecord {
                round,
                loss,
                accuracy,
                bytes_up: cost.bytes_up,
                bytes_down: cost.bytes_down,
                sim_time_s: self.timeline.total_time(),
                wall_ms: wall.elapsed().as_secs_f64() * 1e3,
            };
            if let Some(acc) = accuracy {
                crate::log_info!(
                    "[{label}] round {round}: loss {loss:.4} acc {:.2}% sim_t {:.1}s",
                    acc * 100.0,
                    rec.sim_time_s
                );
                if let Some(target) = self.cfg.target_accuracy {
                    if acc >= target && time_to_target.is_none() {
                        time_to_target = Some(rec.sim_time_s);
                        self.metrics.push(rec);
                        break 'rounds;
                    }
                }
            } else {
                crate::log_debug!("[{label}] round {round}: loss {loss:.4}");
            }
            self.metrics.push(rec);
        }

        for conn in conns.iter_mut() {
            conn.send(&Message::Shutdown { reason: "training complete".into() })?;
        }
        for d in 0..n {
            pump(d)?;
        }
        let framed: u64 = conns.iter().map(|c| c.stats().bytes_sent + c.stats().bytes_recv).sum();
        let (bytes_up, bytes_down) = self.metrics.total_bytes();
        crate::log_info!(
            "[{label}] session done: {rounds_run} rounds, {} payload bytes, {framed} framed bytes",
            bytes_up + bytes_down
        );
        Ok(TrainReport {
            label,
            final_accuracy: self.metrics.final_accuracy().unwrap_or(0.0),
            best_accuracy: self.metrics.best_accuracy().unwrap_or(0.0),
            total_sim_time_s: self.timeline.total_time(),
            total_bytes_up: bytes_up,
            total_bytes_down: bytes_down,
            time_to_target_s: time_to_target,
            rounds_run,
            metrics: std::mem::take(&mut self.metrics),
        })
    }
}

/// Accept `runtime.devices()` TCP connections on `listener`, handshake,
/// and run the session (remote devices pump themselves).
pub fn accept_and_serve<C: Compute>(
    runtime: &mut ServerRuntime<C>,
    listener: &std::net::TcpListener,
) -> Result<TrainReport, String> {
    let n = runtime.devices();
    let mut conns: Vec<Box<dyn Transport>> = Vec::with_capacity(n);
    for i in 0..n {
        crate::log_info!("transport: waiting for device connection {}/{n}", i + 1);
        conns.push(Box::new(super::tcp::TcpTransport::accept(listener)?));
    }
    let (mut conns, hellos) = handshake(conns, n)?;
    runtime.serve(&mut conns, &hellos, |_| Ok(()))
}

/// Build the engine-free server runtime for a mock session (the twin of
/// [`super::device::mock_worker`]).
pub fn mock_runtime(
    cfg: &ExperimentConfig,
    test: Arc<Dataset>,
) -> Result<ServerRuntime<MockCompute>, String> {
    let channels = compute::MOCK_CUT.0;
    let mut ups = Vec::with_capacity(cfg.devices);
    let mut downs = Vec::with_capacity(cfg.devices);
    for d in 0..cfg.devices {
        ups.push(cfg.uplink_codec(channels, d)?);
        downs.push(cfg.downlink_codec(channels, d)?);
    }
    let classes = test.classes;
    ServerRuntime::new(
        cfg.serve_config(compute::MOCK_BATCH),
        MockCompute::new(classes),
        compute::mock_server_init(),
        ups,
        downs,
        test,
        cfg.network(),
    )
}

/// Run a complete mock session over in-process loopback transports:
/// N device workers + the server runtime on one thread. This is the
/// engine-free twin of `Trainer::run`, used by the transport tests and
/// `examples/distributed.rs` to check loopback/TCP byte parity.
pub fn run_mock_loopback(cfg: &ExperimentConfig) -> Result<TrainReport, String> {
    cfg.validate()?;
    let (train, test) = Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
    let train = Arc::new(train);
    let mut runtime = mock_runtime(cfg, Arc::new(test))?;
    let mut workers = Vec::with_capacity(cfg.devices);
    let mut dev_conns = Vec::with_capacity(cfg.devices);
    let mut srv_conns: Vec<Box<dyn Transport>> = Vec::with_capacity(cfg.devices);
    for d in 0..cfg.devices {
        let worker = super::device::mock_worker(cfg, train.clone(), d)?;
        let (mut dev_end, srv_end) = super::loopback::pair(&format!("mock{d}"));
        dev_end.send(&worker.hello())?;
        workers.push(worker);
        dev_conns.push(dev_end);
        srv_conns.push(Box::new(srv_end));
    }
    let (mut conns, hellos) = handshake(srv_conns, cfg.devices)?;
    runtime.serve(&mut conns, &hellos, |d| {
        super::device::pump(&mut workers[d], &mut dev_conns[d])
    })
}
