//! Server-side session runtime: accepts N device connections and performs
//! the compute half of stages ii–iii per device — decompress the uplink
//! envelope, `server_step` through [`Compute`], compress the downlink
//! gradients — plus FedAvg aggregation, evaluation, metrics, and the
//! simulated-time accounting.
//!
//! The runtime is transport-agnostic *and* schedule-agnostic: the round
//! flow (who is stepped when, straggler handling) is owned by
//! [`crate::sched::round::RoundScheduler`] driving a
//! [`crate::sched::fleet::Fleet`] — the in-process trainer hands it
//! loopback connections behind a [`crate::sched::fleet::PumpFleet`], while
//! `slacc serve` hands it the poll-driven
//! [`crate::sched::event_loop::PollFleet`]. Either way the compute path is
//! this one code path, and `NetworkSim::round_cost_sched` is fed the same
//! codec-envelope byte counts the simulator always measured.
//!
//! Under the default `InOrder` policy devices are processed in device-id
//! order every round (the shared server sub-model makes stage iii
//! inherently sequential, as in SFL), so a session's numerics and wire
//! bytes are identical across transports and timings. `ArrivalOrder`
//! trades that determinism for wall-clock: see the scheduler docs.

use std::sync::Arc;

use crate::adapt::{self, AdaptState, PendingUpdate, RoundObs, SpecEpochs};
use crate::codecs::stream::{
    record_decode, record_encode, StreamKind, StreamSet, StreamSpecs,
};
use crate::codecs::RoundCtx;
use crate::config::ExperimentConfig;
use crate::coordinator::device::fedavg_params;
use crate::coordinator::metrics::{MetricsLog, TrainReport};
use crate::coordinator::server::ServerState;
use crate::data::Dataset;
use crate::net::timeline::{SchedRecord, Timeline};
use crate::net::NetworkSim;
use crate::obs::export::{MetricsExporter, SnapshotWriter};
use crate::obs::metrics;
use crate::span;
use crate::quant::payload::ByteWriter;
use crate::member::{JoinRequest, MembershipTable};
use crate::sched::fleet::{ChurnEvent, Fleet, PumpFleet};
use crate::sched::round::RoundScheduler;
use crate::sched::{Participation, Policy};
use crate::shard::link::ShardLink;
use crate::shard::FleetShape;
use crate::tensor::Tensor;

use super::compute::{self, Compute, MockCompute, StepOut};
use super::proto::Message;
use super::{sync, Transport, TransportError};

/// The run shape a server session enforces (a projection of
/// [`ExperimentConfig`] plus the model's batch geometry).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// devices this node serves (the LOCAL count — a shard of a
    /// multi-server topology serves a slice of the fleet)
    pub devices: usize,
    /// total devices in the cluster (what every device's Hello declares)
    pub global_devices: usize,
    /// first global device id this node serves (0 on a single server)
    pub device_base: usize,
    pub rounds: usize,
    pub lr: f32,
    pub eval_every: usize,
    pub client_agg_every: usize,
    pub target_accuracy: Option<f64>,
    pub compress_gradients: bool,
    /// codec label for logs and the report
    pub label: String,
    /// evaluation batch size (the artifacts are shape-specialized)
    pub eval_batch: usize,
    /// [`ExperimentConfig::fingerprint`] of the launching config; devices
    /// must present the same digest in their Hello
    pub config_fp: u64,
    /// round-scheduling policy (see [`crate::sched::Policy`])
    pub schedule: Policy,
    /// `--batch-window`: max same-shaped Activations coalesced into one
    /// `server_step_batch` dispatch (arrival-order scheduling only;
    /// InOrder forces 1 to stay message-for-message deterministic)
    pub batch_window: usize,
    /// the negotiated per-stream codec spec table; devices must present
    /// an identical table in their Hello (mismatches are rejected naming
    /// the offending stream)
    pub specs: StreamSpecs,
    /// `--adapt`: runtime renegotiation directive (see [`crate::adapt`]);
    /// None freezes the handshake table for the whole session (the
    /// historical behavior)
    pub adapt: Option<String>,
    /// `--elastic`: admit proto-v6 `Join`s mid-session and treat closed
    /// connections as typed departures instead of fatal errors (see
    /// [`crate::member`]); requires arrival-order scheduling
    pub elastic: bool,
    /// `--select`: which in-session devices a round opens for
    pub participation: Participation,
}

impl ServeConfig {
    /// Global device id of local slot `d` (messages on the wire always
    /// carry global ids; the runtime's arrays are local-indexed).
    pub fn gid(&self, d: usize) -> usize {
        self.device_base + d
    }

    /// The fleet slice this node handshakes with.
    pub fn shape(&self) -> FleetShape {
        FleetShape {
            global: self.global_devices,
            base: self.device_base,
            local: self.devices,
        }
    }
}

/// What a device declared in its Hello frame. `device_id` is the *global*
/// id; a sharded node maps it onto a local slot via
/// [`FleetShape::slot`].
#[derive(Debug, Clone)]
pub struct DeviceHello {
    pub device_id: usize,
    pub shard_len: usize,
    /// the per-stream spec table the device was configured with
    pub streams: StreamSpecs,
    pub config_fp: u64,
}

/// Validate one handshake frame against the fleet slice this node serves.
/// Shared by the blocking [`handshake`] and the poll-loop accept
/// ([`crate::sched::event_loop::PollFleet::accept`]).
pub fn hello_from_message(
    msg: Message,
    shape: FleetShape,
    peer: &str,
) -> Result<DeviceHello, String> {
    let (device_id, fleet, shard_len, config_fp, uplink, downlink, sync, streams_fp) =
        match msg {
            Message::Hello {
                device_id,
                devices,
                shard_len,
                config_fp,
                uplink,
                downlink,
                sync,
                streams_fp,
            } => (
                device_id as usize,
                devices as usize,
                shard_len as usize,
                config_fp,
                uplink,
                downlink,
                sync,
                streams_fp,
            ),
            Message::ShardHello { shards, .. } => {
                return Err(format!(
                    "handshake: {peer} opened with a ShardHello ({shards} shards) \
                     — this port serves devices; coordinators connect to \
                     --shard-bind"
                ))
            }
            other => {
                return Err(format!(
                    "handshake: expected Hello from {peer}, got {}",
                    other.type_name()
                ))
            }
        };
    if fleet != shape.global {
        return Err(format!(
            "device {device_id} was configured for {fleet} devices, the cluster \
             for {}",
            shape.global
        ));
    }
    if device_id >= shape.global {
        return Err(format!(
            "device id {device_id} out of range (devices={})",
            shape.global
        ));
    }
    if shape.slot(device_id).is_none() {
        return Err(format!(
            "device {device_id} connected to the wrong shard (this shard serves \
             devices {}..{})",
            shape.base,
            shape.base + shape.local
        ));
    }
    if shard_len == 0 {
        return Err(format!("device {device_id} declares an empty data shard"));
    }
    let streams = StreamSpecs::parse(&uplink, &downlink, &sync).map_err(|e| {
        format!("device {device_id} presents an invalid stream spec table: {e}")
    })?;
    if streams.fingerprint() != streams_fp {
        return Err(format!(
            "device {device_id}: stream table digest {streams_fp:#018x} does not \
             match its own spec strings ({}) — corrupted or mismatched Hello",
            streams.table()
        ));
    }
    Ok(DeviceHello { device_id, shard_len, streams, config_fp })
}

/// Receive one Hello per connection and order connections by local slot.
/// Connections may arrive in any order (TCP accept order is racy); the
/// Hello tells the server which slot each one serves.
pub fn handshake(
    conns: Vec<Box<dyn Transport>>,
    shape: FleetShape,
) -> Result<(Vec<Box<dyn Transport>>, Vec<DeviceHello>), String> {
    if conns.len() != shape.local {
        return Err(format!(
            "handshake: {} connections for {} devices",
            conns.len(),
            shape.local
        ));
    }
    let mut slots: Vec<Option<(Box<dyn Transport>, DeviceHello)>> =
        (0..shape.local).map(|_| None).collect();
    for mut conn in conns {
        let msg = conn.recv()?;
        let peer = conn.peer();
        let hello = hello_from_message(msg, shape, &peer)?;
        let slot = shape.slot(hello.device_id).expect("validated by hello_from_message");
        if slots[slot].is_some() {
            return Err(format!("two connections claim device id {}", hello.device_id));
        }
        crate::log_info!(
            "transport: device {} connected from {peer} (shard={}, {})",
            hello.device_id,
            hello.shard_len,
            hello.streams.table()
        );
        slots[slot] = Some((conn, hello));
    }
    let mut out_conns = Vec::with_capacity(shape.local);
    let mut hellos = Vec::with_capacity(shape.local);
    for (slot, entry) in slots.into_iter().enumerate() {
        let (conn, hello) = entry
            .ok_or_else(|| format!("no connection for device {}", shape.gid(slot)))?;
        out_conns.push(conn);
        hellos.push(hello);
    }
    Ok((out_conns, hellos))
}

/// The server half of an SL training session.
pub struct ServerRuntime<C: Compute> {
    pub(crate) cfg: ServeConfig,
    pub(crate) compute: C,
    pub(crate) server: ServerState,
    /// every per-device, per-direction codec instance, organized as
    /// per-round *epochs* ([`SpecEpochs`]): epoch 0 is the
    /// handshake-negotiated table, later epochs are installed by accepted
    /// `--adapt` transitions, and lookups key on the frame's round so
    /// stale-round traffic (carried stragglers) is served under the table
    /// its round ran with. Decode twins mirror the devices' compressors
    /// exactly; sync streams are session-long and stay pinned to epoch 0.
    pub(crate) streams: SpecEpochs,
    /// raw (pre-codec) f32 bytes moved this round per stream kind
    /// [uplink, downlink, sync] — drained by `take_round_raw` at each
    /// round close for the per-stream compression-ratio axis
    pub(crate) raw_round: [usize; 3],
    /// last client sub-model each device pushed via ModelSync
    pub(crate) client_params: Vec<Option<Vec<Tensor>>>,
    /// FedAvg weights (shard sizes), filled in at handshake
    pub(crate) weights: Vec<f64>,
    pub(crate) test: Arc<Dataset>,
    pub(crate) net: NetworkSim,
    pub(crate) timeline: Timeline,
    pub(crate) metrics: MetricsLog,
    /// one downlink-encode scratch shared across a batch's devices (the
    /// frame still owns its payload; this kills the per-device buffer
    /// growth the old fresh-`ByteWriter`-per-device path paid)
    down_scratch: ByteWriter,
    /// flatten + envelope scratch for the sync broadcast loop
    sync_scratch: sync::SyncScratch,
    /// total `server_step` items executed (one per device Activations)
    server_steps: usize,
    /// total `server_step_batch` dispatches those items crossed the
    /// compute boundary in — the amortization numerator
    server_dispatches: usize,
    /// coordinator link of a sharded topology (None on a single server):
    /// [`ServerRuntime::cross_shard`] exchanges sub-models through it at
    /// `--shard-sync-every` round boundaries
    shard: Option<ShardLink>,
    /// shard-link wire bytes this round (push + merged reply), drained at
    /// round close onto the `bytes_sync` axis
    pub(crate) shard_round_wire: usize,
    /// `--metrics-every`: periodic registry snapshots, written at round
    /// close (None unless the CLI attached one)
    pub(crate) snapshot: Option<SnapshotWriter>,
    /// `--adapt`: the renegotiation control loop (controller + in-flight
    /// transition), consulted at every round close; None runs the frozen
    /// handshake table
    pub(crate) adapt: Option<AdaptState>,
    /// elastic-membership state machine, one entry per local slot; only
    /// consulted when `cfg.elastic` (a fixed fleet stays all-Active)
    pub(crate) membership: MembershipTable,
    /// the most recent FedAvg broadcast, kept for re-admission catchup: a
    /// returning device receives it through its (rebuilt) sync stream so
    /// it rejoins on the fleet's current client sub-model
    pub(crate) last_broadcast: Option<Vec<Tensor>>,
}

/// One device's uplink contribution awaiting the next batched dispatch:
/// everything [`ServerRuntime::step_batch`] needs to run stages ii–iii
/// for that device.
pub struct BatchItem {
    pub d: usize,
    /// the round this Activations frame belongs to (a carried straggler's
    /// stale round can ride in the same batch as current-round items)
    pub round: usize,
    pub labels: Vec<i32>,
    pub payload: Vec<u8>,
}

impl<C: Compute> ServerRuntime<C> {
    pub fn new(
        cfg: ServeConfig,
        compute: C,
        server_init: Vec<Tensor>,
        streams: StreamSet,
        test: Arc<Dataset>,
        net: NetworkSim,
    ) -> Result<ServerRuntime<C>, String> {
        if streams.devices() != cfg.devices {
            return Err(format!(
                "runtime got a stream set for {} devices, session has {}",
                streams.devices(),
                cfg.devices
            ));
        }
        if streams.specs() != &cfg.specs {
            return Err(format!(
                "runtime stream set was built from a different spec table \
                 ({} vs {})",
                streams.specs().table(),
                cfg.specs.table()
            ));
        }
        if cfg.batch_window == 0 {
            return Err("batch window must be >= 1".into());
        }
        let adapt = cfg
            .adapt
            .as_deref()
            .map(|d| AdaptState::from_directive(d, &cfg.specs))
            .transpose()?;
        let client_params = (0..cfg.devices).map(|_| None).collect();
        let membership = MembershipTable::new(cfg.devices);
        Ok(ServerRuntime {
            cfg,
            compute,
            server: ServerState::new(server_init),
            streams: SpecEpochs::new(streams),
            raw_round: [0; 3],
            client_params,
            weights: Vec::new(),
            test,
            net,
            timeline: Timeline::new(),
            metrics: MetricsLog::new(),
            down_scratch: ByteWriter::new(),
            sync_scratch: sync::SyncScratch::default(),
            server_steps: 0,
            server_dispatches: 0,
            shard: None,
            shard_round_wire: 0,
            snapshot: None,
            adapt,
            membership,
            last_broadcast: None,
        })
    }

    /// Attach a `--metrics-every` snapshot writer; one JSONL registry
    /// snapshot lands per cadence boundary at round close.
    pub fn attach_snapshot_writer(&mut self, writer: SnapshotWriter) {
        self.snapshot = Some(writer);
    }

    /// Attach this shard's coordinator link (multi-server topologies
    /// only). The session will exchange sub-models through it at every
    /// `--shard-sync-every` aggregation boundary and announce its
    /// departure at shutdown.
    pub fn attach_shard_link(&mut self, link: ShardLink) {
        self.shard = Some(link);
    }

    /// Drain the per-round raw-byte counters ([uplink, downlink, sync]).
    pub(crate) fn take_round_raw(&mut self) -> [usize; 3] {
        std::mem::take(&mut self.raw_round)
    }

    pub fn devices(&self) -> usize {
        self.cfg.devices
    }

    pub fn metrics(&self) -> &MetricsLog {
        &self.metrics
    }

    /// Per-round scheduling records (participants/stragglers/waits), for
    /// policy comparisons and tests.
    pub fn sched_records(&self) -> Vec<SchedRecord> {
        self.timeline.sched_records()
    }

    /// Test accuracy of (client, server) params over the held-out set.
    ///
    /// The whole walk is handed to [`Compute::eval_logits_batch`] in one
    /// call, so a backend with a stacked `eval_logits` artifact evaluates
    /// the full test set in a single dispatch (the same PJRT-boundary
    /// amortization `server_step_batch` buys training); the default
    /// implementation is the historical per-batch walk, bit for bit.
    pub fn evaluate_with(&mut self, client: &[Tensor]) -> Result<f64, String> {
        let batch = self.cfg.eval_batch;
        let n_batches = self.test.len() / batch;
        if n_batches == 0 {
            return Err("test set smaller than one batch".into());
        }
        let x_dims = [batch, self.test.channels, self.test.height, self.test.width];
        // the whole walk is materialized so the stacked path can concat it
        // into one dispatch — a deliberate peak-memory-for-dispatch trade
        // (one extra f32 copy of the held-out set, a few MB at our sizes)
        let mut xs_data: Vec<Vec<f32>> = Vec::with_capacity(n_batches);
        let mut ys: Vec<Vec<i32>> = Vec::with_capacity(n_batches);
        for bi in 0..n_batches {
            let idx: Vec<usize> = (bi * batch..(bi + 1) * batch).collect();
            let (x, y) = self.test.batch(&idx);
            xs_data.push(x);
            ys.push(y);
        }
        let xs: Vec<&[f32]> = xs_data.iter().map(|v| v.as_slice()).collect();
        let logits_list = self.compute.eval_logits_batch(
            client,
            &self.server.server_params,
            &xs,
            &x_dims,
        )?;
        if logits_list.len() != n_batches {
            return Err(format!(
                "eval_logits_batch returned {} outputs for {n_batches} batches",
                logits_list.len()
            ));
        }
        let classes = self.test.classes;
        let mut correct = 0usize;
        let mut total = 0usize;
        for (logits, y) in logits_list.iter().zip(&ys) {
            for (i, &label) in y.iter().enumerate() {
                let row = &logits.data()[i * classes..(i + 1) * classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred == label as usize {
                    correct += 1;
                }
                total += 1;
            }
        }
        Ok(correct as f64 / total as f64)
    }

    pub(crate) fn evaluate(&mut self) -> Result<f64, String> {
        let client = self.client_params[0]
            .take()
            .ok_or("evaluate: device 0 has not synced its client sub-model")?;
        let acc = self.evaluate_with(&client);
        self.client_params[0] = Some(client);
        acc
    }

    /// Stages ii–iii for a batch of device uplinks: per device decode,
    /// then ONE `server_step_batch` dispatch per same-shaped run (the
    /// PJRT-boundary amortization `--batch-window` exists for), then per
    /// device entropy + downlink encode. Returns per-item
    /// `(loss, downlink payload)` in input order. A single-item slice is
    /// exactly the old `step_device`.
    pub(crate) fn step_batch(
        &mut self,
        items: &[BatchItem],
    ) -> Result<Vec<(f64, Vec<u8>)>, String> {
        // stage ii (server half): decode every uplink envelope through its
        // device's stream — per-device state, inherently per-item work
        let mut acts: Vec<Tensor> = Vec::with_capacity(items.len());
        for it in items {
            let t0 = std::time::Instant::now();
            let acts_hat = {
                let _sp = span!(
                    "uplink_decode",
                    round = it.round,
                    gid = self.cfg.gid(it.d),
                    kind = StreamKind::Uplink
                );
                // epoch lookup by the frame's round: a carried straggler's
                // stale round decodes under the table it was opened with
                self.streams.for_round(it.round).device(it.d).up.decode(&it.payload).map_err(
                    |e| format!("round {}: device {} uplink stream: {e}", it.round, it.d),
                )?
            };
            record_decode(StreamKind::Uplink, t0, it.payload.len());
            self.raw_round[0] += acts_hat.len() * 4;
            acts.push(acts_hat);
        }

        let mut results: Vec<(f64, Vec<u8>)> = Vec::with_capacity(items.len());
        let mut i = 0usize;
        while i < items.len() {
            // one dispatch per run of same-shaped activations (the batch
            // planner already groups by wire-header dims; this re-check
            // costs nothing and keeps step_batch safe standalone)
            let mut j = i + 1;
            while j < items.len() && acts[j].dims() == acts[i].dims() {
                j += 1;
            }
            let group_acts: Vec<&Tensor> = acts[i..j].iter().collect();
            let group_ys: Vec<&[i32]> =
                items[i..j].iter().map(|it| it.labels.as_slice()).collect();
            let dispatch_t0 = std::time::Instant::now();
            let mut outs = {
                let _sp =
                    span!("server_step_batch", round = items[i].round, width = j - i);
                self.compute.server_step_batch(
                    &self.server.server_params,
                    &group_acts,
                    &group_ys,
                    self.cfg.lr,
                )?
            };
            metrics::SERVER_STEP_BATCH_NS.observe(dispatch_t0.elapsed().as_nanos() as u64);
            metrics::DISPATCH_WIDTH.observe((j - i) as u64);
            metrics::SERVER_DISPATCHES.inc();
            metrics::SERVER_STEPS.add((j - i) as u64);
            if outs.len() != j - i {
                return Err(format!(
                    "server_step_batch returned {} outputs for {} items",
                    outs.len(),
                    j - i
                ));
            }
            self.server_dispatches += 1;
            self.server_steps += j - i;
            // the shared model advances to the end of the chain (or the
            // fused update — batched backends may fill only the final
            // StepOut's new_params)
            let final_params = outs
                .iter_mut()
                .rev()
                .find(|o| !o.new_params.is_empty())
                .map(|o| std::mem::take(&mut o.new_params))
                .ok_or("server_step_batch returned no parameter update")?;
            self.server.update(final_params);

            for (it, out) in items[i..j].iter().zip(outs) {
                let StepOut { loss, g_acts, .. } = out;
                if !loss.is_finite() {
                    return Err(format!(
                        "round {} device {}: loss diverged ({loss})",
                        it.round, it.d
                    ));
                }
                // downlink: every path goes through a codec envelope (the
                // uncompressed config uses the identity stream), so byte
                // accounting is comparable across configs
                let g_ent = if self.cfg.compress_gradients {
                    Some(self.compute.entropy(&g_acts)?)
                } else {
                    None
                };
                let g_cm = g_acts.to_channel_major();
                self.raw_round[1] += g_cm.data().len() * 4;
                // ONE warmed scratch serves every downlink encode in the
                // batch; the frame still owns its payload (the to_vec is
                // the single steady-state allocation per message)
                self.down_scratch.clear();
                let enc_t0 = std::time::Instant::now();
                {
                    let _sp = span!(
                        "downlink_encode",
                        round = it.round,
                        gid = self.cfg.gid(it.d),
                        kind = StreamKind::Downlink
                    );
                    self.streams.for_round(it.round).device(it.d).down.encode(
                        &g_cm,
                        RoundCtx {
                            entropy: g_ent.as_deref(),
                            kind: Some(StreamKind::Downlink),
                        },
                        &mut self.down_scratch,
                    );
                }
                record_encode(StreamKind::Downlink, enc_t0, self.down_scratch.len());
                results.push((loss, self.down_scratch.to_vec()));
            }
            i = j;
        }
        Ok(results)
    }

    /// (items stepped, compute dispatches they crossed the boundary in)
    /// so far — `benches/batching.rs` and the equivalence tests read the
    /// amortization off the report.
    pub fn dispatch_stats(&self) -> (usize, usize) {
        (self.server_steps, self.server_dispatches)
    }

    /// How many stream-table epochs this session has negotiated so far
    /// (1 = the handshake table was never retuned).
    pub fn spec_epochs(&self) -> usize {
        self.streams.len()
    }

    /// The telemetry view the controller decides on: the just-closed
    /// round's per-stream compression ratios plus the live obs-registry
    /// entropy-drift gauges and the scheduler's worst wait.
    fn round_obs(&self, max_wait_s: f64) -> RoundObs {
        let (ratio_up, ratio_down) = self
            .metrics
            .records
            .last()
            .map(|r| (r.ratio_up(), r.ratio_down()))
            .unwrap_or((0.0, 0.0));
        RoundObs {
            ratio_up,
            ratio_down,
            entropy_mean_milli: metrics::ENTROPY_MEAN_UP.get(),
            entropy_var_milli: metrics::ENTROPY_VAR_UP.get(),
            max_wait_s,
        }
    }

    /// The `--adapt` hook, called by both schedulers after every round
    /// close (except a stopping one): consult the controller and, if it
    /// retunes, push the [`Message::SpecUpdate`] to the whole fleet and
    /// install the new epoch server-side. At most one transition is in
    /// flight: while a pushed update still owes acks the controller is not
    /// consulted, and the deferred decision fires at a later boundary.
    pub(crate) fn adapt_after_close(
        &mut self,
        round: usize,
        fleet: &mut dyn Fleet,
        max_wait_s: f64,
    ) -> Result<(), String> {
        // the state is taken out for the duration so the controller can be
        // consulted while `self` assembles telemetry and drives the fleet
        let Some(mut adapt) = self.adapt.take() else { return Ok(()) };
        let result = self.adapt_step(&mut adapt, round, fleet, max_wait_s);
        self.adapt = Some(adapt);
        result
    }

    fn adapt_step(
        &mut self,
        adapt: &mut AdaptState,
        round: usize,
        fleet: &mut dyn Fleet,
        max_wait_s: f64,
    ) -> Result<(), String> {
        if adapt.pending.is_some() {
            return Ok(()); // a pushed transition still owes acks
        }
        let activate = round + adapt::ACTIVATION_LEAD;
        if activate >= self.cfg.rounds {
            return Ok(()); // no full round left to activate in
        }
        let obs = self.round_obs(max_wait_s);
        let Some(next_up) = adapt.controller.decide(round, &obs) else {
            return Ok(());
        };
        let current = self.streams.current_specs().clone();
        let next = adapt::retuned_specs(&current, &next_up)
            .map_err(|e| format!("round {round}: --adapt retune to '{next_up}': {e}"))?;
        if next == current {
            return Ok(()); // the controller re-chose the active table
        }
        let t0 = crate::util::logging::elapsed_ns();
        let set = self
            .streams
            .current()
            .rebuilt(next.clone())
            .map_err(|e| format!("round {round}: rebuilding streams for '{next_up}': {e}"))?;
        let fp = next.fingerprint();
        crate::log_info!(
            "[{}] round {round}: spec update -> {} (digest {fp:#018x}, activates \
             round {activate})",
            self.cfg.label,
            next.table()
        );
        let n = self.cfg.devices;
        for d in 0..n {
            fleet.send(d, &Message::SpecUpdate {
                activate_round: activate as u32,
                uplink: next.uplink.as_str().to_string(),
                downlink: next.downlink.as_str().to_string(),
                sync: next.sync.as_str().to_string(),
                streams_fp: fp,
            })?;
        }
        for d in 0..n {
            fleet.pump(d)?;
        }
        // the transition boundary is a first-class critical-path stage:
        // `slacc trace` attributes it to the activation round instead of
        // letting renegotiation time inflate `other`
        if crate::obs::span::enabled() {
            let now = crate::util::logging::elapsed_ns();
            crate::obs::span::record(
                crate::obs::span::SpanEvent::manual(
                    "spec_update",
                    t0,
                    now.saturating_sub(t0),
                )
                .round(activate as u32)
                .attr("digest", fp),
            );
        }
        self.streams.push(activate, set);
        adapt.pending = Some(PendingUpdate { activate, fp, unacked: vec![true; n] });
        Ok(())
    }

    /// Accept a device's [`Message::SpecUpdateAck`], matching it against
    /// the in-flight transition by activation round and digest.
    pub(crate) fn accept_spec_ack(
        &mut self,
        d: usize,
        activate: usize,
        fp: u64,
    ) -> Result<(), String> {
        let adapt = self.adapt.as_mut().ok_or_else(|| {
            format!("device {d}: SpecUpdateAck on a session without --adapt")
        })?;
        let pending = adapt.pending.as_mut().ok_or_else(|| {
            format!("device {d}: SpecUpdateAck with no spec update in flight")
        })?;
        if activate != pending.activate || fp != pending.fp {
            return Err(format!(
                "device {d}: SpecUpdateAck for round {activate} digest {fp:#018x}, \
                 the in-flight update is round {} digest {:#018x}",
                pending.activate, pending.fp
            ));
        }
        if !pending.unacked[d] {
            return Err(format!(
                "device {d}: duplicate SpecUpdateAck for round {activate}"
            ));
        }
        pending.unacked[d] = false;
        if pending.fully_acked() {
            adapt.pending = None; // settled: the controller may retune again
        }
        Ok(())
    }

    /// Protocol discipline at the activation boundary: a device whose
    /// frame belongs to round `>= activate` without having acked the
    /// in-flight update is violating the renegotiation handshake (its
    /// codec state would silently diverge from the server's epoch).
    pub(crate) fn spec_ack_gate(&self, d: usize, round: usize) -> Result<(), String> {
        if let Some(p) = self.adapt.as_ref().and_then(|a| a.pending.as_ref()) {
            if round >= p.activate && p.unacked[d] {
                return Err(format!(
                    "round {round}: device {d} entered spec-update activation round \
                     {} without acking the update (digest {:#018x})",
                    p.activate, p.fp
                ));
            }
        }
        Ok(())
    }

    /// Accept a device's ModelSync push (unpack through its sync stream).
    pub(crate) fn accept_sync(&mut self, d: usize, payload: &[u8]) -> Result<(), String> {
        let t0 = std::time::Instant::now();
        let tensors =
            sync::unpack_params(payload, self.streams.sync_set().device(d).sync_up.as_mut())
                .map_err(|e| format!("device {d} sync stream (push): {e}"))?;
        record_decode(StreamKind::Sync, t0, payload.len());
        if tensors.is_empty() {
            return Err(format!("device {d}: ModelSync push carried no tensors"));
        }
        self.raw_round[2] += tensors.iter().map(|t| t.len() * 4).sum::<usize>();
        self.client_params[d] = Some(tensors);
        Ok(())
    }

    /// Pack the FedAvg result for device `d`'s downlink sync stream. One
    /// caller-owned scratch (flatten buffer + envelope writer) serves the
    /// whole broadcast loop instead of a fresh allocation set per device;
    /// downstream, `PollFleet::send` writes the resulting payload with a
    /// vectored write (frame prefix + borrowed payload), so the packed
    /// bytes are never copied into a per-device frame buffer either.
    pub(crate) fn pack_broadcast(&mut self, d: usize, params: &[Tensor]) -> Vec<u8> {
        self.raw_round[2] += params.iter().map(|t| t.len() * 4).sum::<usize>();
        let t0 = std::time::Instant::now();
        let payload = sync::pack_params_with(
            params,
            self.streams.sync_set().device(d).sync_down.as_mut(),
            &mut self.sync_scratch,
        );
        record_encode(StreamKind::Sync, t0, payload.len());
        payload
    }

    /// Weighted FedAvg over `basis` (device-id order preserved for f32
    /// reproducibility). Rejects shape-mismatched sub-models — peers are
    /// remote, so this must not panic.
    pub(crate) fn fedavg_over(
        &self,
        basis: &[usize],
        round: usize,
    ) -> Result<Vec<Tensor>, String> {
        let mut sets: Vec<&[Tensor]> = Vec::with_capacity(basis.len());
        let mut weights = Vec::with_capacity(basis.len());
        for &d in basis {
            let set = self.client_params[d].as_deref().ok_or_else(|| {
                format!("round {round}: device {d} has no synced sub-model to aggregate")
            })?;
            sets.push(set);
            weights.push(self.weights[d]);
        }
        for (i, set) in sets.iter().enumerate().skip(1) {
            let shapes_match = set.len() == sets[0].len()
                && set.iter().zip(sets[0].iter()).all(|(a, b)| a.dims() == b.dims());
            if !shapes_match {
                return Err(format!(
                    "round {round}: device {} synced a client sub-model \
                     whose shape differs from device {}'s",
                    basis[i], basis[0]
                ));
            }
        }
        Ok(fedavg_params(&sets, &weights))
    }

    /// After a full-fleet aggregation every device holds the reply.
    pub(crate) fn set_all_params(&mut self, reply: Vec<Tensor>) {
        self.last_broadcast = Some(reply.clone());
        for p in self.client_params.iter_mut() {
            *p = Some(reply.clone());
        }
    }

    /// Admit (or reject) a parked `Join` at a round boundary. Runs the
    /// same validation as the initial `Hello` — fleet size, session
    /// fingerprint, per-stream spec table, data-shard size — plus the
    /// membership epoch check, then rebuilds the slot's server-side codec
    /// twins (a re-joiner is a fresh process with fresh stream state) and
    /// assembles the reply frames: a `JoinAck` stamping the new admission
    /// epoch and a `Catchup` carrying the last FedAvg broadcast through
    /// the rebuilt sync stream (empty payload = no aggregation yet, keep
    /// the local init). On `Err` the slot is rolled back to `Departed`;
    /// the caller forwards the reason via `Fleet::reject_join`.
    pub(crate) fn process_join(
        &mut self,
        req: &JoinRequest,
        round: usize,
    ) -> Result<Vec<Message>, String> {
        let d = self
            .cfg
            .shape()
            .slot(req.gid)
            .ok_or_else(|| format!("join for device {} outside this shard's slice", req.gid))?;
        self.membership.begin_join(d, req.member_epoch)?;
        let checked = (|| -> Result<usize, String> {
            let Message::Join {
                devices, shard_len, config_fp, uplink, downlink, sync, streams_fp, ..
            } = &req.msg
            else {
                return Err(format!("device {}: parked join holds a non-Join frame", req.gid));
            };
            if *devices as usize != self.cfg.global_devices {
                return Err(format!(
                    "device {} rejoins a {}-device cluster, session has {}",
                    req.gid, devices, self.cfg.global_devices
                ));
            }
            if *shard_len == 0 {
                return Err(format!("device {} declares an empty data shard", req.gid));
            }
            let want_fp =
                super::session_fingerprint(self.cfg.config_fp, self.compute.kind());
            if *config_fp != want_fp {
                return Err(format!(
                    "device {} rejoins with session fingerprint {config_fp:#018x}, \
                     server expects {want_fp:#018x}",
                    req.gid
                ));
            }
            let streams = StreamSpecs::parse(uplink, downlink, sync)
                .map_err(|e| format!("device {} join spec table: {e}", req.gid))?;
            if streams.fingerprint() != *streams_fp {
                return Err(format!(
                    "device {}: join stream digest {streams_fp:#018x} does not match \
                     its own spec strings ({})",
                    req.gid,
                    streams.table()
                ));
            }
            for kind in StreamKind::ALL {
                let want = self.cfg.specs.get(kind);
                let got = streams.get(kind);
                if got != want {
                    return Err(format!(
                        "device {} rejoins with {} stream '{got}', session runs \
                         '{want}'",
                        req.gid,
                        kind.label()
                    ));
                }
            }
            Ok(*shard_len as usize)
        })();
        let shard_len = match checked {
            Ok(s) => s,
            Err(e) => {
                self.membership.reject(d);
                return Err(e);
            }
        };
        if let Err(e) = self.streams.rebuild_device(d) {
            self.membership.reject(d);
            return Err(format!("device {}: rebuilding streams on rejoin: {e}", req.gid));
        }
        let epoch = self.membership.admit(d)?;
        self.weights[d] = shard_len as f64;
        let payload = match self.last_broadcast.take() {
            Some(params) => {
                let _sp = span!("catchup", round = round, gid = req.gid);
                let p = self.pack_broadcast(d, &params);
                self.client_params[d] = Some(params.clone());
                self.last_broadcast = Some(params);
                p
            }
            None => Vec::new(),
        };
        crate::log_info!(
            "[{}] round {round}: device {} re-admitted (epoch {epoch}, catchup {} bytes)",
            self.cfg.label,
            req.gid,
            payload.len()
        );
        Ok(vec![
            Message::JoinAck {
                device_id: req.gid as u32,
                round: round as u32,
                member_epoch: epoch,
                rounds: self.cfg.rounds as u32,
                agg_every: self.cfg.client_agg_every as u32,
            },
            Message::Catchup {
                round: round as u32,
                device_id: req.gid as u32,
                spec_epoch: (self.streams.len() - 1) as u32,
                payload,
            },
        ])
    }

    /// The cross-shard sync point: if this node is a shard of a
    /// multi-server topology and `round` is a `--shard-sync-every`
    /// boundary, exchange the local aggregation result (`local`, the
    /// shard's FedAvg'd client sub-model — `None` when a quorum round had
    /// no client basis) and the server sub-model with the coordinator and
    /// apply the cluster-wide merge of both. No link, or an off-cadence
    /// round, passes `local` through untouched. Wire bytes land on the
    /// `bytes_sync` axis at round close; raw bytes feed the sync
    /// compression ratio.
    pub(crate) fn cross_shard(
        &mut self,
        round: usize,
        local: Option<Vec<Tensor>>,
    ) -> Result<Option<Vec<Tensor>>, String> {
        // disjoint field borrows: the link is driven while the server
        // params are read, then replaced
        let ServerRuntime { shard, server, raw_round, shard_round_wire, .. } = self;
        let Some(link) = shard.as_mut() else { return Ok(local) };
        if !link.due(round) {
            return Ok(local);
        }
        let raw = |ts: &[Tensor]| ts.iter().map(|t| t.len() * 4).sum::<usize>();
        let client_push: &[Tensor] = local.as_deref().unwrap_or(&[]);
        raw_round[2] += raw(client_push) + raw(&server.server_params);
        // the barrier span covers the whole blocking exchange (push +
        // coordinator merge wait); the inner `shard_sync` span inside
        // `ShardLink::exchange` keys on epoch, this one on the round
        let (merged_client, merged_server) = {
            let _sp = span!("shard_barrier", round = round);
            link.exchange(client_push, &server.server_params)
                .map_err(|e| format!("round {round}: shard link: {e}"))?
        };
        let (wire_up, wire_down) = link.last_wire();
        *shard_round_wire += wire_up + wire_down;
        raw_round[2] += raw(&merged_client) + raw(&merged_server);
        // the coordinator is a remote peer: shape-validate before applying
        use crate::shard::shapes_match;
        if !shapes_match(&merged_server, &server.server_params) {
            return Err(format!(
                "round {round}: coordinator returned a server sub-model whose \
                 shape differs from this shard's"
            ));
        }
        server.update(merged_server);
        if merged_client.is_empty() {
            if local.is_some() {
                return Err(format!(
                    "round {round}: coordinator dropped this shard's client \
                     sub-model from the merge"
                ));
            }
            return Ok(None);
        }
        if let Some(l) = &local {
            if !shapes_match(&merged_client, l) {
                return Err(format!(
                    "round {round}: coordinator returned a client sub-model \
                     whose shape differs from this shard's"
                ));
            }
        }
        Ok(Some(merged_client))
    }

    /// Drive a full training session over the given (handshaken,
    /// slot-ordered) connections. `pump(d)` gives in-process device
    /// workers their turn; pass a no-op for remote transports. Convenience
    /// wrapper over [`ServerRuntime::serve_fleet`] with a [`PumpFleet`].
    pub fn serve(
        &mut self,
        conns: &mut [Box<dyn Transport>],
        hellos: &[DeviceHello],
        pump: impl FnMut(usize) -> Result<(), TransportError>,
    ) -> Result<TrainReport, String> {
        let mut fleet = PumpFleet::new(conns, pump);
        self.serve_fleet(&mut fleet, hellos)
    }

    /// Drive a full training session over any [`Fleet`]: validate the
    /// handshakes, ack, run the configured scheduling policy, shut down.
    pub fn serve_fleet(
        &mut self,
        fleet: &mut dyn Fleet,
        hellos: &[DeviceHello],
    ) -> Result<TrainReport, String> {
        let n = self.cfg.devices;
        if fleet.devices() != n || hellos.len() != n {
            return Err(format!(
                "serve: {} connections / {} hellos for {n} devices",
                fleet.devices(),
                hellos.len()
            ));
        }
        let want_fp = super::session_fingerprint(self.cfg.config_fp, self.compute.kind());
        for (d, hello) in hellos.iter().enumerate() {
            // per-stream spec comparison first: a stream mismatch is
            // reported by name (with its flag), not as an opaque digest
            for kind in StreamKind::ALL {
                let want = self.cfg.specs.get(kind);
                let got = hello.streams.get(kind);
                if got != want {
                    return Err(format!(
                        "device {d} runs {} stream '{got}', server expects '{want}' — \
                         launch both sides with the same {} (or --codec) flag",
                        kind.label(),
                        kind.flag()
                    ));
                }
            }
            if hello.config_fp != want_fp {
                return Err(format!(
                    "device {d} presents session fingerprint {:#018x}, server expects \
                     {want_fp:#018x} — launch both sides with identical flags \
                     (lr/seed/dataset/partition/schedule/...) and the same \
                     engine-vs-mock mode",
                    hello.config_fp
                ));
            }
        }
        self.weights = hellos.iter().map(|h| h.shard_len as f64).collect();
        // trace joinability: the session fingerprint names the session in
        // every node's trace header, and the per-device anchor (this side's
        // monotonic clock at HelloAck send; the device stamps its own at
        // receipt) lets `slacc trace` align the two clocks offline
        crate::obs::span::set_trace_session(want_fp);
        for d in 0..n {
            fleet.send(d, &Message::HelloAck {
                device_id: self.cfg.gid(d) as u32,
                rounds: self.cfg.rounds as u32,
                agg_every: self.cfg.client_agg_every as u32,
            })?;
            crate::obs::span::record_anchor(
                self.cfg.gid(d) as u32,
                crate::util::logging::elapsed_ns(),
            );
        }
        for d in 0..n {
            fleet.pump(d)?;
        }

        let label = self.cfg.label.clone();
        let policy = self.cfg.schedule;
        let window = self.cfg.batch_window;
        if self.cfg.elastic {
            if !matches!(policy, Policy::ArrivalOrder { .. }) {
                return Err(
                    "elastic membership requires arrival-order scheduling (the \
                     in-order schedule cannot absorb a shrinking participant set)"
                        .into(),
                );
            }
            if self.adapt.is_some() {
                return Err(
                    "elastic membership and --adapt are mutually exclusive (a \
                     re-joining device cannot replay a mid-session spec \
                     renegotiation)"
                        .into(),
                );
            }
        }
        if self.cfg.participation == Participation::BiasStragglers
            && !matches!(policy, Policy::ArrivalOrder { .. })
        {
            return Err(
                "--select bias-stragglers requires arrival-order scheduling".into(),
            );
        }
        if window > 1 && policy == Policy::InOrder {
            crate::log_info!(
                "[{label}] --batch-window {window} forced to 1 under the \
                 in-order schedule (its byte-level determinism contract \
                 precludes coalescing); use --schedule arrival to batch"
            );
        }
        crate::log_info!(
            "[{label}] serving {n} devices, schedule={} batch_window={window}",
            policy.label()
        );
        let outcome = RoundScheduler::new(policy).run(self, fleet)?;

        // leave the sync tier cleanly (early stop included) before the
        // device shutdowns, so the coordinator never blocks on a finished
        // shard's next push
        if let Some(link) = self.shard.as_mut() {
            link.finish().map_err(|e| format!("shard link shutdown: {e}"))?;
        }
        for d in 0..n {
            // a departed slot of an elastic session has nobody to notify
            if fleet.vacant(d) {
                continue;
            }
            fleet.send(d, &Message::Shutdown { reason: "training complete".into() })?;
        }
        for d in 0..n {
            if fleet.vacant(d) {
                continue;
            }
            fleet.pump(d)?;
        }
        let framed: u64 = (0..n)
            .map(|d| {
                let s = fleet.stats(d);
                s.bytes_sent + s.bytes_recv
            })
            .sum();
        let (bytes_up, bytes_down) = self.metrics.total_bytes();
        let (ratio_up, ratio_down, ratio_sync) = self.metrics.ratio_by_stream();
        crate::log_info!(
            "[{label}] session done: {} rounds, {} payload bytes, {framed} framed bytes",
            outcome.rounds_run,
            bytes_up + bytes_down
        );
        Ok(TrainReport {
            label,
            final_accuracy: self.metrics.final_accuracy().unwrap_or(0.0),
            best_accuracy: self.metrics.best_accuracy().unwrap_or(0.0),
            total_sim_time_s: self.timeline.total_time(),
            total_bytes_up: bytes_up,
            total_bytes_down: bytes_down,
            total_bytes_sync: self.metrics.total_bytes_sync(),
            ratio_up,
            ratio_down,
            ratio_sync,
            time_to_target_s: outcome.time_to_target_s,
            rounds_run: outcome.rounds_run,
            straggler_events: self.metrics.straggler_events(),
            server_steps: self.server_steps,
            server_dispatches: self.server_dispatches,
            device_waits: self
                .timeline
                .device_wait_profiles(n)
                .into_iter()
                .enumerate()
                .map(|(d, p)| (self.cfg.gid(d), p))
                .collect(),
            metrics: std::mem::take(&mut self.metrics),
        })
    }
}

/// Accept `runtime.devices()` TCP connections on `listener` into the
/// poll-driven event loop and run the session (remote devices pump
/// themselves). One thread, no reader thread per connection.
pub fn accept_and_serve<C: Compute>(
    runtime: &mut ServerRuntime<C>,
    listener: &std::net::TcpListener,
) -> Result<TrainReport, String> {
    accept_and_serve_with(runtime, listener, None)
}

/// [`accept_and_serve`] with an optional live-metrics exporter
/// (`--metrics-bind`) attached to the poll loop before the session runs.
pub fn accept_and_serve_with<C: Compute>(
    runtime: &mut ServerRuntime<C>,
    listener: &std::net::TcpListener,
    exporter: Option<MetricsExporter>,
) -> Result<TrainReport, String> {
    accept_and_serve_opts(
        runtime,
        listener,
        exporter,
        crate::sched::event_loop::FleetOptions::default(),
    )
}

/// [`accept_and_serve_with`] plus the event-loop tunables (`--io-backend`,
/// `--write-stall-secs`). The options steer only how sockets are polled
/// and how long a jammed write may park — wire traffic is bit-identical
/// across backends, so they stay out of the config fingerprint.
pub fn accept_and_serve_opts<C: Compute>(
    runtime: &mut ServerRuntime<C>,
    listener: &std::net::TcpListener,
    exporter: Option<MetricsExporter>,
    opts: crate::sched::event_loop::FleetOptions,
) -> Result<TrainReport, String> {
    let shape = runtime.cfg.shape();
    let mut opts = opts;
    // elastic mode is a session property, not an event-loop tunable: the
    // runtime's config decides, whatever options the caller assembled
    opts.elastic = runtime.cfg.elastic;
    let (mut fleet, hellos) =
        crate::sched::event_loop::PollFleet::accept_with(listener, shape, opts)?;
    crate::log_info!("sched: io backend {}", fleet.backend_kind());
    if runtime.cfg.elastic {
        let l = listener
            .try_clone()
            .map_err(|e| format!("elastic: cloning the session listener: {e}"))?;
        fleet.arm_listener(l)?;
    }
    if let Some(ex) = exporter {
        fleet.attach_exporter(ex);
    }
    runtime.serve_fleet(&mut fleet, &hellos)
}

/// Build the engine-free server runtime for a mock session (the twin of
/// [`super::device::mock_worker`]).
pub fn mock_runtime(
    cfg: &ExperimentConfig,
    test: Arc<Dataset>,
) -> Result<ServerRuntime<MockCompute>, String> {
    mock_runtime_for_shard(cfg, 0, test)
}

/// [`mock_runtime`] for shard `shard_id` of a multi-server topology: the
/// runtime serves that shard's contiguous global-device-id slice (stream
/// codecs and network links stay globally seeded/sliced).
pub fn mock_runtime_for_shard(
    cfg: &ExperimentConfig,
    shard_id: usize,
    test: Arc<Dataset>,
) -> Result<ServerRuntime<MockCompute>, String> {
    let channels = compute::MOCK_CUT.0;
    let classes = test.classes;
    ServerRuntime::new(
        cfg.serve_config_for_shard(compute::MOCK_BATCH, shard_id)?,
        MockCompute::new(classes),
        compute::mock_server_init(),
        cfg.stream_set_for_shard(channels, shard_id)?,
        test,
        cfg.network_for_shard(shard_id),
    )
}

/// Run a complete mock session over in-process loopback transports:
/// N device workers + the server runtime on one thread. This is the
/// engine-free twin of `Trainer::run`, used by the transport tests and
/// `examples/distributed.rs` to check loopback/TCP byte parity.
pub fn run_mock_loopback(cfg: &ExperimentConfig) -> Result<TrainReport, String> {
    let n = cfg.devices;
    run_mock_loopback_delayed(cfg, &vec![0.0; n], 0).map(|(report, _)| report)
}

/// [`run_mock_loopback`] with the artificial-delay shim: every message
/// from device `d` arrives `delays[d]` virtual seconds late (±10% seeded
/// jitter), which makes arrival-order scheduling, straggler timeouts, and
/// quorum closes deterministically testable. Also returns the per-round
/// scheduling records.
pub fn run_mock_loopback_delayed(
    cfg: &ExperimentConfig,
    delays: &[f64],
    shim_seed: u64,
) -> Result<(TrainReport, Vec<SchedRecord>), String> {
    run_mock_loopback_shimmed(cfg, delays, shim_seed, std::time::Duration::ZERO)
}

/// [`run_mock_loopback_delayed`] with a modeled PJRT-boundary cost burned
/// by the server's [`MockCompute`] once per `server_step` *dispatch*.
/// `benches/batching.rs` uses it to measure what `--batch-window`
/// amortizes without needing an engine; zero cost is the plain mock.
pub fn run_mock_loopback_shimmed(
    cfg: &ExperimentConfig,
    delays: &[f64],
    shim_seed: u64,
    dispatch_cost: std::time::Duration,
) -> Result<(TrainReport, Vec<SchedRecord>), String> {
    cfg.validate()?;
    if cfg.shards > 1 {
        return Err(format!(
            "run_mock_loopback drives a single server; --shards {} needs \
             crate::shard::sim::run_sharded_mock",
            cfg.shards
        ));
    }
    if delays.len() != cfg.devices {
        return Err(format!(
            "{} delays for {} devices",
            delays.len(),
            cfg.devices
        ));
    }
    let (train, test) = Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
    let train = Arc::new(train);
    let mut runtime = mock_runtime(cfg, Arc::new(test))?;
    runtime.compute.set_dispatch_cost(dispatch_cost);
    let mut workers = Vec::with_capacity(cfg.devices);
    let mut dev_conns = Vec::with_capacity(cfg.devices);
    let mut srv_conns: Vec<Box<dyn Transport>> = Vec::with_capacity(cfg.devices);
    for d in 0..cfg.devices {
        let worker = super::device::mock_worker(cfg, train.clone(), d)?;
        let (mut dev_end, srv_end) = super::loopback::pair(&format!("mock{d}"));
        dev_end.send(&worker.hello())?;
        workers.push(worker);
        dev_conns.push(dev_end);
        srv_conns.push(Box::new(srv_end));
    }
    let (mut conns, hellos) = handshake(srv_conns, FleetShape::flat(cfg.devices))?;
    let report = {
        let mut fleet = PumpFleet::with_delays(
            &mut conns,
            |d| super::device::pump(&mut workers[d], &mut dev_conns[d]),
            delays.to_vec(),
            shim_seed,
        );
        runtime.serve_fleet(&mut fleet, &hellos)?
    };
    Ok((report, runtime.sched_records()))
}

/// [`run_mock_loopback`] with `--elastic` and a scripted churn plan:
/// `kills` are `(round, device)` hang-ups fired when the scheduler opens
/// that round, `rejoins` are `(round, device)` re-admissions — the same
/// in-process worker dials back in with a proto-v6 `Join`, is admitted at
/// the round boundary, and catches up from the server's last broadcast.
/// Deterministic end to end (zero-delay shim), so two identical runs
/// produce identical metrics and scheduling records.
pub fn run_mock_loopback_churn(
    cfg: &ExperimentConfig,
    kills: &[(u32, usize)],
    rejoins: &[(u32, usize)],
) -> Result<(TrainReport, Vec<SchedRecord>), String> {
    cfg.validate()?;
    if !cfg.elastic {
        return Err("run_mock_loopback_churn needs cfg.elastic".into());
    }
    if cfg.shards > 1 {
        return Err("run_mock_loopback_churn drives a single server".into());
    }
    for &(_, d) in kills.iter().chain(rejoins) {
        if d >= cfg.devices {
            return Err(format!("churn names device {d} of a {}-device fleet", cfg.devices));
        }
    }
    let (train, test) = Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
    let train = Arc::new(train);
    let mut runtime = mock_runtime(cfg, Arc::new(test))?;
    let mut workers = Vec::with_capacity(cfg.devices);
    let mut dev_conns = Vec::with_capacity(cfg.devices);
    let mut srv_conns: Vec<Box<dyn Transport>> = Vec::with_capacity(cfg.devices);
    for d in 0..cfg.devices {
        let worker = super::device::mock_worker(cfg, train.clone(), d)?;
        let (mut dev_end, srv_end) = super::loopback::pair(&format!("mock{d}"));
        dev_end.send(&worker.hello())?;
        workers.push(worker);
        dev_conns.push(dev_end);
        srv_conns.push(Box::new(srv_end));
    }
    let churn: Vec<ChurnEvent> = kills
        .iter()
        .map(|&(round, device)| ChurnEvent::Kill { round, device })
        .chain(rejoins.iter().map(|&(round, device)| ChurnEvent::Rejoin {
            round,
            device,
            join: workers[device].join(),
        }))
        .collect();
    let (mut conns, hellos) = handshake(srv_conns, FleetShape::flat(cfg.devices))?;
    let report = {
        let mut fleet = PumpFleet::new(&mut conns, |d| {
            super::device::pump(&mut workers[d], &mut dev_conns[d])
        })
        .with_churn(churn);
        runtime.serve_fleet(&mut fleet, &hellos)?
    };
    Ok((report, runtime.sched_records()))
}
