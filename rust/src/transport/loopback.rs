//! In-process loopback transport: a deterministic pair of byte queues.
//!
//! Messages are fully framed ([`proto::Message::encode_frame`]) and decoded
//! on receive, so a loopback session exercises the exact bytes a socket
//! would carry — the trainer's simulated runs and the TCP runtime differ
//! only in who pumps the queues.
//!
//! Loopback is single-threaded (`Rc`-shared queues). `recv` on an empty
//! queue is therefore an *error*, not a block: the driver must run the peer
//! (see [`crate::transport::device::pump`]) before receiving.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;

use super::proto::Message;
use super::{Transport, TransportError, WireStats};

type Queue = Rc<RefCell<VecDeque<Vec<u8>>>>;

/// One end of a loopback pair.
pub struct Loopback {
    inbox: Queue,
    outbox: Queue,
    stats: WireStats,
    name: String,
}

/// Create a connected pair: `(device_end, server_end)`.
pub fn pair(label: &str) -> (Loopback, Loopback) {
    let to_server: Queue = Rc::new(RefCell::new(VecDeque::new()));
    let to_device: Queue = Rc::new(RefCell::new(VecDeque::new()));
    let device_end = Loopback {
        inbox: to_device.clone(),
        outbox: to_server.clone(),
        stats: WireStats::default(),
        name: format!("{label}/device"),
    };
    let server_end = Loopback {
        inbox: to_server,
        outbox: to_device,
        stats: WireStats::default(),
        name: format!("{label}/server"),
    };
    (device_end, server_end)
}

impl Transport for Loopback {
    fn send(&mut self, msg: &Message) -> Result<(), TransportError> {
        let frame = msg.encode_frame();
        self.stats.frames_sent += 1;
        self.stats.bytes_sent += frame.len() as u64;
        self.outbox.borrow_mut().push_back(frame);
        Ok(())
    }

    fn recv(&mut self) -> Result<Message, TransportError> {
        match self.try_recv()? {
            Some(msg) => Ok(msg),
            None => Err(TransportError::Protocol(format!(
                "loopback '{}': recv on empty queue (single-threaded loopback \
                 cannot block; pump the peer first)",
                self.name
            ))),
        }
    }

    fn try_recv(&mut self) -> Result<Option<Message>, TransportError> {
        let frame = self.inbox.borrow_mut().pop_front();
        match frame {
            None => Ok(None),
            Some(frame) => {
                self.stats.frames_recv += 1;
                self.stats.bytes_recv += frame.len() as u64;
                Ok(Some(
                    Message::decode_frame(&frame).map_err(TransportError::Protocol)?,
                ))
            }
        }
    }

    fn stats(&self) -> WireStats {
        self.stats
    }

    fn peer(&self) -> String {
        self.name.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delivers_in_order_and_counts_bytes() {
        let (mut dev, mut srv) = pair("t");
        let a = Message::RoundOpen { round: 0, sync: false };
        let b = Message::Shutdown { reason: "x".into() };
        dev.send(&a).unwrap();
        dev.send(&b).unwrap();
        assert_eq!(srv.recv().unwrap(), a);
        assert_eq!(srv.recv().unwrap(), b);
        assert_eq!(dev.stats().frames_sent, 2);
        assert_eq!(srv.stats().frames_recv, 2);
        assert_eq!(dev.stats().bytes_sent, srv.stats().bytes_recv);
        assert!(dev.stats().bytes_sent > 0);
    }

    #[test]
    fn empty_recv_is_error_try_recv_is_none() {
        let (mut dev, mut srv) = pair("t");
        assert!(srv.try_recv().unwrap().is_none());
        assert!(srv.recv().is_err());
        dev.send(&Message::RoundOpen { round: 1, sync: true }).unwrap();
        assert!(srv.try_recv().unwrap().is_some());
        assert!(srv.try_recv().unwrap().is_none());
    }
}
