//! Device-side protocol logic: stages i (client forward + uplink
//! compression) and iv (downlink decompression + client backward) of the
//! round loop, expressed as a message-driven state machine.
//!
//! [`DeviceWorker::handle`] consumes one server message and returns the
//! replies to send; it is transport-agnostic, so the same worker runs
//! behind an in-process loopback (pumped by the trainer) or a TCP
//! connection in a separate `slacc device` process ([`run_blocking`]).
//!
//! ModelSync pushes ride the device's *sync codec stream*
//! ([`crate::transport::sync`], `--sync-codec`, identity by default), so
//! FedAvg traffic is byte-accounted and compressible like everything else
//! on the wire.

use std::sync::Arc;

use crate::codecs::stream::{
    record_decode, record_encode, DeviceStreams, SessionStreamCfg, StreamKind, StreamSpecs,
};
use crate::codecs::RoundCtx;
use crate::config::ExperimentConfig;
use crate::coordinator::device::DeviceState;
use crate::data::loader::BatchLoader;
use crate::data::{partition, Dataset};

use super::compute::{self, Compute, MockCompute};
use super::proto::Message;
use super::{sync, Transport, TransportError};

struct Pending {
    round: u32,
    x: Vec<f32>,
    x_dims: [usize; 4],
    sync: bool,
}

/// One edge device's half of an SL session.
pub struct DeviceWorker<C: Compute> {
    compute: C,
    data: Arc<Dataset>,
    state: DeviceState,
    devices: usize,
    rounds: usize,
    lr: f32,
    session_fp: u64,
    /// the negotiated per-stream spec table (declared in the Hello;
    /// replaced when a [`Message::SpecUpdate`] activates)
    specs: StreamSpecs,
    /// session stream-build parameters, retained so a SpecUpdate can
    /// rebuild [`DeviceStreams`] mid-session with the original seeds
    stream_cfg: SessionStreamCfg,
    /// acked SpecUpdates not yet activated, ordered by activation round.
    /// A queue (not an `Option`): the server may push update N+1 as soon
    /// as update N is fully acked, before a carried straggler has seen
    /// N's activation round.
    pending_specs: Vec<(u32, StreamSpecs)>,
    /// highest round the server has opened on this device — SpecUpdates
    /// must activate strictly after it
    latest_open: Option<u32>,
    /// membership epoch from the last [`Message::JoinAck`]; 0 for a fresh
    /// process (a first-time joiner or a rejoiner restarted from scratch,
    /// which the server accepts as "no epoch to claim")
    member_epoch: u32,
    /// reusable flatten/envelope scratch for the ModelSync pushes (one
    /// allocation per push — the frame-owned payload)
    sync_scratch: sync::SyncScratch,
    pending: Option<Pending>,
    done: bool,
}

impl<C: Compute> DeviceWorker<C> {
    pub fn new(
        state: DeviceState,
        compute: C,
        data: Arc<Dataset>,
        cfg: &ExperimentConfig,
        channels: usize,
    ) -> Result<DeviceWorker<C>, String> {
        let session_fp = super::session_fingerprint(cfg.fingerprint(), compute.kind());
        let specs = cfg.stream_specs()?;
        let stream_cfg = cfg.session_stream_cfg(channels);
        Ok(DeviceWorker {
            compute,
            data,
            state,
            devices: cfg.devices,
            rounds: cfg.rounds,
            lr: cfg.lr,
            session_fp,
            specs,
            stream_cfg,
            pending_specs: Vec::new(),
            latest_open: None,
            member_epoch: 0,
            sync_scratch: sync::SyncScratch::default(),
            pending: None,
            done: false,
        })
    }

    pub fn id(&self) -> usize {
        self.state.id
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    pub fn client_params(&self) -> &[crate::tensor::Tensor] {
        &self.state.client_params
    }

    /// The handshake frame this worker opens its connection with: device
    /// slot, fleet shape, and the full per-stream spec table + digest.
    pub fn hello(&self) -> Message {
        Message::Hello {
            device_id: self.state.id as u32,
            devices: self.devices as u32,
            shard_len: self.state.loader.shard_len() as u32,
            config_fp: self.session_fp,
            uplink: self.specs.uplink.as_str().to_string(),
            downlink: self.specs.downlink.as_str().to_string(),
            sync: self.specs.sync.as_str().to_string(),
            streams_fp: self.specs.fingerprint(),
        }
    }

    /// The mid-session admission frame: same shape and validation surface
    /// as [`DeviceWorker::hello`], plus the membership epoch this device
    /// last held (0 for a fresh process). Sent instead of Hello when the
    /// session is already running (`slacc device --rejoin`).
    pub fn join(&self) -> Message {
        Message::Join {
            device_id: self.state.id as u32,
            devices: self.devices as u32,
            shard_len: self.state.loader.shard_len() as u32,
            config_fp: self.session_fp,
            member_epoch: self.member_epoch,
            uplink: self.specs.uplink.as_str().to_string(),
            downlink: self.specs.downlink.as_str().to_string(),
            sync: self.specs.sync.as_str().to_string(),
            streams_fp: self.specs.fingerprint(),
        }
    }

    /// Consume one server message; return the replies to send, in order.
    pub fn handle(&mut self, msg: Message) -> Result<Vec<Message>, String> {
        let me = self.state.id;
        match msg {
            Message::HelloAck { device_id, rounds, .. } => {
                if device_id as usize != me {
                    return Err(format!(
                        "device {me}: HelloAck addressed to device {device_id}"
                    ));
                }
                if rounds as usize != self.rounds {
                    return Err(format!(
                        "device {me}: server runs {rounds} rounds, local config says {}",
                        self.rounds
                    ));
                }
                // trace joinability: this side's clock anchor for the Hello
                // exchange (the server stamps its own at HelloAck send), plus
                // the validated session fingerprint for the header row
                crate::obs::span::set_trace_session(self.session_fp);
                crate::obs::span::record_anchor(
                    me as u32,
                    crate::util::logging::elapsed_ns(),
                );
                Ok(Vec::new())
            }
            Message::JoinAck { device_id, round, member_epoch, rounds, .. } => {
                if device_id as usize != me {
                    return Err(format!(
                        "device {me}: JoinAck addressed to device {device_id}"
                    ));
                }
                if rounds as usize != self.rounds {
                    return Err(format!(
                        "device {me}: server runs {rounds} rounds, local config says {}",
                        self.rounds
                    ));
                }
                self.member_epoch = member_epoch;
                crate::obs::span::set_trace_session(self.session_fp);
                crate::obs::span::record_anchor(
                    me as u32,
                    crate::util::logging::elapsed_ns(),
                );
                crate::log_info!(
                    "device {me}: admitted mid-session at round {round} \
                     (member epoch {member_epoch})"
                );
                Ok(Vec::new())
            }
            Message::Catchup { round, device_id, spec_epoch, payload } => {
                if device_id as usize != me {
                    return Err(format!(
                        "device {me}: Catchup addressed to device {device_id}"
                    ));
                }
                // elastic sessions run with adaptive retuning off, so the
                // only stream table a rejoiner can decode against is the
                // session-initial one (epoch 0)
                if spec_epoch != 0 {
                    return Err(format!(
                        "device {me}: Catchup at spec epoch {spec_epoch}; rejoin \
                         under adaptive retuning is not supported"
                    ));
                }
                // empty pack = "no broadcast has happened yet; keep the
                // local deterministic init"
                if payload.is_empty() {
                    crate::log_debug!(
                        "device {me}: catchup at round {round}: no broadcast yet, \
                         keeping local init"
                    );
                    return Ok(Vec::new());
                }
                let tensors =
                    sync::unpack_params(&payload, self.state.streams.sync_down.as_mut())
                        .map_err(|e| format!("device {me}: sync stream (catchup): {e}"))?;
                if tensors.len() != self.state.client_params.len() {
                    return Err(format!(
                        "device {me}: Catchup has {} tensors, model has {}",
                        tensors.len(),
                        self.state.client_params.len()
                    ));
                }
                for (t, p) in tensors.iter().zip(self.state.client_params.iter()) {
                    if t.dims() != p.dims() {
                        return Err(format!(
                            "device {me}: Catchup tensor shape {:?} != model {:?}",
                            t.dims(),
                            p.dims()
                        ));
                    }
                }
                self.state.client_params = tensors;
                crate::log_info!("device {me}: model caught up to round {round}");
                Ok(Vec::new())
            }
            Message::RoundOpen { round, sync } => {
                if self.pending.is_some() {
                    return Err(format!("device {me}: RoundOpen {round} while a round is open"));
                }
                self.latest_open = Some(round);
                self.apply_due_spec_updates(round)?;
                // stage i: client forward on the next local batch
                let idx = self.state.loader.next_batch();
                let (x, y) = self.data.batch(&idx);
                let x_dims = [
                    idx.len(),
                    self.data.channels,
                    self.data.height,
                    self.data.width,
                ];
                let acts = {
                    let _sp = crate::span!("client_fwd", round = round, gid = me);
                    self.compute
                        .client_fwd(&self.state.client_params, &x, &x_dims)?
                };
                // stage ii (device half): ACII entropy + uplink compression
                // (the frame owns its payload: single-allocation compress,
                // with the reusable-buffer encode as the primitive)
                let h_inst = self.compute.entropy(&acts)?;
                let acts_cm = acts.to_channel_major();
                let t0 = std::time::Instant::now();
                let payload = {
                    let _sp = crate::span!(
                        "uplink_encode",
                        round = round,
                        gid = me,
                        kind = StreamKind::Uplink
                    );
                    self.state.streams.up.compress(
                        &acts_cm,
                        RoundCtx {
                            entropy: Some(&h_inst),
                            kind: Some(StreamKind::Uplink),
                        },
                    )
                };
                record_encode(StreamKind::Uplink, t0, payload.len());
                self.pending = Some(Pending { round, x, x_dims, sync });
                Ok(vec![Message::Activations {
                    round,
                    device_id: me as u32,
                    labels: y,
                    payload,
                }])
            }
            Message::Gradients { round, device_id, payload, .. } => {
                let pending = self
                    .pending
                    .take()
                    .ok_or_else(|| format!("device {me}: Gradients without an open round"))?;
                if round != pending.round || device_id as usize != me {
                    return Err(format!(
                        "device {me}: Gradients for round {round}/device {device_id}, \
                         expected round {}",
                        pending.round
                    ));
                }
                // stage iv: downlink decode + client backward
                let t0 = std::time::Instant::now();
                let g_hat = {
                    let _sp = crate::span!(
                        "downlink_decode",
                        round = round,
                        gid = me,
                        kind = StreamKind::Downlink
                    );
                    self.state
                        .streams
                        .down
                        .decode(&payload)
                        .map_err(|e| format!("device {me}: downlink stream: {e}"))?
                };
                record_decode(StreamKind::Downlink, t0, payload.len());
                let new_params = {
                    let _sp = crate::span!("client_bwd", round = round, gid = me);
                    self.compute.client_bwd(
                        &self.state.client_params,
                        &pending.x,
                        &pending.x_dims,
                        &g_hat,
                        self.lr,
                    )?
                };
                self.state.client_params = new_params;
                if pending.sync {
                    let payload = sync::pack_params_with(
                        &self.state.client_params,
                        self.state.streams.sync_up.as_mut(),
                        &mut self.sync_scratch,
                    );
                    Ok(vec![Message::ModelSync {
                        round,
                        device_id: me as u32,
                        payload,
                    }])
                } else {
                    Ok(Vec::new())
                }
            }
            Message::ModelSync { payload, device_id, .. } => {
                if device_id as usize != me {
                    return Err(format!(
                        "device {me}: ModelSync addressed to device {device_id}"
                    ));
                }
                // empty pack = "keep your local params" (non-agg round)
                if !payload.is_empty() {
                    let tensors =
                        sync::unpack_params(&payload, self.state.streams.sync_down.as_mut())
                            .map_err(|e| format!("device {me}: sync stream (broadcast): {e}"))?;
                    if tensors.is_empty() {
                        return Ok(Vec::new());
                    }
                    if tensors.len() != self.state.client_params.len() {
                        return Err(format!(
                            "device {me}: ModelSync has {} tensors, model has {}",
                            tensors.len(),
                            self.state.client_params.len()
                        ));
                    }
                    for (t, p) in tensors.iter().zip(self.state.client_params.iter()) {
                        if t.dims() != p.dims() {
                            return Err(format!(
                                "device {me}: ModelSync tensor shape {:?} != model {:?}",
                                t.dims(),
                                p.dims()
                            ));
                        }
                    }
                    self.state.client_params = tensors;
                }
                Ok(Vec::new())
            }
            Message::SpecUpdate { activate_round, uplink, downlink, sync, streams_fp } => {
                let next = StreamSpecs::parse(&uplink, &downlink, &sync)
                    .map_err(|e| format!("device {me}: SpecUpdate: {e}"))?;
                if next.fingerprint() != streams_fp {
                    return Err(format!(
                        "device {me}: SpecUpdate digest {streams_fp:#018x} does not match \
                         its spec strings ({})",
                        next.table()
                    ));
                }
                if next.sync.as_str() != self.specs.sync.as_str() {
                    return Err(format!(
                        "device {me}: SpecUpdate changes the sync stream ({} -> {}); \
                         sync codecs are session-long",
                        self.specs.sync.as_str(),
                        next.sync.as_str()
                    ));
                }
                if let Some(open) = self.latest_open {
                    if activate_round <= open {
                        return Err(format!(
                            "device {me}: SpecUpdate activates at round {activate_round}, \
                             but round {open} is already open"
                        ));
                    }
                }
                if let Some(&(last, _)) = self.pending_specs.last() {
                    if activate_round <= last {
                        return Err(format!(
                            "device {me}: SpecUpdate activates at round {activate_round}, \
                             not after the queued update at round {last}"
                        ));
                    }
                }
                crate::log_info!(
                    "device {me}: spec update queued for round {activate_round}: {}",
                    next.table()
                );
                self.pending_specs.push((activate_round, next));
                Ok(vec![Message::SpecUpdateAck { activate_round, streams_fp }])
            }
            Message::Shutdown { reason } => {
                crate::log_debug!("device {me}: shutdown ({reason})");
                self.done = true;
                Ok(Vec::new())
            }
            other => Err(format!(
                "device {me}: unexpected {} from server",
                other.type_name()
            )),
        }
    }

    /// Activate every queued spec update due by `round`. Only the last
    /// applicable table is built (intermediate epochs were never used on
    /// the wire for this device — the server skips them identically).
    /// Data codecs are rebuilt from the session seeds; the sync pair is
    /// carried over, since sync codecs are stateful and session-long.
    fn apply_due_spec_updates(&mut self, round: u32) -> Result<(), String> {
        let due = self.pending_specs.iter().take_while(|(at, _)| *at <= round).count();
        if due == 0 {
            return Ok(());
        }
        let (_, specs) = self.pending_specs.drain(..due).last().unwrap();
        let me = self.state.id;
        let mut fresh = DeviceStreams::build(&specs, &self.stream_cfg, me)
            .map_err(|e| format!("device {me}: spec update activation: {e}"))?;
        std::mem::swap(&mut fresh.sync_up, &mut self.state.streams.sync_up);
        std::mem::swap(&mut fresh.sync_down, &mut self.state.streams.sync_down);
        self.state.streams = fresh;
        crate::log_info!("device {me}: spec update active from round {round}: {}", specs.table());
        self.specs = specs;
        Ok(())
    }
}

/// Drain every queued message on `conn` through the worker (non-blocking).
/// This is how the single-threaded loopback trainer gives a device its
/// turn; TCP sessions use [`run_blocking`] instead. Typed like the rest
/// of the transport layer: a worker that rejects a message is a protocol
/// violation, transport failures keep their own variants.
pub fn pump<C: Compute>(
    worker: &mut DeviceWorker<C>,
    conn: &mut dyn Transport,
) -> Result<(), TransportError> {
    while let Some(msg) = conn.try_recv()? {
        for reply in worker.handle(msg).map_err(TransportError::Protocol)? {
            conn.send(&reply)?;
        }
    }
    Ok(())
}

/// Run a device's full session over a blocking transport: send Hello, then
/// serve messages until Shutdown.
pub fn run_blocking<C: Compute>(
    worker: &mut DeviceWorker<C>,
    conn: &mut dyn Transport,
) -> Result<(), String> {
    let opening = worker.hello();
    run_opening(worker, conn, opening)
}

/// Join (or re-join) a session that is already running: send
/// [`DeviceWorker::join`] instead of Hello, then serve messages until
/// Shutdown. The server parks the connection until the next round
/// boundary, replies JoinAck + Catchup, and folds the device into the
/// round loop.
pub fn run_blocking_rejoin<C: Compute>(
    worker: &mut DeviceWorker<C>,
    conn: &mut dyn Transport,
) -> Result<(), String> {
    let opening = worker.join();
    run_opening(worker, conn, opening)
}

fn run_opening<C: Compute>(
    worker: &mut DeviceWorker<C>,
    conn: &mut dyn Transport,
    opening: Message,
) -> Result<(), String> {
    conn.send(&opening)?;
    while !worker.is_done() {
        let msg = conn.recv()?;
        for reply in worker.handle(msg)? {
            conn.send(&reply)?;
        }
    }
    Ok(())
}

/// Build the engine-free worker for device `id` of a mock session. The
/// shard split, loader seeding, and codec streams match the real path
/// exactly, so wire bytes are comparable across transports.
pub fn mock_worker(
    cfg: &ExperimentConfig,
    train: Arc<Dataset>,
    id: usize,
) -> Result<DeviceWorker<MockCompute>, String> {
    if id >= cfg.devices {
        return Err(format!("device id {id} out of range (devices={})", cfg.devices));
    }
    let channels = compute::MOCK_CUT.0;
    let shards = partition::partition(&train, cfg.devices, cfg.partition, cfg.seed);
    let loader = BatchLoader::new(
        shards.device(id),
        compute::MOCK_BATCH,
        cfg.seed ^ ((id as u64) << 8),
    );
    let state = DeviceState::new(
        id,
        compute::mock_client_init(),
        loader,
        cfg.device_streams(channels, id)?,
    );
    let classes = train.classes;
    DeviceWorker::new(state, MockCompute::new(classes), train, cfg, channels)
}
