//! The model-execution boundary of the round loop.
//!
//! [`DeviceWorker`](super::device::DeviceWorker) and
//! [`ServerRuntime`](super::server::ServerRuntime) never call PJRT
//! directly; they go through [`Compute`], with two implementations:
//!
//! * [`EngineCompute`] — the real path: the AOT artifacts through
//!   [`crate::runtime::Engine`]. `Rc<RefCell<_>>` lets the in-process
//!   trainer share one compiled engine between the server runtime and all
//!   device workers (PJRT objects never cross threads).
//! * [`MockCompute`] — a deterministic, engine-free stand-in used by the
//!   transport tests, the `--mock` CLI flag, and `examples/distributed.rs`
//!   when artifacts are absent. It produces shaped, channel-varying
//!   activations so the real codecs and the wire protocol are exercised
//!   end-to-end; only the model math is fake.

use std::cell::RefCell;
use std::rc::Rc;
use std::time::{Duration, Instant};

use crate::runtime::{Arg, Engine};
use crate::tensor::Tensor;

/// Result of one server training step (stage iii).
pub struct StepOut {
    pub loss: f64,
    pub g_acts: Tensor,
    pub new_params: Vec<Tensor>,
}

/// Model execution for the four round-loop stages plus evaluation.
pub trait Compute {
    /// Short tag naming the execution backend ("engine" / "mock"); folded
    /// into the session fingerprint so an engine server rejects mock
    /// devices and vice versa.
    fn kind(&self) -> &'static str;

    /// Stage i: client sub-model forward → cut-layer activations.
    fn client_fwd(
        &mut self,
        params: &[Tensor],
        x: &[f32],
        x_dims: &[usize],
    ) -> Result<Tensor, String>;

    /// Stage iv: client backward + SGD → new client params.
    fn client_bwd(
        &mut self,
        params: &[Tensor],
        x: &[f32],
        x_dims: &[usize],
        g: &Tensor,
        lr: f32,
    ) -> Result<Vec<Tensor>, String>;

    /// Stage iii: server forward+backward+SGD on (decompressed) smashed data.
    fn server_step(
        &mut self,
        params: &[Tensor],
        acts: &Tensor,
        y: &[i32],
        lr: f32,
    ) -> Result<StepOut, String>;

    /// Stage iii for a *batch* of devices' smashed data in one dispatch.
    ///
    /// Semantics are the sequential chain: item `i` steps from item
    /// `i-1`'s updated parameters, exactly as `acts.len()` back-to-back
    /// [`Compute::server_step`] calls would. The default implementation is
    /// literally that chain; backends override it to amortize their
    /// per-dispatch overhead (the whole point of `--batch-window`).
    ///
    /// A backend that performs one *fused* parameter update for the batch
    /// (the stacked engine path) may leave `new_params` empty on all but
    /// the final [`StepOut`]; callers must apply the **last non-empty**
    /// `new_params`. [`MockCompute`] always fills the full chain, which is
    /// what the batched-vs-sequential equivalence tests pin down.
    fn server_step_batch(
        &mut self,
        params: &[Tensor],
        acts: &[&Tensor],
        ys: &[&[i32]],
        lr: f32,
    ) -> Result<Vec<StepOut>, String> {
        sequential_step_chain(self, params, acts, ys, lr)
    }

    /// Per-channel ACII entropy of a smashed-data tensor.
    fn entropy(&mut self, t: &Tensor) -> Result<Vec<f32>, String>;

    /// Full-model logits for test evaluation.
    fn eval_logits(
        &mut self,
        client: &[Tensor],
        server: &[Tensor],
        x: &[f32],
        x_dims: &[usize],
    ) -> Result<Tensor, String>;

    /// Full-model logits for a run of equally-shaped eval batches, one
    /// `[B, classes]` tensor per input batch.
    ///
    /// Evaluation is a pure row-wise forward pass, so stacking batches
    /// cannot change any example's logits — unlike `server_step_batch`
    /// there is no parameter chain to preserve. The default is the
    /// historical per-batch walk (one [`Compute::eval_logits`] dispatch
    /// each); backends override it to cross the compute boundary once for
    /// the whole test set when a stacked artifact exists, falling back to
    /// the exact walk otherwise.
    fn eval_logits_batch(
        &mut self,
        client: &[Tensor],
        server: &[Tensor],
        xs: &[&[f32]],
        x_dims: &[usize],
    ) -> Result<Vec<Tensor>, String> {
        eval_walk(self, client, server, xs, x_dims)
    }
}

/// The reference eval semantics: one [`Compute::eval_logits`] call per
/// batch. The trait default and the engine fallback both route through
/// this single walk, so "stacked == walked" parity has one definition.
pub fn eval_walk<C: Compute + ?Sized>(
    compute: &mut C,
    client: &[Tensor],
    server: &[Tensor],
    xs: &[&[f32]],
    x_dims: &[usize],
) -> Result<Vec<Tensor>, String> {
    xs.iter()
        .map(|x| compute.eval_logits(client, server, x, x_dims))
        .collect()
}

/// The real PJRT-backed compute path.
pub struct EngineCompute {
    engine: Rc<RefCell<Engine>>,
    entropy_via_kernel: bool,
}

impl EngineCompute {
    pub fn new(engine: Rc<RefCell<Engine>>, entropy_via_kernel: bool) -> EngineCompute {
        EngineCompute { engine, entropy_via_kernel }
    }

    pub fn engine(&self) -> Rc<RefCell<Engine>> {
        self.engine.clone()
    }
}

fn param_args(params: &[Tensor]) -> Vec<Arg<'_>> {
    params.iter().map(|t| Arg::F32(t.data(), t.dims())).collect()
}

/// The one definition of the chain itself, parameterized over how a
/// single step runs: item `i` borrows item `i-1`'s `new_params` straight
/// out of the output list (no cloning — the old per-device path never
/// copied the server model, and neither does this).
fn chain_steps<F>(
    params: &[Tensor],
    acts: &[&Tensor],
    ys: &[&[i32]],
    lr: f32,
    mut step: F,
) -> Result<Vec<StepOut>, String>
where
    F: FnMut(&[Tensor], &Tensor, &[i32], f32) -> Result<StepOut, String>,
{
    if acts.len() != ys.len() {
        return Err(format!(
            "server_step_batch: {} activation tensors for {} label sets",
            acts.len(),
            ys.len()
        ));
    }
    let mut out: Vec<StepOut> = Vec::with_capacity(acts.len());
    for (&a, &y) in acts.iter().zip(ys) {
        let p = out.last().map(|o| o.new_params.as_slice()).unwrap_or(params);
        let s = step(p, a, y, lr)?;
        out.push(s);
    }
    Ok(out)
}

/// The reference batched semantics: `acts.len()` back-to-back
/// [`Compute::server_step`] calls, item `i` starting from item `i-1`'s
/// updated parameters. The trait default, the engine fallbacks, and (via
/// [`chain_steps`]) the mock's amortized path all route through this one
/// chain, so "batched == sequential" is true by construction wherever it
/// is used.
pub fn sequential_step_chain<C: Compute + ?Sized>(
    compute: &mut C,
    params: &[Tensor],
    acts: &[&Tensor],
    ys: &[&[i32]],
    lr: f32,
) -> Result<Vec<StepOut>, String> {
    chain_steps(params, acts, ys, lr, |p, a, y, l| compute.server_step(p, a, y, l))
}

/// Name of the AOT artifact that can serve a stacked `[B_total, C, H, W]`
/// input in one dispatch, if the manifest compiled one for exactly that
/// geometry. Artifacts are shape-specialized, so this is a strict dims
/// check against the stacked input slot (position `input_slot`), probing
/// the `names` candidates in order — a dedicated wide artifact first, the
/// plain one second (it matches when the stacked batch happens to equal
/// its compiled batch, i.e. a batch of one). Shared by the
/// `server_step_batch` training path and the `eval_logits_batch` eval
/// path.
fn stacked_artifact(
    engine: &Engine,
    names: &[&'static str],
    input_slot: usize,
    dims: &[usize],
) -> Option<&'static str> {
    for &name in names {
        if let Ok(spec) = engine.manifest().artifact(name) {
            if spec.inputs.get(input_slot).is_some_and(|io| io.dims == dims) {
                return Some(name);
            }
        }
    }
    None
}

impl Compute for EngineCompute {
    fn kind(&self) -> &'static str {
        "engine"
    }

    fn client_fwd(
        &mut self,
        params: &[Tensor],
        x: &[f32],
        x_dims: &[usize],
    ) -> Result<Tensor, String> {
        let mut args = param_args(params);
        args.push(Arg::F32(x, x_dims));
        let out = self.engine.borrow_mut().execute("client_fwd", &args)?;
        out.into_iter().next().ok_or_else(|| "client_fwd returned no output".into())
    }

    fn client_bwd(
        &mut self,
        params: &[Tensor],
        x: &[f32],
        x_dims: &[usize],
        g: &Tensor,
        lr: f32,
    ) -> Result<Vec<Tensor>, String> {
        let mut args = param_args(params);
        args.push(Arg::F32(x, x_dims));
        args.push(Arg::F32(g.data(), g.dims()));
        args.push(Arg::ScalarF32(lr));
        self.engine.borrow_mut().execute("client_bwd", &args)
    }

    fn server_step(
        &mut self,
        params: &[Tensor],
        acts: &Tensor,
        y: &[i32],
        lr: f32,
    ) -> Result<StepOut, String> {
        let y_dims = [y.len()];
        let mut args = param_args(params);
        args.push(Arg::F32(acts.data(), acts.dims()));
        args.push(Arg::I32(y, &y_dims));
        args.push(Arg::ScalarF32(lr));
        let mut out = self.engine.borrow_mut().execute("server_step", &args)?;
        if out.len() < 2 {
            return Err(format!("server_step returned {} outputs, need >= 2", out.len()));
        }
        let new_params = out.split_off(2);
        let g_acts = out.pop().unwrap();
        let loss = out.pop().unwrap().data()[0] as f64;
        Ok(StepOut { loss, g_acts, new_params })
    }

    /// Real stacked-tensor execution: when the manifest carries an
    /// artifact compiled for the concatenated `[B_total, C, H, W]` batch,
    /// the whole group crosses the PJRT boundary in ONE dispatch (one
    /// fused forward/backward/update; `new_params` lands on the final
    /// [`StepOut`] only). Artifacts are shape-specialized, so any batch
    /// the compiled geometry cannot serve falls back to the exact
    /// sequential chain — correctness never depends on which path ran.
    fn server_step_batch(
        &mut self,
        params: &[Tensor],
        acts: &[&Tensor],
        ys: &[&[i32]],
        lr: f32,
    ) -> Result<Vec<StepOut>, String> {
        if acts.len() != ys.len() {
            return Err(format!(
                "server_step_batch: {} activation tensors for {} label sets",
                acts.len(),
                ys.len()
            ));
        }
        if acts.len() <= 1 {
            return sequential_step_chain(self, params, acts, ys, lr);
        }
        let d0 = acts[0].dims().to_vec();
        let same_shape = d0.len() == 4
            && acts
                .iter()
                .all(|a| a.dims().len() == 4 && a.dims()[1..] == d0[1..]);
        if !same_shape {
            return sequential_step_chain(self, params, acts, ys, lr);
        }
        let b_total: usize = acts.iter().map(|a| a.dims()[0]).sum();
        let stacked_dims = vec![b_total, d0[1], d0[2], d0[3]];
        let artifact = {
            let eng = self.engine.borrow();
            stacked_artifact(
                &eng,
                &["server_step_batch", "server_step"],
                params.len(),
                &stacked_dims,
            )
        };
        let Some(name) = artifact else {
            return sequential_step_chain(self, params, acts, ys, lr);
        };

        let mut flat: Vec<f32> = Vec::with_capacity(b_total * d0[1] * d0[2] * d0[3]);
        for a in acts {
            flat.extend_from_slice(a.data());
        }
        let mut labels: Vec<i32> =
            Vec::with_capacity(ys.iter().map(|y| y.len()).sum());
        for y in ys {
            labels.extend_from_slice(y);
        }
        let y_dims = [labels.len()];
        let mut args = param_args(params);
        args.push(Arg::F32(&flat, &stacked_dims));
        args.push(Arg::I32(&labels, &y_dims));
        args.push(Arg::ScalarF32(lr));
        let mut out = self.engine.borrow_mut().execute(name, &args)?;
        if out.len() < 2 {
            return Err(format!("{name} returned {} outputs, need >= 2", out.len()));
        }
        let mut new_params = out.split_off(2);
        let g_stacked = out.pop().unwrap();
        let loss = out.pop().unwrap().data()[0] as f64;
        if g_stacked.len() != flat.len() {
            return Err(format!(
                "{name}: stacked gradient has {} elements, batch sent {}",
                g_stacked.len(),
                flat.len()
            ));
        }
        let g = g_stacked.data();
        let mut outs = Vec::with_capacity(acts.len());
        let mut off = 0usize;
        for (i, a) in acts.iter().enumerate() {
            let n = a.len();
            let g_acts = Tensor::new(a.dims().to_vec(), g[off..off + n].to_vec());
            off += n;
            let np = if i + 1 == acts.len() {
                std::mem::take(&mut new_params)
            } else {
                Vec::new()
            };
            outs.push(StepOut { loss, g_acts, new_params: np });
        }
        Ok(outs)
    }

    fn entropy(&mut self, t: &Tensor) -> Result<Vec<f32>, String> {
        if self.entropy_via_kernel {
            let out = self
                .engine
                .borrow_mut()
                .execute("entropy", &[Arg::F32(t.data(), t.dims())])?;
            Ok(out
                .into_iter()
                .next()
                .ok_or("entropy kernel returned no output")?
                .into_data())
        } else {
            Ok(crate::entropy::shannon::entropies(&t.to_channel_major()))
        }
    }

    fn eval_logits(
        &mut self,
        client: &[Tensor],
        server: &[Tensor],
        x: &[f32],
        x_dims: &[usize],
    ) -> Result<Tensor, String> {
        let mut args = param_args(client);
        args.extend(param_args(server));
        args.push(Arg::F32(x, x_dims));
        let out = self.engine.borrow_mut().execute("eval_logits", &args)?;
        out.into_iter().next().ok_or_else(|| "eval_logits returned no output".into())
    }

    /// Stacked eval: when the manifest carries an artifact compiled for
    /// the concatenated `[k*B, C, H, W]` geometry, the whole test-set
    /// walk crosses the PJRT boundary in ONE dispatch and the stacked
    /// logits are split back per batch. Eval is row-wise, so the split
    /// rows are the per-batch logits exactly; any geometry the manifest
    /// cannot serve falls back to the per-batch walk.
    fn eval_logits_batch(
        &mut self,
        client: &[Tensor],
        server: &[Tensor],
        xs: &[&[f32]],
        x_dims: &[usize],
    ) -> Result<Vec<Tensor>, String> {
        if xs.len() <= 1 || x_dims.len() != 4 {
            return eval_walk(self, client, server, xs, x_dims);
        }
        let b = x_dims[0];
        let per = b * x_dims[1] * x_dims[2] * x_dims[3];
        if xs.iter().any(|x| x.len() != per) {
            return Err(format!(
                "eval_logits_batch: a batch has the wrong element count for \
                 dims {x_dims:?}"
            ));
        }
        let stacked_dims = vec![xs.len() * b, x_dims[1], x_dims[2], x_dims[3]];
        let artifact = {
            let eng = self.engine.borrow();
            stacked_artifact(
                &eng,
                &["eval_logits_batch", "eval_logits"],
                client.len() + server.len(),
                &stacked_dims,
            )
        };
        let Some(name) = artifact else {
            return eval_walk(self, client, server, xs, x_dims);
        };
        let mut flat: Vec<f32> = Vec::with_capacity(xs.len() * per);
        for x in xs {
            flat.extend_from_slice(x);
        }
        let mut args = param_args(client);
        args.extend(param_args(server));
        args.push(Arg::F32(&flat, &stacked_dims));
        let out = self.engine.borrow_mut().execute(name, &args)?;
        let logits = out
            .into_iter()
            .next()
            .ok_or_else(|| format!("{name} returned no output"))?;
        let dims = logits.dims();
        if dims.len() != 2 || dims[0] != xs.len() * b {
            return Err(format!(
                "{name}: stacked logits have dims {dims:?}, expected \
                 [{}, classes]",
                xs.len() * b
            ));
        }
        let classes = dims[1];
        let data = logits.data();
        Ok((0..xs.len())
            .map(|i| {
                Tensor::new(
                    vec![b, classes],
                    data[i * b * classes..(i + 1) * b * classes].to_vec(),
                )
            })
            .collect())
    }
}

/// Cut-layer shape (C, H, W) the mock model emits.
pub const MOCK_CUT: (usize, usize, usize) = (8, 4, 4);
/// Batch size mock sessions run with.
pub const MOCK_BATCH: usize = 8;

/// Initial "client sub-model" for mock sessions: one scalar-ish parameter.
pub fn mock_client_init() -> Vec<Tensor> {
    vec![Tensor::new(vec![2], vec![1.0, 0.5])]
}

/// Initial "server sub-model" for mock sessions.
pub fn mock_server_init() -> Vec<Tensor> {
    vec![Tensor::new(vec![2], vec![0.25, -0.25])]
}

/// Deterministic engine-free compute (see module docs). All math is simple
/// elementwise arithmetic, so two processes with the same inputs produce
/// bit-identical activations, gradients, and therefore wire bytes.
pub struct MockCompute {
    classes: usize,
    /// modeled cost of one PJRT-boundary crossing, burned once per
    /// `server_step` *dispatch* (so a batched dispatch pays it once).
    /// Zero by default — tests and parity checks are unaffected;
    /// `benches/batching.rs` sets it to a PJRT-representative latency to
    /// measure what `--batch-window` amortizes.
    dispatch_cost: Duration,
}

impl MockCompute {
    pub fn new(classes: usize) -> MockCompute {
        assert!(classes >= 1);
        MockCompute { classes, dispatch_cost: Duration::ZERO }
    }

    /// Set the modeled per-dispatch boundary cost (see the field docs).
    pub fn set_dispatch_cost(&mut self, cost: Duration) {
        self.dispatch_cost = cost;
    }

    /// Busy-wait for the modeled dispatch latency (spin, not sleep: the
    /// interesting costs are in the tens-to-hundreds of microseconds,
    /// well under scheduler sleep granularity).
    fn burn_dispatch(&self) {
        if self.dispatch_cost.is_zero() {
            return;
        }
        let t0 = Instant::now();
        while t0.elapsed() < self.dispatch_cost {
            std::hint::spin_loop();
        }
    }

    /// One server step's math, shared verbatim by the single and batched
    /// entry points so `server_step_batch` is bit-for-bit the sequential
    /// chain.
    fn step_once(
        &self,
        params: &[Tensor],
        acts: &Tensor,
        y: &[i32],
        lr: f32,
    ) -> Result<StepOut, String> {
        if y.is_empty() {
            return Err("mock server_step: empty labels".into());
        }
        let m2 = acts.data().iter().map(|&v| (v * v) as f64).sum::<f64>()
            / acts.len().max(1) as f64;
        let loss = m2 + 0.01 * params.first().map(|t| t.data()[0].abs() as f64).unwrap_or(0.0);
        let g_data: Vec<f32> = acts.data().iter().map(|&v| 0.3 * v - 0.01).collect();
        let g_acts = Tensor::new(acts.dims().to_vec(), g_data);
        let step = lr * loss as f32;
        let new_params = params
            .iter()
            .map(|t| {
                let data = t.data().iter().map(|&v| v - step * 0.1).collect();
                Tensor::new(t.dims().to_vec(), data)
            })
            .collect();
        Ok(StepOut { loss, g_acts, new_params })
    }
}

fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f32>() / xs.len() as f32
    }
}

impl Compute for MockCompute {
    fn kind(&self) -> &'static str {
        "mock"
    }

    fn client_fwd(
        &mut self,
        params: &[Tensor],
        x: &[f32],
        x_dims: &[usize],
    ) -> Result<Tensor, String> {
        if x_dims.len() != 4 {
            return Err(format!("mock client_fwd wants NCHW input, got {x_dims:?}"));
        }
        let (b, ic, ih, iw) = (x_dims[0], x_dims[1], x_dims[2], x_dims[3]);
        let p = params.first().map(|t| t.data()[0]).unwrap_or(1.0);
        let (c, h, w) = MOCK_CUT;
        let mut data = Vec::with_capacity(b * c * h * w);
        for bi in 0..b {
            for ci in 0..c {
                for hi in 0..h {
                    for wi in 0..w {
                        let src =
                            ((bi * ic + ci % ic) * ih + hi % ih) * iw + wi % iw;
                        let gain = 1.0 + 0.11 * ci as f32;
                        data.push((p * x[src] * gain).max(0.0));
                    }
                }
            }
        }
        Ok(Tensor::new(vec![b, c, h, w], data))
    }

    fn client_bwd(
        &mut self,
        params: &[Tensor],
        _x: &[f32],
        _x_dims: &[usize],
        g: &Tensor,
        lr: f32,
    ) -> Result<Vec<Tensor>, String> {
        let step = lr * mean(g.data());
        Ok(params
            .iter()
            .map(|t| {
                let data = t.data().iter().map(|&v| v - step).collect();
                Tensor::new(t.dims().to_vec(), data)
            })
            .collect())
    }

    fn server_step(
        &mut self,
        params: &[Tensor],
        acts: &Tensor,
        y: &[i32],
        lr: f32,
    ) -> Result<StepOut, String> {
        self.burn_dispatch();
        self.step_once(params, acts, y, lr)
    }

    /// Exact per-item semantics (the shared [`chain_steps`] chain over the
    /// same `step_once` the single path uses) with the modeled
    /// PJRT-boundary cost paid ONCE for the whole batch — what a real
    /// stacked dispatch amortizes, measurable without an engine.
    fn server_step_batch(
        &mut self,
        params: &[Tensor],
        acts: &[&Tensor],
        ys: &[&[i32]],
        lr: f32,
    ) -> Result<Vec<StepOut>, String> {
        self.burn_dispatch();
        chain_steps(params, acts, ys, lr, |p, a, y, l| self.step_once(p, a, y, l))
    }

    fn entropy(&mut self, t: &Tensor) -> Result<Vec<f32>, String> {
        Ok(crate::entropy::shannon::entropies(&t.to_channel_major()))
    }

    fn eval_logits(
        &mut self,
        client: &[Tensor],
        _server: &[Tensor],
        x: &[f32],
        x_dims: &[usize],
    ) -> Result<Tensor, String> {
        let b = *x_dims.first().unwrap_or(&1);
        let p = client.first().map(|t| t.data()[0]).unwrap_or(1.0);
        let per = x.len() / b.max(1);
        let mut data = Vec::with_capacity(b * self.classes);
        for bi in 0..b {
            let xm = mean(&x[bi * per..(bi + 1) * per]);
            for k in 0..self.classes {
                data.push(p * xm + 0.1 * k as f32);
            }
        }
        Ok(Tensor::new(vec![b, self.classes], data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_is_deterministic_and_shaped() {
        let mut m = MockCompute::new(7);
        let params = mock_client_init();
        let x: Vec<f32> = (0..2 * 3 * 5 * 5).map(|i| (i % 13) as f32 * 0.1).collect();
        let dims = [2usize, 3, 5, 5];
        let a1 = m.client_fwd(&params, &x, &dims).unwrap();
        let a2 = m.client_fwd(&params, &x, &dims).unwrap();
        assert_eq!(a1, a2);
        assert_eq!(a1.dims(), &[2, MOCK_CUT.0, MOCK_CUT.1, MOCK_CUT.2]);

        let StepOut { loss, g_acts, new_params } = m
            .server_step(&mock_server_init(), &a1, &[0, 1], 1e-2)
            .unwrap();
        assert!(loss.is_finite());
        assert_eq!(g_acts.dims(), a1.dims());
        assert_eq!(new_params.len(), mock_server_init().len());

        let np = m.client_bwd(&params, &x, &dims, &g_acts, 1e-2).unwrap();
        assert_eq!(np.len(), params.len());
        assert_ne!(np[0].data(), params[0].data());

        let e = m.entropy(&a1).unwrap();
        assert_eq!(e.len(), MOCK_CUT.0);

        let logits = m
            .eval_logits(&params, &mock_server_init(), &x, &dims)
            .unwrap();
        assert_eq!(logits.dims(), &[2, 7]);
    }

    /// The tentpole contract: one batched dispatch == the sequential
    /// chain, bit for bit (losses, gradients, and the parameter chain).
    #[test]
    fn mock_batch_step_is_bitwise_sequential() {
        let mut m = MockCompute::new(7);
        let cparams = mock_client_init();
        let dims = [2usize, 3, 5, 5];
        let acts: Vec<Tensor> = (0..4)
            .map(|i| {
                let x: Vec<f32> = (0..2 * 3 * 5 * 5)
                    .map(|j| ((i * 7 + j) % 13) as f32 * 0.1)
                    .collect();
                m.client_fwd(&cparams, &x, &dims).unwrap()
            })
            .collect();
        let ys: Vec<Vec<i32>> = (0..4).map(|i| vec![i as i32, (i + 1) as i32]).collect();

        // sequential reference: thread new_params through by hand
        let mut seq = Vec::new();
        let mut params = mock_server_init();
        for (a, y) in acts.iter().zip(&ys) {
            let out = m.server_step(&params, a, y, 1e-2).unwrap();
            params = out.new_params.clone();
            seq.push(out);
        }

        let act_refs: Vec<&Tensor> = acts.iter().collect();
        let y_refs: Vec<&[i32]> = ys.iter().map(|y| y.as_slice()).collect();
        let batched = m
            .server_step_batch(&mock_server_init(), &act_refs, &y_refs, 1e-2)
            .unwrap();
        assert_eq!(batched.len(), seq.len());
        for (b, s) in batched.iter().zip(&seq) {
            assert_eq!(b.loss.to_bits(), s.loss.to_bits());
            assert_eq!(b.g_acts, s.g_acts);
            assert_eq!(b.new_params, s.new_params);
        }
        // a dispatch cost must not change a single bit
        let mut costed = MockCompute::new(7);
        costed.set_dispatch_cost(std::time::Duration::from_micros(50));
        let again = costed
            .server_step_batch(&mock_server_init(), &act_refs, &y_refs, 1e-2)
            .unwrap();
        for (b, s) in again.iter().zip(&seq) {
            assert_eq!(b.loss.to_bits(), s.loss.to_bits());
            assert_eq!(b.g_acts, s.g_acts);
        }
    }

    #[test]
    fn batch_rejects_mismatched_lengths() {
        let mut m = MockCompute::new(3);
        let a = Tensor::new(vec![1, 1, 1, 2], vec![1.0, 2.0]);
        let y: &[i32] = &[0];
        assert!(m
            .server_step_batch(&mock_server_init(), &[&a, &a], &[y], 1e-2)
            .is_err());
    }

    /// The batched-eval contract: one `eval_logits_batch` call over the
    /// whole walk is bit-identical to the per-batch `eval_logits` walk.
    #[test]
    fn eval_logits_batch_is_bitwise_the_walk() {
        let mut m = MockCompute::new(5);
        let client = mock_client_init();
        let server = mock_server_init();
        let dims = [2usize, 3, 4, 4];
        let batches: Vec<Vec<f32>> = (0..3)
            .map(|i| {
                (0..2 * 3 * 4 * 4)
                    .map(|j| ((i * 5 + j) % 11) as f32 * 0.2 - 0.7)
                    .collect()
            })
            .collect();
        let walked: Vec<Tensor> = batches
            .iter()
            .map(|x| m.eval_logits(&client, &server, x, &dims).unwrap())
            .collect();
        let xs: Vec<&[f32]> = batches.iter().map(|v| v.as_slice()).collect();
        let batched = m.eval_logits_batch(&client, &server, &xs, &dims).unwrap();
        assert_eq!(batched.len(), walked.len());
        for (b, w) in batched.iter().zip(&walked) {
            assert_eq!(b.dims(), w.dims());
            let bits = |t: &Tensor| {
                t.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            };
            assert_eq!(bits(b), bits(w));
        }
    }
}
