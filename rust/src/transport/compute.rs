//! The model-execution boundary of the round loop.
//!
//! [`DeviceWorker`](super::device::DeviceWorker) and
//! [`ServerRuntime`](super::server::ServerRuntime) never call PJRT
//! directly; they go through [`Compute`], with two implementations:
//!
//! * [`EngineCompute`] — the real path: the AOT artifacts through
//!   [`crate::runtime::Engine`]. `Rc<RefCell<_>>` lets the in-process
//!   trainer share one compiled engine between the server runtime and all
//!   device workers (PJRT objects never cross threads).
//! * [`MockCompute`] — a deterministic, engine-free stand-in used by the
//!   transport tests, the `--mock` CLI flag, and `examples/distributed.rs`
//!   when artifacts are absent. It produces shaped, channel-varying
//!   activations so the real codecs and the wire protocol are exercised
//!   end-to-end; only the model math is fake.

use std::cell::RefCell;
use std::rc::Rc;

use crate::runtime::{Arg, Engine};
use crate::tensor::Tensor;

/// Result of one server training step (stage iii).
pub struct StepOut {
    pub loss: f64,
    pub g_acts: Tensor,
    pub new_params: Vec<Tensor>,
}

/// Model execution for the four round-loop stages plus evaluation.
pub trait Compute {
    /// Short tag naming the execution backend ("engine" / "mock"); folded
    /// into the session fingerprint so an engine server rejects mock
    /// devices and vice versa.
    fn kind(&self) -> &'static str;

    /// Stage i: client sub-model forward → cut-layer activations.
    fn client_fwd(
        &mut self,
        params: &[Tensor],
        x: &[f32],
        x_dims: &[usize],
    ) -> Result<Tensor, String>;

    /// Stage iv: client backward + SGD → new client params.
    fn client_bwd(
        &mut self,
        params: &[Tensor],
        x: &[f32],
        x_dims: &[usize],
        g: &Tensor,
        lr: f32,
    ) -> Result<Vec<Tensor>, String>;

    /// Stage iii: server forward+backward+SGD on (decompressed) smashed data.
    fn server_step(
        &mut self,
        params: &[Tensor],
        acts: &Tensor,
        y: &[i32],
        lr: f32,
    ) -> Result<StepOut, String>;

    /// Per-channel ACII entropy of a smashed-data tensor.
    fn entropy(&mut self, t: &Tensor) -> Result<Vec<f32>, String>;

    /// Full-model logits for test evaluation.
    fn eval_logits(
        &mut self,
        client: &[Tensor],
        server: &[Tensor],
        x: &[f32],
        x_dims: &[usize],
    ) -> Result<Tensor, String>;
}

/// The real PJRT-backed compute path.
pub struct EngineCompute {
    engine: Rc<RefCell<Engine>>,
    entropy_via_kernel: bool,
}

impl EngineCompute {
    pub fn new(engine: Rc<RefCell<Engine>>, entropy_via_kernel: bool) -> EngineCompute {
        EngineCompute { engine, entropy_via_kernel }
    }

    pub fn engine(&self) -> Rc<RefCell<Engine>> {
        self.engine.clone()
    }
}

fn param_args(params: &[Tensor]) -> Vec<Arg<'_>> {
    params.iter().map(|t| Arg::F32(t.data(), t.dims())).collect()
}

impl Compute for EngineCompute {
    fn kind(&self) -> &'static str {
        "engine"
    }

    fn client_fwd(
        &mut self,
        params: &[Tensor],
        x: &[f32],
        x_dims: &[usize],
    ) -> Result<Tensor, String> {
        let mut args = param_args(params);
        args.push(Arg::F32(x, x_dims));
        let out = self.engine.borrow_mut().execute("client_fwd", &args)?;
        out.into_iter().next().ok_or_else(|| "client_fwd returned no output".into())
    }

    fn client_bwd(
        &mut self,
        params: &[Tensor],
        x: &[f32],
        x_dims: &[usize],
        g: &Tensor,
        lr: f32,
    ) -> Result<Vec<Tensor>, String> {
        let mut args = param_args(params);
        args.push(Arg::F32(x, x_dims));
        args.push(Arg::F32(g.data(), g.dims()));
        args.push(Arg::ScalarF32(lr));
        self.engine.borrow_mut().execute("client_bwd", &args)
    }

    fn server_step(
        &mut self,
        params: &[Tensor],
        acts: &Tensor,
        y: &[i32],
        lr: f32,
    ) -> Result<StepOut, String> {
        let y_dims = [y.len()];
        let mut args = param_args(params);
        args.push(Arg::F32(acts.data(), acts.dims()));
        args.push(Arg::I32(y, &y_dims));
        args.push(Arg::ScalarF32(lr));
        let mut out = self.engine.borrow_mut().execute("server_step", &args)?;
        if out.len() < 2 {
            return Err(format!("server_step returned {} outputs, need >= 2", out.len()));
        }
        let new_params = out.split_off(2);
        let g_acts = out.pop().unwrap();
        let loss = out.pop().unwrap().data()[0] as f64;
        Ok(StepOut { loss, g_acts, new_params })
    }

    fn entropy(&mut self, t: &Tensor) -> Result<Vec<f32>, String> {
        if self.entropy_via_kernel {
            let out = self
                .engine
                .borrow_mut()
                .execute("entropy", &[Arg::F32(t.data(), t.dims())])?;
            Ok(out
                .into_iter()
                .next()
                .ok_or("entropy kernel returned no output")?
                .into_data())
        } else {
            Ok(crate::entropy::shannon::entropies(&t.to_channel_major()))
        }
    }

    fn eval_logits(
        &mut self,
        client: &[Tensor],
        server: &[Tensor],
        x: &[f32],
        x_dims: &[usize],
    ) -> Result<Tensor, String> {
        let mut args = param_args(client);
        args.extend(param_args(server));
        args.push(Arg::F32(x, x_dims));
        let out = self.engine.borrow_mut().execute("eval_logits", &args)?;
        out.into_iter().next().ok_or_else(|| "eval_logits returned no output".into())
    }
}

/// Cut-layer shape (C, H, W) the mock model emits.
pub const MOCK_CUT: (usize, usize, usize) = (8, 4, 4);
/// Batch size mock sessions run with.
pub const MOCK_BATCH: usize = 8;

/// Initial "client sub-model" for mock sessions: one scalar-ish parameter.
pub fn mock_client_init() -> Vec<Tensor> {
    vec![Tensor::new(vec![2], vec![1.0, 0.5])]
}

/// Initial "server sub-model" for mock sessions.
pub fn mock_server_init() -> Vec<Tensor> {
    vec![Tensor::new(vec![2], vec![0.25, -0.25])]
}

/// Deterministic engine-free compute (see module docs). All math is simple
/// elementwise arithmetic, so two processes with the same inputs produce
/// bit-identical activations, gradients, and therefore wire bytes.
pub struct MockCompute {
    classes: usize,
}

impl MockCompute {
    pub fn new(classes: usize) -> MockCompute {
        assert!(classes >= 1);
        MockCompute { classes }
    }
}

fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f32>() / xs.len() as f32
    }
}

impl Compute for MockCompute {
    fn kind(&self) -> &'static str {
        "mock"
    }

    fn client_fwd(
        &mut self,
        params: &[Tensor],
        x: &[f32],
        x_dims: &[usize],
    ) -> Result<Tensor, String> {
        if x_dims.len() != 4 {
            return Err(format!("mock client_fwd wants NCHW input, got {x_dims:?}"));
        }
        let (b, ic, ih, iw) = (x_dims[0], x_dims[1], x_dims[2], x_dims[3]);
        let p = params.first().map(|t| t.data()[0]).unwrap_or(1.0);
        let (c, h, w) = MOCK_CUT;
        let mut data = Vec::with_capacity(b * c * h * w);
        for bi in 0..b {
            for ci in 0..c {
                for hi in 0..h {
                    for wi in 0..w {
                        let src =
                            ((bi * ic + ci % ic) * ih + hi % ih) * iw + wi % iw;
                        let gain = 1.0 + 0.11 * ci as f32;
                        data.push((p * x[src] * gain).max(0.0));
                    }
                }
            }
        }
        Ok(Tensor::new(vec![b, c, h, w], data))
    }

    fn client_bwd(
        &mut self,
        params: &[Tensor],
        _x: &[f32],
        _x_dims: &[usize],
        g: &Tensor,
        lr: f32,
    ) -> Result<Vec<Tensor>, String> {
        let step = lr * mean(g.data());
        Ok(params
            .iter()
            .map(|t| {
                let data = t.data().iter().map(|&v| v - step).collect();
                Tensor::new(t.dims().to_vec(), data)
            })
            .collect())
    }

    fn server_step(
        &mut self,
        params: &[Tensor],
        acts: &Tensor,
        y: &[i32],
        lr: f32,
    ) -> Result<StepOut, String> {
        if y.is_empty() {
            return Err("mock server_step: empty labels".into());
        }
        let m2 = acts.data().iter().map(|&v| (v * v) as f64).sum::<f64>()
            / acts.len().max(1) as f64;
        let loss = m2 + 0.01 * params.first().map(|t| t.data()[0].abs() as f64).unwrap_or(0.0);
        let g_data: Vec<f32> = acts.data().iter().map(|&v| 0.3 * v - 0.01).collect();
        let g_acts = Tensor::new(acts.dims().to_vec(), g_data);
        let step = lr * loss as f32;
        let new_params = params
            .iter()
            .map(|t| {
                let data = t.data().iter().map(|&v| v - step * 0.1).collect();
                Tensor::new(t.dims().to_vec(), data)
            })
            .collect();
        Ok(StepOut { loss, g_acts, new_params })
    }

    fn entropy(&mut self, t: &Tensor) -> Result<Vec<f32>, String> {
        Ok(crate::entropy::shannon::entropies(&t.to_channel_major()))
    }

    fn eval_logits(
        &mut self,
        client: &[Tensor],
        _server: &[Tensor],
        x: &[f32],
        x_dims: &[usize],
    ) -> Result<Tensor, String> {
        let b = *x_dims.first().unwrap_or(&1);
        let p = client.first().map(|t| t.data()[0]).unwrap_or(1.0);
        let per = x.len() / b.max(1);
        let mut data = Vec::with_capacity(b * self.classes);
        for bi in 0..b {
            let xm = mean(&x[bi * per..(bi + 1) * per]);
            for k in 0..self.classes {
                data.push(p * xm + 0.1 * k as f32);
            }
        }
        Ok(Tensor::new(vec![b, self.classes], data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_is_deterministic_and_shaped() {
        let mut m = MockCompute::new(7);
        let params = mock_client_init();
        let x: Vec<f32> = (0..2 * 3 * 5 * 5).map(|i| (i % 13) as f32 * 0.1).collect();
        let dims = [2usize, 3, 5, 5];
        let a1 = m.client_fwd(&params, &x, &dims).unwrap();
        let a2 = m.client_fwd(&params, &x, &dims).unwrap();
        assert_eq!(a1, a2);
        assert_eq!(a1.dims(), &[2, MOCK_CUT.0, MOCK_CUT.1, MOCK_CUT.2]);

        let StepOut { loss, g_acts, new_params } = m
            .server_step(&mock_server_init(), &a1, &[0, 1], 1e-2)
            .unwrap();
        assert!(loss.is_finite());
        assert_eq!(g_acts.dims(), a1.dims());
        assert_eq!(new_params.len(), mock_server_init().len());

        let np = m.client_bwd(&params, &x, &dims, &g_acts, 1e-2).unwrap();
        assert_eq!(np.len(), params.len());
        assert_ne!(np[0].data(), params[0].data());

        let e = m.entropy(&a1).unwrap();
        assert_eq!(e.len(), MOCK_CUT.0);

        let logits = m
            .eval_logits(&params, &mock_server_init(), &x, &dims)
            .unwrap();
        assert_eq!(logits.dims(), &[2, 7]);
    }
}
