//! In-process, thread-safe channel transport: the cross-thread twin of
//! [`crate::transport::loopback`].
//!
//! Loopback's `Rc`-shared queues pin both endpoints to one thread, which
//! is exactly right for a device fleet pumped by a single-threaded server
//! loop — but the in-process sharded-topology simulator
//! ([`crate::shard::sim`]) runs each shard session on its own thread with
//! the coordinator on another, so the shard↔coordinator links need
//! endpoints that can cross threads. `ChannelTransport` carries fully
//! framed bytes over `std::sync::mpsc` channels: `recv` blocks like a
//! socket, `try_recv` polls, and a dropped peer surfaces as the typed
//! [`TransportError::PeerClosed`] — the same semantics the TCP transport
//! exposes, so code driven over channels behaves identically over real
//! sockets.
//!
//! Frames are encoded/decoded exactly as on a wire ([`Message::encode_frame`]),
//! so byte accounting through a channel session matches a TCP session
//! bit-for-bit.

use std::sync::mpsc;

use super::proto::Message;
use super::{Transport, TransportError, WireStats};

/// One end of a channel transport pair.
pub struct ChannelTransport {
    tx: mpsc::Sender<Vec<u8>>,
    rx: mpsc::Receiver<Vec<u8>>,
    stats: WireStats,
    name: String,
}

/// Create a connected pair `(a_end, b_end)`; either end may move to its
/// own thread.
pub fn pair(label: &str) -> (ChannelTransport, ChannelTransport) {
    let (a_tx, b_rx) = mpsc::channel();
    let (b_tx, a_rx) = mpsc::channel();
    (
        ChannelTransport {
            tx: a_tx,
            rx: a_rx,
            stats: WireStats::default(),
            name: format!("{label}/a"),
        },
        ChannelTransport {
            tx: b_tx,
            rx: b_rx,
            stats: WireStats::default(),
            name: format!("{label}/b"),
        },
    )
}

impl ChannelTransport {
    fn note_recv(&mut self, frame: &[u8]) -> Result<Message, TransportError> {
        self.stats.frames_recv += 1;
        self.stats.bytes_recv += frame.len() as u64;
        Message::decode_frame(frame).map_err(TransportError::Protocol)
    }

    fn closed(&self) -> TransportError {
        TransportError::PeerClosed { peer: self.name.clone() }
    }
}

impl Transport for ChannelTransport {
    fn send(&mut self, msg: &Message) -> Result<(), TransportError> {
        let frame = msg.encode_frame();
        let n = frame.len() as u64;
        self.tx.send(frame).map_err(|_| self.closed())?;
        self.stats.frames_sent += 1;
        self.stats.bytes_sent += n;
        Ok(())
    }

    fn recv(&mut self) -> Result<Message, TransportError> {
        let frame = self.rx.recv().map_err(|_| self.closed())?;
        self.note_recv(&frame)
    }

    fn try_recv(&mut self) -> Result<Option<Message>, TransportError> {
        match self.rx.try_recv() {
            Ok(frame) => Ok(Some(self.note_recv(&frame)?)),
            Err(mpsc::TryRecvError::Empty) => Ok(None),
            Err(mpsc::TryRecvError::Disconnected) => Err(self.closed()),
        }
    }

    fn stats(&self) -> WireStats {
        self.stats
    }

    fn peer(&self) -> String {
        self.name.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn frames_cross_threads_with_byte_accounting() {
        let (mut a, mut b) = pair("t");
        let handle = thread::spawn(move || {
            let msg = b.recv().unwrap();
            assert!(matches!(msg, Message::RoundOpen { round: 7, .. }));
            b.send(&Message::Shutdown { reason: "ok".into() }).unwrap();
            b.stats()
        });
        a.send(&Message::RoundOpen { round: 7, sync: true }).unwrap();
        assert!(matches!(a.recv().unwrap(), Message::Shutdown { .. }));
        let b_stats = handle.join().unwrap();
        assert_eq!(a.stats().bytes_sent, b_stats.bytes_recv);
        assert_eq!(a.stats().bytes_recv, b_stats.bytes_sent);
        assert!(a.stats().bytes_sent > 0);
    }

    #[test]
    fn dropped_peer_is_typed_peer_closed() {
        let (mut a, b) = pair("t");
        drop(b);
        assert!(a.recv().unwrap_err().is_peer_closed());
        assert!(a.try_recv().unwrap_err().is_peer_closed());
        assert!(a
            .send(&Message::RoundOpen { round: 0, sync: false })
            .unwrap_err()
            .is_peer_closed());
    }

    #[test]
    fn try_recv_polls_without_blocking() {
        let (mut a, mut b) = pair("t");
        assert!(a.try_recv().unwrap().is_none());
        b.send(&Message::RoundOpen { round: 1, sync: false }).unwrap();
        assert!(a.try_recv().unwrap().is_some());
        assert!(a.try_recv().unwrap().is_none());
    }
}
