//! Real wire transport for the split-learning round loop.
//!
//! The codecs shrink the smashed-data bytes; this subsystem actually moves
//! them. It carries the codec payload envelopes ([`crate::quant::payload`])
//! inside a framed message protocol ([`proto`]) over one of two transports:
//!
//! * [`loopback`] — an in-process, deterministic byte-queue pair. The
//!   [`crate::coordinator::trainer::Trainer`] drives every simulated run
//!   through it, so the simulator path and the real-socket path execute the
//!   same protocol code.
//! * [`tcp`] — `std::net` streams. The device side reads lock-step on the
//!   caller's thread; the server side no longer spawns a reader thread per
//!   connection — `slacc serve` drives every accepted socket from one
//!   non-blocking poll loop ([`crate::sched::event_loop`]). The threaded
//!   accept mode in [`tcp`] remains for generic [`Transport`] consumers.
//!
//! The round loop itself lives in [`server::ServerRuntime`] (stages ii–iii:
//! decompress → `server_step` → compress gradients) and
//! [`device::DeviceWorker`] (stages i and iv), both expressed against the
//! [`Transport`] trait, with the PJRT engine abstracted behind
//! [`compute::Compute`] so protocol tests and `--mock` sessions run without
//! AOT artifacts. Round *ordering* — in-order vs arrival-order, straggler
//! timeouts, quorum closes — is owned by [`crate::sched::round`].
//!
//! Byte accounting: `NetworkSim::round_cost` is fed the codec *envelope*
//! bytes (identical to what the in-process simulator always measured);
//! ModelSync traffic is packed through its own codec stream ([`sync`]) and
//! accounted separately, and [`WireStats`] additionally tracks full framed
//! bytes per connection so the protocol overhead is observable.

pub mod channel;
pub mod compute;
pub mod device;
pub mod loopback;
pub mod proto;
pub mod server;
pub mod sync;
pub mod tcp;

use proto::Message;

/// Fold a config fingerprint ([`crate::config::ExperimentConfig::fingerprint`])
/// with the compute backend tag ([`compute::Compute::kind`]): both ends of a
/// session must agree on every numerics-affecting flag AND on engine-vs-mock
/// execution, and this is the digest the Hello handshake compares.
pub fn session_fingerprint(config_fp: u64, compute_kind: &str) -> u64 {
    let mut h = config_fp ^ 0x9e37_79b9_7f4a_7c15;
    for b in compute_kind.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// What went wrong on a transport endpoint. Callers that only propagate
/// context keep using `Result<_, String>` (`?` converts via
/// `From<TransportError> for String`); callers that *react* to disconnects
/// — the scheduler dropping a dead device, tests asserting clean-close
/// semantics — match on [`TransportError::PeerClosed`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TransportError {
    /// The peer closed the connection cleanly (EOF at a frame boundary).
    PeerClosed { peer: String },
    /// The connection is alive but carried bytes that violate the framed
    /// protocol (bad magic, oversized lengths, unexpected message, ...).
    Protocol(String),
    /// OS-level I/O failure: reset, refused, or a mid-frame truncation.
    Io(String),
}

impl TransportError {
    pub fn is_peer_closed(&self) -> bool {
        matches!(self, TransportError::PeerClosed { .. })
    }
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::PeerClosed { peer } => {
                write!(f, "{peer}: peer closed the connection")
            }
            TransportError::Protocol(m) => write!(f, "protocol error: {m}"),
            TransportError::Io(m) => write!(f, "i/o error: {m}"),
        }
    }
}

impl std::error::Error for TransportError {}

impl From<TransportError> for String {
    fn from(e: TransportError) -> String {
        e.to_string()
    }
}

/// A [`Transport`] decorator that sleeps before forwarding Activations —
/// latency injection for straggler tests, benches, and examples (a real
/// slow device on a real socket, not a simulated one).
pub struct DelayedTransport<T: Transport> {
    inner: T,
    delay: std::time::Duration,
}

impl<T: Transport> DelayedTransport<T> {
    /// Delay every Activations send by `delay` (the straggler shape:
    /// slow client compute / slow uplink).
    pub fn slow_activations(inner: T, delay: std::time::Duration) -> DelayedTransport<T> {
        DelayedTransport { inner, delay }
    }
}

impl<T: Transport> Transport for DelayedTransport<T> {
    fn send(&mut self, msg: &Message) -> Result<(), TransportError> {
        if matches!(msg, Message::Activations { .. }) {
            std::thread::sleep(self.delay);
        }
        self.inner.send(msg)
    }
    fn recv(&mut self) -> Result<Message, TransportError> {
        self.inner.recv()
    }
    fn try_recv(&mut self) -> Result<Option<Message>, TransportError> {
        self.inner.try_recv()
    }
    fn stats(&self) -> WireStats {
        self.inner.stats()
    }
    fn peer(&self) -> String {
        self.inner.peer()
    }
}

/// Cumulative framed-byte accounting for one transport endpoint.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireStats {
    pub frames_sent: u64,
    pub frames_recv: u64,
    pub bytes_sent: u64,
    pub bytes_recv: u64,
}

/// A duplex, ordered, framed message channel between one device and the
/// server. Implementations: [`loopback::Loopback`], [`tcp::TcpTransport`].
pub trait Transport {
    /// Serialize and send one message.
    fn send(&mut self, msg: &Message) -> Result<(), TransportError>;

    /// Receive the next message. TCP blocks; loopback (single-threaded)
    /// errors if the peer has not been pumped — see [`loopback`].
    fn recv(&mut self) -> Result<Message, TransportError>;

    /// Non-blocking receive: `Ok(None)` when nothing is queued.
    fn try_recv(&mut self) -> Result<Option<Message>, TransportError>;

    /// Framed bytes sent/received so far on this endpoint.
    fn stats(&self) -> WireStats;

    /// Human-readable peer label for logs.
    fn peer(&self) -> String;
}
