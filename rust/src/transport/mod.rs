//! Real wire transport for the split-learning round loop.
//!
//! The codecs shrink the smashed-data bytes; this subsystem actually moves
//! them. It carries the codec payload envelopes ([`crate::quant::payload`])
//! inside a framed message protocol ([`proto`]) over one of two transports:
//!
//! * [`loopback`] — an in-process, deterministic byte-queue pair. The
//!   [`crate::coordinator::trainer::Trainer`] drives every simulated run
//!   through it, so the simulator path and the real-socket path execute the
//!   same protocol code.
//! * [`tcp`] — `std::net` streams, one reader thread per accepted
//!   connection on the server side (`slacc serve` / `slacc device`).
//!
//! The round loop itself lives in [`server::ServerRuntime`] (stages ii–iii:
//! decompress → `server_step` → compress gradients) and
//! [`device::DeviceWorker`] (stages i and iv), both expressed against the
//! [`Transport`] trait, with the PJRT engine abstracted behind
//! [`compute::Compute`] so protocol tests and `--mock` sessions run without
//! AOT artifacts.
//!
//! Byte accounting: `NetworkSim::round_cost` is fed the codec *envelope*
//! bytes (identical to what the in-process simulator always measured);
//! [`WireStats`] additionally tracks full framed bytes per connection so
//! the protocol overhead is observable.

pub mod compute;
pub mod device;
pub mod loopback;
pub mod proto;
pub mod server;
pub mod tcp;

use proto::Message;

/// Fold a config fingerprint ([`crate::config::ExperimentConfig::fingerprint`])
/// with the compute backend tag ([`compute::Compute::kind`]): both ends of a
/// session must agree on every numerics-affecting flag AND on engine-vs-mock
/// execution, and this is the digest the Hello handshake compares.
pub fn session_fingerprint(config_fp: u64, compute_kind: &str) -> u64 {
    let mut h = config_fp ^ 0x9e37_79b9_7f4a_7c15;
    for b in compute_kind.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Cumulative framed-byte accounting for one transport endpoint.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireStats {
    pub frames_sent: u64,
    pub frames_recv: u64,
    pub bytes_sent: u64,
    pub bytes_recv: u64,
}

/// A duplex, ordered, framed message channel between one device and the
/// server. Implementations: [`loopback::Loopback`], [`tcp::TcpTransport`].
pub trait Transport {
    /// Serialize and send one message.
    fn send(&mut self, msg: &Message) -> Result<(), String>;

    /// Receive the next message. TCP blocks; loopback (single-threaded)
    /// errors if the peer has not been pumped — see [`loopback`].
    fn recv(&mut self) -> Result<Message, String>;

    /// Non-blocking receive: `Ok(None)` when nothing is queued.
    fn try_recv(&mut self) -> Result<Option<Message>, String>;

    /// Framed bytes sent/received so far on this endpoint.
    fn stats(&self) -> WireStats;

    /// Human-readable peer label for logs.
    fn peer(&self) -> String;
}
