//! Framed SL wire protocol: the messages a device and the server exchange
//! during one split-learning session, serialized as length-prefixed frames.
//!
//! ```text
//! magic    u32 = 0x534C4143 ("SLAC")
//! version  u8  = 2 (v2: Hello carries the per-stream codec spec table)
//! type     u8  (msg_type::*)
//! body_len u32 (little-endian, <= MAX_FRAME_BODY)
//! body     type-specific, encoded with ByteWriter/ByteReader
//! ```
//!
//! The codec payload envelopes from [`crate::quant::payload`] travel as
//! opaque byte blobs inside [`Message::Activations`] / [`Message::Gradients`]
//! — the transport never re-encodes smashed data, so the byte count the
//! network simulator accounts is exactly the envelope the codec produced.
//! [`Message::ModelSync`] likewise carries an opaque blob: the sub-model
//! pack produced by [`crate::transport::sync`], which routes FedAvg traffic
//! through its own codec stream.
//!
//! Like the payload header's `MAX_ELEMENTS` guard, every length field read
//! off the wire is capped *before* allocation so a hostile 10-byte frame
//! header cannot demand gigabytes.
//!
//! Two read paths exist: [`read_frame`] / [`read_frame_or_eof`] for
//! blocking streams (one `read_exact` per header/body), and
//! [`FrameDecoder`] for non-blocking sockets driven by a poll loop — feed
//! it whatever bytes `read` produced, pop complete messages.

use crate::quant::payload::{ByteReader, ByteWriter};

/// Frame magic: "SLAC" in ASCII.
pub const FRAME_MAGIC: u32 = 0x534C_4143;
/// Wire-protocol version (frames, not payload envelopes). v2 replaced
/// Hello's single codec string with the full per-stream spec table; v3
/// added the shard-tier frames (ShardHello/ShardSync) for multi-server
/// topologies; v4 added the telemetry roll-up blob to ShardSync so the
/// coordinator can report cluster-wide counter totals; v5 added the
/// runtime renegotiation frames (SpecUpdate/SpecUpdateAck) that swap the
/// per-stream codec table mid-session at an agreed round boundary; v6
/// added the elastic-membership frames (Join/JoinAck/Catchup/Leave) that
/// let a device enter or leave a session after handshake.
pub const PROTO_VERSION: u8 = 6;
/// Fixed frame-header size in bytes (magic + version + type + body_len).
pub const FRAME_HEADER_BYTES: usize = 4 + 1 + 1 + 4;
/// Hard cap on a frame body: 1 GiB, matching the payload header's
/// 2^28-element (1 GiB of f32) guard.
pub const MAX_FRAME_BODY: usize = 1 << 30;
/// Cap on a label vector per batch (a batch is never near this).
const MAX_LABELS: usize = 1 << 20;
/// Cap on string fields (codec names, shutdown reasons).
const MAX_STR: usize = 4096;

/// Stable message-type ids for the frame header.
pub mod msg_type {
    pub const HELLO: u8 = 1;
    pub const HELLO_ACK: u8 = 2;
    pub const ROUND_OPEN: u8 = 3;
    pub const ACTIVATIONS: u8 = 4;
    pub const GRADIENTS: u8 = 5;
    pub const MODEL_SYNC: u8 = 6;
    pub const SHUTDOWN: u8 = 7;
    pub const SHARD_HELLO: u8 = 8;
    pub const SHARD_SYNC: u8 = 9;
    pub const SPEC_UPDATE: u8 = 10;
    pub const SPEC_UPDATE_ACK: u8 = 11;
    pub const JOIN: u8 = 12;
    pub const JOIN_ACK: u8 = 13;
    pub const CATCHUP: u8 = 14;
    pub const LEAVE: u8 = 15;
}

/// One SL-protocol message.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// device → server: first frame on a connection. Declares which device
    /// slot this connection serves, the fleet size, the full per-stream
    /// codec spec table (uplink/downlink/sync, canonical strings plus a
    /// digest), and the session fingerprint (config digest + compute kind)
    /// the device was configured with — the server rejects mismatches,
    /// naming the offending stream — plus the shard size (the FedAvg
    /// weight).
    Hello {
        device_id: u32,
        devices: u32,
        shard_len: u32,
        config_fp: u64,
        /// canonical spec of the uplink stream
        uplink: String,
        /// canonical spec of the downlink stream
        downlink: String,
        /// canonical spec of the ModelSync streams
        sync: String,
        /// [`crate::codecs::stream::StreamSpecs::fingerprint`] of the table
        streams_fp: u64,
    },
    /// server → device: handshake accept, echoing the negotiated run shape.
    HelloAck { device_id: u32, rounds: u32, agg_every: u32 },
    /// server → device: start round `round`. `sync` asks the device to push
    /// its client sub-model (ModelSync) after the backward pass.
    RoundOpen { round: u32, sync: bool },
    /// device → server: stage-ii uplink — the codec's wire envelope plus
    /// this batch's labels (standard label-sharing SL; labels are not part
    /// of the smashed-data byte accounting).
    Activations { round: u32, device_id: u32, labels: Vec<i32>, payload: Vec<u8> },
    /// server → device: stage-iv downlink — compressed cut-layer gradients
    /// and this device's training loss for the round.
    Gradients { round: u32, device_id: u32, loss: f32, payload: Vec<u8> },
    /// Both directions: client sub-model parameters, packed through the
    /// session's ModelSync codec stream ([`crate::transport::sync`]).
    /// Device → server pushes the post-backward params; server → device
    /// returns the FedAvg result (an empty payload means "keep what you
    /// have").
    ModelSync { round: u32, device_id: u32, payload: Vec<u8> },
    /// server → device: session over (completed, early-stopped, or failed).
    Shutdown { reason: String },
    /// Shard-tier handshake, both directions. The coordinator opens each
    /// shard connection by declaring the topology it was launched with
    /// (which shard slot this connection serves, the shard count, the
    /// cross-shard sync cadence, and the session fingerprint); the shard
    /// validates and echoes the same fields back with its FedAvg `weight`
    /// (total local training samples). Either side rejects a mismatch,
    /// naming the offending flag — a mis-shaped cluster must not train.
    ShardHello {
        shard_id: u32,
        shards: u32,
        sync_every: u32,
        config_fp: u64,
        /// shard → coordinator only: this shard's sample count (its
        /// cross-shard FedAvg weight). 0 in the coordinator's opener.
        weight: u64,
    },
    /// Shard-tier parameter sync, both directions. Shard → coordinator:
    /// push the shard's aggregated client sub-model and its server
    /// sub-model, each packed through the negotiated `--sync-codec`
    /// stream ([`crate::transport::sync`]). Coordinator → shard: the
    /// cross-shard FedAvg merge of both, same packing. A push with two
    /// zero-length blobs means "this shard's session is over" (clean
    /// departure from the sync tier).
    ShardSync {
        /// cross-shard sync epoch (round / `--shard-sync-every`), so a
        /// cadence desync is caught instead of silently merging stale
        /// models
        epoch: u32,
        shard_id: u32,
        /// sync pack of the shard/merged client sub-model (may be an
        /// empty *pack* — zero tensors — when a quorum round had no
        /// client basis; a zero-length *blob* is the done marker)
        client: Vec<u8>,
        /// sync pack of the shard/merged server sub-model
        server: Vec<u8>,
        /// telemetry roll-up ([`crate::obs::metrics::rollup_blob`]):
        /// shard → coordinator carries the shard's cumulative counters so
        /// the coordinator can report cluster-wide totals; empty in the
        /// coordinator's replies (and from pre-telemetry peers)
        metrics: Vec<u8>,
    },
    /// server → device: runtime renegotiation (proto v5). The control loop
    /// ([`crate::adapt`]) re-negotiated the per-stream codec table; every
    /// device must swap its streams atomically at the start of round
    /// `activate_round`. Pushed at a round boundary, at least one full
    /// round before activation, and acked ([`Message::SpecUpdateAck`])
    /// before the device's first frame of the activation round. Frames for
    /// rounds below `activate_round` (including carried stragglers
    /// finishing a stale round) keep using the old table. The digest is
    /// cross-checked against the spec strings on receipt, exactly like
    /// Hello's.
    SpecUpdate {
        activate_round: u32,
        /// canonical spec of the new uplink stream
        uplink: String,
        /// canonical spec of the new downlink stream
        downlink: String,
        /// canonical spec of the new ModelSync streams
        sync: String,
        /// [`crate::codecs::stream::StreamSpecs::fingerprint`] of the table
        streams_fp: u64,
    },
    /// device → server: the device accepted a [`Message::SpecUpdate`] and
    /// will swap at `activate_round`. Echoes the update's round + digest so
    /// the server can match the ack against the transition it pushed.
    SpecUpdateAck { activate_round: u32, streams_fp: u64 },
    /// device → server: elastic membership (proto v6) — the first frame on
    /// a *late* connection, from a device asking to join (or rejoin) a
    /// session that is already past its initial handshake. Carries the
    /// same validation payload as [`Message::Hello`] plus `member_epoch`:
    /// the admission epoch the device last held (0 for a process that was
    /// never admitted), so the server can reject a stale incarnation
    /// replaying an admission it no longer owns.
    Join {
        device_id: u32,
        devices: u32,
        shard_len: u32,
        config_fp: u64,
        member_epoch: u32,
        /// canonical spec of the uplink stream
        uplink: String,
        /// canonical spec of the downlink stream
        downlink: String,
        /// canonical spec of the ModelSync streams
        sync: String,
        /// [`crate::codecs::stream::StreamSpecs::fingerprint`] of the table
        streams_fp: u64,
    },
    /// server → device: admission accept for a [`Message::Join`], pushed
    /// at the next round boundary. `round` is the first round the device
    /// will be opened for; `member_epoch` is the server-stamped admission
    /// epoch (the device echoes it in any future Join); `rounds` and
    /// `agg_every` mirror [`Message::HelloAck`] so a fresh process learns
    /// the run shape.
    JoinAck {
        device_id: u32,
        round: u32,
        member_epoch: u32,
        rounds: u32,
        agg_every: u32,
    },
    /// server → device: model catch-up, sent immediately after
    /// [`Message::JoinAck`]. `payload` is the current client sub-model
    /// packed through the negotiated ModelSync codec stream
    /// ([`crate::transport::sync`]; empty means "keep your local init" —
    /// no broadcast has happened yet), `spec_epoch` is the active
    /// [`crate::codecs::stream::StreamSpecs`] epoch, and `round` the
    /// server's round counter, so the rejoiner rebuilds its codec state
    /// in lock-step with the server's twin.
    Catchup { round: u32, device_id: u32, spec_epoch: u32, payload: Vec<u8> },
    /// device → server: graceful departure announcement. The server
    /// retires the slot as a typed membership event at the next
    /// scheduling step instead of treating the subsequent hang-up as an
    /// I/O failure.
    Leave { device_id: u32, reason: String },
}

impl Message {
    pub fn type_id(&self) -> u8 {
        match self {
            Message::Hello { .. } => msg_type::HELLO,
            Message::HelloAck { .. } => msg_type::HELLO_ACK,
            Message::RoundOpen { .. } => msg_type::ROUND_OPEN,
            Message::Activations { .. } => msg_type::ACTIVATIONS,
            Message::Gradients { .. } => msg_type::GRADIENTS,
            Message::ModelSync { .. } => msg_type::MODEL_SYNC,
            Message::Shutdown { .. } => msg_type::SHUTDOWN,
            Message::ShardHello { .. } => msg_type::SHARD_HELLO,
            Message::ShardSync { .. } => msg_type::SHARD_SYNC,
            Message::SpecUpdate { .. } => msg_type::SPEC_UPDATE,
            Message::SpecUpdateAck { .. } => msg_type::SPEC_UPDATE_ACK,
            Message::Join { .. } => msg_type::JOIN,
            Message::JoinAck { .. } => msg_type::JOIN_ACK,
            Message::Catchup { .. } => msg_type::CATCHUP,
            Message::Leave { .. } => msg_type::LEAVE,
        }
    }

    pub fn type_name(&self) -> &'static str {
        match self {
            Message::Hello { .. } => "Hello",
            Message::HelloAck { .. } => "HelloAck",
            Message::RoundOpen { .. } => "RoundOpen",
            Message::Activations { .. } => "Activations",
            Message::Gradients { .. } => "Gradients",
            Message::ModelSync { .. } => "ModelSync",
            Message::Shutdown { .. } => "Shutdown",
            Message::ShardHello { .. } => "ShardHello",
            Message::ShardSync { .. } => "ShardSync",
            Message::SpecUpdate { .. } => "SpecUpdate",
            Message::SpecUpdateAck { .. } => "SpecUpdateAck",
            Message::Join { .. } => "Join",
            Message::JoinAck { .. } => "JoinAck",
            Message::Catchup { .. } => "Catchup",
            Message::Leave { .. } => "Leave",
        }
    }

    fn write_body(&self, w: &mut ByteWriter) {
        match self {
            Message::Hello {
                device_id,
                devices,
                shard_len,
                config_fp,
                uplink,
                downlink,
                sync,
                streams_fp,
            } => {
                w.u32(*device_id);
                w.u32(*devices);
                w.u32(*shard_len);
                w.u64(*config_fp);
                w.u64(*streams_fp);
                write_str(w, uplink);
                write_str(w, downlink);
                write_str(w, sync);
            }
            Message::HelloAck { device_id, rounds, agg_every } => {
                w.u32(*device_id);
                w.u32(*rounds);
                w.u32(*agg_every);
            }
            Message::RoundOpen { round, sync } => {
                w.u32(*round);
                w.u8(*sync as u8);
            }
            Message::Activations { round, device_id, labels, payload } => {
                w.u32(*round);
                w.u32(*device_id);
                w.u32(labels.len() as u32);
                for &l in labels {
                    w.u32(l as u32);
                }
                write_blob(w, payload);
            }
            Message::Gradients { round, device_id, loss, payload } => {
                w.u32(*round);
                w.u32(*device_id);
                w.f32(*loss);
                write_blob(w, payload);
            }
            Message::ModelSync { round, device_id, payload } => {
                w.u32(*round);
                w.u32(*device_id);
                write_blob(w, payload);
            }
            Message::Shutdown { reason } => {
                write_str(w, reason);
            }
            Message::ShardHello { shard_id, shards, sync_every, config_fp, weight } => {
                w.u32(*shard_id);
                w.u32(*shards);
                w.u32(*sync_every);
                w.u64(*config_fp);
                w.u64(*weight);
            }
            Message::ShardSync { epoch, shard_id, client, server, metrics } => {
                w.u32(*epoch);
                w.u32(*shard_id);
                write_blob(w, client);
                write_blob(w, server);
                write_blob(w, metrics);
            }
            Message::SpecUpdate { activate_round, uplink, downlink, sync, streams_fp } => {
                w.u32(*activate_round);
                w.u64(*streams_fp);
                write_str(w, uplink);
                write_str(w, downlink);
                write_str(w, sync);
            }
            Message::SpecUpdateAck { activate_round, streams_fp } => {
                w.u32(*activate_round);
                w.u64(*streams_fp);
            }
            Message::Join {
                device_id,
                devices,
                shard_len,
                config_fp,
                member_epoch,
                uplink,
                downlink,
                sync,
                streams_fp,
            } => {
                w.u32(*device_id);
                w.u32(*devices);
                w.u32(*shard_len);
                w.u64(*config_fp);
                w.u64(*streams_fp);
                w.u32(*member_epoch);
                write_str(w, uplink);
                write_str(w, downlink);
                write_str(w, sync);
            }
            Message::JoinAck { device_id, round, member_epoch, rounds, agg_every } => {
                w.u32(*device_id);
                w.u32(*round);
                w.u32(*member_epoch);
                w.u32(*rounds);
                w.u32(*agg_every);
            }
            Message::Catchup { round, device_id, spec_epoch, payload } => {
                w.u32(*round);
                w.u32(*device_id);
                w.u32(*spec_epoch);
                write_blob(w, payload);
            }
            Message::Leave { device_id, reason } => {
                w.u32(*device_id);
                write_str(w, reason);
            }
        }
    }

    fn read_body(ty: u8, r: &mut ByteReader) -> Result<Message, String> {
        let msg = match ty {
            msg_type::HELLO => Message::Hello {
                device_id: r.u32()?,
                devices: r.u32()?,
                shard_len: r.u32()?,
                config_fp: r.u64()?,
                streams_fp: r.u64()?,
                uplink: read_str(r)?,
                downlink: read_str(r)?,
                sync: read_str(r)?,
            },
            msg_type::HELLO_ACK => Message::HelloAck {
                device_id: r.u32()?,
                rounds: r.u32()?,
                agg_every: r.u32()?,
            },
            msg_type::ROUND_OPEN => Message::RoundOpen {
                round: r.u32()?,
                sync: r.u8()? != 0,
            },
            msg_type::ACTIVATIONS => {
                let round = r.u32()?;
                let device_id = r.u32()?;
                let n = r.u32()? as usize;
                if n > MAX_LABELS {
                    return Err(format!("frame claims {n} labels (cap {MAX_LABELS})"));
                }
                let mut labels = Vec::with_capacity(n);
                for _ in 0..n {
                    labels.push(r.u32()? as i32);
                }
                let payload = read_blob(r)?;
                Message::Activations { round, device_id, labels, payload }
            }
            msg_type::GRADIENTS => Message::Gradients {
                round: r.u32()?,
                device_id: r.u32()?,
                loss: r.f32()?,
                payload: read_blob(r)?,
            },
            msg_type::MODEL_SYNC => Message::ModelSync {
                round: r.u32()?,
                device_id: r.u32()?,
                payload: read_blob(r)?,
            },
            msg_type::SHUTDOWN => Message::Shutdown { reason: read_str(r)? },
            msg_type::SHARD_HELLO => Message::ShardHello {
                shard_id: r.u32()?,
                shards: r.u32()?,
                sync_every: r.u32()?,
                config_fp: r.u64()?,
                weight: r.u64()?,
            },
            msg_type::SHARD_SYNC => Message::ShardSync {
                epoch: r.u32()?,
                shard_id: r.u32()?,
                client: read_blob(r)?,
                server: read_blob(r)?,
                metrics: read_blob(r)?,
            },
            msg_type::SPEC_UPDATE => Message::SpecUpdate {
                activate_round: r.u32()?,
                streams_fp: r.u64()?,
                uplink: read_str(r)?,
                downlink: read_str(r)?,
                sync: read_str(r)?,
            },
            msg_type::SPEC_UPDATE_ACK => Message::SpecUpdateAck {
                activate_round: r.u32()?,
                streams_fp: r.u64()?,
            },
            msg_type::JOIN => Message::Join {
                device_id: r.u32()?,
                devices: r.u32()?,
                shard_len: r.u32()?,
                config_fp: r.u64()?,
                streams_fp: r.u64()?,
                member_epoch: r.u32()?,
                uplink: read_str(r)?,
                downlink: read_str(r)?,
                sync: read_str(r)?,
            },
            msg_type::JOIN_ACK => Message::JoinAck {
                device_id: r.u32()?,
                round: r.u32()?,
                member_epoch: r.u32()?,
                rounds: r.u32()?,
                agg_every: r.u32()?,
            },
            msg_type::CATCHUP => Message::Catchup {
                round: r.u32()?,
                device_id: r.u32()?,
                spec_epoch: r.u32()?,
                payload: read_blob(r)?,
            },
            msg_type::LEAVE => Message::Leave {
                device_id: r.u32()?,
                reason: read_str(r)?,
            },
            other => return Err(format!("unknown message type {other}")),
        };
        Ok(msg)
    }

    /// Serialize to one complete frame (header + body).
    pub fn encode_frame(&self) -> Vec<u8> {
        let mut body = ByteWriter::new();
        self.write_body(&mut body);
        let body = body.finish();
        // hard check: past this cap the receiver rejects the frame anyway,
        // and past u32::MAX the length prefix would wrap and desync the
        // stream — fail loudly at the source instead
        assert!(
            body.len() <= MAX_FRAME_BODY,
            "{} body is {} bytes (cap {MAX_FRAME_BODY})",
            self.type_name(),
            body.len()
        );
        let mut w = ByteWriter::with_capacity(FRAME_HEADER_BYTES + body.len());
        w.u32(FRAME_MAGIC);
        w.u8(PROTO_VERSION);
        w.u8(self.type_id());
        w.u32(body.len() as u32);
        w.bytes(&body);
        w.finish()
    }

    /// Serialize the frame header plus the body *prefix* — everything up
    /// to and including the payload blob's length word — into `w`
    /// (clearing it first), returning the borrowed payload slice that
    /// completes the frame. `prefix ++ payload` is byte-identical to
    /// [`Message::encode_frame`] (pinned by a golden test), which lets the
    /// event loop send broadcast payloads via vectored writes without
    /// assembling a per-device copy of header + payload.
    ///
    /// Only the three payload-bearing types (`Activations`, `Gradients`,
    /// `ModelSync`) have this split form; other types return `None` and
    /// callers fall back to [`Message::encode_frame`].
    pub fn encode_frame_prefix<'a>(&'a self, w: &mut ByteWriter) -> Option<&'a [u8]> {
        let (prefix_len, payload): (usize, &[u8]) = match self {
            Message::Activations { labels, payload, .. } => {
                (4 + 4 + 4 + labels.len() * 4 + 4, payload)
            }
            Message::Gradients { payload, .. } => (4 + 4 + 4 + 4, payload),
            Message::ModelSync { payload, .. } => (4 + 4 + 4, payload),
            _ => return None,
        };
        let body_len = prefix_len + payload.len();
        assert!(
            body_len <= MAX_FRAME_BODY,
            "{} body is {body_len} bytes (cap {MAX_FRAME_BODY})",
            self.type_name()
        );
        w.clear();
        w.reserve(FRAME_HEADER_BYTES + prefix_len);
        w.u32(FRAME_MAGIC);
        w.u8(PROTO_VERSION);
        w.u8(self.type_id());
        w.u32(body_len as u32);
        match self {
            Message::Activations { round, device_id, labels, payload } => {
                w.u32(*round);
                w.u32(*device_id);
                w.u32(labels.len() as u32);
                for &l in labels {
                    w.u32(l as u32);
                }
                w.u32(payload.len() as u32);
            }
            Message::Gradients { round, device_id, loss, payload } => {
                w.u32(*round);
                w.u32(*device_id);
                w.f32(*loss);
                w.u32(payload.len() as u32);
            }
            Message::ModelSync { round, device_id, payload } => {
                w.u32(*round);
                w.u32(*device_id);
                w.u32(payload.len() as u32);
            }
            _ => unreachable!("prefix_len matched a payload-bearing type"),
        }
        Some(payload)
    }

    /// Parse exactly one frame from `buf`; trailing bytes are an error.
    pub fn decode_frame(buf: &[u8]) -> Result<Message, String> {
        let mut r = ByteReader::new(buf);
        let (ty, body_len) = read_frame_header(&mut r)?;
        if r.remaining() != body_len {
            return Err(format!(
                "frame length mismatch: header says {body_len} body bytes, have {}",
                r.remaining()
            ));
        }
        decode_body(ty, &buf[FRAME_HEADER_BYTES..])
    }
}

/// Decode one complete frame body, enforcing the trailing-garbage check —
/// the single implementation behind the blocking reader, the incremental
/// [`FrameDecoder`], and [`Message::decode_frame`], so the device side and
/// the poll server can never disagree on what constitutes a valid frame.
fn decode_body(ty: u8, body: &[u8]) -> Result<Message, String> {
    let mut r = ByteReader::new(body);
    let msg = Message::read_body(ty, &mut r)?;
    if r.remaining() != 0 {
        return Err(format!("{} bytes of trailing garbage after body", r.remaining()));
    }
    Ok(msg)
}

fn read_frame_header(r: &mut ByteReader) -> Result<(u8, usize), String> {
    let magic = r.u32()?;
    if magic != FRAME_MAGIC {
        return Err(format!("bad frame magic {magic:#010x}"));
    }
    let version = r.u8()?;
    if version != PROTO_VERSION {
        // name both versions: a v5 peer (pre-membership) dialing a v6 node
        // must learn exactly which side is stale, not just "unsupported"
        return Err(format!(
            "unsupported protocol version: peer speaks v{version}, this build \
             speaks v{PROTO_VERSION}"
        ));
    }
    let ty = r.u8()?;
    let body_len = r.u32()? as usize;
    if body_len > MAX_FRAME_BODY {
        return Err(format!("frame claims {body_len} body bytes (cap {MAX_FRAME_BODY})"));
    }
    Ok((ty, body_len))
}

/// Outcome of reading one frame from a blocking byte stream.
#[derive(Debug)]
pub enum FrameRead {
    /// A complete frame: the decoded message + total framed byte count.
    Frame(Message, usize),
    /// The stream ended cleanly *between* frames (0 bytes of the next
    /// header had arrived) — a peer hang-up, not a protocol violation.
    Eof,
}

/// Stream-read failures, split so transports can type their errors: `Io`
/// is the socket failing (reset, mid-frame truncation), `Protocol` is the
/// peer sending bytes that violate the framing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    Io(String),
    Protocol(String),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Io(m) | FrameError::Protocol(m) => write!(f, "{m}"),
        }
    }
}

/// Read exactly `buf.len()` bytes, distinguishing "closed before the first
/// byte" (`Ok(false)`) from "closed mid-way" (`Err`).
fn read_exact_or_eof(
    stream: &mut impl std::io::Read,
    buf: &mut [u8],
    what: &str,
) -> Result<bool, FrameError> {
    let mut got = 0usize;
    while got < buf.len() {
        match stream.read(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(false);
                }
                return Err(FrameError::Io(format!(
                    "connection closed mid-{what} ({got}/{} bytes)",
                    buf.len()
                )));
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError::Io(format!("read frame {what}: {e}"))),
        }
    }
    Ok(true)
}

/// Read one frame from a blocking byte stream, surfacing a clean peer
/// hang-up as [`FrameRead::Eof`]. The body-length cap is enforced before
/// the body buffer is allocated.
pub fn read_frame_or_eof(
    stream: &mut impl std::io::Read,
) -> Result<FrameRead, FrameError> {
    let mut head = [0u8; FRAME_HEADER_BYTES];
    if !read_exact_or_eof(stream, &mut head, "header")? {
        return Ok(FrameRead::Eof);
    }
    let mut r = ByteReader::new(&head);
    let (ty, body_len) = read_frame_header(&mut r).map_err(FrameError::Protocol)?;
    let mut body = vec![0u8; body_len];
    if body_len > 0 && !read_exact_or_eof(stream, &mut body, "body")? {
        return Err(FrameError::Io(format!(
            "connection closed before {body_len}-byte body"
        )));
    }
    let msg = decode_body(ty, &body).map_err(FrameError::Protocol)?;
    Ok(FrameRead::Frame(msg, FRAME_HEADER_BYTES + body_len))
}

/// Read one frame from a byte stream (blocking). Returns the message and
/// the total frame size in bytes; a clean EOF is an error here — use
/// [`read_frame_or_eof`] to react to hang-ups.
pub fn read_frame(stream: &mut impl std::io::Read) -> Result<(Message, usize), String> {
    match read_frame_or_eof(stream) {
        Ok(FrameRead::Frame(msg, n)) => Ok((msg, n)),
        Ok(FrameRead::Eof) => Err("read frame header: connection closed".to_string()),
        Err(e) => Err(e.to_string()),
    }
}

/// Write one frame to a byte stream. Returns the frame size in bytes.
pub fn write_frame(stream: &mut impl std::io::Write, msg: &Message) -> Result<usize, String> {
    let frame = msg.encode_frame();
    stream
        .write_all(&frame)
        .map_err(|e| format!("write {} frame: {e}", msg.type_name()))?;
    stream.flush().map_err(|e| format!("flush {} frame: {e}", msg.type_name()))?;
    Ok(frame.len())
}

/// Retained ring capacity after a decoder drains empty: large enough that
/// steady-state traffic never reallocates, small enough that 10k idle
/// connections don't pin the peak capacity one giant frame ever forced.
pub const DECODER_RETAIN_CAP: usize = 128 * 1024;

/// Incremental frame decoder for non-blocking sockets, backed by a
/// compacting ring the socket reads **directly into**: grab a spare-space
/// slot with [`read_slot`], `read(2)` into it, [`commit`] the byte count,
/// then pop frames. Two decode modes:
///
/// * [`next_view`] — zero-copy: yields a [`FrameView`] whose body borrows
///   the ring in place (no drain memmove, no body materialization).
/// * [`next`] — compatibility: decodes to an owned [`Message`].
///
/// [`feed`] remains for callers holding bytes in their own buffer (it
/// copies into the ring). Partial frames stay buffered between poll
/// wake-ups; length caps are enforced from the header alone, before the
/// body has arrived. After extraction, [`reclaim`] resets the ring and
/// drops capacity beyond [`DECODER_RETAIN_CAP`] so one giant frame doesn't
/// pin memory forever.
///
/// [`read_slot`]: FrameDecoder::read_slot
/// [`commit`]: FrameDecoder::commit
/// [`next_view`]: FrameDecoder::next_view
/// [`next`]: FrameDecoder::next
/// [`feed`]: FrameDecoder::feed
/// [`reclaim`]: FrameDecoder::reclaim
#[derive(Debug, Default)]
pub struct FrameDecoder {
    /// storage; `len()` is the usable size (zero-filled on growth only)
    buf: Vec<u8>,
    /// first unconsumed byte
    head: usize,
    /// one past the last valid byte
    tail: usize,
}

impl FrameDecoder {
    pub fn new() -> FrameDecoder {
        FrameDecoder::default()
    }

    /// Append raw stream bytes (copies into the ring; socket readers
    /// should prefer [`FrameDecoder::read_slot`] + [`FrameDecoder::commit`]
    /// to skip this copy).
    pub fn feed(&mut self, bytes: &[u8]) {
        let n = bytes.len();
        if n == 0 {
            return;
        }
        self.read_slot(n)[..n].copy_from_slice(bytes);
        self.commit(n);
    }

    /// Mutable spare space at the ring's tail, at least `min` bytes long
    /// (often longer — callers may fill any prefix of it). Compacts
    /// buffered bytes to the front or grows the storage as needed; follow
    /// with [`FrameDecoder::commit`] for however many bytes were written.
    pub fn read_slot(&mut self, min: usize) -> &mut [u8] {
        if self.tail + min > self.buf.len() {
            if self.head > 0 {
                // compact: slide the unconsumed window to the front
                self.buf.copy_within(self.head..self.tail, 0);
                self.tail -= self.head;
                self.head = 0;
            }
            if self.tail + min > self.buf.len() {
                let need = (self.tail + min).next_power_of_two().max(4096);
                self.buf.resize(need, 0);
            }
        }
        &mut self.buf[self.tail..]
    }

    /// Mark `n` bytes of the last [`FrameDecoder::read_slot`] as filled.
    pub fn commit(&mut self, n: usize) {
        self.tail += n;
        debug_assert!(self.tail <= self.buf.len(), "commit past the read slot");
    }

    /// Bytes buffered but not yet returned as a frame (0 means the stream
    /// is at a frame boundary — a hang-up here is a clean close).
    pub fn buffered(&self) -> usize {
        self.tail - self.head
    }

    /// Current ring storage footprint in bytes (drives the
    /// `slacc_conn_buf_bytes` gauge).
    pub fn capacity(&self) -> usize {
        self.buf.capacity()
    }

    /// Pop the next complete frame as a borrowed in-place view, if fully
    /// buffered. The frame's bytes are consumed immediately — decode the
    /// view before the next ring operation. Header-parse errors consume
    /// nothing (the connection is torn down on error anyway).
    pub fn next_view(&mut self) -> Result<Option<FrameView<'_>>, String> {
        let avail = self.tail - self.head;
        if avail < FRAME_HEADER_BYTES {
            return Ok(None);
        }
        let mut r = ByteReader::new(&self.buf[self.head..self.tail]);
        let (ty, body_len) = read_frame_header(&mut r)?;
        let total = FRAME_HEADER_BYTES + body_len;
        if avail < total {
            return Ok(None);
        }
        let start = self.head;
        self.head += total;
        Ok(Some(FrameView {
            ty,
            body: &self.buf[start + FRAME_HEADER_BYTES..start + total],
            total,
        }))
    }

    /// Pop the next complete frame as an owned message plus its framed
    /// size. Compatibility wrapper over [`FrameDecoder::next_view`];
    /// reclaims ring capacity when the buffer drains.
    pub fn next(&mut self) -> Result<Option<(Message, usize)>, String> {
        let popped = match self.next_view()? {
            Some(view) => {
                let total = view.total();
                let msg = view.decode()?;
                Some((msg, total))
            }
            None => None,
        };
        if popped.is_some() {
            self.reclaim();
        }
        Ok(popped)
    }

    /// If the ring is empty, rewind it and drop storage beyond
    /// [`DECODER_RETAIN_CAP`]. Call after frame extraction; a no-op while
    /// a partial frame is still buffered.
    pub fn reclaim(&mut self) {
        if self.head == self.tail {
            self.head = 0;
            self.tail = 0;
            if self.buf.len() > DECODER_RETAIN_CAP {
                self.buf.truncate(DECODER_RETAIN_CAP);
                self.buf.shrink_to_fit();
            }
        }
    }
}

/// One complete frame borrowed in place from a [`FrameDecoder`]'s ring:
/// the zero-copy decode mode. [`FrameView::body`] aliases the connection's
/// read buffer, so consumers that only need the raw payload bytes (stats,
/// forwarding, checksums) touch them without a single copy;
/// [`FrameView::decode`] materializes an owned [`Message`] on demand.
#[derive(Debug)]
pub struct FrameView<'a> {
    ty: u8,
    body: &'a [u8],
    total: usize,
}

impl<'a> FrameView<'a> {
    /// Wire type id (see [`msg_type`]).
    pub fn type_id(&self) -> u8 {
        self.ty
    }

    /// The frame body, borrowed from the decode ring.
    pub fn body(&self) -> &'a [u8] {
        self.body
    }

    /// Total framed size (header + body) in bytes.
    pub fn total(&self) -> usize {
        self.total
    }

    /// Decode to an owned [`Message`], enforcing the same trailing-garbage
    /// check as every other decode path.
    pub fn decode(&self) -> Result<Message, String> {
        decode_body(self.ty, self.body)
    }
}

fn write_str(w: &mut ByteWriter, s: &str) {
    w.u32(s.len() as u32);
    w.bytes(s.as_bytes());
}

fn read_str(r: &mut ByteReader) -> Result<String, String> {
    let n = r.u32()? as usize;
    if n > MAX_STR {
        return Err(format!("frame claims {n}-byte string (cap {MAX_STR})"));
    }
    let raw = r.bytes(n)?;
    String::from_utf8(raw.to_vec()).map_err(|_| "string field is not UTF-8".to_string())
}

fn write_blob(w: &mut ByteWriter, b: &[u8]) {
    w.u32(b.len() as u32);
    w.bytes(b);
}

fn read_blob(r: &mut ByteReader) -> Result<Vec<u8>, String> {
    let n = r.u32()? as usize;
    if n > MAX_FRAME_BODY {
        return Err(format!("frame claims {n}-byte payload (cap {MAX_FRAME_BODY})"));
    }
    Ok(r.bytes(n)?.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn samples() -> Vec<Message> {
        vec![
            Message::Hello {
                device_id: 3,
                devices: 4,
                shard_len: 128,
                config_fp: 0xfeed_beef_dead_cafe,
                uplink: "slacc".into(),
                downlink: "uniform8".into(),
                sync: "identity".into(),
                streams_fp: 0x0123_4567_89ab_cdef,
            },
            Message::HelloAck { device_id: 3, rounds: 300, agg_every: 1 },
            Message::RoundOpen { round: 7, sync: true },
            Message::Activations {
                round: 7,
                device_id: 3,
                labels: vec![0, 5, -1, 6],
                payload: vec![1, 2, 3, 4, 5],
            },
            Message::Gradients {
                round: 7,
                device_id: 3,
                loss: 0.25,
                payload: vec![9; 17],
            },
            Message::ModelSync {
                round: 7,
                device_id: 3,
                payload: vec![42; 33],
            },
            Message::Shutdown { reason: "done".into() },
            Message::ShardHello {
                shard_id: 1,
                shards: 2,
                sync_every: 4,
                config_fp: 0xdead_beef_0000_0001,
                weight: 1024,
            },
            Message::ShardSync {
                epoch: 3,
                shard_id: 1,
                client: vec![7; 12],
                server: vec![8; 20],
                metrics: vec![1, 0, 0, 0, 0],
            },
            Message::SpecUpdate {
                activate_round: 12,
                uplink: "uniform4".into(),
                downlink: "identity".into(),
                sync: "identity".into(),
                streams_fp: 0xfaca_de00_1234_5678,
            },
            Message::SpecUpdateAck {
                activate_round: 12,
                streams_fp: 0xfaca_de00_1234_5678,
            },
            Message::Join {
                device_id: 2,
                devices: 4,
                shard_len: 128,
                config_fp: 0xfeed_beef_dead_cafe,
                member_epoch: 1,
                uplink: "slacc".into(),
                downlink: "uniform8".into(),
                sync: "identity".into(),
                streams_fp: 0x0123_4567_89ab_cdef,
            },
            Message::JoinAck {
                device_id: 2,
                round: 41,
                member_epoch: 2,
                rounds: 300,
                agg_every: 1,
            },
            Message::Catchup {
                round: 41,
                device_id: 2,
                spec_epoch: 0,
                payload: vec![13; 29],
            },
            Message::Leave { device_id: 2, reason: "battery".into() },
        ]
    }

    #[test]
    fn every_message_roundtrips() {
        for m in samples() {
            let frame = m.encode_frame();
            let back = Message::decode_frame(&frame)
                .unwrap_or_else(|e| panic!("{}: {e}", m.type_name()));
            assert_eq!(back, m, "{}", m.type_name());
        }
    }

    #[test]
    fn stream_roundtrip_and_size() {
        let mut buf = Vec::new();
        let mut sizes = Vec::new();
        for m in samples() {
            sizes.push(write_frame(&mut buf, &m).unwrap());
        }
        let mut cur = std::io::Cursor::new(buf);
        for (m, want) in samples().into_iter().zip(sizes) {
            let (back, n) = read_frame(&mut cur).unwrap();
            assert_eq!(back, m);
            assert_eq!(n, want);
        }
    }

    #[test]
    fn truncated_frames_rejected() {
        for m in samples() {
            let frame = m.encode_frame();
            // every strict prefix must fail, never panic
            for cut in [0, 1, FRAME_HEADER_BYTES - 1, FRAME_HEADER_BYTES, frame.len() - 1] {
                if cut < frame.len() {
                    assert!(
                        Message::decode_frame(&frame[..cut]).is_err(),
                        "{} cut at {cut}",
                        m.type_name()
                    );
                }
            }
        }
    }

    #[test]
    fn bad_magic_version_type_rejected() {
        let good = Message::RoundOpen { round: 1, sync: false }.encode_frame();
        let mut bad = good.clone();
        bad[0] ^= 0xff; // magic
        assert!(Message::decode_frame(&bad).is_err());
        let mut bad = good.clone();
        bad[4] = 99; // version
        let err = Message::decode_frame(&bad).unwrap_err();
        // the rejection must name BOTH versions (a stale v4 peer needs to
        // learn which side to upgrade)
        assert!(err.contains("v99"), "{err}");
        assert!(err.contains(&format!("v{PROTO_VERSION}")), "{err}");
        let mut bad = good.clone();
        bad[5] = 200; // type
        assert!(Message::decode_frame(&bad).is_err());
    }

    #[test]
    fn oversized_body_rejected() {
        let mut w = ByteWriter::new();
        w.u32(FRAME_MAGIC);
        w.u8(PROTO_VERSION);
        w.u8(msg_type::SHUTDOWN);
        w.u32((MAX_FRAME_BODY + 1) as u32);
        let frame = w.finish();
        assert!(Message::decode_frame(&frame).is_err());
        let mut cur = std::io::Cursor::new(frame);
        assert!(read_frame(&mut cur).is_err());
    }

    #[test]
    fn hostile_inner_lengths_rejected() {
        // a Shutdown whose string length claims 1 GiB
        let mut body = ByteWriter::new();
        body.u32(u32::MAX);
        let body = body.finish();
        let mut w = ByteWriter::new();
        w.u32(FRAME_MAGIC);
        w.u8(PROTO_VERSION);
        w.u8(msg_type::SHUTDOWN);
        w.u32(body.len() as u32);
        w.bytes(&body);
        assert!(Message::decode_frame(&w.finish()).is_err());
        // a ModelSync whose blob length claims ~4 GiB with a 12-byte body
        let mut body = ByteWriter::new();
        body.u32(0); // round
        body.u32(0); // device
        body.u32(u32::MAX); // blob length
        let body = body.finish();
        let mut w = ByteWriter::new();
        w.u32(FRAME_MAGIC);
        w.u8(PROTO_VERSION);
        w.u8(msg_type::MODEL_SYNC);
        w.u32(body.len() as u32);
        w.bytes(&body);
        assert!(Message::decode_frame(&w.finish()).is_err());
    }

    #[test]
    fn trailing_garbage_rejected() {
        let mut frame = Message::RoundOpen { round: 1, sync: false }.encode_frame();
        frame.push(0);
        assert!(Message::decode_frame(&frame).is_err());
    }

    #[test]
    fn clean_eof_is_typed_midframe_is_error() {
        // empty stream: clean EOF
        let mut cur = std::io::Cursor::new(Vec::<u8>::new());
        assert!(matches!(read_frame_or_eof(&mut cur), Ok(FrameRead::Eof)));
        // half a header: an I/O error, not a clean close
        let frame = Message::RoundOpen { round: 1, sync: true }.encode_frame();
        let mut cur = std::io::Cursor::new(frame[..3].to_vec());
        assert!(matches!(read_frame_or_eof(&mut cur), Err(FrameError::Io(_))));
        // header but truncated body: also an I/O error
        let mut cur = std::io::Cursor::new(frame[..frame.len() - 1].to_vec());
        assert!(matches!(read_frame_or_eof(&mut cur), Err(FrameError::Io(_))));
    }

    #[test]
    fn frame_decoder_reassembles_chunked_streams() {
        let mut wire = Vec::new();
        for m in samples() {
            wire.extend_from_slice(&m.encode_frame());
        }
        // feed in awkward 3-byte chunks; every message must come out intact
        let mut dec = FrameDecoder::new();
        let mut out = Vec::new();
        for chunk in wire.chunks(3) {
            dec.feed(chunk);
            while let Some((msg, _)) = dec.next().unwrap() {
                out.push(msg);
            }
        }
        assert_eq!(out, samples());
        assert_eq!(dec.buffered(), 0);
    }

    #[test]
    fn old_proto_v5_frame_rejected_by_name() {
        // a pre-membership peer: same framing, version byte 5
        let mut frame = Message::RoundOpen { round: 0, sync: false }.encode_frame();
        frame[4] = 5;
        let err = Message::decode_frame(&frame).unwrap_err();
        assert!(err.contains("v5"), "{err}");
        assert!(err.contains("v6"), "{err}");
        let mut dec = FrameDecoder::new();
        dec.feed(&frame);
        assert!(dec.next().unwrap_err().contains("v5"));
    }

    /// Systematic hostile-envelope fuzz for the v5 renegotiation frames:
    /// every strict prefix truncation and every single-bit header flip of
    /// a valid SpecUpdate/SpecUpdateAck must be rejected, never panic and
    /// never decode to the original message.
    #[test]
    fn spec_update_prefix_truncations_and_header_bitflips_rejected() {
        let frames = [
            Message::SpecUpdate {
                activate_round: 9,
                uplink: "ef:slacc".into(),
                downlink: "uniform8".into(),
                sync: "identity".into(),
                streams_fp: 0x1122_3344_5566_7788,
            }
            .encode_frame(),
            Message::SpecUpdateAck {
                activate_round: 9,
                streams_fp: 0x1122_3344_5566_7788,
            }
            .encode_frame(),
        ];
        for frame in &frames {
            for cut in 0..frame.len() {
                assert!(
                    Message::decode_frame(&frame[..cut]).is_err(),
                    "prefix of {cut}/{} bytes accepted",
                    frame.len()
                );
            }
            let original = Message::decode_frame(frame).unwrap();
            for byte in 0..FRAME_HEADER_BYTES {
                for bit in 0..8 {
                    let mut bad = frame.clone();
                    bad[byte] ^= 1 << bit;
                    match Message::decode_frame(&bad) {
                        Err(_) => {}
                        Ok(m) => panic!(
                            "header bit {bit} of byte {byte} flipped, still \
                             decoded as {} (original {})",
                            m.type_name(),
                            original.type_name()
                        ),
                    }
                }
            }
        }
    }

    /// Same hostile-envelope fuzz for the v6 membership frames: every
    /// strict prefix truncation and every single-bit header flip of a
    /// valid Join/JoinAck/Catchup/Leave must be rejected, never panic and
    /// never decode to the original message.
    #[test]
    fn join_family_prefix_truncations_and_header_bitflips_rejected() {
        let frames = [
            Message::Join {
                device_id: 7,
                devices: 16,
                shard_len: 64,
                config_fp: 0xaaaa_bbbb_cccc_dddd,
                member_epoch: 3,
                uplink: "ef:slacc".into(),
                downlink: "uniform8".into(),
                sync: "identity".into(),
                streams_fp: 0x1122_3344_5566_7788,
            }
            .encode_frame(),
            Message::JoinAck {
                device_id: 7,
                round: 19,
                member_epoch: 4,
                rounds: 300,
                agg_every: 1,
            }
            .encode_frame(),
            Message::Catchup { round: 19, device_id: 7, spec_epoch: 1, payload: vec![5; 40] }
                .encode_frame(),
            Message::Leave { device_id: 7, reason: "signal lost".into() }.encode_frame(),
        ];
        for frame in &frames {
            for cut in 0..frame.len() {
                assert!(
                    Message::decode_frame(&frame[..cut]).is_err(),
                    "prefix of {cut}/{} bytes accepted",
                    frame.len()
                );
            }
            let original = Message::decode_frame(frame).unwrap();
            for byte in 0..FRAME_HEADER_BYTES {
                for bit in 0..8 {
                    let mut bad = frame.clone();
                    bad[byte] ^= 1 << bit;
                    match Message::decode_frame(&bad) {
                        Err(_) => {}
                        Ok(m) => panic!(
                            "header bit {bit} of byte {byte} flipped, still \
                             decoded as {} (original {})",
                            m.type_name(),
                            original.type_name()
                        ),
                    }
                }
            }
        }
    }

    #[test]
    fn frame_decoder_rejects_bad_magic() {
        let mut frame = Message::RoundOpen { round: 1, sync: false }.encode_frame();
        frame[0] ^= 0xff;
        let mut dec = FrameDecoder::new();
        dec.feed(&frame);
        assert!(dec.next().is_err());
    }

    #[test]
    fn encode_frame_prefix_matches_encode_frame_byte_for_byte() {
        let payload: Vec<u8> = (0..613u32).map(|i| (i * 7) as u8).collect();
        let msgs = [
            Message::Activations {
                round: 3,
                device_id: 9,
                labels: vec![0, 5, 2, 7],
                payload: payload.clone(),
            },
            Message::Activations {
                round: 0,
                device_id: 0,
                labels: vec![],
                payload: vec![],
            },
            Message::Gradients { round: 11, device_id: 4, loss: 0.625, payload: payload.clone() },
            Message::ModelSync { round: 2, device_id: 1, payload },
        ];
        let mut w = ByteWriter::new();
        for m in &msgs {
            let tail = m.encode_frame_prefix(&mut w).expect("payload-bearing type");
            let mut assembled = w.as_slice().to_vec();
            assembled.extend_from_slice(tail);
            assert_eq!(
                assembled,
                m.encode_frame(),
                "prefix ++ payload diverged for {}",
                m.type_name()
            );
        }
    }

    #[test]
    fn encode_frame_prefix_declines_payload_free_types() {
        let mut w = ByteWriter::new();
        assert!(Message::RoundOpen { round: 1, sync: false }
            .encode_frame_prefix(&mut w)
            .is_none());
        assert!(Message::Shutdown { reason: "done".into() }
            .encode_frame_prefix(&mut w)
            .is_none());
    }

    #[test]
    fn decoder_read_slot_commit_reassembles_dripped_frames() {
        let msgs = [
            Message::RoundOpen { round: 7, sync: true },
            Message::Gradients { round: 7, device_id: 2, loss: 1.5, payload: vec![9; 300] },
            Message::Shutdown { reason: "bye".into() },
        ];
        let mut wire = Vec::new();
        for m in &msgs {
            wire.extend_from_slice(&m.encode_frame());
        }
        // drip the wire bytes through read_slot/commit in awkward chunks
        let mut dec = FrameDecoder::new();
        let mut got = Vec::new();
        for chunk in wire.chunks(13) {
            let slot = dec.read_slot(chunk.len());
            slot[..chunk.len()].copy_from_slice(chunk);
            dec.commit(chunk.len());
            while let Some((m, _)) = dec.next().unwrap() {
                got.push(m);
            }
        }
        assert_eq!(got.len(), msgs.len());
        for (a, b) in got.iter().zip(msgs.iter()) {
            assert_eq!(a.encode_frame(), b.encode_frame());
        }
        assert_eq!(dec.buffered(), 0);
    }

    #[test]
    fn next_view_yields_borrowed_bodies_in_place() {
        let m = Message::ModelSync { round: 5, device_id: 3, payload: vec![0xAB; 64] };
        let frame = m.encode_frame();
        let mut dec = FrameDecoder::new();
        dec.feed(&frame);
        let view = dec.next_view().unwrap().expect("complete frame buffered");
        assert_eq!(view.type_id(), msg_type::MODEL_SYNC);
        assert_eq!(view.total(), frame.len());
        assert_eq!(view.body(), &frame[FRAME_HEADER_BYTES..]);
        let decoded = view.decode().unwrap();
        assert_eq!(decoded.encode_frame(), frame);
        assert_eq!(dec.buffered(), 0);
        assert!(dec.next_view().unwrap().is_none());
    }

    #[test]
    fn reclaim_drops_capacity_pinned_by_a_giant_frame() {
        let big = Message::ModelSync {
            round: 0,
            device_id: 0,
            payload: vec![7; 4 * 1024 * 1024],
        };
        let mut dec = FrameDecoder::new();
        dec.feed(&big.encode_frame());
        assert!(dec.capacity() > DECODER_RETAIN_CAP);
        let (_, _) = dec.next().unwrap().expect("giant frame decodes");
        // next() reclaims on drain: retained storage is back under the cap
        assert!(
            dec.capacity() <= DECODER_RETAIN_CAP,
            "retained {} bytes (cap {DECODER_RETAIN_CAP})",
            dec.capacity()
        );
        // and the decoder still works after the shrink
        let small = Message::RoundOpen { round: 1, sync: false }.encode_frame();
        dec.feed(&small);
        assert!(dec.next().unwrap().is_some());
    }

    #[test]
    fn reclaim_is_a_noop_mid_frame() {
        let frame = Message::RoundOpen { round: 2, sync: false }.encode_frame();
        let mut dec = FrameDecoder::new();
        dec.feed(&frame[..4]);
        dec.reclaim();
        assert_eq!(dec.buffered(), 4, "partial frame must survive reclaim");
        dec.feed(&frame[4..]);
        assert!(dec.next().unwrap().is_some());
    }
}
