//! TCP transport: framed SL protocol over `std::net` streams.
//!
//! Two modes:
//!
//! * **direct** (device side, [`TcpTransport::connect`]) — blocking
//!   request/response reads on the caller's thread; the device loop is
//!   strictly lock-step so no reader thread is needed.
//! * **threaded** (server side, [`TcpTransport::accept`]) — one reader
//!   thread per accepted connection decodes frames into an in-memory
//!   channel, so the next device's uplink is parsed while the server is
//!   still stepping the previous one. The PJRT engine never crosses a
//!   thread boundary: only decoded [`Message`] values do.

use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use super::proto::{self, Message};
use super::{Transport, WireStats};

enum Reader {
    Direct(TcpStream),
    Threaded(mpsc::Receiver<Result<(Message, usize), String>>),
}

/// One framed TCP connection (either end).
pub struct TcpTransport {
    writer: TcpStream,
    reader: Reader,
    stats: WireStats,
    peer: String,
}

impl TcpTransport {
    /// Client side: connect once.
    pub fn connect(addr: &str) -> Result<TcpTransport, String> {
        let stream =
            TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
        Self::direct(stream)
    }

    /// Client side: retry until the server is listening (covers the
    /// serve/device startup race in scripts and examples).
    pub fn connect_retry(
        addr: &str,
        attempts: u32,
        delay: Duration,
    ) -> Result<TcpTransport, String> {
        let mut last = String::new();
        for _ in 0..attempts.max(1) {
            match Self::connect(addr) {
                Ok(t) => return Ok(t),
                Err(e) => last = e,
            }
            thread::sleep(delay);
        }
        Err(format!("{last} (after {attempts} attempts)"))
    }

    fn direct(stream: TcpStream) -> Result<TcpTransport, String> {
        let peer = peer_label(&stream);
        stream.set_nodelay(true).map_err(|e| format!("set_nodelay: {e}"))?;
        let reader = stream.try_clone().map_err(|e| format!("clone stream: {e}"))?;
        Ok(TcpTransport {
            writer: stream,
            reader: Reader::Direct(reader),
            stats: WireStats::default(),
            peer,
        })
    }

    /// Server side: accept one connection and spawn its reader thread.
    pub fn accept(listener: &TcpListener) -> Result<TcpTransport, String> {
        let (stream, _) = listener.accept().map_err(|e| format!("accept: {e}"))?;
        let peer = peer_label(&stream);
        stream.set_nodelay(true).map_err(|e| format!("set_nodelay: {e}"))?;
        let mut read_half =
            stream.try_clone().map_err(|e| format!("clone stream: {e}"))?;
        // bounded: the protocol is lock-step, so a couple of frames of
        // read-ahead is all pipelining needs — and a peer that floods valid
        // frames blocks in our TCP window instead of ballooning server RAM
        let (tx, rx) = mpsc::sync_channel(2);
        thread::Builder::new()
            .name(format!("slacc-rx-{peer}"))
            .spawn(move || loop {
                match proto::read_frame(&mut read_half) {
                    Ok(item) => {
                        if tx.send(Ok(item)).is_err() {
                            break; // transport dropped
                        }
                    }
                    Err(e) => {
                        let _ = tx.send(Err(e));
                        break;
                    }
                }
            })
            .map_err(|e| format!("spawn reader thread: {e}"))?;
        Ok(TcpTransport {
            writer: stream,
            reader: Reader::Threaded(rx),
            stats: WireStats::default(),
            peer,
        })
    }

    fn note_recv(&mut self, n: usize) {
        self.stats.frames_recv += 1;
        self.stats.bytes_recv += n as u64;
    }
}

fn peer_label(stream: &TcpStream) -> String {
    stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "tcp:unknown".to_string())
}

impl Transport for TcpTransport {
    fn send(&mut self, msg: &Message) -> Result<(), String> {
        let n = proto::write_frame(&mut self.writer, msg)
            .map_err(|e| format!("{} -> {e}", self.peer))?;
        self.stats.frames_sent += 1;
        self.stats.bytes_sent += n as u64;
        Ok(())
    }

    fn recv(&mut self) -> Result<Message, String> {
        match &mut self.reader {
            Reader::Direct(stream) => {
                let (msg, n) = proto::read_frame(stream)
                    .map_err(|e| format!("{} -> {e}", self.peer))?;
                self.note_recv(n);
                Ok(msg)
            }
            Reader::Threaded(rx) => {
                let item = rx
                    .recv()
                    .map_err(|_| format!("{}: connection reader exited", self.peer))?;
                let (msg, n) = item.map_err(|e| format!("{} -> {e}", self.peer))?;
                self.note_recv(n);
                Ok(msg)
            }
        }
    }

    fn try_recv(&mut self) -> Result<Option<Message>, String> {
        match &mut self.reader {
            Reader::Direct(_) => Err(format!(
                "{}: try_recv is not supported on a direct TCP transport",
                self.peer
            )),
            Reader::Threaded(rx) => match rx.try_recv() {
                Ok(item) => {
                    let (msg, n) = item.map_err(|e| format!("{} -> {e}", self.peer))?;
                    self.note_recv(n);
                    Ok(Some(msg))
                }
                Err(mpsc::TryRecvError::Empty) => Ok(None),
                Err(mpsc::TryRecvError::Disconnected) => {
                    Err(format!("{}: connection reader exited", self.peer))
                }
            },
        }
    }

    fn stats(&self) -> WireStats {
        self.stats
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        // unblock a parked reader thread; errors on an already-dead socket
        // are expected
        let _ = self.writer.shutdown(Shutdown::Both);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_cross_a_real_socket() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let client = thread::spawn(move || {
            let mut t = TcpTransport::connect(&addr).unwrap();
            t.send(&Message::Hello {
                device_id: 0,
                devices: 1,
                shard_len: 10,
                codec: "identity".into(),
                config_fp: 7,
            })
            .unwrap();
            let ack = t.recv().unwrap();
            assert!(matches!(ack, Message::HelloAck { device_id: 0, .. }));
        });
        let mut server = TcpTransport::accept(&listener).unwrap();
        let hello = server.recv().unwrap();
        assert!(matches!(hello, Message::Hello { device_id: 0, .. }));
        server
            .send(&Message::HelloAck { device_id: 0, rounds: 1, agg_every: 1 })
            .unwrap();
        client.join().unwrap();
        assert_eq!(server.stats().frames_recv, 1);
        assert_eq!(server.stats().frames_sent, 1);
    }

    #[test]
    fn connect_to_nothing_fails() {
        assert!(TcpTransport::connect("127.0.0.1:1").is_err());
        assert!(TcpTransport::connect_retry(
            "127.0.0.1:1",
            2,
            Duration::from_millis(10)
        )
        .is_err());
    }
}
