//! TCP transport: framed SL protocol over `std::net` streams.
//!
//! Two modes:
//!
//! * **direct** (device side, [`TcpTransport::connect`]) — blocking
//!   request/response reads on the caller's thread; the device loop is
//!   strictly lock-step so no reader thread is needed.
//! * **threaded** ([`TcpTransport::accept`]) — one reader thread per
//!   accepted connection decodes frames into an in-memory channel. This is
//!   the generic [`Transport`]-object accept path (tests, ad-hoc tools);
//!   `slacc serve` itself no longer uses it — the server runtime drives
//!   every accepted socket from one non-blocking poll loop
//!   ([`crate::sched::event_loop::PollFleet`]), which scales past a few
//!   hundred connections without a thread apiece.
//!
//! Peer hang-ups are *typed*: a clean close at a frame boundary surfaces
//! as [`TransportError::PeerClosed`], never as a generic recv error, so
//! callers can tell "the device went away" from "the stream is corrupt".

use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use super::proto::{self, FrameError, FrameRead, Message};
use super::{Transport, TransportError, WireStats};

enum Reader {
    Direct(TcpStream),
    Threaded(mpsc::Receiver<Result<(Message, usize), TransportError>>),
}

/// One framed TCP connection (either end).
pub struct TcpTransport {
    writer: TcpStream,
    reader: Reader,
    stats: WireStats,
    peer: String,
}

fn classify(e: FrameError, peer: &str) -> TransportError {
    match e {
        FrameError::Io(m) => TransportError::Io(format!("{peer}: {m}")),
        FrameError::Protocol(m) => TransportError::Protocol(format!("{peer}: {m}")),
    }
}

impl TcpTransport {
    /// Client side: connect once.
    pub fn connect(addr: &str) -> Result<TcpTransport, String> {
        let stream =
            TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
        Self::direct(stream)
    }

    /// Client side: retry until the server is listening (covers the
    /// serve/device startup race in scripts and examples).
    pub fn connect_retry(
        addr: &str,
        attempts: u32,
        delay: Duration,
    ) -> Result<TcpTransport, String> {
        let mut last = String::new();
        for _ in 0..attempts.max(1) {
            match Self::connect(addr) {
                Ok(t) => return Ok(t),
                Err(e) => last = e,
            }
            thread::sleep(delay);
        }
        Err(format!("{last} (after {attempts} attempts)"))
    }

    fn direct(stream: TcpStream) -> Result<TcpTransport, String> {
        let peer = peer_label(&stream);
        stream.set_nodelay(true).map_err(|e| format!("set_nodelay: {e}"))?;
        let reader = stream.try_clone().map_err(|e| format!("clone stream: {e}"))?;
        Ok(TcpTransport {
            writer: stream,
            reader: Reader::Direct(reader),
            stats: WireStats::default(),
            peer,
        })
    }

    /// Accept one connection in blocking **direct** mode (no reader
    /// thread). For strictly lock-step peers on a dedicated listener —
    /// the shard server's coordinator port is the canonical user: one
    /// connection, request/response only, so the thread-per-connection
    /// accept mode buys nothing.
    pub fn accept_direct(listener: &TcpListener) -> Result<TcpTransport, String> {
        let (stream, _) = listener.accept().map_err(|e| format!("accept: {e}"))?;
        Self::direct(stream)
    }

    /// Server side: accept one connection and spawn its reader thread.
    pub fn accept(listener: &TcpListener) -> Result<TcpTransport, String> {
        let (stream, _) = listener.accept().map_err(|e| format!("accept: {e}"))?;
        let peer = peer_label(&stream);
        stream.set_nodelay(true).map_err(|e| format!("set_nodelay: {e}"))?;
        let mut read_half =
            stream.try_clone().map_err(|e| format!("clone stream: {e}"))?;
        // bounded: the protocol is lock-step, so a couple of frames of
        // read-ahead is all pipelining needs — and a peer that floods valid
        // frames blocks in our TCP window instead of ballooning server RAM
        let (tx, rx) = mpsc::sync_channel(2);
        let thread_peer = peer.clone();
        thread::Builder::new()
            .name(format!("slacc-rx-{peer}"))
            .spawn(move || loop {
                match proto::read_frame_or_eof(&mut read_half) {
                    Ok(FrameRead::Frame(msg, n)) => {
                        if tx.send(Ok((msg, n))).is_err() {
                            break; // transport dropped
                        }
                    }
                    Ok(FrameRead::Eof) => {
                        // clean hang-up at a frame boundary: typed, so the
                        // consumer can react to disconnects specifically
                        let _ = tx.send(Err(TransportError::PeerClosed {
                            peer: thread_peer.clone(),
                        }));
                        break;
                    }
                    Err(e) => {
                        let _ = tx.send(Err(classify(e, &thread_peer)));
                        break;
                    }
                }
            })
            .map_err(|e| format!("spawn reader thread: {e}"))?;
        Ok(TcpTransport {
            writer: stream,
            reader: Reader::Threaded(rx),
            stats: WireStats::default(),
            peer,
        })
    }

    fn note_recv(&mut self, n: usize) {
        self.stats.frames_recv += 1;
        self.stats.bytes_recv += n as u64;
    }
}

fn peer_label(stream: &TcpStream) -> String {
    stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "tcp:unknown".to_string())
}

impl Transport for TcpTransport {
    fn send(&mut self, msg: &Message) -> Result<(), TransportError> {
        let n = proto::write_frame(&mut self.writer, msg)
            .map_err(|e| TransportError::Io(format!("{} -> {e}", self.peer)))?;
        self.stats.frames_sent += 1;
        self.stats.bytes_sent += n as u64;
        Ok(())
    }

    fn recv(&mut self) -> Result<Message, TransportError> {
        match &mut self.reader {
            Reader::Direct(stream) => match proto::read_frame_or_eof(stream) {
                Ok(FrameRead::Frame(msg, n)) => {
                    self.note_recv(n);
                    Ok(msg)
                }
                Ok(FrameRead::Eof) => {
                    Err(TransportError::PeerClosed { peer: self.peer.clone() })
                }
                Err(e) => Err(classify(e, &self.peer)),
            },
            Reader::Threaded(rx) => {
                // a Disconnected channel means the reader delivered its
                // terminal item (already consumed) and exited — the
                // connection is over either way
                let item = rx.recv().map_err(|_| TransportError::PeerClosed {
                    peer: self.peer.clone(),
                })?;
                let (msg, n) = item?;
                self.note_recv(n);
                Ok(msg)
            }
        }
    }

    fn try_recv(&mut self) -> Result<Option<Message>, TransportError> {
        match &mut self.reader {
            Reader::Direct(_) => Err(TransportError::Protocol(format!(
                "{}: try_recv is not supported on a direct TCP transport",
                self.peer
            ))),
            Reader::Threaded(rx) => match rx.try_recv() {
                Ok(item) => {
                    let (msg, n) = item?;
                    self.note_recv(n);
                    Ok(Some(msg))
                }
                Err(mpsc::TryRecvError::Empty) => Ok(None),
                Err(mpsc::TryRecvError::Disconnected) => {
                    Err(TransportError::PeerClosed { peer: self.peer.clone() })
                }
            },
        }
    }

    fn stats(&self) -> WireStats {
        self.stats
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        // unblock a parked reader thread; errors on an already-dead socket
        // are expected
        let _ = self.writer.shutdown(Shutdown::Both);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_cross_a_real_socket() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let client = thread::spawn(move || {
            let mut t = TcpTransport::connect(&addr).unwrap();
            t.send(&Message::Hello {
                device_id: 0,
                devices: 1,
                shard_len: 10,
                config_fp: 7,
                uplink: "identity".into(),
                downlink: "identity".into(),
                sync: "identity".into(),
                streams_fp: 7,
            })
            .unwrap();
            let ack = t.recv().unwrap();
            assert!(matches!(ack, Message::HelloAck { device_id: 0, .. }));
        });
        let mut server = TcpTransport::accept(&listener).unwrap();
        let hello = server.recv().unwrap();
        assert!(matches!(hello, Message::Hello { device_id: 0, .. }));
        server
            .send(&Message::HelloAck { device_id: 0, rounds: 1, agg_every: 1 })
            .unwrap();
        client.join().unwrap();
        assert_eq!(server.stats().frames_recv, 1);
        assert_eq!(server.stats().frames_sent, 1);
    }

    #[test]
    fn connect_to_nothing_fails() {
        assert!(TcpTransport::connect("127.0.0.1:1").is_err());
        assert!(TcpTransport::connect_retry(
            "127.0.0.1:1",
            2,
            Duration::from_millis(10)
        )
        .is_err());
    }

    #[test]
    fn threaded_peer_disconnect_is_typed() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let client = thread::spawn(move || {
            let mut t = TcpTransport::connect(&addr).unwrap();
            t.send(&Message::RoundOpen { round: 3, sync: false }).unwrap();
            // drop: clean close after one frame
        });
        let mut server = TcpTransport::accept(&listener).unwrap();
        // the queued frame still arrives...
        assert!(matches!(server.recv().unwrap(), Message::RoundOpen { round: 3, .. }));
        client.join().unwrap();
        // ...then the hang-up surfaces as PeerClosed, not a generic error
        let err = server.recv().unwrap_err();
        assert!(err.is_peer_closed(), "want PeerClosed, got {err:?}");
        // and stays typed on subsequent receives
        let err = server.recv().unwrap_err();
        assert!(err.is_peer_closed(), "want PeerClosed again, got {err:?}");
    }

    #[test]
    fn direct_peer_disconnect_is_typed() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let client = thread::spawn(move || {
            let t = TcpTransport::connect(&addr).unwrap();
            drop(t); // immediate clean close
        });
        let (stream, _) = listener.accept().unwrap();
        let mut server = TcpTransport::direct(stream).unwrap();
        client.join().unwrap();
        let err = server.recv().unwrap_err();
        assert!(err.is_peer_closed(), "want PeerClosed, got {err:?}");
    }

    #[test]
    fn garbage_bytes_are_protocol_not_peer_closed() {
        use std::io::Write;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let client = thread::spawn(move || {
            let mut s = TcpStream::connect(&addr).unwrap();
            s.write_all(&[0xde, 0xad, 0xbe, 0xef, 1, 2, 3, 4, 5, 6]).unwrap();
        });
        let mut server = TcpTransport::accept(&listener).unwrap();
        client.join().unwrap();
        let err = server.recv().unwrap_err();
        assert!(
            matches!(err, TransportError::Protocol(_)),
            "want Protocol, got {err:?}"
        );
    }
}
