//! Criterion-free benchmark harness (criterion is not vendored).
//!
//! Two layers:
//!
//! * [`Bencher`] — wall-clock micro-benchmarks with warmup, percentile
//!   summaries and throughput, used by `rust/benches/microbench.rs`.
//! * [`Table`] — aligned experiment tables (one per paper figure), with a
//!   JSON sidecar written under `bench_results/` so figures can be
//!   regenerated/plotted without re-running.

use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::Summary;

/// Micro-benchmark runner.
pub struct Bencher {
    name: String,
    warmup: usize,
    samples: usize,
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
    /// optional bytes processed per iteration (enables MB/s reporting)
    pub bytes_per_iter: Option<usize>,
}

impl BenchResult {
    pub fn throughput_mbs(&self) -> Option<f64> {
        self.bytes_per_iter
            .map(|b| b as f64 / self.summary.mean / 1e6)
    }

    pub fn row(&self) -> String {
        let tp = self
            .throughput_mbs()
            .map_or(String::new(), |t| format!("  {t:9.1} MB/s"));
        format!(
            "{:<44} {:>10.3} us  p50 {:>10.3} us  p95 {:>10.3} us{}",
            self.name,
            self.summary.mean * 1e6,
            self.summary.p50 * 1e6,
            self.summary.p95 * 1e6,
            tp
        )
    }
}

impl Bencher {
    pub fn new(name: &str) -> Bencher {
        Bencher { name: name.to_string(), warmup: 3, samples: 30 }
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup = n;
        self
    }

    pub fn samples(mut self, n: usize) -> Self {
        self.samples = n;
        self
    }

    /// Time `f` (which should perform one full iteration).
    pub fn run<F: FnMut()>(self, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64());
        }
        BenchResult {
            name: self.name,
            summary: Summary::of(&times),
            bytes_per_iter: None,
        }
    }

    /// Like `run`, recording bytes/iter for throughput reporting.
    pub fn run_bytes<F: FnMut() -> usize>(self, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut times = Vec::with_capacity(self.samples);
        let mut bytes = 0usize;
        for _ in 0..self.samples {
            let t0 = Instant::now();
            bytes = f();
            times.push(t0.elapsed().as_secs_f64());
        }
        BenchResult {
            name: self.name,
            summary: Summary::of(&times),
            bytes_per_iter: Some(bytes),
        }
    }
}

/// An experiment result table (one per paper figure/bench binary).
pub struct Table {
    title: String,
    columns: Vec<String>,
    rows: Vec<Vec<String>>,
    json_rows: Vec<Json>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            json_rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len());
        let obj = Json::Obj(
            self.columns
                .iter()
                .zip(&cells)
                .map(|(c, v)| {
                    let val = v
                        .parse::<f64>()
                        .map(Json::Num)
                        .unwrap_or_else(|_| Json::Str(v.clone()));
                    (c.clone(), val)
                })
                .collect(),
        );
        self.json_rows.push(obj);
        self.rows.push(cells);
    }

    /// Attach raw series data (e.g. a full accuracy-vs-time curve) to the
    /// JSON sidecar without cluttering the printed table.
    pub fn series(&mut self, name: &str, points: &[(f64, f64)]) {
        let arr = Json::Arr(
            points
                .iter()
                .map(|&(x, y)| Json::Arr(vec![Json::Num(x), Json::Num(y)]))
                .collect(),
        );
        self.json_rows.push(Json::obj(vec![
            ("series", Json::str(name)),
            ("points", arr),
        ]));
    }

    /// Print aligned and write the JSON sidecar to `bench_results/`.
    pub fn finish(self) {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        println!("\n=== {} ===", self.title);
        let header: Vec<String> = self
            .columns
            .iter()
            .zip(&widths)
            .map(|(c, w)| format!("{c:<w$}"))
            .collect();
        println!("{}", header.join("  "));
        println!("{}", "-".repeat(header.join("  ").len()));
        for row in &self.rows {
            let line: Vec<String> = row
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect();
            println!("{}", line.join("  "));
        }

        let slug: String = self
            .title
            .to_lowercase()
            .chars()
            .map(|c| if c.is_alphanumeric() { c } else { '_' })
            .collect();
        let path = std::path::Path::new("bench_results").join(format!("{slug}.json"));
        if std::fs::create_dir_all("bench_results").is_ok() {
            let doc = Json::obj(vec![
                ("title", Json::str(&self.title)),
                ("rows", Json::Arr(self.json_rows)),
            ]);
            if std::fs::write(&path, doc.dump()).is_ok() {
                println!("[saved {}]", path.display());
            }
        }
    }
}

/// Format seconds for human-readable tables.
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0}s")
    } else if s >= 1.0 {
        format!("{s:.1}s")
    } else {
        format!("{:.0}ms", s * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_measures_something() {
        let r = Bencher::new("spin").warmup(1).samples(5).run(|| {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(r.summary.n, 5);
        assert!(r.summary.mean >= 0.0);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            summary: Summary::of(&[0.001, 0.001]),
            bytes_per_iter: Some(1_000_000),
        };
        let tp = r.throughput_mbs().unwrap();
        assert!((tp - 1000.0).abs() < 1.0);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(0.05), "50ms");
        assert_eq!(fmt_secs(2.34), "2.3s");
        assert_eq!(fmt_secs(250.0), "250s");
    }

    #[test]
    #[should_panic]
    fn table_rejects_wrong_width() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
