//! The fleet abstraction the round scheduler drives, plus the in-process
//! implementation.
//!
//! [`Fleet`] is the seam between *scheduling* (which device to step next,
//! when to give up on a straggler) and *transport* (how bytes move).
//! Implementations:
//!
//! * [`PumpFleet`] — wraps the loopback connections of an in-process
//!   session. Single-threaded, so "time" is a **virtual clock**: each
//!   message is stamped with an arrival time derived from an optional
//!   per-device artificial delay (plus seeded jitter), and `recv_any`
//!   replays messages in stamped order, advancing the clock. This makes
//!   arrival-order scheduling, straggler timeouts, and quorum closes fully
//!   deterministic in unit tests — no real sleeping anywhere.
//! * [`crate::sched::event_loop::PollFleet`] — real non-blocking TCP
//!   sockets behind `poll`, wall-clock time.
//! * [`ShardFleet`] — a fleet whose "devices" are downstream shard
//!   *servers*: the coordinator tier of a multi-server topology drives
//!   inter-shard ModelSync through the same [`Fleet`] seam, over any
//!   [`Transport`] (TCP across machines, [`crate::transport::channel`]
//!   between threads).

use std::collections::VecDeque;

use crate::member::{Departure, JoinRequest};
use crate::transport::proto::Message;
use crate::transport::{Transport, TransportError, WireStats};
use crate::util::rng::Pcg32;

/// A set of device connections the scheduler can step in any order.
pub trait Fleet {
    fn devices(&self) -> usize;

    /// Fleet-clock seconds since session start: virtual for in-process
    /// fleets, wall-clock for socket fleets. Monotone non-decreasing.
    fn now_s(&self) -> f64;

    /// Send one message to device `d`.
    fn send(&mut self, d: usize, msg: &Message) -> Result<(), TransportError>;

    /// Next message from device `d` specifically (the in-order path).
    /// Messages other devices deliver in the meantime stay queued.
    fn recv_from(&mut self, d: usize) -> Result<Message, TransportError>;

    /// Next message from *any* device, in arrival order. `Ok(None)` once
    /// `timeout_s` elapses with nothing arriving; `None` timeout waits
    /// indefinitely. Elastic fleets also return `Ok(None)` when a
    /// membership event is ready (a departure with no frames left to
    /// drain) so the scheduler can rule on it instead of blocking.
    fn recv_any(
        &mut self,
        timeout_s: Option<f64>,
    ) -> Result<Option<(usize, Message)>, TransportError>;

    /// Give an in-process device worker its turn (no-op on socket fleets,
    /// where remote devices run themselves).
    fn pump(&mut self, d: usize) -> Result<(), TransportError>;

    /// Framed-byte accounting for device `d`'s connection.
    fn stats(&self, d: usize) -> WireStats;

    /// Peer label for logs.
    fn peer(&self, d: usize) -> String;

    // ---- elastic membership (proto v6) ----------------------------------
    // The defaults describe a fixed fleet: nobody leaves (a hang-up stays
    // a fatal transport error), nobody joins.

    /// Drain departures that are ready to act on: connections that ended
    /// mid-session *and* whose already-received frames have all been
    /// consumed. An entry appears here exactly once.
    fn take_departures(&mut self) -> Vec<Departure> {
        Vec::new()
    }

    /// Surface parked `Join` handshakes, each exactly once. Called by the
    /// scheduler at round boundaries; the fleet keeps the connection
    /// parked until [`Fleet::admit_join`] / [`Fleet::reject_join`] rules
    /// on it.
    fn poll_joins(&mut self) -> Vec<JoinRequest> {
        Vec::new()
    }

    /// Admit the parked join behind `key`: wire its connection into the
    /// vacant device slot and deliver `replies` (JoinAck, Catchup, …) on
    /// it as one batch.
    fn admit_join(&mut self, _key: u64, _replies: &[Message]) -> Result<(), TransportError> {
        Err(TransportError::Protocol(
            "this fleet does not admit joins".to_string(),
        ))
    }

    /// Reject the parked join behind `key` and drop its connection.
    fn reject_join(&mut self, _key: u64, _reason: &str) {}

    /// Is device `d`'s slot vacant (departed and not yet readmitted)?
    fn vacant(&self, _d: usize) -> bool {
        false
    }

    /// Send several messages to device `d`. Socket fleets coalesce the
    /// batch into a single vectored write; the default is sequential
    /// sends with identical bytes on the wire.
    fn send_batch(&mut self, d: usize, msgs: &[Message]) -> Result<(), TransportError> {
        for m in msgs {
            self.send(d, m)?;
        }
        Ok(())
    }

    /// Tell the fleet which round the scheduler is opening. Fixed fleets
    /// ignore this; [`PumpFleet`] uses it to fire scripted churn events
    /// at deterministic points.
    fn note_round(&mut self, _round: u32) {}
}

/// One scripted churn event for [`PumpFleet::with_churn`]: deterministic
/// device kills and rejoins keyed to round numbers, so elastic-membership
/// scheduling is testable without real sockets or real time.
#[derive(Debug, Clone)]
pub enum ChurnEvent {
    /// Device `device` hangs up at the open of round `round`.
    Kill { round: u32, device: usize },
    /// Device `device` offers `join` (a [`Message::Join`]) at the open of
    /// round `round`. Ignored until the device has actually been killed.
    Rejoin { round: u32, device: usize, join: Message },
}

struct ChurnSlot {
    event: ChurnEvent,
    fired: bool,
}

/// In-process fleet over loopback transports (see module docs).
pub struct PumpFleet<'a, P: FnMut(usize) -> Result<(), TransportError>> {
    conns: &'a mut [Box<dyn Transport>],
    pump_fn: P,
    /// per-device queue of (message, virtual arrival time)
    pending: Vec<VecDeque<(Message, f64)>>,
    /// per-device artificial delay in virtual seconds (0 = instant)
    delays: Vec<f64>,
    rng: Pcg32,
    now: f64,
    /// scripted churn events ([`PumpFleet::with_churn`]), fired by round
    churn: Vec<ChurnSlot>,
    /// device slots currently out of the session
    killed: Vec<bool>,
    /// kills recorded but not yet drained via `take_departures`
    departures: VecDeque<Departure>,
    /// last round the scheduler announced via `note_round`
    round: u32,
}

impl<'a, P: FnMut(usize) -> Result<(), TransportError>> PumpFleet<'a, P> {
    /// Plain fleet: no artificial delays, arrival ties broken by device id
    /// (which makes zero-delay arrival-order runs identical to in-order).
    pub fn new(conns: &'a mut [Box<dyn Transport>], pump_fn: P) -> PumpFleet<'a, P> {
        let n = conns.len();
        Self::with_delays(conns, pump_fn, vec![0.0; n], 0)
    }

    /// Fleet with a seeded artificial-delay shim: every message from
    /// device `d` arrives `delays[d]` virtual seconds after it was handed
    /// to the transport, jittered ±10% from `seed` so arrival interleaving
    /// is exercised but exactly reproducible.
    pub fn with_delays(
        conns: &'a mut [Box<dyn Transport>],
        pump_fn: P,
        delays: Vec<f64>,
        seed: u64,
    ) -> PumpFleet<'a, P> {
        let n = conns.len();
        assert_eq!(delays.len(), n, "one delay per device");
        PumpFleet {
            conns,
            pump_fn,
            pending: (0..n).map(|_| VecDeque::new()).collect(),
            delays,
            rng: Pcg32::new(seed, 0x57AC_4EED),
            now: 0.0,
            churn: Vec::new(),
            killed: vec![false; n],
            departures: VecDeque::new(),
            round: 0,
        }
    }

    /// Attach a scripted churn plan: each [`ChurnEvent`] fires when the
    /// scheduler announces its round via [`Fleet::note_round`], making
    /// elastic kills and rejoins exactly reproducible.
    pub fn with_churn(mut self, churn: Vec<ChurnEvent>) -> Self {
        self.churn = churn
            .into_iter()
            .map(|event| ChurnSlot { event, fired: false })
            .collect();
        self
    }

    /// Virtual clock (exposed for tests).
    pub fn clock_s(&self) -> f64 {
        self.now
    }

    /// Pump device `d` and stamp anything it produced with an arrival time.
    /// A killed device's worker no longer runs, but messages it handed to
    /// the transport before the kill stay deliverable — mirroring bytes a
    /// real peer wrote before hanging up.
    fn fill(&mut self, d: usize) -> Result<(), TransportError> {
        if !self.killed[d] {
            (self.pump_fn)(d)?;
        }
        while let Some(msg) = self.conns[d].try_recv()? {
            let arrival = if self.delays[d] > 0.0 {
                let jitter = self.rng.range_f32(0.9, 1.1) as f64;
                self.now + self.delays[d] * jitter
            } else {
                self.now
            };
            self.pending[d].push_back((msg, arrival));
        }
        Ok(())
    }

    /// Earliest pending head across all devices: (arrival, device).
    fn earliest_head(&self) -> Option<(f64, usize)> {
        let mut best: Option<(f64, usize)> = None;
        for (d, q) in self.pending.iter().enumerate() {
            if let Some((_, a)) = q.front() {
                let a = *a;
                let better = match best {
                    None => true,
                    Some((ba, bd)) => a < ba || (a == ba && d < bd),
                };
                if better {
                    best = Some((a, d));
                }
            }
        }
        best
    }
}

impl<P: FnMut(usize) -> Result<(), TransportError>> Fleet for PumpFleet<'_, P> {
    fn devices(&self) -> usize {
        self.conns.len()
    }

    fn now_s(&self) -> f64 {
        self.now
    }

    fn send(&mut self, d: usize, msg: &Message) -> Result<(), TransportError> {
        if self.killed[d] {
            return Err(TransportError::PeerClosed { peer: self.conns[d].peer() });
        }
        self.conns[d].send(msg)
    }

    fn recv_from(&mut self, d: usize) -> Result<Message, TransportError> {
        if self.pending[d].is_empty() {
            self.fill(d)?;
        }
        match self.pending[d].pop_front() {
            Some((msg, arrival)) => {
                if arrival > self.now {
                    self.now = arrival;
                }
                Ok(msg)
            }
            None => Err(TransportError::Protocol(format!(
                "no message queued from device {d} \
                 (single-threaded in-process fleet cannot block)"
            ))),
        }
    }

    fn recv_any(
        &mut self,
        timeout_s: Option<f64>,
    ) -> Result<Option<(usize, Message)>, TransportError> {
        for d in 0..self.conns.len() {
            if self.pending[d].is_empty() {
                self.fill(d)?;
            }
        }
        match self.earliest_head() {
            None => match timeout_s {
                Some(t) => {
                    // nothing in flight: burn the timeout on the virtual clock
                    self.now += t.max(0.0);
                    Ok(None)
                }
                None => Err(TransportError::Protocol(
                    "recv_any: every queue is empty and nothing is in flight \
                     (single-threaded in-process fleet cannot block)"
                        .to_string(),
                )),
            },
            Some((arrival, d)) => {
                if let Some(t) = timeout_s {
                    if arrival > self.now + t {
                        // earliest message lands past the deadline: time out
                        self.now += t.max(0.0);
                        return Ok(None);
                    }
                }
                if arrival > self.now {
                    self.now = arrival;
                }
                let (msg, _) = self.pending[d].pop_front().unwrap();
                Ok(Some((d, msg)))
            }
        }
    }

    fn pump(&mut self, d: usize) -> Result<(), TransportError> {
        if self.killed[d] {
            return Ok(());
        }
        (self.pump_fn)(d)
    }

    fn stats(&self, d: usize) -> WireStats {
        self.conns[d].stats()
    }

    fn peer(&self, d: usize) -> String {
        self.conns[d].peer()
    }

    fn take_departures(&mut self) -> Vec<Departure> {
        // a departure is actionable only once the device's in-flight
        // messages have been consumed (same contract as the socket fleet)
        let mut ready = Vec::new();
        let mut waiting = VecDeque::new();
        while let Some(dep) = self.departures.pop_front() {
            if self.pending[dep.slot].is_empty() {
                ready.push(dep);
            } else {
                waiting.push_back(dep);
            }
        }
        self.departures = waiting;
        ready
    }

    fn poll_joins(&mut self) -> Vec<JoinRequest> {
        let round = self.round;
        let killed = &self.killed;
        let mut out = Vec::new();
        for (i, s) in self.churn.iter_mut().enumerate() {
            if s.fired {
                continue;
            }
            if let ChurnEvent::Rejoin { round: r, device, join } = &s.event {
                if *r <= round && killed[*device] {
                    s.fired = true;
                    let member_epoch = match join {
                        Message::Join { member_epoch, .. } => *member_epoch,
                        _ => 0,
                    };
                    out.push(JoinRequest {
                        key: i as u64,
                        gid: *device,
                        member_epoch,
                        msg: join.clone(),
                        join_bytes: join.encode_frame().len() as u64,
                    });
                }
            }
        }
        out
    }

    fn admit_join(&mut self, key: u64, replies: &[Message]) -> Result<(), TransportError> {
        let device = match self.churn.get(key as usize) {
            Some(ChurnSlot { event: ChurnEvent::Rejoin { device, .. }, fired: true }) => *device,
            _ => {
                return Err(TransportError::Protocol(format!(
                    "admit_join: key {key} is not a surfaced rejoin"
                )))
            }
        };
        if !self.killed[device] {
            return Err(TransportError::Protocol(format!(
                "admit_join: device {device} slot is not vacant"
            )));
        }
        self.killed[device] = false;
        for m in replies {
            self.conns[device].send(m)?;
        }
        Ok(())
    }

    fn vacant(&self, d: usize) -> bool {
        self.killed[d]
    }

    fn note_round(&mut self, round: u32) {
        self.round = round;
        for i in 0..self.churn.len() {
            let device = match &self.churn[i] {
                ChurnSlot { event: ChurnEvent::Kill { round: r, device }, fired: false }
                    if *r <= round =>
                {
                    *device
                }
                _ => continue,
            };
            self.churn[i].fired = true;
            if !self.killed[device] {
                self.killed[device] = true;
                self.departures.push_back(Departure {
                    slot: device,
                    error: TransportError::PeerClosed { peer: self.conns[device].peer() },
                    graceful: false,
                });
            }
        }
    }
}

/// A [`Fleet`] whose "devices" are downstream shard servers.
///
/// This is the seam that makes the server tier recursive: the coordinator
/// of a multi-server topology ([`crate::shard::coordinator`]) drives its
/// shards through the exact interface the round scheduler drives devices
/// through — `send`/`recv_from` over the framed protocol — so everything
/// built against [`Fleet`] (byte accounting, peer labels, future
/// shard-level straggler policy) applies one tier up unchanged.
///
/// Cross-shard sync is a barrier (every active shard pushes before the
/// merge), so the coordinator consumes messages with blocking
/// `recv_from`; `recv_any` is a cooperative try-recv poll for transports
/// that support it (channels; the threaded TCP accept mode), provided for
/// [`Fleet`] completeness.
pub struct ShardFleet {
    conns: Vec<Box<dyn Transport>>,
    start: std::time::Instant,
}

impl ShardFleet {
    /// Wrap connections to the downstream shards, index = shard id.
    pub fn new(conns: Vec<Box<dyn Transport>>) -> ShardFleet {
        ShardFleet { conns, start: std::time::Instant::now() }
    }
}

impl Fleet for ShardFleet {
    fn devices(&self) -> usize {
        self.conns.len()
    }

    fn now_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    fn send(&mut self, d: usize, msg: &Message) -> Result<(), TransportError> {
        self.conns[d].send(msg)
    }

    fn recv_from(&mut self, d: usize) -> Result<Message, TransportError> {
        self.conns[d].recv()
    }

    fn recv_any(
        &mut self,
        timeout_s: Option<f64>,
    ) -> Result<Option<(usize, Message)>, TransportError> {
        let deadline = timeout_s.map(|t| {
            std::time::Instant::now() + std::time::Duration::from_secs_f64(t.max(0.0))
        });
        loop {
            for (d, conn) in self.conns.iter_mut().enumerate() {
                if let Some(msg) = conn.try_recv()? {
                    return Ok(Some((d, msg)));
                }
            }
            if let Some(dl) = deadline {
                if std::time::Instant::now() >= dl {
                    return Ok(None);
                }
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }

    fn pump(&mut self, _d: usize) -> Result<(), TransportError> {
        Ok(()) // shard servers run themselves
    }

    fn stats(&self, d: usize) -> WireStats {
        self.conns[d].stats()
    }

    fn peer(&self, d: usize) -> String {
        self.conns[d].peer()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::loopback;

    fn fleet_pair(
        n: usize,
    ) -> (Vec<loopback::Loopback>, Vec<Box<dyn Transport>>) {
        let mut dev = Vec::new();
        let mut srv: Vec<Box<dyn Transport>> = Vec::new();
        for d in 0..n {
            let (de, se) = loopback::pair(&format!("f{d}"));
            dev.push(de);
            srv.push(Box::new(se));
        }
        (dev, srv)
    }

    #[test]
    fn zero_delay_recv_any_is_id_order() {
        let (mut dev, mut srv) = fleet_pair(3);
        for (d, end) in dev.iter_mut().enumerate() {
            end.send(&Message::RoundOpen { round: d as u32, sync: false }).unwrap();
        }
        let mut fleet = PumpFleet::new(&mut srv, |_| Ok(()));
        for want in 0..3 {
            let (d, msg) = fleet.recv_any(None).unwrap().unwrap();
            assert_eq!(d, want);
            assert!(matches!(msg, Message::RoundOpen { .. }));
        }
        assert_eq!(fleet.now_s(), 0.0);
    }

    #[test]
    fn delays_reorder_and_advance_the_clock() {
        let (mut dev, mut srv) = fleet_pair(2);
        for end in dev.iter_mut() {
            end.send(&Message::RoundOpen { round: 0, sync: false }).unwrap();
        }
        // device 0 is slow (1.0 s), device 1 fast (0.01 s)
        let mut fleet =
            PumpFleet::with_delays(&mut srv, |_| Ok(()), vec![1.0, 0.01], 7);
        let (first, _) = fleet.recv_any(None).unwrap().unwrap();
        assert_eq!(first, 1, "fast device must arrive first");
        let t1 = fleet.now_s();
        assert!(t1 > 0.0 && t1 < 0.1);
        let (second, _) = fleet.recv_any(None).unwrap().unwrap();
        assert_eq!(second, 0);
        assert!(fleet.now_s() > 0.8, "clock must advance to the slow arrival");
    }

    #[test]
    fn timeout_expires_before_slow_arrival() {
        let (mut dev, mut srv) = fleet_pair(2);
        for end in dev.iter_mut() {
            end.send(&Message::RoundOpen { round: 0, sync: false }).unwrap();
        }
        let mut fleet =
            PumpFleet::with_delays(&mut srv, |_| Ok(()), vec![5.0, 0.0], 7);
        // fast one arrives inside the window
        let got = fleet.recv_any(Some(0.5)).unwrap();
        assert_eq!(got.map(|(d, _)| d), Some(1));
        // slow one does not: timeout, clock advances by the timeout
        let before = fleet.now_s();
        assert!(fleet.recv_any(Some(0.5)).unwrap().is_none());
        assert!((fleet.now_s() - before - 0.5).abs() < 1e-9);
        // eventually (unbounded wait) it lands
        let got = fleet.recv_any(None).unwrap();
        assert_eq!(got.map(|(d, _)| d), Some(0));
    }

    #[test]
    fn same_seed_same_schedule() {
        let order_for = |seed: u64| -> Vec<usize> {
            let (mut dev, mut srv) = fleet_pair(3);
            for end in dev.iter_mut() {
                for r in 0..3 {
                    end.send(&Message::RoundOpen { round: r, sync: false }).unwrap();
                }
            }
            let mut fleet = PumpFleet::with_delays(
                &mut srv,
                |_| Ok(()),
                vec![0.3, 0.2, 0.25],
                seed,
            );
            let mut order = Vec::new();
            while let Ok(Some((d, _))) = fleet.recv_any(None) {
                order.push(d);
                if order.len() == 9 {
                    break;
                }
            }
            order
        };
        assert_eq!(order_for(42), order_for(42), "seeded shim must be deterministic");
    }

    #[test]
    fn zero_timeout_drains_ready_but_never_blocks() {
        // the batch planner's probe: recv_any(Some(0.0)) must hand over
        // everything already arrived and return None the moment the queue
        // is quiet, without advancing the virtual clock
        let (mut dev, mut srv) = fleet_pair(3);
        for (d, end) in dev.iter_mut().enumerate() {
            end.send(&Message::RoundOpen { round: d as u32, sync: false }).unwrap();
        }
        let mut fleet = PumpFleet::new(&mut srv, |_| Ok(()));
        for want in 0..3 {
            let got = fleet.recv_any(Some(0.0)).unwrap();
            assert_eq!(got.map(|(d, _)| d), Some(want));
        }
        assert!(fleet.recv_any(Some(0.0)).unwrap().is_none());
        assert_eq!(fleet.now_s(), 0.0);
        // a delayed message is NOT ready at zero timeout
        dev[1].send(&Message::RoundOpen { round: 9, sync: false }).unwrap();
        let mut delayed =
            PumpFleet::with_delays(&mut srv, |_| Ok(()), vec![0.0, 0.5, 0.0], 3);
        assert!(delayed.recv_any(Some(0.0)).unwrap().is_none());
        // but an unbounded wait still surfaces it
        assert_eq!(delayed.recv_any(None).unwrap().map(|(d, _)| d), Some(1));
    }

    #[test]
    fn scripted_churn_kills_and_readmits_deterministically() {
        let join = Message::Join {
            device_id: 1,
            devices: 3,
            shard_len: 8,
            config_fp: 1,
            member_epoch: 0,
            uplink: "identity".into(),
            downlink: "identity".into(),
            sync: "identity".into(),
            streams_fp: 0,
        };
        let (mut dev, mut srv) = fleet_pair(3);
        // device 1 has a frame in flight when the kill fires
        dev[1].send(&Message::RoundOpen { round: 0, sync: false }).unwrap();
        let mut fleet = PumpFleet::new(&mut srv, |_| Ok(())).with_churn(vec![
            ChurnEvent::Kill { round: 1, device: 1 },
            ChurnEvent::Rejoin { round: 2, device: 1, join: join.clone() },
        ]);
        fleet.note_round(0);
        assert!(fleet.take_departures().is_empty(), "no churn before round 1");
        assert!(fleet.poll_joins().is_empty());

        fleet.note_round(1);
        // the in-flight frame gates the departure until consumed
        assert!(fleet.take_departures().is_empty());
        let (d, _) = fleet.recv_any(None).unwrap().unwrap();
        assert_eq!(d, 1);
        let deps = fleet.take_departures();
        assert_eq!(deps.len(), 1);
        assert_eq!(deps[0].slot, 1);
        assert!(deps[0].error.is_peer_closed());
        assert!(fleet.vacant(1));
        assert!(fleet.send(1, &Message::RoundOpen { round: 1, sync: false }).is_err());
        assert!(fleet.poll_joins().is_empty(), "rejoin is scripted for round 2");

        fleet.note_round(2);
        let reqs = fleet.poll_joins();
        assert_eq!(reqs.len(), 1);
        assert_eq!(reqs[0].gid, 1);
        assert_eq!(reqs[0].member_epoch, 0);
        assert!(fleet.poll_joins().is_empty(), "a join surfaces exactly once");
        fleet
            .admit_join(
                reqs[0].key,
                &[Message::JoinAck {
                    device_id: 1,
                    round: 2,
                    member_epoch: 1,
                    rounds: 4,
                    agg_every: 1,
                }],
            )
            .unwrap();
        assert!(!fleet.vacant(1));
        drop(fleet);
        // the admit replies landed on the device end of the loopback
        let ack = dev[1].try_recv().unwrap().unwrap();
        assert!(matches!(ack, Message::JoinAck { member_epoch: 1, .. }));
    }

    #[test]
    fn recv_from_skips_other_devices() {
        let (mut dev, mut srv) = fleet_pair(2);
        dev[0].send(&Message::RoundOpen { round: 0, sync: false }).unwrap();
        dev[1].send(&Message::Shutdown { reason: "x".into() }).unwrap();
        let mut fleet = PumpFleet::new(&mut srv, |_| Ok(()));
        let msg = fleet.recv_from(1).unwrap();
        assert!(matches!(msg, Message::Shutdown { .. }));
        let msg = fleet.recv_from(0).unwrap();
        assert!(matches!(msg, Message::RoundOpen { .. }));
        assert!(fleet.recv_from(0).is_err(), "empty queue cannot block");
    }
}
