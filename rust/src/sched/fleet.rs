//! The fleet abstraction the round scheduler drives, plus the in-process
//! implementation.
//!
//! [`Fleet`] is the seam between *scheduling* (which device to step next,
//! when to give up on a straggler) and *transport* (how bytes move).
//! Implementations:
//!
//! * [`PumpFleet`] — wraps the loopback connections of an in-process
//!   session. Single-threaded, so "time" is a **virtual clock**: each
//!   message is stamped with an arrival time derived from an optional
//!   per-device artificial delay (plus seeded jitter), and `recv_any`
//!   replays messages in stamped order, advancing the clock. This makes
//!   arrival-order scheduling, straggler timeouts, and quorum closes fully
//!   deterministic in unit tests — no real sleeping anywhere.
//! * [`crate::sched::event_loop::PollFleet`] — real non-blocking TCP
//!   sockets behind `poll`, wall-clock time.
//! * [`ShardFleet`] — a fleet whose "devices" are downstream shard
//!   *servers*: the coordinator tier of a multi-server topology drives
//!   inter-shard ModelSync through the same [`Fleet`] seam, over any
//!   [`Transport`] (TCP across machines, [`crate::transport::channel`]
//!   between threads).

use std::collections::VecDeque;

use crate::transport::proto::Message;
use crate::transport::{Transport, TransportError, WireStats};
use crate::util::rng::Pcg32;

/// A set of device connections the scheduler can step in any order.
pub trait Fleet {
    fn devices(&self) -> usize;

    /// Fleet-clock seconds since session start: virtual for in-process
    /// fleets, wall-clock for socket fleets. Monotone non-decreasing.
    fn now_s(&self) -> f64;

    /// Send one message to device `d`.
    fn send(&mut self, d: usize, msg: &Message) -> Result<(), TransportError>;

    /// Next message from device `d` specifically (the in-order path).
    /// Messages other devices deliver in the meantime stay queued.
    fn recv_from(&mut self, d: usize) -> Result<Message, TransportError>;

    /// Next message from *any* device, in arrival order. `Ok(None)` once
    /// `timeout_s` elapses with nothing arriving; `None` timeout waits
    /// indefinitely.
    fn recv_any(
        &mut self,
        timeout_s: Option<f64>,
    ) -> Result<Option<(usize, Message)>, TransportError>;

    /// Give an in-process device worker its turn (no-op on socket fleets,
    /// where remote devices run themselves).
    fn pump(&mut self, d: usize) -> Result<(), TransportError>;

    /// Framed-byte accounting for device `d`'s connection.
    fn stats(&self, d: usize) -> WireStats;

    /// Peer label for logs.
    fn peer(&self, d: usize) -> String;
}

/// In-process fleet over loopback transports (see module docs).
pub struct PumpFleet<'a, P: FnMut(usize) -> Result<(), TransportError>> {
    conns: &'a mut [Box<dyn Transport>],
    pump_fn: P,
    /// per-device queue of (message, virtual arrival time)
    pending: Vec<VecDeque<(Message, f64)>>,
    /// per-device artificial delay in virtual seconds (0 = instant)
    delays: Vec<f64>,
    rng: Pcg32,
    now: f64,
}

impl<'a, P: FnMut(usize) -> Result<(), TransportError>> PumpFleet<'a, P> {
    /// Plain fleet: no artificial delays, arrival ties broken by device id
    /// (which makes zero-delay arrival-order runs identical to in-order).
    pub fn new(conns: &'a mut [Box<dyn Transport>], pump_fn: P) -> PumpFleet<'a, P> {
        let n = conns.len();
        Self::with_delays(conns, pump_fn, vec![0.0; n], 0)
    }

    /// Fleet with a seeded artificial-delay shim: every message from
    /// device `d` arrives `delays[d]` virtual seconds after it was handed
    /// to the transport, jittered ±10% from `seed` so arrival interleaving
    /// is exercised but exactly reproducible.
    pub fn with_delays(
        conns: &'a mut [Box<dyn Transport>],
        pump_fn: P,
        delays: Vec<f64>,
        seed: u64,
    ) -> PumpFleet<'a, P> {
        let n = conns.len();
        assert_eq!(delays.len(), n, "one delay per device");
        PumpFleet {
            conns,
            pump_fn,
            pending: (0..n).map(|_| VecDeque::new()).collect(),
            delays,
            rng: Pcg32::new(seed, 0x57AC_4EED),
            now: 0.0,
        }
    }

    /// Virtual clock (exposed for tests).
    pub fn clock_s(&self) -> f64 {
        self.now
    }

    /// Pump device `d` and stamp anything it produced with an arrival time.
    fn fill(&mut self, d: usize) -> Result<(), TransportError> {
        (self.pump_fn)(d)?;
        while let Some(msg) = self.conns[d].try_recv()? {
            let arrival = if self.delays[d] > 0.0 {
                let jitter = self.rng.range_f32(0.9, 1.1) as f64;
                self.now + self.delays[d] * jitter
            } else {
                self.now
            };
            self.pending[d].push_back((msg, arrival));
        }
        Ok(())
    }

    /// Earliest pending head across all devices: (arrival, device).
    fn earliest_head(&self) -> Option<(f64, usize)> {
        let mut best: Option<(f64, usize)> = None;
        for (d, q) in self.pending.iter().enumerate() {
            if let Some((_, a)) = q.front() {
                let a = *a;
                let better = match best {
                    None => true,
                    Some((ba, bd)) => a < ba || (a == ba && d < bd),
                };
                if better {
                    best = Some((a, d));
                }
            }
        }
        best
    }
}

impl<P: FnMut(usize) -> Result<(), TransportError>> Fleet for PumpFleet<'_, P> {
    fn devices(&self) -> usize {
        self.conns.len()
    }

    fn now_s(&self) -> f64 {
        self.now
    }

    fn send(&mut self, d: usize, msg: &Message) -> Result<(), TransportError> {
        self.conns[d].send(msg)
    }

    fn recv_from(&mut self, d: usize) -> Result<Message, TransportError> {
        if self.pending[d].is_empty() {
            self.fill(d)?;
        }
        match self.pending[d].pop_front() {
            Some((msg, arrival)) => {
                if arrival > self.now {
                    self.now = arrival;
                }
                Ok(msg)
            }
            None => Err(TransportError::Protocol(format!(
                "no message queued from device {d} \
                 (single-threaded in-process fleet cannot block)"
            ))),
        }
    }

    fn recv_any(
        &mut self,
        timeout_s: Option<f64>,
    ) -> Result<Option<(usize, Message)>, TransportError> {
        for d in 0..self.conns.len() {
            if self.pending[d].is_empty() {
                self.fill(d)?;
            }
        }
        match self.earliest_head() {
            None => match timeout_s {
                Some(t) => {
                    // nothing in flight: burn the timeout on the virtual clock
                    self.now += t.max(0.0);
                    Ok(None)
                }
                None => Err(TransportError::Protocol(
                    "recv_any: every queue is empty and nothing is in flight \
                     (single-threaded in-process fleet cannot block)"
                        .to_string(),
                )),
            },
            Some((arrival, d)) => {
                if let Some(t) = timeout_s {
                    if arrival > self.now + t {
                        // earliest message lands past the deadline: time out
                        self.now += t.max(0.0);
                        return Ok(None);
                    }
                }
                if arrival > self.now {
                    self.now = arrival;
                }
                let (msg, _) = self.pending[d].pop_front().unwrap();
                Ok(Some((d, msg)))
            }
        }
    }

    fn pump(&mut self, d: usize) -> Result<(), TransportError> {
        (self.pump_fn)(d)
    }

    fn stats(&self, d: usize) -> WireStats {
        self.conns[d].stats()
    }

    fn peer(&self, d: usize) -> String {
        self.conns[d].peer()
    }
}

/// A [`Fleet`] whose "devices" are downstream shard servers.
///
/// This is the seam that makes the server tier recursive: the coordinator
/// of a multi-server topology ([`crate::shard::coordinator`]) drives its
/// shards through the exact interface the round scheduler drives devices
/// through — `send`/`recv_from` over the framed protocol — so everything
/// built against [`Fleet`] (byte accounting, peer labels, future
/// shard-level straggler policy) applies one tier up unchanged.
///
/// Cross-shard sync is a barrier (every active shard pushes before the
/// merge), so the coordinator consumes messages with blocking
/// `recv_from`; `recv_any` is a cooperative try-recv poll for transports
/// that support it (channels; the threaded TCP accept mode), provided for
/// [`Fleet`] completeness.
pub struct ShardFleet {
    conns: Vec<Box<dyn Transport>>,
    start: std::time::Instant,
}

impl ShardFleet {
    /// Wrap connections to the downstream shards, index = shard id.
    pub fn new(conns: Vec<Box<dyn Transport>>) -> ShardFleet {
        ShardFleet { conns, start: std::time::Instant::now() }
    }
}

impl Fleet for ShardFleet {
    fn devices(&self) -> usize {
        self.conns.len()
    }

    fn now_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    fn send(&mut self, d: usize, msg: &Message) -> Result<(), TransportError> {
        self.conns[d].send(msg)
    }

    fn recv_from(&mut self, d: usize) -> Result<Message, TransportError> {
        self.conns[d].recv()
    }

    fn recv_any(
        &mut self,
        timeout_s: Option<f64>,
    ) -> Result<Option<(usize, Message)>, TransportError> {
        let deadline = timeout_s.map(|t| {
            std::time::Instant::now() + std::time::Duration::from_secs_f64(t.max(0.0))
        });
        loop {
            for (d, conn) in self.conns.iter_mut().enumerate() {
                if let Some(msg) = conn.try_recv()? {
                    return Ok(Some((d, msg)));
                }
            }
            if let Some(dl) = deadline {
                if std::time::Instant::now() >= dl {
                    return Ok(None);
                }
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }

    fn pump(&mut self, _d: usize) -> Result<(), TransportError> {
        Ok(()) // shard servers run themselves
    }

    fn stats(&self, d: usize) -> WireStats {
        self.conns[d].stats()
    }

    fn peer(&self, d: usize) -> String {
        self.conns[d].peer()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::loopback;

    fn fleet_pair(
        n: usize,
    ) -> (Vec<loopback::Loopback>, Vec<Box<dyn Transport>>) {
        let mut dev = Vec::new();
        let mut srv: Vec<Box<dyn Transport>> = Vec::new();
        for d in 0..n {
            let (de, se) = loopback::pair(&format!("f{d}"));
            dev.push(de);
            srv.push(Box::new(se));
        }
        (dev, srv)
    }

    #[test]
    fn zero_delay_recv_any_is_id_order() {
        let (mut dev, mut srv) = fleet_pair(3);
        for (d, end) in dev.iter_mut().enumerate() {
            end.send(&Message::RoundOpen { round: d as u32, sync: false }).unwrap();
        }
        let mut fleet = PumpFleet::new(&mut srv, |_| Ok(()));
        for want in 0..3 {
            let (d, msg) = fleet.recv_any(None).unwrap().unwrap();
            assert_eq!(d, want);
            assert!(matches!(msg, Message::RoundOpen { .. }));
        }
        assert_eq!(fleet.now_s(), 0.0);
    }

    #[test]
    fn delays_reorder_and_advance_the_clock() {
        let (mut dev, mut srv) = fleet_pair(2);
        for end in dev.iter_mut() {
            end.send(&Message::RoundOpen { round: 0, sync: false }).unwrap();
        }
        // device 0 is slow (1.0 s), device 1 fast (0.01 s)
        let mut fleet =
            PumpFleet::with_delays(&mut srv, |_| Ok(()), vec![1.0, 0.01], 7);
        let (first, _) = fleet.recv_any(None).unwrap().unwrap();
        assert_eq!(first, 1, "fast device must arrive first");
        let t1 = fleet.now_s();
        assert!(t1 > 0.0 && t1 < 0.1);
        let (second, _) = fleet.recv_any(None).unwrap().unwrap();
        assert_eq!(second, 0);
        assert!(fleet.now_s() > 0.8, "clock must advance to the slow arrival");
    }

    #[test]
    fn timeout_expires_before_slow_arrival() {
        let (mut dev, mut srv) = fleet_pair(2);
        for end in dev.iter_mut() {
            end.send(&Message::RoundOpen { round: 0, sync: false }).unwrap();
        }
        let mut fleet =
            PumpFleet::with_delays(&mut srv, |_| Ok(()), vec![5.0, 0.0], 7);
        // fast one arrives inside the window
        let got = fleet.recv_any(Some(0.5)).unwrap();
        assert_eq!(got.map(|(d, _)| d), Some(1));
        // slow one does not: timeout, clock advances by the timeout
        let before = fleet.now_s();
        assert!(fleet.recv_any(Some(0.5)).unwrap().is_none());
        assert!((fleet.now_s() - before - 0.5).abs() < 1e-9);
        // eventually (unbounded wait) it lands
        let got = fleet.recv_any(None).unwrap();
        assert_eq!(got.map(|(d, _)| d), Some(0));
    }

    #[test]
    fn same_seed_same_schedule() {
        let order_for = |seed: u64| -> Vec<usize> {
            let (mut dev, mut srv) = fleet_pair(3);
            for end in dev.iter_mut() {
                for r in 0..3 {
                    end.send(&Message::RoundOpen { round: r, sync: false }).unwrap();
                }
            }
            let mut fleet = PumpFleet::with_delays(
                &mut srv,
                |_| Ok(()),
                vec![0.3, 0.2, 0.25],
                seed,
            );
            let mut order = Vec::new();
            while let Ok(Some((d, _))) = fleet.recv_any(None) {
                order.push(d);
                if order.len() == 9 {
                    break;
                }
            }
            order
        };
        assert_eq!(order_for(42), order_for(42), "seeded shim must be deterministic");
    }

    #[test]
    fn zero_timeout_drains_ready_but_never_blocks() {
        // the batch planner's probe: recv_any(Some(0.0)) must hand over
        // everything already arrived and return None the moment the queue
        // is quiet, without advancing the virtual clock
        let (mut dev, mut srv) = fleet_pair(3);
        for (d, end) in dev.iter_mut().enumerate() {
            end.send(&Message::RoundOpen { round: d as u32, sync: false }).unwrap();
        }
        let mut fleet = PumpFleet::new(&mut srv, |_| Ok(()));
        for want in 0..3 {
            let got = fleet.recv_any(Some(0.0)).unwrap();
            assert_eq!(got.map(|(d, _)| d), Some(want));
        }
        assert!(fleet.recv_any(Some(0.0)).unwrap().is_none());
        assert_eq!(fleet.now_s(), 0.0);
        // a delayed message is NOT ready at zero timeout
        dev[1].send(&Message::RoundOpen { round: 9, sync: false }).unwrap();
        let mut delayed =
            PumpFleet::with_delays(&mut srv, |_| Ok(()), vec![0.0, 0.5, 0.0], 3);
        assert!(delayed.recv_any(Some(0.0)).unwrap().is_none());
        // but an unbounded wait still surfaces it
        assert_eq!(delayed.recv_any(None).unwrap().map(|(d, _)| d), Some(1));
    }

    #[test]
    fn recv_from_skips_other_devices() {
        let (mut dev, mut srv) = fleet_pair(2);
        dev[0].send(&Message::RoundOpen { round: 0, sync: false }).unwrap();
        dev[1].send(&Message::Shutdown { reason: "x".into() }).unwrap();
        let mut fleet = PumpFleet::new(&mut srv, |_| Ok(()));
        let msg = fleet.recv_from(1).unwrap();
        assert!(matches!(msg, Message::Shutdown { .. }));
        let msg = fleet.recv_from(0).unwrap();
        assert!(matches!(msg, Message::RoundOpen { .. }));
        assert!(fleet.recv_from(0).is_err(), "empty queue cannot block");
    }
}
