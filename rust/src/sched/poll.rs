//! Socket-readiness polling for the event-loop server — `libc` `poll(2)`
//! through a direct FFI declaration, so no async runtime (or even the
//! `libc` crate) is needed. `poll` scales comfortably to the few hundred
//! sockets one `slacc serve` shard handles; an epoll/kqueue backend can
//! slot in behind the same two functions if fleets outgrow it.
//!
//! The API deliberately traffics in `&TcpStream`, not raw fds, so the
//! unix-only fd plumbing stays inside this module. On non-unix targets the
//! functions degrade to a short-sleep busy poll over the non-blocking
//! sockets — correct (reads still return `WouldBlock`), just less
//! efficient.

use std::net::TcpStream;

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_short};

    #[repr(C)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: c_short,
        pub revents: c_short,
    }

    // identical values on linux and macos
    pub const POLLIN: c_short = 0x001;
    pub const POLLOUT: c_short = 0x004;

    #[cfg(target_os = "macos")]
    pub type Nfds = u32;
    #[cfg(not(target_os = "macos"))]
    pub type Nfds = std::os::raw::c_ulong;

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: Nfds, timeout: c_int) -> c_int;
    }
}

/// Block until at least one of `streams` is readable (or has hung up /
/// errored — a subsequent `read` surfaces which), or `timeout_ms` elapses
/// (`-1` = wait forever). Returns one readiness flag per stream; all-false
/// means the timeout expired.
#[cfg(unix)]
pub fn wait_readable(streams: &[&TcpStream], timeout_ms: i32) -> Result<Vec<bool>, String> {
    use std::os::unix::io::AsRawFd;
    if streams.is_empty() {
        if timeout_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(timeout_ms as u64));
        }
        return Ok(Vec::new());
    }
    let mut fds: Vec<sys::PollFd> = streams
        .iter()
        .map(|s| sys::PollFd { fd: s.as_raw_fd(), events: sys::POLLIN, revents: 0 })
        .collect();
    loop {
        let rc = unsafe { sys::poll(fds.as_mut_ptr(), fds.len() as sys::Nfds, timeout_ms) };
        if rc < 0 {
            let e = std::io::Error::last_os_error();
            if e.kind() == std::io::ErrorKind::Interrupted {
                continue; // EINTR: retry (restarting the timeout is fine here)
            }
            return Err(format!("poll: {e}"));
        }
        // POLLHUP/POLLERR also count as "readable": the next read returns
        // 0 or the error, which is exactly how the event loop learns of it
        return Ok(fds.iter().map(|p| p.revents != 0).collect());
    }
}

/// Block until `stream` is writable or `timeout_ms` elapses. Returns
/// whether it became writable.
#[cfg(unix)]
pub fn wait_writable(stream: &TcpStream, timeout_ms: i32) -> Result<bool, String> {
    use std::os::unix::io::AsRawFd;
    let mut fds = [sys::PollFd { fd: stream.as_raw_fd(), events: sys::POLLOUT, revents: 0 }];
    loop {
        let rc = unsafe { sys::poll(fds.as_mut_ptr(), 1 as sys::Nfds, timeout_ms) };
        if rc < 0 {
            let e = std::io::Error::last_os_error();
            if e.kind() == std::io::ErrorKind::Interrupted {
                continue;
            }
            return Err(format!("poll: {e}"));
        }
        return Ok(rc > 0);
    }
}

#[cfg(not(unix))]
pub fn wait_readable(streams: &[&TcpStream], timeout_ms: i32) -> Result<Vec<bool>, String> {
    // busy-poll fallback: report everything "ready"; non-blocking reads
    // sort out who actually has bytes
    let nap = if timeout_ms < 0 { 1 } else { timeout_ms.min(1) as u64 };
    std::thread::sleep(std::time::Duration::from_millis(nap.max(1)));
    Ok(vec![true; streams.len()])
}

#[cfg(not(unix))]
pub fn wait_writable(_stream: &TcpStream, _timeout_ms: i32) -> Result<bool, String> {
    std::thread::sleep(std::time::Duration::from_millis(1));
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::TcpListener;

    #[test]
    fn readiness_tracks_arriving_bytes() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        // nothing written yet: poll times out
        let ready = wait_readable(&[&server], 20).unwrap();
        assert!(!ready.iter().any(|&r| r), "spurious readiness: {ready:?}");

        client.write_all(b"hi").unwrap();
        client.flush().unwrap();
        // bytes in flight: poll must wake up well inside the timeout
        let ready = wait_readable(&[&server], 2000).unwrap();
        assert!(ready[0], "socket with pending bytes not reported readable");
    }

    #[test]
    fn writable_socket_reports_writable() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let _server = listener.accept().unwrap();
        assert!(wait_writable(&client, 1000).unwrap());
    }

    #[test]
    fn hangup_counts_as_readable() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        drop(client);
        let ready = wait_readable(&[&server], 2000).unwrap();
        assert!(ready[0], "hung-up socket must be reported (read will see EOF)");
    }
}
