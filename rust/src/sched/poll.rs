//! Socket-readiness polling for the event-loop server — `libc` `poll(2)`
//! and `epoll(7)` through direct FFI declarations, so no async runtime (or
//! even the `libc` crate) is needed.
//!
//! Two layers live here:
//!
//! * The original free functions [`wait_readable`]/[`wait_writable`] — a
//!   stateless one-shot `poll(2)` over a slice of streams. Still used for
//!   single-socket waits (write parking, client-side receive timeouts).
//! * The [`Poller`] seam — a persistent readiness set with stable integer
//!   tokens, selected by [`Backend`]: edge-triggered `epoll` on linux
//!   (O(ready) dispatch, no per-wakeup allocation), a persistent `poll(2)`
//!   set elsewhere on unix (O(n) kernel scan but zero rebuild cost), and a
//!   busy-poll fallback on non-unix targets. The event loop talks only to
//!   `Poller`, so all three backends drive bit-identical sessions.
//!
//! The API deliberately traffics in `&TcpStream`, not raw fds, so the
//! unix-only fd plumbing stays inside this module (and
//! [`crate::sched::epoll`]).

use std::net::TcpStream;

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_short};

    #[repr(C)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: c_short,
        pub revents: c_short,
    }

    // identical values on linux and macos
    pub const POLLIN: c_short = 0x001;
    pub const POLLOUT: c_short = 0x004;

    #[cfg(target_os = "macos")]
    pub type Nfds = u32;
    #[cfg(not(target_os = "macos"))]
    pub type Nfds = std::os::raw::c_ulong;

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: Nfds, timeout: c_int) -> c_int;
    }
}

/// Block until at least one of `streams` is readable (or has hung up /
/// errored — a subsequent `read` surfaces which), or `timeout_ms` elapses
/// (`-1` = wait forever). Returns one readiness flag per stream; all-false
/// means the timeout expired.
#[cfg(unix)]
pub fn wait_readable(streams: &[&TcpStream], timeout_ms: i32) -> Result<Vec<bool>, String> {
    use std::os::unix::io::AsRawFd;
    if streams.is_empty() {
        if timeout_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(timeout_ms as u64));
        }
        return Ok(Vec::new());
    }
    let mut fds: Vec<sys::PollFd> = streams
        .iter()
        .map(|s| sys::PollFd { fd: s.as_raw_fd(), events: sys::POLLIN, revents: 0 })
        .collect();
    loop {
        let rc = unsafe { sys::poll(fds.as_mut_ptr(), fds.len() as sys::Nfds, timeout_ms) };
        if rc < 0 {
            let e = std::io::Error::last_os_error();
            if e.kind() == std::io::ErrorKind::Interrupted {
                continue; // EINTR: retry (restarting the timeout is fine here)
            }
            return Err(format!("poll: {e}"));
        }
        // POLLHUP/POLLERR also count as "readable": the next read returns
        // 0 or the error, which is exactly how the event loop learns of it
        return Ok(fds.iter().map(|p| p.revents != 0).collect());
    }
}

/// Block until `stream` is writable or `timeout_ms` elapses. Returns
/// whether it became writable.
#[cfg(unix)]
pub fn wait_writable(stream: &TcpStream, timeout_ms: i32) -> Result<bool, String> {
    use std::os::unix::io::AsRawFd;
    let mut fds = [sys::PollFd { fd: stream.as_raw_fd(), events: sys::POLLOUT, revents: 0 }];
    loop {
        let rc = unsafe { sys::poll(fds.as_mut_ptr(), 1 as sys::Nfds, timeout_ms) };
        if rc < 0 {
            let e = std::io::Error::last_os_error();
            if e.kind() == std::io::ErrorKind::Interrupted {
                continue;
            }
            return Err(format!("poll: {e}"));
        }
        return Ok(rc > 0);
    }
}

#[cfg(not(unix))]
pub fn wait_readable(streams: &[&TcpStream], timeout_ms: i32) -> Result<Vec<bool>, String> {
    // busy-poll fallback: report everything "ready"; non-blocking reads
    // sort out who actually has bytes
    let nap = if timeout_ms < 0 { 1 } else { timeout_ms.min(1) as u64 };
    std::thread::sleep(std::time::Duration::from_millis(nap.max(1)));
    Ok(vec![true; streams.len()])
}

#[cfg(not(unix))]
pub fn wait_writable(_stream: &TcpStream, _timeout_ms: i32) -> Result<bool, String> {
    std::thread::sleep(std::time::Duration::from_millis(1));
    Ok(true)
}

/// Which readiness backend drives the event loop. Parsed from
/// `--io-backend`; `Auto` picks the best available for the target.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    /// Edge-triggered epoll on linux, persistent poll elsewhere on unix,
    /// busy-poll on everything else.
    #[default]
    Auto,
    /// Force edge-triggered epoll (linux only — errors elsewhere).
    Epoll,
    /// Force the portable persistent-`poll(2)` set.
    Poll,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Backend, String> {
        match s {
            "auto" => Ok(Backend::Auto),
            "epoll" => Ok(Backend::Epoll),
            "poll" => Ok(Backend::Poll),
            other => Err(format!(
                "unknown io backend {other:?} (expected auto|epoll|poll)"
            )),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Backend::Auto => "auto",
            Backend::Epoll => "epoll",
            Backend::Poll => "poll",
        }
    }
}

#[cfg(target_os = "linux")]
use crate::sched::epoll::Epoll;

enum Imp {
    #[cfg(target_os = "linux")]
    Epoll(Epoll),
    #[cfg(unix)]
    Poll(PollSet),
    #[cfg(not(unix))]
    Busy(BusySet),
}

/// Persistent readiness set over stable caller-chosen tokens.
///
/// Registered streams stay in the set across wakeups; [`Poller::wait`]
/// fills an internal ready list that callers walk via
/// [`Poller::ready_token`]. Backpressure gating goes through
/// [`Poller::mask`]/[`Poller::unmask`]; [`Poller::force_ready`] marks a
/// token ready on the next `wait` regardless of kernel state (used after
/// un-gating so bytes already buffered in userspace are re-serviced even
/// if no new kernel edge fires).
///
/// None of the steady-state methods allocate: the ready/forced lists and
/// the backend's fd tables are reused across wakeups.
pub struct Poller {
    imp: Imp,
    ready: Vec<usize>,
    forced: Vec<usize>,
    armed: usize,
}

impl Poller {
    pub fn new(backend: Backend) -> Result<Poller, String> {
        let imp = match backend {
            #[cfg(target_os = "linux")]
            Backend::Auto | Backend::Epoll => Imp::Epoll(Epoll::new()?),
            #[cfg(all(unix, not(target_os = "linux")))]
            Backend::Auto => Imp::Poll(PollSet::new()),
            #[cfg(not(target_os = "linux"))]
            Backend::Epoll => {
                return Err(
                    "io backend 'epoll' is linux-only; use --io-backend poll".to_string()
                )
            }
            #[cfg(unix)]
            Backend::Poll => Imp::Poll(PollSet::new()),
            #[cfg(not(unix))]
            Backend::Auto | Backend::Poll => Imp::Busy(BusySet::new()),
        };
        Ok(Poller { imp, ready: Vec::new(), forced: Vec::new(), armed: 0 })
    }

    /// Resolved backend name for logs and bench rows.
    pub fn kind(&self) -> &'static str {
        match &self.imp {
            #[cfg(target_os = "linux")]
            Imp::Epoll(_) => "epoll",
            #[cfg(unix)]
            Imp::Poll(_) => "poll",
            #[cfg(not(unix))]
            Imp::Busy(_) => "busy",
        }
    }

    /// Add `stream` to the interest set under `token`. Tokens are caller
    /// state (connection slot indices) and must be unique among armed
    /// entries.
    pub fn register(&mut self, stream: &TcpStream, token: usize) -> Result<(), String> {
        match &mut self.imp {
            #[cfg(target_os = "linux")]
            Imp::Epoll(ep) => ep.add(stream, token)?,
            #[cfg(unix)]
            Imp::Poll(ps) => ps.add(stream, token),
            #[cfg(not(unix))]
            Imp::Busy(bs) => bs.add(token),
        }
        self.armed += 1;
        Ok(())
    }

    /// Remove `stream`/`token` from the set. Tolerates entries that were
    /// never registered or were already masked, so close paths can be
    /// unconditional.
    pub fn deregister(&mut self, stream: &TcpStream, token: usize) -> Result<(), String> {
        let was = match &mut self.imp {
            #[cfg(target_os = "linux")]
            Imp::Epoll(ep) => ep.del(stream)?, // false = ENOENT (never armed)
            #[cfg(unix)]
            Imp::Poll(ps) => ps.remove(stream, token),
            #[cfg(not(unix))]
            Imp::Busy(bs) => bs.remove(token),
        };
        if was && self.armed > 0 {
            self.armed -= 1;
        }
        Ok(())
    }

    /// Stop delivering readiness for `token` (backpressure gate). The
    /// stream stays open; kernel-side bytes back up into the TCP window.
    pub fn mask(&mut self, stream: &TcpStream, token: usize) -> Result<(), String> {
        self.deregister(stream, token)
    }

    /// Re-arm a gated `token`. On epoll the re-`ADD` regenerates an edge if
    /// the socket holds bytes; pair with [`Poller::force_ready`] so data
    /// already drained into userspace is re-serviced too.
    pub fn unmask(&mut self, stream: &TcpStream, token: usize) -> Result<(), String> {
        self.register(stream, token)
    }

    /// Mark `token` ready on the next [`Poller::wait`] regardless of
    /// kernel readiness.
    pub fn force_ready(&mut self, token: usize) {
        self.forced.push(token);
    }

    /// Number of currently armed (registered, unmasked) entries.
    pub fn armed(&self) -> usize {
        self.armed
    }

    /// Whether any force-marked tokens are pending delivery.
    pub fn has_forced(&self) -> bool {
        !self.forced.is_empty()
    }

    /// Wait up to `timeout_ms` (`-1` = forever) for readiness; returns how
    /// many ready tokens can be fetched via [`Poller::ready_token`].
    /// Force-marked tokens are delivered first and turn the wait into a
    /// non-blocking peek.
    pub fn wait(&mut self, timeout_ms: i32) -> Result<usize, String> {
        self.ready.clear();
        self.ready.append(&mut self.forced);
        let timeout_ms = if self.ready.is_empty() { timeout_ms } else { 0 };
        match &mut self.imp {
            #[cfg(target_os = "linux")]
            Imp::Epoll(ep) => ep.wait(timeout_ms, &mut self.ready)?,
            #[cfg(unix)]
            Imp::Poll(ps) => ps.wait(timeout_ms, &mut self.ready)?,
            #[cfg(not(unix))]
            Imp::Busy(bs) => bs.wait(timeout_ms, &mut self.ready),
        }
        Ok(self.ready.len())
    }

    /// The `k`-th ready token from the last [`Poller::wait`].
    pub fn ready_token(&self, k: usize) -> usize {
        self.ready[k]
    }
}

/// Persistent `poll(2)` interest set: the pollfd array survives across
/// wakeups (no per-wakeup rebuild or allocation); the kernel scan stays
/// O(n), which is the cost epoll removes.
#[cfg(unix)]
struct PollSet {
    fds: Vec<sys::PollFd>,
    tokens: Vec<usize>,
}

#[cfg(unix)]
impl PollSet {
    fn new() -> PollSet {
        PollSet { fds: Vec::new(), tokens: Vec::new() }
    }

    fn add(&mut self, stream: &TcpStream, token: usize) {
        use std::os::unix::io::AsRawFd;
        let fd = stream.as_raw_fd();
        // re-adding a known token re-arms it in place
        if let Some(i) = self.tokens.iter().position(|&t| t == token) {
            self.fds[i] = sys::PollFd { fd, events: sys::POLLIN, revents: 0 };
            return;
        }
        self.tokens.push(token);
        self.fds.push(sys::PollFd { fd, events: sys::POLLIN, revents: 0 });
    }

    /// Returns whether the token was present.
    fn remove(&mut self, _stream: &TcpStream, token: usize) -> bool {
        match self.tokens.iter().position(|&t| t == token) {
            Some(i) => {
                self.fds.swap_remove(i);
                self.tokens.swap_remove(i);
                true
            }
            None => false,
        }
    }

    fn wait(&mut self, timeout_ms: i32, out: &mut Vec<usize>) -> Result<(), String> {
        if self.fds.is_empty() {
            if timeout_ms > 0 {
                std::thread::sleep(std::time::Duration::from_millis(timeout_ms as u64));
            }
            return Ok(());
        }
        loop {
            let rc = unsafe {
                sys::poll(self.fds.as_mut_ptr(), self.fds.len() as sys::Nfds, timeout_ms)
            };
            if rc < 0 {
                let e = std::io::Error::last_os_error();
                if e.kind() == std::io::ErrorKind::Interrupted {
                    continue;
                }
                return Err(format!("poll: {e}"));
            }
            for (i, p) in self.fds.iter_mut().enumerate() {
                if p.revents != 0 {
                    out.push(self.tokens[i]);
                    p.revents = 0;
                }
            }
            return Ok(());
        }
    }
}

/// Non-unix fallback: every armed token is "ready" after a 1ms nap;
/// non-blocking reads sort out who actually has bytes.
#[cfg(not(unix))]
struct BusySet {
    tokens: Vec<usize>,
}

#[cfg(not(unix))]
impl BusySet {
    fn new() -> BusySet {
        BusySet { tokens: Vec::new() }
    }

    fn add(&mut self, token: usize) {
        if !self.tokens.contains(&token) {
            self.tokens.push(token);
        }
    }

    fn remove(&mut self, token: usize) -> bool {
        match self.tokens.iter().position(|&t| t == token) {
            Some(i) => {
                self.tokens.swap_remove(i);
                true
            }
            None => false,
        }
    }

    fn wait(&mut self, timeout_ms: i32, out: &mut Vec<usize>) {
        let nap = if timeout_ms < 0 { 1 } else { (timeout_ms as u64).min(1) };
        std::thread::sleep(std::time::Duration::from_millis(nap.max(1)));
        out.extend_from_slice(&self.tokens);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::TcpListener;

    #[test]
    fn readiness_tracks_arriving_bytes() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        // nothing written yet: poll times out
        let ready = wait_readable(&[&server], 20).unwrap();
        assert!(!ready.iter().any(|&r| r), "spurious readiness: {ready:?}");

        client.write_all(b"hi").unwrap();
        client.flush().unwrap();
        // bytes in flight: poll must wake up well inside the timeout
        let ready = wait_readable(&[&server], 2000).unwrap();
        assert!(ready[0], "socket with pending bytes not reported readable");
    }

    #[test]
    fn writable_socket_reports_writable() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let _server = listener.accept().unwrap();
        assert!(wait_writable(&client, 1000).unwrap());
    }

    #[test]
    fn hangup_counts_as_readable() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        drop(client);
        let ready = wait_readable(&[&server], 2000).unwrap();
        assert!(ready[0], "hung-up socket must be reported (read will see EOF)");
    }

    fn pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        (client, server)
    }

    fn backends_under_test() -> Vec<Backend> {
        #[cfg(target_os = "linux")]
        return vec![Backend::Epoll, Backend::Poll];
        #[cfg(not(target_os = "linux"))]
        vec![Backend::Poll]
    }

    #[test]
    fn poller_reports_ready_tokens_on_every_backend() {
        for backend in backends_under_test() {
            let (mut c0, s0) = pair();
            let (_c1, s1) = pair();
            let mut p = Poller::new(backend).unwrap();
            p.register(&s0, 10).unwrap();
            p.register(&s1, 20).unwrap();
            assert_eq!(p.armed(), 2);

            let n = p.wait(20).unwrap();
            assert_eq!(n, 0, "{}: quiet sockets reported ready", p.kind());

            c0.write_all(b"hi").unwrap();
            let n = p.wait(2000).unwrap();
            let ready: Vec<usize> = (0..n).map(|k| p.ready_token(k)).collect();
            assert!(
                ready.contains(&10) && !ready.contains(&20),
                "{}: got {ready:?}, want [10]",
                p.kind()
            );
        }
    }

    #[test]
    fn poller_mask_gates_and_unmask_rearms() {
        for backend in backends_under_test() {
            let (mut c, s) = pair();
            let mut p = Poller::new(backend).unwrap();
            p.register(&s, 5).unwrap();
            c.write_all(b"x").unwrap();
            assert_eq!(p.wait(2000).unwrap(), 1, "{}", p.kind());

            // gate without draining: no wakeups even though bytes pend
            p.mask(&s, 5).unwrap();
            assert_eq!(p.armed(), 0);
            assert_eq!(p.wait(20).unwrap(), 0, "{}: masked token woke up", p.kind());

            // un-gate: pending bytes must surface again
            p.unmask(&s, 5).unwrap();
            assert_eq!(p.armed(), 1);
            let n = p.wait(2000).unwrap();
            assert!(n >= 1, "{}: unmasked token never re-fired", p.kind());
            assert_eq!(p.ready_token(0), 5);
        }
    }

    #[test]
    fn poller_force_ready_preempts_the_wait() {
        for backend in backends_under_test() {
            let (_c, s) = pair();
            let mut p = Poller::new(backend).unwrap();
            p.register(&s, 9).unwrap();
            p.force_ready(9);
            let start = std::time::Instant::now();
            let n = p.wait(5_000).unwrap();
            assert!(n >= 1, "{}", p.kind());
            assert_eq!(p.ready_token(0), 9);
            assert!(
                start.elapsed() < std::time::Duration::from_secs(2),
                "{}: forced token did not shortcut the timeout",
                p.kind()
            );
        }
    }

    #[test]
    fn poller_deregister_tolerates_unknown_tokens() {
        for backend in backends_under_test() {
            let (_c, s) = pair();
            let mut p = Poller::new(backend).unwrap();
            p.deregister(&s, 3).unwrap(); // never registered
            p.register(&s, 3).unwrap();
            p.deregister(&s, 3).unwrap();
            assert_eq!(p.armed(), 0, "{}", p.kind());
        }
    }

    #[test]
    fn stray_deregister_does_not_decrement_armed() {
        // a deregister of a never-registered stream must not eat an armed
        // slot: armed()==0 short-circuits poll_step into a no-sleep return,
        // so an undercount would busy-spin the event loop at 100% CPU
        for backend in backends_under_test() {
            let (_c0, s0) = pair();
            let (_c1, s1) = pair();
            let (_c2, stray) = pair();
            let mut p = Poller::new(backend).unwrap();
            p.register(&s0, 0).unwrap();
            p.register(&s1, 1).unwrap();
            p.deregister(&stray, 2).unwrap(); // ENOENT / unknown token
            assert_eq!(p.armed(), 2, "{}: stray deregister ate an armed slot", p.kind());
        }
    }

    #[cfg(not(target_os = "linux"))]
    #[test]
    fn epoll_backend_errors_off_linux() {
        assert!(Poller::new(Backend::Epoll).is_err());
    }

    #[test]
    fn backend_parses_and_round_trips() {
        assert_eq!(Backend::parse("auto").unwrap(), Backend::Auto);
        assert_eq!(Backend::parse("epoll").unwrap(), Backend::Epoll);
        assert_eq!(Backend::parse("poll").unwrap(), Backend::Poll);
        assert!(Backend::parse("kqueue").is_err());
        for b in [Backend::Auto, Backend::Epoll, Backend::Poll] {
            assert_eq!(Backend::parse(b.as_str()).unwrap(), b);
        }
    }
}
