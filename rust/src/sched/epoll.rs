//! Edge-triggered `epoll` readiness backend — `libc` `epoll(7)` through
//! direct FFI declarations, matching the no-async-runtime (and no `libc`
//! crate) discipline of [`crate::sched::poll`].
//!
//! Where `poll(2)` hands the kernel the whole interest set on every call
//! and scans O(n) revents back out, epoll keeps the interest set *in the
//! kernel*: registration happens once per connection
//! ([`Epoll::add`]/[`Epoll::del`]) and each [`Epoll::wait`] returns only
//! the fds that actually transitioned — O(ready) per wakeup regardless of
//! fleet size. With `EPOLLET` (edge triggering) a readiness event fires
//! once per transition, so the caller must drain the socket to
//! `WouldBlock` before waiting again; [`crate::sched::event_loop`] already
//! drains on every wakeup, which is exactly the ET contract.
//!
//! Backpressure gating uses `EPOLL_CTL_DEL` + re-`ADD`: re-adding an fd
//! whose socket already holds bytes generates a fresh edge, and the event
//! loop additionally force-marks re-armed tokens ready so bytes parked in
//! the decode ring are never stranded waiting for a new kernel edge.

use std::net::TcpStream;
use std::os::raw::c_int;
use std::os::unix::io::{AsRawFd, RawFd};

mod sys {
    use std::os::raw::c_int;

    // the x86_64 kernel ABI packs epoll_event to 12 bytes; other
    // architectures use natural alignment — mirror the UAPI header
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLRDHUP: u32 = 0x2000;
    pub const EPOLLET: u32 = 1 << 31;

    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;

    pub const EPOLL_CLOEXEC: c_int = 0o2000000;

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(
            epfd: c_int,
            op: c_int,
            fd: c_int,
            event: *mut EpollEvent,
        ) -> c_int;
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        pub fn close(fd: c_int) -> c_int;
    }
}

/// Ready events fetched per `epoll_wait` call. Dispatch is O(ready), so a
/// burst wider than this simply drains over consecutive wakeups.
const EVENTS_CAP: usize = 1024;

/// An edge-triggered epoll instance holding the kernel-side interest set.
pub struct Epoll {
    epfd: RawFd,
    /// reusable event buffer — no per-wakeup allocation
    events: Vec<sys::EpollEvent>,
}

impl Epoll {
    pub fn new() -> Result<Epoll, String> {
        let epfd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(format!("epoll_create1: {}", std::io::Error::last_os_error()));
        }
        Ok(Epoll {
            epfd,
            events: vec![sys::EpollEvent { events: 0, data: 0 }; EVENTS_CAP],
        })
    }

    /// Register `stream` for edge-triggered read readiness under `token`.
    /// HUP/ERR conditions are always delivered regardless of the mask, so a
    /// hang-up surfaces as a readiness event whose subsequent read sees EOF.
    pub fn add(&mut self, stream: &TcpStream, token: usize) -> Result<(), String> {
        let mut ev = sys::EpollEvent {
            events: sys::EPOLLIN | sys::EPOLLRDHUP | sys::EPOLLET,
            data: token as u64,
        };
        let rc = unsafe {
            sys::epoll_ctl(self.epfd, sys::EPOLL_CTL_ADD, stream.as_raw_fd(), &mut ev)
        };
        if rc < 0 {
            return Err(format!("epoll_ctl(ADD): {}", std::io::Error::last_os_error()));
        }
        Ok(())
    }

    /// Remove `stream` from the interest set. Removing an fd that is not
    /// registered (ENOENT) is tolerated so close paths can be
    /// unconditional; the return says whether the fd was actually removed
    /// (`false` = it was never in the set) so callers can keep their armed
    /// count honest.
    pub fn del(&mut self, stream: &TcpStream) -> Result<bool, String> {
        let mut ev = sys::EpollEvent { events: 0, data: 0 };
        let rc = unsafe {
            sys::epoll_ctl(self.epfd, sys::EPOLL_CTL_DEL, stream.as_raw_fd(), &mut ev)
        };
        if rc < 0 {
            let e = std::io::Error::last_os_error();
            if e.raw_os_error() == Some(2) {
                return Ok(false); // ENOENT: already gone
            }
            return Err(format!("epoll_ctl(DEL): {e}"));
        }
        Ok(true)
    }

    /// Wait up to `timeout_ms` (-1 = forever) and append the tokens of
    /// every ready fd to `out`. EINTR restarts the wait.
    pub fn wait(&mut self, timeout_ms: i32, out: &mut Vec<usize>) -> Result<(), String> {
        loop {
            let rc = unsafe {
                sys::epoll_wait(
                    self.epfd,
                    self.events.as_mut_ptr(),
                    self.events.len() as c_int,
                    timeout_ms,
                )
            };
            if rc < 0 {
                let e = std::io::Error::last_os_error();
                if e.kind() == std::io::ErrorKind::Interrupted {
                    continue;
                }
                return Err(format!("epoll_wait: {e}"));
            }
            for ev in self.events.iter().take(rc as usize) {
                // value read of a packed field (no reference taken)
                let token = ev.data;
                out.push(token as usize);
            }
            return Ok(());
        }
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe {
            sys::close(self.epfd);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::TcpListener;

    fn pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        (client, server)
    }

    #[test]
    fn edge_fires_once_per_transition_and_rearms_after_drain() {
        let (mut client, mut server) = pair();
        let mut ep = Epoll::new().unwrap();
        ep.add(&server, 7).unwrap();
        let mut ready = Vec::new();

        // quiet socket: timeout, no events
        ep.wait(20, &mut ready).unwrap();
        assert!(ready.is_empty(), "spurious readiness: {ready:?}");

        client.write_all(b"x").unwrap();
        ep.wait(2000, &mut ready).unwrap();
        assert_eq!(ready, vec![7]);

        // edge triggering: without a drain + new bytes, no second event
        ready.clear();
        ep.wait(20, &mut ready).unwrap();
        assert!(ready.is_empty(), "ET must not re-report undrained data");

        // drain, write again: a fresh edge fires
        let mut buf = [0u8; 8];
        let n = server.read(&mut buf).unwrap();
        assert_eq!(n, 1);
        client.write_all(b"y").unwrap();
        ready.clear();
        ep.wait(2000, &mut ready).unwrap();
        assert_eq!(ready, vec![7]);
    }

    #[test]
    fn del_then_add_regenerates_the_edge_for_pending_bytes() {
        let (mut client, server) = pair();
        let mut ep = Epoll::new().unwrap();
        ep.add(&server, 3).unwrap();
        let mut ready = Vec::new();

        client.write_all(b"abc").unwrap();
        ep.wait(2000, &mut ready).unwrap();
        assert_eq!(ready, vec![3]);

        // gate (DEL) without draining, then re-arm (ADD): the pending
        // bytes must produce a fresh edge — this is the backpressure
        // un-gate path of the event loop
        ep.del(&server).unwrap();
        ep.add(&server, 3).unwrap();
        ready.clear();
        ep.wait(2000, &mut ready).unwrap();
        assert_eq!(ready, vec![3], "re-ADD with buffered bytes must fire");
    }

    #[test]
    fn hangup_surfaces_as_readiness() {
        let (client, server) = pair();
        let mut ep = Epoll::new().unwrap();
        ep.add(&server, 1).unwrap();
        drop(client);
        let mut ready = Vec::new();
        ep.wait(2000, &mut ready).unwrap();
        assert_eq!(ready, vec![1], "hung-up socket must be reported (read sees EOF)");
    }

    #[test]
    fn double_del_is_tolerated_and_reported() {
        let (_client, server) = pair();
        let mut ep = Epoll::new().unwrap();
        ep.add(&server, 0).unwrap();
        assert!(ep.del(&server).unwrap(), "first DEL removed a registered fd");
        // ENOENT swallowed, but reported so armed counts stay honest
        assert!(!ep.del(&server).unwrap(), "second DEL must report not-present");
    }
}
