//! Scale-soak harness: drive one [`crate::sched::event_loop::PollFleet`]
//! with hundreds-to-thousands of scripted mock devices and report
//! per-device wire statistics, so integration tests and the
//! `benches/event_loop.rs` scale curve can assert byte parity across I/O
//! backends and fleet sizes.
//!
//! The protocol is a miniature of the real serve loop with fully
//! deterministic payloads:
//!
//! 1. every device connects and Hellos; the server HelloAcks each slot;
//! 2. per round, the server RoundOpens every device, then `recv_any`s one
//!    Activations frame per device (payload is a [`Pcg32`] pattern keyed
//!    by `(device, round)`, verified byte-for-byte on receipt) and
//!    immediately answers it with a Gradients frame carrying the
//!    downlink pattern (verified on the device side);
//! 3. after the last round every device gets a Shutdown.
//!
//! Because every device exchanges frames of identical sizes, every
//! per-device [`WireStats`] in a clean run is identical — to every other
//! device in the same run, to the same run on the other I/O backend, and
//! to a smaller reference fleet. That single `==` is the parity
//! assertion the integration soak tests lean on.
//!
//! Devices are scripted blocking [`TcpTransport`]s multiplexed over a
//! small pool of driver threads (device `d` belongs to thread
//! `d % driver_threads`), so a 1024-device soak does not need 1024 OS
//! threads. An optional slow reader — one device that sleeps before
//! reading its round-0 downlink — exercises the server's write-park path
//! under fleet load.
//!
//! [`run_churn_soak`] is the elastic variant: the same echo protocol on a
//! fleet with `FleetOptions::elastic`, plus a membership script — devices
//! killed right after receiving a RoundOpen (with or without a `Leave`
//! notice, so both the graceful and the mid-frame hang-up paths run) and
//! later re-admitted through the proto-v6 `Join`/`JoinAck`/`Catchup`
//! handshake at a scripted round boundary. Because the script pins every
//! membership event to a round, the exact per-device frame counts are
//! computable ([`ChurnSoakConfig::expected_frames`]) and identical across
//! I/O backends, which is what the churn integration soak asserts.

use std::net::TcpListener;
use std::thread;
use std::time::{Duration, Instant};

use crate::member::{JoinRequest, MembershipTable};
use crate::sched::event_loop::{FleetOptions, PollFleet};
use crate::sched::fleet::Fleet;
use crate::shard::FleetShape;
use crate::transport::proto::Message;
use crate::transport::tcp::TcpTransport;
use crate::transport::{Transport, WireStats};
use crate::util::rng::Pcg32;

/// Pcg32 stream ids for the two payload directions, so the uplink and
/// downlink patterns for the same `(device, round)` never coincide.
const STREAM_UP: u64 = 0x5eed_0001;
const STREAM_DOWN: u64 = 0x5eed_0002;

/// Server side gives up if the fleet delivers nothing for this long —
/// turns a deadlocked soak into a failed test instead of a hung one.
const RECV_TIMEOUT_S: f64 = 60.0;

/// One scale-soak run: fleet size, traffic shape, and I/O backend.
#[derive(Debug, Clone)]
pub struct SoakConfig {
    /// Devices in the fleet (each is one real TCP connection).
    pub devices: usize,
    /// Rounds of RoundOpen → Activations → Gradients echo.
    pub rounds: usize,
    /// Uplink (Activations) payload bytes per device per round.
    pub up_bytes: usize,
    /// Downlink (Gradients) payload bytes per device per round.
    pub down_bytes: usize,
    /// Event-loop options for the server under test.
    pub opts: FleetOptions,
    /// Client driver threads; devices are striped across them.
    pub driver_threads: usize,
    /// `(device, pause_ms)`: that device sleeps `pause_ms` before reading
    /// its round-0 Gradients, backing the server's write up against a
    /// full TCP window.
    pub slow_reader: Option<(usize, u64)>,
}

impl SoakConfig {
    /// A small clean-echo soak; callers override fields as needed.
    pub fn new(devices: usize, rounds: usize) -> SoakConfig {
        SoakConfig {
            devices,
            rounds,
            up_bytes: 96,
            down_bytes: 128,
            opts: FleetOptions::default(),
            driver_threads: 8,
            slow_reader: None,
        }
    }
}

/// What a soak run measured.
#[derive(Debug, Clone)]
pub struct SoakReport {
    /// Which readiness backend actually served the run.
    pub backend: &'static str,
    /// Wall-clock seconds from HelloAck to the last Shutdown sent.
    pub wall_s: f64,
    /// Per-device framed-byte accounting, indexed by device id. In a
    /// clean run every entry is identical — the parity invariant. A churn
    /// run's entries differ per device but are still exactly computable
    /// from the script ([`ChurnSoakConfig::expected_frames`]).
    pub per_device: Vec<WireStats>,
    /// `(device, graceful)` for every departure the server observed,
    /// sorted by device id. Empty on a clean [`run_soak`].
    pub departures: Vec<(usize, bool)>,
}

/// Deterministic payload for one direction of one `(device, round)` step.
fn pattern(device: usize, round: usize, len: usize, stream: u64) -> Vec<u8> {
    let mut rng = Pcg32::new(((device as u64) << 32) | round as u64, stream);
    let mut buf = vec![0u8; len];
    for b in buf.iter_mut() {
        *b = rng.next_u32() as u8;
    }
    buf
}

fn hello_for(device: usize, devices: usize) -> Message {
    let specs = crate::codecs::stream::StreamSpecs::parse("identity", "identity", "identity")
        .expect("identity stream specs always parse");
    Message::Hello {
        device_id: device as u32,
        devices: devices as u32,
        shard_len: 8,
        config_fp: 1,
        uplink: specs.uplink.as_str().to_string(),
        downlink: specs.downlink.as_str().to_string(),
        sync: specs.sync.as_str().to_string(),
        streams_fp: specs.fingerprint(),
    }
}

/// Drive the devices striped onto one client thread through the whole
/// scripted session.
fn drive_clients(tid: usize, addr: String, cfg: SoakConfig) -> Result<(), String> {
    let mine: Vec<usize> =
        (0..cfg.devices).filter(|d| d % cfg.driver_threads == tid).collect();
    let mut conns = Vec::with_capacity(mine.len());
    for &d in &mine {
        let mut conn = TcpTransport::connect(&addr)?;
        conn.send(&hello_for(d, cfg.devices))
            .map_err(|e| format!("device {d}: hello send: {e}"))?;
        conns.push(conn);
    }
    for (k, &d) in mine.iter().enumerate() {
        match conns[k].recv().map_err(|e| format!("device {d}: hello ack: {e}"))? {
            Message::HelloAck { device_id, .. } if device_id as usize == d => {}
            other => {
                return Err(format!(
                    "device {d}: expected HelloAck, got {}",
                    other.type_name()
                ))
            }
        }
    }
    for r in 0..cfg.rounds {
        for (k, &d) in mine.iter().enumerate() {
            match conns[k].recv().map_err(|e| format!("device {d}: round open: {e}"))? {
                Message::RoundOpen { round, .. } if round as usize == r => {}
                other => {
                    return Err(format!(
                        "device {d} round {r}: expected RoundOpen, got {}",
                        other.type_name()
                    ))
                }
            }
            conns[k]
                .send(&Message::Activations {
                    round: r as u32,
                    device_id: d as u32,
                    labels: Vec::new(),
                    payload: pattern(d, r, cfg.up_bytes, STREAM_UP),
                })
                .map_err(|e| format!("device {d} round {r}: activations: {e}"))?;
            if r == 0 {
                if let Some((slow, pause_ms)) = cfg.slow_reader {
                    if slow == d {
                        thread::sleep(Duration::from_millis(pause_ms));
                    }
                }
            }
            match conns[k]
                .recv()
                .map_err(|e| format!("device {d} round {r}: gradients: {e}"))?
            {
                Message::Gradients { round, device_id, payload, .. } => {
                    if round as usize != r || device_id as usize != d {
                        return Err(format!(
                            "device {d} round {r}: gradients addressed to \
                             device {device_id} round {round}"
                        ));
                    }
                    if payload != pattern(d, r, cfg.down_bytes, STREAM_DOWN) {
                        return Err(format!(
                            "device {d} round {r}: downlink payload corrupted"
                        ));
                    }
                }
                other => {
                    return Err(format!(
                        "device {d} round {r}: expected Gradients, got {}",
                        other.type_name()
                    ))
                }
            }
        }
    }
    for (k, &d) in mine.iter().enumerate() {
        match conns[k].recv().map_err(|e| format!("device {d}: shutdown: {e}"))? {
            Message::Shutdown { .. } => {}
            other => {
                return Err(format!(
                    "device {d}: expected Shutdown, got {}",
                    other.type_name()
                ))
            }
        }
    }
    Ok(())
}

/// Run one scripted soak session: spawn the client driver pool, serve the
/// fleet from this thread, and return per-device wire accounting.
pub fn run_soak(cfg: &SoakConfig) -> Result<SoakReport, String> {
    if cfg.devices == 0 || cfg.rounds == 0 {
        return Err("soak needs at least one device and one round".to_string());
    }
    let listener =
        TcpListener::bind("127.0.0.1:0").map_err(|e| format!("soak bind: {e}"))?;
    let addr = listener
        .local_addr()
        .map_err(|e| format!("soak addr: {e}"))?
        .to_string();

    let threads = cfg.driver_threads.clamp(1, cfg.devices);
    let mut run_cfg = cfg.clone();
    run_cfg.driver_threads = threads;
    let mut handles = Vec::with_capacity(threads);
    for tid in 0..threads {
        let addr = addr.clone();
        let cfg = run_cfg.clone();
        handles.push(
            thread::Builder::new()
                .name(format!("soak-drv-{tid}"))
                .spawn(move || drive_clients(tid, addr, cfg))
                .map_err(|e| format!("soak driver spawn: {e}"))?,
        );
    }

    let serve = serve_soak(&listener, &run_cfg);

    let mut client_err = None;
    for handle in handles {
        match handle.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                client_err.get_or_insert(e);
            }
            Err(_) => {
                client_err.get_or_insert("soak driver panicked".to_string());
            }
        }
    }
    let report = serve?;
    if let Some(e) = client_err {
        return Err(format!("soak client: {e}"));
    }
    Ok(report)
}

/// The server half of [`run_soak`]: echo the scripted session over a
/// [`PollFleet`] and account every device's traffic.
fn serve_soak(listener: &TcpListener, cfg: &SoakConfig) -> Result<SoakReport, String> {
    let devices = cfg.devices;
    let shape = FleetShape::flat(devices);
    let (mut fleet, _hellos) = PollFleet::accept_with(listener, shape, cfg.opts)?;
    let backend = fleet.backend_kind();
    let start = Instant::now();
    for d in 0..devices {
        fleet
            .send(
                d,
                &Message::HelloAck {
                    device_id: d as u32,
                    rounds: cfg.rounds as u32,
                    agg_every: 1,
                },
            )
            .map_err(|e| format!("hello ack to {d}: {e}"))?;
    }
    for r in 0..cfg.rounds {
        for d in 0..devices {
            fleet
                .send(d, &Message::RoundOpen { round: r as u32, sync: false })
                .map_err(|e| format!("round open {r} to {d}: {e}"))?;
        }
        let mut seen = vec![false; devices];
        for _ in 0..devices {
            let (d, msg) = fleet
                .recv_any(Some(RECV_TIMEOUT_S))
                .map_err(|e| format!("round {r}: {e}"))?
                .ok_or_else(|| {
                    format!("round {r}: fleet went quiet for {RECV_TIMEOUT_S}s")
                })?;
            match msg {
                Message::Activations { round, device_id, payload, .. } => {
                    if round as usize != r || device_id as usize != d {
                        return Err(format!(
                            "round {r}: slot {d} delivered activations for \
                             device {device_id} round {round}"
                        ));
                    }
                    if payload != pattern(d, r, cfg.up_bytes, STREAM_UP) {
                        return Err(format!(
                            "round {r}: device {d} uplink payload corrupted"
                        ));
                    }
                }
                other => {
                    return Err(format!(
                        "round {r}: expected Activations from {d}, got {}",
                        other.type_name()
                    ))
                }
            }
            if seen[d] {
                return Err(format!("round {r}: device {d} delivered twice"));
            }
            seen[d] = true;
            fleet
                .send(
                    d,
                    &Message::Gradients {
                        round: r as u32,
                        device_id: d as u32,
                        loss: 0.0,
                        payload: pattern(d, r, cfg.down_bytes, STREAM_DOWN),
                    },
                )
                .map_err(|e| format!("gradients {r} to {d}: {e}"))?;
        }
    }
    for d in 0..devices {
        fleet
            .send(d, &Message::Shutdown { reason: "soak complete".to_string() })
            .map_err(|e| format!("shutdown to {d}: {e}"))?;
    }
    let wall_s = start.elapsed().as_secs_f64();
    let per_device = (0..devices).map(|d| fleet.stats(d)).collect();
    Ok(SoakReport { backend, wall_s, per_device, departures: Vec::new() })
}

/// Membership script for one elastic soak: scripted departures and
/// re-admissions pinned to round numbers, so the session's wire traffic
/// is deterministic device-by-device.
#[derive(Debug, Clone)]
pub struct ChurnSoakConfig {
    /// The underlying echo session. `opts.elastic` is forced on by the
    /// server; `driver_threads` and `slow_reader` are ignored (every
    /// churn device gets its own driver thread, because a device parked
    /// in a re-join handshake must not stall its thread-mates).
    pub base: SoakConfig,
    /// `(round, device, graceful)`: the device hangs up right after
    /// receiving that round's RoundOpen — with a `Leave` notice first
    /// when `graceful`, abruptly otherwise — leaving the server's
    /// RoundOpen to a dead peer and its own Activations unsent.
    pub kills: Vec<(usize, usize, bool)>,
    /// `(round, device)`: a fresh process for a killed device `Join`s and
    /// is admitted at that round's boundary (must be after the kill).
    pub rejoins: Vec<(usize, usize)>,
}

/// Per-device view of the churn script, derived once and validated.
#[derive(Debug, Clone, Copy, Default)]
struct DeviceScript {
    /// `(round, graceful)` of this device's scripted hang-up
    kill: Option<(usize, bool)>,
    /// round boundary where a fresh incarnation is admitted
    rejoin: Option<usize>,
}

impl ChurnSoakConfig {
    fn scripts(&self) -> Result<Vec<DeviceScript>, String> {
        let (devices, rounds) = (self.base.devices, self.base.rounds);
        let mut scripts = vec![DeviceScript::default(); devices];
        for &(round, device, graceful) in &self.kills {
            if device >= devices || round >= rounds {
                return Err(format!(
                    "kill ({round}, {device}) outside a {devices}x{rounds} session"
                ));
            }
            if scripts[device].kill.is_some() {
                return Err(format!("device {device} killed twice"));
            }
            scripts[device].kill = Some((round, graceful));
        }
        for &(round, device) in &self.rejoins {
            if device >= devices || round >= rounds {
                return Err(format!(
                    "rejoin ({round}, {device}) outside a {devices}x{rounds} session"
                ));
            }
            let Some((killed_at, _)) = scripts[device].kill else {
                return Err(format!("device {device} rejoins without a kill"));
            };
            if round <= killed_at {
                return Err(format!(
                    "device {device} rejoins at round {round}, not after its \
                     kill at round {killed_at}"
                ));
            }
            if scripts[device].rejoin.is_some() {
                return Err(format!("device {device} rejoins twice"));
            }
            scripts[device].rejoin = Some(round);
        }
        Ok(scripts)
    }

    /// Exact `(frames_sent, frames_recv)` the server's per-slot
    /// [`WireStats`] must show for `device` after a clean churn run —
    /// counted from the server's side of the wire, derived purely from
    /// the script. Panics on an invalid script (validate via
    /// [`run_churn_soak`] first).
    pub fn expected_frames(&self, device: usize) -> (u64, u64) {
        let s = self.scripts().expect("churn script validated")[device];
        let rounds = self.base.rounds as u64;
        match (s.kill, s.rejoin) {
            // HelloAck + per-round RoundOpen/Gradients + Shutdown;
            // Hello + per-round Activations
            (None, _) => (2 + 2 * rounds, 1 + rounds),
            (Some((k, graceful)), rejoin) => {
                let k = k as u64;
                // up to the kill: HelloAck, k+1 RoundOpens (the kill
                // round's RoundOpen is received before the hang-up),
                // k Gradients; Hello, k Activations, the Leave notice
                // when graceful. No Shutdown to a vacant slot.
                let mut sent = 1 + (k + 1) + k;
                let mut recv = 1 + k + graceful as u64;
                if let Some(rj) = rejoin {
                    let rj = rj as u64;
                    // JoinAck + Catchup, the remaining rounds, Shutdown;
                    // the Join frame and the remaining Activations
                    sent += 2 + 2 * (rounds - rj) + 1;
                    recv += 1 + (rounds - rj);
                }
                (sent, recv)
            }
        }
    }
}

/// The proto-v6 re-join opening for a fresh soak-device process: same
/// stream table and fingerprint as [`hello_for`], claiming member epoch 0.
fn join_for(device: usize, devices: usize) -> Message {
    let specs = crate::codecs::stream::StreamSpecs::parse("identity", "identity", "identity")
        .expect("identity stream specs always parse");
    Message::Join {
        device_id: device as u32,
        devices: devices as u32,
        shard_len: 8,
        config_fp: 1,
        member_epoch: 0,
        uplink: specs.uplink.as_str().to_string(),
        downlink: specs.downlink.as_str().to_string(),
        sync: specs.sync.as_str().to_string(),
        streams_fp: specs.fingerprint(),
    }
}

/// Drive one churn-soak device through its scripted life: the initial
/// incarnation up to the kill (or the whole session), then optionally a
/// fresh incarnation that `Join`s and serves the remaining rounds.
fn drive_churn_device(
    d: usize,
    addr: &str,
    cfg: &ChurnSoakConfig,
    script: DeviceScript,
) -> Result<(), String> {
    let base = &cfg.base;
    let echo_round = |conn: &mut TcpTransport, r: usize| -> Result<(), String> {
        conn.send(&Message::Activations {
            round: r as u32,
            device_id: d as u32,
            labels: Vec::new(),
            payload: pattern(d, r, base.up_bytes, STREAM_UP),
        })
        .map_err(|e| format!("device {d} round {r}: activations: {e}"))?;
        match conn
            .recv()
            .map_err(|e| format!("device {d} round {r}: gradients: {e}"))?
        {
            Message::Gradients { round, device_id, payload, .. } => {
                if round as usize != r || device_id as usize != d {
                    return Err(format!(
                        "device {d} round {r}: gradients addressed to device \
                         {device_id} round {round}"
                    ));
                }
                if payload != pattern(d, r, base.down_bytes, STREAM_DOWN) {
                    return Err(format!("device {d} round {r}: downlink corrupted"));
                }
                Ok(())
            }
            other => Err(format!(
                "device {d} round {r}: expected Gradients, got {}",
                other.type_name()
            )),
        }
    };
    // first incarnation: scoped so the socket is closed (the scripted
    // hang-up) before the re-join incarnation dials back in
    {
        let mut conn = TcpTransport::connect(addr)?;
        conn.send(&hello_for(d, base.devices))
            .map_err(|e| format!("device {d}: hello send: {e}"))?;
        match conn.recv().map_err(|e| format!("device {d}: hello ack: {e}"))? {
            Message::HelloAck { device_id, .. } if device_id as usize == d => {}
            other => {
                return Err(format!(
                    "device {d}: expected HelloAck, got {}",
                    other.type_name()
                ))
            }
        }
        let mut hung_up = false;
        for r in 0..base.rounds {
            match conn.recv().map_err(|e| format!("device {d}: round open: {e}"))? {
                Message::RoundOpen { round, .. } if round as usize == r => {}
                other => {
                    return Err(format!(
                        "device {d} round {r}: expected RoundOpen, got {}",
                        other.type_name()
                    ))
                }
            }
            if let Some((kill_round, graceful)) = script.kill {
                if r == kill_round {
                    if graceful {
                        conn.send(&Message::Leave {
                            device_id: d as u32,
                            reason: "scripted departure".to_string(),
                        })
                        .map_err(|e| format!("device {d}: leave: {e}"))?;
                    }
                    hung_up = true;
                    break;
                }
            }
            echo_round(&mut conn, r)?;
        }
        if !hung_up {
            match conn.recv().map_err(|e| format!("device {d}: shutdown: {e}"))? {
                Message::Shutdown { .. } => {}
                other => {
                    return Err(format!(
                        "device {d}: expected Shutdown, got {}",
                        other.type_name()
                    ))
                }
            }
        }
    }
    let Some(rejoin_round) = script.rejoin else { return Ok(()) };
    // second incarnation: a fresh process claiming member epoch 0
    let mut conn = TcpTransport::connect(addr)?;
    conn.send(&join_for(d, base.devices))
        .map_err(|e| format!("device {d}: join send: {e}"))?;
    match conn.recv().map_err(|e| format!("device {d}: join ack: {e}"))? {
        Message::JoinAck { device_id, round, member_epoch, .. } => {
            if device_id as usize != d {
                return Err(format!("device {d}: JoinAck addressed to {device_id}"));
            }
            if round as usize != rejoin_round {
                return Err(format!(
                    "device {d}: admitted at round {round}, script says \
                     {rejoin_round}"
                ));
            }
            if member_epoch == 0 {
                return Err(format!("device {d}: re-admission kept epoch 0"));
            }
        }
        other => {
            return Err(format!(
                "device {d}: expected JoinAck, got {}",
                other.type_name()
            ))
        }
    }
    match conn.recv().map_err(|e| format!("device {d}: catchup: {e}"))? {
        Message::Catchup { device_id, payload, .. } => {
            if device_id as usize != d || !payload.is_empty() {
                return Err(format!(
                    "device {d}: bad Catchup (addressed to {device_id}, {} \
                     payload bytes — the soak has no model)",
                    payload.len()
                ));
            }
        }
        other => {
            return Err(format!(
                "device {d}: expected Catchup, got {}",
                other.type_name()
            ))
        }
    }
    for r in rejoin_round..base.rounds {
        match conn.recv().map_err(|e| format!("device {d}: round open: {e}"))? {
            Message::RoundOpen { round, .. } if round as usize == r => {}
            other => {
                return Err(format!(
                    "device {d} round {r}: expected RoundOpen after re-join, \
                     got {}",
                    other.type_name()
                ))
            }
        }
        echo_round(&mut conn, r)?;
    }
    match conn.recv().map_err(|e| format!("device {d}: shutdown: {e}"))? {
        Message::Shutdown { .. } => Ok(()),
        other => Err(format!(
            "device {d}: expected Shutdown, got {}",
            other.type_name()
        )),
    }
}

/// Run one elastic churn-soak session: every device on its own driver
/// thread, the server on this thread, per-device accounting returned.
pub fn run_churn_soak(cfg: &ChurnSoakConfig) -> Result<SoakReport, String> {
    if cfg.base.devices == 0 || cfg.base.rounds == 0 {
        return Err("soak needs at least one device and one round".to_string());
    }
    let scripts = cfg.scripts()?;
    let listener =
        TcpListener::bind("127.0.0.1:0").map_err(|e| format!("soak bind: {e}"))?;
    let addr = listener
        .local_addr()
        .map_err(|e| format!("soak addr: {e}"))?
        .to_string();
    let mut handles = Vec::with_capacity(cfg.base.devices);
    for (d, &script) in scripts.iter().enumerate() {
        let addr = addr.clone();
        let cfg = cfg.clone();
        handles.push(
            thread::Builder::new()
                .name(format!("churn-dev-{d}"))
                .spawn(move || drive_churn_device(d, &addr, &cfg, script))
                .map_err(|e| format!("churn driver spawn: {e}"))?,
        );
    }
    let serve = serve_churn(&listener, cfg);
    let mut client_err = None;
    for handle in handles {
        match handle.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                client_err.get_or_insert(e);
            }
            Err(_) => {
                client_err.get_or_insert("churn driver panicked".to_string());
            }
        }
    }
    let report = serve?;
    if let Some(e) = client_err {
        return Err(format!("churn client: {e}"));
    }
    Ok(report)
}

/// The server half of [`run_churn_soak`]: the scripted echo session on an
/// elastic fleet, admitting re-joins at their scripted round boundaries
/// and absorbing scripted departures mid-round. A [`MembershipTable`]
/// tracks every slot so the admission epochs in `JoinAck` are real.
fn serve_churn(listener: &TcpListener, cfg: &ChurnSoakConfig) -> Result<SoakReport, String> {
    let base = &cfg.base;
    let devices = base.devices;
    let shape = FleetShape::flat(devices);
    let mut opts = base.opts;
    opts.elastic = true;
    let (mut fleet, _hellos) = PollFleet::accept_with(listener, shape, opts)?;
    fleet.arm_listener(
        listener
            .try_clone()
            .map_err(|e| format!("churn soak: listener clone: {e}"))?,
    )?;
    let backend = fleet.backend_kind();
    let start = Instant::now();
    for d in 0..devices {
        fleet
            .send(
                d,
                &Message::HelloAck {
                    device_id: d as u32,
                    rounds: base.rounds as u32,
                    agg_every: 1,
                },
            )
            .map_err(|e| format!("hello ack to {d}: {e}"))?;
    }
    let mut members = MembershipTable::new(devices);
    let mut present = vec![true; devices];
    let mut parked: Vec<JoinRequest> = Vec::new();
    let mut departures: Vec<(usize, bool)> = Vec::new();
    for r in 0..base.rounds {
        // round boundary: surface handshakes and departures that landed
        // since the last poll
        parked.extend(fleet.poll_joins());
        for dep in fleet.take_departures() {
            members.depart(dep.slot);
            present[dep.slot] = false;
            departures.push((dep.slot, dep.graceful));
        }
        for &(rejoin_round, d) in &cfg.rejoins {
            if rejoin_round != r {
                continue;
            }
            // the fresh incarnation dialed in some time after its kill;
            // wait (briefly) for its parked Join and the old slot to
            // fully retire, then admit with JoinAck + empty Catchup
            let deadline = Instant::now() + Duration::from_secs_f64(RECV_TIMEOUT_S);
            let req = loop {
                let ready = parked.iter().position(|p| p.gid == d);
                if let Some(i) = ready {
                    if fleet.vacant(d) {
                        break parked.remove(i);
                    }
                }
                for dep in fleet.take_departures() {
                    members.depart(dep.slot);
                    present[dep.slot] = false;
                    departures.push((dep.slot, dep.graceful));
                }
                parked.extend(fleet.poll_joins());
                if Instant::now() > deadline {
                    return Err(format!(
                        "round {r}: no admissible join from device {d} after \
                         {RECV_TIMEOUT_S}s"
                    ));
                }
                thread::sleep(Duration::from_millis(1));
            };
            members
                .begin_join(req.gid, req.member_epoch)
                .map_err(|e| format!("round {r}: {e}"))?;
            let epoch = members.admit(d).map_err(|e| format!("round {r}: {e}"))?;
            fleet
                .admit_join(
                    req.key,
                    &[
                        Message::JoinAck {
                            device_id: d as u32,
                            round: r as u32,
                            member_epoch: epoch,
                            rounds: base.rounds as u32,
                            agg_every: 1,
                        },
                        Message::Catchup {
                            round: r as u32,
                            device_id: d as u32,
                            spec_epoch: 0,
                            payload: Vec::new(),
                        },
                    ],
                )
                .map_err(|e| format!("round {r}: admitting device {d}: {e}"))?;
            present[d] = true;
        }
        for d in 0..devices {
            if !present[d] {
                continue;
            }
            fleet
                .send(d, &Message::RoundOpen { round: r as u32, sync: false })
                .map_err(|e| format!("round open {r} to {d}: {e}"))?;
        }
        let mut seen = vec![false; devices];
        let mut remaining = present.iter().filter(|&&p| p).count();
        while remaining > 0 {
            match fleet
                .recv_any(Some(RECV_TIMEOUT_S))
                .map_err(|e| format!("round {r}: {e}"))?
            {
                Some((d, Message::Activations { round, device_id, payload, .. })) => {
                    if round as usize != r || device_id as usize != d {
                        return Err(format!(
                            "round {r}: slot {d} delivered activations for \
                             device {device_id} round {round}"
                        ));
                    }
                    if payload != pattern(d, r, base.up_bytes, STREAM_UP) {
                        return Err(format!(
                            "round {r}: device {d} uplink payload corrupted"
                        ));
                    }
                    if seen[d] {
                        return Err(format!("round {r}: device {d} delivered twice"));
                    }
                    seen[d] = true;
                    remaining -= 1;
                    fleet
                        .send(
                            d,
                            &Message::Gradients {
                                round: r as u32,
                                device_id: d as u32,
                                loss: 0.0,
                                payload: pattern(d, r, base.down_bytes, STREAM_DOWN),
                            },
                        )
                        .map_err(|e| format!("gradients {r} to {d}: {e}"))?;
                }
                Some((d, Message::Leave { device_id, .. })) => {
                    if device_id as usize != d {
                        return Err(format!(
                            "round {r}: slot {d} delivered a Leave for device \
                             {device_id}"
                        ));
                    }
                    // the hang-up departure surfaces once the inbox drains
                }
                Some((d, other)) => {
                    return Err(format!(
                        "round {r}: expected Activations from {d}, got {}",
                        other.type_name()
                    ))
                }
                None => {
                    let deps = fleet.take_departures();
                    if deps.is_empty() {
                        return Err(format!(
                            "round {r}: fleet went quiet for {RECV_TIMEOUT_S}s"
                        ));
                    }
                    for dep in deps {
                        members.depart(dep.slot);
                        present[dep.slot] = false;
                        departures.push((dep.slot, dep.graceful));
                        if !seen[dep.slot] {
                            remaining -= 1;
                        }
                    }
                }
            }
        }
    }
    for d in 0..devices {
        if !present[d] {
            continue;
        }
        fleet
            .send(d, &Message::Shutdown { reason: "soak complete".to_string() })
            .map_err(|e| format!("shutdown to {d}: {e}"))?;
    }
    let wall_s = start.elapsed().as_secs_f64();
    let per_device = (0..devices).map(|d| fleet.stats(d)).collect();
    departures.sort_unstable();
    Ok(SoakReport { backend, wall_s, per_device, departures })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::poll::Backend;

    fn backends_under_test() -> Vec<Backend> {
        if cfg!(target_os = "linux") {
            vec![Backend::Epoll, Backend::Poll]
        } else {
            vec![Backend::Poll]
        }
    }

    #[test]
    fn small_soak_echoes_cleanly_on_every_backend() {
        for backend in backends_under_test() {
            let mut cfg = SoakConfig::new(12, 3);
            cfg.driver_threads = 4;
            cfg.opts = FleetOptions { backend, write_stall_secs: 10, elastic: false };
            let report = run_soak(&cfg).expect("soak should complete");
            assert_eq!(report.per_device.len(), 12);
            assert!(report.departures.is_empty());
            let first = report.per_device[0];
            assert!(first.bytes_sent > 0 && first.bytes_recv > 0);
            for stats in &report.per_device {
                assert_eq!(*stats, first, "per-device traffic must be uniform");
            }
        }
    }

    #[test]
    fn churn_soak_departs_and_readmits_with_exact_accounting() {
        for backend in backends_under_test() {
            let mut base = SoakConfig::new(6, 5);
            base.opts = FleetOptions { backend, write_stall_secs: 10, elastic: false };
            let cfg = ChurnSoakConfig {
                base,
                // device 2 announces its departure, device 4 just vanishes
                kills: vec![(1, 2, true), (2, 4, false)],
                rejoins: vec![(3, 2)],
            };
            let report = run_churn_soak(&cfg).expect("churn soak should complete");
            assert_eq!(report.departures, vec![(2, true), (4, false)]);
            for d in 0..cfg.base.devices {
                let (sent, recv) = cfg.expected_frames(d);
                let stats = report.per_device[d];
                assert_eq!(stats.frames_sent, sent, "device {d} frames sent");
                assert_eq!(stats.frames_recv, recv, "device {d} frames recv");
            }
        }
    }

    #[test]
    fn churn_scripts_are_validated() {
        let base = SoakConfig::new(4, 3);
        let bad = |kills: Vec<(usize, usize, bool)>, rejoins: Vec<(usize, usize)>| {
            run_churn_soak(&ChurnSoakConfig { base: base.clone(), kills, rejoins })
                .expect_err("invalid churn script must be rejected")
        };
        // device out of range / round out of range
        bad(vec![(0, 9, false)], vec![]);
        bad(vec![(9, 0, false)], vec![]);
        // rejoin without a kill, and not after the kill
        bad(vec![], vec![(1, 0)]);
        bad(vec![(2, 0, false)], vec![(1, 0)]);
        // duplicates
        bad(vec![(0, 1, false), (1, 1, true)], vec![]);
        bad(vec![(0, 1, false)], vec![(1, 1), (2, 1)]);
    }

    #[test]
    fn soak_rejects_empty_fleets() {
        assert!(run_soak(&SoakConfig::new(0, 1)).is_err());
        assert!(run_soak(&SoakConfig::new(1, 0)).is_err());
    }
}
