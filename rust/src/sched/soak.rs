//! Scale-soak harness: drive one [`crate::sched::event_loop::PollFleet`]
//! with hundreds-to-thousands of scripted mock devices and report
//! per-device wire statistics, so integration tests and the
//! `benches/event_loop.rs` scale curve can assert byte parity across I/O
//! backends and fleet sizes.
//!
//! The protocol is a miniature of the real serve loop with fully
//! deterministic payloads:
//!
//! 1. every device connects and Hellos; the server HelloAcks each slot;
//! 2. per round, the server RoundOpens every device, then `recv_any`s one
//!    Activations frame per device (payload is a [`Pcg32`] pattern keyed
//!    by `(device, round)`, verified byte-for-byte on receipt) and
//!    immediately answers it with a Gradients frame carrying the
//!    downlink pattern (verified on the device side);
//! 3. after the last round every device gets a Shutdown.
//!
//! Because every device exchanges frames of identical sizes, every
//! per-device [`WireStats`] in a clean run is identical — to every other
//! device in the same run, to the same run on the other I/O backend, and
//! to a smaller reference fleet. That single `==` is the parity
//! assertion the integration soak tests lean on.
//!
//! Devices are scripted blocking [`TcpTransport`]s multiplexed over a
//! small pool of driver threads (device `d` belongs to thread
//! `d % driver_threads`), so a 1024-device soak does not need 1024 OS
//! threads. An optional slow reader — one device that sleeps before
//! reading its round-0 downlink — exercises the server's write-park path
//! under fleet load.

use std::net::TcpListener;
use std::thread;
use std::time::{Duration, Instant};

use crate::sched::event_loop::{FleetOptions, PollFleet};
use crate::sched::fleet::Fleet;
use crate::shard::FleetShape;
use crate::transport::proto::Message;
use crate::transport::tcp::TcpTransport;
use crate::transport::{Transport, WireStats};
use crate::util::rng::Pcg32;

/// Pcg32 stream ids for the two payload directions, so the uplink and
/// downlink patterns for the same `(device, round)` never coincide.
const STREAM_UP: u64 = 0x5eed_0001;
const STREAM_DOWN: u64 = 0x5eed_0002;

/// Server side gives up if the fleet delivers nothing for this long —
/// turns a deadlocked soak into a failed test instead of a hung one.
const RECV_TIMEOUT_S: f64 = 60.0;

/// One scale-soak run: fleet size, traffic shape, and I/O backend.
#[derive(Debug, Clone)]
pub struct SoakConfig {
    /// Devices in the fleet (each is one real TCP connection).
    pub devices: usize,
    /// Rounds of RoundOpen → Activations → Gradients echo.
    pub rounds: usize,
    /// Uplink (Activations) payload bytes per device per round.
    pub up_bytes: usize,
    /// Downlink (Gradients) payload bytes per device per round.
    pub down_bytes: usize,
    /// Event-loop options for the server under test.
    pub opts: FleetOptions,
    /// Client driver threads; devices are striped across them.
    pub driver_threads: usize,
    /// `(device, pause_ms)`: that device sleeps `pause_ms` before reading
    /// its round-0 Gradients, backing the server's write up against a
    /// full TCP window.
    pub slow_reader: Option<(usize, u64)>,
}

impl SoakConfig {
    /// A small clean-echo soak; callers override fields as needed.
    pub fn new(devices: usize, rounds: usize) -> SoakConfig {
        SoakConfig {
            devices,
            rounds,
            up_bytes: 96,
            down_bytes: 128,
            opts: FleetOptions::default(),
            driver_threads: 8,
            slow_reader: None,
        }
    }
}

/// What a soak run measured.
#[derive(Debug, Clone)]
pub struct SoakReport {
    /// Which readiness backend actually served the run.
    pub backend: &'static str,
    /// Wall-clock seconds from HelloAck to the last Shutdown sent.
    pub wall_s: f64,
    /// Per-device framed-byte accounting, indexed by device id. In a
    /// clean run every entry is identical — the parity invariant.
    pub per_device: Vec<WireStats>,
}

/// Deterministic payload for one direction of one `(device, round)` step.
fn pattern(device: usize, round: usize, len: usize, stream: u64) -> Vec<u8> {
    let mut rng = Pcg32::new(((device as u64) << 32) | round as u64, stream);
    let mut buf = vec![0u8; len];
    for b in buf.iter_mut() {
        *b = rng.next_u32() as u8;
    }
    buf
}

fn hello_for(device: usize, devices: usize) -> Message {
    let specs = crate::codecs::stream::StreamSpecs::parse("identity", "identity", "identity")
        .expect("identity stream specs always parse");
    Message::Hello {
        device_id: device as u32,
        devices: devices as u32,
        shard_len: 8,
        config_fp: 1,
        uplink: specs.uplink.as_str().to_string(),
        downlink: specs.downlink.as_str().to_string(),
        sync: specs.sync.as_str().to_string(),
        streams_fp: specs.fingerprint(),
    }
}

/// Drive the devices striped onto one client thread through the whole
/// scripted session.
fn drive_clients(tid: usize, addr: String, cfg: SoakConfig) -> Result<(), String> {
    let mine: Vec<usize> =
        (0..cfg.devices).filter(|d| d % cfg.driver_threads == tid).collect();
    let mut conns = Vec::with_capacity(mine.len());
    for &d in &mine {
        let mut conn = TcpTransport::connect(&addr)?;
        conn.send(&hello_for(d, cfg.devices))
            .map_err(|e| format!("device {d}: hello send: {e}"))?;
        conns.push(conn);
    }
    for (k, &d) in mine.iter().enumerate() {
        match conns[k].recv().map_err(|e| format!("device {d}: hello ack: {e}"))? {
            Message::HelloAck { device_id, .. } if device_id as usize == d => {}
            other => {
                return Err(format!(
                    "device {d}: expected HelloAck, got {}",
                    other.type_name()
                ))
            }
        }
    }
    for r in 0..cfg.rounds {
        for (k, &d) in mine.iter().enumerate() {
            match conns[k].recv().map_err(|e| format!("device {d}: round open: {e}"))? {
                Message::RoundOpen { round, .. } if round as usize == r => {}
                other => {
                    return Err(format!(
                        "device {d} round {r}: expected RoundOpen, got {}",
                        other.type_name()
                    ))
                }
            }
            conns[k]
                .send(&Message::Activations {
                    round: r as u32,
                    device_id: d as u32,
                    labels: Vec::new(),
                    payload: pattern(d, r, cfg.up_bytes, STREAM_UP),
                })
                .map_err(|e| format!("device {d} round {r}: activations: {e}"))?;
            if r == 0 {
                if let Some((slow, pause_ms)) = cfg.slow_reader {
                    if slow == d {
                        thread::sleep(Duration::from_millis(pause_ms));
                    }
                }
            }
            match conns[k]
                .recv()
                .map_err(|e| format!("device {d} round {r}: gradients: {e}"))?
            {
                Message::Gradients { round, device_id, payload, .. } => {
                    if round as usize != r || device_id as usize != d {
                        return Err(format!(
                            "device {d} round {r}: gradients addressed to \
                             device {device_id} round {round}"
                        ));
                    }
                    if payload != pattern(d, r, cfg.down_bytes, STREAM_DOWN) {
                        return Err(format!(
                            "device {d} round {r}: downlink payload corrupted"
                        ));
                    }
                }
                other => {
                    return Err(format!(
                        "device {d} round {r}: expected Gradients, got {}",
                        other.type_name()
                    ))
                }
            }
        }
    }
    for (k, &d) in mine.iter().enumerate() {
        match conns[k].recv().map_err(|e| format!("device {d}: shutdown: {e}"))? {
            Message::Shutdown { .. } => {}
            other => {
                return Err(format!(
                    "device {d}: expected Shutdown, got {}",
                    other.type_name()
                ))
            }
        }
    }
    Ok(())
}

/// Run one scripted soak session: spawn the client driver pool, serve the
/// fleet from this thread, and return per-device wire accounting.
pub fn run_soak(cfg: &SoakConfig) -> Result<SoakReport, String> {
    if cfg.devices == 0 || cfg.rounds == 0 {
        return Err("soak needs at least one device and one round".to_string());
    }
    let listener =
        TcpListener::bind("127.0.0.1:0").map_err(|e| format!("soak bind: {e}"))?;
    let addr = listener
        .local_addr()
        .map_err(|e| format!("soak addr: {e}"))?
        .to_string();

    let threads = cfg.driver_threads.clamp(1, cfg.devices);
    let mut run_cfg = cfg.clone();
    run_cfg.driver_threads = threads;
    let mut handles = Vec::with_capacity(threads);
    for tid in 0..threads {
        let addr = addr.clone();
        let cfg = run_cfg.clone();
        handles.push(
            thread::Builder::new()
                .name(format!("soak-drv-{tid}"))
                .spawn(move || drive_clients(tid, addr, cfg))
                .map_err(|e| format!("soak driver spawn: {e}"))?,
        );
    }

    let serve = serve_soak(&listener, &run_cfg);

    let mut client_err = None;
    for handle in handles {
        match handle.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                client_err.get_or_insert(e);
            }
            Err(_) => {
                client_err.get_or_insert("soak driver panicked".to_string());
            }
        }
    }
    let report = serve?;
    if let Some(e) = client_err {
        return Err(format!("soak client: {e}"));
    }
    Ok(report)
}

/// The server half of [`run_soak`]: echo the scripted session over a
/// [`PollFleet`] and account every device's traffic.
fn serve_soak(listener: &TcpListener, cfg: &SoakConfig) -> Result<SoakReport, String> {
    let devices = cfg.devices;
    let shape = FleetShape::flat(devices);
    let (mut fleet, _hellos) = PollFleet::accept_with(listener, shape, cfg.opts)?;
    let backend = fleet.backend_kind();
    let start = Instant::now();
    for d in 0..devices {
        fleet
            .send(
                d,
                &Message::HelloAck {
                    device_id: d as u32,
                    rounds: cfg.rounds as u32,
                    agg_every: 1,
                },
            )
            .map_err(|e| format!("hello ack to {d}: {e}"))?;
    }
    for r in 0..cfg.rounds {
        for d in 0..devices {
            fleet
                .send(d, &Message::RoundOpen { round: r as u32, sync: false })
                .map_err(|e| format!("round open {r} to {d}: {e}"))?;
        }
        let mut seen = vec![false; devices];
        for _ in 0..devices {
            let (d, msg) = fleet
                .recv_any(Some(RECV_TIMEOUT_S))
                .map_err(|e| format!("round {r}: {e}"))?
                .ok_or_else(|| {
                    format!("round {r}: fleet went quiet for {RECV_TIMEOUT_S}s")
                })?;
            match msg {
                Message::Activations { round, device_id, payload, .. } => {
                    if round as usize != r || device_id as usize != d {
                        return Err(format!(
                            "round {r}: slot {d} delivered activations for \
                             device {device_id} round {round}"
                        ));
                    }
                    if payload != pattern(d, r, cfg.up_bytes, STREAM_UP) {
                        return Err(format!(
                            "round {r}: device {d} uplink payload corrupted"
                        ));
                    }
                }
                other => {
                    return Err(format!(
                        "round {r}: expected Activations from {d}, got {}",
                        other.type_name()
                    ))
                }
            }
            if seen[d] {
                return Err(format!("round {r}: device {d} delivered twice"));
            }
            seen[d] = true;
            fleet
                .send(
                    d,
                    &Message::Gradients {
                        round: r as u32,
                        device_id: d as u32,
                        loss: 0.0,
                        payload: pattern(d, r, cfg.down_bytes, STREAM_DOWN),
                    },
                )
                .map_err(|e| format!("gradients {r} to {d}: {e}"))?;
        }
    }
    for d in 0..devices {
        fleet
            .send(d, &Message::Shutdown { reason: "soak complete".to_string() })
            .map_err(|e| format!("shutdown to {d}: {e}"))?;
    }
    let wall_s = start.elapsed().as_secs_f64();
    let per_device = (0..devices).map(|d| fleet.stats(d)).collect();
    Ok(SoakReport { backend, wall_s, per_device })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::poll::Backend;

    fn backends_under_test() -> Vec<Backend> {
        if cfg!(target_os = "linux") {
            vec![Backend::Epoll, Backend::Poll]
        } else {
            vec![Backend::Poll]
        }
    }

    #[test]
    fn small_soak_echoes_cleanly_on_every_backend() {
        for backend in backends_under_test() {
            let mut cfg = SoakConfig::new(12, 3);
            cfg.driver_threads = 4;
            cfg.opts = FleetOptions { backend, write_stall_secs: 10 };
            let report = run_soak(&cfg).expect("soak should complete");
            assert_eq!(report.per_device.len(), 12);
            let first = report.per_device[0];
            assert!(first.bytes_sent > 0 && first.bytes_recv > 0);
            for stats in &report.per_device {
                assert_eq!(*stats, first, "per-device traffic must be uniform");
            }
        }
    }

    #[test]
    fn soak_rejects_empty_fleets() {
        assert!(run_soak(&SoakConfig::new(0, 1)).is_err());
        assert!(run_soak(&SoakConfig::new(1, 0)).is_err());
    }
}
