//! Fleet-scale round scheduling: the event-loop server and the
//! out-of-order round scheduler.
//!
//! PR 1's transport gave the smashed-data link real wire semantics, but the
//! server still *scheduled* badly: one blocking reader thread per
//! connection and strict device-id-order stepping, so one slow device
//! stalled the whole fleet every round. This subsystem replaces both:
//!
//! * [`poll`] — readiness polling over `libc::poll` via direct FFI (no
//!   async runtime, no new crates).
//! * [`event_loop`] — [`event_loop::PollFleet`]: every accepted device
//!   socket is non-blocking and driven from **one** thread; frames are
//!   reassembled incrementally ([`crate::transport::proto::FrameDecoder`])
//!   and surfaced in true arrival order.
//! * [`fleet`] — the [`fleet::Fleet`] abstraction the scheduler drives, and
//!   [`fleet::PumpFleet`], the in-process implementation with a virtual
//!   clock and a seeded artificial-delay shim so arrival-order behavior is
//!   unit-testable deterministically.
//! * [`round`] — [`round::RoundScheduler`]: owns round state and steps
//!   whichever device's Activations frame arrives first, under one of the
//!   [`Policy`] variants below.
//!
//! Per-device wait and straggler times are recorded into
//! [`crate::net::timeline::Timeline`] so time-to-accuracy can be compared
//! across policies.

#[cfg(target_os = "linux")]
pub mod epoll;
pub mod event_loop;
pub mod fleet;
pub mod poll;
pub mod round;
pub mod soak;

/// How the server orders device work within a round.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum Policy {
    /// Deterministic device-id order (the default). Byte-for-byte identical
    /// wire traffic across transports and timings — the parity baseline.
    #[default]
    InOrder,
    /// Step whichever device's Activations frame arrives first. With
    /// `straggler_timeout_s` set, a round closes once the timeout expires
    /// and at least `min_quorum` devices delivered (partial FedAvg);
    /// devices that missed the close are carried into the next round.
    ArrivalOrder {
        /// `None`: wait for every opened device each round (reorder only).
        straggler_timeout_s: Option<f64>,
        /// Devices required to close a timed-out round. `None` = 1: a
        /// timeout with no explicit quorum closes with whoever has
        /// delivered, so `--straggler-timeout` works on its own. Clamped
        /// to the opened count at runtime.
        min_quorum: Option<usize>,
    },
}

impl Policy {
    /// Plain arrival-order scheduling (no timeout, no quorum).
    pub fn arrival() -> Policy {
        Policy::ArrivalOrder { straggler_timeout_s: None, min_quorum: None }
    }

    /// Arrival order with a straggler timeout and quorum close.
    pub fn arrival_with_timeout(straggler_timeout_s: f64, min_quorum: usize) -> Policy {
        Policy::ArrivalOrder {
            straggler_timeout_s: Some(straggler_timeout_s),
            min_quorum: Some(min_quorum),
        }
    }

    /// Stable label for logs and the config fingerprint. Includes the
    /// timeout bits: two sessions with different straggler timeouts close
    /// different rounds and must not handshake as numerically identical.
    pub fn label(&self) -> String {
        match self {
            Policy::InOrder => "inorder".to_string(),
            Policy::ArrivalOrder { straggler_timeout_s: None, min_quorum: None } => {
                "arrival".to_string()
            }
            Policy::ArrivalOrder { straggler_timeout_s, min_quorum } => format!(
                "arrival+t{:x}q{}",
                straggler_timeout_s.map_or(0, f64::to_bits),
                min_quorum.unwrap_or(0)
            ),
        }
    }
}

/// Which devices the scheduler opens a round for (`--select`). Orthogonal
/// to [`Policy`]: the policy orders work *within* a round, participation
/// decides who is invited at round open.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Participation {
    /// Every in-session device participates in every round (the default).
    #[default]
    All,
    /// Deprioritize chronic stragglers: a device whose
    /// [`crate::net::timeline::DeviceWaitProfile`] history shows it
    /// straggling in more rounds than it completed on time sits out every
    /// other round, so the fleet stops paying its timeout tax twice per
    /// cadence. The opened set is never allowed to go empty.
    BiasStragglers,
}

impl Participation {
    /// Parse the `--select` flag value.
    pub fn parse(s: &str) -> Result<Participation, String> {
        match s {
            "all" => Ok(Participation::All),
            "bias-stragglers" => Ok(Participation::BiasStragglers),
            other => Err(format!(
                "unknown participation policy '{other}' (expected 'all' or \
                 'bias-stragglers')"
            )),
        }
    }

    /// Stable label for logs and the config fingerprint.
    pub fn label(&self) -> &'static str {
        match self {
            Participation::All => "all",
            Participation::BiasStragglers => "bias-stragglers",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn participation_parses_and_labels() {
        assert_eq!(Participation::parse("all").unwrap(), Participation::All);
        assert_eq!(
            Participation::parse("bias-stragglers").unwrap(),
            Participation::BiasStragglers
        );
        assert!(Participation::parse("nope").is_err());
        assert_eq!(Participation::default().label(), "all");
        assert_eq!(Participation::BiasStragglers.label(), "bias-stragglers");
    }

    #[test]
    fn labels_distinguish_policies() {
        assert_eq!(Policy::InOrder.label(), "inorder");
        assert_eq!(Policy::arrival().label(), "arrival");
        let a = Policy::arrival_with_timeout(0.5, 3).label();
        let b = Policy::arrival_with_timeout(1.0, 3).label();
        assert_ne!(a, b);
        assert_ne!(a, Policy::arrival().label());
    }
}
