//! The out-of-order round scheduler: owns round state and decides which
//! device to step next.
//!
//! [`RoundScheduler::run`] drives a handshaken session over any
//! [`Fleet`], calling back into [`ServerRuntime`] for the compute work
//! (decompress → `server_step` → compress). Three behaviors, selected by
//! [`Policy`]:
//!
//! * **InOrder** — the PR 1 baseline, replicated message-for-message:
//!   devices are processed in id order every round, so a session's
//!   numerics and wire bytes are identical across transports and timings.
//! * **ArrivalOrder** — stages ii–iii run for whichever device's
//!   Activations frame lands first. Numerics depend on arrival order (the
//!   shared server sub-model makes stage iii order-sensitive), which is
//!   exactly the accuracy/time trade-off this mode exists to measure.
//! * **ArrivalOrder + straggler timeout / quorum** — a round closes once
//!   the timeout expires with at least `min_quorum` arrivals; devices that
//!   missed the close are *carried*: their stale Activations are served
//!   whenever they land (against the then-current server model), after
//!   which the device rejoins at the next round boundary. Aggregation
//!   rounds FedAvg over whatever sub-models are available (partial
//!   aggregation), and the broadcast goes only to devices at a round
//!   boundary — a straggler mid-backward must not have its params swapped
//!   underneath it.
//!
//! Every round's participants, stragglers, and per-device waits are
//! recorded into [`crate::net::timeline::Timeline`] via [`SchedRecord`],
//! and the simulated round time excludes carried stragglers
//! ([`crate::net::NetworkSim::round_cost_sched`]) — closing a round
//! without the slow device is the whole point.
//!
//! **Cross-shard scheduling.** When the runtime is a shard of a
//! multi-server topology, both policies call
//! [`ServerRuntime::cross_shard`] between the local FedAvg and its
//! broadcast: at every [`ShardSyncPolicy`] boundary the shard exchanges
//! its aggregated client sub-model and its server sub-model with the
//! coordinator tier and broadcasts the *cluster-wide* merge to its
//! devices instead of the local average. Device indices inside the
//! scheduler are local slots; everything that crosses the wire carries
//! the device's *global* id (`rt.cfg.gid(d)`), so a device behaves
//! identically whichever shard serves it.

use std::time::Instant;

use crate::coordinator::metrics::RoundRecord;
use crate::net::timeline::SchedRecord;
use crate::quant::payload::{ByteReader, Header};
use crate::sched::fleet::Fleet;
use crate::sched::{Participation, Policy};
use crate::transport::compute::Compute;
use crate::transport::proto::Message;
use crate::transport::server::{BatchItem, ServerRuntime};

/// Where one device stands in the round protocol.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Phase {
    /// At a round boundary: safe to open a new round or receive a FedAvg
    /// broadcast.
    Idle,
    /// RoundOpen sent; owes Activations for `round`.
    Open { round: usize, sync: bool, opened_s: f64 },
    /// Gradients sent for `round`; owes a ModelSync push.
    AwaitSync { round: usize },
}

/// Outcome of a scheduled session (the runtime assembles the report).
pub struct SchedOutcome {
    pub rounds_run: usize,
    pub time_to_target_s: Option<f64>,
}

/// The cross-shard scheduling policy: when a shard pauses at an
/// aggregation boundary to merge sub-models with the coordinator tier
/// (`--shard-sync-every K`; every aggregation round at the default 1).
/// Amortizing the sync trades inter-shard traffic and coordinator
/// barriers against shard-model drift — the same time-vs-fidelity axis
/// the straggler policies trade on, one tier up.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSyncPolicy {
    /// Sync every this many rounds (>= 1).
    pub every: usize,
}

impl ShardSyncPolicy {
    pub fn new(every: usize) -> ShardSyncPolicy {
        ShardSyncPolicy { every: every.max(1) }
    }

    /// Is round `round` (0-based) a cross-shard sync boundary?
    pub fn due(&self, round: usize) -> bool {
        (round + 1) % self.every == 0
    }
}

/// Coalesces arrival-ordered Activations into same-shaped dispatch groups
/// under the `--batch-window N` policy.
///
/// The arrival-order queue naturally runs same-shaped (every device of a
/// session cuts at one geometry), so the plan usually just counts to the
/// window; the wire-header dims peek makes it robust to a mixed-geometry
/// batch anyway — a shape change seals the current group so one
/// `server_step_batch` dispatch never has to straddle shapes. Envelopes
/// whose header doesn't parse form their own group and surface the decode
/// error through the normal `step_batch` path, device and round named.
pub struct BatchPlan {
    window: usize,
    dims: Option<[u32; 4]>,
    items: Vec<BatchItem>,
    /// `elapsed_ns` when the buffered group's first item was admitted
    /// (tracing enabled only) — the `batch_seal_wait` span measures how
    /// long arrivals sat buffered waiting for the window to fill or seal
    first_admit_ns: Option<u64>,
}

impl BatchPlan {
    pub fn new(window: usize) -> BatchPlan {
        BatchPlan {
            window: window.max(1),
            dims: None,
            items: Vec::new(),
            first_admit_ns: None,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// The payload's claimed geometry, if its envelope header parses.
    fn peek_dims(payload: &[u8]) -> Option<[u32; 4]> {
        Header::read(&mut ByteReader::new(payload)).ok().map(|h| h.dims)
    }

    /// Admit one uplink. Returns a ready group when the incoming item's
    /// shape seals the current one, or the window fills — the caller
    /// dispatches it immediately.
    pub fn push(&mut self, item: BatchItem) -> Option<Vec<BatchItem>> {
        let dims = Self::peek_dims(&item.payload);
        let sealed = if !self.items.is_empty() && dims != self.dims {
            self.note_seal();
            Some(std::mem::take(&mut self.items))
        } else {
            None
        };
        self.dims = dims;
        if self.items.is_empty() && crate::obs::span::enabled() {
            self.first_admit_ns = Some(crate::util::logging::elapsed_ns());
        }
        self.items.push(item);
        if sealed.is_some() {
            return sealed;
        }
        if self.items.len() >= self.window {
            self.note_seal();
            return Some(std::mem::take(&mut self.items));
        }
        None
    }

    /// Drain whatever is buffered (queue went quiet, or the round is
    /// closing).
    pub fn flush(&mut self) -> Option<Vec<BatchItem>> {
        if self.items.is_empty() {
            None
        } else {
            self.note_seal();
            Some(std::mem::take(&mut self.items))
        }
    }

    /// Trace how long the (non-empty) buffered group sat between its first
    /// admit and this seal — recorded manually because the wait already
    /// happened by the time the group is handed out for dispatch.
    fn note_seal(&mut self) {
        let Some(t0) = self.first_admit_ns.take() else { return };
        if !crate::obs::span::enabled() {
            return;
        }
        let now = crate::util::logging::elapsed_ns();
        crate::obs::span::record(
            crate::obs::span::SpanEvent::manual(
                "batch_seal_wait",
                t0,
                now.saturating_sub(t0),
            )
            .round(self.items[0].round as u32),
        );
    }
}

/// Drives the per-round message flow for one session.
pub struct RoundScheduler {
    policy: Policy,
}

impl RoundScheduler {
    pub fn new(policy: Policy) -> RoundScheduler {
        RoundScheduler { policy }
    }

    pub fn run<C: Compute>(
        &mut self,
        rt: &mut ServerRuntime<C>,
        fleet: &mut dyn Fleet,
    ) -> Result<SchedOutcome, String> {
        match self.policy {
            Policy::InOrder => run_in_order(rt, fleet),
            Policy::ArrivalOrder { straggler_timeout_s, min_quorum } => {
                run_arrival(rt, fleet, straggler_timeout_s, min_quorum)
            }
        }
    }
}

/// Shared per-round bookkeeping: record cost + metrics, evaluate, check
/// the early-stop target. Returns `true` when the session should stop.
#[allow(clippy::too_many_arguments, clippy::type_complexity)]
fn close_round<C: Compute>(
    rt: &mut ServerRuntime<C>,
    round: usize,
    wall: Instant,
    eval_due: bool,
    loss: f64,
    bytes: (Vec<usize>, Vec<usize>, Vec<usize>, Vec<usize>),
    active: Vec<bool>,
    sched: SchedRecord,
    time_to_target: &mut Option<f64>,
) -> Result<bool, String> {
    let label = rt.cfg.label.clone();
    let (up, down, sync_up, sync_down) = bytes;
    let cost = rt.net.round_cost_sched(&up, &down, &sync_up, &sync_down, &active);
    let participants = sched.participants.len();
    let stragglers = sched.stragglers.len();
    // raw (pre-codec) bytes this round, accumulated by the runtime's
    // decode/encode/sync helpers — the per-stream compression-ratio axis
    let [raw_up, raw_down, raw_sync] = rt.take_round_raw();
    // shard-link traffic (cross-shard push + merged reply) rides the
    // ModelSync byte axis: it is FedAvg traffic, one tier up
    let shard_wire = std::mem::take(&mut rt.shard_round_wire);
    rt.timeline.push_with_sched(cost, sched);
    // a straggling device 0 has no fresh sub-model to evaluate; skip the
    // eval rather than fail the session (InOrder never hits this)
    let accuracy = if eval_due && rt.client_params[0].is_some() {
        let _sp = crate::span!("eval", round = round);
        Some(rt.evaluate()?)
    } else {
        None
    };
    // authoritative wire counters: incremented from the exact values that
    // build the RoundRecord below, so a live scrape's totals agree with
    // the end-of-run report byte-for-byte
    crate::obs::metrics::ROUNDS_CLOSED.inc();
    crate::obs::metrics::WIRE_UP_BYTES.add(cost.bytes_up as u64);
    crate::obs::metrics::WIRE_DOWN_BYTES.add(cost.bytes_down as u64);
    crate::obs::metrics::WIRE_SYNC_BYTES.add((cost.bytes_sync + shard_wire) as u64);
    if let Some(sw) = rt.snapshot.as_mut() {
        sw.maybe_snapshot(round);
    }
    let rec = RoundRecord {
        round,
        loss,
        accuracy,
        spec: rt.streams.active_table(round),
        bytes_up: cost.bytes_up,
        bytes_down: cost.bytes_down,
        bytes_sync: cost.bytes_sync + shard_wire,
        raw_up,
        raw_down,
        raw_sync,
        participants,
        stragglers,
        sim_time_s: rt.timeline.total_time(),
        wall_ms: wall.elapsed().as_secs_f64() * 1e3,
    };
    let mut stop = false;
    if let Some(acc) = accuracy {
        crate::log_info!(
            "[{label}] round {round}: loss {loss:.4} acc {:.2}% sim_t {:.1}s",
            acc * 100.0,
            rec.sim_time_s
        );
        if let Some(target) = rt.cfg.target_accuracy {
            if acc >= target && time_to_target.is_none() {
                *time_to_target = Some(rec.sim_time_s);
                stop = true;
            }
        }
    } else {
        crate::log_debug!("[{label}] round {round}: loss {loss:.4}");
    }
    rt.metrics.push(rec);
    // the per-round umbrella span, recorded manually at close: start is
    // back-dated to the round's wall-clock open, so every stage span of
    // this round nests inside it in the merged timeline
    if crate::obs::span::enabled() {
        let dur = wall.elapsed().as_nanos() as u64;
        let now = crate::util::logging::elapsed_ns();
        crate::obs::span::record(
            crate::obs::span::SpanEvent::manual(
                "round",
                now.saturating_sub(dur),
                dur,
            )
            .round(round as u32),
        );
    }
    Ok(stop)
}

/// The deterministic baseline: PR 1's device-id-order round loop,
/// message-for-message (byte parity with the pre-scheduler goldens).
fn run_in_order<C: Compute>(
    rt: &mut ServerRuntime<C>,
    fleet: &mut dyn Fleet,
) -> Result<SchedOutcome, String> {
    let n = rt.cfg.devices;
    let mut time_to_target = None;
    let mut rounds_run = 0;
    for round in 0..rt.cfg.rounds {
        let wall = Instant::now();
        let agg_due = (round + 1) % rt.cfg.client_agg_every == 0;
        let eval_due =
            (round + 1) % rt.cfg.eval_every == 0 || round + 1 == rt.cfg.rounds;
        // aggregation needs every device's sub-model; evaluation only
        // device 0's — don't ship N-1 unused full models on eval-only
        // rounds (ModelSync is outside the smashed-data byte axis, but
        // it is real wall-clock on a wide fleet)
        let wants_sync = |d: usize| agg_due || (eval_due && d == 0);

        // stage i fans out to every device in parallel
        for d in 0..n {
            fleet.send(d, &Message::RoundOpen { round: round as u32, sync: wants_sync(d) })?;
        }
        for d in 0..n {
            fleet.pump(d)?;
        }

        // stages ii-iii, sequential in device order (shared server model)
        let mut up = vec![0usize; n];
        let mut down = vec![0usize; n];
        let mut sync_up = vec![0usize; n];
        let mut sync_down = vec![0usize; n];
        let mut loss_sum = 0.0f64;
        for d in 0..n {
            // a SpecUpdate pushed at the previous round's close is acked
            // before the device's first frame of any later round; consume
            // the ack(s) queued ahead of this round's Activations
            let msg = loop {
                match fleet.recv_from(d)? {
                    Message::SpecUpdateAck { activate_round, streams_fp } => {
                        rt.accept_spec_ack(d, activate_round as usize, streams_fp)?;
                    }
                    m => break m,
                }
            };
            let (r2, dev, labels, payload) = match msg {
                Message::Activations { round, device_id, labels, payload } => {
                    (round as usize, device_id as usize, labels, payload)
                }
                other => {
                    return Err(format!(
                        "round {round}: expected Activations from device {d}, got {}",
                        other.type_name()
                    ))
                }
            };
            if r2 != round || dev != rt.cfg.gid(d) {
                return Err(format!(
                    "round {round}: device {} sent activations for round {r2} as device {dev}",
                    rt.cfg.gid(d)
                ));
            }
            rt.spec_ack_gate(d, round)?;
            up[d] = payload.len();
            // always a single-item batch: InOrder's contract is
            // message-for-message determinism, which a >1 window would
            // break (Gradients sends would shift relative to receives)
            let item = BatchItem { d, round, labels, payload };
            let (loss, payload_down) = rt
                .step_batch(std::slice::from_ref(&item))?
                .pop()
                .expect("step_batch returns one result per item");
            loss_sum += loss;
            down[d] = payload_down.len();
            fleet.send(d, &Message::Gradients {
                round: round as u32,
                device_id: rt.cfg.gid(d) as u32,
                loss: loss as f32,
                payload: payload_down,
            })?;
        }
        for d in 0..n {
            fleet.pump(d)?;
        }

        // SFL aggregation / model sync
        if agg_due || eval_due {
            for d in 0..n {
                if !wants_sync(d) {
                    continue;
                }
                let msg = fleet.recv_from(d)?;
                match msg {
                    Message::ModelSync { device_id, payload, .. }
                        if device_id as usize == rt.cfg.gid(d) && !payload.is_empty() =>
                    {
                        sync_up[d] = payload.len();
                        rt.accept_sync(d, &payload)?;
                    }
                    other => {
                        return Err(format!(
                            "round {round}: expected non-empty ModelSync from device {}, got {}",
                            rt.cfg.gid(d),
                            other.type_name()
                        ))
                    }
                }
            }
            if agg_due {
                let basis: Vec<usize> = (0..n).collect();
                let reply = {
                    let _sp = crate::span!("fedavg", round = round);
                    rt.fedavg_over(&basis, round)?
                };
                // cross-shard boundary: merge with the other shards before
                // broadcasting (a no-op on a single server). cross_shard
                // only returns None for a None input (a Some push that the
                // coordinator dropped is an error inside it)
                let reply = rt
                    .cross_shard(round, Some(reply))?
                    .expect("cross_shard preserves a Some client model");
                for d in 0..n {
                    let payload = rt.pack_broadcast(d, &reply);
                    sync_down[d] = payload.len();
                    fleet.send(d, &Message::ModelSync {
                        round: round as u32,
                        device_id: rt.cfg.gid(d) as u32,
                        payload,
                    })?;
                }
                rt.set_all_params(reply);
            }
            for d in 0..n {
                fleet.pump(d)?;
            }
        }

        rounds_run = round + 1;
        let loss = loss_sum / n as f64;
        let sched = SchedRecord {
            round,
            participants: (0..n).collect(),
            stale: Vec::new(),
            stragglers: Vec::new(),
            wait_s: vec![0.0; n],
        };
        let stop = close_round(
            rt,
            round,
            wall,
            eval_due,
            loss,
            (up, down, sync_up, sync_down),
            vec![true; n],
            sched,
            &mut time_to_target,
        )?;
        if stop {
            break;
        }
        rt.adapt_after_close(round, fleet, 0.0)?;
    }
    Ok(SchedOutcome { rounds_run, time_to_target_s: time_to_target })
}

/// Dispatch one ready batch group: step every item in ONE
/// `server_step_batch` crossing, then send each device's Gradients in
/// arrival order and give in-process workers their turn — per device,
/// exactly what the unbatched path did after its step.
fn flush_group<C: Compute>(
    rt: &mut ServerRuntime<C>,
    fleet: &mut dyn Fleet,
    group: Vec<BatchItem>,
    down: &mut [usize],
    loss_sum: &mut f64,
    steps: &mut usize,
    elastic: bool,
) -> Result<(), String> {
    let results = rt.step_batch(&group)?;
    for (it, (loss, payload_down)) in group.iter().zip(results) {
        *loss_sum += loss;
        *steps += 1;
        let len = payload_down.len();
        let sent = fleet.send(it.d, &Message::Gradients {
            round: it.round as u32,
            device_id: rt.cfg.gid(it.d) as u32,
            loss: loss as f32,
            payload: payload_down,
        });
        match sent {
            Ok(()) => {
                down[it.d] += len;
                fleet.pump(it.d)?;
            }
            // elastic: the slot died under the Gradients send — the server
            // step already happened (the model advanced), only the reply is
            // lost; the typed departure surfaces on the next drain
            Err(e) if elastic => crate::log_debug!(
                "sched: round {}: gradients for departing device {} dropped: {e}",
                it.round,
                rt.cfg.gid(it.d)
            ),
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// Drain the fleet's typed departures into the membership table: each
/// departed slot leaves the participant set (its phase resets, it is
/// dropped from this round's `opened` list) and the session keeps running
/// on whoever remains — the elastic replacement for the fixed fleet's
/// fatal `PeerClosed`. Returns how many slots departed.
fn drain_departures<C: Compute>(
    rt: &mut ServerRuntime<C>,
    fleet: &mut dyn Fleet,
    round: usize,
    present: &mut [bool],
    phase: &mut [Phase],
    opened: &mut Vec<usize>,
) -> usize {
    let mut gone = 0;
    for dep in fleet.take_departures() {
        let d = dep.slot;
        if !present[d] {
            continue; // already accounted (close paths may double-fire)
        }
        present[d] = false;
        rt.membership.depart(d);
        phase[d] = Phase::Idle;
        opened.retain(|&x| x != d);
        gone += 1;
        crate::log_info!(
            "[{}] round {round}: device {} departed ({}{})",
            rt.cfg.label,
            rt.cfg.gid(d),
            if dep.graceful { "graceful leave: " } else { "" },
            dep.error
        );
        if crate::obs::span::enabled() {
            let now = crate::util::logging::elapsed_ns();
            crate::obs::span::record(
                crate::obs::span::SpanEvent::manual("leave", now, 0)
                    .round(round as u32)
                    .attr("gid", rt.cfg.gid(d) as u64),
            );
        }
    }
    gone
}

/// Admit (or reject) every parked `Join` the fleet surfaced: runtime-side
/// validation + catchup assembly ([`ServerRuntime::process_join`]), then
/// the fleet swaps the pending connection into its slot and delivers the
/// `JoinAck` + `Catchup` replies as one batched write. Returns how many
/// devices were admitted; each re-enters scheduling as `Idle` and is
/// opened at the next round-open pass.
fn admit_parked<C: Compute>(
    rt: &mut ServerRuntime<C>,
    fleet: &mut dyn Fleet,
    round: usize,
    present: &mut [bool],
    phase: &mut [Phase],
) -> Result<usize, String> {
    let mut admitted = 0;
    for req in fleet.poll_joins() {
        let _sp = crate::span!("join", round = round, gid = req.gid);
        match rt.process_join(&req, round) {
            Ok(replies) => {
                let d = rt
                    .cfg
                    .shape()
                    .slot(req.gid)
                    .expect("validated by process_join");
                if let Err(e) = fleet.admit_join(req.key, &replies) {
                    // the runtime admitted but the connection is unusable
                    // (raced a close, pipelined early bytes): roll back
                    rt.membership.depart(d);
                    crate::log_info!(
                        "[{}] round {round}: join for device {} dropped by the \
                         fleet: {e}",
                        rt.cfg.label,
                        req.gid
                    );
                    continue;
                }
                present[d] = true;
                phase[d] = Phase::Idle;
                admitted += 1;
            }
            Err(reason) => {
                crate::log_info!(
                    "[{}] round {round}: join rejected for device {}: {reason}",
                    rt.cfg.label,
                    req.gid
                );
                fleet.reject_join(req.key, &reason);
            }
        }
    }
    Ok(admitted)
}

/// Arrival-order scheduling with optional straggler timeout + quorum,
/// coalescing up to `--batch-window` same-shaped Activations per compute
/// dispatch (a [`BatchPlan`] per round; only what actually arrived is
/// ever batched, so quorum closes and carried stragglers batch exactly
/// the devices present).
fn run_arrival<C: Compute>(
    rt: &mut ServerRuntime<C>,
    fleet: &mut dyn Fleet,
    timeout_s: Option<f64>,
    min_quorum: Option<usize>,
) -> Result<SchedOutcome, String> {
    let n = rt.cfg.devices;
    let label = rt.cfg.label.clone();
    let window = rt.cfg.batch_window.max(1);
    let elastic = rt.cfg.elastic;
    let participation = rt.cfg.participation;
    let mut phase = vec![Phase::Idle; n];
    // which slots are in the session right now (elastic: shrinks on
    // departure, grows back on admission; fixed fleet: always all true)
    let mut present = vec![true; n];
    let mut time_to_target = None;
    let mut rounds_run = 0;
    for round in 0..rt.cfg.rounds {
        let wall = Instant::now();
        let agg_due = (round + 1) % rt.cfg.client_agg_every == 0;
        let eval_due =
            (round + 1) % rt.cfg.eval_every == 0 || round + 1 == rt.cfg.rounds;
        let wants_sync = |d: usize| agg_due || (eval_due && d == 0);

        let mut opened = Vec::new();
        let mut open_s = fleet.now_s();
        // the round boundary is the membership boundary: settle departures
        // first (so a vacated slot is re-joinable), then admit whatever
        // `Join`s parked since the last boundary
        if elastic {
            fleet.note_round(round as u32);
            drain_departures(rt, fleet, round, &mut present, &mut phase, &mut opened);
            admit_parked(rt, fleet, round, &mut present, &mut phase)?;
        }

        let mut up = vec![0usize; n];
        let mut down = vec![0usize; n];
        let mut sync_up = vec![0usize; n];
        let mut sync_down = vec![0usize; n];
        let mut wait_s = vec![0.0f64; n];
        let mut active = vec![false; n];
        let mut participants: Vec<usize> = Vec::new();
        let mut stale: Vec<usize> = Vec::new();
        let mut loss_sum = 0.0f64;
        let mut steps = 0usize;
        // devices that already delivered *this* round's Activations: if a
        // wave of departures empties `opened`, the re-open pass below must
        // not hand them a second RoundOpen for the same round
        let mut done = vec![false; n];
        let mut plan = BatchPlan::new(window);

        loop {
            // elastic: surface departures before evaluating the close
            // conditions (a dead slot must stop counting as outstanding),
            // and admit parked joins while nobody has opened yet — an
            // emptied fleet can only restart through an admission; a
            // mid-round join waits for the next boundary
            if elastic {
                drain_departures(rt, fleet, round, &mut present, &mut phase, &mut opened);
                if opened.is_empty() {
                    admit_parked(rt, fleet, round, &mut present, &mut phase)?;
                }
            }
            // open the round for devices at a round boundary. Opening is
            // *lazy*: if every device is mid-carry (all straggling or
            // finishing old syncs), the loop below serves their carried
            // work until one reaches a boundary, and THAT device opens
            // this round — so every recorded round runs at least one real
            // step and the fleet can never deadlock waiting for a
            // RoundOpen nobody is eligible to receive. Once a first batch
            // has opened, later-freed devices wait for the next round.
            if opened.is_empty() {
                let mut cands: Vec<usize> = (0..n)
                    .filter(|&d| phase[d] == Phase::Idle && present[d] && !done[d])
                    .collect();
                // `--select bias-stragglers`: a device whose history shows
                // more carried closes than on-time deliveries sits out
                // every other round — the fleet stops paying its timeout
                // twice per cadence. Never bench the whole candidate set.
                if participation == Participation::BiasStragglers
                    && round % 2 == 1
                    && cands.len() > 1
                {
                    let profiles = rt.timeline.device_wait_profiles(n);
                    let kept: Vec<usize> = cands
                        .iter()
                        .copied()
                        .filter(|&d| {
                            let p = &profiles[d];
                            p.straggles < 2 || p.straggles <= p.participations
                        })
                        .collect();
                    if !kept.is_empty() && kept.len() < cands.len() {
                        crate::log_debug!(
                            "[{label}] round {round}: benching {} chronic \
                             straggler(s) this round",
                            cands.len() - kept.len()
                        );
                        cands = kept;
                    }
                }
                for d in cands {
                    let sent = fleet.send(d, &Message::RoundOpen {
                        round: round as u32,
                        sync: wants_sync(d),
                    });
                    match sent {
                        Ok(()) => {
                            phase[d] = Phase::Open {
                                round,
                                sync: wants_sync(d),
                                opened_s: fleet.now_s(),
                            };
                            opened.push(d);
                        }
                        // the slot died under the open: don't count it in
                        // this round; the typed departure surfaces on the
                        // next drain pass
                        Err(e) if elastic => crate::log_debug!(
                            "[{label}] round {round}: RoundOpen to departing \
                             device {} dropped: {e}",
                            rt.cfg.gid(d)
                        ),
                        Err(e) => return Err(e.into()),
                    }
                }
                if !opened.is_empty() {
                    for d in 0..n {
                        fleet.pump(d)?;
                    }
                    open_s = fleet.now_s();
                }
            }
            // a timeout with no explicit quorum closes with whoever has
            // delivered (>= 1 step) — `--straggler-timeout` must do what
            // it says on its own; clamped to what was opened this round
            let required = min_quorum.unwrap_or(1).min(opened.len());
            // completion close: everyone opened this round has delivered
            // (Activations, plus the ModelSync push when requested)
            let outstanding = opened
                .iter()
                .filter(|&&d| match phase[d] {
                    Phase::Open { round: r, .. } => r == round,
                    Phase::AwaitSync { round: r } => r == round,
                    Phase::Idle => false,
                })
                .count();
            let worked = participants.len() + stale.len();
            if outstanding == 0 && worked > 0 {
                // a non-full batch can still be pending here (its devices
                // reached Idle at receive time): dispatch it before the
                // round closes
                if let Some(group) = plan.flush() {
                    flush_group(
                        rt, fleet, group, &mut down, &mut loss_sum, &mut steps, elastic,
                    )?;
                }
                break;
            }
            // timeout close: deadline passed with a quorum of this round's
            // Activations delivered (a round with zero server steps would
            // be meaningless, hence `worked > 0`). `rem` is computed once
            // per iteration so the close test and the recv timeout agree
            // at the float boundary.
            let mut timeout_arg = None;
            if let Some(t) = timeout_s {
                if !opened.is_empty() {
                    let rem = open_s + t - fleet.now_s();
                    if rem <= 0.0 {
                        if worked > 0 && participants.len() >= required {
                            if let Some(group) = plan.flush() {
                                flush_group(
                                    rt, fleet, group, &mut down, &mut loss_sum,
                                    &mut steps, elastic,
                                )?;
                            }
                            break;
                        }
                        // past the deadline but below quorum: wait unbounded
                    } else {
                        timeout_arg = Some(rem);
                    }
                }
                // nobody opened yet: block until carried work frees someone
            }
            // elastic: an emptied fleet makes progress only through
            // admissions — poll on a short tick instead of blocking on a
            // recv that can never complete
            if elastic && !present.iter().any(|&p| p) {
                timeout_arg = Some(timeout_arg.map_or(0.05, |t: f64| t.min(0.05)));
            }
            // with a batch pending, never block: take only what has
            // already arrived (zero timeout) and dispatch the batch the
            // moment the queue goes quiet — opportunistic coalescing that
            // cannot deadlock on devices waiting for their Gradients
            let received = if plan.is_empty() {
                fleet.recv_any(timeout_arg)?
            } else {
                fleet.recv_any(Some(0.0))?
            };
            let Some((d, msg)) = received else {
                if let Some(group) = plan.flush() {
                    flush_group(
                        rt, fleet, group, &mut down, &mut loss_sum, &mut steps, elastic,
                    )?;
                }
                continue; // re-evaluate the close conditions
            };
            match msg {
                Message::Activations { round: r2, device_id, labels, payload } => {
                    if device_id as usize != rt.cfg.gid(d) {
                        return Err(format!(
                            "round {round}: device {} sent activations labeled device {device_id}",
                            rt.cfg.gid(d)
                        ));
                    }
                    let (oround, osync, opened_at) = match phase[d] {
                        Phase::Open { round, sync, opened_s } => (round, sync, opened_s),
                        _ => {
                            return Err(format!(
                                "round {round}: unsolicited Activations from device {d}"
                            ))
                        }
                    };
                    if r2 as usize != oround {
                        return Err(format!(
                            "round {round}: device {d} sent activations for round {r2}, \
                             was opened for {oround}"
                        ));
                    }
                    rt.spec_ack_gate(d, oround)?;
                    up[d] += payload.len();
                    active[d] = true;
                    wait_s[d] = fleet.now_s() - opened_at;
                    if oround == round {
                        participants.push(d);
                        done[d] = true;
                    } else {
                        stale.push(d);
                        crate::log_info!(
                            "[{label}] round {round}: straggler device {d} caught up \
                             (round {oround} activations, waited {:.3}s)",
                            wait_s[d]
                        );
                    }
                    // the device's protocol position advances at receive
                    // time (its Activations are consumed; it owes a sync
                    // push after Gradients, or nothing) — the compute and
                    // the Gradients send ride the batch dispatch
                    phase[d] = if osync {
                        Phase::AwaitSync { round: oround }
                    } else {
                        Phase::Idle
                    };
                    let item = BatchItem { d, round: oround, labels, payload };
                    if let Some(group) = plan.push(item) {
                        flush_group(
                            rt, fleet, group, &mut down, &mut loss_sum, &mut steps,
                            elastic,
                        )?;
                    }
                }
                Message::ModelSync { round: r2, device_id, payload } => {
                    if device_id as usize != rt.cfg.gid(d) {
                        return Err(format!(
                            "round {round}: device {} sent ModelSync labeled device {device_id}",
                            rt.cfg.gid(d)
                        ));
                    }
                    let owed = match phase[d] {
                        Phase::AwaitSync { round } => round,
                        _ => {
                            return Err(format!(
                                "round {round}: unsolicited ModelSync from device {d}"
                            ))
                        }
                    };
                    if r2 as usize != owed {
                        return Err(format!(
                            "round {round}: device {d} pushed ModelSync for round {r2}, \
                             owes round {owed}"
                        ));
                    }
                    if payload.is_empty() {
                        return Err(format!(
                            "round {round}: empty ModelSync push from device {d}"
                        ));
                    }
                    sync_up[d] += payload.len();
                    rt.accept_sync(d, &payload)?;
                    // sync-only progress: the device ran no training step
                    // this round, so it is NOT marked active (no phantom
                    // fwd/bwd/server time) — round_cost_sched still
                    // charges the sync bytes themselves. The loop top
                    // opens it for this round if nobody has opened yet.
                    phase[d] = Phase::Idle;
                }
                Message::SpecUpdateAck { activate_round, streams_fp } => {
                    rt.accept_spec_ack(d, activate_round as usize, streams_fp)?;
                }
                other => {
                    return Err(format!(
                        "round {round}: unexpected {} from device {d}",
                        other.type_name()
                    ))
                }
            }
        }

        // mark devices carried past this close
        let close_s = fleet.now_s();
        let required = min_quorum.unwrap_or(1).min(opened.len());
        let mut stragglers = Vec::new();
        for &d in &opened {
            if let Phase::Open { round: r, opened_s, .. } = phase[d] {
                if r == round {
                    stragglers.push(d);
                    wait_s[d] = close_s - opened_s;
                    crate::log_info!(
                        "[{label}] round {round}: carrying straggler device {d} \
                         (waited {:.3}s, quorum {}/{})",
                        wait_s[d],
                        participants.len(),
                        required
                    );
                }
            }
        }

        // partial FedAvg over whatever sub-models are available; the
        // broadcast goes only to devices at a round boundary. The
        // cross-shard exchange still runs on a basis-less sync round
        // (pushing only the server sub-model) so the coordinator barrier
        // never desyncs, and can even *supply* a cluster client model a
        // straggling shard had no local basis for.
        if agg_due {
            let basis: Vec<usize> =
                (0..n).filter(|&d| rt.client_params[d].is_some()).collect();
            let local = if basis.is_empty() {
                crate::log_debug!(
                    "[{label}] round {round}: no sub-models available for FedAvg"
                );
                None
            } else {
                let _sp = crate::span!("fedavg", round = round);
                Some(rt.fedavg_over(&basis, round)?)
            };
            if let Some(reply) = rt.cross_shard(round, local)? {
                for d in 0..n {
                    if phase[d] == Phase::Idle && present[d] {
                        let payload = rt.pack_broadcast(d, &reply);
                        let len = payload.len();
                        let sent = fleet.send(d, &Message::ModelSync {
                            round: round as u32,
                            device_id: rt.cfg.gid(d) as u32,
                            payload,
                        });
                        match sent {
                            Ok(()) => {
                                sync_down[d] += len;
                                fleet.pump(d)?;
                                rt.client_params[d] = Some(reply.clone());
                            }
                            Err(e) if elastic => crate::log_debug!(
                                "[{label}] round {round}: broadcast to departing \
                                 device {} dropped: {e}",
                                rt.cfg.gid(d)
                            ),
                            Err(e) => return Err(e.into()),
                        }
                    }
                }
                // the re-admission catchup hands this model to whoever
                // returns before the next aggregation boundary
                rt.last_broadcast = Some(reply);
            }
        }

        rounds_run = round + 1;
        let loss = loss_sum / steps.max(1) as f64;
        let sched = SchedRecord {
            round,
            participants: participants.clone(),
            stale,
            stragglers,
            wait_s: wait_s.clone(),
        };
        let stop = close_round(
            rt,
            round,
            wall,
            eval_due,
            loss,
            (up, down, sync_up, sync_down),
            active,
            sched,
            &mut time_to_target,
        )?;
        if stop {
            break;
        }
        let max_wait = wait_s.iter().cloned().fold(0.0f64, f64::max);
        rt.adapt_after_close(round, fleet, max_wait)?;
    }
    Ok(SchedOutcome { rounds_run, time_to_target_s: time_to_target })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::payload::ByteWriter;

    fn payload_with_dims(dims: [u32; 4]) -> Vec<u8> {
        let mut w = ByteWriter::new();
        Header { codec_id: 0, dims }.write(&mut w);
        w.finish()
    }

    fn item(d: usize, dims: [u32; 4]) -> BatchItem {
        BatchItem { d, round: 0, labels: vec![0], payload: payload_with_dims(dims) }
    }

    #[test]
    fn window_one_flushes_every_push() {
        let mut plan = BatchPlan::new(1);
        for d in 0..3 {
            let group = plan.push(item(d, [8, 4, 2, 2])).expect("window 1 = immediate");
            assert_eq!(group.len(), 1);
            assert_eq!(group[0].d, d);
            assert!(plan.is_empty());
        }
        assert!(plan.flush().is_none());
    }

    #[test]
    fn window_fills_then_flushes_in_arrival_order() {
        let mut plan = BatchPlan::new(3);
        assert!(plan.push(item(2, [8, 4, 2, 2])).is_none());
        assert!(plan.push(item(0, [8, 4, 2, 2])).is_none());
        assert_eq!(plan.len(), 2);
        let group = plan.push(item(1, [8, 4, 2, 2])).expect("window reached");
        assert_eq!(group.iter().map(|i| i.d).collect::<Vec<_>>(), vec![2, 0, 1]);
        assert!(plan.is_empty());
    }

    #[test]
    fn shape_change_seals_the_current_group() {
        let mut plan = BatchPlan::new(8);
        assert!(plan.push(item(0, [8, 4, 2, 2])).is_none());
        assert!(plan.push(item(1, [8, 4, 2, 2])).is_none());
        // a differently-shaped uplink must not ride the same dispatch
        let sealed = plan.push(item(2, [4, 4, 2, 2])).expect("shape change seals");
        assert_eq!(sealed.iter().map(|i| i.d).collect::<Vec<_>>(), vec![0, 1]);
        // the odd one out is buffered, not lost
        let rest = plan.flush().expect("new-shape item buffered");
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].d, 2);
    }

    #[test]
    fn unparseable_payloads_form_their_own_group() {
        let mut plan = BatchPlan::new(8);
        assert!(plan.push(item(0, [8, 4, 2, 2])).is_none());
        let garbage =
            BatchItem { d: 1, round: 0, labels: vec![0], payload: vec![1, 2, 3] };
        let sealed = plan.push(garbage).expect("garbage seals the shaped group");
        assert_eq!(sealed.len(), 1);
        assert_eq!(sealed[0].d, 0);
        let rest = plan.flush().unwrap();
        assert_eq!(rest[0].d, 1, "the garbage item surfaces for decode-error reporting");
    }
}
