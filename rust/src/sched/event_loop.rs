//! The event-loop server transport: every accepted device socket is
//! non-blocking and driven from **one** thread.
//!
//! PR 1's `slacc serve` spawned a reader thread per connection
//! ([`crate::transport::tcp::TcpTransport::accept`]); that caps a server at
//! a few hundred devices and buys nothing — the protocol is frame-oriented
//! and the server's work per frame is CPU-bound PJRT stepping anyway.
//! [`PollFleet`] replaces it: sockets sit behind a persistent
//! [`poll::Poller`] interest set (edge-triggered epoll on linux, `poll(2)`
//! elsewhere — see [`FleetOptions::backend`]), reads drain **directly into**
//! per-connection [`FrameDecoder`] rings (no intermediate read buffer), and
//! completed messages surface through the [`Fleet`] interface in true
//! arrival order — which is exactly what the arrival-order round scheduler
//! wants to consume.
//!
//! The connection slab is addressed by stable tokens (= local device
//! slots): a wakeup dispatches O(ready) connections, not O(fleet), and the
//! steady-state wakeup→decode→dispatch path performs no allocation (pinned
//! by the counting-allocator audit in `benches/obs.rs`).
//!
//! Writes are also non-blocking: a `WouldBlock` mid-frame parks on
//! `poll(POLLOUT)` for that one socket, bounded by
//! [`FleetOptions::write_stall_secs`]. Payload-bearing frames go out as a
//! vectored write (header+prefix from a reusable scratch, payload borrowed
//! from the message), so FedAvg/ModelSync broadcasts never assemble a
//! per-device copy of the shared payload. The PJRT engine never crosses a
//! thread boundary because there are no other threads.

use std::collections::VecDeque;
use std::io::{IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Instant;

use crate::member::{Departure, JoinRequest};
use crate::obs::export::MetricsExporter;
use crate::obs::metrics;
use crate::quant::payload::ByteWriter;
use crate::sched::fleet::Fleet;
use crate::sched::poll;
use crate::shard::FleetShape;
use crate::transport::proto::{FrameDecoder, Message};
use crate::transport::server::{hello_from_message, DeviceHello};
use crate::transport::{TransportError, WireStats};

/// Read chunk size per `read` call; frames larger than this reassemble
/// across poll wake-ups in the decoder ring.
const READ_CHUNK: usize = 64 * 1024;

/// Per-connection cap on decoded-but-unconsumed frames. The protocol is
/// lock-step, so a handful of read-ahead is all pipelining needs — this is
/// the poll-loop equivalent of the threaded path's `sync_channel(2)`
/// bound: a peer that floods valid frames is gated out of the interest set
/// (its bytes back up in our TCP window) instead of ballooning server RAM.
const MAX_QUEUED_FRAMES: usize = 8;

/// With a metrics exporter attached, indefinite poll waits are clamped to
/// this so pending scrapers are serviced even while the fleet is quiet.
const EXPORT_TICK_MS: i32 = 50;

/// With the listener armed (elastic sessions), indefinite poll waits are
/// clamped to this so a late joiner is noticed even while the fleet idles.
const JOIN_TICK_MS: i32 = 100;

/// Cap on simultaneously parked `Join` handshakes; connections past the
/// cap are dropped at accept (a churny fleet retries).
const MAX_PENDING_JOINS: usize = 64;

/// Tunables for a [`PollFleet`], surfaced on the CLI as `--io-backend` and
/// `--write-stall-secs`. Deliberately *not* part of the config
/// fingerprint: how a server polls its sockets must not change the
/// handshake, and both backends produce bit-identical sessions.
#[derive(Debug, Clone, Copy)]
pub struct FleetOptions {
    /// Readiness backend (`--io-backend epoll|poll|auto`).
    pub backend: poll::Backend,
    /// Abort a write after stalling this many seconds on a peer that has
    /// stopped reading (`--write-stall-secs`, default 10; 0 = abort at the
    /// first full-buffer stall).
    pub write_stall_secs: u64,
    /// Elastic membership (`--elastic`): mid-session hang-ups and stalls
    /// become typed [`Departure`] events instead of fatal errors, and the
    /// listener stays armed ([`PollFleet::arm_listener`]) so departed or
    /// late devices can `Join` at the next round boundary. Off, the fleet
    /// keeps the fixed-membership semantics every pre-v6 test pins.
    pub elastic: bool,
}

impl Default for FleetOptions {
    fn default() -> FleetOptions {
        FleetOptions { backend: poll::Backend::Auto, write_stall_secs: 10, elastic: false }
    }
}

struct PollConn {
    stream: TcpStream,
    decoder: FrameDecoder,
    /// decoded frames awaiting the scheduler, each with its enqueue
    /// timestamp (`elapsed_ns` at decode) — the `queue_wait` span measures
    /// decode→consume latency per frame
    inbox: VecDeque<(Message, u64)>,
    stats: WireStats,
    peer: String,
    closed: bool,
    /// terminal error to surface when the inbox drains
    failure: Option<TransportError>,
    /// inbox hit [`MAX_QUEUED_FRAMES`] and the socket left the interest
    /// set; re-armed by the ungate path when the scheduler drains it
    gated: bool,
    /// decoder-ring capacity last reported to the `slacc_conn_buf_bytes`
    /// gauge (delta-tracked so closes and reclaims subtract correctly)
    buf_cap: usize,
    /// elastic mode: this close was recorded as a typed [`Departure`]
    /// (queued or already drained by the scheduler) — the slot is vacant
    /// and must not surface a fatal `first_dead_error`
    departed: bool,
    /// a `Leave` frame was decoded on this connection, so the close that
    /// follows is a graceful departure, not a failure
    saw_leave: bool,
}

impl PollConn {
    fn terminal_error(&self) -> TransportError {
        self.failure
            .clone()
            .unwrap_or_else(|| TransportError::PeerClosed { peer: self.peer.clone() })
    }
}

/// A connection accepted after session start, parked until its first frame
/// (which must be a `Join`) arrives and the scheduler rules on admission
/// at the next round boundary.
struct PendingJoin {
    stream: TcpStream,
    decoder: FrameDecoder,
    peer: String,
    key: u64,
    /// decoded `Join`, surfaced to the scheduler exactly once
    request: Option<JoinRequest>,
    surfaced: bool,
    dead: bool,
}

/// A fleet of non-blocking TCP device connections behind one poll loop.
pub struct PollFleet {
    conns: Vec<PollConn>,
    /// connection indices in frame-completion order, one entry per queued
    /// message (the arrival-order queue)
    order: VecDeque<usize>,
    /// persistent readiness set; tokens are connection slots
    poller: poll::Poller,
    /// reusable frame-prefix scratch for the vectored write path
    wbuf: ByteWriter,
    /// connections not yet closed (mirrors the `slacc_open_conns` gauge)
    open_count: usize,
    write_stall_secs: u64,
    start: Instant,
    /// the fleet slice this node serves — maps connection slots to global
    /// device ids for the per-device trace spans
    shape: FleetShape,
    /// `--metrics-bind` scrape endpoint, serviced once per poll pass
    exporter: Option<MetricsExporter>,
    /// elastic membership on ([`FleetOptions::elastic`])
    elastic: bool,
    /// the session listener, kept armed after handshake in elastic mode
    /// ([`PollFleet::arm_listener`]) so late joiners can connect
    listener: Option<TcpListener>,
    /// connections parked mid-`Join` handshake
    pending: Vec<PendingJoin>,
    next_join_key: u64,
    /// typed departures not yet drained by the scheduler
    departures: Vec<Departure>,
}

impl PollFleet {
    /// [`PollFleet::accept_with`] under [`FleetOptions::default`] (auto
    /// backend, 10s write stall).
    pub fn accept(
        listener: &TcpListener,
        shape: FleetShape,
    ) -> Result<(PollFleet, Vec<DeviceHello>), String> {
        PollFleet::accept_with(listener, shape, FleetOptions::default())
    }

    /// Accept one connection per served device slot, run the Hello
    /// handshake through the poll loop, and return the fleet with
    /// connections re-indexed by local slot (TCP accept order is racy;
    /// the Hello says which slot each connection serves). `shape` is the
    /// fleet slice this node serves — [`FleetShape::flat`] for a single
    /// server, a shard's contiguous range in a multi-server topology.
    pub fn accept_with(
        listener: &TcpListener,
        shape: FleetShape,
        opts: FleetOptions,
    ) -> Result<(PollFleet, Vec<DeviceHello>), String> {
        let devices = shape.local;
        let mut poller = poll::Poller::new(opts.backend)?;
        let mut conns = Vec::with_capacity(devices);
        for i in 0..devices {
            crate::log_info!("sched: waiting for device connection {}/{devices}", i + 1);
            let (stream, _) = listener.accept().map_err(|e| format!("accept: {e}"))?;
            let peer = stream
                .peer_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "tcp:unknown".to_string());
            stream.set_nodelay(true).map_err(|e| format!("set_nodelay: {e}"))?;
            stream
                .set_nonblocking(true)
                .map_err(|e| format!("set_nonblocking: {e}"))?;
            poller.register(&stream, i)?;
            conns.push(PollConn {
                stream,
                decoder: FrameDecoder::new(),
                inbox: VecDeque::new(),
                stats: WireStats::default(),
                peer,
                closed: false,
                failure: None,
                gated: false,
                buf_cap: 0,
                departed: false,
                saw_leave: false,
            });
        }
        let mut fleet = PollFleet {
            conns,
            order: VecDeque::new(),
            poller,
            wbuf: ByteWriter::new(),
            open_count: devices,
            write_stall_secs: opts.write_stall_secs,
            start: Instant::now(),
            shape,
            exporter: None,
            elastic: false, // handshake runs fixed-fleet; flips below
            listener: None,
            pending: Vec::new(),
            next_join_key: 0,
            departures: Vec::new(),
        };

        // one Hello per connection, in whatever order they land
        let mut by_conn: Vec<Option<DeviceHello>> = (0..devices).map(|_| None).collect();
        let mut got = 0usize;
        while got < devices {
            let (i, msg) = match fleet.recv_any(None) {
                Ok(Some(pair)) => pair,
                Ok(None) => unreachable!("recv_any(None) cannot time out"),
                Err(e) => return Err(format!("handshake: {e}")),
            };
            if by_conn[i].is_some() {
                return Err(format!(
                    "handshake: {} sent a second frame before HelloAck",
                    fleet.conns[i].peer
                ));
            }
            let peer = fleet.conns[i].peer.clone();
            let hello = hello_from_message(msg, shape, &peer)?;
            crate::log_info!(
                "sched: device {} connected from {peer} (shard={}, {})",
                hello.device_id,
                hello.shard_len,
                hello.streams.table()
            );
            by_conn[i] = Some(hello);
            got += 1;
        }
        // devices are lock-step (they wait for HelloAck before anything
        // else); a frame already queued behind a Hello would desync the
        // rebuilt arrival queue below, so reject it outright
        if !fleet.order.is_empty() {
            return Err("handshake: a device pipelined frames before HelloAck".into());
        }

        // re-index connections by declared device id's local slot
        let mut slots: Vec<Option<(PollConn, DeviceHello)>> =
            (0..devices).map(|_| None).collect();
        let accepted = std::mem::take(&mut fleet.conns);
        for (conn, hello) in accepted.into_iter().zip(by_conn.into_iter()) {
            let hello = hello.expect("every connection delivered a Hello");
            let id = hello.device_id;
            let slot = shape.slot(id).expect("validated by hello_from_message");
            if slots[slot].is_some() {
                return Err(format!("two connections claim device id {id}"));
            }
            slots[slot] = Some((conn, hello));
        }
        let mut conns = Vec::with_capacity(devices);
        let mut hellos = Vec::with_capacity(devices);
        for (slot, entry) in slots.into_iter().enumerate() {
            let (conn, hello) = entry
                .ok_or_else(|| format!("no connection for device {}", shape.gid(slot)))?;
            conns.push(conn);
            hellos.push(hello);
        }
        // a fresh interest set keyed by the *final* slot tokens; the
        // handshake poller (accept-order tokens) unwinds with `fleet`
        let mut poller = poll::Poller::new(opts.backend)?;
        for (slot, conn) in conns.iter().enumerate() {
            poller.register(&conn.stream, slot)?;
        }
        // every inbox was verified empty above, so the rebuilt fleet
        // starts with a consistent (empty) arrival queue
        Ok((
            PollFleet {
                conns,
                order: VecDeque::new(),
                poller,
                wbuf: ByteWriter::new(),
                open_count: devices,
                write_stall_secs: opts.write_stall_secs,
                start: fleet.start,
                shape,
                exporter: fleet.exporter.take(),
                elastic: opts.elastic,
                listener: None,
                pending: Vec::new(),
                next_join_key: 0,
                departures: Vec::new(),
            },
            hellos,
        ))
    }

    /// Keep the session listener armed after handshake (elastic mode):
    /// every poll pass accepts waiting connections, parks them through the
    /// `Join` handshake, and surfaces complete requests via
    /// [`Fleet::poll_joins`]. Requires [`FleetOptions::elastic`].
    pub fn arm_listener(&mut self, listener: TcpListener) -> Result<(), String> {
        if !self.elastic {
            return Err("arm_listener requires FleetOptions::elastic".to_string());
        }
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("listener set_nonblocking: {e}"))?;
        self.listener = Some(listener);
        Ok(())
    }

    /// Attach a `--metrics-bind` scrape endpoint. The exporter is serviced
    /// (non-blocking) on every poll pass, and indefinite waits are clamped
    /// to [`EXPORT_TICK_MS`] so scrapers get answers while the fleet idles.
    pub fn attach_exporter(&mut self, exporter: MetricsExporter) {
        self.exporter = Some(exporter);
    }

    /// Resolved readiness-backend name (`"epoll"`, `"poll"`, or `"busy"`).
    pub fn backend_kind(&self) -> &'static str {
        self.poller.kind()
    }

    /// Mark `i` closed: record the terminal error, leave the interest set,
    /// keep the `open_conns` count and buffer gauge honest. Idempotent.
    /// In elastic mode the close is additionally queued as a typed
    /// [`Departure`] (drained via [`Fleet::take_departures`] once the
    /// slot's already-decoded frames are consumed) and the slot becomes
    /// vacant instead of poisoning the session.
    fn close_conn(&mut self, i: usize, failure: Option<TransportError>) {
        if self.conns[i].closed {
            return;
        }
        self.conns[i].closed = true;
        if self.conns[i].failure.is_none() {
            self.conns[i].failure = failure;
        }
        self.open_count -= 1;
        if self.conns[i].gated {
            // a gated socket already left the interest set
            self.conns[i].gated = false;
        } else {
            let _ = self.poller.deregister(&self.conns[i].stream, i);
        }
        if self.elastic {
            self.conns[i].departed = true;
            self.departures.push(Departure {
                slot: i,
                error: self.conns[i].terminal_error(),
                graceful: self.conns[i].saw_leave && self.conns[i].failure.is_none(),
            });
        }
    }

    /// Sync the `slacc_conn_buf_bytes` gauge with slot `i`'s current
    /// decoder-ring capacity (delta-tracked per connection).
    fn note_buf_cap(&mut self, i: usize) {
        let cap = self.conns[i].decoder.capacity();
        let prev = self.conns[i].buf_cap;
        if cap != prev {
            metrics::CONN_BUF_BYTES.add(cap as i64 - prev as i64);
            self.conns[i].buf_cap = cap;
        }
    }

    /// Service one ready connection: drain the socket into its decoder
    /// ring (edge-triggered contract: read to `WouldBlock`), extract every
    /// complete frame into the inbox, then apply the read-ahead gate and
    /// EOF verdict. Returns how many frames were decoded. Stale tokens
    /// (closed or duplicate) are a no-op.
    fn service(&mut self, i: usize) -> usize {
        if self.conns[i].closed {
            return 0;
        }
        let mut hit_eof = false;
        let mut read_err: Option<String> = None;
        loop {
            let conn = &mut self.conns[i];
            let slot = conn.decoder.read_slot(READ_CHUNK);
            match conn.stream.read(slot) {
                Ok(0) => {
                    hit_eof = true;
                    break;
                }
                Ok(n) => conn.decoder.commit(n),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    read_err = Some(format!("{}: read: {e}", conn.peer));
                    break;
                }
            }
        }
        if let Some(msg) = read_err {
            self.close_conn(i, Some(TransportError::Io(msg)));
        }
        // extract complete frames; whether an EOF was clean is only
        // decidable *after* this pass (the final frames and the hang-up
        // often land in the same wakeup)
        let mut decoded = 0usize;
        loop {
            let conn = &mut self.conns[i];
            // the read-ahead cap bounds *decoded* frames, not just kernel
            // bytes: a live peer's decode stops at the cap with the rest of
            // the burst parked in the ring (the ungate force_ready path
            // re-services it as the scheduler drains the inbox). A dead
            // peer (EOF / read error) drains fully — no more bytes can
            // arrive, and the truncation verdict below must only see
            // genuinely incomplete bytes
            if !hit_eof && !conn.closed && conn.inbox.len() >= MAX_QUEUED_FRAMES {
                break;
            }
            match conn.decoder.next() {
                Ok(Some((msg, n))) => {
                    conn.stats.frames_recv += 1;
                    conn.stats.bytes_recv += n as u64;
                    metrics::FRAMES_RECV.inc();
                    metrics::NET_RX_BYTES.add(n as u64);
                    if matches!(msg, Message::Leave { .. }) {
                        // the hang-up that follows is a graceful departure
                        conn.saw_leave = true;
                    }
                    conn.inbox
                        .push_back((msg, crate::util::logging::elapsed_ns()));
                    self.order.push_back(i);
                    decoded += 1;
                }
                Ok(None) => break,
                Err(e) => {
                    let msg = format!("{}: {e}", conn.peer);
                    self.close_conn(i, Some(TransportError::Protocol(msg)));
                    break;
                }
            }
        }
        if hit_eof {
            // leftover bytes after extracting every complete frame = a
            // genuine mid-frame truncation; none = clean hang-up
            // (surfaces as PeerClosed via terminal_error)
            let buffered = self.conns[i].decoder.buffered();
            let failure = if buffered > 0 {
                Some(TransportError::Io(format!(
                    "{}: connection closed mid-frame ({buffered} bytes buffered)",
                    self.conns[i].peer
                )))
            } else {
                None
            };
            self.close_conn(i, failure);
        }
        // read-ahead gate: at the cap, leave the interest set; bytes back
        // up into the TCP window until the scheduler drains the inbox
        if !self.conns[i].closed
            && !self.conns[i].gated
            && self.conns[i].inbox.len() >= MAX_QUEUED_FRAMES
        {
            let _ = self.poller.mask(&self.conns[i].stream, i);
            self.conns[i].gated = true;
        }
        self.note_buf_cap(i);
        decoded
    }

    /// Re-arm slot `i` after the scheduler drained its inbox below the
    /// cap. The re-registration regenerates an epoll edge if kernel bytes
    /// are pending; the forced-ready mark covers bytes already sitting in
    /// the userspace ring.
    fn ungate(&mut self, i: usize) -> Result<(), TransportError> {
        if !self.conns[i].gated
            || self.conns[i].closed
            || self.conns[i].inbox.len() >= MAX_QUEUED_FRAMES
        {
            return Ok(());
        }
        self.poller
            .unmask(&self.conns[i].stream, i)
            .map_err(TransportError::Io)?;
        self.conns[i].gated = false;
        self.poller.force_ready(i);
        Ok(())
    }

    /// One poll pass: wait up to `timeout_ms` (-1 = forever) for readable
    /// sockets, drain them, decode complete frames into inboxes. Returns
    /// how many frames were decoded.
    fn poll_step(&mut self, timeout_ms: i32) -> Result<usize, TransportError> {
        metrics::POLL_WAKEUPS.inc();
        let timeout_ms = match &mut self.exporter {
            Some(ex) => {
                ex.service();
                // clamp indefinite waits so pending scrapers aren't starved
                // while the fleet is quiet
                if timeout_ms < 0 {
                    EXPORT_TICK_MS
                } else {
                    timeout_ms.min(EXPORT_TICK_MS)
                }
            }
            None => timeout_ms,
        };
        // elastic: accept waiting connections and advance parked Join
        // handshakes every pass, and clamp indefinite waits so a late
        // joiner is noticed even while the fleet is quiet
        let timeout_ms = if self.listener.is_some() {
            self.accept_pending();
            self.service_pending();
            if timeout_ms < 0 { JOIN_TICK_MS } else { timeout_ms.min(JOIN_TICK_MS) }
        } else {
            timeout_ms
        };
        metrics::OPEN_CONNS.set(self.open_count as i64);
        if self.poller.armed() == 0 && !self.poller.has_forced() {
            // every connection is closed or gated: nothing to wait on —
            // but with the listener armed, nap for the tick instead of
            // busy-spinning while an empty fleet waits for joiners
            if self.listener.is_some() && timeout_ms != 0 {
                std::thread::sleep(std::time::Duration::from_millis(
                    timeout_ms.max(1) as u64,
                ));
            }
            return Ok(0);
        }
        let n = self.poller.wait(timeout_ms).map_err(TransportError::Io)?;
        metrics::READY_EVENTS.add(n as u64);
        let mut decoded = 0usize;
        for k in 0..n {
            decoded += self.service(self.poller.ready_token(k));
        }
        metrics::QUEUE_DEPTH.set(self.order.len() as i64);
        Ok(decoded)
    }

    /// The terminal error of the first dead connection. Called when the
    /// arrival queue is drained and at least one socket has closed: a
    /// device that vanishes mid-session is fatal to the session (matching
    /// the in-order `recv_from` semantics), never a silent hang. Elastic
    /// slots are exempt: their closes surface as typed [`Departure`]s.
    fn first_dead_error(&self) -> Option<TransportError> {
        self.conns
            .iter()
            .find(|c| c.closed && !c.departed)
            .map(|c| c.terminal_error())
    }

    /// Whether a departure is ready for the scheduler: a closed elastic
    /// slot whose already-decoded frames have all been consumed.
    /// `recv_any` returns `Ok(None)` on these so an elastic scheduler
    /// wakes up and shrinks its participant set instead of blocking on a
    /// fleet that just shrank. (Parked joins don't wake `recv_any` — they
    /// are acted on at round boundaries via [`Fleet::poll_joins`].)
    fn membership_event_ready(&self) -> bool {
        self.departures.iter().any(|d| self.conns[d.slot].inbox.is_empty())
    }

    /// Trace the decode→consume latency of a frame popped from slot `i`'s
    /// inbox: the uplink's "sat in the arrival queue" stage of a round.
    /// Recorded manually (the wait already happened) with the connection's
    /// global device id; the analyzer assigns the round by time containment.
    fn note_queue_wait(&self, i: usize, enq_ns: u64) {
        if !crate::obs::span::enabled() {
            return;
        }
        let now = crate::util::logging::elapsed_ns();
        crate::obs::span::record(
            crate::obs::span::SpanEvent::manual(
                "queue_wait",
                enq_ns,
                now.saturating_sub(enq_ns),
            )
            .gid(self.shape.gid(i) as u32),
        );
    }

    /// Accept whatever connections are waiting on the armed listener and
    /// park them as pending joins. Non-blocking; called once per poll
    /// pass. Connections past [`MAX_PENDING_JOINS`] are dropped at accept.
    fn accept_pending(&mut self) {
        let Some(listener) = self.listener.as_ref() else { return };
        let mut fresh = Vec::new();
        loop {
            match listener.accept() {
                Ok((stream, _)) => fresh.push(stream),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
        for stream in fresh {
            if self.pending.len() >= MAX_PENDING_JOINS {
                crate::log_info!("sched: dropping join connection (pending cap)");
                continue; // dropping the stream closes it
            }
            let peer = stream
                .peer_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "tcp:unknown".to_string());
            if stream.set_nodelay(true).is_err() || stream.set_nonblocking(true).is_err() {
                continue;
            }
            let key = self.next_join_key;
            self.next_join_key += 1;
            crate::log_info!("sched: join connection from {peer} parked (key {key})");
            self.pending.push(PendingJoin {
                stream,
                decoder: FrameDecoder::new(),
                peer,
                key,
                request: None,
                surfaced: false,
                dead: false,
            });
        }
    }

    /// Advance every parked join handshake: read what the socket has,
    /// decode the first frame, and require it to be a `Join` for a slot
    /// this node serves. Violations (wrong first frame, framing errors,
    /// hang-ups, foreign device ids) kill the pending connection.
    fn service_pending(&mut self) {
        let shape = self.shape;
        for p in &mut self.pending {
            if p.dead || p.request.is_some() {
                continue;
            }
            loop {
                let slot = p.decoder.read_slot(READ_CHUNK);
                match p.stream.read(slot) {
                    Ok(0) => {
                        p.dead = true;
                        break;
                    }
                    Ok(n) => p.decoder.commit(n),
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        p.dead = true;
                        break;
                    }
                }
            }
            if p.dead {
                continue;
            }
            match p.decoder.next() {
                Ok(Some((msg, n))) => match &msg {
                    Message::Join { device_id, member_epoch, .. } => {
                        let gid = *device_id as usize;
                        if shape.slot(gid).is_none() {
                            crate::log_info!(
                                "sched: {} sent Join for device {gid}, not served here",
                                p.peer
                            );
                            p.dead = true;
                            continue;
                        }
                        metrics::FRAMES_RECV.inc();
                        metrics::NET_RX_BYTES.add(n as u64);
                        p.request = Some(JoinRequest {
                            key: p.key,
                            gid,
                            member_epoch: *member_epoch,
                            msg: msg.clone(),
                            join_bytes: n as u64,
                        });
                    }
                    other => {
                        crate::log_info!(
                            "sched: {} opened with {} instead of Join",
                            p.peer,
                            other.type_name()
                        );
                        p.dead = true;
                    }
                },
                Ok(None) => {} // partial frame: keep waiting
                Err(_) => p.dead = true,
            }
        }
        self.pending.retain(|p| !p.dead);
    }
}

impl Drop for PollFleet {
    fn drop(&mut self) {
        // return this fleet's retained ring capacity to the gauge so a
        // finished session reads as zero
        for c in &self.conns {
            if c.buf_cap > 0 {
                metrics::CONN_BUF_BYTES.add(-(c.buf_cap as i64));
            }
        }
    }
}

impl Fleet for PollFleet {
    fn devices(&self) -> usize {
        self.conns.len()
    }

    fn now_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    fn send(&mut self, d: usize, msg: &Message) -> Result<(), TransportError> {
        // payload-bearing frames split into [header+prefix | payload] for a
        // vectored write: the payload bytes are borrowed from the message,
        // never copied into a per-device frame buffer — a broadcast's
        // shared payload goes out of every socket from one allocation
        let payload: &[u8] = match msg.encode_frame_prefix(&mut self.wbuf) {
            Some(p) => p,
            None => {
                // control frames are tiny; assemble them whole
                let frame = msg.encode_frame();
                self.wbuf.clear();
                self.wbuf.bytes(&frame);
                &[]
            }
        };
        let conn = &mut self.conns[d];
        if conn.closed {
            return Err(conn.terminal_error());
        }
        let head = self.wbuf.as_slice();
        let total = head.len() + payload.len();
        let stall_ms =
            self.write_stall_secs.saturating_mul(1000).min(i32::MAX as u64) as i32;
        let mut off = 0usize;
        let mut fail: Option<TransportError> = None;
        while off < total {
            let res = if off < head.len() {
                let bufs = [IoSlice::new(&head[off..]), IoSlice::new(payload)];
                conn.stream.write_vectored(&bufs)
            } else {
                conn.stream.write(&payload[off - head.len()..])
            };
            match res {
                Ok(0) => {
                    fail = Some(TransportError::Io(format!(
                        "{}: write returned 0",
                        conn.peer
                    )));
                    break;
                }
                Ok(n) => off += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    // a peer that stops reading must not wedge the whole
                    // single-threaded loop: bound the stall and fail the
                    // connection instead of retrying forever
                    let _sp = crate::span!("write_park", gid = self.shape.gid(d));
                    match poll::wait_writable(&conn.stream, stall_ms) {
                        Ok(true) => {}
                        Ok(false) => {
                            metrics::WRITE_STALLS.inc();
                            fail = Some(TransportError::Io(format!(
                                "{}: write of {} stalled for {}s (peer not reading)",
                                conn.peer,
                                msg.type_name(),
                                self.write_stall_secs
                            )));
                            break;
                        }
                        Err(e) => {
                            fail = Some(TransportError::Io(e));
                            break;
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    fail = Some(TransportError::Io(format!(
                        "{}: write {}: {e}",
                        conn.peer,
                        msg.type_name()
                    )));
                    break;
                }
            }
        }
        if let Some(e) = fail {
            // elastic: a dead write path is a *departure* — close the slot
            // (queueing the typed event) so the session sheds the device
            // instead of aborting on the error the caller sees
            if self.elastic {
                self.close_conn(d, Some(e.clone()));
            }
            return Err(e);
        }
        let conn = &mut self.conns[d];
        conn.stats.frames_sent += 1;
        conn.stats.bytes_sent += total as u64;
        metrics::FRAMES_SENT.inc();
        metrics::NET_TX_BYTES.add(total as u64);
        Ok(())
    }

    fn recv_from(&mut self, d: usize) -> Result<Message, TransportError> {
        loop {
            if let Some(pos) = self.order.iter().position(|&i| i == d) {
                let _ = self.order.remove(pos);
                let (msg, enq_ns) = self.conns[d]
                    .inbox
                    .pop_front()
                    .expect("order entry implies a queued message");
                self.note_queue_wait(d, enq_ns);
                self.ungate(d)?;
                return Ok(msg);
            }
            if self.conns[d].closed {
                return Err(self.conns[d].terminal_error());
            }
            self.poll_step(-1)?;
        }
    }

    fn recv_any(
        &mut self,
        timeout_s: Option<f64>,
    ) -> Result<Option<(usize, Message)>, TransportError> {
        let deadline = timeout_s
            .map(|t| Instant::now() + std::time::Duration::from_secs_f64(t.max(0.0)));
        loop {
            if let Some(i) = self.order.pop_front() {
                let (msg, enq_ns) = self.conns[i]
                    .inbox
                    .pop_front()
                    .expect("order entry implies a queued message");
                self.note_queue_wait(i, enq_ns);
                self.ungate(i)?;
                return Ok(Some((i, msg)));
            }
            // queue drained: an elastic scheduler must rule on pending
            // membership events (departures with no frames left, parked
            // joins) before blocking on the survivors
            if self.elastic && self.membership_event_ready() {
                return Ok(None);
            }
            // queue drained (so every inbox is empty): any closed socket
            // means a device is gone for good — surface it instead of
            // waiting on the survivors forever
            if let Some(err) = self.first_dead_error() {
                return Err(err);
            }
            let timeout_ms = match deadline {
                None => -1,
                Some(dl) => {
                    let rem = dl.saturating_duration_since(Instant::now());
                    if rem.is_zero() {
                        // drain whatever already landed on the sockets
                        // before giving up: the batch planner probes with
                        // a zero timeout between steps, and frames that
                        // arrived since the last poll pass should coalesce
                        // into the current dispatch, not wait for the next
                        if self.poll_step(0)? == 0 {
                            return Ok(None);
                        }
                        continue;
                    }
                    rem.as_millis().clamp(1, i32::MAX as u128) as i32
                }
            };
            self.poll_step(timeout_ms)?;
        }
    }

    fn pump(&mut self, _d: usize) -> Result<(), TransportError> {
        Ok(()) // remote devices run themselves
    }

    fn stats(&self, d: usize) -> WireStats {
        self.conns[d].stats
    }

    fn peer(&self, d: usize) -> String {
        self.conns[d].peer.clone()
    }

    fn vacant(&self, d: usize) -> bool {
        self.elastic && self.conns[d].closed
    }

    fn take_departures(&mut self) -> Vec<Departure> {
        if self.departures.is_empty() {
            return Vec::new();
        }
        // a departure is only actionable once its slot's in-flight frames
        // are consumed — otherwise the scheduler would shrink the
        // participant set while decoded frames from that device still sit
        // in the inbox and per-device wire accounting would drift
        let all = std::mem::take(&mut self.departures);
        let (ready, waiting): (Vec<_>, Vec<_>) = all
            .into_iter()
            .partition(|d| self.conns[d.slot].inbox.is_empty());
        self.departures = waiting;
        ready
    }

    fn poll_joins(&mut self) -> Vec<JoinRequest> {
        // the scheduler polls at round boundaries, which may be a while
        // after the last poll_step: advance the handshakes now
        self.accept_pending();
        self.service_pending();
        let mut out = Vec::new();
        for p in &mut self.pending {
            if let Some(req) = &p.request {
                if !p.surfaced {
                    p.surfaced = true;
                    out.push(req.clone());
                }
            }
        }
        out
    }

    fn admit_join(&mut self, key: u64, replies: &[Message]) -> Result<(), TransportError> {
        let idx = self
            .pending
            .iter()
            .position(|p| p.key == key)
            .ok_or_else(|| {
                TransportError::Protocol(format!("admit_join: no parked join with key {key}"))
            })?;
        let p = self.pending.remove(idx);
        let req = match &p.request {
            Some(r) => r.clone(),
            None => {
                return Err(TransportError::Protocol(
                    "admit_join: pending connection has no decoded Join".to_string(),
                ))
            }
        };
        let slot = self
            .shape
            .slot(req.gid)
            .expect("service_pending validated the gid maps to a served slot");
        if !self.conns[slot].closed {
            return Err(TransportError::Protocol(format!(
                "admit_join: device {} slot is still open",
                req.gid
            )));
        }
        if !self.conns[slot].inbox.is_empty() {
            return Err(TransportError::Protocol(format!(
                "admit_join: device {} has undrained frames from its previous incarnation",
                req.gid
            )));
        }
        if p.decoder.buffered() > 0 {
            return Err(TransportError::Protocol(format!(
                "{}: sent {} bytes past the Join before JoinAck",
                p.peer,
                p.decoder.buffered()
            )));
        }
        // swap the fresh connection into the vacant slot; per-device wire
        // totals span incarnations (the churn soak pins exact per-device
        // accounting), the decoder ring starts fresh
        let mut stats = self.conns[slot].stats;
        stats.frames_recv += 1; // the Join frame itself
        stats.bytes_recv += req.join_bytes;
        let old = std::mem::replace(
            &mut self.conns[slot],
            PollConn {
                stream: p.stream,
                decoder: p.decoder,
                inbox: VecDeque::new(),
                stats,
                peer: p.peer,
                closed: false,
                failure: None,
                gated: false,
                buf_cap: 0,
                departed: false,
                saw_leave: false,
            },
        );
        if old.buf_cap > 0 {
            metrics::CONN_BUF_BYTES.add(-(old.buf_cap as i64));
        }
        drop(old); // closes the previous incarnation's socket, if still open
        self.open_count += 1;
        metrics::OPEN_CONNS.set(self.open_count as i64);
        self.poller
            .register(&self.conns[slot].stream, slot)
            .map_err(TransportError::Io)?;
        self.note_buf_cap(slot);
        // any stale departure record for this slot is now moot
        self.departures.retain(|d| d.slot != slot);
        self.send_batch(slot, replies)
    }

    fn reject_join(&mut self, key: u64, reason: &str) {
        if let Some(idx) = self.pending.iter().position(|p| p.key == key) {
            let mut p = self.pending.remove(idx);
            crate::log_info!("sched: rejecting join from {}: {reason}", p.peer);
            // best-effort refusal; the connection drops either way
            let frame = Message::Shutdown { reason: reason.to_string() }.encode_frame();
            let _ = p.stream.write(&frame);
        }
    }

    fn send_batch(&mut self, d: usize, msgs: &[Message]) -> Result<(), TransportError> {
        if msgs.len() < 2 {
            return match msgs.first() {
                Some(m) => self.send(d, m),
                None => Ok(()),
            };
        }
        // adjacent control frames for one connection are tiny (JoinAck,
        // SpecUpdate, round control): assemble each whole and push the
        // batch through a single vectored write instead of one syscall
        // per frame
        let frames: Vec<Vec<u8>> = msgs.iter().map(|m| m.encode_frame()).collect();
        let total: usize = frames.iter().map(|f| f.len()).sum();
        let conn = &mut self.conns[d];
        if conn.closed {
            return Err(conn.terminal_error());
        }
        let stall_ms =
            self.write_stall_secs.saturating_mul(1000).min(i32::MAX as u64) as i32;
        let mut off = 0usize;
        let mut writes = 0u64;
        let mut fail: Option<TransportError> = None;
        while off < total {
            // rebuild the slice list past `off` (short writes are rare)
            let mut bufs: Vec<IoSlice> = Vec::with_capacity(frames.len());
            let mut before = 0usize;
            for f in &frames {
                if before + f.len() > off {
                    bufs.push(IoSlice::new(&f[off.saturating_sub(before)..]));
                }
                before += f.len();
            }
            match conn.stream.write_vectored(&bufs) {
                Ok(0) => {
                    fail = Some(TransportError::Io(format!(
                        "{}: write returned 0",
                        conn.peer
                    )));
                    break;
                }
                Ok(n) => {
                    writes += 1;
                    off += n;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    let _sp = crate::span!("write_park", gid = self.shape.gid(d));
                    match poll::wait_writable(&conn.stream, stall_ms) {
                        Ok(true) => {}
                        Ok(false) => {
                            metrics::WRITE_STALLS.inc();
                            fail = Some(TransportError::Io(format!(
                                "{}: batched write of {} frames stalled for {}s \
                                 (peer not reading)",
                                conn.peer,
                                msgs.len(),
                                self.write_stall_secs
                            )));
                            break;
                        }
                        Err(e) => {
                            fail = Some(TransportError::Io(e));
                            break;
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    fail = Some(TransportError::Io(format!(
                        "{}: batched write: {e}",
                        conn.peer
                    )));
                    break;
                }
            }
        }
        if let Some(e) = fail {
            // same departure semantics as the per-frame path
            if self.elastic {
                self.close_conn(d, Some(e.clone()));
            }
            return Err(e);
        }
        // byte parity with the per-frame path: the batch put exactly the
        // sum of the individual frame encodings on the wire
        assert_eq!(off, total, "vectored batch wrote {off} of {total} bytes");
        let conn = &mut self.conns[d];
        conn.stats.frames_sent += msgs.len() as u64;
        conn.stats.bytes_sent += total as u64;
        metrics::FRAMES_SENT.add(msgs.len() as u64);
        metrics::NET_TX_BYTES.add(total as u64);
        metrics::WRITE_BATCHES_TOTAL.add((msgs.len() as u64).saturating_sub(writes));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::tcp::TcpTransport;
    use crate::transport::Transport;
    use std::thread;

    fn hello(d: u32, devices: u32) -> Message {
        let specs = crate::codecs::stream::StreamSpecs::parse(
            "identity", "identity", "identity",
        )
        .unwrap();
        Message::Hello {
            device_id: d,
            devices,
            shard_len: 8,
            config_fp: 1,
            uplink: specs.uplink.as_str().to_string(),
            downlink: specs.downlink.as_str().to_string(),
            sync: specs.sync.as_str().to_string(),
            streams_fp: specs.fingerprint(),
        }
    }

    fn backends_under_test() -> Vec<poll::Backend> {
        if cfg!(target_os = "linux") {
            vec![poll::Backend::Epoll, poll::Backend::Poll]
        } else {
            vec![poll::Backend::Poll]
        }
    }

    fn opts(backend: poll::Backend) -> FleetOptions {
        FleetOptions { backend, write_stall_secs: 10, elastic: false }
    }

    #[test]
    fn accepts_and_orders_by_device_id() {
        for backend in backends_under_test() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap().to_string();
            let mut handles = Vec::new();
            // connect in reverse id order to force re-indexing
            for d in [2u32, 0, 1] {
                let addr = addr.clone();
                handles.push(thread::spawn(move || {
                    let mut t = TcpTransport::connect(&addr).unwrap();
                    t.send(&hello(d, 3)).unwrap();
                    // wait for one reply so the server-side test can send
                    let ack = t.recv().unwrap();
                    assert!(matches!(ack, Message::HelloAck { .. }));
                }));
            }
            let (mut fleet, hellos) =
                PollFleet::accept_with(&listener, FleetShape::flat(3), opts(backend))
                    .unwrap();
            assert_eq!(fleet.devices(), 3);
            for (d, h) in hellos.iter().enumerate() {
                assert_eq!(h.device_id, d);
            }
            for d in 0..3 {
                fleet
                    .send(
                        d,
                        &Message::HelloAck { device_id: d as u32, rounds: 1, agg_every: 1 },
                    )
                    .unwrap();
            }
            for h in handles {
                h.join().unwrap();
            }
        }
    }

    #[test]
    fn recv_any_surfaces_arrival_order() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let mut handles = Vec::new();
        for d in 0..2u32 {
            let addr = addr.clone();
            handles.push(thread::spawn(move || {
                let mut t = TcpTransport::connect(&addr).unwrap();
                t.send(&hello(d, 2)).unwrap();
                // lock-step protocol: round traffic only after HelloAck
                let ack = t.recv().unwrap();
                assert!(matches!(ack, Message::HelloAck { .. }));
                // device 1 answers immediately; device 0 after a pause
                if d == 0 {
                    thread::sleep(std::time::Duration::from_millis(300));
                }
                t.send(&Message::RoundOpen { round: d, sync: false }).unwrap();
                let _ = t.recv(); // hold the socket open until shutdown
            }));
        }
        let (mut fleet, _) = PollFleet::accept(&listener, FleetShape::flat(2)).unwrap();
        for d in 0..2 {
            fleet
                .send(d, &Message::HelloAck { device_id: d as u32, rounds: 1, agg_every: 1 })
                .unwrap();
        }
        let (first, _) = fleet.recv_any(None).unwrap().unwrap();
        assert_eq!(first, 1, "the fast device must surface first");
        let (second, _) = fleet.recv_any(None).unwrap().unwrap();
        assert_eq!(second, 0);
        for d in 0..2 {
            fleet.send(d, &Message::Shutdown { reason: "t".into() }).unwrap();
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn recv_any_times_out_without_traffic() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = thread::spawn(move || {
            let mut t = TcpTransport::connect(&addr).unwrap();
            t.send(&hello(0, 1)).unwrap();
            let _ = t.recv(); // blocks until shutdown
        });
        let (mut fleet, _) = PollFleet::accept(&listener, FleetShape::flat(1)).unwrap();
        let t0 = Instant::now();
        assert!(fleet.recv_any(Some(0.05)).unwrap().is_none());
        let waited = t0.elapsed().as_secs_f64();
        assert!(waited >= 0.04, "returned too early ({waited}s)");
        assert!(waited < 2.0, "timeout wildly overshot ({waited}s)");
        fleet.send(0, &Message::Shutdown { reason: "t".into() }).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn disconnect_surfaces_peer_closed() {
        for backend in backends_under_test() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap().to_string();
            let handle = thread::spawn(move || {
                let mut t = TcpTransport::connect(&addr).unwrap();
                t.send(&hello(0, 1)).unwrap();
                // drop: clean close after the handshake
            });
            let (mut fleet, _) =
                PollFleet::accept_with(&listener, FleetShape::flat(1), opts(backend))
                    .unwrap();
            handle.join().unwrap();
            let err = fleet.recv_from(0).unwrap_err();
            assert!(err.is_peer_closed(), "want PeerClosed, got {err:?}");
        }
    }

    #[test]
    fn flood_gates_at_the_cap_and_recovers_in_order() {
        // a device that fires 50 frames back-to-back must not balloon the
        // inbox: the gate engages at MAX_QUEUED_FRAMES and the ungate path
        // re-arms the socket as the scheduler drains, preserving order
        const FLOOD: u32 = 50;
        for backend in backends_under_test() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap().to_string();
            let handle = thread::spawn(move || {
                let mut t = TcpTransport::connect(&addr).unwrap();
                t.send(&hello(0, 1)).unwrap();
                // lock-step protocol: the flood starts only after HelloAck
                let ack = t.recv().unwrap();
                assert!(matches!(ack, Message::HelloAck { .. }));
                for r in 0..FLOOD {
                    t.send(&Message::RoundOpen { round: r, sync: false }).unwrap();
                }
                let _ = t.recv(); // hold open until shutdown
            });
            let (mut fleet, _) =
                PollFleet::accept_with(&listener, FleetShape::flat(1), opts(backend))
                    .unwrap();
            fleet
                .send(0, &Message::HelloAck { device_id: 0, rounds: 1, agg_every: 1 })
                .unwrap();
            for want in 0..FLOOD {
                let (i, msg) = fleet.recv_any(None).unwrap().unwrap();
                assert_eq!(i, 0);
                match msg {
                    Message::RoundOpen { round, .. } => {
                        assert_eq!(round, want, "{}: flood reordered", backend.as_str())
                    }
                    other => panic!("unexpected {}", other.type_name()),
                }
                assert!(
                    fleet.conns[0].inbox.len() <= MAX_QUEUED_FRAMES,
                    "{}: inbox grew past the gate ({} frames)",
                    backend.as_str(),
                    fleet.conns[0].inbox.len()
                );
            }
            fleet.send(0, &Message::Shutdown { reason: "t".into() }).unwrap();
            handle.join().unwrap();
        }
    }

    #[test]
    fn write_stall_zero_aborts_and_counts() {
        // a peer that never reads: with --write-stall-secs 0 the first
        // full-buffer WouldBlock aborts instead of parking for 10s
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = thread::spawn(move || {
            let mut t = TcpTransport::connect(&addr).unwrap();
            t.send(&hello(0, 1)).unwrap();
            // never read again; hold the socket open long enough for the
            // server's send side to jam
            thread::sleep(std::time::Duration::from_secs(4));
        });
        let (mut fleet, _) = PollFleet::accept_with(
            &listener,
            FleetShape::flat(1),
            FleetOptions { backend: poll::Backend::Auto, write_stall_secs: 0, elastic: false },
        )
        .unwrap();
        let stalls_before = metrics::WRITE_STALLS.get();
        let payload = vec![0u8; 256 * 1024];
        let t0 = Instant::now();
        let mut result = Ok(());
        for round in 0..64 {
            result = fleet.send(
                0,
                &Message::ModelSync { round, device_id: 0, payload: payload.clone() },
            );
            if result.is_err() {
                break;
            }
        }
        let err = result.expect_err("send into a jammed socket must abort");
        assert!(
            err.to_string().contains("stalled"),
            "want a stall error, got: {err}"
        );
        assert!(
            metrics::WRITE_STALLS.get() > stalls_before,
            "slacc_write_stall_total did not move"
        );
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(3),
            "stall abort took {:?} with write_stall_secs=0",
            t0.elapsed()
        );
        drop(fleet);
        handle.join().unwrap();
    }

    #[test]
    fn giant_frame_capacity_is_reclaimed_after_consumption() {
        use crate::transport::proto::DECODER_RETAIN_CAP;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let big = 4 * 1024 * 1024;
        let handle = thread::spawn(move || {
            let mut t = TcpTransport::connect(&addr).unwrap();
            t.send(&hello(0, 1)).unwrap();
            // lock-step protocol: round traffic only after HelloAck
            let ack = t.recv().unwrap();
            assert!(matches!(ack, Message::HelloAck { .. }));
            t.send(&Message::Gradients {
                round: 0,
                device_id: 0,
                loss: 0.0,
                payload: vec![3u8; big],
            })
            .unwrap();
            let _ = t.recv();
        });
        let (mut fleet, _) = PollFleet::accept(&listener, FleetShape::flat(1)).unwrap();
        fleet
            .send(0, &Message::HelloAck { device_id: 0, rounds: 1, agg_every: 1 })
            .unwrap();
        let (_, msg) = fleet.recv_any(None).unwrap().unwrap();
        assert!(matches!(msg, Message::Gradients { .. }));
        // ring capacity ballooned for the 4 MiB frame, then reclaimed on
        // drain; the gauge tracks the retained footprint
        assert!(
            fleet.conns[0].decoder.capacity() <= DECODER_RETAIN_CAP,
            "ring retained {} bytes after the giant frame",
            fleet.conns[0].decoder.capacity()
        );
        assert!(
            metrics::CONN_BUF_BYTES.get() >= 0,
            "conn-buf gauge went negative"
        );
        fleet.send(0, &Message::Shutdown { reason: "t".into() }).unwrap();
        drop(fleet);
        handle.join().unwrap();
    }

    fn elastic_opts() -> FleetOptions {
        FleetOptions { backend: poll::Backend::Auto, write_stall_secs: 10, elastic: true }
    }

    fn join_msg(d: u32, devices: u32, member_epoch: u32) -> Message {
        let specs = crate::codecs::stream::StreamSpecs::parse(
            "identity", "identity", "identity",
        )
        .unwrap();
        Message::Join {
            device_id: d,
            devices,
            shard_len: 8,
            config_fp: 1,
            member_epoch,
            uplink: specs.uplink.as_str().to_string(),
            downlink: specs.downlink.as_str().to_string(),
            sync: specs.sync.as_str().to_string(),
            streams_fp: specs.fingerprint(),
        }
    }

    #[test]
    fn elastic_departure_is_typed_not_fatal() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let quitter = {
            let addr = addr.clone();
            thread::spawn(move || {
                let mut t = TcpTransport::connect(&addr).unwrap();
                t.send(&hello(0, 2)).unwrap();
                // drop: clean close right after the handshake
            })
        };
        let survivor_addr = addr.clone();
        let survivor = thread::spawn(move || {
            let mut t = TcpTransport::connect(&survivor_addr).unwrap();
            t.send(&hello(1, 2)).unwrap();
            let _ = t.recv(); // hold open until shutdown
        });
        let (mut fleet, _) =
            PollFleet::accept_with(&listener, FleetShape::flat(2), elastic_opts()).unwrap();
        quitter.join().unwrap();
        // the hang-up surfaces as a membership wakeup, not a fatal error
        let got = fleet.recv_any(None).unwrap();
        assert!(got.is_none(), "membership event must surface as Ok(None)");
        let deps = fleet.take_departures();
        assert_eq!(deps.len(), 1);
        assert_eq!(deps[0].slot, 0);
        assert!(deps[0].error.is_peer_closed(), "got {:?}", deps[0].error);
        assert!(!deps[0].graceful, "a silent hang-up is not graceful");
        assert!(fleet.vacant(0));
        assert!(!fleet.vacant(1));
        // with the departure drained the fleet blocks normally: a timed
        // wait times out instead of resurfacing the dead slot
        assert!(fleet.recv_any(Some(0.05)).unwrap().is_none());
        assert!(fleet.take_departures().is_empty(), "departure must drain once");
        fleet.send(1, &Message::Shutdown { reason: "t".into() }).unwrap();
        drop(fleet);
        survivor.join().unwrap();
    }

    #[test]
    fn graceful_leave_surfaces_frame_then_departure() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = thread::spawn(move || {
            let mut t = TcpTransport::connect(&addr).unwrap();
            t.send(&hello(0, 1)).unwrap();
            t.send(&Message::Leave { device_id: 0, reason: "battery".into() }).unwrap();
            // drop: the close right after a Leave is a graceful departure
        });
        let (mut fleet, _) =
            PollFleet::accept_with(&listener, FleetShape::flat(1), elastic_opts()).unwrap();
        handle.join().unwrap();
        // the Leave frame itself is delivered first (in-flight frames are
        // consumed before the departure becomes actionable)...
        let (d, msg) = fleet.recv_any(None).unwrap().expect("Leave frame first");
        assert_eq!(d, 0);
        assert!(matches!(msg, Message::Leave { ref reason, .. } if reason == "battery"));
        // ...then the typed departure, flagged graceful
        assert!(fleet.recv_any(None).unwrap().is_none());
        let deps = fleet.take_departures();
        assert_eq!(deps.len(), 1);
        assert!(deps[0].graceful, "Leave-then-close must read as graceful");
    }

    #[test]
    fn late_join_is_parked_and_admitted_with_batched_replies() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let quitter = {
            let addr = addr.clone();
            thread::spawn(move || {
                let mut t = TcpTransport::connect(&addr).unwrap();
                t.send(&hello(0, 2)).unwrap();
            })
        };
        let anchor_addr = addr.clone();
        let anchor = thread::spawn(move || {
            let mut t = TcpTransport::connect(&anchor_addr).unwrap();
            t.send(&hello(1, 2)).unwrap();
            let _ = t.recv(); // hold open until shutdown
        });
        let (mut fleet, _) =
            PollFleet::accept_with(&listener, FleetShape::flat(2), elastic_opts()).unwrap();
        fleet.arm_listener(listener.try_clone().unwrap()).unwrap();
        quitter.join().unwrap();
        assert!(fleet.recv_any(None).unwrap().is_none());
        let deps = fleet.take_departures();
        assert_eq!(deps.len(), 1);
        let stats_before = fleet.stats(0);

        // the device comes back on a fresh connection
        let rejoin_addr = addr.clone();
        let rejoiner = thread::spawn(move || {
            let mut t = TcpTransport::connect(&rejoin_addr).unwrap();
            t.send(&join_msg(0, 2, 0)).unwrap();
            let ack = t.recv().unwrap();
            match ack {
                Message::JoinAck { device_id, member_epoch, .. } => {
                    assert_eq!(device_id, 0);
                    assert_eq!(member_epoch, 1);
                }
                other => panic!("want JoinAck, got {}", other.type_name()),
            }
            let catchup = t.recv().unwrap();
            assert!(matches!(catchup, Message::Catchup { round: 7, .. }));
            t.send(&Message::RoundOpen { round: 7, sync: false }).unwrap();
            let _ = t.recv(); // hold open until shutdown
        });

        // park → surface exactly once → admit with a batched reply pair
        let req = loop {
            let mut reqs = fleet.poll_joins();
            if let Some(r) = reqs.pop() {
                break r;
            }
            thread::sleep(std::time::Duration::from_millis(5));
        };
        assert_eq!(req.gid, 0);
        assert_eq!(req.member_epoch, 0);
        assert!(matches!(req.msg, Message::Join { .. }));
        assert!(fleet.poll_joins().is_empty(), "a join must surface once");

        let batches_before = metrics::WRITE_BATCHES_TOTAL.get();
        fleet
            .admit_join(
                req.key,
                &[
                    Message::JoinAck {
                        device_id: 0,
                        round: 7,
                        member_epoch: 1,
                        rounds: 10,
                        agg_every: 1,
                    },
                    Message::Catchup { round: 7, device_id: 0, spec_epoch: 0, payload: vec![] },
                ],
            )
            .unwrap();
        assert!(
            metrics::WRITE_BATCHES_TOTAL.get() > batches_before,
            "batched admit replies must count saved syscalls"
        );
        assert!(!fleet.vacant(0), "admitted slot is live again");
        // per-device accounting spans incarnations: the old totals plus
        // exactly the Join frame arrived so far
        let stats_after = fleet.stats(0);
        assert_eq!(stats_after.frames_recv, stats_before.frames_recv + 1);
        assert!(stats_after.bytes_recv > stats_before.bytes_recv);

        // the readmitted device participates like any other
        let (d, msg) = fleet.recv_any(None).unwrap().expect("round frame");
        assert_eq!(d, 0);
        assert!(matches!(msg, Message::RoundOpen { round: 7, .. }));
        for d in 0..2 {
            fleet.send(d, &Message::Shutdown { reason: "t".into() }).unwrap();
        }
        drop(fleet);
        rejoiner.join().unwrap();
        anchor.join().unwrap();
    }

    #[test]
    fn arm_listener_requires_elastic_mode() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = thread::spawn(move || {
            let mut t = TcpTransport::connect(&addr).unwrap();
            t.send(&hello(0, 1)).unwrap();
            let _ = t.recv();
        });
        let (mut fleet, _) = PollFleet::accept(&listener, FleetShape::flat(1)).unwrap();
        let err = fleet.arm_listener(listener.try_clone().unwrap()).unwrap_err();
        assert!(err.contains("elastic"), "{err}");
        fleet.send(0, &Message::Shutdown { reason: "t".into() }).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn send_batch_single_writev_matches_per_frame_bytes() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = thread::spawn(move || {
            let mut t = TcpTransport::connect(&addr).unwrap();
            t.send(&hello(0, 1)).unwrap();
            for want in 0..3u32 {
                match t.recv().unwrap() {
                    Message::RoundOpen { round, .. } => assert_eq!(round, want),
                    other => panic!("unexpected {}", other.type_name()),
                }
            }
        });
        let (mut fleet, _) = PollFleet::accept(&listener, FleetShape::flat(1)).unwrap();
        let msgs: Vec<Message> = (0..3)
            .map(|r| Message::RoundOpen { round: r, sync: false })
            .collect();
        let expected: u64 = msgs.iter().map(|m| m.encode_frame().len() as u64).sum();
        let before = fleet.stats(0);
        fleet.send_batch(0, &msgs).unwrap();
        let after = fleet.stats(0);
        assert_eq!(after.frames_sent, before.frames_sent + 3);
        assert_eq!(
            after.bytes_sent,
            before.bytes_sent + expected,
            "batched bytes must match the per-frame encodings exactly"
        );
        handle.join().unwrap();
    }
}
