//! The event-loop server transport: every accepted device socket is
//! non-blocking and driven from **one** thread.
//!
//! PR 1's `slacc serve` spawned a reader thread per connection
//! ([`crate::transport::tcp::TcpTransport::accept`]); that caps a server at
//! a few hundred devices and buys nothing — the protocol is frame-oriented
//! and the server's work per frame is CPU-bound PJRT stepping anyway.
//! [`PollFleet`] replaces it: sockets sit in a `poll(2)` set
//! ([`crate::sched::poll`]), reads drain into per-connection
//! [`FrameDecoder`]s, and completed messages surface through the
//! [`Fleet`] interface in true arrival order — which is exactly what the
//! arrival-order round scheduler wants to consume.
//!
//! Writes are also non-blocking: a `WouldBlock` mid-frame parks on
//! `poll(POLLOUT)` for that one socket. The PJRT engine never crosses a
//! thread boundary because there are no other threads.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Instant;

use crate::obs::export::MetricsExporter;
use crate::obs::metrics;
use crate::sched::fleet::Fleet;
use crate::sched::poll;
use crate::shard::FleetShape;
use crate::transport::proto::{FrameDecoder, Message};
use crate::transport::server::{hello_from_message, DeviceHello};
use crate::transport::{TransportError, WireStats};

/// Read chunk size per `read` call; frames larger than this reassemble
/// across poll wake-ups in the decoder.
const READ_CHUNK: usize = 64 * 1024;

/// Per-connection cap on decoded-but-unconsumed frames. The protocol is
/// lock-step, so a handful of read-ahead is all pipelining needs — this is
/// the poll-loop equivalent of the threaded path's `sync_channel(2)`
/// bound: a peer that floods valid frames blocks in our TCP window (we
/// stop reading its socket) instead of ballooning server RAM.
const MAX_QUEUED_FRAMES: usize = 8;

/// With a metrics exporter attached, indefinite poll waits are clamped to
/// this so pending scrapers are serviced even while the fleet is quiet.
const EXPORT_TICK_MS: i32 = 50;

struct PollConn {
    stream: TcpStream,
    decoder: FrameDecoder,
    /// decoded frames awaiting the scheduler, each with its enqueue
    /// timestamp (`elapsed_ns` at decode) — the `queue_wait` span measures
    /// decode→consume latency per frame
    inbox: VecDeque<(Message, u64)>,
    stats: WireStats,
    peer: String,
    closed: bool,
    /// terminal error to surface when the inbox drains
    failure: Option<TransportError>,
}

impl PollConn {
    fn terminal_error(&self) -> TransportError {
        self.failure
            .clone()
            .unwrap_or_else(|| TransportError::PeerClosed { peer: self.peer.clone() })
    }
}

/// A fleet of non-blocking TCP device connections behind one poll loop.
pub struct PollFleet {
    conns: Vec<PollConn>,
    /// connection indices in frame-completion order, one entry per queued
    /// message (the arrival-order queue)
    order: VecDeque<usize>,
    /// reusable read buffer (poll_step runs on every recv; don't allocate
    /// 64 KiB per wake-up)
    rbuf: Vec<u8>,
    start: Instant,
    /// the fleet slice this node serves — maps connection slots to global
    /// device ids for the per-device trace spans
    shape: FleetShape,
    /// `--metrics-bind` scrape endpoint, serviced once per poll pass
    exporter: Option<MetricsExporter>,
}

impl PollFleet {
    /// Accept one connection per served device slot, run the Hello
    /// handshake through the poll loop, and return the fleet with
    /// connections re-indexed by local slot (TCP accept order is racy;
    /// the Hello says which slot each connection serves). `shape` is the
    /// fleet slice this node serves — [`FleetShape::flat`] for a single
    /// server, a shard's contiguous range in a multi-server topology.
    pub fn accept(
        listener: &TcpListener,
        shape: FleetShape,
    ) -> Result<(PollFleet, Vec<DeviceHello>), String> {
        let devices = shape.local;
        let mut conns = Vec::with_capacity(devices);
        for i in 0..devices {
            crate::log_info!("sched: waiting for device connection {}/{devices}", i + 1);
            let (stream, _) = listener.accept().map_err(|e| format!("accept: {e}"))?;
            let peer = stream
                .peer_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "tcp:unknown".to_string());
            stream.set_nodelay(true).map_err(|e| format!("set_nodelay: {e}"))?;
            stream
                .set_nonblocking(true)
                .map_err(|e| format!("set_nonblocking: {e}"))?;
            conns.push(PollConn {
                stream,
                decoder: FrameDecoder::new(),
                inbox: VecDeque::new(),
                stats: WireStats::default(),
                peer,
                closed: false,
                failure: None,
            });
        }
        let mut fleet = PollFleet {
            conns,
            order: VecDeque::new(),
            rbuf: vec![0u8; READ_CHUNK],
            start: Instant::now(),
            shape,
            exporter: None,
        };

        // one Hello per connection, in whatever order they land
        let mut by_conn: Vec<Option<DeviceHello>> = (0..devices).map(|_| None).collect();
        let mut got = 0usize;
        while got < devices {
            let (i, msg) = match fleet.recv_any(None) {
                Ok(Some(pair)) => pair,
                Ok(None) => unreachable!("recv_any(None) cannot time out"),
                Err(e) => return Err(format!("handshake: {e}")),
            };
            if by_conn[i].is_some() {
                return Err(format!(
                    "handshake: {} sent a second frame before HelloAck",
                    fleet.conns[i].peer
                ));
            }
            let peer = fleet.conns[i].peer.clone();
            let hello = hello_from_message(msg, shape, &peer)?;
            crate::log_info!(
                "sched: device {} connected from {peer} (shard={}, {})",
                hello.device_id,
                hello.shard_len,
                hello.streams.table()
            );
            by_conn[i] = Some(hello);
            got += 1;
        }
        // devices are lock-step (they wait for HelloAck before anything
        // else); a frame already queued behind a Hello would desync the
        // rebuilt arrival queue below, so reject it outright
        if !fleet.order.is_empty() {
            return Err("handshake: a device pipelined frames before HelloAck".into());
        }

        // re-index connections by declared device id's local slot
        let mut slots: Vec<Option<(PollConn, DeviceHello)>> =
            (0..devices).map(|_| None).collect();
        for (conn, hello) in fleet.conns.into_iter().zip(by_conn.into_iter()) {
            let hello = hello.expect("every connection delivered a Hello");
            let id = hello.device_id;
            let slot = shape.slot(id).expect("validated by hello_from_message");
            if slots[slot].is_some() {
                return Err(format!("two connections claim device id {id}"));
            }
            slots[slot] = Some((conn, hello));
        }
        let mut conns = Vec::with_capacity(devices);
        let mut hellos = Vec::with_capacity(devices);
        for (slot, entry) in slots.into_iter().enumerate() {
            let (conn, hello) = entry
                .ok_or_else(|| format!("no connection for device {}", shape.gid(slot)))?;
            conns.push(conn);
            hellos.push(hello);
        }
        // every inbox was verified empty above, so the rebuilt fleet
        // starts with a consistent (empty) arrival queue
        Ok((
            PollFleet {
                conns,
                order: VecDeque::new(),
                rbuf: vec![0u8; READ_CHUNK],
                start: fleet.start,
                shape,
                exporter: fleet.exporter,
            },
            hellos,
        ))
    }

    /// Attach a `--metrics-bind` scrape endpoint. The exporter is serviced
    /// (non-blocking) on every poll pass, and indefinite waits are clamped
    /// to [`EXPORT_TICK_MS`] so scrapers get answers while the fleet idles.
    pub fn attach_exporter(&mut self, exporter: MetricsExporter) {
        self.exporter = Some(exporter);
    }

    /// One poll pass: wait up to `timeout_ms` (-1 = forever) for readable
    /// sockets, drain them, decode complete frames into inboxes. Returns
    /// how many frames were decoded.
    fn poll_step(&mut self, timeout_ms: i32) -> Result<usize, TransportError> {
        metrics::POLL_WAKEUPS.inc();
        let timeout_ms = match &mut self.exporter {
            Some(ex) => {
                ex.service();
                // clamp indefinite waits so pending scrapers aren't starved
                // while the fleet is quiet
                if timeout_ms < 0 {
                    EXPORT_TICK_MS
                } else {
                    timeout_ms.min(EXPORT_TICK_MS)
                }
            }
            None => timeout_ms,
        };
        metrics::OPEN_CONNS.set(self.conns.iter().filter(|c| !c.closed).count() as i64);
        // connections whose inbox is at the read-ahead cap are left out of
        // the poll set entirely: their bytes back up into the TCP window
        // until the scheduler drains them
        let open: Vec<usize> = (0..self.conns.len())
            .filter(|&i| {
                !self.conns[i].closed && self.conns[i].inbox.len() < MAX_QUEUED_FRAMES
            })
            .collect();
        if open.is_empty() {
            return Ok(0);
        }
        let ready = {
            let streams: Vec<&TcpStream> =
                open.iter().map(|&i| &self.conns[i].stream).collect();
            poll::wait_readable(&streams, timeout_ms).map_err(TransportError::Io)?
        };
        let mut decoded = 0usize;
        for (&i, &is_ready) in open.iter().zip(ready.iter()) {
            if !is_ready {
                continue;
            }
            // drain this socket completely, then extract complete frames;
            // whether an EOF was clean is only decidable *after* the
            // extraction pass (the final frames and the hang-up often land
            // in the same poll wake-up)
            let mut hit_eof = false;
            loop {
                match self.conns[i].stream.read(&mut self.rbuf) {
                    Ok(0) => {
                        hit_eof = true;
                        break;
                    }
                    Ok(n) => {
                        let conn = &mut self.conns[i];
                        conn.decoder.feed(&self.rbuf[..n]);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(e) => {
                        let conn = &mut self.conns[i];
                        conn.closed = true;
                        conn.failure = Some(TransportError::Io(format!(
                            "{}: read: {e}",
                            conn.peer
                        )));
                        break;
                    }
                }
            }
            loop {
                match self.conns[i].decoder.next() {
                    Ok(Some((msg, n))) => {
                        let conn = &mut self.conns[i];
                        conn.stats.frames_recv += 1;
                        conn.stats.bytes_recv += n as u64;
                        metrics::FRAMES_RECV.inc();
                        metrics::NET_RX_BYTES.add(n as u64);
                        conn.inbox
                            .push_back((msg, crate::util::logging::elapsed_ns()));
                        self.order.push_back(i);
                        decoded += 1;
                    }
                    Ok(None) => break,
                    Err(e) => {
                        let conn = &mut self.conns[i];
                        conn.closed = true;
                        conn.failure = Some(TransportError::Protocol(format!(
                            "{}: {e}",
                            conn.peer
                        )));
                        break;
                    }
                }
            }
            if hit_eof {
                let conn = &mut self.conns[i];
                conn.closed = true;
                // leftover bytes after extracting every complete frame =
                // a genuine mid-frame truncation; none = clean hang-up
                // (surfaces as PeerClosed via terminal_error)
                if conn.failure.is_none() && conn.decoder.buffered() > 0 {
                    conn.failure = Some(TransportError::Io(format!(
                        "{}: connection closed mid-frame ({} bytes buffered)",
                        conn.peer,
                        conn.decoder.buffered()
                    )));
                }
            }
        }
        metrics::QUEUE_DEPTH.set(self.order.len() as i64);
        Ok(decoded)
    }

    /// The terminal error of the first dead connection. Called when the
    /// arrival queue is drained and at least one socket has closed: a
    /// device that vanishes mid-session is fatal to the session (matching
    /// the in-order `recv_from` semantics), never a silent hang.
    fn first_dead_error(&self) -> Option<TransportError> {
        self.conns.iter().find(|c| c.closed).map(|c| c.terminal_error())
    }

    /// Trace the decode→consume latency of a frame popped from slot `i`'s
    /// inbox: the uplink's "sat in the arrival queue" stage of a round.
    /// Recorded manually (the wait already happened) with the connection's
    /// global device id; the analyzer assigns the round by time containment.
    fn note_queue_wait(&self, i: usize, enq_ns: u64) {
        if !crate::obs::span::enabled() {
            return;
        }
        let now = crate::util::logging::elapsed_ns();
        crate::obs::span::record(
            crate::obs::span::SpanEvent::manual(
                "queue_wait",
                enq_ns,
                now.saturating_sub(enq_ns),
            )
            .gid(self.shape.gid(i) as u32),
        );
    }
}

impl Fleet for PollFleet {
    fn devices(&self) -> usize {
        self.conns.len()
    }

    fn now_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    fn send(&mut self, d: usize, msg: &Message) -> Result<(), TransportError> {
        let frame = msg.encode_frame();
        let conn = &mut self.conns[d];
        if conn.closed {
            return Err(conn.terminal_error());
        }
        let mut off = 0usize;
        while off < frame.len() {
            match conn.stream.write(&frame[off..]) {
                Ok(0) => {
                    return Err(TransportError::Io(format!(
                        "{}: write returned 0",
                        conn.peer
                    )))
                }
                Ok(n) => off += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    // a peer that stops reading must not wedge the whole
                    // single-threaded loop: bound the stall and fail the
                    // connection instead of retrying forever
                    let _sp = crate::span!("write_park", gid = self.shape.gid(d));
                    if !poll::wait_writable(&conn.stream, 10_000)
                        .map_err(TransportError::Io)?
                    {
                        return Err(TransportError::Io(format!(
                            "{}: write of {} stalled for 10s (peer not reading)",
                            conn.peer,
                            msg.type_name()
                        )));
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    return Err(TransportError::Io(format!(
                        "{}: write {}: {e}",
                        conn.peer,
                        msg.type_name()
                    )))
                }
            }
        }
        conn.stats.frames_sent += 1;
        conn.stats.bytes_sent += frame.len() as u64;
        metrics::FRAMES_SENT.inc();
        metrics::NET_TX_BYTES.add(frame.len() as u64);
        Ok(())
    }

    fn recv_from(&mut self, d: usize) -> Result<Message, TransportError> {
        loop {
            if let Some(pos) = self.order.iter().position(|&i| i == d) {
                let _ = self.order.remove(pos);
                let (msg, enq_ns) = self.conns[d]
                    .inbox
                    .pop_front()
                    .expect("order entry implies a queued message");
                self.note_queue_wait(d, enq_ns);
                return Ok(msg);
            }
            if self.conns[d].closed {
                return Err(self.conns[d].terminal_error());
            }
            self.poll_step(-1)?;
        }
    }

    fn recv_any(
        &mut self,
        timeout_s: Option<f64>,
    ) -> Result<Option<(usize, Message)>, TransportError> {
        let deadline = timeout_s
            .map(|t| Instant::now() + std::time::Duration::from_secs_f64(t.max(0.0)));
        loop {
            if let Some(i) = self.order.pop_front() {
                let (msg, enq_ns) = self.conns[i]
                    .inbox
                    .pop_front()
                    .expect("order entry implies a queued message");
                self.note_queue_wait(i, enq_ns);
                return Ok(Some((i, msg)));
            }
            // queue drained (so every inbox is empty): any closed socket
            // means a device is gone for good — surface it instead of
            // waiting on the survivors forever
            if let Some(err) = self.first_dead_error() {
                return Err(err);
            }
            let timeout_ms = match deadline {
                None => -1,
                Some(dl) => {
                    let rem = dl.saturating_duration_since(Instant::now());
                    if rem.is_zero() {
                        // drain whatever already landed on the sockets
                        // before giving up: the batch planner probes with
                        // a zero timeout between steps, and frames that
                        // arrived since the last poll pass should coalesce
                        // into the current dispatch, not wait for the next
                        if self.poll_step(0)? == 0 {
                            return Ok(None);
                        }
                        continue;
                    }
                    rem.as_millis().clamp(1, i32::MAX as u128) as i32
                }
            };
            self.poll_step(timeout_ms)?;
        }
    }

    fn pump(&mut self, _d: usize) -> Result<(), TransportError> {
        Ok(()) // remote devices run themselves
    }

    fn stats(&self, d: usize) -> WireStats {
        self.conns[d].stats
    }

    fn peer(&self, d: usize) -> String {
        self.conns[d].peer.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::tcp::TcpTransport;
    use crate::transport::Transport;
    use std::thread;

    fn hello(d: u32, devices: u32) -> Message {
        let specs = crate::codecs::stream::StreamSpecs::parse(
            "identity", "identity", "identity",
        )
        .unwrap();
        Message::Hello {
            device_id: d,
            devices,
            shard_len: 8,
            config_fp: 1,
            uplink: specs.uplink.as_str().to_string(),
            downlink: specs.downlink.as_str().to_string(),
            sync: specs.sync.as_str().to_string(),
            streams_fp: specs.fingerprint(),
        }
    }

    #[test]
    fn accepts_and_orders_by_device_id() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let mut handles = Vec::new();
        // connect in reverse id order to force re-indexing
        for d in [2u32, 0, 1] {
            let addr = addr.clone();
            handles.push(thread::spawn(move || {
                let mut t = TcpTransport::connect(&addr).unwrap();
                t.send(&hello(d, 3)).unwrap();
                // wait for one reply so the server-side test can send
                let ack = t.recv().unwrap();
                assert!(matches!(ack, Message::HelloAck { .. }));
            }));
        }
        let (mut fleet, hellos) = PollFleet::accept(&listener, FleetShape::flat(3)).unwrap();
        assert_eq!(fleet.devices(), 3);
        for (d, h) in hellos.iter().enumerate() {
            assert_eq!(h.device_id, d);
        }
        for d in 0..3 {
            fleet
                .send(d, &Message::HelloAck { device_id: d as u32, rounds: 1, agg_every: 1 })
                .unwrap();
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn recv_any_surfaces_arrival_order() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let mut handles = Vec::new();
        for d in 0..2u32 {
            let addr = addr.clone();
            handles.push(thread::spawn(move || {
                let mut t = TcpTransport::connect(&addr).unwrap();
                t.send(&hello(d, 2)).unwrap();
                // device 1 answers immediately; device 0 after a pause
                if d == 0 {
                    thread::sleep(std::time::Duration::from_millis(300));
                }
                t.send(&Message::RoundOpen { round: d, sync: false }).unwrap();
                let _ = t.recv(); // hold the socket open until shutdown
            }));
        }
        let (mut fleet, _) = PollFleet::accept(&listener, FleetShape::flat(2)).unwrap();
        let (first, _) = fleet.recv_any(None).unwrap().unwrap();
        assert_eq!(first, 1, "the fast device must surface first");
        let (second, _) = fleet.recv_any(None).unwrap().unwrap();
        assert_eq!(second, 0);
        for d in 0..2 {
            fleet.send(d, &Message::Shutdown { reason: "t".into() }).unwrap();
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn recv_any_times_out_without_traffic() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = thread::spawn(move || {
            let mut t = TcpTransport::connect(&addr).unwrap();
            t.send(&hello(0, 1)).unwrap();
            let _ = t.recv(); // blocks until shutdown
        });
        let (mut fleet, _) = PollFleet::accept(&listener, FleetShape::flat(1)).unwrap();
        let t0 = Instant::now();
        assert!(fleet.recv_any(Some(0.05)).unwrap().is_none());
        let waited = t0.elapsed().as_secs_f64();
        assert!(waited >= 0.04, "returned too early ({waited}s)");
        assert!(waited < 2.0, "timeout wildly overshot ({waited}s)");
        fleet.send(0, &Message::Shutdown { reason: "t".into() }).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn disconnect_surfaces_peer_closed() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = thread::spawn(move || {
            let mut t = TcpTransport::connect(&addr).unwrap();
            t.send(&hello(0, 1)).unwrap();
            // drop: clean close after the handshake
        });
        let (mut fleet, _) = PollFleet::accept(&listener, FleetShape::flat(1)).unwrap();
        handle.join().unwrap();
        let err = fleet.recv_from(0).unwrap_err();
        assert!(err.is_peer_closed(), "want PeerClosed, got {err:?}");
    }
}
