//! The shard side of the coordinator tier: one connection from a shard
//! server up to its coordinator.
//!
//! A [`ShardLink`] owns the transport, the `--sync-codec` stream twins
//! for both directions, and the cross-shard cadence
//! ([`ShardSyncPolicy`]). The server runtime calls
//! [`ShardLink::exchange`] at every due aggregation boundary — a
//! blocking barrier with the coordinator, exactly like a device's
//! ModelSync round-trip one tier down — and [`ShardLink::finish`] when
//! the session ends, so the coordinator never waits on a departed shard.
//!
//! Sub-models ride the existing ModelSync pack format
//! ([`crate::transport::sync`]): client and server sub-models travel as
//! two packs inside one [`Message::ShardSync`] frame, compressed through
//! the negotiated sync stream.

use crate::codecs::Codec;
use crate::tensor::Tensor;
use crate::transport::proto::Message;
use crate::transport::{sync, Transport};

use super::Topology;
use crate::sched::round::ShardSyncPolicy;

/// How a [`ShardLink`] gets a replacement coordinator connection after a
/// mid-session hang-up: typically "accept the next connection on this
/// node's `--shard-bind` listener".
pub type Reacquire = Box<dyn FnMut() -> Result<Box<dyn Transport>, String> + Send>;

/// A shard server's connection to the coordinator tier.
pub struct ShardLink {
    conn: Box<dyn Transport>,
    shard_id: usize,
    policy: ShardSyncPolicy,
    /// compress-side codec for this shard's pushes
    push: Box<dyn Codec>,
    /// decode twin of the coordinator's broadcast codec
    bcast: Box<dyn Codec>,
    scratch: sync::SyncScratch,
    /// next cross-shard sync epoch (increments per completed exchange)
    epoch: usize,
    /// wire bytes of the most recent exchange: (push, merged reply)
    last_wire: (usize, usize),
    finished: bool,
    /// the topology this link was handshaken with, retained so a resumed
    /// coordinator's re-handshake validates against the same flags
    shards: usize,
    sync_every: usize,
    session_fp: u64,
    weight: u64,
    /// re-admission hook: when set, a coordinator hang-up mid-exchange is
    /// a *departure*, not a session failure — the link re-accepts, redoes
    /// the handshake, and re-pushes the barriered epoch (`None` keeps the
    /// pre-elastic behavior: a hang-up is fatal)
    reacquire: Option<Reacquire>,
}

impl ShardLink {
    /// Complete the coordinator handshake on a fresh connection: receive
    /// the coordinator's [`Message::ShardHello`], validate the topology
    /// it declares against this node's flags (shard slot, shard count,
    /// sync cadence, session fingerprint), and echo the hello back with
    /// this shard's FedAvg `weight` (total local training samples).
    /// `codecs` is the `(push, broadcast)` stream pair from
    /// [`crate::config::ExperimentConfig::shard_link_streams`].
    pub fn handshake(
        mut conn: Box<dyn Transport>,
        topo: &Topology,
        shard_id: usize,
        weight: u64,
        session_fp: u64,
        codecs: (Box<dyn Codec>, Box<dyn Codec>),
    ) -> Result<ShardLink, String> {
        hello_exchange(
            &mut conn,
            shard_id,
            topo.shards,
            topo.sync_every,
            session_fp,
            weight,
        )?;
        let (push, bcast) = codecs;
        Ok(ShardLink {
            conn,
            shard_id,
            policy: ShardSyncPolicy::new(topo.sync_every),
            push,
            bcast,
            scratch: sync::SyncScratch::default(),
            epoch: 0,
            last_wire: (0, 0),
            finished: false,
            shards: topo.shards,
            sync_every: topo.sync_every,
            session_fp,
            weight,
            reacquire: None,
        })
    }

    /// Enable coordinator re-admission (see the field docs): `f` yields
    /// the replacement connection — typically by blocking on the shard's
    /// `--shard-bind` listener until a resumed coordinator dials back in.
    pub fn set_reacquire(&mut self, f: Reacquire) {
        self.reacquire = Some(f);
    }

    /// A coordinator hang-up was detected mid-exchange: accept a
    /// replacement connection and redo the hello exchange against the
    /// retained session flags.
    fn readmit(&mut self) -> Result<(), String> {
        let me = self.shard_id;
        let f = self
            .reacquire
            .as_mut()
            .expect("readmit without a reacquire hook");
        crate::log_warn!(
            "shard {me}: coordinator departed mid-session — waiting to re-admit \
             a resumed coordinator (sync epoch {})",
            self.epoch
        );
        let mut conn = f()?;
        hello_exchange(
            &mut conn,
            me,
            self.shards,
            self.sync_every,
            self.session_fp,
            self.weight,
        )?;
        self.conn = conn;
        crate::log_info!("shard {me}: coordinator re-admitted ({})", self.conn.peer());
        Ok(())
    }

    /// Is round `round` a cross-shard sync boundary?
    pub fn due(&self, round: usize) -> bool {
        self.policy.due(round)
    }

    /// Wire bytes of the most recent exchange: (push, merged reply).
    pub fn last_wire(&self) -> (usize, usize) {
        self.last_wire
    }

    /// Completed sync epochs so far.
    pub fn epochs(&self) -> usize {
        self.epoch
    }

    /// One cross-shard sync: push this shard's aggregated client
    /// sub-model (may be empty on a quorum round with no client basis)
    /// and its server sub-model, block until the coordinator's merged
    /// pair arrives, and return it. The merged client list is empty iff
    /// no shard in the cluster had a client basis this epoch.
    pub fn exchange(
        &mut self,
        client: &[Tensor],
        server: &[Tensor],
    ) -> Result<(Vec<Tensor>, Vec<Tensor>), String> {
        let me = self.shard_id;
        if self.finished {
            return Err(format!("shard {me}: exchange after finish"));
        }
        if server.is_empty() {
            return Err(format!("shard {me}: refusing to push an empty server sub-model"));
        }
        let client_pack = sync::pack_params_with(client, self.push.as_mut(), &mut self.scratch);
        let server_pack = sync::pack_params_with(server, self.push.as_mut(), &mut self.scratch);
        let pushed = client_pack.len() + server_pack.len();
        let _sp = crate::span!("shard_sync", epoch = self.epoch);
        // one hang-up is survivable when re-admission is armed: accept the
        // resumed coordinator and re-push this same barriered epoch. A
        // second failure in the same exchange is fatal either way.
        let mut readmitted = false;
        let push_msg = Message::ShardSync {
            epoch: self.epoch as u32,
            shard_id: me as u32,
            client: client_pack,
            server: server_pack,
            // piggyback this shard's cumulative counters so the
            // coordinator can report cluster-wide totals
            metrics: crate::obs::metrics::rollup_blob(),
        };
        let reply = loop {
            let barrier_t0 = std::time::Instant::now();
            let attempt = self
                .conn
                .send(&push_msg)
                .and_then(|_| self.conn.recv());
            match attempt {
                Ok(reply) => {
                    crate::obs::metrics::SHARD_SYNC_WAIT_NS
                        .observe(barrier_t0.elapsed().as_nanos() as u64);
                    crate::obs::metrics::SHARD_SYNCS.inc();
                    break reply;
                }
                Err(e)
                    if e.is_peer_closed() && self.reacquire.is_some() && !readmitted =>
                {
                    readmitted = true;
                    self.readmit()?;
                }
                Err(e) => {
                    return Err(format!("shard {me}: coordinator exchange: {e}"));
                }
            }
        };
        match reply {
            Message::ShardSync { epoch, shard_id, client, server, .. } => {
                if shard_id as usize != me {
                    return Err(format!(
                        "shard {me}: coordinator merge addressed shard {shard_id}"
                    ));
                }
                if epoch as usize != self.epoch {
                    return Err(format!(
                        "shard {me}: coordinator merge for epoch {epoch}, expected \
                         {} — cadence desync",
                        self.epoch
                    ));
                }
                let received = client.len() + server.len();
                let merged_client = sync::unpack_params(&client, self.bcast.as_mut())
                    .map_err(|e| format!("shard {me}: merged client sub-model: {e}"))?;
                let merged_server = sync::unpack_params(&server, self.bcast.as_mut())
                    .map_err(|e| format!("shard {me}: merged server sub-model: {e}"))?;
                if merged_server.is_empty() {
                    return Err(format!(
                        "shard {me}: coordinator merge carried no server sub-model"
                    ));
                }
                self.epoch += 1;
                self.last_wire = (pushed, received);
                Ok((merged_client, merged_server))
            }
            other => Err(format!(
                "shard {me}: expected the coordinator's ShardSync merge, got {}",
                other.type_name()
            )),
        }
    }

    /// Announce a clean departure from the sync tier (two zero-length
    /// blobs). Idempotent; called by the runtime at session end so the
    /// coordinator never blocks on a finished shard.
    pub fn finish(&mut self) -> Result<(), String> {
        if self.finished {
            return Ok(());
        }
        self.finished = true;
        let notice = Message::ShardSync {
            epoch: self.epoch as u32,
            shard_id: self.shard_id as u32,
            client: Vec::new(),
            server: Vec::new(),
            // final counter roll-up rides the departure notice, so the
            // coordinator's cluster totals include the whole session
            metrics: crate::obs::metrics::rollup_blob(),
        };
        match self.conn.send(&notice) {
            Ok(()) => Ok(()),
            // same single-retry rule as exchange: a resumed coordinator
            // still needs the departure notice, or its barrier hangs
            Err(e) if e.is_peer_closed() && self.reacquire.is_some() => {
                self.readmit()?;
                self.conn
                    .send(&notice)
                    .map_err(|e| format!("shard {}: departure notice: {e}", self.shard_id))
            }
            Err(e) => Err(format!("shard {}: departure notice: {e}", self.shard_id)),
        }
    }
}

/// One side of the symmetric ShardHello exchange, shard end: receive the
/// coordinator's topology announcement, validate it against this node's
/// flags, echo it back with this shard's FedAvg weight. Shared by the
/// initial [`ShardLink::handshake`] and the re-admission path — a resumed
/// coordinator is held to exactly the same checks as the original.
fn hello_exchange(
    conn: &mut Box<dyn Transport>,
    shard_id: usize,
    shards: usize,
    sync_every: usize,
    session_fp: u64,
    weight: u64,
) -> Result<(), String> {
    let msg = conn
        .recv()
        .map_err(|e| format!("shard {shard_id}: coordinator handshake: {e}"))?;
    match msg {
        Message::ShardHello { shard_id: sid, shards: m, sync_every: se, config_fp, .. } => {
            if sid as usize != shard_id {
                return Err(format!(
                    "coordinator addressed shard {sid}, this node is shard \
                     {shard_id} — check the --connect-shard address order"
                ));
            }
            if m as usize != shards {
                return Err(format!(
                    "coordinator runs {m} shards, this node was launched \
                     with --shards {shards} — the cluster must agree"
                ));
            }
            if se as usize != sync_every {
                return Err(format!(
                    "coordinator syncs every {se} round(s), this node \
                     every {sync_every} — launch both with the same \
                     --shard-sync-every"
                ));
            }
            if config_fp != session_fp {
                return Err(format!(
                    "coordinator presents session fingerprint {config_fp:#018x}, \
                     this shard expects {session_fp:#018x} — launch every node \
                     of the cluster with identical flags and the same \
                     engine-vs-mock mode"
                ));
            }
        }
        Message::Hello { device_id, .. } => {
            return Err(format!(
                "shard {shard_id}: a device (id {device_id}) connected on the \
                 coordinator port — devices connect to --bind, coordinators \
                 to --shard-bind"
            ))
        }
        other => {
            return Err(format!(
                "shard {shard_id}: expected ShardHello from the coordinator, \
                 got {}",
                other.type_name()
            ))
        }
    }
    conn.send(&Message::ShardHello {
        shard_id: shard_id as u32,
        shards: shards as u32,
        sync_every: sync_every as u32,
        config_fp: session_fp,
        weight,
    })
    .map_err(|e| format!("shard {shard_id}: coordinator handshake reply: {e}"))?;
    crate::log_info!(
        "shard {shard_id}: coordinator link up ({}, weight {weight}, sync \
         every {sync_every})",
        conn.peer()
    );
    Ok(())
}
