//! Coordinator checkpointing: durable cross-shard state, written
//! atomically every sync epoch.
//!
//! The coordinator is the only node in a sharded cluster whose loss is
//! unrecoverable — shards can rejoin the device tier, but a dead
//! coordinator used to take the merged models (and the epoch counter the
//! whole cluster is barriered on) with it. A [`Checkpoint`] captures
//! exactly the state [`super::coordinator::Coordinator::run_resumed`]
//! needs to take over an in-flight session: the session fingerprint and
//! topology (so a resume with different flags is rejected at load time),
//! the per-shard FedAvg weights, the completed-epoch counter, and the
//! last merged client + server sub-models.
//!
//! Durability protocol: serialize to `<dir>/coordinator.ckpt.tmp`, fsync,
//! then atomically rename onto `<dir>/coordinator.ckpt`. A crash mid-write
//! leaves the previous checkpoint intact; a reader never observes a torn
//! file.
//!
//! The format is a little-endian binary layout under a `SLCK` magic —
//! self-contained (no codec streams involved: resumability must not
//! depend on replaying stateful codec history).

use std::io::Write;
use std::path::{Path, PathBuf};

use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"SLCK";
const VERSION: u32 = 1;

/// Final path component of the checkpoint inside `--checkpoint-dir`.
pub const FILE_NAME: &str = "coordinator.ckpt";

/// Everything the coordinator needs to resume an in-flight session.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// session fingerprint (config digest + compute kind) of the cluster
    /// this state belongs to
    pub session_fp: u64,
    pub shards: u32,
    pub sync_every: u32,
    /// completed sync epochs: the resumed coordinator's barrier expects
    /// shard pushes labeled with exactly this epoch next
    pub epochs_done: u32,
    /// per-shard FedAvg weights (index = shard id), captured at handshake
    pub weights: Vec<f64>,
    /// merged client sub-model from the last completed epoch (may be
    /// empty: no shard had a client basis that epoch)
    pub client: Vec<Tensor>,
    /// merged server sub-model from the last completed epoch
    pub server: Vec<Tensor>,
}

impl Checkpoint {
    /// Serialize to the on-disk layout.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64);
        out.extend_from_slice(MAGIC);
        put_u32(&mut out, VERSION);
        put_u64(&mut out, self.session_fp);
        put_u32(&mut out, self.shards);
        put_u32(&mut out, self.sync_every);
        put_u32(&mut out, self.epochs_done);
        put_u32(&mut out, self.weights.len() as u32);
        for w in &self.weights {
            put_u64(&mut out, w.to_bits());
        }
        put_tensors(&mut out, &self.client);
        put_tensors(&mut out, &self.server);
        out
    }

    /// Parse the on-disk layout. Checkpoints come from a prior run of
    /// this same binary family, but the file is still external input:
    /// every length is bounds-checked, truncation is an error, never a
    /// panic.
    pub fn decode(bytes: &[u8]) -> Result<Checkpoint, String> {
        let mut r = Reader { bytes, at: 0 };
        let magic = r.take(4)?;
        if magic != MAGIC {
            return Err("not a coordinator checkpoint (bad magic)".into());
        }
        let version = r.u32()?;
        if version != VERSION {
            return Err(format!(
                "checkpoint version {version}, this binary reads {VERSION}"
            ));
        }
        let session_fp = r.u64()?;
        let shards = r.u32()?;
        let sync_every = r.u32()?;
        let epochs_done = r.u32()?;
        let n = r.u32()? as usize;
        if n != shards as usize {
            return Err(format!("{n} weights for {shards} shards"));
        }
        let mut weights = Vec::with_capacity(n);
        for _ in 0..n {
            weights.push(f64::from_bits(r.u64()?));
        }
        let client = take_tensors(&mut r)?;
        let server = take_tensors(&mut r)?;
        if r.at != r.bytes.len() {
            return Err(format!(
                "{} trailing byte(s) after the checkpoint body",
                r.bytes.len() - r.at
            ));
        }
        Ok(Checkpoint {
            session_fp,
            shards,
            sync_every,
            epochs_done,
            weights,
            client,
            server,
        })
    }

    /// Durably replace `<dir>/coordinator.ckpt` with this state:
    /// write-then-rename through a `.tmp` sibling (see module docs).
    /// Creates `dir` if missing.
    pub fn write_atomic(&self, dir: &Path) -> Result<(), String> {
        std::fs::create_dir_all(dir)
            .map_err(|e| format!("checkpoint dir {}: {e}", dir.display()))?;
        let tmp = dir.join(format!("{FILE_NAME}.tmp"));
        let fin = dir.join(FILE_NAME);
        let bytes = self.encode();
        let mut f = std::fs::File::create(&tmp)
            .map_err(|e| format!("checkpoint {}: {e}", tmp.display()))?;
        f.write_all(&bytes)
            .and_then(|_| f.sync_all())
            .map_err(|e| format!("checkpoint {}: {e}", tmp.display()))?;
        drop(f);
        std::fs::rename(&tmp, &fin)
            .map_err(|e| format!("checkpoint rename onto {}: {e}", fin.display()))?;
        Ok(())
    }

    /// Load `<dir>/coordinator.ckpt`.
    pub fn load(dir: &Path) -> Result<Checkpoint, String> {
        let path = checkpoint_path(dir);
        let bytes = std::fs::read(&path)
            .map_err(|e| format!("checkpoint {}: {e}", path.display()))?;
        Checkpoint::decode(&bytes).map_err(|e| format!("checkpoint {}: {e}", path.display()))
    }
}

/// Where [`Checkpoint::write_atomic`] puts the durable file.
pub fn checkpoint_path(dir: &Path) -> PathBuf {
    dir.join(FILE_NAME)
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_tensors(out: &mut Vec<u8>, ts: &[Tensor]) {
    put_u32(out, ts.len() as u32);
    for t in ts {
        let dims = t.dims();
        put_u32(out, dims.len() as u32);
        for &d in dims {
            put_u32(out, d as u32);
        }
        // f32 bit patterns: the resumed merge must be byte-identical to
        // the uninterrupted one, so no text round-trip
        let data = t.data();
        put_u32(out, data.len() as u32);
        for &x in data {
            put_u32(out, x.to_bits());
        }
    }
}

struct Reader<'a> {
    bytes: &'a [u8],
    at: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.bytes.len() - self.at < n {
            return Err("truncated checkpoint".into());
        }
        let s = &self.bytes[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, String> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, String> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }
}

fn take_tensors(r: &mut Reader) -> Result<Vec<Tensor>, String> {
    // caps keep a corrupt length field from oversizing an allocation;
    // they are far above any real model in this codebase
    const MAX_TENSORS: usize = 1 << 16;
    const MAX_ELEMS: usize = 1 << 28;
    let n = r.u32()? as usize;
    if n > MAX_TENSORS {
        return Err(format!("absurd tensor count {n}"));
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let nd = r.u32()? as usize;
        if nd > 8 {
            return Err(format!("absurd tensor rank {nd}"));
        }
        let mut dims = Vec::with_capacity(nd);
        for _ in 0..nd {
            dims.push(r.u32()? as usize);
        }
        let len = r.u32()? as usize;
        if len > MAX_ELEMS {
            return Err(format!("absurd tensor length {len}"));
        }
        if dims.iter().product::<usize>() != len {
            return Err(format!("tensor dims {dims:?} disagree with length {len}"));
        }
        let mut data = Vec::with_capacity(len);
        for _ in 0..len {
            data.push(f32::from_bits(r.u32()?));
        }
        out.push(Tensor::new(dims, data));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(dims: Vec<usize>, v: Vec<f32>) -> Tensor {
        Tensor::new(dims, v)
    }

    fn sample() -> Checkpoint {
        Checkpoint {
            session_fp: 0xdead_beef_cafe_f00d,
            shards: 2,
            sync_every: 3,
            epochs_done: 7,
            weights: vec![1000.0, 1024.0],
            client: vec![t(vec![2, 2], vec![1.0, -2.5, 0.0, f32::MIN_POSITIVE])],
            server: vec![
                t(vec![3], vec![0.25, 0.5, 0.75]),
                t(vec![1, 2], vec![9.0, -9.0]),
            ],
        }
    }

    #[test]
    fn encode_decode_roundtrips_bit_exactly() {
        let ck = sample();
        let back = Checkpoint::decode(&ck.encode()).unwrap();
        assert_eq!(back, ck);
        // empty client merge (no shard had a client basis) survives too
        let mut ck = sample();
        ck.client = Vec::new();
        assert_eq!(Checkpoint::decode(&ck.encode()).unwrap(), ck);
    }

    #[test]
    fn decode_rejects_corrupt_input() {
        let ck = sample();
        let bytes = ck.encode();
        assert!(Checkpoint::decode(b"nope").is_err());
        assert!(Checkpoint::decode(&bytes[..bytes.len() - 1]).is_err());
        let mut bad_magic = bytes.clone();
        bad_magic[0] = b'X';
        assert!(Checkpoint::decode(&bad_magic).is_err());
        let mut bad_version = bytes.clone();
        bad_version[4] = 99;
        assert!(Checkpoint::decode(&bad_version).is_err());
        let mut trailing = bytes.clone();
        trailing.push(0);
        assert!(Checkpoint::decode(&trailing).is_err());
    }

    #[test]
    fn write_atomic_then_load_and_replace() {
        let dir = std::env::temp_dir().join(format!(
            "slacc-ckpt-test-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let ck = sample();
        ck.write_atomic(&dir).unwrap();
        assert_eq!(Checkpoint::load(&dir).unwrap(), ck);
        // no .tmp litter after a completed write
        assert!(!dir.join(format!("{FILE_NAME}.tmp")).exists());
        // a second write replaces, not appends
        let mut next = ck.clone();
        next.epochs_done = 8;
        next.write_atomic(&dir).unwrap();
        assert_eq!(Checkpoint::load(&dir).unwrap(), next);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
