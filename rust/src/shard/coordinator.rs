//! The coordinator tier: FedAvg across shard servers.
//!
//! A [`Coordinator`] drives a [`crate::sched::fleet::ShardFleet`] whose
//! "devices" are the downstream shard servers. After a symmetric
//! [`Message::ShardHello`] handshake (the coordinator declares the
//! topology, each shard validates and echoes it back with its FedAvg
//! weight), the run is a sequence of *sync epochs*: every active shard
//! pushes its aggregated client sub-model and its server sub-model
//! ([`Message::ShardSync`], packed through the negotiated `--sync-codec`
//! stream), the coordinator merges each with a weighted FedAvg, and
//! broadcasts the merged pair back. A shard leaves the tier by pushing
//! two zero-length blobs (sent by [`crate::shard::link::ShardLink::finish`]
//! at session end — early stopping included); the epoch loop ends when
//! every shard has left.
//!
//! The merge math is the same [`fedavg_params`] the device tier uses —
//! weighted by shard sample counts, folded in shard-id order — so a
//! cluster-wide average at `--shard-sync-every 1` equals the single-server
//! FedAvg up to f32 association.

use std::path::PathBuf;

use crate::codecs::Codec;
use crate::config::ExperimentConfig;
use crate::coordinator::device::fedavg_params;
use crate::sched::fleet::Fleet;
use crate::tensor::Tensor;
use crate::transport::proto::Message;
use crate::transport::{session_fingerprint, sync, TransportError};

use super::checkpoint::Checkpoint;

/// One shard's codec twins on the coordinator side: `push` decodes the
/// shard's uplink packs, `bcast` encodes the merged broadcast.
pub struct ShardCodecs {
    pub push: Box<dyn Codec>,
    pub bcast: Box<dyn Codec>,
}

/// What the coordinator was launched with (every shard must echo it).
#[derive(Debug, Clone)]
pub struct CoordinatorCfg {
    pub shards: usize,
    pub sync_every: usize,
    /// session fingerprint (config digest + compute kind) the whole
    /// cluster must share
    pub session_fp: u64,
    /// codec label for logs
    pub label: String,
    /// `--checkpoint-dir`: write a [`Checkpoint`] (atomic
    /// write-then-rename) after every completed sync epoch
    pub checkpoint_dir: Option<PathBuf>,
    /// `--resume`: load the checkpoint from `checkpoint_dir` at startup
    /// and continue the session from its epoch counter instead of epoch 0
    pub resume: bool,
}

/// Outcome of a coordinator run.
#[derive(Debug, Clone)]
pub struct CoordReport {
    pub shards: usize,
    /// completed cross-shard sync epochs (merges performed)
    pub sync_epochs: usize,
    /// shard → coordinator payload bytes (client + server packs)
    pub bytes_up: usize,
    /// coordinator → shard payload bytes
    pub bytes_down: usize,
    /// per-shard (up, down) payload bytes, index = shard id
    pub per_shard: Vec<(usize, usize)>,
    /// cluster-wide counter totals: every shard's last telemetry roll-up
    /// (piggybacked on its ShardSync pushes) summed by instrument, names
    /// resolved against this binary's registry. Empty when no shard sent
    /// a roll-up (pre-telemetry peers).
    pub cluster_counters: Vec<(String, u64)>,
}

impl CoordReport {
    /// Cluster-wide total of one counter by full exposition name
    /// (e.g. `slacc_wire_bytes_total{stream="uplink"}`).
    pub fn cluster_counter(&self, name: &str) -> Option<u64> {
        self.cluster_counters
            .iter()
            .find(|(n, _)| n == name)
            .map(|&(_, v)| v)
    }
}

/// The coordinator runtime (see module docs).
pub struct Coordinator {
    cfg: CoordinatorCfg,
    codecs: Vec<ShardCodecs>,
    scratch: sync::SyncScratch,
    /// stop after this many *completed* sync epochs, leaving the shards
    /// blocked at their next barrier: the failure-drill knob behind the
    /// kill-and-resume test (and `--halt-after` drills) — the session can
    /// then be picked up by [`Coordinator::run_resumed`]
    halt_after: Option<usize>,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorCfg, codecs: Vec<ShardCodecs>) -> Result<Coordinator, String> {
        if cfg.shards < 2 {
            return Err(format!(
                "a coordinator needs at least 2 shards, got {}",
                cfg.shards
            ));
        }
        if codecs.len() != cfg.shards {
            return Err(format!(
                "{} codec pairs for {} shards",
                codecs.len(),
                cfg.shards
            ));
        }
        Ok(Coordinator { cfg, codecs, scratch: sync::SyncScratch::default(), halt_after: None })
    }

    /// Attach checkpointing flags after construction (the CLI path:
    /// `--checkpoint-dir` / `--resume` are process flags, not part of the
    /// fingerprinted experiment config).
    pub fn configure_checkpoint(&mut self, dir: Option<PathBuf>, resume: bool) {
        self.cfg.checkpoint_dir = dir;
        self.cfg.resume = resume;
    }

    /// Stop after `epochs` completed sync epochs (see the field docs).
    pub fn halt_after(&mut self, epochs: usize) {
        self.halt_after = Some(epochs);
    }

    /// Build a coordinator from the experiment flags. `compute_kind` is
    /// the cluster's execution backend tag ("engine" / "mock") — the
    /// coordinator runs no model itself but must fold the same tag into
    /// the session fingerprint its shards present.
    pub fn from_experiment(
        cfg: &ExperimentConfig,
        compute_kind: &str,
    ) -> Result<Coordinator, String> {
        cfg.validate()?;
        let mut codecs = Vec::with_capacity(cfg.shards);
        for k in 0..cfg.shards {
            let (push, bcast) = cfg.shard_link_streams(k)?;
            codecs.push(ShardCodecs { push, bcast });
        }
        Coordinator::new(
            CoordinatorCfg {
                shards: cfg.shards,
                sync_every: cfg.shard_sync_every,
                session_fp: session_fingerprint(cfg.fingerprint(), compute_kind),
                label: cfg.codec.label(),
                checkpoint_dir: None,
                resume: false,
            },
            codecs,
        )
    }

    /// Drive the full coordinator session over the shard fleet:
    /// handshake, sync epochs until every shard departs, report. With
    /// [`CoordinatorCfg::resume`], the checkpoint is loaded first and the
    /// epoch loop starts at its counter — the shards, re-accepting the
    /// fresh connections through their listeners, re-push the epoch they
    /// were barriered on (see [`crate::shard::link::ShardLink`]'s
    /// re-admission path), so the cluster picks up where the previous
    /// coordinator incarnation died.
    pub fn run(&mut self, fleet: &mut dyn Fleet) -> Result<CoordReport, String> {
        let resumed = if self.cfg.resume {
            let dir = self
                .cfg
                .checkpoint_dir
                .clone()
                .ok_or("--resume needs --checkpoint-dir")?;
            let ck = Checkpoint::load(&dir)?;
            self.validate_checkpoint(&ck)?;
            Some(ck)
        } else {
            None
        };
        let m = self.cfg.shards;
        let label = self.cfg.label.clone();
        if fleet.devices() != m {
            return Err(format!(
                "coordinator: {} shard connections for {m} shards",
                fleet.devices()
            ));
        }
        // announce the topology to every shard, then validate the echoes
        for k in 0..m {
            fleet.send(k, &Message::ShardHello {
                shard_id: k as u32,
                shards: m as u32,
                sync_every: self.cfg.sync_every as u32,
                config_fp: self.cfg.session_fp,
                weight: 0,
            })?;
            fleet.pump(k)?;
        }
        let mut weights = vec![0f64; m];
        for k in 0..m {
            let msg = fleet
                .recv_from(k)
                .map_err(|e| shard_err(k, &fleet.peer(k), &e))?;
            weights[k] = self.validate_hello(k, msg)?;
            crate::log_info!(
                "[{label}] coordinator: shard {k} up ({}, weight {})",
                fleet.peer(k),
                weights[k]
            );
        }
        let start = match resumed {
            Some(ck) => {
                // the weights are derived from the fingerprint-matched
                // config on both sides — a mismatch means the checkpoint
                // belongs to a different cluster despite the fingerprint
                for (k, (&w, &cw)) in weights.iter().zip(ck.weights.iter()).enumerate() {
                    if w != cw {
                        return Err(format!(
                            "shard {k} declares weight {w}, the checkpoint recorded \
                             {cw} — this checkpoint is not from this cluster"
                        ));
                    }
                }
                crate::log_info!(
                    "[{label}] coordinator: resuming from checkpoint at sync \
                     epoch {}",
                    ck.epochs_done
                );
                ck.epochs_done as usize
            }
            None => 0,
        };
        self.run_loop(fleet, &weights, start)
    }

    /// Take over an in-flight session without a handshake: the fleet's
    /// shard links outlived the previous coordinator incarnation (the
    /// in-process takeover path — channel transports whose shard ends are
    /// still barriered on their next push). Epoch counter and FedAvg
    /// weights come from the checkpoint.
    pub fn run_resumed(
        &mut self,
        fleet: &mut dyn Fleet,
        ck: &Checkpoint,
    ) -> Result<CoordReport, String> {
        self.validate_checkpoint(ck)?;
        if fleet.devices() != self.cfg.shards {
            return Err(format!(
                "coordinator: {} shard connections for {} shards",
                fleet.devices(),
                self.cfg.shards
            ));
        }
        crate::log_info!(
            "[{}] coordinator: taking over at sync epoch {}",
            self.cfg.label,
            ck.epochs_done
        );
        self.run_loop(fleet, &ck.weights, ck.epochs_done as usize)
    }

    /// Does this checkpoint belong to the session this coordinator was
    /// launched for?
    fn validate_checkpoint(&self, ck: &Checkpoint) -> Result<(), String> {
        if ck.session_fp != self.cfg.session_fp {
            return Err(format!(
                "checkpoint session fingerprint {:#018x} != this cluster's \
                 {:#018x} — resume with the exact flags of the original run",
                ck.session_fp, self.cfg.session_fp
            ));
        }
        if ck.shards as usize != self.cfg.shards
            || ck.sync_every as usize != self.cfg.sync_every
        {
            return Err(format!(
                "checkpoint topology ({} shards, sync every {}) != launch flags \
                 ({} shards, sync every {})",
                ck.shards, ck.sync_every, self.cfg.shards, self.cfg.sync_every
            ));
        }
        if ck.weights.len() != self.cfg.shards {
            return Err(format!(
                "checkpoint carries {} weights for {} shards",
                ck.weights.len(),
                self.cfg.shards
            ));
        }
        Ok(())
    }

    /// The sync-epoch loop (see [`Coordinator::run`] docs), starting at
    /// `start_epoch`.
    fn run_loop(
        &mut self,
        fleet: &mut dyn Fleet,
        weights: &[f64],
        start_epoch: usize,
    ) -> Result<CoordReport, String> {
        let m = self.cfg.shards;
        let label = self.cfg.label.clone();
        let mut active = vec![true; m];
        let mut epoch = start_epoch;
        let mut bytes_up = 0usize;
        let mut bytes_down = 0usize;
        let mut per_shard = vec![(0usize, 0usize); m];
        // last-seen telemetry roll-up per shard (the blobs are cumulative,
        // so only the newest matters; the departure notice carries the
        // final one)
        let mut rollups: Vec<Vec<(u64, u64)>> = vec![Vec::new(); m];
        loop {
            if let Some(halt) = self.halt_after {
                if epoch >= halt {
                    crate::log_warn!(
                        "[{label}] coordinator: halting after sync epoch {epoch} \
                         (failure drill) — shards stay barriered for a resume"
                    );
                    break;
                }
            }
            // barrier: one message per active shard (push or departure)
            let mut pushes: Vec<Option<(Vec<Tensor>, Vec<Tensor>)>> =
                (0..m).map(|_| None).collect();
            for k in 0..m {
                if !active[k] {
                    continue;
                }
                let msg = fleet
                    .recv_from(k)
                    .map_err(|e| shard_err(k, &fleet.peer(k), &e))?;
                match msg {
                    Message::ShardSync { epoch: e, shard_id, client, server, metrics } => {
                        if shard_id as usize != k {
                            return Err(format!(
                                "shard {k} pushed a sync labeled shard {shard_id}"
                            ));
                        }
                        // telemetry is advisory: a malformed roll-up is
                        // logged and dropped, never a session failure
                        if !metrics.is_empty() {
                            match crate::obs::metrics::parse_rollup(&metrics) {
                                Ok(pairs) => rollups[k] = pairs,
                                Err(e) => crate::log_warn!(
                                    "[{label}] coordinator: shard {k} sent an \
                                     unreadable metrics roll-up: {e}"
                                ),
                            }
                        }
                        if client.is_empty() && server.is_empty() {
                            active[k] = false;
                            crate::log_info!(
                                "[{label}] coordinator: shard {k} left the sync \
                                 tier after {epoch} epoch(s)"
                            );
                            continue;
                        }
                        if e as usize != epoch {
                            return Err(format!(
                                "shard {k} pushed sync epoch {e}, coordinator is \
                                 at {epoch} — cadence desync"
                            ));
                        }
                        let c = sync::unpack_params(&client, self.codecs[k].push.as_mut())
                            .map_err(|e| format!("shard {k} client push: {e}"))?;
                        let s = sync::unpack_params(&server, self.codecs[k].push.as_mut())
                            .map_err(|e| format!("shard {k} server push: {e}"))?;
                        if s.is_empty() {
                            return Err(format!(
                                "shard {k} pushed an empty server sub-model"
                            ));
                        }
                        bytes_up += client.len() + server.len();
                        per_shard[k].0 += client.len() + server.len();
                        pushes[k] = Some((c, s));
                    }
                    other => {
                        return Err(format!(
                            "expected ShardSync from shard {k}, got {}",
                            other.type_name()
                        ))
                    }
                }
            }
            if pushes.iter().all(|p| p.is_none()) {
                break; // every shard has left
            }
            let fedavg_t0 = std::time::Instant::now();
            let (merged_client, merged_server) = {
                let _sp = crate::span!("fedavg_merge", epoch = epoch);
                merge_shard_models(&pushes, weights, epoch)?
            };
            crate::obs::metrics::FEDAVG_NS.observe(fedavg_t0.elapsed().as_nanos() as u64);
            for k in 0..m {
                if pushes[k].is_none() {
                    continue;
                }
                let cb = sync::pack_params_with(
                    &merged_client,
                    self.codecs[k].bcast.as_mut(),
                    &mut self.scratch,
                );
                let sb = sync::pack_params_with(
                    &merged_server,
                    self.codecs[k].bcast.as_mut(),
                    &mut self.scratch,
                );
                bytes_down += cb.len() + sb.len();
                per_shard[k].1 += cb.len() + sb.len();
                fleet.send(k, &Message::ShardSync {
                    epoch: epoch as u32,
                    shard_id: k as u32,
                    client: cb,
                    server: sb,
                    metrics: Vec::new(),
                })?;
                fleet.pump(k)?;
            }
            epoch += 1;
            // durable point: everything a successor needs to take over is
            // on disk before the next barrier is entered
            if let Some(dir) = self.cfg.checkpoint_dir.clone() {
                let t0 = std::time::Instant::now();
                let _sp = crate::span!("checkpoint", epoch = epoch);
                Checkpoint {
                    session_fp: self.cfg.session_fp,
                    shards: m as u32,
                    sync_every: self.cfg.sync_every as u32,
                    epochs_done: epoch as u32,
                    weights: weights.to_vec(),
                    client: merged_client,
                    server: merged_server,
                }
                .write_atomic(&dir)?;
                crate::obs::metrics::CHECKPOINT_WRITE_NS
                    .observe(t0.elapsed().as_nanos() as u64);
            }
            crate::log_debug!("[{label}] coordinator: sync epoch {epoch} merged");
        }
        crate::log_info!(
            "[{label}] coordinator done: {epoch} sync epoch(s), {bytes_up} B up / \
             {bytes_down} B down"
        );
        Ok(CoordReport {
            shards: m,
            sync_epochs: epoch,
            bytes_up,
            bytes_down,
            per_shard,
            cluster_counters: sum_rollups(&rollups),
        })
    }

    /// Validate one shard's hello echo; returns its FedAvg weight.
    fn validate_hello(&self, k: usize, msg: Message) -> Result<f64, String> {
        match msg {
            Message::ShardHello { shard_id, shards, sync_every, config_fp, weight } => {
                if shard_id as usize != k {
                    return Err(format!(
                        "connection {k} answered as shard {shard_id} — check the \
                         --connect-shard address order"
                    ));
                }
                if shards as usize != self.cfg.shards {
                    return Err(format!(
                        "shard {k} was configured for {shards} shards, the \
                         coordinator for {} — launch with the same --shards",
                        self.cfg.shards
                    ));
                }
                if sync_every as usize != self.cfg.sync_every {
                    return Err(format!(
                        "shard {k} syncs every {sync_every} round(s), the \
                         coordinator every {} — launch with the same \
                         --shard-sync-every",
                        self.cfg.sync_every
                    ));
                }
                if config_fp != self.cfg.session_fp {
                    return Err(format!(
                        "shard {k} presents session fingerprint {config_fp:#018x}, \
                         the coordinator expects {:#018x} — launch every node of \
                         the cluster with identical flags and the same \
                         engine-vs-mock mode",
                        self.cfg.session_fp
                    ));
                }
                if weight == 0 {
                    return Err(format!("shard {k} declares an empty device fleet"));
                }
                Ok(weight as f64)
            }
            Message::Hello { device_id, .. } => Err(format!(
                "a device (id {device_id}) connected to the coordinator — devices \
                 connect to a shard server's --bind address, the coordinator's \
                 --connect-shard list points at shard --shard-bind addresses"
            )),
            other => Err(format!(
                "expected ShardHello from shard {k}, got {}",
                other.type_name()
            )),
        }
    }
}

/// Sum per-shard roll-ups by instrument hash and resolve names against
/// this binary's registry. Hashes no local counter matches (a newer peer's
/// instrument) are reported under their hex hash rather than dropped.
fn sum_rollups(rollups: &[Vec<(u64, u64)>]) -> Vec<(String, u64)> {
    use std::collections::BTreeMap;
    let mut totals: BTreeMap<u64, u64> = BTreeMap::new();
    for pairs in rollups {
        for &(hash, value) in pairs {
            *totals.entry(hash).or_insert(0) += value;
        }
    }
    // registry order keeps the report stable and human-scannable
    let mut out = Vec::with_capacity(totals.len());
    for c in crate::obs::metrics::counters() {
        let name = c.full_name();
        if let Some(v) = totals.remove(&crate::codecs::stream::fnv1a(&name)) {
            out.push((name, v));
        }
    }
    for (hash, v) in totals {
        out.push((format!("unknown_{hash:#018x}"), v));
    }
    out
}

fn shard_err(k: usize, peer: &str, e: &TransportError) -> String {
    if e.is_peer_closed() {
        format!("shard {k} ({peer}) disconnected mid-session: {e}")
    } else {
        format!("shard {k} ({peer}): {e}")
    }
}

/// Weighted FedAvg of the pushed shard sub-models, folded in shard-id
/// order (deterministic f32 association). Server sub-models must agree in
/// shape across every pushing shard; client sub-models are merged over
/// the shards that had one this epoch (a quorum round on some shard may
/// push none) with weights renormalized among them — empty result iff no
/// shard had a client basis.
pub(crate) fn merge_shard_models(
    pushes: &[Option<(Vec<Tensor>, Vec<Tensor>)>],
    weights: &[f64],
    epoch: usize,
) -> Result<(Vec<Tensor>, Vec<Tensor>), String> {
    use super::shapes_match;
    let mut server_sets: Vec<&[Tensor]> = Vec::new();
    let mut server_w: Vec<f64> = Vec::new();
    let mut client_sets: Vec<&[Tensor]> = Vec::new();
    let mut client_w: Vec<f64> = Vec::new();
    let mut first_server: Option<usize> = None;
    let mut first_client: Option<usize> = None;
    for (k, push) in pushes.iter().enumerate() {
        let Some((client, server)) = push else { continue };
        if let Some(j) = first_server {
            if !shapes_match(server, server_sets[0]) {
                return Err(format!(
                    "sync epoch {epoch}: shard {k} pushed a server sub-model whose \
                     shape differs from shard {j}'s"
                ));
            }
        } else {
            first_server = Some(k);
        }
        server_sets.push(server);
        server_w.push(weights[k]);
        if !client.is_empty() {
            if let Some(j) = first_client {
                if !shapes_match(client, client_sets[0]) {
                    return Err(format!(
                        "sync epoch {epoch}: shard {k} pushed a client sub-model \
                         whose shape differs from shard {j}'s"
                    ));
                }
            } else {
                first_client = Some(k);
            }
            client_sets.push(client);
            client_w.push(weights[k]);
        }
    }
    if server_sets.is_empty() {
        return Err(format!("sync epoch {epoch}: no shard pushed a sub-model"));
    }
    let merged_server = fedavg_params(&server_sets, &server_w);
    let merged_client = if client_sets.is_empty() {
        Vec::new()
    } else {
        fedavg_params(&client_sets, &client_w)
    };
    Ok((merged_client, merged_server))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: &[f32]) -> Tensor {
        Tensor::new(vec![v.len()], v.to_vec())
    }

    #[test]
    fn merge_weights_by_shard_samples() {
        let pushes = vec![
            Some((vec![t(&[1.0])], vec![t(&[0.0, 2.0])])),
            Some((vec![t(&[3.0])], vec![t(&[4.0, 0.0])])),
        ];
        // weights 1:3 — merged = 0.25*a + 0.75*b
        let (mc, ms) = merge_shard_models(&pushes, &[1.0, 3.0], 0).unwrap();
        assert_eq!(mc.len(), 1);
        assert!((mc[0].data()[0] - 2.5).abs() < 1e-6);
        assert!((ms[0].data()[0] - 3.0).abs() < 1e-6);
        assert!((ms[0].data()[1] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn merge_skips_clientless_pushes_and_renormalizes() {
        let pushes = vec![
            Some((Vec::new(), vec![t(&[2.0])])),
            Some((vec![t(&[6.0])], vec![t(&[4.0])])),
        ];
        let (mc, ms) = merge_shard_models(&pushes, &[1.0, 1.0], 1).unwrap();
        // only shard 1 had a client model: merge == its model exactly
        assert_eq!(mc.len(), 1);
        assert!((mc[0].data()[0] - 6.0).abs() < 1e-6);
        // server merge still spans both shards
        assert!((ms[0].data()[0] - 3.0).abs() < 1e-6);

        // nobody had a client basis: empty client merge, server still runs
        let pushes = vec![
            Some((Vec::new(), vec![t(&[2.0])])),
            Some((Vec::new(), vec![t(&[4.0])])),
        ];
        let (mc, _) = merge_shard_models(&pushes, &[1.0, 1.0], 2).unwrap();
        assert!(mc.is_empty());
    }

    #[test]
    fn rollups_sum_across_shards_and_resolve_names() {
        let name = crate::obs::metrics::ROUNDS_CLOSED.full_name();
        let hash = crate::codecs::stream::fnv1a(&name);
        let rollups = vec![
            vec![(hash, 3), (0xdead_beef, 7)],
            vec![(hash, 5)],
            Vec::new(),
        ];
        let totals = sum_rollups(&rollups);
        assert_eq!(
            totals.iter().find(|(n, _)| n == &name).map(|&(_, v)| v),
            Some(8)
        );
        // an unknown instrument hash survives under its hex name
        assert!(totals.iter().any(|(n, v)| n.starts_with("unknown_0x") && *v == 7));
    }

    #[test]
    fn merge_rejects_shape_mismatch_and_empty_epochs() {
        let pushes = vec![
            Some((vec![t(&[1.0])], vec![t(&[1.0, 2.0])])),
            Some((vec![t(&[1.0])], vec![t(&[1.0])])),
        ];
        assert!(merge_shard_models(&pushes, &[1.0, 1.0], 0).is_err());
        let none: Vec<Option<(Vec<Tensor>, Vec<Tensor>)>> = vec![None, None];
        assert!(merge_shard_models(&none, &[1.0, 1.0], 0).is_err());
    }
}
