//! Multi-server topology tier: role-based nodes and cross-shard
//! parameter sync.
//!
//! A single `slacc serve` process is the scaling ceiling no codec can
//! lift — every device's smashed data funnels through one server model.
//! This subsystem partitions the device fleet across several shard
//! servers with a parameter-sync tier between them:
//!
//! * [`Role::Shard`] — today's behavior: a
//!   [`crate::transport::server::ServerRuntime`] driving a device
//!   [`crate::sched::fleet::Fleet`] (`PollFleet` over sockets, `PumpFleet`
//!   in-process). In a sharded cluster a shard additionally holds a
//!   [`link::ShardLink`] to the coordinator and pauses at
//!   `--shard-sync-every` round boundaries to exchange sub-models.
//! * [`Role::Coordinator`] — a node whose "fleet" is the downstream shard
//!   servers themselves: a [`crate::sched::fleet::ShardFleet`] over the
//!   same framed protocol, driven by [`coordinator::Coordinator`]. Each
//!   sync epoch it FedAvgs the shards' client and server sub-models
//!   (weighted by shard sample counts) and broadcasts the merge back.
//!
//! Inter-shard traffic rides the existing ModelSync pack format
//! ([`crate::transport::sync`]) on the negotiated `--sync-codec` stream
//! and is accounted on the `bytes_sync` axis. The topology (shard count,
//! sync cadence) is folded into the session fingerprint and echoed in the
//! [`crate::transport::proto::Message::ShardHello`] handshake, so a
//! mismatched cluster is rejected at connect time exactly like mismatched
//! codecs and batch windows.
//!
//! The fleet is split into contiguous equal ranges: shard `k` of `M`
//! serves global device ids `[k*per, (k+1)*per)` where
//! `per = devices / M` ([`Topology::shape_for`]). Devices keep their
//! *global* ids everywhere — data partition, batch-loader seeds, and
//! codec stream seeds are all derived from the global id, so a sharded
//! cluster and a single server train the *same* per-device data streams.
//!
//! [`sim::run_sharded_mock`] runs the whole topology in one process
//! (shard sessions on threads over loopback, the coordinator over
//! [`crate::transport::channel`] transports) so the tier is testable
//! deterministically without sockets; `examples/sharded.rs` runs the same
//! cluster as real processes over localhost TCP.

pub mod checkpoint;
pub mod coordinator;
pub mod link;
pub mod sim;

/// Do two tensor lists agree element-for-element in shape? The one
/// definition both tiers validate remote sub-models against (the
/// coordinator checking shard pushes, a shard checking the coordinator's
/// merge) — peers are remote, so a mismatch must be an error, never a
/// panic downstream.
pub(crate) fn shapes_match(a: &[crate::tensor::Tensor], b: &[crate::tensor::Tensor]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.dims() == y.dims())
}

/// Shard `shard_id`'s cross-shard FedAvg weight: total training samples
/// across its device slice. Every node derives the same partition from
/// the shared (fingerprint-matched) config, so the cluster agrees on the
/// weights without shipping the dataset — the single definition behind
/// the shard CLI, the in-process simulator, and `examples/sharded.rs`.
pub fn shard_weight(
    cfg: &crate::config::ExperimentConfig,
    train: &crate::data::Dataset,
    shard_id: usize,
) -> u64 {
    let shape = cfg.topology().shape_for(cfg.devices, shard_id);
    let parts =
        crate::data::partition::partition(train, cfg.devices, cfg.partition, cfg.seed);
    (shape.base..shape.base + shape.local)
        .map(|g| parts.device(g).len() as u64)
        .sum()
}

/// What a `slacc serve` node is in the topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// A (possibly the only) device-serving shard server.
    Shard,
    /// The cross-shard aggregation tier: serves shard servers, not devices.
    Coordinator,
}

impl Role {
    pub fn parse(s: &str) -> Result<Role, String> {
        match s {
            "shard" => Ok(Role::Shard),
            "coordinator" => Ok(Role::Coordinator),
            other => Err(format!("unknown --role '{other}' (shard|coordinator)")),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Role::Shard => "shard",
            Role::Coordinator => "coordinator",
        }
    }
}

/// The cluster shape every node must agree on: how many shard servers the
/// device fleet is split across and how often they merge sub-models.
/// `shards == 1` is the degenerate single-server topology (no coordinator,
/// no shard link — exactly the pre-topology behavior).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    /// Number of shard servers the device fleet is partitioned across.
    pub shards: usize,
    /// `--shard-sync-every K`: the coordinator FedAvgs shard sub-models
    /// every K rounds (1 = every round).
    pub sync_every: usize,
}

impl Topology {
    /// The single-server topology.
    pub fn single() -> Topology {
        Topology { shards: 1, sync_every: 1 }
    }

    pub fn is_sharded(&self) -> bool {
        self.shards > 1
    }

    /// Validate against the fleet shape. A cross-shard sync round needs
    /// fresh client sub-models to merge, so the sync cadence must land on
    /// aggregation rounds only.
    pub fn validate(&self, devices: usize, client_agg_every: usize) -> Result<(), String> {
        if self.shards == 0 {
            return Err("shards must be >= 1".into());
        }
        if self.sync_every == 0 {
            return Err("--shard-sync-every must be >= 1".into());
        }
        if self.shards > 1 {
            if devices % self.shards != 0 {
                return Err(format!(
                    "{devices} devices do not split evenly across {} shards \
                     (the fleet is partitioned into contiguous equal ranges)",
                    self.shards
                ));
            }
            if self.sync_every % client_agg_every != 0 {
                return Err(format!(
                    "--shard-sync-every {} must be a multiple of --agg-every \
                     {client_agg_every} (a cross-shard sync round needs fresh \
                     client sub-models to merge)",
                    self.sync_every
                ));
            }
        }
        Ok(())
    }

    /// The contiguous global-device-id range shard `shard_id` serves.
    /// Call [`Topology::validate`] first; an indivisible fleet here is a
    /// programmer error.
    pub fn shape_for(&self, devices: usize, shard_id: usize) -> FleetShape {
        assert!(
            self.shards >= 1 && devices % self.shards == 0,
            "topology not validated: {devices} devices across {} shards",
            self.shards
        );
        assert!(
            shard_id < self.shards,
            "shard id {shard_id} out of range ({} shards)",
            self.shards
        );
        let per = devices / self.shards;
        FleetShape { global: devices, base: shard_id * per, local: per }
    }
}

/// The slice of the global device fleet one server node handshakes with:
/// devices declare their *global* id and the global fleet size, and the
/// node maps ids in `[base, base + local)` onto its local slots. A
/// single server is the `flat` shape (base 0, local == global).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FleetShape {
    /// Total devices in the cluster (what every device's Hello declares).
    pub global: usize,
    /// First global device id served by this node.
    pub base: usize,
    /// Number of devices served by this node.
    pub local: usize,
}

impl FleetShape {
    /// The unsharded shape: one server, every device.
    pub fn flat(n: usize) -> FleetShape {
        FleetShape { global: n, base: 0, local: n }
    }

    /// Local slot of a global device id, if this node serves it.
    pub fn slot(&self, gid: usize) -> Option<usize> {
        if gid >= self.base && gid < self.base + self.local {
            Some(gid - self.base)
        } else {
            None
        }
    }

    /// Global device id of a local slot.
    pub fn gid(&self, slot: usize) -> usize {
        debug_assert!(slot < self.local);
        self.base + slot
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn role_parses() {
        assert_eq!(Role::parse("shard").unwrap(), Role::Shard);
        assert_eq!(Role::parse("coordinator").unwrap(), Role::Coordinator);
        assert!(Role::parse("server").is_err());
    }

    #[test]
    fn topology_validates() {
        Topology::single().validate(5, 1).unwrap();
        let t = Topology { shards: 2, sync_every: 4 };
        t.validate(4, 1).unwrap();
        t.validate(4, 2).unwrap();
        // 5 devices across 2 shards
        assert!(t.validate(5, 1).is_err());
        // sync cadence off the aggregation grid
        assert!(t.validate(4, 3).is_err());
        assert!(Topology { shards: 0, sync_every: 1 }.validate(4, 1).is_err());
        assert!(Topology { shards: 2, sync_every: 0 }.validate(4, 1).is_err());
    }

    #[test]
    fn shapes_partition_the_fleet_contiguously() {
        let t = Topology { shards: 2, sync_every: 1 };
        let s0 = t.shape_for(4, 0);
        let s1 = t.shape_for(4, 1);
        assert_eq!(s0, FleetShape { global: 4, base: 0, local: 2 });
        assert_eq!(s1, FleetShape { global: 4, base: 2, local: 2 });
        assert_eq!(s1.slot(2), Some(0));
        assert_eq!(s1.slot(3), Some(1));
        assert_eq!(s1.slot(1), None);
        assert_eq!(s1.gid(1), 3);
        let flat = FleetShape::flat(3);
        assert_eq!(flat.slot(2), Some(2));
        assert_eq!(flat.slot(3), None);
    }
}
