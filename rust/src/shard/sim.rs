//! In-process N-shard topology simulation: the whole coordinator tier in
//! one process, deterministically.
//!
//! Each shard session (a mock [`ServerRuntime`] plus its local device
//! workers over single-threaded loopback, exactly
//! [`crate::transport::server::run_mock_loopback`]) runs on its own
//! thread; the coordinator runs the *real*
//! [`crate::shard::coordinator::Coordinator`] over a
//! [`crate::sched::fleet::ShardFleet`] of
//! [`crate::transport::channel`] transports. Nothing is stubbed: the
//! same handshakes, frames, codec packs, and merge math run here as in a
//! multi-process TCP cluster, so `examples/sharded.rs` can assert
//! byte-for-byte parity between the two.
//!
//! Determinism: every shard's device round loop is the in-order loopback
//! path (deterministic on its own), and cross-shard merges fold pushes in
//! shard-id order with a full barrier per epoch — thread scheduling
//! cannot reorder anything that affects numerics or wire bytes.

use std::sync::Arc;

use crate::config::ExperimentConfig;
use crate::coordinator::metrics::TrainReport;
use crate::data::Dataset;
use crate::sched::fleet::{PumpFleet, ShardFleet};
use crate::transport::server::{
    handshake, mock_runtime_for_shard, run_mock_loopback, ServerRuntime,
};
use crate::transport::{channel, device, loopback, session_fingerprint, Transport};

use super::checkpoint::Checkpoint;
use super::coordinator::{CoordReport, Coordinator};
use super::link::ShardLink;

/// Everything a sharded mock session produced: one [`TrainReport`] per
/// shard (index = shard id) plus the coordinator's byte accounting.
#[derive(Debug, Clone)]
pub struct ShardedReport {
    pub shard_reports: Vec<TrainReport>,
    pub coordinator: CoordReport,
}

impl ShardedReport {
    /// Total ModelSync bytes across every shard (device tier + shard
    /// tier; the shard-link bytes ride each shard's `bytes_sync` axis).
    pub fn total_bytes_sync(&self) -> usize {
        self.shard_reports.iter().map(|r| r.total_bytes_sync).sum()
    }

    /// (min, max) final accuracy across shards — after a
    /// `--shard-sync-every 1` session every shard evaluates the same
    /// merged models, so the range collapses.
    pub fn accuracy_range(&self) -> (f64, f64) {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for r in &self.shard_reports {
            lo = lo.min(r.final_accuracy);
            hi = hi.max(r.final_accuracy);
        }
        (lo, hi)
    }
}

/// Run a complete sharded mock session in-process (see module docs).
/// `cfg.shards == 1` degenerates to [`run_mock_loopback`] with an empty
/// coordinator report — the single-server baseline through the same entry
/// point.
pub fn run_sharded_mock(cfg: &ExperimentConfig) -> Result<ShardedReport, String> {
    cfg.validate()?;
    let topo = cfg.topology();
    if !topo.is_sharded() {
        let report = run_mock_loopback(cfg)?;
        return Ok(ShardedReport {
            shard_reports: vec![report],
            coordinator: CoordReport {
                shards: 1,
                sync_epochs: 0,
                bytes_up: 0,
                bytes_down: 0,
                per_shard: vec![(0, 0)],
                cluster_counters: Vec::new(),
            },
        });
    }
    let m = topo.shards;
    let mut coord_ends: Vec<Box<dyn Transport>> = Vec::with_capacity(m);
    let mut threads = Vec::with_capacity(m);
    for k in 0..m {
        let (shard_end, coord_end) = channel::pair(&format!("shardlink{k}"));
        coord_ends.push(Box::new(coord_end));
        let cfg = cfg.clone();
        threads.push(
            std::thread::Builder::new()
                .name(format!("slacc-shard{k}"))
                .spawn(move || run_mock_shard_session(&cfg, k, Box::new(shard_end)))
                .map_err(|e| format!("spawn shard {k}: {e}"))?,
        );
    }
    let mut coordinator = Coordinator::from_experiment(cfg, "mock")?;
    let mut fleet = ShardFleet::new(coord_ends);
    let coord_result = coordinator.run(&mut fleet);
    // drop the coordinator-side channel ends BEFORE joining: after a
    // coordinator-side error, a healthy shard may still be blocked in its
    // exchange recv — closing the channels surfaces PeerClosed there, so
    // the joins below cannot hang
    drop(fleet);

    let mut shard_reports = Vec::with_capacity(m);
    let mut errors = Vec::new();
    for (k, t) in threads.into_iter().enumerate() {
        match t.join() {
            Ok(Ok(report)) => shard_reports.push(report),
            Ok(Err(e)) => errors.push(format!("shard {k}: {e}")),
            Err(_) => errors.push(format!("shard {k}: session thread panicked")),
        }
    }
    // shard-side errors are the root cause when the coordinator merely
    // saw the hang-up — surface them first
    if !errors.is_empty() {
        return Err(errors.join("; "));
    }
    let coordinator_report = coord_result?;
    Ok(ShardedReport { shard_reports, coordinator: coordinator_report })
}

/// The coordinator kill-and-resume drill, in-process: run the cluster
/// with checkpointing until the coordinator halts after `halt_after`
/// completed sync epochs (simulating a crash at an epoch boundary — the
/// shard sessions stay barriered on their channel ends, exactly like
/// shards waiting out a coordinator restart over TCP), then load the
/// checkpoint into a *second* coordinator that takes over the same fleet
/// via [`Coordinator::run_resumed`] and finishes the session.
///
/// Because the checkpoint is written after every merge broadcast and the
/// resumed coordinator replays nothing, the shards' loss trajectories
/// must be bit-identical to an uninterrupted [`run_sharded_mock`] run.
pub fn run_sharded_mock_resumed(
    cfg: &ExperimentConfig,
    halt_after: usize,
    checkpoint_dir: &std::path::Path,
) -> Result<ShardedReport, String> {
    cfg.validate()?;
    let topo = cfg.topology();
    if !topo.is_sharded() {
        return Err("the kill-and-resume drill needs --shards > 1".into());
    }
    let m = topo.shards;
    let mut coord_ends: Vec<Box<dyn Transport>> = Vec::with_capacity(m);
    let mut threads = Vec::with_capacity(m);
    for k in 0..m {
        let (shard_end, coord_end) = channel::pair(&format!("shardlink{k}"));
        coord_ends.push(Box::new(coord_end));
        let cfg = cfg.clone();
        threads.push(
            std::thread::Builder::new()
                .name(format!("slacc-shard{k}"))
                .spawn(move || run_mock_shard_session(&cfg, k, Box::new(shard_end)))
                .map_err(|e| format!("spawn shard {k}: {e}"))?,
        );
    }
    let mut fleet = ShardFleet::new(coord_ends);
    let coord_result = (|| {
        let mut first = Coordinator::from_experiment(cfg, "mock")?;
        first.configure_checkpoint(Some(checkpoint_dir.to_path_buf()), false);
        first.halt_after(halt_after);
        first.run(&mut fleet)?;
        // the first coordinator's state dies here; everything the
        // successor knows comes off the checkpoint on disk
        let ck = Checkpoint::load(checkpoint_dir)?;
        let mut second = Coordinator::from_experiment(cfg, "mock")?;
        second.configure_checkpoint(Some(checkpoint_dir.to_path_buf()), false);
        second.run_resumed(&mut fleet, &ck)
    })();
    drop(fleet);

    let mut shard_reports = Vec::with_capacity(m);
    let mut errors = Vec::new();
    for (k, t) in threads.into_iter().enumerate() {
        match t.join() {
            Ok(Ok(report)) => shard_reports.push(report),
            Ok(Err(e)) => errors.push(format!("shard {k}: {e}")),
            Err(_) => errors.push(format!("shard {k}: session thread panicked")),
        }
    }
    if !errors.is_empty() {
        return Err(errors.join("; "));
    }
    let coordinator_report = coord_result?;
    Ok(ShardedReport { shard_reports, coordinator: coordinator_report })
}

/// One shard's full mock session: coordinator handshake, local device
/// fleet over loopback, serve. The device workers carry their *global*
/// ids, so data shards, loader seeds, and codec streams match a
/// single-server session of the same config exactly.
fn run_mock_shard_session(
    cfg: &ExperimentConfig,
    shard_id: usize,
    coord_conn: Box<dyn Transport>,
) -> Result<TrainReport, String> {
    let topo = cfg.topology();
    let shape = topo.shape_for(cfg.devices, shard_id);
    let (train, test) = Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
    let train = Arc::new(train);
    let mut runtime: ServerRuntime<_> = mock_runtime_for_shard(cfg, shard_id, Arc::new(test))?;

    let weight = super::shard_weight(cfg, &train, shard_id);
    let session_fp = session_fingerprint(cfg.fingerprint(), "mock");
    let link = ShardLink::handshake(
        coord_conn,
        &topo,
        shard_id,
        weight,
        session_fp,
        cfg.shard_link_streams(shard_id)?,
    )?;
    runtime.attach_shard_link(link);

    let mut workers = Vec::with_capacity(shape.local);
    let mut dev_conns = Vec::with_capacity(shape.local);
    let mut srv_conns: Vec<Box<dyn Transport>> = Vec::with_capacity(shape.local);
    for g in shape.base..shape.base + shape.local {
        let worker = device::mock_worker(cfg, train.clone(), g)?;
        let (mut dev_end, srv_end) = loopback::pair(&format!("shard{shard_id}dev{g}"));
        dev_end.send(&worker.hello())?;
        workers.push(worker);
        dev_conns.push(dev_end);
        srv_conns.push(Box::new(srv_end));
    }
    let (mut conns, hellos) = handshake(srv_conns, shape)?;
    let report = {
        let mut fleet = PumpFleet::new(&mut conns, |d| {
            device::pump(&mut workers[d], &mut dev_conns[d])
        });
        runtime.serve_fleet(&mut fleet, &hellos)?
    };
    Ok(report)
}
