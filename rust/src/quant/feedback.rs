//! Error-feedback (EF) memory for lossy smashed-data compression — the
//! standard compensation mechanism from the distributed-SGD compression
//! literature (Seide et al. 2014; Karimireddy et al. 2019), implemented
//! here as the paper's natural "future work" extension and exposed as the
//! opt-in [`crate::codecs::ef::EfCodec`] wrapper.
//!
//! Per stream (device × direction) the memory `m` accumulates what the
//! codec lost each round and adds it back before the next compression:
//!
//! ```text
//! x'_t  = x_t + m_{t-1}
//! wire  = C(x'_t)
//! m_t   = x'_t − D(wire)
//! ```
//!
//! For unbiased-ish quantizers the residual stays bounded, so the *time
//! average* of the transmitted signal is unbiased even at 2-bit widths.

/// Error-feedback accumulator for one fixed-shape stream.
#[derive(Debug, Clone)]
pub struct ErrorFeedback {
    memory: Vec<f32>,
    /// decay in [0,1]: 1 = classic EF, <1 leaks stale error (EF with
    /// forgetting, more robust when the signal distribution drifts)
    decay: f32,
}

impl ErrorFeedback {
    pub fn new(len: usize, decay: f32) -> Self {
        assert!((0.0..=1.0).contains(&decay));
        ErrorFeedback { memory: vec![0.0; len], decay }
    }

    pub fn len(&self) -> usize {
        self.memory.len()
    }

    pub fn is_empty(&self) -> bool {
        self.memory.is_empty()
    }

    /// Add the carried error into `x` (in place), returning nothing; call
    /// [`Self::absorb`] with the reconstruction afterwards.
    pub fn apply(&self, x: &mut [f32]) {
        assert_eq!(x.len(), self.memory.len());
        for (xi, &m) in x.iter_mut().zip(&self.memory) {
            *xi += m;
        }
    }

    /// Record this round's loss: m = decay * (x_compensated − x_hat).
    pub fn absorb(&mut self, x_compensated: &[f32], x_hat: &[f32]) {
        assert_eq!(x_compensated.len(), self.memory.len());
        assert_eq!(x_hat.len(), self.memory.len());
        for (m, (&xc, &xh)) in self.memory.iter_mut().zip(x_compensated.iter().zip(x_hat)) {
            *m = self.decay * (xc - xh);
        }
    }

    /// L2 norm of the carried error (diagnostic: must stay bounded).
    pub fn residual_norm(&self) -> f64 {
        self.memory.iter().map(|&m| (m as f64) * (m as f64)).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::linear;
    use crate::util::rng::Pcg32;

    #[test]
    fn zero_initial_memory_is_identity() {
        let ef = ErrorFeedback::new(4, 1.0);
        let mut x = vec![1.0, 2.0, 3.0, 4.0];
        ef.apply(&mut x);
        assert_eq!(x, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn absorb_records_loss() {
        let mut ef = ErrorFeedback::new(2, 1.0);
        ef.absorb(&[1.0, 2.0], &[0.75, 2.25]);
        let mut x = vec![0.0, 0.0];
        ef.apply(&mut x);
        assert_eq!(x, vec![0.25, -0.25]);
    }

    #[test]
    fn decay_leaks_memory() {
        let mut ef = ErrorFeedback::new(1, 0.5);
        ef.absorb(&[1.0], &[0.0]);
        let mut x = vec![0.0];
        ef.apply(&mut x);
        assert_eq!(x, vec![0.5]);
    }

    #[test]
    fn ef_reduces_time_averaged_error_under_coarse_quantization() {
        // quantize a constant signal at 2 bits with a fixed grid that cannot
        // represent it; with EF the *average* reconstruction converges to
        // the true value, without EF it stays biased.
        let truth = vec![0.30f32; 16];
        let (qmin, qmax, bits) = (0.0f32, 1.0f32, 2u32); // grid {0,1/3,2/3,1}
        let rounds = 64;

        // no EF: always reconstructs round(0.3*3)/3 = 1/3
        let plain = linear::fake_quant(&truth, qmin, qmax, bits);
        let plain_avg = plain[0];

        let mut ef = ErrorFeedback::new(16, 1.0);
        let mut sum = vec![0.0f64; 16];
        for _ in 0..rounds {
            let mut x = truth.clone();
            ef.apply(&mut x);
            let xh = linear::fake_quant(&x, qmin, qmax, bits);
            ef.absorb(&x, &xh);
            for (s, &v) in sum.iter_mut().zip(&xh) {
                *s += v as f64;
            }
        }
        let ef_avg = sum[0] / rounds as f64;
        let ef_err = (ef_avg - 0.30).abs();
        let plain_err = (plain_avg - 0.30).abs() as f64;
        assert!(
            ef_err < plain_err / 4.0,
            "EF avg err {ef_err:.5} should beat plain {plain_err:.5}"
        );
    }

    #[test]
    fn residual_stays_bounded_on_random_signals() {
        let mut ef = ErrorFeedback::new(64, 1.0);
        let mut rng = Pcg32::seeded(5);
        for round in 0..200 {
            let mut x: Vec<f32> = (0..64).map(|_| rng.next_gaussian()).collect();
            ef.apply(&mut x);
            let (mn, mx) = crate::tensor::view::min_max(&x);
            let xh = linear::fake_quant(&x, mn, mx, 3);
            ef.absorb(&x, &xh);
            assert!(
                ef.residual_norm() < 64.0,
                "round {round}: residual {}",
                ef.residual_norm()
            );
        }
    }
}
