//! Wire format substrate: byte reader/writer + the common payload header.
//!
//! Every codec serializes to this envelope so the network simulator can
//! account bytes uniformly and the server can dispatch decompression:
//!
//! ```text
//! magic  u16 = 0x51AC          codec_id u8     version u8
//! dims   u32 x 4 (B, C, H, W)
//! body   codec-specific
//! ```
//!
//! All integers little-endian. The byte count of the full envelope is what
//! the paper's "communication overhead" axis measures.
//!
//! Reads fail with the typed [`CodecError`] — truncation, hostile length
//! claims, and structural violations are distinct variants, and every
//! length read off the wire is checked against its guard *before* any
//! allocation.

use crate::codecs::CodecError;

pub const MAGIC: u16 = 0x51AC;
pub const VERSION: u8 = 1;

/// Upper bound on the element count a payload header may claim (2^28
/// elements = 1 GiB of f32). Decompressors allocate from header dims, so
/// without this cap a 17-byte hostile header could demand terabytes.
pub const MAX_ELEMENTS: usize = 1 << 28;

/// Little-endian byte sink.
#[derive(Debug, Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(cap: usize) -> Self {
        ByteWriter { buf: Vec::with_capacity(cap) }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn bytes(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    pub fn f32s(&mut self, vs: &[f32]) {
        self.buf.reserve(vs.len() * 4);
        for &v in vs {
            self.f32(v);
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Drop the contents but keep the capacity — the reusable-buffer
    /// contract of [`crate::codecs::Codec::encode`]: a warmed writer
    /// re-encodes without touching the allocator.
    pub fn clear(&mut self) {
        self.buf.clear();
    }

    /// Grow capacity ahead of a known write size (no-op once warmed).
    pub fn reserve(&mut self, additional: usize) {
        self.buf.reserve(additional);
    }

    /// The bytes written so far, without consuming the writer.
    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }

    /// Copy the written bytes out, keeping the writer (and its capacity)
    /// for the next round.
    pub fn to_vec(&self) -> Vec<u8> {
        self.buf.clone()
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Little-endian byte source with explicit error handling.
#[derive(Debug)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        if self.pos + n > self.buf.len() {
            return Err(CodecError::Truncated {
                need: n,
                have: self.buf.len() - self.pos,
                at: self.pos,
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16, CodecError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32, CodecError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, CodecError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32, CodecError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        self.take(n)
    }

    pub fn f32s(&mut self, n: usize) -> Result<Vec<f32>, CodecError> {
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Structural check every decoder runs after its last read: leftover
    /// bytes mean the envelope disagrees with its own header (a corrupted
    /// header shrinking the claimed geometry, or spliced garbage).
    pub fn expect_end(&self) -> Result<(), CodecError> {
        if self.remaining() != 0 {
            return Err(CodecError::Malformed(format!(
                "{} trailing bytes after payload body",
                self.remaining()
            )));
        }
        Ok(())
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn pos(&self) -> usize {
        self.pos
    }
}

/// Common payload header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Header {
    pub codec_id: u8,
    pub dims: [u32; 4], // B, C, H, W
}

impl Header {
    pub const BYTES: usize = 2 + 1 + 1 + 16;

    pub fn write(&self, w: &mut ByteWriter) {
        w.u16(MAGIC);
        w.u8(self.codec_id);
        w.u8(VERSION);
        for d in self.dims {
            w.u32(d);
        }
    }

    pub fn read(r: &mut ByteReader) -> Result<Header, CodecError> {
        let magic = r.u16()?;
        if magic != MAGIC {
            return Err(CodecError::Malformed(format!("bad magic {magic:#06x}")));
        }
        let codec_id = r.u8()?;
        let version = r.u8()?;
        if version != VERSION {
            return Err(CodecError::Malformed(format!(
                "unsupported payload version {version}"
            )));
        }
        let mut dims = [0u32; 4];
        for d in &mut dims {
            *d = r.u32()?;
        }
        let elems = dims
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d as usize))
            .ok_or(CodecError::LimitExceeded {
                what: "header elements",
                claimed: usize::MAX,
                cap: MAX_ELEMENTS,
            })?;
        if elems == 0 {
            return Err(CodecError::Malformed("header claims 0 elements".into()));
        }
        if elems > MAX_ELEMENTS {
            return Err(CodecError::LimitExceeded {
                what: "header elements",
                claimed: elems,
                cap: MAX_ELEMENTS,
            });
        }
        Ok(Header { codec_id, dims })
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().map(|&d| d as usize).product()
    }

    pub fn n_per_channel(&self) -> usize {
        (self.dims[0] * self.dims[2] * self.dims[3]) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writer_reader_roundtrip() {
        let mut w = ByteWriter::new();
        w.u8(7);
        w.u16(0xbeef);
        w.u32(0xdead_beef);
        w.u64(0x0123_4567_89ab_cdef);
        w.f32(-1.5);
        w.f32s(&[1.0, 2.0]);
        w.bytes(&[9, 9]);
        let buf = w.finish();
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 0xbeef);
        assert_eq!(r.u32().unwrap(), 0xdead_beef);
        assert_eq!(r.u64().unwrap(), 0x0123_4567_89ab_cdef);
        assert_eq!(r.f32().unwrap(), -1.5);
        assert_eq!(r.f32s(2).unwrap(), vec![1.0, 2.0]);
        assert_eq!(r.bytes(2).unwrap(), &[9, 9]);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn truncation_is_error_not_panic() {
        let mut r = ByteReader::new(&[1, 2]);
        assert!(r.u32().is_err());
    }

    #[test]
    fn header_roundtrip() {
        let h = Header { codec_id: 3, dims: [32, 32, 16, 16] };
        let mut w = ByteWriter::new();
        h.write(&mut w);
        let buf = w.finish();
        assert_eq!(buf.len(), Header::BYTES);
        let mut r = ByteReader::new(&buf);
        assert_eq!(Header::read(&mut r).unwrap(), h);
    }

    #[test]
    fn header_rejects_bad_magic() {
        let mut w = ByteWriter::new();
        w.u16(0x1111);
        w.u8(0);
        w.u8(VERSION);
        for _ in 0..4 {
            w.u32(1);
        }
        let buf = w.finish();
        assert!(Header::read(&mut ByteReader::new(&buf)).is_err());
    }

    #[test]
    fn header_rejects_hostile_dims() {
        // terabyte-scale claim
        let mut w = ByteWriter::new();
        w.u16(MAGIC);
        w.u8(0);
        w.u8(VERSION);
        for d in [60000u32, 60000, 60000, 4] {
            w.u32(d);
        }
        let buf = w.finish();
        assert!(Header::read(&mut ByteReader::new(&buf)).is_err());
        // zero-element claim
        let mut w = ByteWriter::new();
        w.u16(MAGIC);
        w.u8(0);
        w.u8(VERSION);
        for d in [0u32, 4, 4, 4] {
            w.u32(d);
        }
        let buf = w.finish();
        assert!(Header::read(&mut ByteReader::new(&buf)).is_err());
    }

    #[test]
    fn header_geometry_helpers() {
        let h = Header { codec_id: 0, dims: [4, 8, 2, 3] };
        assert_eq!(h.element_count(), 4 * 8 * 2 * 3);
        assert_eq!(h.n_per_channel(), 4 * 2 * 3);
    }
}
