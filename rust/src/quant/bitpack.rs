//! Bit-level packing of quantization codes into the wire byte stream.
//!
//! Codes are b-bit unsigned integers (2 <= b <= 32 supported; CGC uses
//! 2..=8), packed LSB-first through a u64 accumulator so the hot loop is a
//! shift+or per code and one byte store per 8 bits — no per-bit branching.

/// Pack `codes` (each < 2^bits) into bytes, LSB-first.
pub fn pack(codes: &[u32], bits: u32) -> Vec<u8> {
    let mut out = Vec::new();
    pack_into(codes, bits, &mut out);
    out
}

/// [`pack`] into a caller-owned buffer (cleared first). The zero-alloc
/// sibling for the codec encode hot path: a warmed buffer is reused at its
/// steady-state capacity.
pub fn pack_into(codes: &[u32], bits: u32, out: &mut Vec<u8>) {
    assert!((1..=32).contains(&bits), "bits must be 1..=32, got {bits}");
    let total_bits = codes.len() * bits as usize;
    out.clear();
    out.reserve(total_bits.div_ceil(8));
    let mut acc: u64 = 0;
    let mut acc_bits: u32 = 0;
    let mask: u64 = if bits == 32 { u32::MAX as u64 } else { (1u64 << bits) - 1 };
    for &code in codes {
        debug_assert!(
            (code as u64) <= mask,
            "code {code} does not fit in {bits} bits"
        );
        acc |= ((code as u64) & mask) << acc_bits;
        acc_bits += bits;
        while acc_bits >= 8 {
            out.push((acc & 0xff) as u8);
            acc >>= 8;
            acc_bits -= 8;
        }
    }
    if acc_bits > 0 {
        out.push((acc & 0xff) as u8);
    }
}

/// Unpack `count` b-bit codes from bytes (inverse of [`pack`]).
pub fn unpack(bytes: &[u8], bits: u32, count: usize) -> Vec<u32> {
    assert!((1..=32).contains(&bits));
    let needed = (count * bits as usize).div_ceil(8);
    assert!(
        bytes.len() >= needed,
        "need {needed} bytes for {count}x{bits}-bit codes, have {}",
        bytes.len()
    );
    let mut out = Vec::with_capacity(count);
    let mut acc: u64 = 0;
    let mut acc_bits: u32 = 0;
    let mut pos = 0usize;
    let mask: u64 = if bits == 32 { u32::MAX as u64 } else { (1u64 << bits) - 1 };
    for _ in 0..count {
        while acc_bits < bits {
            acc |= (bytes[pos] as u64) << acc_bits;
            pos += 1;
            acc_bits += 8;
        }
        out.push((acc & mask) as u32);
        acc >>= bits;
        acc_bits -= bits;
    }
    out
}

/// Exact byte length of `count` codes at `bits` width.
pub fn packed_len(count: usize, bits: u32) -> usize {
    (count * bits as usize).div_ceil(8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;

    #[test]
    fn roundtrip_simple() {
        let codes = vec![0, 1, 2, 3, 3, 2, 1, 0];
        let packed = pack(&codes, 2);
        assert_eq!(packed.len(), 2);
        assert_eq!(unpack(&packed, 2, 8), codes);
    }

    #[test]
    fn roundtrip_odd_bits() {
        for bits in [3u32, 5, 7] {
            let max = (1u32 << bits) - 1;
            let codes: Vec<u32> = (0..100).map(|i| (i * 7) % (max + 1)).collect();
            let packed = pack(&codes, bits);
            assert_eq!(packed.len(), packed_len(100, bits));
            assert_eq!(unpack(&packed, bits, 100), codes);
        }
    }

    #[test]
    fn eight_bit_is_bytes() {
        let codes = vec![0u32, 255, 128, 7];
        assert_eq!(pack(&codes, 8), vec![0u8, 255, 128, 7]);
    }

    #[test]
    fn empty() {
        assert!(pack(&[], 4).is_empty());
        assert!(unpack(&[], 4, 0).is_empty());
    }

    #[test]
    fn wide_codes() {
        let codes = vec![u32::MAX, 0, 0xdead_beef];
        let packed = pack(&codes, 32);
        assert_eq!(unpack(&packed, 32, 3), codes);
    }

    #[test]
    #[should_panic(expected = "need")]
    fn unpack_short_buffer_panics() {
        let _ = unpack(&[0xff], 8, 3);
    }

    #[test]
    fn roundtrip_property() {
        Prop::new("pack/unpack roundtrip").cases(300).max_size(200).run(|rng, size| {
            let bits = 1 + rng.below(16);
            let max = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
            let codes: Vec<u32> = (0..size)
                .map(|_| if max == 0 { 0 } else { rng.next_u32() & max })
                .collect();
            let packed = pack(&codes, bits);
            if packed.len() != packed_len(size, bits) {
                return Err("length mismatch".into());
            }
            if unpack(&packed, bits, size) != codes {
                return Err(format!("roundtrip failed bits={bits} n={size}"));
            }
            Ok(())
        });
    }
}
