//! Linear (uniform) quantizer — the CGC primitive (paper Eq. 7).
//!
//! `quantize` maps f32 values in [qmin, qmax] to b-bit codes with
//! round-half-away-from-zero; `dequantize` reconstructs midpoint-free
//! (code * scale + qmin). Numerics match the Pallas QDQ kernel and ref.py
//! exactly (same EPS, same rounding), which the cross-layer parity tests
//! assert.

pub const EPS: f32 = 1e-8;

/// code = round((x - qmin) / (qmax - qmin) * (2^b - 1)), clamped.
pub fn quantize(xs: &[f32], qmin: f32, qmax: f32, bits: u32, out: &mut Vec<u32>) {
    debug_assert!((1..=16).contains(&bits));
    let levels = ((1u32 << bits) - 1) as f32;
    let rng = qmax - qmin;
    out.clear();
    out.reserve(xs.len());
    if rng <= EPS {
        // flat channel: every value collapses to code 0 (dequant -> qmin)
        out.extend(std::iter::repeat_n(0u32, xs.len()));
        return;
    }
    // Eq. 7 form: t = (x - qmin)/(qmax - qmin) * levels. Computing the
    // multiplier directly (rather than 1/(rng/levels)) avoids a double
    // rounding that can drop a code at exact half-steps.
    let inv = levels / rng;
    for &x in xs {
        let xc = x.clamp(qmin, qmax);
        let t = (xc - qmin) * inv;
        // t >= 0 so floor(t + 0.5) == round-half-away-from-zero
        let code = (t + 0.5).floor();
        out.push((code as u32).min(levels as u32));
    }
}

/// Inverse of [`quantize`]: x̂ = qmin + code * scale.
pub fn dequantize(codes: &[u32], qmin: f32, qmax: f32, bits: u32, out: &mut Vec<f32>) {
    debug_assert!((1..=16).contains(&bits));
    let levels = ((1u32 << bits) - 1) as f32;
    let rng = qmax - qmin;
    out.clear();
    out.reserve(codes.len());
    if rng <= EPS {
        out.extend(std::iter::repeat_n(qmin, codes.len()));
        return;
    }
    let scale = rng / levels;
    for &c in codes {
        out.push(qmin + c as f32 * scale);
    }
}

/// One-shot fake-quant (quantize + dequantize); mirrors the L1 QDQ kernel.
pub fn fake_quant(xs: &[f32], qmin: f32, qmax: f32, bits: u32) -> Vec<f32> {
    let mut codes = Vec::new();
    quantize(xs, qmin, qmax, bits, &mut codes);
    let mut out = Vec::new();
    dequantize(&codes, qmin, qmax, bits, &mut out);
    out
}

/// Worst-case reconstruction error: half a quantization step.
pub fn max_error(qmin: f32, qmax: f32, bits: u32) -> f32 {
    let levels = ((1u32 << bits) - 1) as f32;
    ((qmax - qmin).max(0.0) / levels) * 0.5
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{vec_f32_nonflat, Prop};

    #[test]
    fn endpoints_exact() {
        let xs = [0.0f32, 1.0];
        let y = fake_quant(&xs, 0.0, 1.0, 4);
        assert_eq!(y, vec![0.0, 1.0]);
    }

    #[test]
    fn error_bounded_by_half_step() {
        let xs: Vec<f32> = (0..1000).map(|i| (i as f32) / 999.0 * 6.0 - 3.0).collect();
        for bits in [2u32, 4, 8] {
            let y = fake_quant(&xs, -3.0, 3.0, bits);
            let bound = max_error(-3.0, 3.0, bits) + 1e-6;
            for (a, b) in xs.iter().zip(&y) {
                assert!((a - b).abs() <= bound, "bits={bits}: |{a}-{b}| > {bound}");
            }
        }
    }

    #[test]
    fn flat_range_collapses() {
        let xs = [5.0f32, 5.0, 5.0];
        let y = fake_quant(&xs, 5.0, 5.0, 4);
        assert_eq!(y, vec![5.0, 5.0, 5.0]);
    }

    #[test]
    fn out_of_range_clamped() {
        let y = fake_quant(&[-10.0, 10.0], 0.0, 1.0, 8);
        assert_eq!(y, vec![0.0, 1.0]);
    }

    #[test]
    fn half_rounds_away_from_zero() {
        // qmin=0, qmax=3, bits=2 -> levels=3, scale=1. x=0.5 -> t=0.5 -> code 1.
        let mut codes = Vec::new();
        quantize(&[0.5], 0.0, 3.0, 2, &mut codes);
        assert_eq!(codes, vec![1]);
        // x=1.5 -> code 2
        quantize(&[1.5], 0.0, 3.0, 2, &mut codes);
        assert_eq!(codes, vec![2]);
    }

    #[test]
    fn idempotent_property() {
        Prop::new("fake_quant idempotent").cases(150).max_size(128).run(|rng, size| {
            let xs = vec_f32_nonflat(rng, size + 2);
            let (mut mn, mut mx) = (xs[0], xs[0]);
            for &x in &xs {
                mn = mn.min(x);
                mx = mx.max(x);
            }
            let bits = 2 + rng.below(7);
            let y1 = fake_quant(&xs, mn, mx, bits);
            let y2 = fake_quant(&y1, mn, mx, bits);
            for (a, b) in y1.iter().zip(&y2) {
                if (a - b).abs() > 1e-5 {
                    return Err(format!("not idempotent: {a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn error_bound_property() {
        Prop::new("quant error <= step/2").cases(150).max_size(256).run(|rng, size| {
            let xs = vec_f32_nonflat(rng, size + 2);
            let (mut mn, mut mx) = (xs[0], xs[0]);
            for &x in &xs {
                mn = mn.min(x);
                mx = mx.max(x);
            }
            let bits = 2 + rng.below(7);
            let y = fake_quant(&xs, mn, mx, bits);
            let bound = max_error(mn, mx, bits) * (1.0 + 1e-4) + 1e-6;
            for (a, b) in xs.iter().zip(&y) {
                if (a - b).abs() > bound {
                    return Err(format!("bits={bits}: err {} > {bound}", (a - b).abs()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn codes_fit_in_bits_property() {
        Prop::new("codes < 2^bits").cases(100).max_size(64).run(|rng, size| {
            let xs = vec_f32_nonflat(rng, size + 2);
            let bits = 2 + rng.below(7);
            let mut codes = Vec::new();
            quantize(&xs, -1.0, 1.0, bits, &mut codes);
            let max = (1u32 << bits) - 1;
            if codes.iter().any(|&c| c > max) {
                return Err("code overflow".into());
            }
            Ok(())
        });
    }
}
