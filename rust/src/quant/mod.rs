//! Quantization substrate: linear quantizer (Eq. 7), bit-level packing, and
//! the wire-format envelope shared by every codec.

pub mod bitpack;
pub mod feedback;
pub mod linear;
pub mod payload;
