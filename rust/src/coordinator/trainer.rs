//! The split-learning trainer: the paper's four-stage round loop (Sec.
//! II-A) driven end-to-end from Rust over the PJRT runtime.
//!
//! Per global round t, for each device d (simulated-parallel, the network
//! model takes the max over devices):
//!
//! 1. `client_fwd(cp_d, x_d) -> acts` (PJRT)
//! 2. ACII entropy of `acts` via the AOT Pallas kernel (PJRT), then the
//!    device's uplink codec compresses -> wire bytes (**bytes_up**); the
//!    server decompresses to `acts_hat`
//! 3. `server_step(sp, acts_hat, y_d, lr) -> (loss, g_acts, sp')` (PJRT)
//! 4. downlink codec compresses `g_acts` (**bytes_down**); the device
//!    decompresses and runs `client_bwd(cp_d, x_d, g_hat, lr) -> cp_d'`
//!
//! then client sub-models are FedAvg-aggregated (SFL semantics) and the
//! network simulator converts the exact wire bytes into simulated time.
//!
//! Since the transport subsystem landed, the round loop itself lives in
//! [`ServerRuntime`] + [`crate::sched::round::RoundScheduler`] and
//! [`DeviceWorker`] — this trainer wires N in-process device workers to the
//! server runtime over deterministic loopback transports and pumps them on
//! one thread. A `slacc serve` + N × `slacc device` deployment runs the
//! *same* protocol and scheduling code over poll-driven TCP; given the same
//! config and seed both produce identical per-round wire bytes (under the
//! default InOrder schedule).
//!
//! Stage iii is dispatched through `Compute::server_step_batch`: under
//! `--schedule arrival --batch-window N` the scheduler coalesces up to N
//! same-shaped uplinks into one compute-boundary crossing (the report's
//! `server_dispatches` vs `server_steps` shows the amortization); the
//! default window of 1 — and InOrder always — is the historical
//! per-device dispatch, bit-for-bit.
//!
//! With `--shards M > 1` the in-process twin of a whole *cluster* —
//! M shard sessions plus the coordinator tier — is
//! [`run_sharded_mock`] (re-exported from [`crate::shard::sim`]): shard
//! sessions on threads over loopback, the real coordinator over channel
//! transports, deterministic end to end. The engine path runs sharded as
//! real processes (`slacc serve --role shard|coordinator`,
//! `examples/sharded.rs`) because PJRT objects never cross threads.

use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

use crate::config::ExperimentConfig;
use crate::coordinator::device::DeviceState;
use crate::coordinator::metrics::MetricsLog;
pub use crate::coordinator::metrics::TrainReport;
pub use crate::shard::sim::{run_sharded_mock, ShardedReport};
use crate::data::loader::BatchLoader;
use crate::data::{partition, Dataset};
use crate::runtime::Engine;
use crate::transport::compute::EngineCompute;
use crate::transport::device::{pump, DeviceWorker};
use crate::transport::server::{handshake, ServerRuntime};
use crate::transport::{loopback, Transport};

/// Shared geometry/init loaded from one engine's manifest.
struct ModelGeom {
    channels: usize,
    batch: usize,
    client_init: Vec<crate::tensor::Tensor>,
    server_init: Vec<crate::tensor::Tensor>,
}

fn load_geom(engine: &Engine, train: &Dataset) -> Result<ModelGeom, String> {
    let man = engine.manifest();
    if train.channels != man.in_ch || train.classes != man.classes {
        return Err(format!(
            "dataset/model mismatch: data {}ch/{}cls vs manifest {}ch/{}cls",
            train.channels, train.classes, man.in_ch, man.classes
        ));
    }
    Ok(ModelGeom {
        channels: man.cut.c,
        batch: man.batch,
        client_init: man.load_client_init()?,
        server_init: man.load_server_init()?,
    })
}

fn build_device_state(
    cfg: &ExperimentConfig,
    geom: &ModelGeom,
    shard: &[usize],
    d: usize,
) -> Result<DeviceState, String> {
    let loader = BatchLoader::new(shard, geom.batch, cfg.seed ^ ((d as u64) << 8));
    Ok(DeviceState::new(
        d,
        geom.client_init.clone(),
        loader,
        cfg.device_streams(geom.channels, d)?,
    ))
}

/// Build the PJRT-backed server runtime for a standalone `slacc serve`
/// process (loads its own engine).
pub fn engine_runtime(cfg: &ExperimentConfig) -> Result<ServerRuntime<EngineCompute>, String> {
    engine_runtime_for_shard(cfg, 0)
}

/// [`engine_runtime`] for shard `shard_id` of a multi-server topology:
/// the runtime serves that shard's contiguous global-device-id slice
/// (stream codecs and network links stay globally seeded/sliced, so a
/// device trains identically whichever shard serves it). The caller
/// attaches the coordinator link
/// ([`ServerRuntime::attach_shard_link`]) before serving.
pub fn engine_runtime_for_shard(
    cfg: &ExperimentConfig,
    shard_id: usize,
) -> Result<ServerRuntime<EngineCompute>, String> {
    cfg.validate()?;
    let engine = Rc::new(RefCell::new(Engine::load(&cfg.artifacts_dir())?));
    let (train, test) = Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
    let geom = load_geom(&engine.borrow(), &train)?;
    ServerRuntime::new(
        cfg.serve_config_for_shard(geom.batch, shard_id)?,
        EngineCompute::new(engine, cfg.entropy_via_kernel),
        geom.server_init,
        cfg.stream_set_for_shard(geom.channels, shard_id)?,
        Arc::new(test),
        cfg.network_for_shard(shard_id),
    )
}

/// Build the PJRT-backed worker for a standalone `slacc device` process
/// (loads its own engine; the shard split and codec streams match the
/// in-process trainer exactly).
pub fn engine_worker(
    cfg: &ExperimentConfig,
    id: usize,
) -> Result<DeviceWorker<EngineCompute>, String> {
    cfg.validate()?;
    if id >= cfg.devices {
        return Err(format!("device id {id} out of range (devices={})", cfg.devices));
    }
    let engine = Rc::new(RefCell::new(Engine::load(&cfg.artifacts_dir())?));
    let (train, _) = Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
    let geom = load_geom(&engine.borrow(), &train)?;
    let shards = partition::partition(&train, cfg.devices, cfg.partition, cfg.seed);
    let state = build_device_state(cfg, &geom, shards.device(id), id)?;
    DeviceWorker::new(
        state,
        EngineCompute::new(engine, cfg.entropy_via_kernel),
        Arc::new(train),
        cfg,
        geom.channels,
    )
}

/// The in-process trainer: one shared PJRT engine, N device workers, and
/// the server runtime, connected by loopback transports.
pub struct Trainer {
    cfg: ExperimentConfig,
    runtime: ServerRuntime<EngineCompute>,
    workers: Vec<DeviceWorker<EngineCompute>>,
    dev_conns: Vec<loopback::Loopback>,
    srv_conns: Vec<Box<dyn Transport>>,
}

impl Trainer {
    pub fn new(cfg: ExperimentConfig) -> Result<Trainer, String> {
        cfg.validate()?;
        if cfg.shards > 1 {
            return Err(format!(
                "the in-process trainer drives a single server; --shards {} needs \
                 the multi-process topology (slacc serve --role shard|coordinator \
                 + slacc device) — or shard::sim::run_sharded_mock for an \
                 engine-free in-process cluster",
                cfg.shards
            ));
        }
        let engine = Rc::new(RefCell::new(Engine::load(&cfg.artifacts_dir())?));
        let (train, test) =
            Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
        let geom = load_geom(&engine.borrow(), &train)?;

        let shards = partition::partition(&train, cfg.devices, cfg.partition, cfg.seed);
        crate::log_info!(
            "trainer: dataset={} train={} test={} devices={} partition={} skew={:.3}",
            cfg.dataset,
            train.len(),
            test.len(),
            cfg.devices,
            cfg.partition.label(),
            partition::label_skew(&train, &shards)
        );

        let train = Arc::new(train);
        let mut workers = Vec::with_capacity(cfg.devices);
        let mut dev_conns = Vec::with_capacity(cfg.devices);
        let mut srv_conns: Vec<Box<dyn Transport>> = Vec::with_capacity(cfg.devices);
        for d in 0..cfg.devices {
            let state = build_device_state(&cfg, &geom, shards.device(d), d)?;
            workers.push(DeviceWorker::new(
                state,
                EngineCompute::new(engine.clone(), cfg.entropy_via_kernel),
                train.clone(),
                &cfg,
                geom.channels,
            )?);
            let (dev_end, srv_end) = loopback::pair(&format!("dev{d}"));
            dev_conns.push(dev_end);
            srv_conns.push(Box::new(srv_end));
        }

        let runtime = ServerRuntime::new(
            cfg.serve_config(geom.batch)?,
            EngineCompute::new(engine, cfg.entropy_via_kernel),
            geom.server_init,
            cfg.stream_set(geom.channels)?,
            Arc::new(test),
            cfg.network(),
        )?;
        Ok(Trainer { cfg, runtime, workers, dev_conns, srv_conns })
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    pub fn metrics(&self) -> &MetricsLog {
        self.runtime.metrics()
    }

    /// (device steps executed, compute dispatches they rode in) so far —
    /// see [`ServerRuntime::dispatch_stats`].
    pub fn dispatch_stats(&self) -> (usize, usize) {
        self.runtime.dispatch_stats()
    }

    /// Test accuracy of the current model (device 0's client sub-model +
    /// the server sub-model), without training.
    pub fn evaluate(&mut self) -> Result<f64, String> {
        self.runtime.evaluate_with(self.workers[0].client_params())
    }

    /// Run the configured number of rounds (early-stopping at the target
    /// accuracy if one is set) and return the report.
    pub fn run(&mut self) -> Result<TrainReport, String> {
        let Trainer { runtime, workers, dev_conns, srv_conns, .. } = self;
        if srv_conns.is_empty() {
            return Err("trainer session already consumed (run() is one-shot)".into());
        }
        for (w, c) in workers.iter().zip(dev_conns.iter_mut()) {
            c.send(&w.hello())?;
        }
        let shape = crate::shard::FleetShape::flat(runtime.devices());
        let (mut conns, hellos) = handshake(std::mem::take(srv_conns), shape)?;
        runtime.serve(&mut conns, &hellos, |d| pump(&mut workers[d], &mut dev_conns[d]))
    }
}
