//! The split-learning trainer: the paper's four-stage round loop (Sec.
//! II-A) driven end-to-end from Rust over the PJRT runtime.
//!
//! Per global round t, for each device d (simulated-parallel, the network
//! model takes the max over devices):
//!
//! 1. `client_fwd(cp_d, x_d) -> acts` (PJRT)
//! 2. ACII entropy of `acts` via the AOT Pallas kernel (PJRT), then the
//!    device's uplink codec compresses -> wire bytes (**bytes_up**); the
//!    server decompresses to `acts_hat`
//! 3. `server_step(sp, acts_hat, y_d, lr) -> (loss, g_acts, sp')` (PJRT)
//! 4. downlink codec compresses `g_acts` (**bytes_down**); the device
//!    decompresses and runs `client_bwd(cp_d, x_d, g_hat, lr) -> cp_d'`
//!
//! then client sub-models are FedAvg-aggregated (SFL semantics) and the
//! network simulator converts the exact wire bytes into simulated time.
//! Periodically the full model is evaluated on the test set through the
//! `eval_logits` artifact.

use std::time::Instant;

use crate::codecs::RoundCtx;
use crate::config::ExperimentConfig;
use crate::coordinator::device::{fedavg_clients, DeviceState};
use crate::coordinator::metrics::{MetricsLog, RoundRecord};
use crate::coordinator::server::ServerState;
use crate::data::loader::BatchLoader;
use crate::data::{partition, Dataset};
use crate::net::NetworkSim;
use crate::net::timeline::Timeline;
use crate::runtime::{Arg, Engine};
use crate::tensor::Tensor;

/// Result of a completed run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub label: String,
    pub metrics: MetricsLog,
    pub final_accuracy: f64,
    pub best_accuracy: f64,
    pub total_sim_time_s: f64,
    pub total_bytes_up: usize,
    pub total_bytes_down: usize,
    pub time_to_target_s: Option<f64>,
    pub rounds_run: usize,
}

pub struct Trainer {
    cfg: ExperimentConfig,
    engine: Engine,
    train: Dataset,
    test: Dataset,
    devices: Vec<DeviceState>,
    shard_sizes: Vec<f64>,
    server: ServerState,
    net: NetworkSim,
    timeline: Timeline,
    metrics: MetricsLog,
}

impl Trainer {
    pub fn new(cfg: ExperimentConfig) -> Result<Trainer, String> {
        cfg.validate()?;
        let engine = Engine::load(&cfg.artifacts_dir())?;
        let man = engine.manifest();
        let channels = man.cut.c;

        let (train, test) =
            Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
        if train.channels != man.in_ch || train.classes != man.classes {
            return Err(format!(
                "dataset/model mismatch: data {}ch/{}cls vs manifest {}ch/{}cls",
                train.channels, train.classes, man.in_ch, man.classes
            ));
        }

        let shards = partition::partition(&train, cfg.devices, cfg.partition, cfg.seed);
        crate::log_info!(
            "trainer: dataset={} train={} test={} devices={} partition={} skew={:.3}",
            cfg.dataset,
            train.len(),
            test.len(),
            cfg.devices,
            cfg.partition.label(),
            partition::label_skew(&train, &shards)
        );

        let client_init = man.load_client_init()?;
        let server_init = man.load_server_init()?;

        let mut devices = Vec::with_capacity(cfg.devices);
        let mut shard_sizes = Vec::with_capacity(cfg.devices);
        for d in 0..cfg.devices {
            let loader =
                BatchLoader::new(shards.device(d), man.batch, cfg.seed ^ (d as u64) << 8);
            let up = cfg.build_codec(channels, (d as u64) * 2)?;
            let down = cfg.build_codec(channels, (d as u64) * 2 + 1)?;
            shard_sizes.push(shards.device(d).len() as f64);
            devices.push(DeviceState::new(d, client_init.clone(), loader, up, down));
        }

        let net = cfg.network();
        Ok(Trainer {
            cfg,
            engine,
            train,
            test,
            devices,
            shard_sizes,
            server: ServerState::new(server_init),
            net,
            timeline: Timeline::new(),
            metrics: MetricsLog::new(),
        })
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn metrics(&self) -> &MetricsLog {
        &self.metrics
    }

    /// Instantaneous per-channel entropy of smashed data, through the AOT
    /// Pallas kernel (paper path) or the host mirror.
    fn entropy_of(&mut self, acts: &Tensor) -> Result<Vec<f32>, String> {
        if self.cfg.entropy_via_kernel {
            let out = self
                .engine
                .execute("entropy", &[Arg::F32(acts.data(), acts.dims())])?;
            Ok(out.into_iter().next().unwrap().into_data())
        } else {
            Ok(crate::entropy::shannon::entropies(&acts.to_channel_major()))
        }
    }

    /// Run one global round. Returns (mean loss, per-device up/down bytes).
    fn run_round(&mut self, round: usize) -> Result<(f64, Vec<usize>, Vec<usize>), String> {
        let lr = self.cfg.lr;
        let mut up_bytes = vec![0usize; self.devices.len()];
        let mut down_bytes = vec![0usize; self.devices.len()];
        let mut loss_sum = 0.0f64;

        for d in 0..self.devices.len() {
            // stage i: client forward
            let batch_idx = self.devices[d].loader.next_batch();
            let (x, y) = self.train.batch(&batch_idx);
            let x_dims = [
                batch_idx.len(),
                self.train.channels,
                self.train.height,
                self.train.width,
            ];
            let mut args: Vec<Arg> = self.devices[d]
                .client_params
                .iter()
                .map(|t| Arg::F32(t.data(), t.dims()))
                .collect();
            args.push(Arg::F32(&x, &x_dims));
            let acts = self
                .engine
                .execute("client_fwd", &args)?
                .into_iter()
                .next()
                .unwrap();

            // stage ii: ACII (Pallas kernel) + uplink compression
            let h_inst = self.entropy_of(&acts)?;
            let acts_cm = acts.to_channel_major();
            let wire_up = self.devices[d]
                .up_codec
                .compress(&acts_cm, RoundCtx { entropy: Some(&h_inst) });
            up_bytes[d] = wire_up.len();
            let acts_hat = self.devices[d].up_codec.decompress(&wire_up)?;

            // stage iii: server fwd+bwd+SGD
            let y_dims = [y.len()];
            let mut args: Vec<Arg> = self
                .server
                .server_params
                .iter()
                .map(|t| Arg::F32(t.data(), t.dims()))
                .collect();
            args.push(Arg::F32(acts_hat.data(), acts_hat.dims()));
            args.push(Arg::I32(&y, &y_dims));
            args.push(Arg::ScalarF32(lr));
            let mut out = self.engine.execute("server_step", &args)?;
            let new_sp = out.split_off(2);
            let g_acts = out.pop().unwrap();
            let loss = out.pop().unwrap().data()[0] as f64;
            if !loss.is_finite() {
                return Err(format!("round {round} device {d}: loss diverged ({loss})"));
            }
            loss_sum += loss;
            self.server.update(new_sp);

            // stage iv: downlink gradient compression + client backward
            let g_hat = if self.cfg.compress_gradients {
                let g_ent = self.entropy_of(&g_acts)?;
                let g_cm = g_acts.to_channel_major();
                let wire_down = self.devices[d]
                    .down_codec
                    .compress(&g_cm, RoundCtx { entropy: Some(&g_ent) });
                down_bytes[d] = wire_down.len();
                self.devices[d].down_codec.decompress(&wire_down)?
            } else {
                down_bytes[d] = g_acts.len() * 4;
                g_acts
            };

            let mut args: Vec<Arg> = self.devices[d]
                .client_params
                .iter()
                .map(|t| Arg::F32(t.data(), t.dims()))
                .collect();
            args.push(Arg::F32(&x, &x_dims));
            args.push(Arg::F32(g_hat.data(), g_hat.dims()));
            args.push(Arg::ScalarF32(lr));
            let new_cp = self.engine.execute("client_bwd", &args)?;
            self.devices[d].client_params = new_cp;
        }

        // SFL aggregation of client sub-models
        if (round + 1) % self.cfg.client_agg_every == 0 {
            fedavg_clients(&mut self.devices, &self.shard_sizes);
        }

        Ok((loss_sum / self.devices.len() as f64, up_bytes, down_bytes))
    }

    /// Test accuracy of the aggregated model over the test set.
    pub fn evaluate(&mut self) -> Result<f64, String> {
        let batch = self.engine.manifest().batch;
        let n_batches = self.test.len() / batch;
        if n_batches == 0 {
            return Err("test set smaller than one batch".into());
        }
        let mut correct = 0usize;
        let mut total = 0usize;
        for bi in 0..n_batches {
            let idx: Vec<usize> = (bi * batch..(bi + 1) * batch).collect();
            let (x, y) = self.test.batch(&idx);
            let x_dims = [batch, self.test.channels, self.test.height, self.test.width];
            let mut args: Vec<Arg> = self.devices[0]
                .client_params
                .iter()
                .map(|t| Arg::F32(t.data(), t.dims()))
                .collect();
            for t in &self.server.server_params {
                args.push(Arg::F32(t.data(), t.dims()));
            }
            args.push(Arg::F32(&x, &x_dims));
            let logits = self
                .engine
                .execute("eval_logits", &args)?
                .into_iter()
                .next()
                .unwrap();
            let classes = self.test.classes;
            for (i, &label) in y.iter().enumerate() {
                let row = &logits.data()[i * classes..(i + 1) * classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred == label as usize {
                    correct += 1;
                }
                total += 1;
            }
        }
        Ok(correct as f64 / total as f64)
    }

    /// Run the configured number of rounds (early-stopping at the target
    /// accuracy if one is set) and return the report.
    pub fn run(&mut self) -> Result<TrainReport, String> {
        let label = self.cfg.codec.label();
        let mut time_to_target = None;
        let mut rounds_run = 0;

        for round in 0..self.cfg.rounds {
            let wall = Instant::now();
            let (loss, up, down) = self.run_round(round)?;
            let cost = self.net.round_cost(&up, &down);
            self.timeline.push(cost);
            rounds_run = round + 1;

            let accuracy = if (round + 1) % self.cfg.eval_every == 0
                || round + 1 == self.cfg.rounds
            {
                Some(self.evaluate()?)
            } else {
                None
            };

            let rec = RoundRecord {
                round,
                loss,
                accuracy,
                bytes_up: cost.bytes_up,
                bytes_down: cost.bytes_down,
                sim_time_s: self.timeline.total_time(),
                wall_ms: wall.elapsed().as_secs_f64() * 1e3,
            };
            if let Some(acc) = accuracy {
                crate::log_info!(
                    "[{label}] round {round}: loss {loss:.4} acc {:.2}% sim_t {:.1}s",
                    acc * 100.0,
                    rec.sim_time_s
                );
                if let Some(target) = self.cfg.target_accuracy {
                    if acc >= target && time_to_target.is_none() {
                        time_to_target = Some(rec.sim_time_s);
                        self.metrics.push(rec);
                        break;
                    }
                }
            } else {
                crate::log_debug!("[{label}] round {round}: loss {loss:.4}");
            }
            self.metrics.push(rec);
        }

        let (bytes_up, bytes_down) = self.metrics.total_bytes();
        Ok(TrainReport {
            label,
            final_accuracy: self.metrics.final_accuracy().unwrap_or(0.0),
            best_accuracy: self.metrics.best_accuracy().unwrap_or(0.0),
            total_sim_time_s: self.timeline.total_time(),
            total_bytes_up: bytes_up,
            total_bytes_down: bytes_down,
            time_to_target_s: time_to_target,
            rounds_run,
            metrics: std::mem::take(&mut self.metrics),
        })
    }
}
