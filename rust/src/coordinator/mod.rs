//! The split-learning coordinator — the L3 system contribution.
//!
//! * [`trainer`] — the end-to-end SFL session over the PJRT runtime: N
//!   in-process device workers wired to the server runtime through
//!   deterministic loopback transports (see [`crate::transport`]; the
//!   `slacc serve`/`slacc device` CLI runs the same protocol over TCP).
//! * [`device`] — per-device state (client sub-model, loader, codecs) and
//!   FedAvg aggregation.
//! * [`server`] — the shared server sub-model state.
//! * [`metrics`] — per-round records, accuracy curves, CSV/JSON export,
//!   and the [`metrics::TrainReport`] a session returns.

pub mod device;
pub mod metrics;
pub mod server;
pub mod trainer;
