//! The split-learning coordinator — the L3 system contribution.
//!
//! * [`trainer`] — the end-to-end SFL round loop over the PJRT runtime.
//! * [`device`] — per-device state (client sub-model, loader, codecs) and
//!   FedAvg aggregation.
//! * [`server`] — the shared server sub-model state.
//! * [`metrics`] — per-round records, accuracy curves, CSV/JSON export.

pub mod device;
pub mod metrics;
pub mod server;
pub mod trainer;
