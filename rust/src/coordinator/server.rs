//! Server-side state: the shared server sub-model parameters.
//!
//! In SFL there is a single server model updated sequentially with every
//! device's (decompressed) smashed data each round; this is what
//! `server_step` consumes and produces through the PJRT runtime.

use crate::tensor::Tensor;

pub struct ServerState {
    pub server_params: Vec<Tensor>,
}

impl ServerState {
    pub fn new(server_params: Vec<Tensor>) -> ServerState {
        ServerState { server_params }
    }

    pub fn param_count(&self) -> usize {
        self.server_params.iter().map(|t| t.len()).sum()
    }

    /// Replace parameters with a step result (post-SGD values).
    pub fn update(&mut self, new_params: Vec<Tensor>) {
        debug_assert_eq!(new_params.len(), self.server_params.len());
        self.server_params = new_params;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn update_replaces() {
        let mut s = ServerState::new(vec![Tensor::new(vec![2], vec![1.0, 2.0])]);
        assert_eq!(s.param_count(), 2);
        s.update(vec![Tensor::new(vec![2], vec![3.0, 4.0])]);
        assert_eq!(s.server_params[0].data(), &[3.0, 4.0]);
    }
}
