//! Per-edge-device state: client sub-model replica, data shard loader, and
//! the device's stream codecs (uplink activations / downlink gradients /
//! ModelSync, see [`crate::codecs::stream::DeviceStreams`]).
//!
//! Codec state is per-device *and* per-direction, matching the paper: ACII
//! tracks the entropy history of each smashed-data stream independently
//! (device activations differ, and gradients have different statistics
//! than activations).

use crate::codecs::stream::DeviceStreams;
use crate::data::loader::BatchLoader;
use crate::tensor::Tensor;

pub struct DeviceState {
    pub id: usize,
    /// flat client sub-model parameters (manifest order)
    pub client_params: Vec<Tensor>,
    pub loader: BatchLoader,
    /// this device's four stream codec instances
    pub streams: DeviceStreams,
}

impl DeviceState {
    pub fn new(
        id: usize,
        client_params: Vec<Tensor>,
        loader: BatchLoader,
        streams: DeviceStreams,
    ) -> DeviceState {
        DeviceState { id, client_params, loader, streams }
    }
}

/// FedAvg over parameter sets: the weighted average of `sets[d]`, device
/// order preserved so the f32 accumulation is reproducible wherever the
/// aggregation runs (in-process trainer or the transport server runtime).
pub fn fedavg_params(sets: &[&[Tensor]], weights: &[f64]) -> Vec<Tensor> {
    assert_eq!(sets.len(), weights.len());
    assert!(!sets.is_empty());
    let wsum: f64 = weights.iter().sum();
    assert!(wsum > 0.0);
    let n_params = sets[0].len();

    let mut avg: Vec<Tensor> = sets[0]
        .iter()
        .map(|t| Tensor::zeros(t.dims().to_vec()))
        .collect();
    for (set, &w) in sets.iter().zip(weights) {
        assert_eq!(set.len(), n_params);
        let scale = (w / wsum) as f32;
        for (acc, t) in avg.iter_mut().zip(set.iter()) {
            for (a, &x) in acc.data_mut().iter_mut().zip(t.data()) {
                *a += scale * x;
            }
        }
    }
    avg
}

/// FedAvg: weighted average of every device's client sub-model, written
/// back to all devices (paper workflow step iv + SFL aggregation).
pub fn fedavg_clients(devices: &mut [DeviceState], weights: &[f64]) {
    let sets: Vec<&[Tensor]> = devices.iter().map(|d| d.client_params.as_slice()).collect();
    let avg = fedavg_params(&sets, weights);
    for dev in devices.iter_mut() {
        dev.client_params = avg.clone();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::identity::IdentityCodec;

    fn dev(id: usize, value: f32) -> DeviceState {
        DeviceState::new(
            id,
            vec![Tensor::new(vec![2], vec![value, value * 2.0])],
            BatchLoader::new(&[0, 1, 2], 2, id as u64),
            DeviceStreams {
                up: Box::new(IdentityCodec::new()),
                down: Box::new(IdentityCodec::new()),
                sync_up: Box::new(IdentityCodec::new()),
                sync_down: Box::new(IdentityCodec::new()),
            },
        )
    }

    #[test]
    fn fedavg_equal_weights() {
        let mut devs = vec![dev(0, 1.0), dev(1, 3.0)];
        fedavg_clients(&mut devs, &[1.0, 1.0]);
        assert_eq!(devs[0].client_params[0].data(), &[2.0, 4.0]);
        assert_eq!(devs[1].client_params[0].data(), &[2.0, 4.0]);
    }

    #[test]
    fn fedavg_weighted() {
        let mut devs = vec![dev(0, 0.0), dev(1, 4.0)];
        fedavg_clients(&mut devs, &[3.0, 1.0]);
        assert_eq!(devs[0].client_params[0].data(), &[1.0, 2.0]);
    }

    #[test]
    fn fedavg_single_device_noop() {
        let mut devs = vec![dev(0, 5.0)];
        fedavg_clients(&mut devs, &[2.0]);
        assert_eq!(devs[0].client_params[0].data(), &[5.0, 10.0]);
    }
}
