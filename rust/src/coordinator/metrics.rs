//! Training metrics: per-round records, accuracy observations, and
//! CSV/JSON export for the bench harness and plots.

use crate::net::timeline::DeviceWaitProfile;
use crate::util::json::Json;

/// One training round's measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundRecord {
    pub round: usize,
    /// mean training loss across devices this round
    pub loss: f64,
    /// test accuracy if this was an eval round
    pub accuracy: Option<f64>,
    pub bytes_up: usize,
    pub bytes_down: usize,
    /// ModelSync (FedAvg) traffic this round, both directions — its own
    /// axis, separate from the paper's smashed-data bytes
    pub bytes_sync: usize,
    /// raw (pre-codec) f32 bytes behind `bytes_up` — the denominator-free
    /// side of the per-stream compression ratio
    pub raw_up: usize,
    /// raw f32 bytes behind `bytes_down`
    pub raw_down: usize,
    /// raw f32 bytes behind `bytes_sync`
    pub raw_sync: usize,
    /// devices that participated in this round's close (arrival-order
    /// scheduling can close a round on a quorum)
    pub participants: usize,
    /// devices carried past this round's close as stragglers
    pub stragglers: usize,
    /// cumulative simulated seconds after this round
    pub sim_time_s: f64,
    /// real wall-clock milliseconds spent on this round
    pub wall_ms: f64,
    /// the spec table active for this round (changes mid-session only
    /// under `--adapt`; see [`crate::adapt`])
    pub spec: String,
}

/// Result of a completed training session (in-process or over a real
/// transport — see [`crate::transport::server::ServerRuntime`]).
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub label: String,
    pub metrics: MetricsLog,
    pub final_accuracy: f64,
    pub best_accuracy: f64,
    pub total_sim_time_s: f64,
    pub total_bytes_up: usize,
    pub total_bytes_down: usize,
    /// total ModelSync bytes (separate from the smashed-data axis)
    pub total_bytes_sync: usize,
    /// session compression ratio (raw f32 / wire bytes) per stream kind —
    /// the paper's Fig. 5 overhead axis broken down by direction
    pub ratio_up: f64,
    pub ratio_down: f64,
    pub ratio_sync: f64,
    pub time_to_target_s: Option<f64>,
    pub rounds_run: usize,
    /// straggler carry-overs across the session (0 under InOrder)
    pub straggler_events: usize,
    /// server `server_step` items executed (one per device Activations)
    pub server_steps: usize,
    /// compute dispatches those items crossed the PJRT boundary in —
    /// equal to `server_steps` at `--batch-window 1`, smaller when
    /// batching amortizes the boundary
    pub server_dispatches: usize,
    /// per-device wait accounting for this node's local fleet slice,
    /// `(global device id, profile)` in slot order — the straggler
    /// attribution axis of the end-of-session report
    pub device_waits: Vec<(usize, DeviceWaitProfile)>,
}

impl TrainReport {
    /// Per-device wait CSV (`device,gid,wait_s,straggles,participations`) —
    /// written next to the round CSV as `<stem>_devices.csv` so the
    /// historical round-CSV columns stay index-stable.
    pub fn device_waits_csv(&self) -> String {
        let mut out = String::from("device,gid,wait_s,straggles,participations\n");
        for (d, (gid, p)) in self.device_waits.iter().enumerate() {
            out.push_str(&format!(
                "{d},{gid},{:.6},{},{}\n",
                p.wait_s, p.straggles, p.participations
            ));
        }
        out
    }

    pub fn write_device_waits_csv(&self, path: &std::path::Path) -> Result<(), String> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).map_err(|e| e.to_string())?;
        }
        std::fs::write(path, self.device_waits_csv()).map_err(|e| e.to_string())
    }
}

/// raw/wire compression ratio; 0 when the stream moved no bytes.
pub fn ratio(raw: usize, wire: usize) -> f64 {
    if wire == 0 {
        0.0
    } else {
        raw as f64 / wire as f64
    }
}

impl RoundRecord {
    pub fn ratio_up(&self) -> f64 {
        ratio(self.raw_up, self.bytes_up)
    }

    pub fn ratio_down(&self) -> f64 {
        ratio(self.raw_down, self.bytes_down)
    }

    pub fn ratio_sync(&self) -> f64 {
        ratio(self.raw_sync, self.bytes_sync)
    }
}

/// Append-only metrics log for one run.
#[derive(Debug, Clone, Default)]
pub struct MetricsLog {
    pub records: Vec<RoundRecord>,
}

impl MetricsLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.records.push(r);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// (round, accuracy) pairs for eval rounds.
    pub fn accuracy_curve(&self) -> Vec<(usize, f64)> {
        self.records
            .iter()
            .filter_map(|r| r.accuracy.map(|a| (r.round, a)))
            .collect()
    }

    /// (sim_time_s, accuracy) pairs — the paper's Fig. 5 axes.
    pub fn accuracy_vs_time(&self) -> Vec<(f64, f64)> {
        self.records
            .iter()
            .filter_map(|r| r.accuracy.map(|a| (r.sim_time_s, a)))
            .collect()
    }

    pub fn final_accuracy(&self) -> Option<f64> {
        self.records.iter().rev().find_map(|r| r.accuracy)
    }

    pub fn best_accuracy(&self) -> Option<f64> {
        self.records
            .iter()
            .filter_map(|r| r.accuracy)
            .fold(None, |m, a| Some(m.map_or(a, |m: f64| m.max(a))))
    }

    /// First simulated time at which accuracy >= target.
    pub fn time_to_accuracy(&self, target: f64) -> Option<f64> {
        self.records
            .iter()
            .find(|r| r.accuracy.is_some_and(|a| a >= target))
            .map(|r| r.sim_time_s)
    }

    pub fn total_bytes(&self) -> (usize, usize) {
        (
            self.records.iter().map(|r| r.bytes_up).sum(),
            self.records.iter().map(|r| r.bytes_down).sum(),
        )
    }

    /// Total raw (pre-codec) bytes per stream kind: (up, down, sync).
    pub fn total_raw(&self) -> (usize, usize, usize) {
        (
            self.records.iter().map(|r| r.raw_up).sum(),
            self.records.iter().map(|r| r.raw_down).sum(),
            self.records.iter().map(|r| r.raw_sync).sum(),
        )
    }

    /// Session compression ratio per stream kind: (up, down, sync).
    pub fn ratio_by_stream(&self) -> (f64, f64, f64) {
        let (wu, wd) = self.total_bytes();
        let ws = self.total_bytes_sync();
        let (ru, rd, rs) = self.total_raw();
        (ratio(ru, wu), ratio(rd, wd), ratio(rs, ws))
    }

    /// Total ModelSync bytes across the session.
    pub fn total_bytes_sync(&self) -> usize {
        self.records.iter().map(|r| r.bytes_sync).sum()
    }

    /// Total straggler carry-overs across the session.
    pub fn straggler_events(&self) -> usize {
        self.records.iter().map(|r| r.stragglers).sum()
    }

    pub fn mean_loss_tail(&self, window: usize) -> f64 {
        let n = self.records.len();
        let start = n.saturating_sub(window);
        let tail = &self.records[start..];
        if tail.is_empty() {
            return f64::NAN;
        }
        tail.iter().map(|r| r.loss).sum::<f64>() / tail.len() as f64
    }

    pub fn to_csv(&self) -> String {
        // bytes_up/bytes_down keep their historical columns (3/4) — the
        // distributed-parity checks parse by index; new axes go at the end
        let mut out = String::from(
            "round,loss,accuracy,bytes_up,bytes_down,sim_time_s,wall_ms,bytes_sync,\
             stragglers,ratio_up,ratio_down,ratio_sync,active_spec\n",
        );
        for r in &self.records {
            let acc = r.accuracy.map_or(String::new(), |a| format!("{a:.6}"));
            out.push_str(&format!(
                "{},{:.6},{},{},{},{:.4},{:.1},{},{},{:.3},{:.3},{:.3},{}\n",
                r.round, r.loss, acc, r.bytes_up, r.bytes_down, r.sim_time_s,
                r.wall_ms, r.bytes_sync, r.stragglers, r.ratio_up(),
                r.ratio_down(), r.ratio_sync(), r.spec
            ));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.records
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("round", Json::Num(r.round as f64)),
                        ("loss", Json::Num(r.loss)),
                        (
                            "accuracy",
                            r.accuracy.map_or(Json::Null, Json::Num),
                        ),
                        ("bytes_up", Json::Num(r.bytes_up as f64)),
                        ("bytes_down", Json::Num(r.bytes_down as f64)),
                        ("bytes_sync", Json::Num(r.bytes_sync as f64)),
                        ("ratio_up", Json::Num(r.ratio_up())),
                        ("ratio_down", Json::Num(r.ratio_down())),
                        ("ratio_sync", Json::Num(r.ratio_sync())),
                        ("participants", Json::Num(r.participants as f64)),
                        ("stragglers", Json::Num(r.stragglers as f64)),
                        ("sim_time_s", Json::Num(r.sim_time_s)),
                        ("wall_ms", Json::Num(r.wall_ms)),
                        ("active_spec", Json::Str(r.spec.clone())),
                    ])
                })
                .collect(),
        )
    }

    pub fn write_csv(&self, path: &std::path::Path) -> Result<(), String> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).map_err(|e| e.to_string())?;
        }
        std::fs::write(path, self.to_csv()).map_err(|e| e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, loss: f64, acc: Option<f64>, t: f64) -> RoundRecord {
        RoundRecord {
            round,
            loss,
            accuracy: acc,
            bytes_up: 100,
            bytes_down: 50,
            bytes_sync: 25,
            raw_up: 400,
            raw_down: 200,
            raw_sync: 25,
            participants: 1,
            stragglers: 0,
            sim_time_s: t,
            wall_ms: 1.0,
            spec: "uplink=slacc downlink=slacc sync=identity".into(),
        }
    }

    #[test]
    fn curves_and_queries() {
        let mut m = MetricsLog::new();
        m.push(rec(0, 2.0, None, 1.0));
        m.push(rec(1, 1.5, Some(0.4), 2.0));
        m.push(rec(2, 1.2, None, 3.0));
        m.push(rec(3, 1.0, Some(0.7), 4.0));
        assert_eq!(m.accuracy_curve(), vec![(1, 0.4), (3, 0.7)]);
        assert_eq!(m.final_accuracy(), Some(0.7));
        assert_eq!(m.best_accuracy(), Some(0.7));
        assert_eq!(m.time_to_accuracy(0.5), Some(4.0));
        assert_eq!(m.time_to_accuracy(0.9), None);
        assert_eq!(m.total_bytes(), (400, 200));
        assert_eq!(m.total_raw(), (1600, 800, 100));
        let (ru, rd, rs) = m.ratio_by_stream();
        assert!((ru - 4.0).abs() < 1e-12);
        assert!((rd - 4.0).abs() < 1e-12);
        assert!((rs - 1.0).abs() < 1e-12);
        assert!((m.mean_loss_tail(2) - 1.1).abs() < 1e-12);
    }

    #[test]
    fn csv_shape() {
        let mut m = MetricsLog::new();
        m.push(rec(0, 2.0, Some(0.1), 1.0));
        let csv = m.to_csv();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("round,loss"));
        assert!(lines[1].starts_with("0,2.0"));
    }

    #[test]
    fn json_roundtrips() {
        let mut m = MetricsLog::new();
        m.push(rec(0, 2.0, None, 1.0));
        let j = m.to_json();
        let parsed = Json::parse(&j.dump()).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), 1);
        assert_eq!(
            parsed.as_arr().unwrap()[0].at(&["accuracy"]),
            &Json::Null
        );
    }
}
