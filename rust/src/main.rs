//! `slacc` — launcher for the SL-ACC split-learning framework.
//!
//! Subcommands:
//!   train     run a full SL training experiment (the default)
//!   serve     run the SL server over TCP and wait for device workers
//!   device    run one edge-device worker against a remote server
//!   eval      load artifacts + init params and report test accuracy
//!   inspect   one round of ACII+CGC diagnostics on real activations
//!   codecs    offline codec comparison on synthetic smashed data
//!   trace     merge per-node --trace-out files into a critical-path report
//!
//! Examples:
//!   slacc train --dataset ham --codec slacc --rounds 300 --devices 5
//!   slacc train --dataset mnist --codec powerquant --noniid --beta 0.5
//!   slacc serve --devices 4 --rounds 50 --bind 127.0.0.1:7878
//!   slacc device --id 0 --devices 4 --rounds 50 --connect 127.0.0.1:7878
//!   slacc inspect --dataset ham
//!   slacc codecs
//!
//! `serve`/`device` must be launched with the same dataset/codec/seed
//! flags — the Hello handshake rejects mismatched fleets. With `--mock`
//! (or when AOT artifacts are missing) the session runs the real codecs
//! and wire protocol over a deterministic mock model, which is enough to
//! measure communication behavior without PJRT.

use std::net::TcpListener;
use std::sync::Arc;
use std::time::Duration;

use slacc::cli::Args;
use slacc::codecs::{self, RoundCtx};
use slacc::config::{CodecChoice, ExperimentConfig};
use slacc::coordinator::trainer::{
    engine_runtime_for_shard, engine_worker, TrainReport, Trainer,
};
use slacc::data::partition::Partition;
use slacc::data::Dataset;
use slacc::entropy::AlphaSchedule;
use slacc::sched::event_loop::FleetOptions;
use slacc::sched::fleet::ShardFleet;
use slacc::sched::poll::Backend;
use slacc::sched::{Participation, Policy};
use slacc::shard::coordinator::Coordinator;
use slacc::shard::link::ShardLink;
use slacc::shard::Role;
use slacc::obs::export::{MetricsExporter, SnapshotWriter};
use slacc::obs::span;
use slacc::obs::trace;
use slacc::transport::device::{mock_worker, run_blocking, run_blocking_rejoin};
use slacc::transport::server::{accept_and_serve_opts, mock_runtime_for_shard};
use slacc::transport::tcp::TcpTransport;
use slacc::transport::{session_fingerprint, Transport};
use slacc::util::logging;

fn main() {
    logging::init_from_env();
    let mut args = Args::from_env();
    let sub = args.subcommand.clone().unwrap_or_else(|| "train".to_string());
    if let Some(level) = args.str_opt("log-level") {
        match logging::level_from_str(&level) {
            Some(l) => logging::set_level(l),
            None => {
                eprintln!("invalid --log-level '{level}'");
                std::process::exit(2);
            }
        }
    }
    let result = match sub.as_str() {
        "train" => cmd_train(args),
        "serve" => cmd_serve(args),
        "device" => cmd_device(args),
        "eval" => cmd_eval(args),
        "inspect" => cmd_inspect(args),
        "codecs" => cmd_codecs(args),
        "trace" => cmd_trace(args),
        "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => Err(format!("unknown subcommand '{other}' (try: slacc help)")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "slacc — SL-ACC split learning framework\n\n\
         USAGE: slacc [train|serve|device|eval|inspect|codecs|trace] [--flags]\n\n\
         train flags:\n\
           --dataset ham|mnist     model/dataset config    [ham]\n\
           --codec SPEC            both data directions    [slacc]\n\
                                   base specs: {:?}\n\
                                   plus uniform<bits>, select:<strategy>[:<n>],\n\
                                   and the ef:<spec> error-feedback wrapper\n\
           --uplink-codec SPEC     override the activations stream only\n\
           --downlink-codec SPEC   override the gradients stream only\n\
           --select STRATEGY       channel-selection ablation instead of a codec\n\
                                   (random|std|entropy-instant|entropy-historical|acii|fixed:N)\n\
           --n-select N            channels kept by --select [1]\n\
           --rounds N              training rounds         [300]\n\
           --devices N             edge devices            [5]\n\
           --lr X                  SGD learning rate       [0.001]\n\
           --noniid                Dirichlet partition instead of IID\n\
           --beta X                Dirichlet concentration [0.5]\n\
           --train-n N / --test-n N  dataset sizes         [2000 / 512]\n\
           --eval-every N          eval cadence            [10]\n\
           --target X              stop at this test accuracy\n\
           --alpha X               fixed ACII alpha in [0,1] (default: t/T)\n\
           --groups N              CGC groups g            [4]\n\
           --window N              ACII history window k   [5]\n\
           --bmin N / --bmax N     quantization bit bounds [2 / 8]\n\
           --agg-every N           FedAvg cadence          [1]\n\
           --seed N                RNG seed                [0]\n\
           --artifacts DIR         artifacts root          [artifacts]\n\
           --csv PATH              write per-round metrics CSV\n\
           --no-grad-compress      leave downlink gradients uncompressed\n\
           --host-entropy          host entropy instead of the Pallas kernel\n\
           --schedule MODE         round scheduling: inorder|arrival [inorder]\n\
           --elastic               elastic membership (arrival schedule only):\n\
                                   keep the listener armed after session start,\n\
                                   admit Join frames at round boundaries with a\n\
                                   model-catchup handshake, shed failed devices\n\
                                   as typed departures instead of aborting\n\
           --select all|bias-stragglers  participation policy: who is invited\n\
                                   at round open [all]; bias-stragglers sits\n\
                                   chronic stragglers out every other round\n\
                                   (--select also accepts the channel-selection\n\
                                   ablation strategies below)\n\
           --straggler-timeout S   (arrival) close a round after S seconds\n\
           --min-quorum N          (arrival) devices required to close a\n\
                                   timed-out round [all]\n\
           --batch-window N        (arrival) max same-shaped Activations\n\
                                   coalesced into one server_step dispatch\n\
                                   [1]; inorder always forces 1\n\
           --sync-codec SPEC       codec for ModelSync traffic [identity]\n\
           --shards M              split the fleet across M shard servers [1]\n\
           --shard-sync-every K    cross-shard FedAvg cadence in rounds [1]\n\
           --adapt DIRECTIVE       retune data-stream codecs mid-session:\n\
                                   at:R=SPEC,... (forced schedule) or\n\
                                   ladder:SPEC,SPEC,...[;cooldown=N] (telemetry\n\
                                   control loop) [off]\n\
         serve flags (train flags plus):\n\
           --bind ADDR             device listen address   [127.0.0.1:7878]\n\
           --mock                  mock model (no PJRT artifacts needed)\n\
           --role shard|coordinator  this node's topology role [shard]\n\
           --shard-id K            this shard's slot in 0..shards [0]\n\
           --shard-bind ADDR       coordinator listen address (shard role,\n\
                                   shards > 1)             [127.0.0.1:7978]\n\
           --connect-shard A,B,... shard --shard-bind addresses, one per\n\
                                   shard (coordinator role, required)\n\
           --checkpoint-dir DIR    (coordinator) write an atomic checkpoint of\n\
                                   the merged models + epoch counter every\n\
                                   sync epoch\n\
           --resume                (coordinator) resume a crashed session from\n\
                                   --checkpoint-dir; shards re-admit the new\n\
                                   coordinator and re-push their barriered epoch\n\
           --io-backend MODE       event-loop readiness backend:\n\
                                   auto|epoll|poll [auto]; auto picks\n\
                                   edge-triggered epoll on linux, poll(2)\n\
                                   elsewhere (never fingerprinted — both\n\
                                   backends drive bit-identical sessions)\n\
           --write-stall-secs S    abort a write jammed for S seconds on a\n\
                                   peer that stopped reading [10]\n\
         device flags (train flags plus):\n\
           --id N                  this device's GLOBAL slot in 0..devices\n\
                                   (required; connect to the shard serving it)\n\
           --connect ADDR          server address          [127.0.0.1:7878]\n\
           --mock                  mock model (must match the server)\n\
           --rejoin                join a session already in progress (the\n\
                                   server must run --elastic): send Join\n\
                                   instead of Hello, receive a model catch-up\n\
           --trace-out FILE        record this device's lifecycle spans\n\
         trace flags:\n\
           slacc trace FILE... [--chrome OUT.json]\n\
                                   merge the --trace-out JSONL of every node\n\
                                   of one session (clock-aligned via the\n\
                                   handshake anchors) into a per-round\n\
                                   critical-path breakdown; --chrome also\n\
                                   writes a Chrome trace-event timeline\n\
         serve telemetry (all off by default; never part of the session\n\
         fingerprint):\n\
           --metrics-bind ADDR     live Prometheus scrape endpoint, served\n\
                                   non-blocking from the event loop\n\
           --metrics-every N       whole-registry JSONL snapshot every N\n\
                                   closed rounds\n\
           --metrics-out FILE      snapshot file    [metrics.jsonl]\n\
           --trace-out FILE        enable tracing spans; drain them to\n\
                                   FILE as JSONL at session end\n\
         common:\n\
           --log-level error|warn|info|debug|trace",
        codecs::ALL_CODECS
    );
}

/// Shared train/eval config construction from CLI flags.
fn config_from_args(args: &mut Args) -> Result<ExperimentConfig, String> {
    let dataset = args.str_or("dataset", "ham");
    let mut cfg = ExperimentConfig::default_for(&dataset);
    cfg.artifacts_root = args.str_or("artifacts", "artifacts");
    cfg.rounds = args.usize_or("rounds", cfg.rounds);
    cfg.devices = args.usize_or("devices", cfg.devices);
    cfg.lr = args.f64_or("lr", cfg.lr as f64) as f32;
    cfg.train_n = args.usize_or("train-n", cfg.train_n);
    cfg.test_n = args.usize_or("test-n", cfg.test_n);
    cfg.eval_every = args.usize_or("eval-every", cfg.eval_every);
    cfg.client_agg_every = args.usize_or("agg-every", cfg.client_agg_every);
    cfg.seed = args.usize_or("seed", 0) as u64;
    cfg.target_accuracy = args.f64_opt("target");
    if args.bool_or("noniid", false) {
        cfg.partition = Partition::Dirichlet { beta: args.f64_or("beta", 0.5) };
    } else {
        let _ = args.f64_or("beta", 0.5);
    }
    if let Some(a) = args.f64_opt("alpha") {
        cfg.alpha = Some(AlphaSchedule::Fixed(a as f32));
    }
    cfg.slacc.groups = args.usize_or("groups", cfg.slacc.groups);
    cfg.slacc.history_window = args.usize_or("window", cfg.slacc.history_window);
    cfg.slacc.b_min = args.usize_or("bmin", cfg.slacc.b_min as usize) as u32;
    cfg.slacc.b_max = args.usize_or("bmax", cfg.slacc.b_max as usize) as u32;
    cfg.entropy_via_kernel = !args.bool_or("host-entropy", false);
    cfg.compress_gradients = !args.bool_or("no-grad-compress", false);

    let schedule = args.str_or("schedule", "inorder");
    let straggler_timeout = args.f64_opt("straggler-timeout");
    let min_quorum = args.usize_opt("min-quorum");
    cfg.schedule = match schedule.as_str() {
        "inorder" => {
            if straggler_timeout.is_some() || min_quorum.is_some() {
                return Err(
                    "--straggler-timeout/--min-quorum need --schedule arrival".into()
                );
            }
            Policy::InOrder
        }
        // an explicit `--min-quorum 0` flows through as Some(0) and is
        // rejected by validate(), rather than silently meaning "all"
        "arrival" => Policy::ArrivalOrder {
            straggler_timeout_s: straggler_timeout,
            min_quorum,
        },
        other => return Err(format!("unknown --schedule '{other}' (inorder|arrival)")),
    };
    cfg.elastic = args.bool_or("elastic", false);
    if let Some(name) = args.str_opt("sync-codec") {
        cfg.sync_codec = Some(name);
    }
    cfg.batch_window = args.usize_or("batch-window", cfg.batch_window);
    cfg.shards = args.usize_or("shards", cfg.shards);
    cfg.shard_sync_every = args.usize_or("shard-sync-every", cfg.shard_sync_every);
    cfg.uplink_codec = args.str_opt("uplink-codec");
    cfg.downlink_codec = args.str_opt("downlink-codec");
    cfg.adapt = args.str_opt("adapt");

    if let Some(sel) = args.str_opt("select") {
        // --select is overloaded: participation policies (who is invited
        // at round open) vs channel-selection ablations (what a codec
        // keeps). Policy names win; everything else is a selection spec.
        if let Ok(p) = Participation::parse(&sel) {
            cfg.participation = p;
            cfg.codec = CodecChoice::Named(args.str_or("codec", "slacc"));
            let _ = args.usize_or("n-select", 1);
            return Ok(cfg);
        }
        use slacc::codecs::selection::Selection;
        let strategy = match sel.as_str() {
            "random" => Selection::Random,
            "std" => Selection::MaxStd,
            "entropy-instant" => Selection::EntropyInstant,
            "entropy-historical" => Selection::EntropyHistorical,
            "acii" => Selection::EntropyBlended,
            s if s.starts_with("fixed:") => {
                let c = s[6..]
                    .parse()
                    .map_err(|_| format!("bad --select '{s}'"))?;
                Selection::Fixed(c)
            }
            s => return Err(format!("unknown --select '{s}'")),
        };
        cfg.codec = CodecChoice::Select {
            strategy,
            n_select: args.usize_or("n-select", 1),
        };
    } else {
        cfg.codec = CodecChoice::Named(args.str_or("codec", "slacc"));
        let _ = args.usize_or("n-select", 1);
    }
    Ok(cfg)
}

fn print_report(report: &TrainReport, csv: Option<String>) -> Result<(), String> {
    println!("\n=== training report: {} ===", report.label);
    println!("rounds run        : {}", report.rounds_run);
    println!("final accuracy    : {:.2}%", report.final_accuracy * 100.0);
    println!("best accuracy     : {:.2}%", report.best_accuracy * 100.0);
    println!("simulated time    : {:.1}s", report.total_sim_time_s);
    println!(
        "smashed data bytes: {:.2} MB up / {:.2} MB down",
        report.total_bytes_up as f64 / 1e6,
        report.total_bytes_down as f64 / 1e6
    );
    println!(
        "model sync bytes  : {:.2} MB",
        report.total_bytes_sync as f64 / 1e6
    );
    println!(
        "compression ratio : {:.1}x up / {:.1}x down / {:.1}x sync",
        report.ratio_up, report.ratio_down, report.ratio_sync
    );
    if report.straggler_events > 0 {
        println!("straggler events  : {}", report.straggler_events);
    }
    if report.server_steps > 0 {
        println!(
            "server dispatches : {} for {} device steps ({:.2} steps/dispatch)",
            report.server_dispatches,
            report.server_steps,
            report.server_steps as f64 / report.server_dispatches.max(1) as f64
        );
    }
    if let Some(t) = report.time_to_target_s {
        println!("time to target    : {t:.1}s");
    }
    if !report.device_waits.is_empty() {
        println!("device wait profile:");
        for (d, (gid, p)) in report.device_waits.iter().enumerate() {
            println!(
                "  device {d} (gid {gid}): waited {:.2}s, straggled {} of {} rounds",
                p.wait_s, p.straggles, p.participations
            );
        }
    }
    if let Some(path) = csv {
        let path = std::path::PathBuf::from(path);
        report.metrics.write_csv(&path)?;
        println!("metrics CSV       : {}", path.display());
        if !report.device_waits.is_empty() {
            let stem = path
                .file_stem()
                .and_then(|s| s.to_str())
                .unwrap_or("metrics");
            let dev_path = path.with_file_name(format!("{stem}_devices.csv"));
            report.write_device_waits_csv(&dev_path)?;
            println!("device wait CSV   : {}", dev_path.display());
        }
    }
    Ok(())
}

fn cmd_train(mut args: Args) -> Result<(), String> {
    let cfg = config_from_args(&mut args)?;
    let csv = args.str_opt("csv");
    args.finish()?;

    let mut trainer = Trainer::new(cfg)?;
    let report = trainer.run()?;
    print_report(&report, csv)
}

/// Decide engine vs mock compute for a transport role.
fn use_mock(cfg: &ExperimentConfig, mock_flag: bool) -> Result<bool, String> {
    if mock_flag {
        return Ok(true);
    }
    if cfg.have_artifacts() {
        return Ok(false);
    }
    Err(format!(
        "no AOT artifacts under {} — run `make artifacts`, point --artifacts at \
         them, or pass --mock for an engine-free protocol session",
        cfg.artifacts_dir().display()
    ))
}

/// The `serve` telemetry flags (deliberately outside
/// [`ExperimentConfig::fingerprint`]: observing a session must never
/// change what fleet it handshakes with).
struct ObsFlags {
    metrics_bind: Option<String>,
    metrics_every: Option<usize>,
    metrics_out: String,
    trace_out: Option<String>,
}

impl ObsFlags {
    fn from_args(args: &mut Args) -> ObsFlags {
        ObsFlags {
            metrics_bind: args.str_opt("metrics-bind"),
            metrics_every: args.usize_opt("metrics-every"),
            metrics_out: args.str_or("metrics-out", "metrics.jsonl"),
            trace_out: args.str_opt("trace-out"),
        }
    }
}

fn cmd_serve(mut args: Args) -> Result<(), String> {
    let cfg = config_from_args(&mut args)?;
    let bind = args.str_or("bind", "127.0.0.1:7878");
    let role = Role::parse(&args.str_or("role", "shard"))?;
    let shard_id = args.usize_or("shard-id", 0);
    let shard_bind = args.str_or("shard-bind", "127.0.0.1:7978");
    let connect_shard = args.str_opt("connect-shard");
    let mock = args.bool_or("mock", false);
    let csv = args.str_opt("csv");
    let checkpoint_dir = args.str_opt("checkpoint-dir");
    let resume = args.bool_or("resume", false);
    // event-loop tunables: like the telemetry flags below, deliberately
    // outside the config fingerprint — how the server polls its sockets
    // must not change what fleet it handshakes with
    let io_backend = args.str_opt("io-backend");
    let write_stall_secs = args.usize_opt("write-stall-secs");
    let obs = ObsFlags::from_args(&mut args);
    args.finish()?;
    cfg.validate()?;
    let io = FleetOptions {
        backend: Backend::parse(io_backend.as_deref().unwrap_or("auto"))?,
        write_stall_secs: write_stall_secs.unwrap_or(10) as u64,
        // accept_and_serve_opts flips this on when the config says so
        ..FleetOptions::default()
    };

    if obs.trace_out.is_some() {
        span::set_enabled(true);
        span::set_trace_role(
            match role {
                Role::Coordinator => "coordinator",
                Role::Shard => "server",
            },
            shard_id as u64,
        );
    }
    let mock = use_mock(&cfg, mock)?;
    let result = match role {
        Role::Coordinator => {
            if obs.metrics_bind.is_some() || obs.metrics_every.is_some() {
                return Err(
                    "--metrics-bind/--metrics-every are served by shard servers; \
                     the coordinator's blocking shard links have no event loop \
                     (--trace-out works on any role)"
                        .into(),
                );
            }
            if io_backend.is_some() || write_stall_secs.is_some() {
                return Err(
                    "--io-backend/--write-stall-secs tune the shard event loop; \
                     the coordinator's blocking shard links have no poll loop"
                        .into(),
                );
            }
            serve_coordinator(cfg, connect_shard, mock, checkpoint_dir, resume)
        }
        Role::Shard => {
            if checkpoint_dir.is_some() || resume {
                return Err(
                    "--checkpoint-dir/--resume are coordinator flags (the \
                     coordinator owns the durable cross-shard state)"
                        .into(),
                );
            }
            serve_shard(cfg, bind, shard_id, shard_bind, mock, csv, &obs, io)
        }
    };
    // drain spans even when the session failed: a trace of the rounds
    // leading up to an error is exactly when you want one
    if let Some(path) = &obs.trace_out {
        let n = span::write_jsonl(path)?;
        println!("trace spans       : {n} event(s) -> {path}");
    }
    result
}

/// The coordinator tier: connect to every shard's `--shard-bind` address
/// and run cross-shard FedAvg until the cluster finishes.
fn serve_coordinator(
    cfg: ExperimentConfig,
    connect_shard: Option<String>,
    mock: bool,
    checkpoint_dir: Option<String>,
    resume: bool,
) -> Result<(), String> {
    if resume && checkpoint_dir.is_none() {
        return Err("--resume needs --checkpoint-dir".into());
    }
    if cfg.shards < 2 {
        return Err("--role coordinator needs --shards >= 2".into());
    }
    let addrs: Vec<String> = connect_shard
        .ok_or(
            "--role coordinator needs --connect-shard ADDR[,ADDR...] (one per \
             shard's --shard-bind, in shard-id order)",
        )?
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if addrs.len() != cfg.shards {
        return Err(format!(
            "--connect-shard lists {} address(es) for --shards {}",
            addrs.len(),
            cfg.shards
        ));
    }
    let kind = if mock { "mock" } else { "engine" };
    let mut conns: Vec<Box<dyn Transport>> = Vec::with_capacity(addrs.len());
    for (k, addr) in addrs.iter().enumerate() {
        println!("slacc coordinator: connecting to shard {k} at {addr}");
        conns.push(Box::new(TcpTransport::connect_retry(
            addr,
            120,
            Duration::from_millis(250),
        )?));
    }
    let mut coordinator = Coordinator::from_experiment(&cfg, kind)?;
    coordinator
        .configure_checkpoint(checkpoint_dir.map(std::path::PathBuf::from), resume);
    let mut fleet = ShardFleet::new(conns);
    let report = coordinator.run(&mut fleet)?;
    println!(
        "\n=== coordinator report ===\n\
         shards            : {}\n\
         sync epochs       : {}\n\
         shard-sync bytes  : {:.2} KB up / {:.2} KB down",
        report.shards,
        report.sync_epochs,
        report.bytes_up as f64 / 1e3,
        report.bytes_down as f64 / 1e3
    );
    if !report.cluster_counters.is_empty() {
        println!("cluster counters (summed over shard roll-ups):");
        for (name, v) in &report.cluster_counters {
            println!("  {name:<48} {v}");
        }
    }
    Ok(())
}

/// A (possibly the only) shard server: in a sharded cluster, accept the
/// coordinator on `--shard-bind` first, then the shard's device slice on
/// `--bind`.
#[allow(clippy::too_many_arguments)]
fn serve_shard(
    cfg: ExperimentConfig,
    bind: String,
    shard_id: usize,
    shard_bind: String,
    mock: bool,
    csv: Option<String>,
    obs: &ObsFlags,
    io: FleetOptions,
) -> Result<(), String> {
    let topo = cfg.topology();
    if shard_id >= topo.shards {
        return Err(format!(
            "--shard-id {shard_id} out of range (--shards {})",
            topo.shards
        ));
    }
    let link = if topo.is_sharded() {
        let shard_listener = TcpListener::bind(&shard_bind)
            .map_err(|e| format!("bind {shard_bind}: {e}"))?;
        println!(
            "slacc serve [shard {shard_id}/{}]: waiting for the coordinator on \
             {shard_bind}",
            topo.shards
        );
        let conn = TcpTransport::accept_direct(&shard_listener)?;
        let (train, _) =
            Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
        let weight = slacc::shard::shard_weight(&cfg, &train, shard_id);
        let kind = if mock { "mock" } else { "engine" };
        let session_fp = session_fingerprint(cfg.fingerprint(), kind);
        let mut link = ShardLink::handshake(
            Box::new(conn),
            &topo,
            shard_id,
            weight,
            session_fp,
            cfg.shard_link_streams(shard_id)?,
        )?;
        // keep the listener: if the coordinator dies mid-session, this
        // shard re-accepts a `--resume`d one instead of aborting
        let rebind = shard_bind.clone();
        link.set_reacquire(Box::new(move || {
            println!(
                "slacc serve [shard {shard_id}]: waiting for a resumed \
                 coordinator on {rebind}"
            );
            let conn = TcpTransport::accept_direct(&shard_listener)?;
            Ok(Box::new(conn) as Box<dyn Transport>)
        }));
        Some(link)
    } else {
        None
    };

    let listener = TcpListener::bind(&bind).map_err(|e| format!("bind {bind}: {e}"))?;
    let addr = listener.local_addr().map_err(|e| e.to_string())?;
    let local = topo.shape_for(cfg.devices, shard_id).local;
    println!(
        "slacc serve: listening on {addr}, waiting for {local} device(s) \
         [{}, schedule={}, shards={}, mock={mock}]",
        cfg.stream_specs().map(|s| s.table()).unwrap_or_default(),
        cfg.schedule.label(),
        topo.shards,
    );

    let exporter = match &obs.metrics_bind {
        Some(addr) => {
            let ex = MetricsExporter::bind(addr)?;
            println!("slacc serve: metrics exposition on http://{}/metrics", ex.local_addr());
            Some(ex)
        }
        None => None,
    };
    let snapshot = match obs.metrics_every {
        Some(every) => Some(SnapshotWriter::create(&obs.metrics_out, every)?),
        None => None,
    };

    let report = if mock {
        let (_, test) =
            Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
        let mut rt = mock_runtime_for_shard(&cfg, shard_id, Arc::new(test))?;
        if let Some(link) = link {
            rt.attach_shard_link(link);
        }
        if let Some(sw) = snapshot {
            rt.attach_snapshot_writer(sw);
        }
        accept_and_serve_opts(&mut rt, &listener, exporter, io)?
    } else {
        let mut rt = engine_runtime_for_shard(&cfg, shard_id)?;
        if let Some(link) = link {
            rt.attach_shard_link(link);
        }
        if let Some(sw) = snapshot {
            rt.attach_snapshot_writer(sw);
        }
        accept_and_serve_opts(&mut rt, &listener, exporter, io)?
    };
    print_report(&report, csv)
}

fn cmd_device(mut args: Args) -> Result<(), String> {
    let cfg = config_from_args(&mut args)?;
    let id = args.usize_or("id", usize::MAX);
    let connect = args.str_or("connect", "127.0.0.1:7878");
    let mock = args.bool_or("mock", false);
    let rejoin = args.bool_or("rejoin", false);
    let trace_out = args.str_opt("trace-out");
    args.finish()?;
    cfg.validate()?;
    if id == usize::MAX {
        return Err("--id is required (this device's slot in 0..devices)".into());
    }
    if trace_out.is_some() {
        span::set_enabled(true);
        span::set_trace_role("device", 0);
    }

    let mut conn =
        TcpTransport::connect_retry(&connect, 40, Duration::from_millis(250))?;
    let session = if use_mock(&cfg, mock)? {
        let (train, _) =
            Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
        let mut worker = mock_worker(&cfg, Arc::new(train), id)?;
        if rejoin {
            run_blocking_rejoin(&mut worker, &mut conn)
        } else {
            run_blocking(&mut worker, &mut conn)
        }
    } else {
        let mut worker = engine_worker(&cfg, id)?;
        if rejoin {
            run_blocking_rejoin(&mut worker, &mut conn)
        } else {
            run_blocking(&mut worker, &mut conn)
        }
    };
    // like serve: drain spans even when the session errored out
    if let Some(path) = &trace_out {
        let n = span::write_jsonl(path)?;
        println!("device {id}: {n} trace event(s) -> {path}");
    }
    session?;
    let stats = conn.stats();
    println!(
        "device {id}: session complete ({} frames / {} bytes sent, {} frames / {} bytes received)",
        stats.frames_sent, stats.bytes_sent, stats.frames_recv, stats.bytes_recv
    );
    Ok(())
}

fn cmd_eval(mut args: Args) -> Result<(), String> {
    let mut cfg = config_from_args(&mut args)?;
    args.finish()?;
    cfg.rounds = 1;
    let mut trainer = Trainer::new(cfg)?;
    let acc = trainer.evaluate()?;
    println!("test accuracy at init: {:.2}%", acc * 100.0);
    Ok(())
}

/// One round of real activations -> ACII/CGC diagnostics.
fn cmd_inspect(mut args: Args) -> Result<(), String> {
    let mut cfg = config_from_args(&mut args)?;
    args.finish()?;
    cfg.rounds = 1;
    cfg.eval_every = 1;
    cfg.codec = CodecChoice::Named("slacc".into());
    let mut trainer = Trainer::new(cfg)?;
    let report = trainer.run()?;
    println!("ran 1 inspection round; loss {:.4}", report.metrics.records[0].loss);
    println!("see `slacc train --log-level debug` for per-round detail, or");
    println!("`cargo run --release --example inspect_entropy` for full dumps");
    Ok(())
}

/// `slacc trace FILE...`: the offline critical-path analyzer over the
/// per-node `--trace-out` JSONL of one session.
fn cmd_trace(mut args: Args) -> Result<(), String> {
    let files = args.positionals();
    let chrome = args.str_opt("chrome");
    args.finish()?;
    if files.is_empty() {
        return Err(
            "usage: slacc trace FILE... [--chrome OUT.json] — pass every \
             node's --trace-out JSONL from one session"
                .into(),
        );
    }
    let mut nodes = Vec::with_capacity(files.len());
    for f in &files {
        nodes.push(trace::parse_file(f)?);
    }
    let analysis = trace::analyze(nodes)?;
    print!("{}", trace::summary(&analysis));
    println!();
    print!("{}", trace::render_table(&analysis));
    if let Some(out) = chrome {
        std::fs::write(&out, trace::chrome_json(&analysis).dump())
            .map_err(|e| format!("{out}: {e}"))?;
        println!(
            "\nchrome trace      : {out} (load in chrome://tracing or \
             ui.perfetto.dev)"
        );
    }
    Ok(())
}

/// Offline codec comparison (no PJRT engine).
fn cmd_codecs(mut args: Args) -> Result<(), String> {
    let seed = args.usize_or("seed", 0) as u64;
    args.finish()?;
    use slacc::tensor::Tensor;
    use slacc::util::rng::Pcg32;

    let (b, c, h, w) = (32usize, 32usize, 16usize, 16usize);
    let mut rng = Pcg32::seeded(seed);
    let data: Vec<f32> = (0..b * c * h * w)
        .map(|_| rng.next_gaussian().max(0.0))
        .collect();
    let cm = Tensor::new(vec![b, c, h, w], data).to_channel_major();
    let raw = cm.data().len() * 4;
    let orig = cm.to_nchw();

    println!("{:<16} {:>10} {:>8} {:>12}", "codec", "bytes", "ratio", "mean|err|");
    for name in codecs::ALL_CODECS {
        let mut codec = codecs::by_name(name, c, 100, seed)?;
        let wire = codec.compress(&cm, RoundCtx::default());
        let rec = codec.decode(&wire)?;
        println!(
            "{:<16} {:>10} {:>7.1}x {:>12.5}",
            name,
            wire.len(),
            raw as f64 / wire.len() as f64,
            orig.mean_abs_diff(&rec)
        );
    }
    Ok(())
}
