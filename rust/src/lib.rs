//! # slacc — SL-ACC: Communication-Efficient Split Learning with Adaptive
//! Channel-wise Compression
//!
//! Production-grade reproduction of Lin et al., *"SL-ACC: A
//! Communication-Efficient Split Learning Framework with Adaptive
//! Channel-wise Compression"* (2025) as a three-layer Rust + JAX + Pallas
//! system:
//!
//! * **L3 (this crate)** — the split-learning coordinator: device fleet,
//!   round orchestration, the SL-ACC codec (ACII + CGC) and all baseline
//!   codecs, the framed wire [`transport`] (loopback + TCP), the
//!   poll-based event-loop server and out-of-order round scheduler
//!   ([`sched`]), the network simulator, datasets, and metrics.
//! * **L2 (python/compile/model.py)** — the split GN-ResNet in JAX, AOT
//!   lowered to HLO text once at build time.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the per-round
//!   channel-entropy hot-spot and fused quantize-dequantize.
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! AOT artifacts through PJRT and the coordinator drives them from Rust.

pub mod adapt;
pub mod bench;
pub mod cli;
pub mod codecs;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod entropy;
pub mod grouping;
pub mod member;
pub mod net;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod sched;
pub mod shard;
pub mod tensor;
pub mod transport;
pub mod util;
