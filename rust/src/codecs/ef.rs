//! Error-feedback wrapper codec: wraps any inner codec with a per-stream
//! EF memory (see [`crate::quant::feedback`]). Opt-in extension — the
//! paper's benches never enable it; `ef:<codec>` in the CLI/launcher and
//! the `ext_error_feedback` test exercise it.
//!
//! Wire format is the inner codec's, unchanged: EF only alters *what* gets
//! compressed (x + carried error), so byte accounting and the server-side
//! decompression path are identical.

use crate::codecs::{Codec, CodecError, RoundCtx};
use crate::quant::feedback::ErrorFeedback;
use crate::quant::payload::ByteWriter;
use crate::tensor::{ChannelMajor, Tensor};

pub struct EfCodec {
    inner: Box<dyn Codec>,
    ef: Option<ErrorFeedback>,
    decay: f32,
    name: String,
}

impl EfCodec {
    pub fn new(inner: Box<dyn Codec>, decay: f32) -> EfCodec {
        let name = format!("ef:{}", inner.name());
        EfCodec { inner, ef: None, decay, name }
    }

    pub fn residual_norm(&self) -> f64 {
        self.ef.as_ref().map_or(0.0, |e| e.residual_norm())
    }
}

impl Codec for EfCodec {
    fn name(&self) -> &'static str {
        // `name()` returns `&'static str`, so only the common single-wrap
        // names are spelled out; every other wrapped spec (parameterized
        // bases, nested ef:) falls back to the generic label. Diagnostics
        // that need the exact spec read the stream's canonical
        // `StreamSpec` string, not `name()`.
        match self.name.as_str() {
            "ef:slacc" => "ef:slacc",
            "ef:uniform4" => "ef:uniform4",
            "ef:uniform8" => "ef:uniform8",
            "ef:powerquant" => "ef:powerquant",
            "ef:randtopk" => "ef:randtopk",
            "ef:splitfc" => "ef:splitfc",
            "ef:easyquant" => "ef:easyquant",
            _ => "ef:codec",
        }
    }

    fn encode(&mut self, data: &ChannelMajor, ctx: RoundCtx<'_>, out: &mut ByteWriter) {
        let (b, c, h, w) = data.geometry();
        let ef = self
            .ef
            .get_or_insert_with(|| ErrorFeedback::new(data.data().len(), self.decay));

        // compensate: x' = x + m
        let mut comp = data.data().to_vec();
        ef.apply(&mut comp);
        let comp_cm =
            ChannelMajor::from_rows(c, data.n_per_channel, b, h, w, comp.clone());

        // NOTE: ctx.entropy was computed on the *raw* tensor; the
        // compensated tensor differs, so recompute inside the inner codec
        // by dropping the hint (correctness > the small CPU saving).
        let start = out.len();
        self.inner.encode(&comp_cm, RoundCtx { entropy: None, kind: ctx.kind }, out);

        // absorb: m = decay * (x' - D(C(x'))) — the wire bytes we just
        // wrote are decoded in place (no interior-mutability workaround:
        // decode is &mut self since the stream-pipeline redesign)
        match self.inner.decode(&out.as_slice()[start..]) {
            Ok(rec) => {
                let rec_cm = rec.to_channel_major();
                ef.absorb(&comp, rec_cm.data());
            }
            Err(e) => {
                crate::log_warn!("ef: inner decode failed ({e}); memory frozen");
            }
        }
    }

    fn decode(&mut self, bytes: &[u8]) -> Result<Tensor, CodecError> {
        self.inner.decode(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::test_support::relu_cm;
    use crate::codecs::uniform::UniformCodec;

    #[test]
    fn wire_format_matches_inner() {
        let cm = relu_cm(2, 4, 4, 4, 1);
        let mut ef = EfCodec::new(Box::new(UniformCodec::new(2)), 1.0);
        let wire = ef.compress(&cm, RoundCtx::default());
        // decompressable by a bare inner codec (format unchanged)
        let mut bare = UniformCodec::new(2);
        assert!(bare.decode(&wire).is_ok());
    }

    #[test]
    fn first_round_equals_inner_exactly() {
        let cm = relu_cm(2, 4, 4, 4, 2);
        let mut with_ef = EfCodec::new(Box::new(UniformCodec::new(3)), 1.0);
        let mut bare = UniformCodec::new(3);
        use crate::codecs::Codec as _;
        assert_eq!(
            with_ef.compress(&cm, RoundCtx::default()),
            bare.compress(&cm, RoundCtx::default())
        );
    }

    #[test]
    fn time_average_beats_bare_quantizer() {
        // repeated compression of the same tensor: with EF the mean of the
        // reconstructions approaches the truth; bare 2-bit quantization has
        // a fixed bias.
        let cm = relu_cm(2, 4, 4, 4, 3);
        let truth = cm.to_nchw();
        let rounds = 48;

        let mut bare = UniformCodec::new(2);
        use crate::codecs::Codec as _;
        let bare_wire = bare.compress(&cm, RoundCtx::default());
        let bare_rec = bare.decode(&bare_wire).unwrap();
        let bare_err = truth.mean_abs_diff(&bare_rec);

        let mut ef = EfCodec::new(Box::new(UniformCodec::new(2)), 1.0);
        let mut sum = vec![0.0f64; truth.len()];
        for _ in 0..rounds {
            let wire = ef.compress(&cm, RoundCtx::default());
            let rec = ef.decode(&wire).unwrap();
            for (s, &v) in sum.iter_mut().zip(rec.data()) {
                *s += v as f64;
            }
        }
        let avg: Vec<f32> = sum.iter().map(|&s| (s / rounds as f64) as f32).collect();
        let avg_t = Tensor::new(truth.dims().to_vec(), avg);
        let ef_err = truth.mean_abs_diff(&avg_t);
        assert!(
            ef_err < bare_err / 2.0,
            "EF avg err {ef_err:.5} vs bare {bare_err:.5}"
        );
    }

    #[test]
    fn residual_diagnostic_bounded() {
        let mut ef = EfCodec::new(Box::new(UniformCodec::new(2)), 1.0);
        for seed in 0..20 {
            let cm = relu_cm(2, 4, 4, 4, seed);
            let _ = ef.compress(&cm, RoundCtx::default());
        }
        assert!(ef.residual_norm().is_finite());
        assert!(ef.residual_norm() < 100.0);
    }
}
