//! EasyQuant baseline (Tang et al., EMNLP 2023, as used in the paper's
//! Fig. 7 CGC ablation).
//!
//! Data-free-style per-channel quantization with two EasyQuant signatures:
//! (1) the clip range is *optimized* per channel (grid search shrinking the
//! range to minimize reconstruction MSE rather than using raw min/max), and
//! (2) outliers beyond the clip range are transmitted exactly (index +
//! value) so they do not stretch the quantization grid. Bit width is fixed
//! for all channels — uniform allocation, the property CGC replaces.

use crate::codecs::{ids, Codec, CodecError, RoundCtx};
use crate::quant::{bitpack, linear};
use crate::quant::payload::{ByteReader, ByteWriter, Header};
use crate::tensor::{view, ChannelMajor, Tensor};

/// Candidate clip shrink factors (fraction of the full half-range kept).
const CLIP_GRID: &[f32] = &[1.0, 0.9, 0.8, 0.7, 0.6, 0.5];

#[derive(Debug)]
pub struct EasyQuantCodec {
    bits: u32,
    /// reusable quantization scratch (encode hot path)
    codes: Vec<u32>,
    packed: Vec<u8>,
}

impl EasyQuantCodec {
    pub fn new(bits: u32) -> Self {
        assert!((2..=16).contains(&bits));
        EasyQuantCodec { bits, codes: Vec::new(), packed: Vec::new() }
    }

    /// Pick the clip factor minimizing reconstruction MSE for one channel.
    ///
    /// The search must model exactly what `compress` will do: at most
    /// `cap = max(N/100, 1)` outliers are transmitted exactly (scanning in
    /// element order); any further out-of-range values get clamped into the
    /// grid and pay the full clipping error.
    fn best_clip(row: &[f32], mn: f32, mx: f32, bits: u32) -> f32 {
        let mid = 0.5 * (mn + mx);
        let half = 0.5 * (mx - mn);
        if half <= 0.0 {
            return 1.0;
        }
        let cap = (row.len() / 100).max(1);
        let mut best = 1.0f32;
        let mut best_mse = f64::INFINITY;
        for &f in CLIP_GRID {
            let (cmn, cmx) = (mid - half * f, mid + half * f);
            let mut mse = 0.0f64;
            let mut n_out = 0usize;
            for &x in row {
                let exact_outlier = (x < cmn || x > cmx) && n_out < cap;
                if x < cmn || x > cmx {
                    n_out += 1;
                }
                if exact_outlier {
                    continue; // transmitted exactly, zero error
                }
                // scalar fake-quant inline (same numerics as linear::fake_quant,
                // without the per-element Vec allocations — this loop runs
                // |CLIP_GRID| x N times per channel)
                let levels = ((1u32 << bits) - 1) as f32;
                let rng = cmx - cmn;
                let y = if rng <= linear::EPS {
                    cmn
                } else {
                    let t = (x.clamp(cmn, cmx) - cmn) * (levels / rng);
                    let code = (t + 0.5).floor().min(levels);
                    cmn + code * (rng / levels)
                };
                let d = (x - y) as f64;
                mse += d * d;
            }
            // tie-break: prefer the wider range (fewer outlier bytes)
            let cost_penalty = n_out.min(cap) as f64 * 1e-9;
            if mse + cost_penalty < best_mse {
                best_mse = mse + cost_penalty;
                best = f;
            }
        }
        best
    }
}

impl Codec for EasyQuantCodec {
    fn name(&self) -> &'static str {
        "easyquant"
    }

    fn encode(&mut self, data: &ChannelMajor, _ctx: RoundCtx<'_>, out: &mut ByteWriter) {
        let (b, c, h, w) = data.geometry();
        let n = data.n_per_channel;
        out.reserve(Header::BYTES + 1 + c * (12 + bitpack::packed_len(n, self.bits)));
        Header { codec_id: ids::EASYQUANT, dims: [b as u32, c as u32, h as u32, w as u32] }
            .write(out);
        out.u8(self.bits as u8);

        for ch in 0..c {
            let row = data.channel(ch);
            let (mn, mx) = view::min_max(row);
            let f = Self::best_clip(row, mn, mx, self.bits);
            let mid = 0.5 * (mn + mx);
            let half = 0.5 * (mx - mn);
            let (cmn, cmx) = (mid - half * f, mid + half * f);

            // outliers: exact (index, value) pairs, capped at 1% of N; if
            // more would overflow the cap they are clamped into the grid.
            let cap = (n / 100).max(1);
            let mut outliers: Vec<(u32, f32)> = Vec::new();
            for (i, &x) in row.iter().enumerate() {
                if (x < cmn || x > cmx) && outliers.len() < cap {
                    outliers.push((i as u32, x));
                }
            }
            out.f32(cmn);
            out.f32(cmx);
            out.u32(outliers.len() as u32);
            for &(i, v) in &outliers {
                out.u32(i);
                out.f32(v);
            }
            linear::quantize(row, cmn, cmx, self.bits, &mut self.codes);
            bitpack::pack_into(&self.codes, self.bits, &mut self.packed);
            out.bytes(&self.packed);
        }
    }

    fn decode(&mut self, bytes: &[u8]) -> Result<Tensor, CodecError> {
        let mut r = ByteReader::new(bytes);
        let header = Header::read(&mut r)?;
        if header.codec_id != ids::EASYQUANT {
            return Err(CodecError::WrongCodec {
                expected: "easyquant",
                found: header.codec_id,
            });
        }
        let [b, c, h, w] = header.dims.map(|d| d as usize);
        let n = header.n_per_channel();
        let bits = r.u8()? as u32;
        if !(2..=16).contains(&bits) {
            return Err(CodecError::Malformed(format!("bad bit width {bits}")));
        }
        let mut rows = vec![0.0f32; c * n];
        let mut vals = Vec::new();
        for ch in 0..c {
            let cmn = r.f32()?;
            let cmx = r.f32()?;
            let n_out = r.u32()? as usize;
            if n_out > n {
                return Err(CodecError::LimitExceeded {
                    what: "easyquant outlier count",
                    claimed: n_out,
                    cap: n,
                });
            }
            let mut outliers = Vec::with_capacity(n_out);
            for _ in 0..n_out {
                let i = r.u32()? as usize;
                if i >= n {
                    return Err(CodecError::Malformed(format!(
                        "outlier index {i} out of range"
                    )));
                }
                outliers.push((i, r.f32()?));
            }
            let packed = r.bytes(bitpack::packed_len(n, bits))?;
            let codes = bitpack::unpack(packed, bits, n);
            linear::dequantize(&codes, cmn, cmx, bits, &mut vals);
            let dst = &mut rows[ch * n..(ch + 1) * n];
            dst.copy_from_slice(&vals);
            for (i, v) in outliers {
                dst[i] = v;
            }
        }
        r.expect_end()?;
        Ok(ChannelMajor::from_rows(c, n, b, h, w, rows).to_nchw())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::test_support::random_cm;
    use crate::tensor::Tensor;

    #[test]
    fn roundtrip_error_bounded() {
        let cm = random_cm(2, 8, 4, 4, 1);
        let mut c = EasyQuantCodec::new(6);
        let wire = c.compress(&cm, RoundCtx::default());
        let out = c.decode(&wire).unwrap();
        let orig = cm.to_nchw();
        assert!(orig.mean_abs_diff(&out) < 0.1);
    }

    #[test]
    fn outliers_transmitted_exactly() {
        // one huge spike per channel; clip search shrinks the range, the
        // spike must come back exact.
        let n = 100;
        let mut data = vec![0.1f32; 2 * n];
        // add mild noise so range isn't flat
        for (i, v) in data.iter_mut().enumerate() {
            *v += (i % 7) as f32 * 0.01;
        }
        data[5] = 50.0; // channel 0 outlier
        data[n + 9] = -40.0; // channel 1 outlier
        let cm = Tensor::new(vec![1, 2, 10, 10], data.clone()).to_channel_major();
        let mut c = EasyQuantCodec::new(4);
        let wire = c.compress(&cm, RoundCtx::default());
        let out = c.decode(&wire).unwrap();
        let rec = out.to_channel_major();
        assert_eq!(rec.channel(0)[5], 50.0);
        assert_eq!(rec.channel(1)[9], -40.0);
        // and the bulk is finely quantized despite the spike
        let bulk_err: f32 = rec.channel(0)[20..40]
            .iter()
            .zip(&cm.channel(0)[20..40])
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(bulk_err < 0.5, "bulk err {bulk_err}");
    }

    #[test]
    fn clip_factor_search_is_stable_on_uniformish_data() {
        let row: Vec<f32> = (0..1000).map(|i| i as f32 / 999.0).collect();
        let f = EasyQuantCodec::best_clip(&row, 0.0, 1.0, 8);
        // uniform data: no benefit from clipping
        assert_eq!(f, 1.0);
    }
}
