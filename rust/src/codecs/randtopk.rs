//! RandTopk-SL baseline (Zheng et al., IJCAI 2023, adapted to SL as in the
//! paper's Sec. III-A3).
//!
//! Randomized top-k sparsification: keep the ρ_k fraction of elements with
//! the largest magnitude, plus a uniformly random ρ_r fraction of the
//! remaining elements scaled by 1/p (p = the sampling probability) so the
//! sparsified tensor is an unbiased estimate of the dense one. The wire
//! carries (index u32, value f32) pairs — the classic sparse format, whose
//! 8-byte-per-kept-element cost is what quantization-based schemes beat.

use crate::codecs::{ids, Codec, CodecError, RoundCtx};
use crate::quant::payload::{ByteReader, ByteWriter, Header};
use crate::tensor::{ChannelMajor, Tensor};
use crate::util::rng::Pcg32;

#[derive(Debug)]
pub struct RandTopkCodec {
    /// fraction of elements kept by magnitude
    top_frac: f64,
    /// fraction of *all* elements additionally sampled from the non-top set
    rand_frac: f64,
    rng: Pcg32,
}

impl RandTopkCodec {
    pub fn new(top_frac: f64, rand_frac: f64, seed: u64) -> Self {
        assert!(top_frac > 0.0 && top_frac <= 1.0);
        assert!(rand_frac >= 0.0 && rand_frac < 1.0);
        RandTopkCodec { top_frac, rand_frac, rng: Pcg32::new(seed, 0x70b0) }
    }
}

impl Codec for RandTopkCodec {
    fn name(&self) -> &'static str {
        "randtopk"
    }

    fn encode(&mut self, data: &ChannelMajor, _ctx: RoundCtx<'_>, out: &mut ByteWriter) {
        let (b, c, h, w) = data.geometry();
        let flat = data.data();
        let total = flat.len();
        let k = ((total as f64 * self.top_frac).ceil() as usize).clamp(1, total);

        // top-k by |x|: select_nth on an index array (O(n) average)
        let mut idx: Vec<u32> = (0..total as u32).collect();
        if k < total {
            idx.select_nth_unstable_by(k - 1, |&a, &b| {
                flat[b as usize]
                    .abs()
                    .partial_cmp(&flat[a as usize].abs())
                    .unwrap()
            });
        }
        let (top, rest) = idx.split_at(k.min(total));

        // random subset of the non-top elements, unbiased 1/p scaling
        let n_rand = ((total as f64 * self.rand_frac).round() as usize).min(rest.len());
        let p = if rest.is_empty() {
            1.0
        } else {
            n_rand as f64 / rest.len() as f64
        };
        let mut rest_owned = rest.to_vec();
        // partial shuffle: first n_rand entries are a uniform sample
        for i in 0..n_rand {
            let j = i + self.rng.below((rest_owned.len() - i) as u32) as usize;
            rest_owned.swap(i, j);
        }

        out.reserve(Header::BYTES + 12 + (k + n_rand) * 8);
        Header { codec_id: ids::RANDTOPK, dims: [b as u32, c as u32, h as u32, w as u32] }
            .write(out);
        // total element count, redundantly: the sparse body's length does
        // not otherwise depend on the header dims, so without this binding
        // a corrupted header could silently re-shape the tensor
        out.u32(total as u32);
        out.u32(k as u32);
        out.u32(n_rand as u32);
        for &i in top {
            out.u32(i);
            out.f32(flat[i as usize]);
        }
        let scale = if p > 0.0 { (1.0 / p) as f32 } else { 0.0 };
        for &i in &rest_owned[..n_rand] {
            out.u32(i);
            out.f32(flat[i as usize] * scale);
        }
    }

    fn decode(&mut self, bytes: &[u8]) -> Result<Tensor, CodecError> {
        let mut r = ByteReader::new(bytes);
        let header = Header::read(&mut r)?;
        if header.codec_id != ids::RANDTOPK {
            return Err(CodecError::WrongCodec {
                expected: "randtopk",
                found: header.codec_id,
            });
        }
        let [b, c, h, w] = header.dims.map(|d| d as usize);
        let n = header.n_per_channel();
        let total = c * n;
        let body_total = r.u32()? as usize;
        if body_total != total {
            return Err(CodecError::Malformed(format!(
                "body claims {body_total} elements, header dims give {total}"
            )));
        }
        let k = r.u32()? as usize;
        let n_rand = r.u32()? as usize;
        if k + n_rand > total {
            return Err(CodecError::LimitExceeded {
                what: "randtopk kept elements",
                claimed: k + n_rand,
                cap: total,
            });
        }
        let mut rows = vec![0.0f32; total];
        for _ in 0..k + n_rand {
            let i = r.u32()? as usize;
            if i >= total {
                return Err(CodecError::Malformed(format!("index {i} out of range")));
            }
            rows[i] = r.f32()?;
        }
        r.expect_end()?;
        Ok(ChannelMajor::from_rows(c, n, b, h, w, rows).to_nchw())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::test_support::random_cm;

    #[test]
    fn top_elements_survive_exactly() {
        let cm = random_cm(2, 4, 4, 4, 1);
        let mut c = RandTopkCodec::new(0.25, 0.0, 7);
        let wire = c.compress(&cm, RoundCtx::default());
        let out = c.decode(&wire).unwrap();
        let orig = cm.to_nchw();
        let rec_cm = out.to_channel_major();

        // threshold = k-th largest |x|
        let mut mags: Vec<f32> = cm.data().iter().map(|x| x.abs()).collect();
        mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let k = (cm.data().len() as f64 * 0.25).ceil() as usize;
        let thresh = mags[k - 1];

        let orig_cm = orig.to_channel_major();
        for ch in 0..4 {
            for (a, b) in orig_cm.channel(ch).iter().zip(rec_cm.channel(ch)) {
                if a.abs() > thresh {
                    assert_eq!(a, b, "top element must be exact");
                }
            }
        }
    }

    #[test]
    fn sparsity_structure() {
        let cm = random_cm(2, 8, 4, 4, 2);
        let mut c = RandTopkCodec::new(0.1, 0.0, 7);
        let wire = c.compress(&cm, RoundCtx::default());
        let out = c.decode(&wire).unwrap();
        let nonzero = out.data().iter().filter(|&&x| x != 0.0).count();
        let k = (cm.data().len() as f64 * 0.1).ceil() as usize;
        assert!(nonzero <= k);
    }

    #[test]
    fn random_subset_is_rescaled() {
        // with top_frac tiny and rand_frac = 0.5, surviving non-top values
        // must be ~2x their originals (p = 0.5 over the rest)
        let cm = random_cm(1, 2, 4, 4, 3);
        let mut c = RandTopkCodec::new(1.0 / 32.0, 0.5, 9);
        let wire = c.compress(&cm, RoundCtx::default());
        let out = c.decode(&wire).unwrap();
        let orig = cm.to_nchw();
        let mut checked = 0;
        for (a, b) in orig.data().iter().zip(out.data()) {
            if *b != 0.0 && (b / a - 1.0).abs() > 1e-4 {
                // rescaled element: ratio should be 1/p = rest/n_rand
                let ratio = b / a;
                assert!(ratio > 1.5 && ratio < 2.6, "ratio {ratio}");
                checked += 1;
            }
        }
        assert!(checked > 0, "no rescaled elements found");
    }

    #[test]
    fn deterministic_given_seed() {
        let cm = random_cm(1, 4, 4, 4, 4);
        let w1 = RandTopkCodec::new(0.2, 0.1, 5).compress(&cm, RoundCtx::default());
        let w2 = RandTopkCodec::new(0.2, 0.1, 5).compress(&cm, RoundCtx::default());
        assert_eq!(w1, w2);
    }

    #[test]
    fn wire_size_formula() {
        let cm = random_cm(2, 4, 4, 4, 5);
        let total = cm.data().len();
        let mut c = RandTopkCodec::new(0.1, 0.05, 6);
        let wire = c.compress(&cm, RoundCtx::default());
        let k = (total as f64 * 0.1).ceil() as usize;
        let nr = (total as f64 * 0.05).round() as usize;
        assert_eq!(wire.len(), Header::BYTES + 12 + (k + nr) * 8);
    }
}
