//! PowerQuant-SL baseline (Yvinec et al., ICLR 2023, adapted to SL as in
//! the paper's Sec. III-A3).
//!
//! PowerQuant replaces uniform quantization with a power-law automorphism:
//! values are normalized to v ∈ [0, 1] per channel, companded u = v^a, and
//! u is uniformly quantized at a fixed bit width. The exponent `a` is found
//! by automorphism *search*: a grid over a ∈ [0.25, 3] minimizing the
//! per-tensor reconstruction MSE each round. One exponent per tensor, one
//! (min, max) pair per channel, fixed bits for all channels — i.e. uniform
//! bit allocation, which is exactly the property SL-ACC's CGC improves on.

use crate::codecs::{ids, Codec, CodecError, RoundCtx};
use crate::quant::bitpack;
use crate::quant::payload::{ByteReader, ByteWriter, Header};
use crate::tensor::{view, ChannelMajor, Tensor};

const EXP_GRID: &[f32] = &[
    0.25, 0.35, 0.5, 0.65, 0.8, 1.0, 1.25, 1.5, 1.75, 2.0, 2.5, 3.0,
];
const EPS: f32 = 1e-8;

#[derive(Debug)]
pub struct PowerQuantCodec {
    bits: u32,
    /// reusable quantization scratch (encode hot path)
    codes: Vec<u32>,
    packed: Vec<u8>,
}

impl PowerQuantCodec {
    pub fn new(bits: u32) -> Self {
        assert!((2..=16).contains(&bits));
        PowerQuantCodec { bits, codes: Vec::new(), packed: Vec::new() }
    }

    /// Companded quantize one channel at exponent `a`; returns codes.
    fn quantize_channel(row: &[f32], mn: f32, mx: f32, a: f32, levels: f32,
                        out: &mut Vec<u32>) {
        out.clear();
        let rng = (mx - mn).max(EPS);
        for &x in row {
            let v = ((x - mn) / rng).clamp(0.0, 1.0);
            let u = v.powf(a);
            out.push(((u * levels + 0.5).floor() as u32).min(levels as u32));
        }
    }

    fn dequantize_channel(codes: &[u32], mn: f32, mx: f32, a: f32, levels: f32,
                          out: &mut Vec<f32>) {
        out.clear();
        let rng = mx - mn;
        for &cde in codes {
            let u = cde as f32 / levels;
            let v = u.powf(1.0 / a);
            out.push(mn + v * rng);
        }
    }

    /// MSE of quantizing the whole tensor at exponent `a` (search objective),
    /// estimated on a strided sample for speed.
    fn mse_at(data: &ChannelMajor, ranges: &[(f32, f32)], a: f32, levels: f32) -> f64 {
        let stride = (data.n_per_channel / 64).max(1);
        let mut err = 0.0f64;
        let mut count = 0usize;
        for ch in 0..data.channels {
            let (mn, mx) = ranges[ch];
            let rng = (mx - mn).max(EPS);
            let row = data.channel(ch);
            let mut i = 0;
            while i < row.len() {
                let x = row[i];
                let v = ((x - mn) / rng).clamp(0.0, 1.0);
                let u = v.powf(a);
                let code = (u * levels + 0.5).floor().min(levels);
                let xh = mn + (code / levels).powf(1.0 / a) * rng;
                let d = (x - xh) as f64;
                err += d * d;
                count += 1;
                i += stride;
            }
        }
        err / count.max(1) as f64
    }
}

impl Codec for PowerQuantCodec {
    fn name(&self) -> &'static str {
        "powerquant"
    }

    fn encode(&mut self, data: &ChannelMajor, _ctx: RoundCtx<'_>, out: &mut ByteWriter) {
        let (b, c, h, w) = data.geometry();
        let n = data.n_per_channel;
        let levels = ((1u32 << self.bits) - 1) as f32;

        let ranges: Vec<(f32, f32)> =
            (0..c).map(|ch| view::min_max(data.channel(ch))).collect();

        // automorphism search: best exponent on this round's tensor
        let mut best_a = 1.0f32;
        let mut best_mse = f64::INFINITY;
        for &a in EXP_GRID {
            let m = Self::mse_at(data, &ranges, a, levels);
            if m < best_mse {
                best_mse = m;
                best_a = a;
            }
        }

        out.reserve(Header::BYTES + 5 + c * (8 + bitpack::packed_len(n, self.bits)));
        Header { codec_id: ids::POWERQUANT, dims: [b as u32, c as u32, h as u32, w as u32] }
            .write(out);
        out.u8(self.bits as u8);
        out.f32(best_a);
        for ch in 0..c {
            let (mn, mx) = ranges[ch];
            out.f32(mn);
            out.f32(mx);
            Self::quantize_channel(data.channel(ch), mn, mx, best_a, levels, &mut self.codes);
            bitpack::pack_into(&self.codes, self.bits, &mut self.packed);
            out.bytes(&self.packed);
        }
    }

    fn decode(&mut self, bytes: &[u8]) -> Result<Tensor, CodecError> {
        let mut r = ByteReader::new(bytes);
        let header = Header::read(&mut r)?;
        if header.codec_id != ids::POWERQUANT {
            return Err(CodecError::WrongCodec {
                expected: "powerquant",
                found: header.codec_id,
            });
        }
        let [b, c, h, w] = header.dims.map(|d| d as usize);
        let n = header.n_per_channel();
        let bits = r.u8()? as u32;
        if !(2..=16).contains(&bits) {
            return Err(CodecError::Malformed(format!("bad bit width {bits}")));
        }
        let a = r.f32()?;
        if !(a.is_finite() && a > 0.0) {
            return Err(CodecError::Malformed(format!("bad exponent {a}")));
        }
        let levels = ((1u32 << bits) - 1) as f32;
        let mut rows = vec![0.0f32; c * n];
        let mut vals = Vec::new();
        for ch in 0..c {
            let mn = r.f32()?;
            let mx = r.f32()?;
            let packed = r.bytes(bitpack::packed_len(n, bits))?;
            let codes = bitpack::unpack(packed, bits, n);
            Self::dequantize_channel(&codes, mn, mx, a, levels, &mut vals);
            rows[ch * n..(ch + 1) * n].copy_from_slice(&vals);
        }
        r.expect_end()?;
        Ok(ChannelMajor::from_rows(c, n, b, h, w, rows).to_nchw())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::test_support::{random_cm, relu_cm};

    #[test]
    fn roundtrip_reasonable_error() {
        let cm = relu_cm(2, 8, 4, 4, 1);
        let mut c = PowerQuantCodec::new(4);
        let wire = c.compress(&cm, RoundCtx::default());
        let out = c.decode(&wire).unwrap();
        let orig = cm.to_nchw();
        // 4-bit companded quantization: error well under the value range
        let (mn, mx) = view::min_max(orig.data());
        assert!(orig.mean_abs_diff(&out) < ((mx - mn) as f64) / 8.0);
    }

    #[test]
    fn identity_exponent_matches_linear() {
        // with a=1 the compander is linear; exponent search may pick
        // something else, so test the primitive directly
        let row = [0.0f32, 0.25, 0.5, 0.75, 1.0];
        let mut codes = Vec::new();
        PowerQuantCodec::quantize_channel(&row, 0.0, 1.0, 1.0, 15.0, &mut codes);
        let mut lin = Vec::new();
        crate::quant::linear::quantize(&row, 0.0, 1.0, 4, &mut lin);
        assert_eq!(codes, lin);
    }

    #[test]
    fn skewed_data_prefers_nonunit_exponent() {
        // heavily skewed (relu-like, mass near zero) data should pick a != 1
        // ... or at least not hurt: companded MSE <= linear MSE on the grid.
        let cm = relu_cm(4, 8, 8, 8, 2);
        let ranges: Vec<(f32, f32)> =
            (0..8).map(|ch| view::min_max(cm.channel(ch))).collect();
        let m1 = PowerQuantCodec::mse_at(&cm, &ranges, 1.0, 15.0);
        let best = EXP_GRID
            .iter()
            .map(|&a| PowerQuantCodec::mse_at(&cm, &ranges, a, 15.0))
            .fold(f64::INFINITY, f64::min);
        assert!(best <= m1 * (1.0 + 1e-9));
    }

    #[test]
    fn wire_size_matches_bits() {
        let cm = random_cm(2, 4, 4, 4, 3);
        let n = cm.n_per_channel;
        let mut c = PowerQuantCodec::new(4);
        let wire = c.compress(&cm, RoundCtx::default());
        assert_eq!(wire.len(), Header::BYTES + 5 + 4 * (8 + n / 2));
    }

    #[test]
    fn monotone_codes() {
        // companding is monotone: larger x -> larger (or equal) code
        let row: Vec<f32> = (0..100).map(|i| i as f32 / 99.0).collect();
        for &a in EXP_GRID {
            let mut codes = Vec::new();
            PowerQuantCodec::quantize_channel(&row, 0.0, 1.0, a, 15.0, &mut codes);
            for w in codes.windows(2) {
                assert!(w[0] <= w[1], "a={a}");
            }
        }
    }
}
