//! Identity codec: raw f32 transmission (the uncompressed-SL reference).
//!
//! This is what vanilla split learning sends; every compression curve in
//! the benches is normalized against its byte count.

use crate::codecs::{ids, Codec, CodecError, RoundCtx};
use crate::quant::payload::{ByteReader, ByteWriter, Header};
use crate::tensor::{ChannelMajor, Tensor};

#[derive(Debug, Default)]
pub struct IdentityCodec;

impl IdentityCodec {
    pub fn new() -> Self {
        IdentityCodec
    }
}

impl Codec for IdentityCodec {
    fn name(&self) -> &'static str {
        "identity"
    }

    fn encode(&mut self, data: &ChannelMajor, _ctx: RoundCtx<'_>, out: &mut ByteWriter) {
        let (b, c, h, w) = data.geometry();
        out.reserve(Header::BYTES + data.data().len() * 4);
        Header { codec_id: ids::IDENTITY, dims: [b as u32, c as u32, h as u32, w as u32] }
            .write(out);
        out.f32s(data.data());
    }

    fn decode(&mut self, bytes: &[u8]) -> Result<Tensor, CodecError> {
        let mut r = ByteReader::new(bytes);
        let header = Header::read(&mut r)?;
        if header.codec_id != ids::IDENTITY {
            return Err(CodecError::WrongCodec {
                expected: "identity",
                found: header.codec_id,
            });
        }
        let [b, c, h, w] = header.dims.map(|d| d as usize);
        let n = header.n_per_channel();
        let rows = r.f32s(c * n)?;
        r.expect_end()?;
        Ok(ChannelMajor::from_rows(c, n, b, h, w, rows).to_nchw())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::test_support::random_cm;

    #[test]
    fn lossless_roundtrip() {
        let cm = random_cm(2, 4, 3, 3, 1);
        let mut c = IdentityCodec::new();
        let wire = c.compress(&cm, RoundCtx::default());
        let out = c.decode(&wire).unwrap();
        assert_eq!(out, cm.to_nchw());
    }

    #[test]
    fn wire_size_is_raw_plus_header() {
        let cm = random_cm(2, 4, 3, 3, 2);
        let mut c = IdentityCodec::new();
        let wire = c.compress(&cm, RoundCtx::default());
        assert_eq!(wire.len(), Header::BYTES + 2 * 4 * 3 * 3 * 4);
    }
}
