//! SL-ACC codec: ACII + CGC — the paper's contribution (Sec. II).
//!
//! Per round:
//! 1. **ACII** — instantaneous per-channel entropy H_c^(t) (Eq. 1, from the
//!    AOT Pallas kernel when the coordinator provides it, host mirror
//!    otherwise) blended with the k-round historical mean H̃_c via
//!    α^(t) = t/T (Eqs. 2–3).
//! 2. **CGC** — 1-D K-means over the blended entropies into g groups
//!    (Eq. 4); per-group mean entropy H̃_j (Eq. 5); per-group bit width
//!    (Eq. 6); per-group min/max linear quantization with
//!    round-half-away-from-zero (Eq. 7); bit-packed wire payload.
//!
//! ## Eq. 6 degeneracy and the `BitAlloc` knob
//!
//! Eq. 6 sets b_j = clamp(⌊H̃_j⌋, b_min, b_max) with H in nats. For smashed
//! data with N = B·H·W elements per channel, the softmax entropy lives in
//! roughly [ln N − 1, ln N]; at the paper's own scale (N ≳ 10⁵) ⌊H̃_j⌋
//! saturates b_max for every group and the allocation degenerates to
//! uniform 8-bit. We implement Eq. 6 verbatim ([`BitAlloc::FloorEntropy`],
//! exposed as codec `slacc-paper-eq6`) and default to the intent-preserving
//! [`BitAlloc::MinMaxScaled`]: affinely map the group entropies' observed
//! range onto [b_min, b_max], so higher-entropy groups still get more bits
//! (the paper's stated goal) at every tensor size. The fig7 ablation bench
//! quantifies the difference.

use crate::grouping::{kmeans_1d, Clustering};
use crate::codecs::{ids, Codec, CodecError, RoundCtx};
use crate::entropy::{shannon, Acii, AlphaSchedule};
use crate::quant::bitpack;
use crate::quant::linear;
use crate::quant::payload::{ByteReader, ByteWriter, Header};
use crate::tensor::{view, ChannelMajor, Tensor};
use crate::util::rng::Pcg32;

/// Bit-width allocation rule (Eq. 6 and its non-degenerate variant).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BitAlloc {
    /// Paper Eq. 6 verbatim: b_j = clamp(⌊H̃_j⌋, b_min, b_max).
    FloorEntropy,
    /// b_j = b_min + round((H̃_j − min_j H̃)/(max_j H̃ − min_j H̃) · (b_max − b_min));
    /// midpoint when all groups tie. Default.
    MinMaxScaled,
}

#[derive(Debug, Clone, Copy)]
pub struct SlAccConfig {
    /// g of Eq. 4: number of channel groups.
    pub groups: usize,
    /// k of Eq. 2: historical entropy window (rounds).
    pub history_window: usize,
    /// Quantization bit-width bounds of Eq. 6.
    pub b_min: u32,
    pub b_max: u32,
    pub bit_alloc: BitAlloc,
    /// α^(t) policy (Eq. 3; `Fixed` variants drive the Fig. 4 ablation).
    pub alpha: AlphaSchedule,
}

impl Default for SlAccConfig {
    fn default() -> Self {
        SlAccConfig {
            groups: 4,
            history_window: 5,
            b_min: 2,
            b_max: 8,
            bit_alloc: BitAlloc::MinMaxScaled,
            alpha: AlphaSchedule::Adaptive,
        }
    }
}

/// Diagnostics from the most recent `compress` call (ablation benches and
/// the `inspect-entropy` example read these).
#[derive(Debug, Clone, Default)]
pub struct LastRound {
    pub blended_entropy: Vec<f32>,
    pub group_of_channel: Vec<usize>,
    pub group_entropy: Vec<f32>,
    pub group_bits: Vec<u32>,
    pub avg_bits_per_element: f64,
}

pub struct SlAccCodec {
    cfg: SlAccConfig,
    acii: Acii,
    rng: Pcg32,
    last: Option<LastRound>,
    /// reusable per-channel quantization scratch (encode hot path)
    codes: Vec<u32>,
    packed: Vec<u8>,
    /// reusable instantaneous-entropy buffer (ACII input, Eq. 1) — filled
    /// by `shannon::entropies_into` (host fallback) or copied from the
    /// kernel output, no allocation once warmed
    inst: Vec<f32>,
}

impl SlAccCodec {
    pub fn new(cfg: SlAccConfig, channels: usize, total_rounds: usize, seed: u64) -> Self {
        assert!(cfg.b_min >= 1 && cfg.b_max <= 16 && cfg.b_min <= cfg.b_max);
        assert!(cfg.groups >= 1);
        SlAccCodec {
            cfg,
            acii: Acii::new(channels, cfg.history_window, total_rounds, cfg.alpha),
            rng: Pcg32::new(seed, 0x51acc),
            last: None,
            codes: Vec::new(),
            packed: Vec::new(),
            inst: Vec::new(),
        }
    }

    pub fn config(&self) -> &SlAccConfig {
        &self.cfg
    }

    pub fn last_round(&self) -> Option<&LastRound> {
        self.last.as_ref()
    }

    /// Eq. 6 / variant: per-group bit widths from group mean entropies.
    fn allocate_bits(&self, group_entropy: &[f32]) -> Vec<u32> {
        let (bmin, bmax) = (self.cfg.b_min, self.cfg.b_max);
        match self.cfg.bit_alloc {
            BitAlloc::FloorEntropy => group_entropy
                .iter()
                .map(|&h| (h.max(0.0).floor() as u32).clamp(bmin, bmax))
                .collect(),
            BitAlloc::MinMaxScaled => {
                let mn = group_entropy.iter().cloned().fold(f32::INFINITY, f32::min);
                let mx = group_entropy.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                if (mx - mn) < 1e-6 {
                    let mid = (bmin + bmax).div_ceil(2);
                    return vec![mid; group_entropy.len()];
                }
                group_entropy
                    .iter()
                    .map(|&h| {
                        let t = (h - mn) / (mx - mn);
                        bmin + (t * (bmax - bmin) as f32).round() as u32
                    })
                    .collect()
            }
        }
    }
}

impl Codec for SlAccCodec {
    fn name(&self) -> &'static str {
        match self.cfg.bit_alloc {
            BitAlloc::FloorEntropy => "slacc-paper-eq6",
            BitAlloc::MinMaxScaled => "slacc",
        }
    }

    fn encode(&mut self, data: &ChannelMajor, ctx: RoundCtx<'_>, out: &mut ByteWriter) {
        let c = data.channels;
        assert_eq!(c, self.acii.channels(), "codec built for different C");

        // --- ACII: blended channel importance (Eqs. 1-3) ---
        match ctx.entropy {
            Some(h) => {
                self.inst.clear();
                self.inst.extend_from_slice(h);
            }
            None => shannon::entropies_into(data, &mut self.inst),
        }
        if let Some(kind) = ctx.kind {
            super::stream::record_entropy(kind, &self.inst);
        }
        let blended = self.acii.update(&self.inst);

        // --- CGC: group by entropy (Eq. 4), bits per group (Eqs. 5-6) ---
        let clustering: Clustering = kmeans_1d(&blended, self.cfg.groups, &mut self.rng);
        let members = clustering.members();
        // Eq. 5: group mean entropy == cluster centroid by construction.
        let group_entropy: Vec<f32> = clustering.centroids.clone();
        let group_bits = self.allocate_bits(&group_entropy);

        // --- serialize (Eq. 7 per group) ---
        let (b, _, h, w) = data.geometry();
        out.reserve(Header::BYTES + 2 + members.len() * 16 + c * data.n_per_channel);
        Header { codec_id: ids::SLACC, dims: [b as u32, c as u32, h as u32, w as u32] }
            .write(out);
        out.u16(members.len() as u16);

        let mut total_bits = 0u64;
        for (j, chans) in members.iter().enumerate() {
            // group-wide quantization boundaries x_{j,min/max} (Eq. 7)
            let mut gmin = f32::INFINITY;
            let mut gmax = f32::NEG_INFINITY;
            for &ch in chans {
                let (mn, mx) = view::min_max(data.channel(ch));
                gmin = gmin.min(mn);
                gmax = gmax.max(mx);
            }
            let bits = group_bits[j];
            out.u8(bits as u8);
            out.u16(chans.len() as u16);
            out.f32(gmin);
            out.f32(gmax);
            for &ch in chans {
                out.u16(ch as u16);
            }
            for &ch in chans {
                linear::quantize(data.channel(ch), gmin, gmax, bits, &mut self.codes);
                bitpack::pack_into(&self.codes, bits, &mut self.packed);
                out.bytes(&self.packed);
                total_bits += (self.codes.len() as u64) * bits as u64;
            }
        }

        self.last = Some(LastRound {
            blended_entropy: blended,
            group_of_channel: clustering.assignment,
            group_entropy,
            group_bits,
            avg_bits_per_element: total_bits as f64 / (c * data.n_per_channel) as f64,
        });
    }

    fn decode(&mut self, bytes: &[u8]) -> Result<Tensor, CodecError> {
        let mut r = ByteReader::new(bytes);
        let header = Header::read(&mut r)?;
        if header.codec_id != ids::SLACC {
            return Err(CodecError::WrongCodec { expected: "SL-ACC", found: header.codec_id });
        }
        let [b, c, h, w] = header.dims.map(|d| d as usize);
        let n = header.n_per_channel();
        let n_groups = r.u16()? as usize;

        let mut rows = vec![0.0f32; c * n];
        let mut seen = vec![false; c];
        let mut vals = Vec::new();
        for _ in 0..n_groups {
            let bits = r.u8()? as u32;
            if !(1..=16).contains(&bits) {
                return Err(CodecError::Malformed(format!("bad group bit width {bits}")));
            }
            let n_chans = r.u16()? as usize;
            let gmin = r.f32()?;
            let gmax = r.f32()?;
            let mut chans = Vec::with_capacity(n_chans);
            for _ in 0..n_chans {
                let ch = r.u16()? as usize;
                if ch >= c {
                    return Err(CodecError::Malformed(format!(
                        "channel id {ch} out of range (C={c})"
                    )));
                }
                chans.push(ch);
            }
            for &ch in &chans {
                let packed = r.bytes(bitpack::packed_len(n, bits))?;
                let codes = bitpack::unpack(packed, bits, n);
                linear::dequantize(&codes, gmin, gmax, bits, &mut vals);
                rows[ch * n..(ch + 1) * n].copy_from_slice(&vals);
                seen[ch] = true;
            }
        }
        if let Some(missing) = seen.iter().position(|&s| !s) {
            return Err(CodecError::Malformed(format!("payload missing channel {missing}")));
        }
        r.expect_end()?;
        Ok(ChannelMajor::from_rows(c, n, b, h, w, rows).to_nchw())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::test_support::{random_cm, relu_cm};

    fn codec(channels: usize) -> SlAccCodec {
        SlAccCodec::new(SlAccConfig::default(), channels, 100, 42)
    }

    #[test]
    fn roundtrip_reconstructs_within_quant_error() {
        let cm = random_cm(2, 8, 4, 4, 1);
        let mut c = codec(8);
        let wire = c.compress(&cm, RoundCtx::default());
        let out = c.decode(&wire).unwrap();
        let orig = cm.to_nchw();
        // worst-case group: b_min=2 bits over the group's min/max range
        let (mn, mx) = view::min_max(orig.data());
        let bound = (mx - mn) / 3.0; // step at 2 bits
        for (a, b) in orig.data().iter().zip(out.data()) {
            assert!((a - b).abs() <= bound + 1e-5);
        }
    }

    #[test]
    fn eight_bit_group_high_fidelity() {
        // single group => every channel gets the same bits (midpoint = 5);
        // with b_min=b_max=8 reconstruction error is tiny.
        let cfg = SlAccConfig { groups: 1, b_min: 8, b_max: 8, ..Default::default() };
        let cm = relu_cm(2, 4, 4, 4, 2);
        let mut c = SlAccCodec::new(cfg, 4, 100, 1);
        let wire = c.compress(&cm, RoundCtx::default());
        let out = c.decode(&wire).unwrap();
        let orig = cm.to_nchw();
        assert!(orig.mean_abs_diff(&out) < 0.02);
    }

    #[test]
    fn respects_bit_bounds() {
        let cm = random_cm(2, 16, 4, 4, 3);
        let mut c = codec(16);
        let _ = c.compress(&cm, RoundCtx::default());
        let last = c.last_round().unwrap();
        for &b in &last.group_bits {
            assert!((2..=8).contains(&b), "bits {b} out of [2,8]");
        }
        assert!(last.avg_bits_per_element >= 2.0 - 1e-9);
        assert!(last.avg_bits_per_element <= 8.0 + 1e-9);
    }

    #[test]
    fn external_entropy_is_used() {
        // Feed a synthetic entropy vector that forces a specific grouping:
        // channels 0..4 low, 4..8 high. Groups=2 must split exactly there.
        let cm = random_cm(2, 8, 4, 4, 4);
        let ent = [1.0f32, 1.1, 0.9, 1.05, 6.0, 6.1, 5.9, 6.05];
        let cfg = SlAccConfig { groups: 2, ..Default::default() };
        let mut c = SlAccCodec::new(cfg, 8, 100, 5);
        let _ = c.compress(&cm, RoundCtx { entropy: Some(&ent), kind: None });
        let last = c.last_round().unwrap();
        let g0 = last.group_of_channel[0];
        for ch in 0..4 {
            assert_eq!(last.group_of_channel[ch], g0);
        }
        for ch in 4..8 {
            assert_ne!(last.group_of_channel[ch], g0);
        }
        // higher-entropy group gets at least as many bits (MinMaxScaled)
        let g_hi = last.group_of_channel[4];
        assert!(last.group_bits[g_hi] >= last.group_bits[g0]);
        assert_eq!(last.group_bits[g_hi], 8);
        assert_eq!(last.group_bits[g0], 2);
    }

    #[test]
    fn floor_entropy_matches_eq6() {
        let cm = random_cm(2, 4, 4, 4, 6);
        let ent = [3.7f32, 3.7, 3.7, 3.7];
        let cfg = SlAccConfig {
            groups: 1,
            bit_alloc: BitAlloc::FloorEntropy,
            ..Default::default()
        };
        let mut c = SlAccCodec::new(cfg, 4, 100, 7);
        let _ = c.compress(&cm, RoundCtx { entropy: Some(&ent), kind: None });
        assert_eq!(c.last_round().unwrap().group_bits, vec![3]); // floor(3.7)
    }

    #[test]
    fn floor_entropy_clamps() {
        let cm = random_cm(1, 2, 2, 2, 7);
        let cfg = SlAccConfig {
            groups: 2,
            bit_alloc: BitAlloc::FloorEntropy,
            ..Default::default()
        };
        let mut c = SlAccCodec::new(cfg, 2, 100, 7);
        let _ = c.compress(&cm, RoundCtx { entropy: Some(&[0.5, 20.0]), kind: None });
        assert_eq!(c.last_round().unwrap().group_bits, vec![2, 8]);
    }

    #[test]
    fn history_changes_grouping_over_rounds() {
        // With Fixed(1.0) alpha the codec uses pure history; feeding very
        // different inst entropies each round must still give stable groups.
        let cm = random_cm(2, 4, 4, 4, 8);
        let cfg = SlAccConfig {
            alpha: AlphaSchedule::Fixed(1.0),
            groups: 2,
            ..Default::default()
        };
        let mut c = SlAccCodec::new(cfg, 4, 100, 9);
        let _ = c.compress(&cm, RoundCtx { entropy: Some(&[1.0, 1.0, 9.0, 9.0]), kind: None });
        // round 2: wildly different inst entropy, but history dominates
        let _ = c.compress(&cm, RoundCtx { entropy: Some(&[9.0, 9.0, 1.0, 1.0]), kind: None });
        let last = c.last_round().unwrap();
        assert_eq!(last.group_of_channel[0], last.group_of_channel[1]);
        assert_eq!(last.group_of_channel[2], last.group_of_channel[3]);
        assert_ne!(last.group_of_channel[0], last.group_of_channel[2]);
        // blended followed history (round-1 values), not the new inst
        assert!(last.blended_entropy[2] > last.blended_entropy[0]);
    }

    #[test]
    fn wire_smaller_than_raw() {
        let cm = random_cm(4, 32, 8, 8, 9);
        let mut c = codec(32);
        let wire = c.compress(&cm, RoundCtx::default());
        assert!(wire.len() < 32 * cm.n_per_channel * 4);
    }

    #[test]
    fn truncated_payload_is_error() {
        let cm = random_cm(2, 4, 4, 4, 10);
        let mut c = codec(4);
        let wire = c.compress(&cm, RoundCtx::default());
        for cut in [3usize, Header::BYTES, wire.len() - 1] {
            assert!(c.decode(&wire[..cut]).is_err(), "cut at {cut}");
        }
    }
}
