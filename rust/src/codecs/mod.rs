//! Smashed-data compression codecs, organized as **stream pipelines**.
//!
//! A split-learning session moves three kinds of traffic, each of which
//! wants its own compressor (the point of per-channel-adaptive schemes —
//! activations, gradients, and parameters have different statistics):
//!
//! * **uplink** — device → server activations (the paper's main axis),
//! * **downlink** — server → device cut-layer gradients,
//! * **sync** — ModelSync / FedAvg parameter traffic.
//!
//! The surface has three layers:
//!
//! * [`Codec`] — one stateful compressor/decompressor instance. The hot
//!   path is [`Codec::encode`], which writes the wire envelope into a
//!   caller-owned reusable [`ByteWriter`] (zero steady-state allocation
//!   for the quantizing codecs — `benches/codecs.rs` measures it), and
//!   [`Codec::decode`], whose `&mut self` lets stateful wrappers (error
//!   feedback) update without interior-mutability workarounds. Failures
//!   are the typed [`CodecError`], never a panic: envelopes come off the
//!   network.
//! * [`registry::CodecRegistry`] — the single construction path. Every
//!   codec family registers a spec grammar (`"slacc"`, `"uniform8"`,
//!   `"select:acii:2"`, `"ef:"` wrappers); [`registry::CodecRegistry::parse`]
//!   validates a spec string and [`registry::CodecRegistry::build`]
//!   instantiates it for one stream. [`by_name`] is a thin convenience
//!   wrapper over the registry with default SL-ACC parameters.
//! * [`stream`] — the session-level stream model: [`stream::StreamKind`]
//!   names the three streams, [`stream::StreamSpecs`] is the negotiated
//!   per-stream spec table the Hello handshake fingerprints and compares,
//!   and [`stream::StreamSet`] owns every per-device, per-direction codec
//!   instance (including the stream-seed derivation, so stochastic codecs
//!   differ per device and direction).
//!
//! The paper's contribution ([`slacc::SlAccCodec`], ACII + CGC) plus every
//! baseline its evaluation compares against:
//!
//! | spec | paper role |
//! |---|---|
//! | `slacc` / `slacc-paper-eq6` | SL-ACC (Fig. 5–7) |
//! | `powerquant` | PowerQuant-SL (Fig. 5, 7) |
//! | `randtopk` | RandTopk-SL (Fig. 5) |
//! | `splitfc` | SplitFC (Fig. 5) |
//! | `easyquant` | EasyQuant (Fig. 7) |
//! | `uniform<bits>` | fixed-bit ablation substrate |
//! | `identity` | uncompressed SL reference |
//! | `select:<strategy>:<n>` | single/subset-channel ablations (Fig. 2, 3, 6) |
//! | `ef:<spec>` | error-feedback wrapper (extension) |
//!
//! Codecs are stateful across rounds (ACII history, RNG streams, EF
//! memory), so each device-direction stream owns its own instance.

pub mod easyquant;
pub mod ef;
pub mod identity;
pub mod powerquant;
pub mod randtopk;
pub mod registry;
pub mod selection;
pub mod slacc;
pub mod splitfc;
pub mod stream;
pub mod uniform;

use crate::quant::payload::ByteWriter;
use crate::tensor::{ChannelMajor, Tensor};

/// Stable codec ids for the wire header.
pub mod ids {
    pub const IDENTITY: u8 = 0;
    pub const UNIFORM: u8 = 1;
    pub const SLACC: u8 = 2;
    pub const POWERQUANT: u8 = 3;
    pub const RANDTOPK: u8 = 4;
    pub const SPLITFC: u8 = 5;
    pub const EASYQUANT: u8 = 6;
    pub const SELECTION: u8 = 7;
}

/// What went wrong while decoding an envelope or resolving a stream spec.
/// Decoders are exposed to the network, so every failure is a value, never
/// a panic, and every hostile length claim is rejected *before* the
/// allocation it would have demanded.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodecError {
    /// The byte stream ended before a field could be read.
    Truncated { need: usize, have: usize, at: usize },
    /// Structurally invalid bytes: bad magic/version, out-of-range ids,
    /// fields that disagree with each other, trailing garbage.
    Malformed(String),
    /// A length field claims more than a hard guard allows
    /// ([`crate::quant::payload::MAX_ELEMENTS`] and friends).
    LimitExceeded { what: &'static str, claimed: usize, cap: usize },
    /// The envelope belongs to a different codec family than this stream
    /// negotiated.
    WrongCodec { expected: &'static str, found: u8 },
    /// A stream spec string failed to parse or resolve in the registry.
    UnknownSpec(String),
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::Truncated { need, have, at } => write!(
                f,
                "payload truncated: need {need} bytes at offset {at}, have {have}"
            ),
            CodecError::Malformed(m) => write!(f, "malformed payload: {m}"),
            CodecError::LimitExceeded { what, claimed, cap } => {
                write!(f, "{what} claims {claimed} (cap {cap})")
            }
            CodecError::WrongCodec { expected, found } => {
                write!(f, "not a {expected} payload (codec id {found})")
            }
            CodecError::UnknownSpec(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for CodecError {}

impl From<CodecError> for String {
    fn from(e: CodecError) -> String {
        e.to_string()
    }
}

/// Per-round side information handed to `encode`.
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundCtx<'a> {
    /// Instantaneous per-channel entropy, if the coordinator already ran the
    /// AOT Pallas kernel on this tensor. Codecs that need entropy fall back
    /// to the host mirror when `None`.
    pub entropy: Option<&'a [f32]>,
    /// Which session stream this encode serves, when the call site knows
    /// (device uplink, server downlink). The entropy-path codecs feed the
    /// per-stream channel-entropy drift gauges from it
    /// ([`stream::record_entropy`]); `None` records nothing.
    pub kind: Option<stream::StreamKind>,
}

/// A smashed-data compressor/decompressor.
pub trait Codec: Send {
    /// Short stable name for logs/benches/CSV.
    fn name(&self) -> &'static str;

    /// Compress one round's smashed data, appending the wire envelope to
    /// `out`. The buffer is caller-owned and reusable: callers `clear()`
    /// it between rounds and its capacity persists, so the steady-state
    /// encode path of the quantizing codecs performs no allocation
    /// (internal scratch lives on the codec instance).
    fn encode(&mut self, data: &ChannelMajor, ctx: RoundCtx<'_>, out: &mut ByteWriter);

    /// Reconstruct the NCHW tensor from wire bytes. `&mut self` so
    /// stateful wrappers (error feedback) can fold decode-side state
    /// without interior mutability.
    fn decode(&mut self, bytes: &[u8]) -> Result<Tensor, CodecError>;

    /// Encode into a fresh, exactly-consumed buffer — the path for
    /// producing an owned frame payload (one allocation, no copy).
    /// Callers that can reuse a buffer across rounds call
    /// [`Codec::encode`] directly.
    fn compress(&mut self, data: &ChannelMajor, ctx: RoundCtx<'_>) -> Vec<u8> {
        let mut out = ByteWriter::new();
        self.encode(data, ctx, &mut out);
        out.finish()
    }
}

/// Compression ratio helper: raw f32 bytes / wire bytes.
pub fn compression_ratio(data: &ChannelMajor, wire_len: usize) -> f64 {
    let raw = data.channels * data.n_per_channel * 4;
    raw as f64 / wire_len.max(1) as f64
}

/// Convenience factory: build a codec by spec string with default SL-ACC
/// parameters. `seed` namespaces stochastic codecs, `total_rounds` feeds
/// ACII's α schedule. Thin wrapper over [`registry::CodecRegistry`] — the
/// registry is the single construction path; sessions go through
/// [`stream::StreamSet`], which also derives per-stream seeds.
pub fn by_name(
    name: &str,
    channels: usize,
    total_rounds: usize,
    seed: u64,
) -> Result<Box<dyn Codec>, CodecError> {
    let reg = registry::CodecRegistry::standard();
    let spec = reg.parse(name)?;
    reg.build(
        &spec,
        &registry::StreamCtx {
            channels,
            total_rounds,
            seed,
            slacc: slacc::SlAccConfig::default(),
            alpha: None,
        },
    )
}

/// Base spec names the registry accepts (for CLI help / sweep benches).
/// Parameterized families (`uniform<bits>`, `select:...`, `ef:`) accept
/// more — see [`registry::CodecRegistry::grammar`].
pub const ALL_CODECS: &[&str] = &[
    "identity", "uniform4", "uniform8", "slacc", "slacc-paper-eq6",
    "powerquant", "randtopk", "splitfc", "easyquant",
];

#[cfg(test)]
pub(crate) mod test_support {
    use crate::tensor::{ChannelMajor, Tensor};
    use crate::util::rng::Pcg32;

    /// Random NCHW smashed data in channel-major form.
    pub fn random_cm(b: usize, c: usize, h: usize, w: usize, seed: u64) -> ChannelMajor {
        let mut rng = Pcg32::seeded(seed);
        let data: Vec<f32> = (0..b * c * h * w)
            .map(|_| rng.next_gaussian() * rng.range_f32(0.5, 2.0))
            .collect();
        Tensor::new(vec![b, c, h, w], data).to_channel_major()
    }

    /// ReLU-like (non-negative, sparse-ish) activations.
    pub fn relu_cm(b: usize, c: usize, h: usize, w: usize, seed: u64) -> ChannelMajor {
        let mut rng = Pcg32::seeded(seed);
        let data: Vec<f32> = (0..b * c * h * w)
            .map(|_| rng.next_gaussian().max(0.0))
            .collect();
        Tensor::new(vec![b, c, h, w], data).to_channel_major()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use test_support::random_cm;

    #[test]
    fn factory_builds_every_listed_codec() {
        for name in ALL_CODECS {
            let c = by_name(name, 8, 100, 7).unwrap_or_else(|e| panic!("{e}"));
            assert!(!c.name().is_empty());
        }
        assert!(by_name("bogus", 8, 100, 7).is_err());
    }

    #[test]
    fn every_codec_roundtrips_shape() {
        let cm = random_cm(2, 8, 4, 4, 1);
        for name in ALL_CODECS {
            let mut c = by_name(name, 8, 100, 7).unwrap();
            let wire = c.compress(&cm, RoundCtx::default());
            let out = c.decode(&wire).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(out.dims(), &[2, 8, 4, 4], "codec {name}");
        }
    }

    #[test]
    fn encode_into_reused_buffer_matches_compress() {
        // the reusable-buffer path and the convenience path must produce
        // identical envelopes, and a warmed buffer must be reusable
        let cm = random_cm(2, 8, 4, 4, 5);
        for name in ALL_CODECS {
            let mut a = by_name(name, 8, 100, 7).unwrap();
            let mut b = by_name(name, 8, 100, 7).unwrap();
            let mut buf = crate::quant::payload::ByteWriter::new();
            for round in 0..3 {
                let wire = a.compress(&cm, RoundCtx::default());
                buf.clear();
                b.encode(&cm, RoundCtx::default(), &mut buf);
                assert_eq!(wire, buf.as_slice(), "{name} round {round}");
            }
        }
    }

    #[test]
    fn lossy_codecs_actually_compress() {
        let cm = random_cm(4, 16, 8, 8, 2);
        let raw = cm.channels * cm.n_per_channel * 4;
        for name in ["slacc", "powerquant", "randtopk", "splitfc", "easyquant", "uniform4"] {
            let mut c = by_name(name, 16, 100, 7).unwrap();
            let wire = c.compress(&cm, RoundCtx::default());
            assert!(
                wire.len() < raw,
                "{name}: wire {} >= raw {raw}",
                wire.len()
            );
        }
    }

    #[test]
    fn decode_rejects_garbage_for_every_codec() {
        // the systematic prefix/bit-flip fuzz lives in
        // tests/integration_codecs.rs; this pins the cheap invariants
        let cm = random_cm(2, 8, 4, 4, 3);
        for name in ALL_CODECS {
            let mut c = by_name(name, 8, 100, 7).unwrap();
            assert!(c.decode(&[1, 2, 3]).is_err(), "{name}");
            assert!(c.decode(&[]).is_err(), "{name}");
            // an envelope with trailing garbage disagrees with its header
            let mut wire = c.compress(&cm, RoundCtx::default());
            wire.push(0);
            assert!(c.decode(&wire).is_err(), "{name}: trailing byte accepted");
        }
    }
}
