//! Smashed-data compression codecs.
//!
//! The paper's contribution ([`slacc::SlAccCodec`], ACII + CGC) plus every
//! baseline its evaluation compares against:
//!
//! | codec | paper role |
//! |---|---|
//! | [`slacc::SlAccCodec`] | SL-ACC (Fig. 5–7) |
//! | [`powerquant::PowerQuantCodec`] | PowerQuant-SL (Fig. 5, 7) |
//! | [`randtopk::RandTopkCodec`] | RandTopk-SL (Fig. 5) |
//! | [`splitfc::SplitFcCodec`] | SplitFC (Fig. 5) |
//! | [`easyquant::EasyQuantCodec`] | EasyQuant (Fig. 7) |
//! | [`uniform::UniformCodec`] | fixed-bit ablation substrate |
//! | [`identity::IdentityCodec`] | uncompressed SL reference |
//! | [`selection::SelectionCodec`] | single/subset-channel ablations (Fig. 2, 3, 6) |
//!
//! A codec maps channel-major smashed data to wire bytes and back. Codecs
//! are stateful across rounds (ACII history, RNG streams), so each
//! device-direction stream owns its own instance.

pub mod easyquant;
pub mod ef;
pub mod identity;
pub mod powerquant;
pub mod randtopk;
pub mod selection;
pub mod slacc;
pub mod splitfc;
pub mod uniform;

use crate::tensor::{ChannelMajor, Tensor};

/// Stable codec ids for the wire header.
pub mod ids {
    pub const IDENTITY: u8 = 0;
    pub const UNIFORM: u8 = 1;
    pub const SLACC: u8 = 2;
    pub const POWERQUANT: u8 = 3;
    pub const RANDTOPK: u8 = 4;
    pub const SPLITFC: u8 = 5;
    pub const EASYQUANT: u8 = 6;
    pub const SELECTION: u8 = 7;
}

/// Per-round side information handed to `compress`.
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundCtx<'a> {
    /// Instantaneous per-channel entropy, if the coordinator already ran the
    /// AOT Pallas kernel on this tensor. Codecs that need entropy fall back
    /// to the host mirror when `None`.
    pub entropy: Option<&'a [f32]>,
}

/// A smashed-data compressor/decompressor.
pub trait Codec: Send {
    /// Short stable name for logs/benches/CSV.
    fn name(&self) -> &'static str;

    /// Compress one round's smashed data into wire bytes.
    fn compress(&mut self, data: &ChannelMajor, ctx: RoundCtx<'_>) -> Vec<u8>;

    /// Reconstruct the NCHW tensor from wire bytes.
    fn decompress(&self, bytes: &[u8]) -> Result<Tensor, String>;
}

/// Compression ratio helper: raw f32 bytes / wire bytes.
pub fn compression_ratio(data: &ChannelMajor, wire_len: usize) -> f64 {
    let raw = data.channels * data.n_per_channel * 4;
    raw as f64 / wire_len.max(1) as f64
}

/// Factory: build a codec by CLI name. `seed` namespaces stochastic codecs,
/// `total_rounds` feeds ACII's α schedule.
pub fn by_name(name: &str, channels: usize, total_rounds: usize, seed: u64)
               -> Result<Box<dyn Codec>, String> {
    // `ef:<codec>` wraps any codec with error-feedback (extension; see ef.rs)
    if let Some(inner) = name.strip_prefix("ef:") {
        let base = by_name(inner, channels, total_rounds, seed)?;
        return Ok(Box::new(ef::EfCodec::new(base, 1.0)));
    }
    let c: Box<dyn Codec> = match name {
        "identity" | "none" => Box::new(identity::IdentityCodec::new()),
        "uniform4" => Box::new(uniform::UniformCodec::new(4)),
        "uniform8" => Box::new(uniform::UniformCodec::new(8)),
        "slacc" => Box::new(slacc::SlAccCodec::new(
            slacc::SlAccConfig::default(), channels, total_rounds, seed)),
        "slacc-paper-eq6" => {
            let cfg = slacc::SlAccConfig {
                bit_alloc: slacc::BitAlloc::FloorEntropy,
                ..slacc::SlAccConfig::default()
            };
            Box::new(slacc::SlAccCodec::new(cfg, channels, total_rounds, seed))
        }
        "powerquant" => Box::new(powerquant::PowerQuantCodec::new(4)),
        "randtopk" => Box::new(randtopk::RandTopkCodec::new(0.1, 0.01, seed)),
        "splitfc" => Box::new(splitfc::SplitFcCodec::new(0.5, 6)),
        "easyquant" => Box::new(easyquant::EasyQuantCodec::new(4)),
        _ => return Err(format!("unknown codec '{name}'")),
    };
    Ok(c)
}

/// All codec names `by_name` accepts (for CLI help / sweep benches).
pub const ALL_CODECS: &[&str] = &[
    "identity", "uniform4", "uniform8", "slacc", "slacc-paper-eq6",
    "powerquant", "randtopk", "splitfc", "easyquant",
];

#[cfg(test)]
pub(crate) mod test_support {
    use crate::tensor::{ChannelMajor, Tensor};
    use crate::util::rng::Pcg32;

    /// Random NCHW smashed data in channel-major form.
    pub fn random_cm(b: usize, c: usize, h: usize, w: usize, seed: u64) -> ChannelMajor {
        let mut rng = Pcg32::seeded(seed);
        let data: Vec<f32> = (0..b * c * h * w)
            .map(|_| rng.next_gaussian() * rng.range_f32(0.5, 2.0))
            .collect();
        Tensor::new(vec![b, c, h, w], data).to_channel_major()
    }

    /// ReLU-like (non-negative, sparse-ish) activations.
    pub fn relu_cm(b: usize, c: usize, h: usize, w: usize, seed: u64) -> ChannelMajor {
        let mut rng = Pcg32::seeded(seed);
        let data: Vec<f32> = (0..b * c * h * w)
            .map(|_| rng.next_gaussian().max(0.0))
            .collect();
        Tensor::new(vec![b, c, h, w], data).to_channel_major()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use test_support::random_cm;

    #[test]
    fn factory_builds_every_listed_codec() {
        for name in ALL_CODECS {
            let c = by_name(name, 8, 100, 7).unwrap_or_else(|e| panic!("{e}"));
            assert!(!c.name().is_empty());
        }
        assert!(by_name("bogus", 8, 100, 7).is_err());
    }

    #[test]
    fn every_codec_roundtrips_shape() {
        let cm = random_cm(2, 8, 4, 4, 1);
        for name in ALL_CODECS {
            let mut c = by_name(name, 8, 100, 7).unwrap();
            let wire = c.compress(&cm, RoundCtx::default());
            let out = c.decompress(&wire).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(out.dims(), &[2, 8, 4, 4], "codec {name}");
        }
    }

    #[test]
    fn lossy_codecs_actually_compress() {
        let cm = random_cm(4, 16, 8, 8, 2);
        let raw = cm.channels * cm.n_per_channel * 4;
        for name in ["slacc", "powerquant", "randtopk", "splitfc", "easyquant", "uniform4"] {
            let mut c = by_name(name, 16, 100, 7).unwrap();
            let wire = c.compress(&cm, RoundCtx::default());
            assert!(
                wire.len() < raw,
                "{name}: wire {} >= raw {raw}",
                wire.len()
            );
        }
    }

    #[test]
    fn decompress_rejects_garbage() {
        let c = by_name("slacc", 8, 100, 7).unwrap();
        assert!(c.decompress(&[1, 2, 3]).is_err());
        assert!(c.decompress(&[]).is_err());
    }
}
