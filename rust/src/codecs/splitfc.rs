//! SplitFC baseline (Oh et al., TNNLS 2025, as described in the paper's
//! Sec. III-A3): standard-deviation-based feature dropping + quantization.
//!
//! Per round: rank channels by their standard deviation, keep the top
//! `keep_frac` fraction, and uniformly quantize the kept channels at a
//! fixed bit width. Dropped channels are reconstructed from their
//! transmitted mean (one f32 each) — the cheapest compensation that keeps
//! the server-side GroupNorm statistics finite. The paper's critique —
//! "sensitive to noise and often discards low-variance yet informative
//! channels" — is exactly what the Fig. 5/6 benches surface.

use crate::codecs::{ids, Codec, CodecError, RoundCtx};
use crate::quant::{bitpack, linear};
use crate::quant::payload::{ByteReader, ByteWriter, Header};
use crate::tensor::{view, ChannelMajor, Tensor};

#[derive(Debug)]
pub struct SplitFcCodec {
    keep_frac: f64,
    bits: u32,
    /// reusable quantization scratch (encode hot path)
    codes: Vec<u32>,
    packed: Vec<u8>,
}

impl SplitFcCodec {
    pub fn new(keep_frac: f64, bits: u32) -> Self {
        assert!(keep_frac > 0.0 && keep_frac <= 1.0);
        assert!((2..=16).contains(&bits));
        SplitFcCodec { keep_frac, bits, codes: Vec::new(), packed: Vec::new() }
    }
}

impl Codec for SplitFcCodec {
    fn name(&self) -> &'static str {
        "splitfc"
    }

    fn encode(&mut self, data: &ChannelMajor, _ctx: RoundCtx<'_>, out: &mut ByteWriter) {
        let (b, c, h, w) = data.geometry();
        let n = data.n_per_channel;
        let n_keep = ((c as f64 * self.keep_frac).ceil() as usize).clamp(1, c);

        // rank channels by std (descending)
        let stats: Vec<(f32, f32)> = (0..c).map(|ch| view::mean_std(data.channel(ch))).collect();
        let mut order: Vec<usize> = (0..c).collect();
        order.sort_by(|&a, &b| stats[b].1.partial_cmp(&stats[a].1).unwrap());
        let mut kept = order[..n_keep].to_vec();
        kept.sort_unstable(); // canonical order on the wire
        let dropped: Vec<usize> = (0..c).filter(|ch| !kept.contains(ch)).collect();

        out.reserve(
            Header::BYTES + 5 + c * 2 + dropped.len() * 4
                + n_keep * (8 + bitpack::packed_len(n, self.bits)),
        );
        Header { codec_id: ids::SPLITFC, dims: [b as u32, c as u32, h as u32, w as u32] }
            .write(out);
        out.u8(self.bits as u8);
        out.u16(kept.len() as u16);
        for &ch in &kept {
            out.u16(ch as u16);
        }
        // dropped channels: transmit mean only
        for &ch in &dropped {
            out.f32(stats[ch].0);
        }
        for &ch in &kept {
            let row = data.channel(ch);
            let (mn, mx) = view::min_max(row);
            out.f32(mn);
            out.f32(mx);
            linear::quantize(row, mn, mx, self.bits, &mut self.codes);
            bitpack::pack_into(&self.codes, self.bits, &mut self.packed);
            out.bytes(&self.packed);
        }
    }

    fn decode(&mut self, bytes: &[u8]) -> Result<Tensor, CodecError> {
        let mut r = ByteReader::new(bytes);
        let header = Header::read(&mut r)?;
        if header.codec_id != ids::SPLITFC {
            return Err(CodecError::WrongCodec {
                expected: "splitfc",
                found: header.codec_id,
            });
        }
        let [b, c, h, w] = header.dims.map(|d| d as usize);
        let n = header.n_per_channel();
        let bits = r.u8()? as u32;
        if !(2..=16).contains(&bits) {
            return Err(CodecError::Malformed(format!("bad bit width {bits}")));
        }
        let n_keep = r.u16()? as usize;
        if n_keep > c {
            return Err(CodecError::LimitExceeded {
                what: "splitfc kept channels",
                claimed: n_keep,
                cap: c,
            });
        }
        let mut kept = Vec::with_capacity(n_keep);
        let mut is_kept = vec![false; c];
        for _ in 0..n_keep {
            let ch = r.u16()? as usize;
            if ch >= c {
                return Err(CodecError::Malformed(format!("channel {ch} out of range")));
            }
            kept.push(ch);
            is_kept[ch] = true;
        }
        let dropped: Vec<usize> = (0..c).filter(|&ch| !is_kept[ch]).collect();

        let mut rows = vec![0.0f32; c * n];
        for &ch in &dropped {
            let mean = r.f32()?;
            rows[ch * n..(ch + 1) * n].fill(mean);
        }
        let mut vals = Vec::new();
        for &ch in &kept {
            let mn = r.f32()?;
            let mx = r.f32()?;
            let packed = r.bytes(bitpack::packed_len(n, bits))?;
            let codes = bitpack::unpack(packed, bits, n);
            linear::dequantize(&codes, mn, mx, bits, &mut vals);
            rows[ch * n..(ch + 1) * n].copy_from_slice(&vals);
        }
        r.expect_end()?;
        Ok(ChannelMajor::from_rows(c, n, b, h, w, rows).to_nchw())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::rng::Pcg32;

    /// Data where channel std is strictly increasing with channel index.
    fn graded_cm(b: usize, c: usize, hw: usize) -> ChannelMajor {
        let mut rng = Pcg32::seeded(11);
        let mut data = vec![0.0f32; b * c * hw * hw];
        for bi in 0..b {
            for ch in 0..c {
                let scale = 0.1 + ch as f32;
                for i in 0..hw * hw {
                    data[(bi * c + ch) * hw * hw + i] = rng.next_gaussian() * scale;
                }
            }
        }
        Tensor::new(vec![b, c, hw, hw], data).to_channel_major()
    }

    #[test]
    fn keeps_high_std_channels() {
        let cm = graded_cm(2, 8, 4);
        let mut c = SplitFcCodec::new(0.5, 8);
        let wire = c.compress(&cm, RoundCtx::default());
        let out = c.decode(&wire).unwrap();
        let rec = out.to_channel_major();
        // high-std channels (4..8) must be near-exact (8-bit quant)
        for ch in 4..8 {
            let row = cm.channel(ch);
            let (mn, mx) = view::min_max(row);
            let bound = linear::max_error(mn, mx, 8) + 1e-5;
            for (a, b) in row.iter().zip(rec.channel(ch)) {
                assert!((a - b).abs() <= bound, "kept channel {ch}");
            }
        }
        // dropped channels (0..4) are constant = their mean
        for ch in 0..4 {
            let rec_row = rec.channel(ch);
            assert!(rec_row.iter().all(|&x| x == rec_row[0]), "dropped {ch}");
            let (mean, _) = view::mean_std(cm.channel(ch));
            assert!((rec_row[0] - mean).abs() < 1e-5);
        }
    }

    #[test]
    fn keep_all_equals_uniform_quant() {
        let cm = graded_cm(1, 4, 4);
        let mut sfc = SplitFcCodec::new(1.0, 6);
        let mut uni = crate::codecs::uniform::UniformCodec::new(6);
        let a = sfc.compress(&cm, RoundCtx::default());
        let b = uni.compress(&cm, RoundCtx::default());
        let ta = sfc.decode(&a).unwrap();
        let tb = uni.decode(&b).unwrap();
        assert_eq!(ta.data(), tb.data());
    }

    #[test]
    fn wire_smaller_with_lower_keep() {
        let cm = graded_cm(2, 16, 4);
        let w25 = SplitFcCodec::new(0.25, 6).compress(&cm, RoundCtx::default());
        let w100 = SplitFcCodec::new(1.0, 6).compress(&cm, RoundCtx::default());
        assert!(w25.len() < w100.len() / 2);
    }
}
