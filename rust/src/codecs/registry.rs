//! The codec registry: the **single construction path** for every codec.
//!
//! Each codec family registers one [`Entry`] owning its slice of the spec
//! grammar (parse) and its instantiation (build). [`CodecRegistry::parse`]
//! resolves a spec string like `"ef:slacc"`, `"uniform8"`, or
//! `"select:acii:2"` into a validated [`StreamSpec`];
//! [`CodecRegistry::build`] turns a spec into a live [`Codec`] for one
//! stream, parameterized by [`StreamCtx`] (channels, rounds, the stream
//! seed, and the SL-ACC/α overrides that used to be special-cased in
//! `config::build_codec`).
//!
//! Adding a codec (or a stream layer — a cipher, a shard coordinator hop)
//! means adding one entry here; `config.rs`, the CLI, and the Hello
//! handshake pick it up through the grammar with no further plumbing.

use super::slacc::{BitAlloc, SlAccConfig};
use super::stream::{BaseSpec, StreamSpec};
use super::selection::Selection;
use super::{easyquant, ef, identity, powerquant, randtopk, selection, slacc, splitfc, uniform};
use super::{Codec, CodecError};
use crate::entropy::AlphaSchedule;

/// Everything a registry build may need to instantiate one stream codec.
#[derive(Debug, Clone, Copy)]
pub struct StreamCtx {
    /// channels of the tensors this stream carries (1 for sync streams)
    pub channels: usize,
    /// total training rounds (feeds ACII's α schedule)
    pub total_rounds: usize,
    /// this stream's seed (derived per device/direction by
    /// [`crate::codecs::stream::DeviceStreams::build`])
    pub seed: u64,
    /// SL-ACC parameter overrides (groups/window/bit bounds)
    pub slacc: SlAccConfig,
    /// α-schedule override for slacc / selection codecs
    pub alpha: Option<AlphaSchedule>,
}

/// Cap on `ef:` wrapper nesting (each layer costs a full decode per
/// encode; more than a couple is never useful).
pub const MAX_EF_DEPTH: u8 = 4;

type ParseFn = fn(&str) -> Option<Result<BaseSpec, CodecError>>;
type BuildFn = fn(&BaseSpec, &StreamCtx) -> Option<Result<Box<dyn Codec>, CodecError>>;

/// One codec family's registration: its slice of the spec grammar and its
/// constructor. `parse`/`build` return `None` when the token/spec belongs
/// to a different family.
struct Entry {
    grammar: &'static str,
    parse: ParseFn,
    build: BuildFn,
}

/// The registry itself — see the module docs.
pub struct CodecRegistry {
    entries: Vec<Entry>,
}

impl CodecRegistry {
    /// The standard registry: every built-in codec family.
    pub fn standard() -> CodecRegistry {
        CodecRegistry {
            entries: vec![
                Entry {
                    grammar: "identity (alias: none)",
                    parse: parse_identity,
                    build: build_identity,
                },
                Entry {
                    grammar: "uniform<bits 1..=16> (e.g. uniform4, uniform8)",
                    parse: parse_uniform,
                    build: build_uniform,
                },
                Entry {
                    grammar: "slacc | slacc-paper-eq6",
                    parse: parse_slacc,
                    build: build_slacc,
                },
                Entry {
                    grammar: "powerquant",
                    parse: parse_powerquant,
                    build: build_powerquant,
                },
                Entry { grammar: "randtopk", parse: parse_randtopk, build: build_randtopk },
                Entry { grammar: "splitfc", parse: parse_splitfc, build: build_splitfc },
                Entry {
                    grammar: "easyquant",
                    parse: parse_easyquant,
                    build: build_easyquant,
                },
                Entry {
                    grammar: "select:<random|std|entropy-instant|entropy-historical|\
                              acii|fixed#K>[:<n>]",
                    parse: parse_select,
                    build: build_select,
                },
            ],
        }
    }

    /// One line per registered family, for CLI help and docs. The `ef:`
    /// wrapper composes with every family.
    pub fn grammar(&self) -> Vec<&'static str> {
        self.entries.iter().map(|e| e.grammar).collect()
    }

    /// Parse and validate one spec string (`[ef:]*<base>`).
    pub fn parse(&self, spec: &str) -> Result<StreamSpec, CodecError> {
        let mut rest = spec;
        let mut ef_depth = 0u8;
        while let Some(inner) = rest.strip_prefix("ef:") {
            ef_depth += 1;
            if ef_depth > MAX_EF_DEPTH {
                return Err(CodecError::UnknownSpec(format!(
                    "spec '{spec}' nests ef: deeper than {MAX_EF_DEPTH}"
                )));
            }
            rest = inner;
        }
        for entry in &self.entries {
            if let Some(parsed) = (entry.parse)(rest) {
                return parsed.map(|base| StreamSpec::new(ef_depth, base));
            }
        }
        Err(CodecError::UnknownSpec(format!(
            "unknown codec spec '{rest}' (families: {})",
            self.grammar().join("; ")
        )))
    }

    /// Instantiate one stream's codec chain from a parsed spec.
    pub fn build(
        &self,
        spec: &StreamSpec,
        ctx: &StreamCtx,
    ) -> Result<Box<dyn Codec>, CodecError> {
        for entry in &self.entries {
            if let Some(built) = (entry.build)(&spec.base, ctx) {
                let mut codec = built?;
                for _ in 0..spec.ef_depth {
                    codec = Box::new(ef::EfCodec::new(codec, 1.0));
                }
                return Ok(codec);
            }
        }
        Err(CodecError::UnknownSpec(format!(
            "no registry entry builds spec '{spec}'"
        )))
    }
}

// --- per-family parse/build functions ---------------------------------

fn parse_identity(s: &str) -> Option<Result<BaseSpec, CodecError>> {
    if matches!(s, "identity" | "none") {
        Some(Ok(BaseSpec::Identity))
    } else {
        None
    }
}

fn build_identity(b: &BaseSpec, _ctx: &StreamCtx) -> Option<Result<Box<dyn Codec>, CodecError>> {
    if matches!(b, BaseSpec::Identity) {
        Some(Ok(Box::new(identity::IdentityCodec::new())))
    } else {
        None
    }
}

fn parse_uniform(s: &str) -> Option<Result<BaseSpec, CodecError>> {
    let rest = s.strip_prefix("uniform")?;
    Some(match rest.parse::<u32>() {
        Ok(bits) if (1..=16).contains(&bits) => Ok(BaseSpec::Uniform { bits }),
        _ => Err(CodecError::UnknownSpec(format!(
            "'{s}': uniform needs a bit width in 1..=16 (e.g. uniform8)"
        ))),
    })
}

fn build_uniform(b: &BaseSpec, _ctx: &StreamCtx) -> Option<Result<Box<dyn Codec>, CodecError>> {
    let BaseSpec::Uniform { bits } = b else { return None };
    Some(Ok(Box::new(uniform::UniformCodec::new(*bits))))
}

fn parse_slacc(s: &str) -> Option<Result<BaseSpec, CodecError>> {
    match s {
        "slacc" => Some(Ok(BaseSpec::SlAcc { paper_eq6: false })),
        "slacc-paper-eq6" => Some(Ok(BaseSpec::SlAcc { paper_eq6: true })),
        _ => None,
    }
}

fn build_slacc(b: &BaseSpec, ctx: &StreamCtx) -> Option<Result<Box<dyn Codec>, CodecError>> {
    let BaseSpec::SlAcc { paper_eq6 } = b else { return None };
    let mut cfg = ctx.slacc;
    if *paper_eq6 {
        cfg.bit_alloc = BitAlloc::FloorEntropy;
    }
    if let Some(a) = ctx.alpha {
        cfg.alpha = a;
    }
    Some(Ok(Box::new(slacc::SlAccCodec::new(
        cfg,
        ctx.channels,
        ctx.total_rounds,
        ctx.seed,
    ))))
}

fn parse_powerquant(s: &str) -> Option<Result<BaseSpec, CodecError>> {
    if s == "powerquant" {
        Some(Ok(BaseSpec::PowerQuant))
    } else {
        None
    }
}

fn build_powerquant(
    b: &BaseSpec,
    _ctx: &StreamCtx,
) -> Option<Result<Box<dyn Codec>, CodecError>> {
    if matches!(b, BaseSpec::PowerQuant) {
        Some(Ok(Box::new(powerquant::PowerQuantCodec::new(4))))
    } else {
        None
    }
}

fn parse_randtopk(s: &str) -> Option<Result<BaseSpec, CodecError>> {
    if s == "randtopk" {
        Some(Ok(BaseSpec::RandTopk))
    } else {
        None
    }
}

fn build_randtopk(b: &BaseSpec, ctx: &StreamCtx) -> Option<Result<Box<dyn Codec>, CodecError>> {
    if matches!(b, BaseSpec::RandTopk) {
        Some(Ok(Box::new(randtopk::RandTopkCodec::new(0.1, 0.01, ctx.seed))))
    } else {
        None
    }
}

fn parse_splitfc(s: &str) -> Option<Result<BaseSpec, CodecError>> {
    if s == "splitfc" {
        Some(Ok(BaseSpec::SplitFc))
    } else {
        None
    }
}

fn build_splitfc(b: &BaseSpec, _ctx: &StreamCtx) -> Option<Result<Box<dyn Codec>, CodecError>> {
    if matches!(b, BaseSpec::SplitFc) {
        Some(Ok(Box::new(splitfc::SplitFcCodec::new(0.5, 6))))
    } else {
        None
    }
}

fn parse_easyquant(s: &str) -> Option<Result<BaseSpec, CodecError>> {
    if s == "easyquant" {
        Some(Ok(BaseSpec::EasyQuant))
    } else {
        None
    }
}

fn build_easyquant(
    b: &BaseSpec,
    _ctx: &StreamCtx,
) -> Option<Result<Box<dyn Codec>, CodecError>> {
    if matches!(b, BaseSpec::EasyQuant) {
        Some(Ok(Box::new(easyquant::EasyQuantCodec::new(4))))
    } else {
        None
    }
}

fn parse_select(s: &str) -> Option<Result<BaseSpec, CodecError>> {
    let rest = s.strip_prefix("select:")?;
    Some(parse_select_inner(s, rest))
}

fn parse_select_inner(full: &str, rest: &str) -> Result<BaseSpec, CodecError> {
    let mut parts = rest.splitn(2, ':');
    let strat_tok = parts.next().unwrap_or("");
    let strategy = if let Some(k) = strat_tok.strip_prefix("fixed#") {
        let ch: usize = k.parse().map_err(|_| {
            CodecError::UnknownSpec(format!("'{full}': fixed#K needs an integer channel"))
        })?;
        Selection::Fixed(ch)
    } else {
        match strat_tok {
            "random" => Selection::Random,
            "std" => Selection::MaxStd,
            "entropy-instant" => Selection::EntropyInstant,
            "entropy-historical" => Selection::EntropyHistorical,
            "acii" => Selection::EntropyBlended,
            other => {
                return Err(CodecError::UnknownSpec(format!(
                    "'{full}': unknown selection strategy '{other}' \
                     (random|std|entropy-instant|entropy-historical|acii|fixed#K)"
                )))
            }
        }
    };
    let n_select = match parts.next() {
        None => 1,
        Some(n) => n.parse::<usize>().map_err(|_| {
            CodecError::UnknownSpec(format!("'{full}': select count must be an integer"))
        })?,
    };
    if n_select == 0 {
        return Err(CodecError::UnknownSpec(format!(
            "'{full}': select count must be >= 1"
        )));
    }
    Ok(BaseSpec::Select { strategy, n_select })
}

fn build_select(b: &BaseSpec, ctx: &StreamCtx) -> Option<Result<Box<dyn Codec>, CodecError>> {
    let BaseSpec::Select { strategy, n_select } = b else { return None };
    if *n_select > ctx.channels {
        return Some(Err(CodecError::Malformed(format!(
            "select wants {n_select} of {} channels",
            ctx.channels
        ))));
    }
    if let Selection::Fixed(ch) = strategy {
        if *ch >= ctx.channels {
            return Some(Err(CodecError::Malformed(format!(
                "select:fixed#{ch} is out of range for {} channels",
                ctx.channels
            ))));
        }
    }
    Some(Ok(Box::new(selection::SelectionCodec::new(
        *strategy,
        *n_select,
        ctx.channels,
        ctx.slacc.history_window,
        ctx.total_rounds,
        ctx.seed,
    ))))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(channels: usize) -> StreamCtx {
        StreamCtx {
            channels,
            total_rounds: 50,
            seed: 7,
            slacc: SlAccConfig::default(),
            alpha: None,
        }
    }

    #[test]
    fn parses_every_base_family() {
        let reg = CodecRegistry::standard();
        for (spec, canon) in [
            ("identity", "identity"),
            ("none", "identity"),
            ("uniform4", "uniform4"),
            ("uniform12", "uniform12"),
            ("slacc", "slacc"),
            ("slacc-paper-eq6", "slacc-paper-eq6"),
            ("powerquant", "powerquant"),
            ("randtopk", "randtopk"),
            ("splitfc", "splitfc"),
            ("easyquant", "easyquant"),
            ("select:acii", "select:acii:1"),
            ("select:std:3", "select:std:3"),
            ("select:fixed#2:1", "select:fixed#2:1"),
            ("ef:slacc", "ef:slacc"),
            ("ef:ef:uniform8", "ef:ef:uniform8"),
        ] {
            let parsed = reg.parse(spec).unwrap_or_else(|e| panic!("{spec}: {e}"));
            assert_eq!(parsed.as_str(), canon, "spec {spec}");
        }
    }

    #[test]
    fn rejects_bad_specs() {
        let reg = CodecRegistry::standard();
        for bad in [
            "bogus",
            "uniform",
            "uniform0",
            "uniform17",
            "uniformx",
            "select:",
            "select:nope",
            "select:acii:0",
            "select:acii:x",
            "select:fixed#",
            "ef:bogus",
            "ef:ef:ef:ef:ef:slacc",
            "",
        ] {
            assert!(reg.parse(bad).is_err(), "'{bad}' should not parse");
        }
    }

    #[test]
    fn builds_with_overrides() {
        let reg = CodecRegistry::standard();
        // α override reaches slacc through the ctx (the old build_codec
        // special case, now the one path)
        let mut c = ctx(8);
        c.alpha = Some(AlphaSchedule::Fixed(0.25));
        let spec = reg.parse("slacc").unwrap();
        assert_eq!(reg.build(&spec, &c).unwrap().name(), "slacc");
        let spec = reg.parse("slacc-paper-eq6").unwrap();
        assert_eq!(reg.build(&spec, &c).unwrap().name(), "slacc-paper-eq6");
        // select count must fit the stream's channel count
        let spec = reg.parse("select:std:9").unwrap();
        assert!(reg.build(&spec, &ctx(8)).is_err());
        assert!(reg.build(&spec, &ctx(16)).is_ok());
        // a fixed channel index must exist, not silently clamp
        let spec = reg.parse("select:fixed#8").unwrap();
        assert!(reg.build(&spec, &ctx(8)).is_err());
        assert!(reg.build(&spec, &ctx(9)).is_ok());
    }

    #[test]
    fn ef_wrapping_composes() {
        let reg = CodecRegistry::standard();
        let spec = reg.parse("ef:uniform4").unwrap();
        let c = reg.build(&spec, &ctx(4)).unwrap();
        assert_eq!(c.name(), "ef:uniform4");
    }
}
