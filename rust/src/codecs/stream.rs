//! The session-level stream model: which codec runs on which traffic.
//!
//! A split-learning session is three logical streams per device —
//! [`StreamKind::Uplink`] activations, [`StreamKind::Downlink`] gradients,
//! and [`StreamKind::Sync`] ModelSync parameter traffic — each negotiated
//! independently (`--uplink-codec` / `--downlink-codec` / `--sync-codec`,
//! with `--codec` as shorthand for both data directions).
//!
//! * [`StreamSpec`] — one stream's validated codec spec, parsed from the
//!   grammar owned by [`crate::codecs::registry::CodecRegistry`]:
//!   `[ef:]*<base>` where `<base>` is `identity`, `uniform<bits>`,
//!   `slacc`, `slacc-paper-eq6`, `powerquant`, `randtopk`, `splitfc`,
//!   `easyquant`, or `select:<strategy>[:<n>]`.
//! * [`StreamSpecs`] — the full per-stream table. The Hello handshake
//!   carries it verbatim plus its [`StreamSpecs::fingerprint`], so a fleet
//!   whose members disagree on any stream is rejected at connect time with
//!   an error naming the offending [`StreamKind`].
//! * [`StreamSet`] / [`DeviceStreams`] — the owned codec instances, one
//!   per device per direction. Stream seeds are derived here (and only
//!   here): data streams get `seed ^ (0x0dec << 16) ^ (device*2 + dir)`,
//!   sync streams `seed ^ (0x5106 << 20) ^ (device*2 + dir)` — the exact
//!   scheme the pre-registry code used, so `--codec slacc` reproduces the
//!   historical wire bytes byte-for-byte.

use super::registry::{CodecRegistry, StreamCtx};
use super::selection::Selection;
use super::slacc::SlAccConfig;
use super::{Codec, CodecError};
use crate::entropy::AlphaSchedule;

/// Which of a session's three per-device streams a spec applies to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamKind {
    /// Device → server smashed activations (the paper's main byte axis).
    Uplink,
    /// Server → device cut-layer gradients.
    Downlink,
    /// ModelSync / FedAvg parameter traffic, both directions.
    Sync,
}

impl StreamKind {
    pub const ALL: [StreamKind; 3] =
        [StreamKind::Uplink, StreamKind::Downlink, StreamKind::Sync];

    /// Short name for logs, errors, and the report/CSV ratio columns.
    pub fn label(&self) -> &'static str {
        match self {
            StreamKind::Uplink => "uplink",
            StreamKind::Downlink => "downlink",
            StreamKind::Sync => "sync",
        }
    }

    /// The CLI flag that configures this stream.
    pub fn flag(&self) -> &'static str {
        match self {
            StreamKind::Uplink => "--uplink-codec",
            StreamKind::Downlink => "--downlink-codec",
            StreamKind::Sync => "--sync-codec",
        }
    }

    /// Codec-site telemetry instruments for this stream direction.
    pub fn obs(&self) -> &'static StreamObs {
        match self {
            StreamKind::Uplink => &UPLINK_OBS,
            StreamKind::Downlink => &DOWNLINK_OBS,
            StreamKind::Sync => &SYNC_OBS,
        }
    }
}

/// The per-stream instrument bundle — static handles into the
/// [`crate::obs::metrics`] registry, so recording is a couple of relaxed
/// atomic ops with zero allocation.
pub struct StreamObs {
    pub encode_ns: &'static crate::obs::metrics::Histogram,
    pub decode_ns: &'static crate::obs::metrics::Histogram,
    pub encode_bytes: &'static crate::obs::metrics::Counter,
    pub decode_bytes: &'static crate::obs::metrics::Counter,
}

static UPLINK_OBS: StreamObs = StreamObs {
    encode_ns: &crate::obs::metrics::CODEC_ENC_NS_UP,
    decode_ns: &crate::obs::metrics::CODEC_DEC_NS_UP,
    encode_bytes: &crate::obs::metrics::CODEC_ENC_BYTES_UP,
    decode_bytes: &crate::obs::metrics::CODEC_DEC_BYTES_UP,
};
static DOWNLINK_OBS: StreamObs = StreamObs {
    encode_ns: &crate::obs::metrics::CODEC_ENC_NS_DOWN,
    decode_ns: &crate::obs::metrics::CODEC_DEC_NS_DOWN,
    encode_bytes: &crate::obs::metrics::CODEC_ENC_BYTES_DOWN,
    decode_bytes: &crate::obs::metrics::CODEC_DEC_BYTES_DOWN,
};
static SYNC_OBS: StreamObs = StreamObs {
    encode_ns: &crate::obs::metrics::CODEC_ENC_NS_SYNC,
    decode_ns: &crate::obs::metrics::CODEC_DEC_NS_SYNC,
    encode_bytes: &crate::obs::metrics::CODEC_ENC_BYTES_SYNC,
    decode_bytes: &crate::obs::metrics::CODEC_DEC_BYTES_SYNC,
};

/// Record one codec encode at a call site: `started` is the `Instant` taken
/// just before the encode, `wire_bytes` the envelope length produced.
#[inline]
pub fn record_encode(kind: StreamKind, started: std::time::Instant, wire_bytes: usize) {
    let o = kind.obs();
    o.encode_ns.observe(started.elapsed().as_nanos() as u64);
    o.encode_bytes.add(wire_bytes as u64);
}

/// Record one codec decode at a call site (`wire_bytes` = envelope length
/// consumed).
#[inline]
pub fn record_decode(kind: StreamKind, started: std::time::Instant, wire_bytes: usize) {
    let o = kind.obs();
    o.decode_ns.observe(started.elapsed().as_nanos() as u64);
    o.decode_bytes.add(wire_bytes as u64);
}

/// Entropy samples kept per stream for the windowed drift statistics.
pub const ENTROPY_WINDOW: usize = 64;

/// Lock-free sliding window of per-encode mean channel entropies for one
/// stream direction, publishing windowed mean/variance as milli-unit
/// gauges. Same discipline as the rest of the registry: relaxed atomics
/// only, zero allocation, races merely smudge the statistics.
struct EntropyDrift {
    /// f32 bit patterns of the most recent samples (ring)
    samples: [std::sync::atomic::AtomicU32; ENTROPY_WINDOW],
    /// monotone write counter; slot = idx % window, fill = min(idx, window)
    idx: std::sync::atomic::AtomicUsize,
    mean: &'static crate::obs::metrics::Gauge,
    var: &'static crate::obs::metrics::Gauge,
}

impl EntropyDrift {
    const fn new(
        mean: &'static crate::obs::metrics::Gauge,
        var: &'static crate::obs::metrics::Gauge,
    ) -> EntropyDrift {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: std::sync::atomic::AtomicU32 = std::sync::atomic::AtomicU32::new(0);
        EntropyDrift {
            samples: [ZERO; ENTROPY_WINDOW],
            idx: std::sync::atomic::AtomicUsize::new(0),
            mean,
            var,
        }
    }

    fn record(&self, sample: f32) {
        use std::sync::atomic::Ordering::Relaxed;
        let i = self.idx.fetch_add(1, Relaxed);
        self.samples[i % ENTROPY_WINDOW].store(sample.to_bits(), Relaxed);
        let n = (i + 1).min(ENTROPY_WINDOW);
        let mut sum = 0.0f64;
        let mut sumsq = 0.0f64;
        for slot in &self.samples[..n] {
            let x = f32::from_bits(slot.load(Relaxed)) as f64;
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = (sumsq / n as f64 - mean * mean).max(0.0);
        self.mean.set((mean * 1000.0) as i64);
        self.var.set((var * 1000.0) as i64);
    }
}

static UPLINK_DRIFT: EntropyDrift = EntropyDrift::new(
    &crate::obs::metrics::ENTROPY_MEAN_UP,
    &crate::obs::metrics::ENTROPY_VAR_UP,
);
static DOWNLINK_DRIFT: EntropyDrift = EntropyDrift::new(
    &crate::obs::metrics::ENTROPY_MEAN_DOWN,
    &crate::obs::metrics::ENTROPY_VAR_DOWN,
);
static SYNC_DRIFT: EntropyDrift = EntropyDrift::new(
    &crate::obs::metrics::ENTROPY_MEAN_SYNC,
    &crate::obs::metrics::ENTROPY_VAR_SYNC,
);

/// Record one encode's per-channel entropies into the stream's drift
/// window (called from the SL-ACC entropy paths when the
/// [`super::RoundCtx`] declares its stream kind). The sample is the mean
/// entropy across channels; the gauges publish windowed mean/variance in
/// milli-bits.
pub fn record_entropy(kind: StreamKind, entropies: &[f32]) {
    if entropies.is_empty() {
        return;
    }
    let sample = entropies.iter().sum::<f32>() / entropies.len() as f32;
    let drift = match kind {
        StreamKind::Uplink => &UPLINK_DRIFT,
        StreamKind::Downlink => &DOWNLINK_DRIFT,
        StreamKind::Sync => &SYNC_DRIFT,
    };
    drift.record(sample);
}

/// The base (innermost) codec family of a spec.
#[derive(Debug, Clone, PartialEq)]
pub enum BaseSpec {
    Identity,
    Uniform { bits: u32 },
    SlAcc { paper_eq6: bool },
    PowerQuant,
    RandTopk,
    SplitFc,
    EasyQuant,
    Select { strategy: Selection, n_select: usize },
}

impl BaseSpec {
    /// Canonical spec token (normalized: `none` → `identity`).
    pub fn canon(&self) -> String {
        match self {
            BaseSpec::Identity => "identity".into(),
            BaseSpec::Uniform { bits } => format!("uniform{bits}"),
            BaseSpec::SlAcc { paper_eq6: false } => "slacc".into(),
            BaseSpec::SlAcc { paper_eq6: true } => "slacc-paper-eq6".into(),
            BaseSpec::PowerQuant => "powerquant".into(),
            BaseSpec::RandTopk => "randtopk".into(),
            BaseSpec::SplitFc => "splitfc".into(),
            BaseSpec::EasyQuant => "easyquant".into(),
            BaseSpec::Select { strategy, n_select } => {
                format!("select:{}:{}", strategy.label(), n_select)
            }
        }
    }
}

/// One stream's validated codec spec: `ef_depth` error-feedback wrappers
/// around a [`BaseSpec`]. Obtained from
/// [`CodecRegistry::parse`] (or the [`StreamSpec::parse`] convenience);
/// the canonical string form is what travels in the Hello handshake.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamSpec {
    pub ef_depth: u8,
    pub base: BaseSpec,
    canon: String,
}

impl StreamSpec {
    pub(crate) fn new(ef_depth: u8, base: BaseSpec) -> StreamSpec {
        let canon = format!("{}{}", "ef:".repeat(ef_depth as usize), base.canon());
        StreamSpec { ef_depth, base, canon }
    }

    /// Parse a spec string through the standard registry grammar.
    pub fn parse(s: &str) -> Result<StreamSpec, CodecError> {
        CodecRegistry::standard().parse(s)
    }

    /// Canonical string form (wire + fingerprint representation).
    pub fn as_str(&self) -> &str {
        &self.canon
    }
}

impl std::fmt::Display for StreamSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.canon)
    }
}

/// FNV-1a over a canonical string — shared with
/// [`crate::config::ExperimentConfig::fingerprint`], so digests are
/// identical across processes and builds.
pub(crate) fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The negotiated per-stream spec table for a session. Both endpoints
/// resolve their flags into one of these; the Hello handshake ships it and
/// the server rejects any per-kind disagreement.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamSpecs {
    pub uplink: StreamSpec,
    pub downlink: StreamSpec,
    pub sync: StreamSpec,
}

impl StreamSpecs {
    /// Parse a full table from the three spec strings.
    pub fn parse(uplink: &str, downlink: &str, sync: &str) -> Result<StreamSpecs, CodecError> {
        let reg = CodecRegistry::standard();
        Ok(StreamSpecs {
            uplink: reg.parse(uplink).map_err(|e| kind_err(StreamKind::Uplink, e))?,
            downlink: reg
                .parse(downlink)
                .map_err(|e| kind_err(StreamKind::Downlink, e))?,
            sync: reg.parse(sync).map_err(|e| kind_err(StreamKind::Sync, e))?,
        })
    }

    pub fn get(&self, kind: StreamKind) -> &StreamSpec {
        match kind {
            StreamKind::Uplink => &self.uplink,
            StreamKind::Downlink => &self.downlink,
            StreamKind::Sync => &self.sync,
        }
    }

    /// Human-readable table for logs and handshake errors.
    pub fn table(&self) -> String {
        format!(
            "uplink={} downlink={} sync={}",
            self.uplink, self.downlink, self.sync
        )
    }

    /// Stable digest of the table (carried in the Hello next to the spec
    /// strings as a cross-check).
    pub fn fingerprint(&self) -> u64 {
        fnv1a(&format!(
            "{}|{}|{}",
            self.uplink.as_str(),
            self.downlink.as_str(),
            self.sync.as_str()
        ))
    }
}

fn kind_err(kind: StreamKind, e: CodecError) -> CodecError {
    CodecError::UnknownSpec(format!("{} stream ({}): {e}", kind.label(), kind.flag()))
}

/// Session parameters every stream build shares (a projection of
/// `ExperimentConfig`, so the registry never needs the full config).
#[derive(Debug, Clone, Copy)]
pub struct SessionStreamCfg {
    /// cut-layer channels of the data streams (sync streams always see 1)
    pub channels: usize,
    pub total_rounds: usize,
    /// the experiment seed; per-stream seeds are derived from it here
    pub seed: u64,
    /// SL-ACC overrides (`--groups`/`--window`/`--bmin`/`--bmax`)
    pub slacc: SlAccConfig,
    /// α-schedule override for slacc / selection codecs (Fig. 4)
    pub alpha: Option<AlphaSchedule>,
}

/// Seed for a data-direction stream (`dir` 0 = uplink, 1 = downlink).
fn data_seed(seed: u64, device: usize, dir: u64) -> u64 {
    seed ^ (0x0dec << 16) ^ ((device as u64) * 2 + dir)
}

/// Seed for a sync-direction stream (`dir` 0 = push, 1 = broadcast).
fn sync_seed(seed: u64, device: usize, dir: u64) -> u64 {
    seed ^ (0x5106 << 20) ^ ((device as u64) * 2 + dir)
}

/// Seed for a shard↔coordinator sync stream (`dir` 0 = shard push, 1 =
/// coordinator broadcast). A distinct namespace from the per-device sync
/// seeds so a shard link never shares an RNG stream with a device link.
fn shard_seed(seed: u64, shard: usize, dir: u64) -> u64 {
    seed ^ (0x51AD << 28) ^ ((shard as u64) * 2 + dir)
}

/// Build the codec pair for one shard↔coordinator link: `(push,
/// broadcast)` instances of the negotiated sync-stream spec. Both ends of
/// a link build identical twins (the seeds are a pure function of the
/// session seed + shard id + direction), exactly like the per-device
/// streams. Shard links see flattened parameters: one logical channel.
pub fn shard_sync_streams(
    specs: &StreamSpecs,
    cfg: &SessionStreamCfg,
    shard: usize,
) -> Result<(Box<dyn Codec>, Box<dyn Codec>), CodecError> {
    let reg = CodecRegistry::standard();
    let ctx = |seed: u64| StreamCtx {
        channels: 1,
        total_rounds: cfg.total_rounds,
        seed,
        slacc: cfg.slacc,
        alpha: cfg.alpha,
    };
    Ok((
        reg.build(&specs.sync, &ctx(shard_seed(cfg.seed, shard, 0)))?,
        reg.build(&specs.sync, &ctx(shard_seed(cfg.seed, shard, 1)))?,
    ))
}

/// The four codec instances serving one device's streams on one endpoint.
/// The compressing side and its decompressing twin build identical
/// instances (the envelopes are self-describing, and stream seeds are a
/// pure function of the session seed + device + direction).
pub struct DeviceStreams {
    /// uplink activations (device compresses, server decodes)
    pub up: Box<dyn Codec>,
    /// downlink gradients (server compresses, device decodes)
    pub down: Box<dyn Codec>,
    /// ModelSync pushes, device → server
    pub sync_up: Box<dyn Codec>,
    /// ModelSync broadcasts, server → device
    pub sync_down: Box<dyn Codec>,
}

impl DeviceStreams {
    /// Build device `device`'s four stream codecs from the negotiated
    /// table.
    pub fn build(
        specs: &StreamSpecs,
        cfg: &SessionStreamCfg,
        device: usize,
    ) -> Result<DeviceStreams, CodecError> {
        let reg = CodecRegistry::standard();
        let ctx = |channels: usize, seed: u64| StreamCtx {
            channels,
            total_rounds: cfg.total_rounds,
            seed,
            slacc: cfg.slacc,
            alpha: cfg.alpha,
        };
        Ok(DeviceStreams {
            up: reg.build(&specs.uplink, &ctx(cfg.channels, data_seed(cfg.seed, device, 0)))?,
            down: reg
                .build(&specs.downlink, &ctx(cfg.channels, data_seed(cfg.seed, device, 1)))?,
            // sync streams see flattened parameters: one logical channel
            sync_up: reg.build(&specs.sync, &ctx(1, sync_seed(cfg.seed, device, 0)))?,
            sync_down: reg.build(&specs.sync, &ctx(1, sync_seed(cfg.seed, device, 1)))?,
        })
    }
}

/// Every per-device, per-direction codec instance of one session endpoint
/// (the server side owns one for the whole fleet; a device worker owns a
/// single [`DeviceStreams`]).
pub struct StreamSet {
    specs: StreamSpecs,
    streams: Vec<DeviceStreams>,
    /// build parameters retained so [`StreamSet::rebuilt`] can produce a
    /// sibling set (same fleet slice, new spec table) mid-session
    session: SessionStreamCfg,
    base: usize,
}

impl StreamSet {
    /// Build the full fleet's stream codecs.
    pub fn build(
        specs: StreamSpecs,
        cfg: &SessionStreamCfg,
        devices: usize,
    ) -> Result<StreamSet, CodecError> {
        Self::build_range(specs, cfg, 0, devices)
    }

    /// Build the stream codecs for a contiguous global-device-id range
    /// `[base, base + count)`, indexed locally from 0. A shard server of a
    /// multi-server topology serves such a slice of the fleet; seeds stay
    /// derived from the *global* id, so shard servers hold exactly the
    /// twins their devices build.
    pub fn build_range(
        specs: StreamSpecs,
        cfg: &SessionStreamCfg,
        base: usize,
        count: usize,
    ) -> Result<StreamSet, CodecError> {
        let mut streams = Vec::with_capacity(count);
        for d in base..base + count {
            streams.push(DeviceStreams::build(&specs, cfg, d)?);
        }
        Ok(StreamSet { specs, streams, session: *cfg, base })
    }

    /// Build a sibling set for the same fleet slice (same session
    /// parameters, same global-id range) under a re-negotiated spec table.
    /// Stream seeds are a pure function of seed + device + direction, so
    /// the server-side instances built here are exact twins of the fresh
    /// [`DeviceStreams`] each device builds when it activates the update.
    pub fn rebuilt(&self, specs: StreamSpecs) -> Result<StreamSet, CodecError> {
        StreamSet::build_range(specs, &self.session, self.base, self.streams.len())
    }

    /// The negotiated spec table this set was built from.
    pub fn specs(&self) -> &StreamSpecs {
        &self.specs
    }

    pub fn devices(&self) -> usize {
        self.streams.len()
    }

    /// Device `d`'s stream codecs.
    pub fn device(&mut self, d: usize) -> &mut DeviceStreams {
        &mut self.streams[d]
    }

    /// Re-instantiate device `d`'s four stream codecs from scratch (same
    /// spec table, same derived seeds). A readmitted device is a fresh
    /// process with fresh codec state; rebuilding its server-side twins at
    /// admission keeps both ends of every stream deterministic across
    /// departures and re-joins.
    pub fn rebuild_device(&mut self, d: usize) -> Result<(), CodecError> {
        self.streams[d] = DeviceStreams::build(&self.specs, &self.session, self.base + d)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn session() -> SessionStreamCfg {
        SessionStreamCfg {
            channels: 8,
            total_rounds: 50,
            seed: 3,
            slacc: SlAccConfig::default(),
            alpha: None,
        }
    }

    #[test]
    fn specs_parse_and_canonicalize() {
        let s = StreamSpecs::parse("slacc", "uniform8", "none").unwrap();
        assert_eq!(s.uplink.as_str(), "slacc");
        assert_eq!(s.downlink.as_str(), "uniform8");
        // `none` normalizes to `identity` so both ends agree on the wire
        assert_eq!(s.sync.as_str(), "identity");
        assert_eq!(s.table(), "uplink=slacc downlink=uniform8 sync=identity");
    }

    #[test]
    fn bad_spec_names_the_stream_and_flag() {
        let e = StreamSpecs::parse("slacc", "bogus", "identity").unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("downlink"), "{msg}");
        assert!(msg.contains("--downlink-codec"), "{msg}");
    }

    #[test]
    fn fingerprint_tracks_every_stream() {
        let a = StreamSpecs::parse("slacc", "slacc", "identity").unwrap();
        let b = StreamSpecs::parse("slacc", "uniform8", "identity").unwrap();
        let c = StreamSpecs::parse("slacc", "slacc", "uniform8").unwrap();
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), c.fingerprint());
        assert_eq!(
            a.fingerprint(),
            StreamSpecs::parse("slacc", "slacc", "identity").unwrap().fingerprint()
        );
    }

    #[test]
    fn stream_set_builds_per_device_instances() {
        let specs = StreamSpecs::parse("slacc", "uniform4", "identity").unwrap();
        let mut set = StreamSet::build(specs, &session(), 3).unwrap();
        assert_eq!(set.devices(), 3);
        for d in 0..3 {
            let ds = set.device(d);
            assert_eq!(ds.up.name(), "slacc");
            assert_eq!(ds.down.name(), "uniform4");
            assert_eq!(ds.sync_up.name(), "identity");
            assert_eq!(ds.sync_down.name(), "identity");
        }
    }

    #[test]
    fn stream_seeds_differ_per_device_and_direction() {
        // stochastic codec (randtopk): same tensor, different streams must
        // produce different envelopes (different RNG seeds)
        use crate::codecs::test_support::random_cm;
        use crate::codecs::RoundCtx;
        let specs = StreamSpecs::parse("randtopk", "randtopk", "identity").unwrap();
        let mut set = StreamSet::build(specs, &session(), 2).unwrap();
        let cm = random_cm(2, 8, 4, 4, 1);
        let w_up0 = set.device(0).up.compress(&cm, RoundCtx::default());
        let w_down0 = set.device(0).down.compress(&cm, RoundCtx::default());
        let w_up1 = set.device(1).up.compress(&cm, RoundCtx::default());
        assert_ne!(w_up0, w_down0, "directions must not share RNG streams");
        assert_ne!(w_up0, w_up1, "devices must not share RNG streams");
    }

    #[test]
    fn kind_labels_and_flags() {
        assert_eq!(StreamKind::Uplink.label(), "uplink");
        assert_eq!(StreamKind::Sync.flag(), "--sync-codec");
        assert_eq!(StreamKind::ALL.len(), 3);
    }
}
