//! Channel-selection codecs — the paper's motivating / ablation experiments.
//!
//! These transmit a *subset* of channels verbatim (f32) and zero the rest:
//!
//! * Fig. 2: `Selection::Fixed(c)` — train with a single fixed channel.
//! * Fig. 3: `Selection::EntropyInstant` vs `Selection::EntropyHistorical` —
//!   transmit the channel(s) with the highest instantaneous / historical
//!   entropy each round.
//! * Fig. 6: `Selection::EntropyBlended` (ACII) vs `Selection::MaxStd` vs
//!   `Selection::Random`.

use crate::codecs::{ids, Codec, CodecError, RoundCtx};
use crate::entropy::{shannon, Acii, AlphaSchedule};
use crate::quant::payload::{ByteReader, ByteWriter, Header};
use crate::tensor::{view, ChannelMajor, Tensor};
use crate::util::rng::Pcg32;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Selection {
    /// Always the given channel (Fig. 2 single-channel probes).
    Fixed(usize),
    /// Uniformly random channel(s) each round (Fig. 6 "Random").
    Random,
    /// Highest standard deviation (Fig. 6 "STD-based").
    MaxStd,
    /// Highest instantaneous entropy H_c^(t) (Fig. 3).
    EntropyInstant,
    /// Highest historical entropy H̃_c (Fig. 3).
    EntropyHistorical,
    /// Highest ACII-blended entropy (Eq. 2; Fig. 6 "ACII").
    EntropyBlended,
}

impl Selection {
    pub fn label(&self) -> String {
        match self {
            Selection::Fixed(c) => format!("fixed#{c}"),
            Selection::Random => "random".into(),
            Selection::MaxStd => "std".into(),
            Selection::EntropyInstant => "entropy-instant".into(),
            Selection::EntropyHistorical => "entropy-historical".into(),
            Selection::EntropyBlended => "acii".into(),
        }
    }
}

pub struct SelectionCodec {
    strategy: Selection,
    n_select: usize,
    acii: Acii,
    rng: Pcg32,
    /// channels picked by the most recent compress (diagnostics)
    last_selected: Vec<usize>,
    /// reusable instantaneous-entropy buffer (no allocation once warmed)
    inst: Vec<f32>,
}

impl SelectionCodec {
    pub fn new(strategy: Selection, n_select: usize, channels: usize,
               history_window: usize, total_rounds: usize, seed: u64) -> Self {
        assert!(n_select >= 1 && n_select <= channels);
        SelectionCodec {
            strategy,
            n_select,
            acii: Acii::new(channels, history_window, total_rounds,
                            AlphaSchedule::Adaptive),
            rng: Pcg32::new(seed, 0x5e1ec7),
            last_selected: Vec::new(),
            inst: Vec::new(),
        }
    }

    pub fn last_selected(&self) -> &[usize] {
        &self.last_selected
    }

    /// Indices of the `n` largest scores (descending).
    fn top_n(scores: &[f32], n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..scores.len()).collect();
        idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
        idx.truncate(n);
        idx
    }

    fn select(&mut self, data: &ChannelMajor, ctx: RoundCtx<'_>) -> Vec<usize> {
        let c = data.channels;
        // ACII state advances every round regardless of strategy so the
        // entropy modes stay comparable round-for-round.
        match ctx.entropy {
            Some(h) => {
                self.inst.clear();
                self.inst.extend_from_slice(h);
            }
            None => shannon::entropies_into(data, &mut self.inst),
        }
        if let Some(kind) = ctx.kind {
            super::stream::record_entropy(kind, &self.inst);
        }
        let hist = self.acii.historical(&self.inst);
        let blended = self.acii.update(&self.inst);

        match self.strategy {
            Selection::Fixed(ch) => vec![ch.min(c - 1)],
            Selection::Random => self
                .rng
                .sample_indices(c, self.n_select),
            Selection::MaxStd => {
                let stds: Vec<f32> =
                    (0..c).map(|ch| view::mean_std(data.channel(ch)).1).collect();
                Self::top_n(&stds, self.n_select)
            }
            Selection::EntropyInstant => Self::top_n(&self.inst, self.n_select),
            Selection::EntropyHistorical => Self::top_n(&hist, self.n_select),
            Selection::EntropyBlended => Self::top_n(&blended, self.n_select),
        }
    }
}

impl Codec for SelectionCodec {
    fn name(&self) -> &'static str {
        match self.strategy {
            Selection::Fixed(_) => "select-fixed",
            Selection::Random => "select-random",
            Selection::MaxStd => "select-std",
            Selection::EntropyInstant => "select-entropy-instant",
            Selection::EntropyHistorical => "select-entropy-historical",
            Selection::EntropyBlended => "select-acii",
        }
    }

    fn encode(&mut self, data: &ChannelMajor, ctx: RoundCtx<'_>, out: &mut ByteWriter) {
        let (b, c, h, w) = data.geometry();
        let mut picked = self.select(data, ctx);
        picked.sort_unstable();
        picked.dedup();
        self.last_selected = picked.clone();

        let n = data.n_per_channel;
        out.reserve(Header::BYTES + 6 + picked.len() * (2 + n * 4));
        Header { codec_id: ids::SELECTION, dims: [b as u32, c as u32, h as u32, w as u32] }
            .write(out);
        // total element count, redundantly: the body length only depends
        // on B*H*W, so without this binding a corrupted header could
        // silently grow the channel count
        out.u32((c * n) as u32);
        out.u16(picked.len() as u16);
        for &ch in &picked {
            out.u16(ch as u16);
        }
        for &ch in &picked {
            out.f32s(data.channel(ch));
        }
    }

    fn decode(&mut self, bytes: &[u8]) -> Result<Tensor, CodecError> {
        let mut r = ByteReader::new(bytes);
        let header = Header::read(&mut r)?;
        if header.codec_id != ids::SELECTION {
            return Err(CodecError::WrongCodec {
                expected: "selection",
                found: header.codec_id,
            });
        }
        let [b, c, h, w] = header.dims.map(|d| d as usize);
        let n = header.n_per_channel();
        let body_total = r.u32()? as usize;
        if body_total != c * n {
            return Err(CodecError::Malformed(format!(
                "body claims {body_total} elements, header dims give {}",
                c * n
            )));
        }
        let n_sel = r.u16()? as usize;
        if n_sel > c {
            return Err(CodecError::LimitExceeded {
                what: "selected channels",
                claimed: n_sel,
                cap: c,
            });
        }
        let mut chans = Vec::with_capacity(n_sel);
        for _ in 0..n_sel {
            let ch = r.u16()? as usize;
            if ch >= c {
                return Err(CodecError::Malformed(format!("channel {ch} out of range")));
            }
            chans.push(ch);
        }
        let mut rows = vec![0.0f32; c * n];
        for &ch in &chans {
            let vals = r.f32s(n)?;
            rows[ch * n..(ch + 1) * n].copy_from_slice(&vals);
        }
        r.expect_end()?;
        Ok(ChannelMajor::from_rows(c, n, b, h, w, rows).to_nchw())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::test_support::random_cm;
    use crate::tensor::Tensor;

    fn codec(strategy: Selection, n_select: usize, channels: usize) -> SelectionCodec {
        SelectionCodec::new(strategy, n_select, channels, 5, 100, 3)
    }

    #[test]
    fn fixed_transmits_exactly_that_channel() {
        let cm = random_cm(2, 6, 4, 4, 1);
        let mut c = codec(Selection::Fixed(3), 1, 6);
        let wire = c.compress(&cm, RoundCtx::default());
        let out = c.decode(&wire).unwrap();
        let rec = out.to_channel_major();
        assert_eq!(rec.channel(3), cm.channel(3));
        for ch in [0usize, 1, 2, 4, 5] {
            assert!(rec.channel(ch).iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn max_std_picks_highest_variance() {
        // channel 2 has much higher variance
        let mut data = vec![0.01f32; 4 * 16];
        for (i, v) in data.iter_mut().enumerate() {
            *v = (i % 3) as f32 * 0.001;
        }
        for i in 0..16 {
            data[2 * 16 + i] = if i % 2 == 0 { 10.0 } else { -10.0 };
        }
        let cm = Tensor::new(vec![1, 4, 4, 4], data).to_channel_major();
        let mut c = codec(Selection::MaxStd, 1, 4);
        let _ = c.compress(&cm, RoundCtx::default());
        assert_eq!(c.last_selected(), &[2]);
    }

    #[test]
    fn entropy_instant_uses_external_entropy() {
        let cm = random_cm(2, 4, 4, 4, 2);
        let ent = [0.1f32, 5.0, 0.2, 0.3];
        let mut c = codec(Selection::EntropyInstant, 1, 4);
        let _ = c.compress(&cm, RoundCtx { entropy: Some(&ent), kind: None });
        assert_eq!(c.last_selected(), &[1]);
    }

    #[test]
    fn historical_lags_instantaneous() {
        let cm = random_cm(2, 2, 4, 4, 3);
        let mut c = codec(Selection::EntropyHistorical, 1, 2);
        // round 0: channel 0 hot (no history -> falls back to inst)
        let _ = c.compress(&cm, RoundCtx { entropy: Some(&[5.0, 0.1]), kind: None });
        assert_eq!(c.last_selected(), &[0]);
        // round 1: channel 1 suddenly hot, but HISTORY still says 0
        let _ = c.compress(&cm, RoundCtx { entropy: Some(&[0.1, 5.0]), kind: None });
        assert_eq!(c.last_selected(), &[0], "historical must lag");
        // after enough rounds the history flips
        for _ in 0..6 {
            let _ = c.compress(&cm, RoundCtx { entropy: Some(&[0.1, 5.0]), kind: None });
        }
        assert_eq!(c.last_selected(), &[1]);
    }

    #[test]
    fn random_selection_varies() {
        let cm = random_cm(2, 16, 4, 4, 4);
        let mut c = codec(Selection::Random, 2, 16);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..20 {
            let _ = c.compress(&cm, RoundCtx::default());
            seen.extend(c.last_selected().iter().copied());
        }
        assert!(seen.len() > 4, "random selection stuck on {seen:?}");
    }

    #[test]
    fn multi_channel_roundtrip() {
        let cm = random_cm(2, 8, 4, 4, 5);
        let mut c = codec(Selection::MaxStd, 3, 8);
        let wire = c.compress(&cm, RoundCtx::default());
        let out = c.decode(&wire).unwrap();
        let rec = out.to_channel_major();
        let sel = c.last_selected().to_vec();
        assert_eq!(sel.len(), 3);
        for &ch in &sel {
            assert_eq!(rec.channel(ch), cm.channel(ch));
        }
    }

    #[test]
    fn wire_size_proportional_to_selection() {
        let cm = random_cm(2, 8, 4, 4, 6);
        let n = cm.n_per_channel;
        let mut c1 = codec(Selection::MaxStd, 1, 8);
        let mut c3 = codec(Selection::MaxStd, 3, 8);
        let w1 = c1.compress(&cm, RoundCtx::default());
        let w3 = c3.compress(&cm, RoundCtx::default());
        assert_eq!(w1.len(), Header::BYTES + 4 + 2 + 2 + n * 4);
        assert_eq!(w3.len(), Header::BYTES + 4 + 2 + 3 * (2 + n * 4));
    }
}
