//! Uniform fixed-bit codec: per-channel min/max linear quantization at one
//! global bit width. The "uniform compression across all channels" strawman
//! the paper argues against (Sec. I), and the fixed-bit substrate inside
//! SplitFC/EasyQuant.

use crate::codecs::{ids, Codec, CodecError, RoundCtx};
use crate::quant::payload::{ByteReader, ByteWriter, Header};
use crate::quant::{bitpack, linear};
use crate::tensor::{view, ChannelMajor, Tensor};

#[derive(Debug)]
pub struct UniformCodec {
    bits: u32,
    /// reusable quantization scratch (codes + packed bytes): the encode
    /// hot path touches the allocator only until these reach their
    /// steady-state capacity
    codes: Vec<u32>,
    packed: Vec<u8>,
}

impl UniformCodec {
    pub fn new(bits: u32) -> Self {
        assert!((1..=16).contains(&bits));
        UniformCodec { bits, codes: Vec::new(), packed: Vec::new() }
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }
}

impl Codec for UniformCodec {
    fn name(&self) -> &'static str {
        match self.bits {
            4 => "uniform4",
            8 => "uniform8",
            _ => "uniform",
        }
    }

    fn encode(&mut self, data: &ChannelMajor, _ctx: RoundCtx<'_>, out: &mut ByteWriter) {
        let (b, c, h, w) = data.geometry();
        let n = data.n_per_channel;
        out.reserve(Header::BYTES + 1 + c * (8 + bitpack::packed_len(n, self.bits)));
        Header { codec_id: ids::UNIFORM, dims: [b as u32, c as u32, h as u32, w as u32] }
            .write(out);
        out.u8(self.bits as u8);
        for ch in 0..c {
            let row = data.channel(ch);
            let (mn, mx) = view::min_max(row);
            out.f32(mn);
            out.f32(mx);
            linear::quantize(row, mn, mx, self.bits, &mut self.codes);
            bitpack::pack_into(&self.codes, self.bits, &mut self.packed);
            out.bytes(&self.packed);
        }
    }

    fn decode(&mut self, bytes: &[u8]) -> Result<Tensor, CodecError> {
        let mut r = ByteReader::new(bytes);
        let header = Header::read(&mut r)?;
        if header.codec_id != ids::UNIFORM {
            return Err(CodecError::WrongCodec {
                expected: "uniform",
                found: header.codec_id,
            });
        }
        let [b, c, h, w] = header.dims.map(|d| d as usize);
        let n = header.n_per_channel();
        let bits = r.u8()? as u32;
        if !(1..=16).contains(&bits) {
            return Err(CodecError::Malformed(format!("bad bit width {bits}")));
        }
        let mut rows = vec![0.0f32; c * n];
        let mut vals = Vec::new();
        for ch in 0..c {
            let mn = r.f32()?;
            let mx = r.f32()?;
            let packed = r.bytes(bitpack::packed_len(n, bits))?;
            let codes = bitpack::unpack(packed, bits, n);
            linear::dequantize(&codes, mn, mx, bits, &mut vals);
            rows[ch * n..(ch + 1) * n].copy_from_slice(&vals);
        }
        r.expect_end()?;
        Ok(ChannelMajor::from_rows(c, n, b, h, w, rows).to_nchw())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::test_support::random_cm;

    #[test]
    fn roundtrip_error_bounded() {
        let cm = random_cm(2, 6, 4, 4, 1);
        for bits in [2u32, 4, 8] {
            let mut c = UniformCodec::new(bits);
            let wire = c.compress(&cm, RoundCtx::default());
            let out = c.decode(&wire).unwrap();
            for ch in 0..6 {
                let row = cm.channel(ch);
                let (mn, mx) = view::min_max(row);
                let bound = linear::max_error(mn, mx, bits) + 1e-5;
                let rec = out.to_channel_major();
                for (a, b) in row.iter().zip(rec.channel(ch)) {
                    assert!((a - b).abs() <= bound, "bits={bits}");
                }
            }
        }
    }

    #[test]
    fn wire_size_scales_with_bits() {
        let cm = random_cm(2, 8, 8, 8, 2);
        let w4 = UniformCodec::new(4).compress(&cm, RoundCtx::default());
        let w8 = UniformCodec::new(8).compress(&cm, RoundCtx::default());
        assert!(w8.len() > w4.len());
        let n = cm.n_per_channel;
        assert_eq!(w4.len(), Header::BYTES + 1 + 8 * (8 + n / 2));
    }

    #[test]
    fn eight_bit_beats_two_bit_fidelity() {
        let cm = random_cm(2, 4, 8, 8, 3);
        let orig = cm.to_nchw();
        let e2 = {
            let mut c = UniformCodec::new(2);
            let w = c.compress(&cm, RoundCtx::default());
            orig.mean_abs_diff(&c.decode(&w).unwrap())
        };
        let e8 = {
            let mut c = UniformCodec::new(8);
            let w = c.compress(&cm, RoundCtx::default());
            orig.mean_abs_diff(&c.decode(&w).unwrap())
        };
        assert!(e8 < e2 / 10.0, "e8={e8} e2={e2}");
    }
}
