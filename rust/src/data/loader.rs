//! Per-device batch loader: epoch shuffling over a shard, fixed batch size.
//!
//! The AOT artifacts are shape-specialized to one batch size, so the loader
//! always yields full batches, wrapping (and reshuffling) at epoch
//! boundaries — matching how the paper's per-round mini-batch sampling
//! works with a fixed `batch_size`.

use crate::util::rng::Pcg32;

#[derive(Debug, Clone)]
pub struct BatchLoader {
    indices: Vec<usize>,
    batch: usize,
    cursor: usize,
    rng: Pcg32,
    epoch: usize,
}

impl BatchLoader {
    pub fn new(shard: &[usize], batch: usize, seed: u64) -> BatchLoader {
        assert!(batch >= 1);
        assert!(!shard.is_empty(), "empty shard");
        let mut rng = Pcg32::new(seed, 0x10ad);
        let mut indices = shard.to_vec();
        rng.shuffle(&mut indices);
        BatchLoader { indices, batch, cursor: 0, rng, epoch: 0 }
    }

    pub fn epoch(&self) -> usize {
        self.epoch
    }

    pub fn shard_len(&self) -> usize {
        self.indices.len()
    }

    /// Next batch of exactly `batch` indices (wraps + reshuffles at epoch
    /// end; shards smaller than a batch repeat within the batch).
    pub fn next_batch(&mut self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.batch);
        while out.len() < self.batch {
            if self.cursor >= self.indices.len() {
                self.rng.shuffle(&mut self.indices);
                self.cursor = 0;
                self.epoch += 1;
            }
            let take = (self.batch - out.len()).min(self.indices.len() - self.cursor);
            out.extend_from_slice(&self.indices[self.cursor..self.cursor + take]);
            self.cursor += take;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_batches_always() {
        let shard: Vec<usize> = (0..10).collect();
        let mut l = BatchLoader::new(&shard, 4, 0);
        for _ in 0..20 {
            assert_eq!(l.next_batch().len(), 4);
        }
    }

    #[test]
    fn epoch_covers_shard() {
        let shard: Vec<usize> = (100..108).collect();
        let mut l = BatchLoader::new(&shard, 4, 1);
        let mut seen: Vec<usize> = Vec::new();
        seen.extend(l.next_batch());
        seen.extend(l.next_batch());
        seen.sort_unstable();
        assert_eq!(seen, (100..108).collect::<Vec<_>>());
        assert_eq!(l.epoch(), 0);
        l.next_batch();
        assert_eq!(l.epoch(), 1);
    }

    #[test]
    fn tiny_shard_repeats() {
        let mut l = BatchLoader::new(&[5, 6], 8, 2);
        let b = l.next_batch();
        assert_eq!(b.len(), 8);
        assert!(b.iter().all(|&i| i == 5 || i == 6));
        assert!(b.contains(&5) && b.contains(&6));
    }

    #[test]
    fn reshuffles_between_epochs() {
        let shard: Vec<usize> = (0..64).collect();
        let mut l = BatchLoader::new(&shard, 64, 3);
        let e0 = l.next_batch();
        let e1 = l.next_batch();
        assert_ne!(e0, e1, "epochs should reshuffle");
        let mut s0 = e0.clone();
        let mut s1 = e1.clone();
        s0.sort_unstable();
        s1.sort_unstable();
        assert_eq!(s0, s1);
    }

    #[test]
    #[should_panic(expected = "empty shard")]
    fn empty_shard_panics() {
        let _ = BatchLoader::new(&[], 4, 0);
    }
}
