//! Synthetic HAM10000 surrogate: 7-class dermatoscopy-like image generator.
//!
//! HAM10000 is a gated medical dataset; this generator preserves the
//! properties the SL-ACC experiments exercise (DESIGN.md §Substitutions):
//! RGB images whose class is encoded in *spatial structure* (lesion shape,
//! border irregularity, satellites) and *photometric structure* (colour,
//! texture), with HAM-like long-tailed class priors. Each class is a
//! distinct region of the generative parameter space with within-class
//! jitter, so a CNN has real signal to learn and per-channel activations
//! develop the uneven importance profile ACII exploits.
//!
//! Classes mirror the HAM10000 taxonomy:
//!   0 nv (melanocytic nevus)  1 mel (melanoma)        2 bkl (keratosis)
//!   3 bcc (basal cell carc.)  4 akiec (actinic ker.)  5 vasc (vascular)
//!   6 df (dermatofibroma)

use super::Dataset;
use crate::util::rng::Pcg32;

pub const CLASSES: usize = 7;
pub const SIZE: usize = 32;

/// HAM10000's empirical long-tailed class distribution (approx.).
pub const CLASS_PRIORS: [f64; CLASSES] = [0.67, 0.11, 0.11, 0.05, 0.033, 0.014, 0.013];

/// Per-class generative parameters.
struct ClassParams {
    /// lesion base colour (r, g, b)
    color: [f32; 3],
    /// mean radius in pixels
    radius: f32,
    /// ellipse eccentricity (1 = circle)
    ecc: f32,
    /// border irregularity amplitude (fraction of radius)
    border: f32,
    /// ring structure strength (keratosis-like)
    ring: f32,
    /// number of satellite blobs
    satellites: usize,
    /// internal texture frequency
    tex_freq: f32,
}

fn class_params(class: usize) -> ClassParams {
    match class {
        // nv: regular brown round lesion
        0 => ClassParams { color: [0.45, 0.28, 0.18], radius: 8.0, ecc: 1.05,
                           border: 0.06, ring: 0.0, satellites: 0, tex_freq: 2.0 },
        // mel: dark, asymmetric, irregular border, satellites
        1 => ClassParams { color: [0.22, 0.12, 0.10], radius: 9.0, ecc: 1.6,
                           border: 0.30, ring: 0.0, satellites: 3, tex_freq: 5.0 },
        // bkl: tan, waxy, ringed texture
        2 => ClassParams { color: [0.55, 0.38, 0.22], radius: 7.5, ecc: 1.15,
                           border: 0.12, ring: 0.5, satellites: 0, tex_freq: 7.0 },
        // bcc: pink-pearly, rolled ring border
        3 => ClassParams { color: [0.72, 0.45, 0.42], radius: 6.5, ecc: 1.1,
                           border: 0.10, ring: 0.8, satellites: 0, tex_freq: 3.0 },
        // akiec: red-brown rough patch, elongated
        4 => ClassParams { color: [0.60, 0.30, 0.24], radius: 7.0, ecc: 1.9,
                           border: 0.22, ring: 0.0, satellites: 1, tex_freq: 9.0 },
        // vasc: bright red, sharply round
        5 => ClassParams { color: [0.75, 0.15, 0.15], radius: 5.5, ecc: 1.0,
                           border: 0.03, ring: 0.0, satellites: 0, tex_freq: 1.0 },
        // df: small firm pink-brown with halo ring
        6 => ClassParams { color: [0.50, 0.32, 0.28], radius: 4.5, ecc: 1.05,
                           border: 0.08, ring: 1.0, satellites: 0, tex_freq: 2.5 },
        _ => unreachable!("class {class} out of range"),
    }
}

/// Sample a class from the HAM-like prior.
fn sample_class(rng: &mut Pcg32) -> usize {
    let u = rng.next_f64();
    let mut acc = 0.0;
    for (c, &p) in CLASS_PRIORS.iter().enumerate() {
        acc += p;
        if u < acc {
            return c;
        }
    }
    CLASSES - 1
}

/// Render one 3×32×32 sample of `class` into `out` (CHW layout).
pub fn render(class: usize, rng: &mut Pcg32, out: &mut [f32]) {
    debug_assert_eq!(out.len(), 3 * SIZE * SIZE);
    let p = class_params(class);

    // skin background with per-image tone jitter + mild vertical gradient
    let skin = [
        0.86 + rng.range_f32(-0.06, 0.06),
        0.66 + rng.range_f32(-0.06, 0.06),
        0.55 + rng.range_f32(-0.06, 0.06),
    ];

    // lesion pose jitter
    let cx = SIZE as f32 / 2.0 + rng.range_f32(-4.0, 4.0);
    let cy = SIZE as f32 / 2.0 + rng.range_f32(-4.0, 4.0);
    let radius = p.radius * rng.range_f32(0.8, 1.25);
    let theta = rng.range_f32(0.0, std::f32::consts::PI);
    let (sin_t, cos_t) = theta.sin_cos();
    let ecc = p.ecc * rng.range_f32(0.9, 1.15);

    // border irregularity: low-order random Fourier wobble of the radius
    let harmonics: Vec<(f32, f32, f32)> = (0..4)
        .map(|k| {
            (
                (k + 2) as f32,
                rng.range_f32(0.0, p.border),
                rng.range_f32(0.0, 2.0 * std::f32::consts::PI),
            )
        })
        .collect();

    // satellites
    let sats: Vec<(f32, f32, f32)> = (0..p.satellites)
        .map(|_| {
            let ang = rng.range_f32(0.0, 2.0 * std::f32::consts::PI);
            let dist = radius * rng.range_f32(1.2, 1.8);
            (cx + dist * ang.cos(), cy + dist * ang.sin(), rng.range_f32(1.0, 2.5))
        })
        .collect();

    let tex_phase = rng.range_f32(0.0, 6.28);
    let color_jit = [
        rng.range_f32(-0.05, 0.05),
        rng.range_f32(-0.05, 0.05),
        rng.range_f32(-0.05, 0.05),
    ];

    for y in 0..SIZE {
        for x in 0..SIZE {
            let fx = x as f32 - cx;
            let fy = y as f32 - cy;
            // rotate into lesion frame, apply eccentricity
            let u = (fx * cos_t + fy * sin_t) * ecc;
            let v = -fx * sin_t + fy * cos_t;
            let r = (u * u + v * v).sqrt();
            let ang = v.atan2(u);
            // wobbled boundary radius at this angle
            let mut boundary = radius;
            for &(k, amp, ph) in &harmonics {
                boundary += radius * amp * (k * ang + ph).sin();
            }
            // soft membership
            let d = (r - boundary) / (0.15 * radius).max(0.5);
            let mut mask = 1.0 / (1.0 + d.max(-20.0).min(20.0).exp());

            // satellites add their own blobs
            for &(sx, sy, sr) in &sats {
                let dd = ((x as f32 - sx).powi(2) + (y as f32 - sy).powi(2)).sqrt();
                mask = mask.max(1.0 / (1.0 + ((dd - sr) / 0.6).exp()));
            }

            // ring structure: brighten an annulus near the boundary
            let ring_w = 0.18 * radius;
            let ring_term =
                p.ring * (-((r - boundary).abs() - 0.0).powi(2) / (2.0 * ring_w * ring_w)).exp();

            // internal texture
            let tex = 0.5
                + 0.5
                    * ((p.tex_freq * (u / radius) + tex_phase).sin()
                        * (p.tex_freq * 0.8 * (v / radius) - tex_phase).cos());

            let idx = y * SIZE + x;
            for ch in 0..3 {
                let lesion =
                    (p.color[ch] + color_jit[ch]) * (0.75 + 0.35 * tex) + 0.20 * ring_term;
                let bg = skin[ch] * (1.0 - 0.002 * y as f32);
                let val = bg * (1.0 - mask) + lesion.clamp(0.0, 1.0) * mask
                    + rng.next_gaussian() * 0.025;
                out[ch * SIZE * SIZE + idx] = val.clamp(0.0, 1.0);
            }
        }
    }
}

/// Generate `n` samples with HAM-like class imbalance.
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Pcg32::new(seed, 0x4a6d);
    let per = 3 * SIZE * SIZE;
    let mut images = vec![0.0f32; n * per];
    let mut labels = vec![0u8; n];
    for i in 0..n {
        let class = sample_class(&mut rng);
        labels[i] = class as u8;
        render(class, &mut rng, &mut images[i * per..(i + 1) * per]);
    }
    Dataset::new("synth-ham", 3, SIZE, SIZE, CLASSES, images, labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::view::mean_std;

    #[test]
    fn generates_requested_count() {
        let d = generate(64, 0);
        assert_eq!(d.len(), 64);
        assert_eq!(d.channels, 3);
    }

    #[test]
    fn pixels_in_unit_range() {
        let d = generate(16, 1);
        for i in 0..d.len() {
            assert!(d.image(i).iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
    }

    #[test]
    fn class_imbalance_matches_priors() {
        let d = generate(4000, 2);
        let h = d.class_histogram();
        let p0 = h[0] as f64 / 4000.0;
        assert!((p0 - CLASS_PRIORS[0]).abs() < 0.05, "nv prior {p0}");
        assert!(h[0] > h[1], "nv must dominate");
    }

    #[test]
    fn same_class_samples_differ() {
        let mut rng = Pcg32::seeded(3);
        let mut a = vec![0.0f32; 3 * SIZE * SIZE];
        let mut b = vec![0.0f32; 3 * SIZE * SIZE];
        render(1, &mut rng, &mut a);
        render(1, &mut rng, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn classes_are_photometrically_distinct() {
        // mean red-channel intensity inside the image differs between the
        // bright-red vascular class (5) and the dark melanoma class (1)
        let mut rng = Pcg32::seeded(4);
        let mut mel = vec![0.0f32; 3 * SIZE * SIZE];
        let mut vasc = vec![0.0f32; 3 * SIZE * SIZE];
        let mut mel_red = 0.0;
        let mut vasc_red = 0.0;
        for _ in 0..8 {
            render(1, &mut rng, &mut mel);
            render(5, &mut rng, &mut vasc);
            // center crop 16x16 red channel
            for y in 8..24 {
                for x in 8..24 {
                    mel_red += mel[y * SIZE + x];
                    vasc_red += vasc[y * SIZE + x];
                }
            }
        }
        // melanoma lesions are darker than vascular ones in the red channel
        assert!(mel_red < vasc_red, "mel {mel_red} vs vasc {vasc_red}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(8, 9);
        let b = generate(8, 9);
        assert_eq!(a.image(5), b.image(5));
        assert_eq!(a.labels(), b.labels());
    }

    #[test]
    fn images_have_structure_not_noise() {
        // within-image std should be non-trivial (lesion vs background)
        let d = generate(8, 10);
        for i in 0..8 {
            let (_, s) = mean_std(d.image(i));
            assert!(s > 0.03, "image {i} looks flat (std {s})");
        }
    }
}
