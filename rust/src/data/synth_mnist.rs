//! Synthetic MNIST surrogate: 10-class parametric digit-stroke generator.
//!
//! Each digit class is a fixed polyline skeleton in the unit square (the
//! canonical 7-segment-ish stroke layout of that digit); samples apply a
//! random affine transform (translation / scale / rotation / shear), stroke
//! thickness jitter, and pixel noise, then render with a smooth
//! distance-to-segment intensity profile. 1×32×32, balanced classes —
//! matching MNIST's role in the paper as the "easy, near-balanced" dataset
//! against HAM's "hard, imbalanced" one.

use super::Dataset;
use crate::util::rng::Pcg32;

pub const CLASSES: usize = 10;
pub const SIZE: usize = 32;

type Seg = ((f32, f32), (f32, f32));

/// Stroke skeleton per digit, coordinates in [0,1]² (y down).
fn skeleton(digit: usize) -> Vec<Seg> {
    // corner shorthand (7-segment-style box 0.2..0.8 x 0.1..0.9)
    let tl = (0.25, 0.12);
    let tr = (0.75, 0.12);
    let ml = (0.25, 0.50);
    let mr = (0.75, 0.50);
    let bl = (0.25, 0.88);
    let br = (0.75, 0.88);
    match digit {
        0 => vec![(tl, tr), (tr, br), (br, bl), (bl, tl)],
        1 => vec![((0.5, 0.10), (0.5, 0.90)), ((0.35, 0.25), (0.5, 0.10))],
        2 => vec![(tl, tr), (tr, mr), (mr, ml), (ml, bl), (bl, br)],
        3 => vec![(tl, tr), (tr, mr), (ml, mr), (mr, br), (br, bl)],
        4 => vec![(tl, ml), (ml, mr), (tr, mr), (mr, br)],
        5 => vec![(tr, tl), (tl, ml), (ml, mr), (mr, br), (br, bl)],
        6 => vec![(tr, tl), (tl, bl), (bl, br), (br, mr), (mr, ml)],
        7 => vec![(tl, tr), (tr, (0.45, 0.88))],
        8 => vec![(tl, tr), (tr, br), (br, bl), (bl, tl), (ml, mr)],
        9 => vec![(mr, ml), (ml, tl), (tl, tr), (tr, br), (br, bl)],
        _ => unreachable!("digit {digit} out of range"),
    }
}

/// Distance from point to segment.
fn seg_dist(px: f32, py: f32, ((x1, y1), (x2, y2)): Seg) -> f32 {
    let (dx, dy) = (x2 - x1, y2 - y1);
    let len2 = dx * dx + dy * dy;
    let t = if len2 > 0.0 {
        (((px - x1) * dx + (py - y1) * dy) / len2).clamp(0.0, 1.0)
    } else {
        0.0
    };
    let (cx, cy) = (x1 + t * dx, y1 + t * dy);
    ((px - cx).powi(2) + (py - cy).powi(2)).sqrt()
}

/// Render one 1×32×32 sample of `digit` into `out`.
pub fn render(digit: usize, rng: &mut Pcg32, out: &mut [f32]) {
    debug_assert_eq!(out.len(), SIZE * SIZE);
    let segs = skeleton(digit);

    // random affine: rotation, anisotropic scale, shear, translation
    let theta = rng.range_f32(-0.25, 0.25);
    let (sin_t, cos_t) = theta.sin_cos();
    let sx = rng.range_f32(0.8, 1.15);
    let sy = rng.range_f32(0.8, 1.15);
    let shear = rng.range_f32(-0.15, 0.15);
    let tx = rng.range_f32(-0.08, 0.08);
    let ty = rng.range_f32(-0.08, 0.08);
    let thick = rng.range_f32(0.035, 0.065);

    let transform = |(x, y): (f32, f32)| -> (f32, f32) {
        // center, affine, re-center
        let (cx, cy) = (x - 0.5, y - 0.5);
        let (ax, ay) = (sx * (cx + shear * cy), sy * cy);
        let (rx, ry) = (ax * cos_t - ay * sin_t, ax * sin_t + ay * cos_t);
        (rx + 0.5 + tx, ry + 0.5 + ty)
    };
    let tsegs: Vec<Seg> = segs.iter().map(|&(a, b)| (transform(a), transform(b))).collect();

    for y in 0..SIZE {
        for x in 0..SIZE {
            let px = (x as f32 + 0.5) / SIZE as f32;
            let py = (y as f32 + 0.5) / SIZE as f32;
            let mut d = f32::INFINITY;
            for &s in &tsegs {
                d = d.min(seg_dist(px, py, s));
            }
            let ink = (-d * d / (2.0 * thick * thick)).exp();
            let val = ink + rng.next_gaussian() * 0.04;
            out[y * SIZE + x] = val.clamp(0.0, 1.0);
        }
    }
}

/// Generate `n` balanced samples (class = i mod 10 before shuffling).
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Pcg32::new(seed, 0x6e157);
    let per = SIZE * SIZE;
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut images = vec![0.0f32; n * per];
    let mut labels = vec![0u8; n];
    for (slot, &i) in order.iter().enumerate() {
        let class = i % CLASSES;
        labels[slot] = class as u8;
        render(class, &mut rng, &mut images[slot * per..(slot + 1) * per]);
    }
    Dataset::new("synth-mnist", 1, SIZE, SIZE, CLASSES, images, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_classes() {
        let d = generate(1000, 0);
        let h = d.class_histogram();
        for (c, &count) in h.iter().enumerate() {
            assert!(count == 100, "class {c}: {count}");
        }
    }

    #[test]
    fn pixels_in_unit_range() {
        let d = generate(20, 1);
        for i in 0..d.len() {
            assert!(d.image(i).iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
    }

    #[test]
    fn digit_one_thinner_than_eight() {
        // total ink of '1' must be well below '8'
        let mut rng = Pcg32::seeded(2);
        let mut one = vec![0.0f32; SIZE * SIZE];
        let mut eight = vec![0.0f32; SIZE * SIZE];
        let (mut ink1, mut ink8) = (0.0f32, 0.0f32);
        for _ in 0..8 {
            render(1, &mut rng, &mut one);
            render(8, &mut rng, &mut eight);
            ink1 += one.iter().sum::<f32>();
            ink8 += eight.iter().sum::<f32>();
        }
        assert!(ink1 * 1.5 < ink8, "ink1={ink1} ink8={ink8}");
    }

    #[test]
    fn same_digit_varies() {
        let mut rng = Pcg32::seeded(3);
        let mut a = vec![0.0f32; SIZE * SIZE];
        let mut b = vec![0.0f32; SIZE * SIZE];
        render(7, &mut rng, &mut a);
        render(7, &mut rng, &mut b);
        assert_ne!(a, b);
        // but both still contain ink
        assert!(a.iter().sum::<f32>() > 10.0);
        assert!(b.iter().sum::<f32>() > 10.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(10, 4);
        let b = generate(10, 4);
        assert_eq!(a.image(3), b.image(3));
    }

    #[test]
    fn seg_dist_basics() {
        // point on segment
        assert!(seg_dist(0.5, 0.5, ((0.0, 0.5), (1.0, 0.5))) < 1e-6);
        // perpendicular distance
        assert!((seg_dist(0.5, 0.8, ((0.0, 0.5), (1.0, 0.5))) - 0.3).abs() < 1e-6);
        // beyond endpoint
        assert!((seg_dist(1.5, 0.5, ((0.0, 0.5), (1.0, 0.5))) - 0.5).abs() < 1e-6);
    }
}
