//! Train-set partitioning across edge devices (paper Sec. III-A2).
//!
//! * IID: global shuffle, equal contiguous shards.
//! * Non-IID: Dirichlet(β) label-skew — for every class, the class's
//!   samples are split across devices with proportions drawn from
//!   Dirichlet(β); β = 0.5 in the paper. Smaller β ⇒ more skew.

use super::Dataset;
use crate::util::rng::Pcg32;

/// How the training set is split across devices.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Partition {
    Iid,
    Dirichlet { beta: f64 },
}

impl Partition {
    pub fn label(&self) -> String {
        match self {
            Partition::Iid => "iid".into(),
            Partition::Dirichlet { beta } => format!("dirichlet{beta}"),
        }
    }
}

/// Per-device sample indices into the parent dataset.
#[derive(Debug, Clone)]
pub struct Shards {
    pub shards: Vec<Vec<usize>>,
}

impl Shards {
    pub fn device(&self, d: usize) -> &[usize] {
        &self.shards[d]
    }

    pub fn n_devices(&self) -> usize {
        self.shards.len()
    }

    /// Assert the shards form a partition of 0..n.
    pub fn validate(&self, n: usize) -> Result<(), String> {
        let mut seen = vec![false; n];
        for (d, shard) in self.shards.iter().enumerate() {
            for &i in shard {
                if i >= n {
                    return Err(format!("device {d}: index {i} >= {n}"));
                }
                if seen[i] {
                    return Err(format!("index {i} assigned twice"));
                }
                seen[i] = true;
            }
        }
        match seen.iter().position(|&s| !s) {
            Some(i) => Err(format!("index {i} unassigned")),
            None => Ok(()),
        }
    }
}

/// Split `data` across `devices` according to `p`.
pub fn partition(data: &Dataset, devices: usize, p: Partition, seed: u64) -> Shards {
    assert!(devices >= 1);
    let n = data.len();
    let mut rng = Pcg32::new(seed, 0x9a47);
    match p {
        Partition::Iid => {
            let mut idx: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut idx);
            let mut shards = vec![Vec::with_capacity(n / devices + 1); devices];
            for (j, i) in idx.into_iter().enumerate() {
                shards[j % devices].push(i);
            }
            Shards { shards }
        }
        Partition::Dirichlet { beta } => {
            let mut shards = vec![Vec::new(); devices];
            for class in 0..data.classes {
                let mut members: Vec<usize> =
                    (0..n).filter(|&i| data.label(i) as usize == class).collect();
                if members.is_empty() {
                    continue;
                }
                rng.shuffle(&mut members);
                let props = rng.dirichlet(beta, devices);
                // cumulative proportional cut points
                let m = members.len();
                let mut start = 0usize;
                let mut acc = 0.0f64;
                for (d, &p_d) in props.iter().enumerate() {
                    acc += p_d;
                    let end = if d + 1 == devices {
                        m
                    } else {
                        (acc * m as f64).round() as usize
                    };
                    let end = end.clamp(start, m);
                    shards[d].extend_from_slice(&members[start..end]);
                    start = end;
                }
            }
            // guarantee every device has at least one sample (steal from the
            // largest shard) so training never divides by zero
            for d in 0..devices {
                if shards[d].is_empty() {
                    let donor = (0..devices)
                        .max_by_key(|&j| shards[j].len())
                        .unwrap();
                    if shards[donor].len() > 1 {
                        let x = shards[donor].pop().unwrap();
                        shards[d].push(x);
                    }
                }
            }
            Shards { shards }
        }
    }
}

/// Label-distribution skew measure: mean total-variation distance between
/// each device's label distribution and the global one. 0 = perfectly IID.
pub fn label_skew(data: &Dataset, shards: &Shards) -> f64 {
    let classes = data.classes;
    let global = data.class_histogram();
    let n = data.len() as f64;
    let gp: Vec<f64> = global.iter().map(|&c| c as f64 / n).collect();
    let mut total = 0.0;
    for shard in &shards.shards {
        let mut h = vec![0usize; classes];
        for &i in shard {
            h[data.label(i) as usize] += 1;
        }
        let sn = shard.len().max(1) as f64;
        let tv: f64 = (0..classes)
            .map(|c| (h[c] as f64 / sn - gp[c]).abs())
            .sum::<f64>()
            / 2.0;
        total += tv;
    }
    total / shards.n_devices() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_mnist;

    #[test]
    fn iid_is_a_partition() {
        let d = synth_mnist::generate(103, 0);
        let s = partition(&d, 5, Partition::Iid, 1);
        s.validate(103).unwrap();
        // near-equal sizes
        for shard in &s.shards {
            assert!((20..=21).contains(&shard.len()));
        }
    }

    #[test]
    fn dirichlet_is_a_partition() {
        let d = synth_mnist::generate(200, 0);
        let s = partition(&d, 5, Partition::Dirichlet { beta: 0.5 }, 1);
        s.validate(200).unwrap();
        for shard in &s.shards {
            assert!(!shard.is_empty());
        }
    }

    #[test]
    fn dirichlet_skews_more_than_iid() {
        let d = synth_mnist::generate(1000, 2);
        let iid = partition(&d, 5, Partition::Iid, 3);
        let nid = partition(&d, 5, Partition::Dirichlet { beta: 0.5 }, 3);
        let (s_iid, s_nid) = (label_skew(&d, &iid), label_skew(&d, &nid));
        assert!(s_nid > s_iid + 0.05, "iid {s_iid} vs dirichlet {s_nid}");
    }

    #[test]
    fn smaller_beta_skews_more() {
        let d = synth_mnist::generate(1000, 4);
        let mild = partition(&d, 5, Partition::Dirichlet { beta: 10.0 }, 5);
        let harsh = partition(&d, 5, Partition::Dirichlet { beta: 0.1 }, 5);
        assert!(label_skew(&d, &harsh) > label_skew(&d, &mild));
    }

    #[test]
    fn deterministic() {
        let d = synth_mnist::generate(100, 5);
        let a = partition(&d, 4, Partition::Dirichlet { beta: 0.5 }, 7);
        let b = partition(&d, 4, Partition::Dirichlet { beta: 0.5 }, 7);
        assert_eq!(a.shards, b.shards);
    }

    #[test]
    fn single_device_gets_everything() {
        let d = synth_mnist::generate(50, 6);
        let s = partition(&d, 1, Partition::Iid, 0);
        assert_eq!(s.shards[0].len(), 50);
        s.validate(50).unwrap();
    }
}
