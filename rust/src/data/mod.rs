//! Dataset substrate.
//!
//! The paper evaluates on HAM10000 (7-class dermatoscopy) and MNIST. Both
//! are gated on this image (no network access), so we build procedural
//! generators that preserve the properties the experiments exercise —
//! multi-class image classification with class-dependent spatial structure,
//! HAM-like class imbalance, and enough intra-class variation that the
//! model must actually learn (see DESIGN.md §Substitutions):
//!
//! * [`synth_ham`] — 7-class 3×32×32 "lesion" generator (class-coded blob
//!   morphology / colour / border irregularity, imbalanced priors).
//! * [`synth_mnist`] — 10-class 1×32×32 parametric digit strokes.
//!
//! [`partition`] implements the paper's IID and Dirichlet(β) non-IID splits;
//! [`loader`] provides per-device shuffled batch iteration.

pub mod loader;
pub mod partition;
pub mod synth_ham;
pub mod synth_mnist;

/// An in-memory labelled image dataset (NCHW f32, labels 0..classes).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub channels: usize,
    pub height: usize,
    pub width: usize,
    pub classes: usize,
    /// n * channels * height * width, row-major NCHW
    images: Vec<f32>,
    labels: Vec<u8>,
}

impl Dataset {
    pub fn new(name: &str, channels: usize, height: usize, width: usize,
               classes: usize, images: Vec<f32>, labels: Vec<u8>) -> Dataset {
        let per = channels * height * width;
        assert_eq!(images.len(), labels.len() * per);
        assert!(labels.iter().all(|&l| (l as usize) < classes));
        Dataset {
            name: name.to_string(),
            channels,
            height,
            width,
            classes,
            images,
            labels,
        }
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn image(&self, i: usize) -> &[f32] {
        let per = self.channels * self.height * self.width;
        &self.images[i * per..(i + 1) * per]
    }

    pub fn label(&self, i: usize) -> u8 {
        self.labels[i]
    }

    pub fn labels(&self) -> &[u8] {
        &self.labels
    }

    /// Gather a batch into a contiguous NCHW buffer + i32 labels.
    pub fn batch(&self, indices: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let per = self.channels * self.height * self.width;
        let mut x = Vec::with_capacity(indices.len() * per);
        let mut y = Vec::with_capacity(indices.len());
        for &i in indices {
            x.extend_from_slice(self.image(i));
            y.push(self.labels[i] as i32);
        }
        (x, y)
    }

    /// Per-class sample counts.
    pub fn class_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.classes];
        for &l in &self.labels {
            h[l as usize] += 1;
        }
        h
    }

    /// Build the train/test pair for a named config ("ham" | "mnist").
    pub fn for_config(name: &str, train_n: usize, test_n: usize, seed: u64)
                      -> Result<(Dataset, Dataset), String> {
        match name {
            "ham" => Ok((
                synth_ham::generate(train_n, seed),
                synth_ham::generate(test_n, seed ^ 0x7e57),
            )),
            "mnist" => Ok((
                synth_mnist::generate(train_n, seed),
                synth_mnist::generate(test_n, seed ^ 0x7e57),
            )),
            other => Err(format!("unknown dataset '{other}' (want ham|mnist)")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_gathers_correct_samples() {
        let d = synth_mnist::generate(16, 0);
        let (x, y) = d.batch(&[3, 7]);
        assert_eq!(x.len(), 2 * 32 * 32);
        assert_eq!(y.len(), 2);
        assert_eq!(&x[..1024], d.image(3));
        assert_eq!(y[0], d.label(3) as i32);
    }

    #[test]
    fn for_config_dispatches() {
        let (tr, te) = Dataset::for_config("ham", 32, 16, 1).unwrap();
        assert_eq!(tr.len(), 32);
        assert_eq!(te.len(), 16);
        assert_eq!(tr.channels, 3);
        assert!(Dataset::for_config("bogus", 1, 1, 0).is_err());
    }

    #[test]
    fn train_test_differ() {
        let (tr, te) = Dataset::for_config("mnist", 8, 8, 5).unwrap();
        assert_ne!(tr.image(0), te.image(0));
    }
}
